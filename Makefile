# Top-level entry points. Tier-1 verification is `make verify`.

.PHONY: build test verify fmt clippy artifacts clean

build:
	cargo build --release

test:
	cargo test -q

verify: build test

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

# AOT-lower the Pallas/JAX models to HLO-text artifacts (needs the
# python/ toolchain; the Rust request path then never runs Python).
artifacts:
	cd python && python -m compile.aot

clean:
	cargo clean
