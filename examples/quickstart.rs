//! Quickstart: the 60-second tour of the public API.
//!
//! Prepares a small corpus, trains a KeyNet through the AOT train-step
//! artifact, and shows the drop-in query-mapping win on an IVF index.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use amips::bench_support::fixtures;
use amips::coordinator::pipeline::{recall_against_truth, MappedSearchPipeline};
use amips::index::ivf::IvfIndex;
use amips::runtime::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    // 1. Artifacts + engine (PJRT CPU).
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;

    // 2. A prepared dataset: synthetic corpus + exact-MIPS targets.
    let config = "fiqa-s.keynet.s.l4.c1";
    let ds = fixtures::prepare_dataset(&manifest, "fiqa-s", 1)?;
    println!(
        "dataset fiqa-s: {} keys, {} train queries, {} val queries",
        ds.n_keys(),
        ds.train.x.rows(),
        ds.val.x.rows()
    );

    // 3. Train (or load a cached checkpoint of) the amortized model.
    //    The Adam step itself is an AOT-compiled XLA executable.
    let model = fixtures::trained_model(&engine, &manifest, config, &ds, None)?;
    println!(
        "model {}: {} params, {} flops/query",
        config,
        model.meta.n_params,
        model.score_flops()
    );

    // 4. Build a standard IVF index over the keys — never modified.
    let index = IvfIndex::build(&ds.keys, fixtures::default_nlist(ds.n_keys()), 15, 42);

    // 5. Compare original vs mapped queries at a few probe budgets.
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();
    // Recall@5%: the paper reports Recall@{0.01..0.5}% on corpora ~100x
    // larger; keeping the *absolute* candidate count comparable (~100)
    // means a proportionally larger fraction here (DESIGN.md §3).
    let k = (ds.n_keys() as f64 * 0.05).ceil() as usize;
    println!("\n{:>7}  {:>10}  {:>10}", "nprobe", "orig R", "mapped R");
    for nprobe in [1usize, 2, 4, 8] {
        let orig = MappedSearchPipeline::original(&index).run(&ds.val.x, k, nprobe)?;
        let mapped = MappedSearchPipeline::mapped(&index, &model).run(&ds.val.x, k, nprobe)?;
        println!(
            "{:>7}  {:>9.1}%  {:>9.1}%",
            nprobe,
            100.0 * recall_against_truth(&orig.results, &truth, k),
            100.0 * recall_against_truth(&mapped.results, &truth, k),
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
