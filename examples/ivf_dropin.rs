//! Drop-in integration demo (paper Sec. 4.4 / Fig. 5): feed KeyNet's
//! predicted key ŷ(x) to an *unmodified* IVF index in place of the query
//! and trace recall vs nprobe/FLOPs/latency for original vs mapped.
//!
//! ```bash
//! cargo run --release --example ivf_dropin -- --dataset nq-s --size s [--steps N]
//! ```

use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::coordinator::pipeline::{recall_against_truth, MappedSearchPipeline};
use amips::index::ivf::IvfIndex;
use amips::index::traits::VectorIndex;
use amips::runtime::Engine;
use amips::cli::Args;
use amips::trainer::TrainOpts;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let dataset = args.get_or("dataset", "nq-s").to_string();
    let size = args.get_or("size", "s").to_string();
    let steps = args.get_usize("steps", 0)?;
    let frac = args.get_f32("recall-frac", 0.01)?;
    args.reject_unknown()?;

    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let config = format!("{dataset}.keynet.{size}.l4.c1");
    let ds = fixtures::prepare_dataset(&manifest, &dataset, 1)?;
    let opts = (steps > 0).then(|| TrainOpts {
        steps,
        ..TrainOpts::default()
    });
    let model = fixtures::trained_model(&engine, &manifest, &config, &ds, opts)?;

    let nlist = fixtures::default_nlist(ds.n_keys());
    let index = IvfIndex::build(&ds.keys, nlist, 15, 42);
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();
    let k = ((ds.n_keys() as f32 * frac).ceil() as usize).max(1);

    let mut rep = Report::new(&format!(
        "IVF drop-in: {config} vs orig (nlist={nlist}, Recall@{:.2}%={k})",
        frac * 100.0
    ));
    rep.header(&[
        "nprobe", "orig R", "mapped R", "orig MFLOP", "mapped MFLOP", "orig ms/q", "mapped ms/q",
    ]);
    for nprobe in [1usize, 2, 4, 8, 16, 32] {
        if nprobe > nlist {
            break;
        }
        let orig = MappedSearchPipeline::original(&index).run(&ds.val.x, k, nprobe)?;
        let mapped = MappedSearchPipeline::mapped(&index, &model).run(&ds.val.x, k, nprobe)?;
        let nq = ds.val.x.rows() as f64;
        let orig_flops = orig.results[0].cost.flops as f64 / 1e6;
        let mapped_flops =
            (mapped.results[0].cost.flops + mapped.map_flops_per_query) as f64 / 1e6;
        rep.row(&[
            nprobe.to_string(),
            pct(recall_against_truth(&orig.results, &truth, k)),
            pct(recall_against_truth(&mapped.results, &truth, k)),
            format!("{orig_flops:.3}"),
            format!("{mapped_flops:.3}"),
            format!("{:.3}", (orig.map_seconds + orig.search_seconds) / nq * 1e3),
            format!(
                "{:.3}",
                (mapped.map_seconds + mapped.search_seconds) / nq * 1e3
            ),
        ]);
    }
    rep.emit("ivf_dropin");
    Ok(())
}
