#!/usr/bin/env python3
"""Perf-regression gate over the hot-path bench trajectory.

Compares the BENCH_hotpath.json produced by the current run against the
committed baseline at the repo root and fails (exit 1) when any row's
GFLOP/s drops by more than --threshold (default 25%) relative to the
baseline. Rows are keyed by (backend, mode, kernel, batch) so the SIMD
and forced-scalar passes gate independently.

Intentional softness — this is a regression tripwire, not a lab:
  * rows missing from either side are warned about, never fatal (the
    detected kernel tier differs across machines, so a baseline recorded
    on avx2fma hardware has rows a NEON/scalar runner can't produce);
  * rows without a finite positive gflops value (e.g. the threaded
    Searcher row and the bench_startup latency/RSS rows) are skipped.

The gate is ARMED: any gated row regressing past the threshold fails
the run. CI skips the whole step only when the PR carries the
`skip-bench-gate` label (for intentional trade-offs; say why in the PR
description).

Usage:
    python3 scripts/bench_gate.py \
        --current rust/BENCH_hotpath.json --baseline BENCH_hotpath.json
"""

from __future__ import annotations

import argparse
import json
import math
import sys


KEY_FIELDS = ("backend", "mode", "kernel", "batch")


def row_key(row):
    return tuple(row.get(f) for f in KEY_FIELDS)


def gated_rows(doc):
    """Map row key -> gflops for every row with a usable throughput."""
    out = {}
    for row in doc.get("rows", []):
        g = row.get("gflops")
        if not isinstance(g, (int, float)) or not math.isfinite(g) or g <= 0:
            continue
        out[row_key(row)] = float(g)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", required=True, help="JSON from this run")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="max tolerated fractional GFLOP/s drop (default 0.25)",
    )
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline_doc = json.load(f)
    with open(args.current) as f:
        current_doc = json.load(f)

    baseline = gated_rows(baseline_doc)
    current = gated_rows(current_doc)

    if not baseline:
        print("bench gate: baseline has no gatable rows; nothing to compare")
        return 0

    failures = []
    compared = 0
    for key, base_g in sorted(baseline.items()):
        cur_g = current.get(key)
        label = "/".join(str(k) for k in key)
        if cur_g is None:
            print(f"  warn: {label}: row missing from current run (skipped)")
            continue
        compared += 1
        drop = (base_g - cur_g) / base_g
        status = "ok"
        if drop > args.threshold:
            status = "FAIL"
            failures.append((label, base_g, cur_g, drop))
        print(
            f"  {status:4} {label}: {base_g:.2f} -> {cur_g:.2f} GFLOP/s "
            f"({-drop:+.1%})"
        )

    for key in sorted(set(current) - set(baseline)):
        label = "/".join(str(k) for k in key)
        print(f"  note: {label}: new row with no baseline (not gated)")

    if compared == 0:
        print("bench gate: no overlapping rows (different machine tier?); passing")
        return 0

    if failures:
        print(
            f"\nbench gate: {len(failures)}/{compared} rows regressed more than "
            f"{args.threshold:.0%}:"
        )
        for label, base_g, cur_g, drop in failures:
            print(f"  {label}: {base_g:.2f} -> {cur_g:.2f} GFLOP/s (-{drop:.1%})")
        print(
            "If the regression is an intentional trade-off, apply the "
            "`skip-bench-gate` label and explain it in the PR; otherwise "
            "refresh the baseline from a CI artifact alongside the fix."
        )
        return 1

    print(f"\nbench gate: all {compared} rows within {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
