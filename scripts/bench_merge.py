#!/usr/bin/env python3
"""Merge bench row files into one trajectory artifact.

CI runs several pure-Rust benches (perf_hotpath, bench_startup) that
each emit their own BENCH_<name>.json. The uploaded artifact — and the
committed baseline scripts/bench_gate.py compares against — is a single
BENCH_hotpath.json, so the extra benches' rows are folded into it here.

Rows keep their provenance in a `bench` field; duplicate rows (same
bench + identical content) are dropped so re-running the merge is
idempotent. The gate keys on (backend, mode, kernel, batch) and skips
rows without a finite positive gflops, so merged startup rows (which
carry `"gflops": null`) ride along ungated.

Usage:
    python3 scripts/bench_merge.py \
        --into rust/BENCH_hotpath.json rust/BENCH_startup.json [more.json]
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--into", required=True, help="target JSON (modified in place)")
    ap.add_argument("sources", nargs="+", help="BENCH_*.json files to fold in")
    args = ap.parse_args()

    target = load(args.into)
    rows = target.get("rows", [])
    for row in rows:
        row.setdefault("bench", target.get("bench", "hotpath"))
    seen = {json.dumps(r, sort_keys=True) for r in rows}

    added = 0
    for src_path in args.sources:
        try:
            src = load(src_path)
        except FileNotFoundError:
            print(f"bench merge: {src_path} missing (bench not run?); skipping")
            continue
        name = src.get("bench", src_path)
        for row in src.get("rows", []):
            row.setdefault("bench", name)
            key = json.dumps(row, sort_keys=True)
            if key in seen:
                continue
            seen.add(key)
            rows.append(row)
            added += 1

    target["rows"] = rows
    with open(args.into, "w") as f:
        json.dump(target, f, indent=2)
        f.write("\n")
    print(f"bench merge: {args.into} now holds {len(rows)} rows (+{added})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
