"""L2 model properties: sizing rule, convexity/homogeneity structure,
pallas/jnp path equality, envelope-theorem consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import losses, model as M, sizing, train


def small_arch(model="supportnet", c=1, residual=False, nx=None, layers=3,
               d=16, h=24):
    return M.Arch(model=model, d=d, c=c, h=h, layers=layers,
                  nx=layers if nx is None else nx, residual=residual,
                  homogenize=model == "supportnet")


def init(arch, seed=0):
    return M.init_params(arch, jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# sizing rule (Eq 3.3)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    rho=st.sampled_from([0.01, 0.05, 0.1, 0.2, 0.4]),
    n=st.integers(1000, 100000),
    d=st.sampled_from([32, 64, 128, 384]),
    layers=st.sampled_from([2, 4, 8, 16]),
)
def test_sizing_hits_budget(rho, n, d, layers):
    """param_count(width_for_budget(P)) stays within ~35% of P for budgets
    that dominate the bias/head terms."""
    P = rho * n * d
    nx = layers
    h = sizing.width_for_budget(P, layers, d, nx)
    got = sizing.param_count(d, h, layers, nx, d_out=1)
    if P > 20 * d:  # tiny budgets are floored at h=8 by design
        assert got <= max(2.0 * P, got)  # sanity: finite
        assert abs(got - P) / P < 0.6 or h == 8


def test_sizing_limiting_cases():
    # Deep: h ~ sqrt(P/(L-1))
    P, d, L = 1e6, 64, 17
    h = sizing.width_for_budget(P, L, d, nx=0)
    assert abs(h - (P / (L - 1)) ** 0.5) / h < 0.2
    # Shallow + dense reinjection: h ~ P / D
    L = 2
    nx = 1
    h = sizing.width_for_budget(P, L, d, nx=nx)
    # (L-1)h^2 term still matters here; just check monotonicity vs nx
    h_dense = sizing.width_for_budget(P, L, d, nx=0)
    assert h <= h_dense


def test_inject_layers_spacing():
    assert sizing.inject_layers(4, 4) == [1, 2, 3]
    assert sizing.inject_layers(4, 0) == []
    assert sizing.inject_layers(8, 2) == [4, 7]
    for L in (2, 4, 8, 16):
        for nx in range(0, L + 2):
            inj = sizing.inject_layers(L, nx)
            assert all(1 <= i <= L - 1 for i in inj)
            assert len(inj) == len(set(inj))


# ---------------------------------------------------------------------------
# architecture structure
# ---------------------------------------------------------------------------

def test_param_specs_shapes_match_init():
    for model in ("supportnet", "keynet"):
        for c in (1, 3):
            arch = small_arch(model, c=c)
            params = init(arch)
            specs = M.param_specs(arch)
            assert len(params) == len(specs)
            for p, (_, s) in zip(params, specs):
                assert p.shape == s


def test_supportnet_homogeneous():
    """H[g](a x) = a H[g](x) for a > 0 (Eq. 3.4)."""
    arch = small_arch("supportnet", c=2)
    params = init(arch)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, arch.d))
    f1 = M.forward(params, x, arch)
    for a in (0.5, 2.0, 7.3):
        fa = M.forward(params, a * x, arch)
        np.testing.assert_allclose(fa, a * f1, rtol=1e-4, atol=1e-5)


def test_supportnet_convex_along_segments():
    """With the non-negative init, f(mid) <= (f(a)+f(b))/2 along random
    segments — convexity the ICNN structure should deliver at init."""
    arch = M.Arch(model="supportnet", d=12, c=1, h=32, layers=3, nx=3,
                  homogenize=False)  # homogenization breaks convexity checks
    params = init(arch)
    key = jax.random.PRNGKey(2)
    a, b = jax.random.normal(key, (2, 64, arch.d))
    fa = M.forward(params, a, arch)[:, 0]
    fb = M.forward(params, b, arch)[:, 0]
    fm = M.forward(params, (a + b) / 2, arch)[:, 0]
    assert (fm <= (fa + fb) / 2 + 1e-4).all()


def test_keynet_output_shape():
    arch = small_arch("keynet", c=4)
    params = init(arch)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, arch.d))
    out = M.forward(params, x, arch)
    assert out.shape == (5, 4, arch.d)
    scores, keys = M.keynet_scores_and_keys(params, x, arch)
    assert scores.shape == (5, 4)
    np.testing.assert_allclose(scores, jnp.einsum("bcd,bd->bc", keys, x),
                               rtol=1e-5)


def test_pallas_and_jnp_paths_agree():
    """The serving HLO (pallas) and train graph (jnp) must be numerically
    identical."""
    for model in ("supportnet", "keynet"):
        arch = M.Arch(model=model, d=16, c=2, h=32, layers=4, nx=4,
                      homogenize=model == "supportnet")
        params = init(arch, seed=5)
        x = jax.random.normal(jax.random.PRNGKey(6), (64, arch.d))
        a = M.forward(params, x, arch, use_pallas=False)
        b = M.forward(params, x, arch, use_pallas=True)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_residual_paths_agree():
    arch = M.Arch(model="keynet", d=16, c=1, h=32, layers=4, nx=4,
                  residual=True, homogenize=False)
    params = init(arch, seed=8)
    x = jax.random.normal(jax.random.PRNGKey(9), (32, arch.d))
    a = M.forward(params, x, arch, use_pallas=False)
    b = M.forward(params, x, arch, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_supportnet_envelope_consistency():
    """Euler's theorem: for the homogenized net, <grad f(x), x> == f(x)."""
    arch = small_arch("supportnet", c=2)
    params = init(arch)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, arch.d))
    scores, keys = M.supportnet_scores_and_keys(params, x, arch)
    euler = jnp.einsum("bcd,bd->bc", keys, x)
    np.testing.assert_allclose(euler, scores, rtol=1e-3, atol=1e-4)


def test_icnn_penalty_zero_at_nonneg_init():
    arch = small_arch("supportnet")
    params = init(arch)
    assert float(M.icnn_penalty(params, arch)) == pytest.approx(0.0, abs=1e-9)
    # and positive once a Wz goes negative
    idx = M.wz_param_indices(arch)[0]
    params[idx] = params[idx] - 1.0
    assert float(M.icnn_penalty(params, arch)) > 0.0


# ---------------------------------------------------------------------------
# losses + train step
# ---------------------------------------------------------------------------

def _fake_batch(arch, B=32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (B, arch.d))
    x = x / jnp.linalg.norm(x, axis=1, keepdims=True)
    y = jax.random.normal(k2, (B, arch.c, arch.d))
    y = y / jnp.linalg.norm(y, axis=-1, keepdims=True)
    sigma = jnp.einsum("bcd,bd->bc", y, x)
    return x, y, sigma


@pytest.mark.parametrize("model", ["supportnet", "keynet"])
def test_train_step_reduces_loss(model):
    arch = small_arch(model, c=2)
    state = train.init_state(arch, jnp.uint32(0))
    x, y, sigma = _fake_batch(arch)
    hp = jnp.asarray([0.01, 1.0, 1e-4, 3e-3, 200.0, 0.025, 0.99, 0.0],
                     jnp.float32)
    losses_seen = []
    for _ in range(60):
        state, metrics = train.train_step(state, x, y, sigma, hp, arch)
        losses_seen.append(float(metrics[0]))
    assert losses_seen[-1] < 0.5 * losses_seen[0], losses_seen[::20]


def test_train_step_state_shapes_stable():
    arch = small_arch("keynet")
    state = train.init_state(arch, jnp.uint32(1))
    x, y, sigma = _fake_batch(arch)
    hp = jnp.asarray([0.01, 1.0, 0.0, 1e-3, 100.0, 0.1, 0.999, 0.0])
    new_state, metrics = train.train_step(state, x, y, sigma, hp, arch)
    assert len(new_state) == len(state)
    for a, b in zip(state, new_state):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert metrics.shape == (4,)
    assert float(new_state[-1]) == 1.0  # step counter


def test_lr_schedule_shape():
    total, warm, peak = 1000.0, 0.025, 1e-3
    lrs = [float(train.lr_schedule(jnp.float32(s), total, warm, peak))
           for s in range(0, 1001, 25)]
    assert max(lrs) <= peak * 1.0001
    assert lrs[-1] < 1e-5           # cosine decays to ~0
    assert lrs[0] < lrs[1]          # warmup rises


def test_relative_transport_error_zero_baseline():
    """E_rel = 0 when prediction == query (identity predictor)."""
    arch = small_arch("keynet", c=1)
    x, y, _ = _fake_batch(arch, B=16)
    pred = jnp.broadcast_to(x[:, None, :], y.shape)
    e = losses.relative_transport_error(pred, x, y)
    assert abs(float(e)) < 1e-5
    perfect = losses.relative_transport_error(y, x, y)
    assert float(perfect) < -20      # log of ~0
