"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and block sizes; allclose against ref is the
core correctness signal for everything the AOT path lowers.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import icnn_layer as K
from compile.kernels import mips_topk as T
from compile.kernels import ref

RTOL, ATOL = 1e-5, 1e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# soft leaky relu
# ---------------------------------------------------------------------------

def test_soft_leaky_relu_limits():
    x = jnp.linspace(-6, 6, 101)
    y = ref.soft_leaky_relu(x, alpha=0.1, beta=200.0)
    leaky = jnp.where(x > 0, x, 0.1 * x)
    np.testing.assert_allclose(y, leaky, atol=2e-2)


def test_soft_leaky_relu_monotone_convex():
    x = jnp.linspace(-10, 10, 401)
    y = np.asarray(ref.soft_leaky_relu(x))
    dy = np.diff(y)
    assert (dy > 0).all(), "activation must be strictly increasing"
    # convex up to f32 rounding noise on the finite-difference stencil
    assert (np.diff(dy) >= -1e-5).all(), "activation must be convex"


def test_soft_leaky_relu_no_overflow():
    x = jnp.asarray([-1e4, -50.0, 0.0, 50.0, 1e4], jnp.float32)
    y = np.asarray(ref.soft_leaky_relu(x))
    assert np.isfinite(y).all()


# ---------------------------------------------------------------------------
# fused ICNN layer kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 8, 64, 130]),
    d=st.sampled_from([8, 48, 64]),
    h=st.sampled_from([8, 96, 128]),
    residual=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_icnn_layer_matches_ref(b, d, h, residual, seed):
    rng = np.random.default_rng(seed)
    z, x = _rand(rng, b, h), _rand(rng, b, d)
    wz, wx, bias = _rand(rng, h, h), _rand(rng, d, h), _rand(rng, h)
    got = K.icnn_layer(z, x, wz, wx, bias, residual=residual)
    want = ref.icnn_layer(z, x, wz, wx, bias, residual=residual)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bm,bn", [(32, 32), (64, 128), (128, 64)])
def test_icnn_layer_tile_invariance(bm, bn):
    """Output must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(7)
    b, d, h = 128, 64, 128
    z, x = _rand(rng, b, h), _rand(rng, b, d)
    wz, wx, bias = _rand(rng, h, h), _rand(rng, d, h), _rand(rng, h)
    base = K.icnn_layer(z, x, wz, wx, bias)
    tiled = K.icnn_layer(z, x, wz, wx, bias, bm=bm, bn=bn)
    np.testing.assert_allclose(base, tiled, rtol=RTOL, atol=ATOL)


def test_icnn_layer_alpha_beta_passthrough():
    rng = np.random.default_rng(3)
    b, d, h = 16, 8, 16
    z, x = _rand(rng, b, h), _rand(rng, b, d)
    wz, wx, bias = _rand(rng, h, h), _rand(rng, d, h), _rand(rng, h)
    got = K.icnn_layer(z, x, wz, wx, bias, alpha=0.2, beta=5.0)
    want = ref.icnn_layer(z, x, wz, wx, bias, alpha=0.2, beta=5.0)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_vmem_budget_default_tiles():
    """Structural perf check: default tiles fit the TPU VMEM budget with
    headroom for double-buffering (DESIGN.md §6)."""
    # Largest exported config scale: h<=512, d<=128, B=4096.
    assert K.vmem_bytes(4096, 128, 512) < 8 * 2**20
    util = K.mxu_utilization_estimate(4096, 128, 512)
    assert util > 0.5


# ---------------------------------------------------------------------------
# blocked MIPS top-1 kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 7, 32]),
    n=st.sampled_from([16, 100, 1024]),
    d=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mips_top1_matches_ref(b, n, d, seed):
    rng = np.random.default_rng(seed)
    q, keys = _rand(rng, b, d), _rand(rng, n, d)
    v, i = T.mips_top1(q, keys)
    rv, ri = ref.mips_top1(q, keys)
    np.testing.assert_allclose(v, rv, rtol=RTOL, atol=ATOL)
    # When scores tie, either index is a valid argmax: compare values.
    scored = jnp.take_along_axis(q @ keys.T, i[:, None], axis=1)[:, 0]
    np.testing.assert_allclose(scored, rv, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bq,bk", [(8, 64), (16, 128), (32, 512)])
def test_mips_top1_block_invariance(bq, bk):
    rng = np.random.default_rng(11)
    q, keys = _rand(rng, 32, 32), _rand(rng, 1024, 32)
    v0, i0 = T.mips_top1(q, keys)
    v1, i1 = T.mips_top1(q, keys, bq=bq, bk=bk)
    np.testing.assert_allclose(v0, v1, rtol=RTOL, atOL=ATOL) if False else \
        np.testing.assert_allclose(v0, v1, rtol=RTOL, atol=ATOL)
    assert (np.asarray(i0) == np.asarray(i1)).all()


def test_mips_top1_known_answer():
    keys = jnp.eye(4, dtype=jnp.float32) * jnp.asarray([1., 2., 3., 4.])
    q = jnp.asarray([[0., 0., 1., 0.], [1., 0., 0., 0.]], jnp.float32)
    v, i = T.mips_top1(q, keys)
    assert list(np.asarray(i)) == [2, 0]
    np.testing.assert_allclose(v, [3.0, 1.0], rtol=RTOL)
