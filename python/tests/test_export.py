"""AOT export consistency: meta sidecars must match the in-code ABI."""

import os

import pytest

from compile import aot, manifest as MF, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.txt")),
    reason="run `make artifacts` first")


def parse_meta(path):
    meta, params = {}, []
    with open(path) as f:
        for line in f:
            key, _, val = line.strip().partition(" ")
            if key == "param":
                name, shape = val.split(" ")
                dims = () if shape == "-" else tuple(
                    int(t) for t in shape.split(","))
                params.append((name, dims))
            else:
                meta[key] = val
    return meta, params


def test_manifest_unique_and_wellformed():
    names = [c.name for c in MF.MANIFEST]
    assert len(names) == len(set(names))
    for cfg in MF.MANIFEST:
        arch = cfg.arch()
        assert arch.h >= 8 and arch.h % 8 == 0
        assert arch.d_out >= 1
        assert cfg.dataset in MF.DATASETS


@needs_artifacts
def test_meta_matches_abi():
    checked = 0
    for cfg in MF.MANIFEST:
        path = os.path.join(ART, f"{cfg.name}.meta.txt")
        if not os.path.exists(path):
            continue
        meta, params = parse_meta(path)
        arch = cfg.arch()
        assert int(meta["h"]) == arch.h
        assert int(meta["c"]) == arch.c
        assert int(meta["n_param_tensors"]) == len(M.param_specs(arch))
        assert params == [(n, s) for n, s in M.param_specs(arch)]
        # state = 4x params + step scalar
        assert int(meta["n_state_tensors"]) == 4 * len(params) + 1
        checked += 1
    assert checked >= 1


@needs_artifacts
def test_expected_files_exist():
    for cfg in MF.MANIFEST[:8]:
        for part in ("init", "train", "fwd", "eval"):
            p = os.path.join(ART, f"{cfg.name}.{part}.hlo.txt")
            assert os.path.exists(p), p
        if cfg.model == "supportnet":
            assert os.path.exists(
                os.path.join(ART, f"{cfg.name}.grad.hlo.txt"))


@needs_artifacts
def test_hlo_is_text_not_proto():
    """The interchange gotcha: artifacts must be HLO text (parseable,
    id-reassignable), never serialized protos."""
    cfg = MF.MANIFEST[0]
    p = os.path.join(ART, f"{cfg.name}.fwd.hlo.txt")
    head = open(p, "rb").read(200)
    assert head.startswith(b"HloModule"), head[:40]
