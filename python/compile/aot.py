"""AOT exporter: lower every manifest config to HLO text + metadata.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Per config <name> it writes:

    <name>.init.hlo.txt    (seed u32[])            -> state...
    <name>.train.hlo.txt   (state..., x, y*, sigma, hparams[8])
                                                   -> state..., metrics[4]
    <name>.fwd.hlo.txt     (params..., x[B,d])     -> scores[B,c], keys[B,c,d]
    <name>.eval.hlo.txt    (params..., x, y*, sigma) -> metrics[4]
    <name>.grad.hlo.txt    (SupportNet only: params..., x) -> scores, keys
    <name>.fwd4096 / .grad4096 (timing configs, Table 1)
    <name>.meta.txt        line-oriented metadata (parsed by Rust)

Interchange is HLO **text**: jax>=0.5 serializes HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md). StableHLO from
jit(...).lower() is converted through xla_client's mlir bridge with
return_tuple=True, so every artifact returns a tuple the Rust side
unpacks with `to_tuple`.

The forward (inference) artifacts are lowered with use_pallas=True, so
the L1 Pallas kernel (interpret mode) is what lands in the serving HLO.
Gradient/training graphs use the numerically-identical jnp path (autodiff
through interpret-mode pallas_call is unsupported); python/tests assert
the two paths agree.
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import manifest as MF
from . import model as M
from . import sizing, train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path, text):
    with open(path, "w") as f:
        f.write(text)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def state_specs(arch):
    """(name, shape) for the full train state, in ABI order."""
    ps = M.param_specs(arch)
    out = []
    for prefix in ("p", "m", "v", "ema"):
        out += [(f"{prefix}.{n}", s) for n, s in ps]
    out.append(("step", ()))
    return out


def export_config(cfg: MF.ModelCfg, outdir: str, force: bool = False):
    arch = cfg.arch()
    ds = MF.DATASETS[cfg.dataset]
    B, Be = MF.TRAIN_BATCH, MF.EVAL_BATCH
    d, c = arch.d, arch.c
    pspecs = M.param_specs(arch)
    sspecs = state_specs(arch)
    meta_path = os.path.join(outdir, f"{cfg.name}.meta.txt")
    if os.path.exists(meta_path) and not force:
        return False

    p_in = [_sds(s) for _, s in pspecs]
    s_in = [_sds(s) for _, s in sspecs]
    x_b, ys_b, sg_b = _sds((B, d)), _sds((B, c, d)), _sds((B, c))
    x_e, ys_e, sg_e = _sds((Be, d)), _sds((Be, c, d)), _sds((Be, c))
    hp = _sds((8,))

    # ---- init: seed -> state -------------------------------------------
    def init_fn(seed):
        return tuple(train.init_state(arch, seed))
    lowered = jax.jit(init_fn).lower(_sds((), jnp.uint32))
    _write(os.path.join(outdir, f"{cfg.name}.init.hlo.txt"),
           to_hlo_text(lowered))

    # ---- train step -----------------------------------------------------
    def train_fn(state, x, y_star, sigma, hparams):
        new_state, metrics = train.train_step(list(state), x, y_star, sigma,
                                              hparams, arch)
        return tuple(new_state) + (metrics,)
    lowered = jax.jit(train_fn).lower(tuple(s_in), x_b, ys_b, sg_b, hp)
    _write(os.path.join(outdir, f"{cfg.name}.train.hlo.txt"),
           to_hlo_text(lowered))

    # ---- forward (serving path, pallas) ----------------------------------
    def fwd_fn(params, x):
        if arch.model == "supportnet":
            scores = M.forward(list(params), x, arch, use_pallas=True)
            return (scores,)
        scores, keys = M.keynet_scores_and_keys(list(params), x, arch,
                                                use_pallas=True)
        return scores, keys
    lowered = jax.jit(fwd_fn).lower(tuple(p_in), x_b)
    _write(os.path.join(outdir, f"{cfg.name}.fwd.hlo.txt"),
           to_hlo_text(lowered))

    # ---- grad (SupportNet key recovery via autodiff) ---------------------
    if arch.model == "supportnet":
        def grad_fn(params, x):
            return M.supportnet_scores_and_keys(list(params), x, arch)
        lowered = jax.jit(grad_fn).lower(tuple(p_in), x_b)
        _write(os.path.join(outdir, f"{cfg.name}.grad.hlo.txt"),
               to_hlo_text(lowered))

    # ---- eval -------------------------------------------------------------
    def eval_fn(params, x, y_star, sigma):
        return (train.eval_step(list(params), x, y_star, sigma, arch),)
    lowered = jax.jit(eval_fn).lower(tuple(p_in), x_e, ys_e, sg_e)
    _write(os.path.join(outdir, f"{cfg.name}.eval.hlo.txt"),
           to_hlo_text(lowered))

    # ---- Table-1 timing batches ------------------------------------------
    if cfg.timing:
        xt = _sds((MF.TIMING_BATCH, d))
        lowered = jax.jit(fwd_fn).lower(tuple(p_in), xt)
        _write(os.path.join(outdir, f"{cfg.name}.fwd4096.hlo.txt"),
               to_hlo_text(lowered))
        if arch.model == "supportnet":
            lowered = jax.jit(grad_fn).lower(tuple(p_in), xt)
            _write(os.path.join(outdir, f"{cfg.name}.grad4096.hlo.txt"),
                   to_hlo_text(lowered))

    # ---- metadata ----------------------------------------------------------
    lines = [
        f"name {cfg.name}",
        f"dataset {cfg.dataset}",
        f"model {arch.model}",
        f"d {arch.d}",
        f"c {arch.c}",
        f"h {arch.h}",
        f"layers {arch.layers}",
        f"nx {arch.nx}",
        f"inject {','.join(map(str, arch.inject)) or '-'}",
        f"residual {int(arch.residual)}",
        f"homogenize {int(arch.homogenize)}",
        f"alpha {arch.alpha}",
        f"beta {arch.beta}",
        f"size {cfg.size}",
        f"rho {sizing.RHO[cfg.size]}",
        f"train_batch {B}",
        f"eval_batch {Be}",
        f"timing_batch {MF.TIMING_BATCH if cfg.timing else 0}",
        f"n_params {arch.n_params}",
        f"n_param_tensors {len(pspecs)}",
        f"n_state_tensors {len(sspecs)}",
        f"fwd_flops {sizing.forward_flops(d, arch.h, arch.layers, arch.nx, arch.d_out, arch.homogenize)}",
        f"grad_flops {sizing.grad_flops(d, arch.h, arch.layers, arch.nx, arch.d_out, arch.homogenize) * arch.c}",
    ]
    for n, s in pspecs:
        lines.append(f"param {n} {','.join(map(str, s)) or '-'}")
    _write(meta_path, "\n".join(lines) + "\n")
    return True


def write_manifest_txt(outdir):
    lines = [
        "# generated by python -m compile.aot; parsed by rust/src/runtime/artifact.rs",
        f"train_batch {MF.TRAIN_BATCH}",
        f"eval_batch {MF.EVAL_BATCH}",
        f"timing_batch {MF.TIMING_BATCH}",
        f"aug_sigma {MF.AUG_SIGMA}",
        f"val_queries {MF.VAL_QUERIES}",
    ]
    for ds in MF.DATASETS.values():
        lines.append(
            f"dataset {ds.name} n={ds.n} d={ds.d} n_queries={ds.n_queries} "
            f"shift={ds.shift} spread={ds.spread} modes={ds.modes} seed={ds.seed}")
    for cfg in MF.MANIFEST:
        lines.append(f"config {cfg.name} dataset={cfg.dataset} "
                     f"model={cfg.model} size={cfg.size} layers={cfg.layers} "
                     f"c={cfg.c} timing={int(cfg.timing)}")
    _write(os.path.join(outdir, "manifest.txt"), "\n".join(lines) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on config names")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    cfgs = MF.MANIFEST
    if args.only:
        cfgs = [c for c in cfgs if args.only in c.name]
    if args.list:
        for c in cfgs:
            a = c.arch()
            print(f"{c.name:46s} h={a.h:4d} params={a.n_params}")
        return

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    done = 0
    for i, cfg in enumerate(cfgs):
        t1 = time.time()
        fresh = export_config(cfg, args.out, force=args.force)
        done += fresh
        status = "export" if fresh else "cached"
        print(f"[{i + 1}/{len(cfgs)}] {status} {cfg.name} "
              f"({time.time() - t1:.1f}s)", flush=True)
    write_manifest_txt(args.out)
    print(f"artifacts: {done} exported, {len(cfgs) - done} cached "
          f"in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
