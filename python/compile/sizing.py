"""Network sizing rule (paper Eq. 3.2/3.3) and parameter accounting.

Given a parameter budget P = rho * n * d (a fraction rho of the database
size), depth L, input dim d and the number of input re-injections n_x,
the hidden width solves

    (L-1) h^2 + (1+n_x) d h ~= P
    h ~= (sqrt(D^2 + 4 (L-1) P) - D) / (2 (L-1)),   D = (1+n_x) d.

The same module provides exact parameter counts and a FLOPs model that
the Rust side mirrors (rust/src/metrics/flops.rs) for all Pareto plots.
"""

import math

# Paper size names -> parameter fraction rho (Sec. 4.1).
RHO = {"xs": 0.01, "s": 0.05, "m": 0.10, "l": 0.20, "xl": 0.40, "xxl": 0.50}


def inject_layers(L: int, nx: int):
    """Indices (1..L-1) of hidden layers that receive the x passthrough.

    nx counts re-injections after the first layer. nx >= L-1 means every
    hidden layer (the paper's n_x = L marker); nx = 0 means a plain MLP.
    Chosen evenly spaced, matching the paper's "every 4 layers" setting
    when nx ~= L/4.
    """
    if L <= 1 or nx <= 0:
        return []
    nx = min(nx, L - 1)
    step = (L - 1) / nx
    layers = sorted({min(L - 1, max(1, round((i + 1) * step))) for i in range(nx)})
    return layers


def width_for_budget(P: float, L: int, d: int, nx: int) -> int:
    """Eq. 3.3, rounded to a multiple of 8 (>= 8) for tiling friendliness."""
    D = (1 + min(nx, max(L - 1, 0))) * d
    if L <= 1:
        h = P / max(D, 1)
    else:
        h = (math.sqrt(D * D + 4 * (L - 1) * P) - D) / (2 * (L - 1))
    return max(8, int(round(h / 8)) * 8)


def param_count(d: int, h: int, L: int, nx: int, d_out: int) -> int:
    """Exact parameter count for the rectangular architecture."""
    inj = inject_layers(L, nx)
    n = d * h + h                      # wx0, b0
    n += (L - 1) * (h * h + h)         # wz_i, b_i
    n += len(inj) * d * h              # wx_i at injected layers
    n += h * d_out + d_out             # output head
    return n


def forward_flops(d: int, h: int, L: int, nx: int, d_out: int,
                  homogenize: bool = False) -> int:
    """FLOPs for one query forward pass (multiply+add = 2 flops)."""
    inj = inject_layers(L, nx)
    f = 2 * d * h                      # input layer
    f += (L - 1) * 2 * h * h           # hidden z-paths
    f += len(inj) * 2 * d * h          # re-injections
    f += 2 * h * d_out                 # head
    f += 8 * (h * L + d_out)           # activation epilogues (approx)
    if homogenize:
        f += 6 * d                     # normalize + rescale
    return f


def grad_flops(d, h, L, nx, d_out, homogenize=False):
    """Backward pass ~2x forward (paper Sec 4.4: 1~2x); per-output-row
    Jacobians for c outputs multiply by c at the caller."""
    return 2 * forward_flops(d, h, L, nx, d_out, homogenize)
