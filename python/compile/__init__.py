"""Build-time Python package: L1 Pallas kernels + L2 JAX models + AOT export.

Never imported at runtime — the Rust binary only consumes artifacts/.
"""
