"""L2: AMIPS loss functions (paper Sec. 3.2).

Targets per batch (precomputed by the Rust data pipeline, Sec. 3.3):
  x      [B, d]      queries ~ p_X (augmented offline)
  y_star [B, c, d]   per-cluster optimal keys
  sigma  [B, c]      per-cluster support values <x, y*_j>

SupportNet:  L = lam_score * L_score + lam_grad * L_grad + lam_icnn * pen
KeyNet:      L = lam_key   * L_key   + lam_consist * L_consist

All lambdas arrive as *runtime inputs* to the AOT train step so the loss
ablation (paper Fig. 14) runs without re-exporting artifacts.
"""

import jax
import jax.numpy as jnp

from . import model as M


def supportnet_loss(params, x, y_star, sigma, arch, lam_score, lam_grad,
                    lam_icnn):
    """Score regression + gradient matching + convexity penalty.

    Computing L_grad needs cross-derivatives d/dtheta d/dx f — handled by
    jax autodiff through the jacrev (paper Sec. 3.2 note).
    """
    scores, keys = M.supportnet_scores_and_keys(params, x, arch)
    l_score = jnp.mean(jnp.square(scores - sigma))            # mean over B,c
    l_grad = jnp.mean(jnp.sum(jnp.square(keys - y_star), axis=-1))
    pen = M.icnn_penalty(params, arch)
    total = lam_score * l_score + lam_grad * l_grad + lam_icnn * pen
    return total, (l_score, l_grad, pen)


def keynet_loss(params, x, y_star, sigma, arch, lam_key, lam_consist):
    """Key regression + Euler score-consistency."""
    scores, keys = M.keynet_scores_and_keys(params, x, arch)
    l_key = jnp.mean(jnp.sum(jnp.square(keys - y_star), axis=-1))
    l_consist = jnp.mean(jnp.square(scores - sigma))
    total = lam_key * l_key + lam_consist * l_consist
    return total, (l_key, l_consist, jnp.zeros(()))


def loss_fn(params, x, y_star, sigma, arch, lam_a, lam_b, lam_icnn):
    """Uniform signature used by the train step.

    SupportNet: lam_a = lam_score, lam_b = lam_grad.
    KeyNet:     lam_a = lam_consist, lam_b = lam_key.
    (lam_b always weights the vector-matching term the paper emphasizes.)
    """
    if arch.model == "supportnet":
        return supportnet_loss(params, x, y_star, sigma, arch,
                               lam_a, lam_b, lam_icnn)
    return keynet_loss(params, x, y_star, sigma, arch, lam_b, lam_a)


def relative_transport_error(pred_keys, x, y_star):
    """Eval-only metric (Eq. 4.1): E[log ||yhat-y*||^2 / ||x-y*||^2],
    averaged over batch and clusters. pred/y* [B,c,d], x [B,d]."""
    num = jnp.sum(jnp.square(pred_keys - y_star), axis=-1)
    den = jnp.sum(jnp.square(x[:, None, :] - y_star), axis=-1)
    return jnp.mean(jnp.log(jnp.maximum(num, 1e-30) /
                            jnp.maximum(den, 1e-30)))
