"""Export manifest: every dataset and model configuration the repo builds.

This is the single source of truth shared by the AOT exporter (aot.py)
and the Rust side (artifacts/manifest.txt is generated from it). Dataset
scales are ~50-100x reductions of the paper's BEIR corpora (DESIGN.md §3
substitution table) sized for the single-core CPU testbed; relative
ordering (fiqa < quora < nq < hotpot < bioasq) and the query/key
distribution-shift structure (App. A.10) are preserved.
"""

from dataclasses import dataclass, field

from . import model as M

# ---------------------------------------------------------------------------
# Datasets. `shift` controls how far the query mixture is displaced from the
# key mixture (App. A.10: Quora aligned -> low shift; NQ/HotpotQA shifted).
# `spread` controls per-cluster anisotropy (outlier keys, Fig. 1).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DatasetCfg:
    name: str
    n: int
    d: int
    n_queries: int          # base (pre-augmentation) train queries
    shift: float            # query-vs-key mixture displacement
    spread: float           # anisotropy of clusters
    modes: int              # mixture components in the generator
    seed: int


DATASETS = {
    "fiqa-s": DatasetCfg("fiqa-s", 2048, 64, 4096, 0.30, 6.0, 12, 101),
    "quora-s": DatasetCfg("quora-s", 6144, 64, 8192, 0.08, 1.6, 16, 102),
    "nq-s": DatasetCfg("nq-s", 16384, 64, 16384, 0.45, 7.0, 24, 103),
    "hotpot-s": DatasetCfg("hotpot-s", 32768, 64, 16384, 0.42, 7.0, 32, 104),
    "bioasq-s": DatasetCfg("bioasq-s", 65536, 64, 12288, 0.42, 7.0, 40, 105),
    # d=768-analog (App. A.5): doubled embedding dim, same corpus scale.
    "nq-s-d128": DatasetCfg("nq-s-d128", 16384, 128, 8192, 0.45, 7.0, 24, 106),
}

TRAIN_BATCH = 256
EVAL_BATCH = 1024
TIMING_BATCH = 4096
AUG_SIGMA = 0.02          # training-time query augmentation (Sec. 4.1)
VAL_QUERIES = 1000        # validation set size (Sec. 4.1)


@dataclass(frozen=True)
class ModelCfg:
    name: str               # unique artifact prefix
    dataset: str
    model: str              # supportnet | keynet
    size: str               # xs/s/m/l/xl/xxl (rho)
    layers: int
    c: int = 1
    nx: int | None = None   # None -> inject every layer (nx = L)
    residual: bool = False
    timing: bool = False    # also export batch-4096 artifacts (Table 1)

    def arch(self) -> M.Arch:
        ds = DATASETS[self.dataset]
        from .sizing import RHO
        return M.make_arch(self.model, ds.d, ds.n, RHO[self.size],
                           self.layers, nx=self.nx, residual=self.residual,
                           c=self.c)


def _cfg(dataset, model, size, layers=4, **kw):
    tag = kw.pop("tag", None)
    c = kw.get("c", 1)
    name = f"{dataset}.{model}.{size}.l{layers}.c{c}"
    if tag:
        name += f".{tag}"
    return ModelCfg(name=name, dataset=dataset, model=model, size=size,
                    layers=layers, **kw)


def build_manifest():
    cfgs = []
    # --- Fig 3: c=10 routing on quora-s & nq-s, both models, xs/s/m ------
    for ds in ("quora-s", "nq-s"):
        for mdl in ("supportnet", "keynet"):
            for size in ("xs", "s", "m"):
                cfgs.append(_cfg(ds, mdl, size, layers=4, c=10))
            # sparse re-injection variant (black-outlined markers, nx~L/4)
            cfgs.append(_cfg(ds, mdl, "s", layers=4, c=10, nx=1, tag="nx1"))
    # --- Fig 4: c=128 routing, XS SupportNet, L=8 ------------------------
    cfgs.append(_cfg("nq-s", "supportnet", "xs", layers=8, c=128, nx=2))
    # --- Fig 5 / 16-27 / Table 1: c=1 KeyNet for index integration -------
    for ds in ("quora-s", "nq-s", "hotpot-s"):
        for size in ("xs", "s", "m", "l"):
            cfgs.append(_cfg(ds, "keynet", size, layers=4,
                             timing=size in ("s", "m", "l")))
    # --- Table 1 + Fig 14: c=1 SupportNet --------------------------------
    for ds in ("quora-s", "nq-s", "hotpot-s"):
        for size in ("s", "m", "l"):
            cfgs.append(_cfg(ds, "supportnet", size, layers=4, timing=True))
    # --- Fig 10: fiqa-s sweep over sizes x depths, both models -----------
    for mdl in ("supportnet", "keynet"):
        for size in ("xs", "s", "m"):
            for layers in (2, 4):
                cfgs.append(_cfg("fiqa-s", mdl, size, layers=layers))
    # --- Fig 28: bioasq-s scale study -------------------------------------
    for size in ("xs", "s"):
        cfgs.append(_cfg("bioasq-s", "keynet", size, layers=4))
    # --- App A.5: higher-dim encoder analog -------------------------------
    for size in ("xs", "s"):
        cfgs.append(_cfg("nq-s-d128", "keynet", size, layers=4))
    # --- Residual-block ablation (Sec. 3.1) --------------------------------
    cfgs.append(_cfg("quora-s", "keynet", "s", layers=4, residual=True,
                     tag="res"))
    cfgs.append(_cfg("quora-s", "supportnet", "s", layers=4, residual=True,
                     tag="res"))
    names = [c.name for c in cfgs]
    assert len(names) == len(set(names)), "duplicate config names"
    return cfgs


MANIFEST = build_manifest()
