"""L2: one fused Adam train step, AOT-compiled and driven from Rust.

State ABI (flat tensor list, in this exact order — mirrored by
rust/src/model/params.rs):

    params[0..P) , m[0..P) , v[0..P) , ema[0..P) , step (f32 scalar)

Step inputs after the state: x [B,d], y_star [B,c,d], sigma [B,c], and a
single hparams vector f32[8]:

    [0] lam_a      (SupportNet: lam_score;  KeyNet: lam_consist)
    [1] lam_b      (SupportNet: lam_grad;   KeyNet: lam_key)
    [2] lam_icnn   convexity penalty weight (SupportNet only)
    [3] peak_lr
    [4] total_steps
    [5] warmup_frac (of total_steps)
    [6] ema_decay
    [7] weight_decay (AdamW-style, usually 0)

Outputs: new state (same order/shapes) followed by metrics f32[4]:
    [loss_total, loss_a, loss_b, penalty].

Keeping LR schedule, EMA and the optimizer *inside* the HLO means Rust
only shuttles batches; state tensors round-trip as device buffers
(execute_b) and never touch the host during training.
"""

import jax
import jax.numpy as jnp

from . import losses
from . import model as M

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def lr_schedule(step, total, warmup_frac, peak):
    """Cosine decay with linear warmup (paper Sec. 4.1)."""
    warm = jnp.maximum(total * warmup_frac, 1.0)
    warm_lr = peak * (step + 1.0) / warm
    prog = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
    cos_lr = 0.5 * peak * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warm, warm_lr, cos_lr)


def init_state(arch: M.Arch, seed):
    """seed (uint32 scalar) -> state list. Exported as the .init HLO."""
    key = jax.random.PRNGKey(seed)
    params = M.init_params(arch, key)
    zeros = [jnp.zeros_like(p) for p in params]
    ema = [p for p in params]
    return params + zeros + [jnp.zeros_like(p) for p in params] + ema + \
        [jnp.zeros((), jnp.float32)]


def split_state(state, arch: M.Arch):
    P = len(M.param_specs(arch))
    return (state[0:P], state[P:2 * P], state[2 * P:3 * P],
            state[3 * P:4 * P], state[4 * P])


def train_step(state, x, y_star, sigma, hparams, arch: M.Arch):
    """One fused Adam + EMA step. Returns (new_state, metrics[4])."""
    params, m, v, ema, step = split_state(state, arch)
    lam_a, lam_b, lam_icnn = hparams[0], hparams[1], hparams[2]
    peak, total, warm, decay, wd = (hparams[3], hparams[4], hparams[5],
                                    hparams[6], hparams[7])

    def scalar_loss(ps):
        total_l, parts = losses.loss_fn(ps, x, y_star, sigma, arch,
                                        lam_a, lam_b, lam_icnn)
        return total_l, parts

    (loss, parts), grads = jax.value_and_grad(scalar_loss, has_aux=True)(params)

    lr = lr_schedule(step, total, warm, peak)
    t = step + 1.0
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t

    new_params, new_m, new_v, new_ema = [], [], [], []
    for p, g, mi, vi, ei in zip(params, grads, m, v, ema):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * jnp.square(g)
        update = (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        p = p - lr * (update + wd * p)
        ei = decay * ei + (1.0 - decay) * p
        new_params.append(p)
        new_m.append(mi)
        new_v.append(vi)
        new_ema.append(ei)

    new_state = new_params + new_m + new_v + new_ema + [step + 1.0]
    metrics = jnp.stack([loss, parts[0], parts[1], parts[2]])
    return new_state, metrics


def eval_step(params, x, y_star, sigma, arch: M.Arch):
    """Validation metrics on one batch, AOT-exported as .eval HLO.

    Returns f32[4]: [E_rel, mse_key, mse_score, mean_pred_score].
    Uses EMA params (caller passes them).
    """
    if arch.model == "supportnet":
        scores, keys = M.supportnet_scores_and_keys(params, x, arch)
    else:
        scores, keys = M.keynet_scores_and_keys(params, x, arch)
    e_rel = losses.relative_transport_error(keys, x, y_star)
    mse_key = jnp.mean(jnp.sum(jnp.square(keys - y_star), axis=-1))
    mse_score = jnp.mean(jnp.square(scores - sigma))
    return jnp.stack([e_rel, mse_key, mse_score, jnp.mean(scores)])
