"""L2: SupportNet / KeyNet model definitions (paper Sec. 3.1).

Both models share one rectangular skeleton:

    z_1     = sigma(Wx0 x + b0)
    z_{i+1} = sigma(Wz_i z_i [+ Wx_i x] + b_i)      (+ z_i if residual)
    out     = W_L z_L + b_L

* SupportNet: out in R^c, convexity encouraged by a non-negativity
  *regularizer* on the Wz_i ("loosely constrained" ICNN, Sec. 2) plus a
  non-negative init; always wrapped by the homogenization wrapper
  H[g](x) = ||x|| g(x / ||x||)  (Eq. 3.4).
* KeyNet: out in R^{c*d}, unconstrained.

Parameters are carried as an explicit ordered list of arrays so the AOT
boundary (Rust side) has a deterministic flattening; `param_specs`
publishes (name, shape) in that exact order into the artifact metadata.

The hidden layers call the L1 Pallas kernel (kernels.icnn_layer) when
`use_pallas=True` — that is the path exported into the inference HLOs, so
the kernel lowers into the artifact. Training/grad graphs use the
numerically identical pure-jnp path (autodiff through interpret-mode
pallas_call is not supported); equality of the two paths is asserted in
python/tests.
"""

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from . import sizing
from .kernels import icnn_layer as pallas_layer
from .kernels import ref


@dataclass(frozen=True)
class Arch:
    """Static architecture description (goes into artifact metadata)."""
    model: str              # "supportnet" | "keynet"
    d: int                  # embedding dim
    c: int = 1              # number of clusters (output heads)
    h: int = 64             # hidden width
    layers: int = 4         # L: number of hidden layers (incl. first)
    nx: int = 4             # input re-injections after first layer
    residual: bool = False
    homogenize: bool = True   # SupportNet only (forced off for KeyNet)
    alpha: float = 0.1
    beta: float = 20.0

    @property
    def d_out(self) -> int:
        return self.c if self.model == "supportnet" else self.c * self.d

    @property
    def inject(self):
        return sizing.inject_layers(self.layers, self.nx)

    @property
    def n_params(self) -> int:
        return sizing.param_count(self.d, self.h, self.layers, self.nx,
                                  self.d_out)


def make_arch(model, d, n, rho, layers, nx=None, residual=False, c=1,
              homogenize=None):
    """Build an Arch from the paper's knobs: budget fraction rho of n*d."""
    if nx is None:
        nx = layers                      # paper default: inject every layer
    P = rho * n * d
    h = sizing.width_for_budget(P, layers, d, nx)
    if homogenize is None:
        homogenize = model == "supportnet"
    if model == "keynet":
        homogenize = False
    return Arch(model=model, d=d, c=c, h=h, layers=layers, nx=nx,
                residual=residual, homogenize=homogenize)


def param_specs(arch: Arch):
    """Ordered (name, shape) list — the AOT parameter ABI."""
    d, h, L = arch.d, arch.h, arch.layers
    specs = [("wx0", (d, h)), ("b0", (h,))]
    inj = set(arch.inject)
    for i in range(1, L):
        specs.append((f"wz{i}", (h, h)))
        if i in inj:
            specs.append((f"wx{i}", (d, h)))
        specs.append((f"b{i}", (h,)))
    specs.append(("wout", (h, arch.d_out)))
    specs.append(("bout", (arch.d_out,)))
    return specs


def wz_param_indices(arch: Arch):
    """Indices into the param list of the Wz matrices (ICNN penalty targets).

    The output head is included for SupportNet: convexity of W_L z_L + b_L
    in z_L also needs W_L >= 0.
    """
    idx = [i for i, (name, _) in enumerate(param_specs(arch))
           if name.startswith("wz")]
    if arch.model == "supportnet":
        idx.append(next(i for i, (n, _) in enumerate(param_specs(arch))
                        if n == "wout"))
    return idx


def init_params(arch: Arch, key):
    """Non-negative principled init for Wz (after Hoedt & Klambauer 2023:
    half-normal scaled to preserve forward variance given E[w]>0),
    LeCun-normal for passthroughs and head."""
    specs = param_specs(arch)
    wz_set = set(wz_param_indices(arch))
    params = []
    keys = jax.random.split(key, len(specs))
    for i, ((name, shape), k) in enumerate(zip(specs, keys)):
        if len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        elif i in wz_set and arch.model == "supportnet":
            fan_in = shape[0]
            # Half-normal with Var|N| = (1 - 2/pi); scale to unit fan-in
            # variance contribution, shrunk to temper the positive mean.
            std = (2.0 / fan_in) ** 0.5
            w = jnp.abs(jax.random.normal(k, shape, jnp.float32)) * std * 0.5
            params.append(w)
        else:
            fan_in = shape[0]
            std = (1.0 / fan_in) ** 0.5
            params.append(jax.random.normal(k, shape, jnp.float32) * std)
    return params


def _unpack(params, arch: Arch):
    """params list -> dict keyed by spec name."""
    return {name: p for (name, _), p in zip(param_specs(arch), params)}


def _backbone(params, x, arch: Arch, use_pallas: bool):
    """Shared trunk: x [B,d] -> z_L [B,h]."""
    P = _unpack(params, arch)
    act = lambda t: ref.soft_leaky_relu(t, arch.alpha, arch.beta)
    z = act(x @ P["wx0"] + P["b0"])
    inj = set(arch.inject)
    for i in range(1, arch.layers):
        wz, b = P[f"wz{i}"], P[f"b{i}"]
        if i in inj:
            wx = P[f"wx{i}"]
            if use_pallas:
                z = pallas_layer.icnn_layer(z, x, wz, wx, b,
                                            alpha=arch.alpha, beta=arch.beta,
                                            residual=arch.residual)
            else:
                z = ref.icnn_layer(z, x, wz, wx, b, arch.alpha, arch.beta,
                                   arch.residual)
        else:
            pre = z @ wz + b
            a = act(pre)
            z = z + a if arch.residual else a
    return z


def _raw_forward(params, x, arch: Arch, use_pallas: bool):
    """Trunk + head, no homogenization: [B,d] -> [B,d_out]."""
    P = _unpack(params, arch)
    z = _backbone(params, x, arch, use_pallas)
    return z @ P["wout"] + P["bout"]


def forward(params, x, arch: Arch, use_pallas: bool = False):
    """Model output.

    SupportNet -> scores [B, c] (homogenized when arch.homogenize).
    KeyNet     -> keys   [B, c, d].
    """
    if arch.model == "supportnet":
        if arch.homogenize:
            nrm = jnp.linalg.norm(x, axis=-1, keepdims=True)
            nrm = jnp.maximum(nrm, 1e-12)
            g = _raw_forward(params, x / nrm, arch, use_pallas)
            return nrm * g
        return _raw_forward(params, x, arch, use_pallas)
    out = _raw_forward(params, x, arch, use_pallas)
    return out.reshape(x.shape[0], arch.c, arch.d)


def supportnet_scores_and_keys(params, x, arch: Arch):
    """SupportNet inference: scores [B,c] and keys [B,c,d] = d f / d x.

    The per-cluster key is the input-gradient of that cluster's output
    (rows of the Jacobian, paper Sec. 3.1). Pure-jnp path: the c backward
    passes must be differentiable, so no pallas here.
    """
    def per_example(xi):
        f = lambda v: forward(params, v[None, :], arch)[0]   # [c]
        scores = f(xi)
        jac = jax.jacrev(f)(xi)                              # [c, d]
        return scores, jac
    return jax.vmap(per_example)(x)


def keynet_scores_and_keys(params, x, arch: Arch, use_pallas: bool = False):
    """KeyNet inference: keys [B,c,d] and scores <F_j(x), x> [B,c]."""
    keys = forward(params, x, arch, use_pallas)
    scores = jnp.einsum("bcd,bd->bc", keys, x)
    return scores, keys


def icnn_penalty(params, arch: Arch):
    """sum_i || ReLU(-Wz_i) ||^2 — the loose convexity regularizer."""
    idx = wz_param_indices(arch)
    return sum(jnp.sum(jnp.square(jnp.maximum(-params[i], 0.0)))
               for i in idx)
