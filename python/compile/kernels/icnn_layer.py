"""L1 Pallas kernel: fused ICNN/MLP hidden layer.

Computes  out = sigma_{alpha,beta}(z @ Wz + x @ Wx + b)  (optionally + z)
in one pass, tiled for TPU VMEM.

Hardware adaptation (DESIGN.md §6): the paper trains on GPU where this
layer would be a cuBLAS GEMM + elementwise epilogue launched per layer.
On TPU we instead express the HBM<->VMEM schedule with a BlockSpec grid:

  grid = (B/BM, h/BN, h/BK-steps folded into the kernel body)

Each program instance owns a (BM, BN) output tile; it streams the
K-dimension of both matmuls (z-path over h, x-path over d) through the
MXU with f32 accumulation (`preferred_element_type`), then applies the
soft-leaky-ReLU epilogue on the VPU before a single writeback. The
weight tiles plus one activation tile are sized to fit comfortably in
VMEM (~16 MB/core budget; see `vmem_bytes`).

interpret=True everywhere on this image: the CPU PJRT plugin cannot run
Mosaic custom-calls, so the kernel's *structure* is what we optimize and
its numerics are validated against `ref.py`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128  # batch-tile rows (MXU-friendly multiple of 8)
DEFAULT_BN = 128  # output-feature tile cols (lane dim multiple of 128)


def _soft_leaky_relu(x, alpha, beta):
    t = beta * x
    softplus = jnp.maximum(t, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(t)))
    return alpha * x + (1.0 - alpha) / beta * softplus


def _layer_kernel(z_ref, x_ref, wz_ref, wx_ref, b_ref, o_ref, *,
                  alpha, beta, residual):
    """One (BM, BN) output tile: both matmul partials + fused epilogue.

    z_ref  (BM, h)   full contraction dim kept resident: h*BM*4 bytes
    x_ref  (BM, d)
    wz_ref (h,  BN)
    wx_ref (d,  BN)
    b_ref  (1,  BN)
    o_ref  (BM, BN)
    """
    acc = jnp.dot(z_ref[...], wz_ref[...], preferred_element_type=jnp.float32)
    acc = acc + jnp.dot(x_ref[...], wx_ref[...],
                        preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    act = _soft_leaky_relu(acc, alpha, beta)
    if residual:
        act = act + z_ref[:, pl.dslice(pl.program_id(1) * o_ref.shape[1],
                                       o_ref.shape[1])]
    o_ref[...] = act.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "residual",
                                             "bm", "bn"))
def icnn_layer(z, x, wz, wx, b, *, alpha=0.1, beta=20.0, residual=False,
               bm=DEFAULT_BM, bn=DEFAULT_BN):
    """Fused hidden layer via pallas_call. Shapes: z [B,h], x [B,d],
    wz [h,h], wx [d,h], b [h] -> [B,h]."""
    B, h = z.shape
    d = x.shape[1]
    bm = min(bm, B)
    bn = min(bn, h)
    # Grid must tile exactly in interpret mode for clean semantics; fall
    # back to single-tile when shapes are ragged (tests cover both paths).
    if B % bm != 0:
        bm = B
    if h % bn != 0:
        bn = h
    grid = (B // bm, h // bn)
    b2 = b.reshape(1, h)
    kernel = functools.partial(_layer_kernel, alpha=alpha, beta=beta,
                               residual=residual)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, h), lambda i, j: (i, 0)),   # z: full K resident
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),   # x
            pl.BlockSpec((h, bn), lambda i, j: (0, j)),   # wz column tile
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),   # wx column tile
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),   # bias tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, h), z.dtype),
        interpret=True,
    )(z, x, wz, wx, b2)


def vmem_bytes(B, d, h, bm=DEFAULT_BM, bn=DEFAULT_BN, itemsize=4):
    """Static VMEM footprint estimate for one program instance (bytes).

    Used by the §Perf structural budget: tile choice must keep this under
    ~half of a TPU core's ~16MB VMEM so double-buffering fits.
    """
    bm = min(bm, B)
    bn = min(bn, h)
    z_t = bm * h
    x_t = bm * d
    wz_t = h * bn
    wx_t = d * bn
    b_t = bn
    o_t = bm * bn
    return (z_t + x_t + wz_t + wx_t + b_t + o_t) * itemsize


def mxu_utilization_estimate(B, d, h, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """Fraction of MXU-issue slots doing useful work for one layer, under
    the 128x128 systolic-array model: efficiency is the product of how
    well each matmul dim fills its 128-lane tile."""
    def fill(dim, tile):
        t = min(tile, dim)
        return dim / (pl.cdiv(dim, t) * max(t, 128))
    # z-path dominates ((B,h)x(h,h)); x-path adds d/h fraction of work.
    z_eff = fill(B, bm) * fill(h, bn) * fill(h, 128)
    x_eff = fill(B, bm) * fill(h, bn) * fill(d, 128)
    w_z = B * h * h
    w_x = B * d * h
    return (z_eff * w_z + x_eff * w_x) / (w_z + w_x)
