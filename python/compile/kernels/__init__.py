"""L1 Pallas kernels (build-time only; lowered into L2 HLO artifacts)."""
from . import icnn_layer, mips_topk, ref  # noqa: F401
