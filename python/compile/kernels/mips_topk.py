"""L1 Pallas kernel: blocked exact-MIPS top-1 scan.

For queries X [B,d] and keys Y [n,d], computes per-query
(max_j <x,y_j>, argmax_j) by streaming key tiles HBM->VMEM and keeping a
running (value, index) pair in VMEM — the TPU re-expression of the CUDA
"threadblock per key chunk + global atomic max" pattern the exact-search
literature uses (DESIGN.md §6).

The grid iterates key tiles in the *last* (sequential on TPU) grid
dimension so the running max in o_refs carries across iterations without
cross-core reduction. Used at build time to generate ground-truth targets
(Sec. 3.3 of the paper) and validated against ref.mips_top1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BK = 512  # keys per tile
DEFAULT_BQ = 128  # queries per tile


def _topk_kernel(x_ref, y_ref, val_ref, idx_ref, *, bk):
    """Grid = (B/bq, n/bk); key-tile index k = program_id(1) is sequential.

    x_ref (bq, d); y_ref (bk, d); val/idx (bq, 1) running accumulators.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, -jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    # (bq, bk) score tile on the MXU, f32 accumulation.
    s = jax.lax.dot_general(
        x_ref[...], y_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    tile_val = jnp.max(s, axis=1, keepdims=True)
    tile_arg = jnp.argmax(s, axis=1).astype(jnp.int32).reshape(-1, 1)
    tile_idx = tile_arg + k * bk

    better = tile_val > val_ref[...]
    val_ref[...] = jnp.where(better, tile_val, val_ref[...])
    idx_ref[...] = jnp.where(better, tile_idx, idx_ref[...])


@functools.partial(jax.jit, static_argnames=("bq", "bk"))
def mips_top1(x, y, *, bq=DEFAULT_BQ, bk=DEFAULT_BK):
    """Blocked top-1 MIPS. x [B,d], y [n,d] -> (values [B], indices [B])."""
    B, d = x.shape
    n = y.shape[0]
    bq = min(bq, B)
    bk = min(bk, n)
    if B % bq != 0:
        bq = B
    if n % bk != 0:
        bk = n
    grid = (B // bq, n // bk)
    kernel = functools.partial(_topk_kernel, bk=bk)
    val, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, k: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=True,
    )(x, y)
    return val[:, 0], idx[:, 0]


def vmem_bytes(B, d, n, bq=DEFAULT_BQ, bk=DEFAULT_BK, itemsize=4):
    """Per-instance VMEM footprint: query tile + key tile + score tile."""
    bq = min(bq, B)
    bk = min(bk, n)
    return (bq * d + bk * d + bq * bk + 2 * bq) * itemsize
