"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here; ``python/tests/test_kernel.py`` sweeps shapes/dtypes with
hypothesis and asserts allclose between the two.
"""

import jax.numpy as jnp


def soft_leaky_relu(x, alpha: float = 0.1, beta: float = 20.0):
    """The paper's activation (Sec. 3.3):

        sigma_{alpha,beta}(x) = alpha*x + (1-alpha)/beta * log(1 + exp(beta*x))

    As beta -> inf this approaches leaky-ReLU with negative slope alpha.
    Computed in a numerically-stable way: log1p(exp(t)) = max(t,0) + log1p(exp(-|t|)).
    """
    t = beta * x
    softplus = jnp.maximum(t, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(t)))
    return alpha * x + (1.0 - alpha) / beta * softplus


def icnn_layer(z, x, wz, wx, b, alpha: float = 0.1, beta: float = 20.0,
               residual: bool = False):
    """Fused ICNN/MLP hidden layer:

        out = sigma(z @ Wz + x @ Wx + b)         (+ z if residual)

    Shapes: z [B,h], x [B,d], wz [h,h], wx [d,h], b [h].
    This is the single hot compute block both SupportNet and KeyNet stack
    L times; the Pallas kernel in `icnn_layer.py` computes the same thing
    tile-by-tile.
    """
    pre = z @ wz + x @ wx + b
    act = soft_leaky_relu(pre, alpha, beta)
    return z + act if residual else act


def input_layer(x, wx0, b0, alpha: float = 0.1, beta: float = 20.0):
    """First layer: sigma(x @ Wx0 + b0). x [B,d], wx0 [d,h], b0 [h]."""
    return soft_leaky_relu(x @ wx0 + b0, alpha, beta)


def mips_scores(queries, keys):
    """Exact MIPS score matrix <x_i, y_j>: queries [B,d], keys [n,d] -> [B,n]."""
    return queries @ keys.T


def mips_top1(queries, keys):
    """Exact top-1 MIPS: returns (values [B], indices [B])."""
    s = mips_scores(queries, keys)
    return jnp.max(s, axis=1), jnp.argmax(s, axis=1)
