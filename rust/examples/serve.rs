//! Serving demo: the full coordinator — dynamic batcher + model-runner
//! thread (PJRT confined) + shared IVF index — under closed-loop client
//! load, reporting recall, throughput and latency quantiles. Clients and
//! server speak `api::SearchRequest` / `CostBreakdown` end to end.
//!
//! ```bash
//! cargo run --release --features xla --example serve -- [--requests 1024] [--clients 4] [--no-map]
//! ```

use amips::api::{Effort, QueryMode, SearchRequest};
use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::cli::Args;
use amips::coordinator::{BatchPolicy, Server, ServerConfig};
use amips::index::ivf::IvfIndex;
use amips::runtime::Engine;
use amips::trainer;
use anyhow::Result;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let dataset = args.get_or("dataset", "quora-s").to_string();
    let requests = args.get_usize("requests", 1024)?;
    let clients = args.get_usize("clients", 4)?;
    let nprobe = args.get_usize("nprobe", 4)?;
    let map_queries = !args.has("no-map");
    args.reject_unknown()?;

    let manifest = fixtures::load_manifest()?;
    let config = format!("{dataset}.keynet.s.l4.c1");
    let meta = manifest.meta(&config)?;
    let ds = fixtures::prepare_dataset(&manifest, &dataset, 1)?;

    // Train (or load) on the main thread, then hand params to the server.
    let params = {
        let engine = Engine::new(manifest.dir.clone())?;
        let opts = trainer::TrainOpts {
            steps: fixtures::default_steps(&meta.size),
            ..Default::default()
        };
        trainer::train_or_load(&engine, &meta, &ds, &opts)?.params
    };

    let nlist = fixtures::default_nlist(ds.n_keys());
    let index = Arc::new(IvfIndex::build(&ds.keys, nlist, 15, 99));
    let k = (ds.n_keys() / 40).max(10); // Recall@2.5%
    let default_request = SearchRequest::top_k(k)
        .effort(Effort::Probes(nprobe))
        .mode(if map_queries {
            QueryMode::Mapped
        } else {
            QueryMode::Original
        });
    let cfg = if map_queries {
        ServerConfig::with_model(
            manifest.dir.clone(),
            meta,
            params,
            BatchPolicy::default(),
            default_request,
        )
    } else {
        ServerConfig::unmapped(BatchPolicy::default(), default_request)
    };
    let (server, handle) = Server::start(cfg, index)?;

    let nq = ds.val.x.rows();
    let t0 = std::time::Instant::now();
    let mut hits = 0usize;
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        for t in 0..clients {
            let handle = handle.clone();
            let ds = &ds;
            joins.push(s.spawn(move || -> usize {
                let mut local = 0;
                for i in (t..requests).step_by(clients) {
                    let q = ds.val.x.row(i % nq).to_vec();
                    if let Ok(resp) = handle.search(q) {
                        let truth = ds.val.gt.global_top1(i % nq).0 as u32;
                        if resp.hits.ids.contains(&truth) {
                            local += 1;
                        }
                    }
                }
                local
            }));
        }
        for j in joins {
            hits += j.join().unwrap();
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.latency_stats();
    drop(handle);
    server.shutdown()?;

    let mut rep = Report::new(&format!(
        "serve {config} map={map_queries} (IVF nlist={nlist} nprobe={nprobe}, {clients} clients)"
    ));
    rep.header(&["requests", "recall@2.5%", "qps", "mean ms", "p50 ms", "p95 ms"]);
    rep.row(&[
        requests.to_string(),
        pct(hits as f64 / requests as f64),
        format!("{:.0}", requests as f64 / wall),
        format!("{:.2}", stats.mean_s() * 1e3),
        format!("{:.2}", stats.quantile_s(0.5) * 1e3),
        format!("{:.2}", stats.quantile_s(0.95) * 1e3),
    ]);
    rep.emit("serve_example");
    Ok(())
}
