//! End-to-end driver (the repo's validation workload, EXPERIMENTS.md §E2E):
//! exercises every layer in one run —
//!
//!   synthetic corpus -> exact targets (L3) -> AOT Adam training loop
//!   (L2 graph + L1 kernel artifacts via PJRT) -> EMA checkpoint ->
//!   inference handles -> routing + IVF integration + serving metrics,
//!   all through the `amips::api` search surface.
//!
//! ```bash
//! cargo run --release --features xla --example train_e2e [-- --dataset nq-s --steps 4000]
//! ```

use amips::api::{recall_against_truth, Effort, MappedSearcher, QueryMode, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::bench_support::report::{f, pct, Report};
use amips::cli::Args;
use amips::coordinator::router::{routing_accuracy, AmortizedRouter, CentroidRouter, Router};
use amips::index::ivf::IvfIndex;
use amips::metrics::{retrieval, transport};
use amips::runtime::Engine;
use amips::tensor::Tensor;
use amips::trainer::{self, TrainOpts};
use amips::util::Timer;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let dataset = args.get_or("dataset", "nq-s").to_string();
    let steps = args.get_usize("steps", 4000)?;
    args.reject_unknown()?;

    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let total = Timer::start();

    // ---- stage 1: data (L3 substrate) --------------------------------
    let t = Timer::start();
    let ds = fixtures::prepare_dataset(&manifest, &dataset, 1)?;
    let ds10 = fixtures::prepare_dataset(&manifest, &dataset, 10)?;
    println!(
        "[data] {} keys, {} train q, {} val q  ({:.1}s)",
        ds.n_keys(),
        ds.train.x.rows(),
        ds.val.x.rows(),
        t.elapsed_s()
    );

    // ---- stage 2: training through the AOT step (fresh, no cache) ----
    let config = format!("{dataset}.keynet.s.l4.c1");
    let meta = manifest.meta(&config)?;
    let opts = TrainOpts {
        steps,
        eval_every: (steps / 8).max(1),
        ..Default::default()
    };
    let t = Timer::start();
    let out = trainer::train(&engine, &meta, &ds, &opts)?;
    let train_s = t.elapsed_s();
    let spm = steps as f64 / train_s;
    println!(
        "[train] {config}: {steps} steps in {train_s:.1}s ({spm:.0} steps/s), loss curve:"
    );
    for p in out.curve.train.iter().step_by(4) {
        println!("    step {:5}  loss {:.5}", p.step, p.loss);
    }
    println!(
        "[train] E_rel trajectory: {}  (final {:.3})",
        out.curve.e_rel_sparkline(),
        out.curve.final_e_rel().unwrap_or(f32::NAN)
    );

    // ---- stage 3: inference metrics -----------------------------------
    let model = amips::model::XlaModel::load(&engine, meta.clone(), &out.params)?;
    let pred = model.map_queries(&ds.val.x)?;
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();
    let rm = retrieval::evaluate(&pred, &ds.keys, &truth);
    let tgt: Tensor = ds.keys.gather_rows(&truth);
    let e_rel = transport::relative_transport_error(&pred, &ds.val.x, &tgt);
    println!(
        "[eval] match {} R@10 {} R@100 {} MRR {} E_rel {}",
        pct(rm.match_rate),
        pct(rm.recall_at_10),
        pct(rm.recall_at_100),
        f(rm.mrr),
        f(e_rel)
    );

    // ---- stage 4: routing (c=10, Sec. 4.3) ----------------------------
    let cfg10 = format!("{dataset}.keynet.s.l4.c10");
    let model10 = fixtures::trained_model(&engine, &manifest, &cfg10, &ds10, None)?;
    let learned = AmortizedRouter::new(model10);
    let centroid = CentroidRouter::new(ds10.centroids.clone());
    let tc: Vec<usize> = (0..ds10.val.gt.n_queries())
        .map(|q| ds10.val.gt.top_cluster(q))
        .collect();
    let mut rep = Report::new("e2e routing (k=1)");
    rep.header(&["router", "accuracy"]);
    for r in [&learned as &dyn Router, &centroid as &dyn Router] {
        let dec = r.route_batch(&ds10.val.x, 1)?;
        rep.row(&[r.name().to_string(), pct(routing_accuracy(&dec, &tc))]);
    }
    rep.emit("train_e2e");

    // ---- stage 5: IVF integration (Sec. 4.4) ---------------------------
    let index = IvfIndex::build(&ds.keys, fixtures::default_nlist(ds.n_keys()), 15, 42);
    let searcher = MappedSearcher::mapped(&index, &model);
    let k = (ds.n_keys() / 40).max(10);
    let mut rep = Report::new("e2e IVF integration (Recall@2.5%)");
    rep.header(&["nprobe", "orig", "mapped"]);
    for nprobe in [1usize, 2, 4, 8] {
        let req = SearchRequest::top_k(k).effort(Effort::Probes(nprobe));
        let orig = searcher.search(&ds.val.x, &req)?;
        let mapped = searcher.search(&ds.val.x, &req.mode(QueryMode::Mapped))?;
        rep.row(&[
            nprobe.to_string(),
            pct(recall_against_truth(&orig.hits, &truth, k)),
            pct(recall_against_truth(&mapped.hits, &truth, k)),
        ]);
    }
    rep.emit("train_e2e");

    println!("train_e2e OK in {:.1}s total", total.elapsed_s());
    Ok(())
}
