//! Cluster-routing demo (paper Sec. 4.3 / Fig. 3): learned SupportNet /
//! KeyNet routers vs the centroid baseline on a clustered database.
//!
//! ```bash
//! cargo run --release --example routing -- --dataset nq-s [--size s] [--model keynet]
//! ```

use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::cli::Args;
use amips::coordinator::router::{routing_accuracy, AmortizedRouter, CentroidRouter, Router};
use amips::metrics::flops;
use amips::runtime::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let dataset = args.get_or("dataset", "nq-s").to_string();
    let size = args.get_or("size", "s").to_string();
    let model_kind = args.get_or("model", "keynet").to_string();
    args.reject_unknown()?;

    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let config = format!("{dataset}.{model_kind}.{size}.l4.c10");
    let ds = fixtures::prepare_dataset(&manifest, &dataset, 10)?;
    let model = fixtures::trained_model(&engine, &manifest, &config, &ds, None)?;

    let learned = AmortizedRouter::new(model);
    let baseline = CentroidRouter::new(ds.centroids.clone());
    let true_clusters: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.top_cluster(q))
        .collect();
    let mut sizes = vec![0usize; ds.c];
    for &a in &ds.assign {
        sizes[a as usize] += 1;
    }

    let mut rep = Report::new(&format!("routing on {dataset} (c=10): {config} vs centroid"));
    rep.header(&["router", "top-k", "accuracy", "kFLOP/query"]);
    for k in 1..=5usize {
        for router in [&learned as &dyn Router, &baseline as &dyn Router] {
            let dec = router.route_batch(&ds.val.x, k)?;
            let acc = routing_accuracy(&dec, &true_clusters);
            let avg: f64 = dec
                .iter()
                .map(|d| {
                    let picked: Vec<usize> =
                        d.clusters.iter().map(|&c| sizes[c as usize]).collect();
                    flops::routing_total_flops(d.selection_flops, &picked, ds.d()) as f64
                })
                .sum::<f64>()
                / dec.len() as f64;
            rep.row(&[
                router.name().to_string(),
                k.to_string(),
                pct(acc),
                format!("{:.1}", avg / 1e3),
            ]);
        }
    }
    rep.emit("routing_example");
    Ok(())
}
