//! Quickstart: the 60-second tour of the public API — pure Rust, no
//! artifacts or XLA required.
//!
//! One request type drives every query path:
//!
//! * any index backbone behind `Searcher` (here: IVF),
//! * the mapped pipeline (`MappedSearcher` + a `QueryMap`) — the paper's
//!   Sec. 4.4 drop-in integration (with `--features xla` a trained
//!   KeyNet `AmortizedModel` is the `QueryMap`; here an identity map
//!   stands in),
//! * routed search (`RoutedSearcher` + any `Router`) — Sec. 4.3.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use amips::api::{
    recall_against_truth, Effort, LinearQueryMap, MappedSearcher, QueryMode, RoutedSearcher,
    SearchRequest, Searcher,
};
use amips::coordinator::router::CentroidRouter;
use amips::data::dataset::PrepareOpts;
use amips::data::{CorpusSpec, Dataset};
use amips::index::ivf::IvfIndex;
use anyhow::Result;

fn main() -> Result<()> {
    // 1. A prepared dataset: synthetic clustered corpus + exact-MIPS
    //    targets (the same generator the benches use).
    let spec = CorpusSpec {
        name: "quickstart".into(),
        n_keys: 8_000,
        d: 32,
        n_queries: 2_400,
        shift: 0.5,
        spread: 2.0,
        modes: 10,
        seed: 7,
    };
    let ds = Dataset::prepare(
        &spec,
        &PrepareOpts {
            c: 8,
            augment: 1,
            val_queries: 600,
            kmeans_restarts: 1,
            ..Default::default()
        },
    );
    println!(
        "dataset {}: {} keys (d={}), {} val queries, {} clusters",
        ds.name,
        ds.n_keys(),
        ds.d(),
        ds.val.x.rows(),
        ds.c
    );
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();

    // 2. Build an IVF index over the dataset's own clustering — the
    //    index is never modified by any of the query paths below.
    let index = IvfIndex::from_clustering(&ds.keys, ds.centroids.clone(), &ds.assign);

    // 3. One request type, three query paths.
    let k = 10;
    let map = LinearQueryMap::identity(ds.d()); // KeyNet stand-in
    let mapped = MappedSearcher::mapped(&index, &map);
    let router = CentroidRouter::new(ds.centroids.clone());
    let routed = RoutedSearcher::new(&router, &index)?;

    println!(
        "\n{:>14}  {:>10}  {:>11}  {:>11}  {:>9}",
        "effort", "orig R@10", "mapped R@10", "routed R@10", "kFLOP/q"
    );
    for effort in [
        Effort::Probes(1),
        Effort::Probes(2),
        Effort::Probes(4),
        Effort::Exhaustive,
    ] {
        let req = SearchRequest::top_k(k).effort(effort);
        // original queries straight into the backbone (blanket Searcher)
        let orig = index.search(&ds.val.x, &req)?;
        // mapped pipeline: map the batch, then the same unmodified index
        let via_map = mapped.search(&ds.val.x, &req.mode(QueryMode::Mapped))?;
        // routed: the router picks the cells instead of centroid ranking
        let via_router = routed.search(&ds.val.x, &req.mode(QueryMode::Routed))?;
        println!(
            "{:>14}  {:>10}  {:>11}  {:>11}  {:>9.1}",
            format!("{effort:?}"),
            format!("{:.1}%", 100.0 * recall_against_truth(&orig.hits, &truth, k)),
            format!("{:.1}%", 100.0 * recall_against_truth(&via_map.hits, &truth, k)),
            format!(
                "{:.1}%",
                100.0 * recall_against_truth(&via_router.hits, &truth, k)
            ),
            orig.flops_per_query() / 1e3,
        );
    }

    // 4. The cost breakdown separates the stages.
    let resp = mapped.search(
        &ds.val.x,
        &SearchRequest::top_k(k)
            .effort(Effort::Probes(2))
            .mode(QueryMode::Mapped),
    )?;
    println!(
        "\nmapped @ Probes(2): map {} flops + scan {} flops over {} keys in {} cells \
         ({:.2} ms map, {:.2} ms scan)",
        resp.cost.map_flops,
        resp.cost.scan_flops,
        resp.cost.keys_scanned,
        resp.cost.cells_probed,
        resp.cost.map_seconds * 1e3,
        resp.cost.search_seconds * 1e3,
    );
    println!("\nquickstart OK");
    Ok(())
}
