//! Drop-in integration demo (paper Sec. 4.4 / Fig. 5): feed KeyNet's
//! predicted key ŷ(x) to an *unmodified* IVF index in place of the query
//! and trace recall vs nprobe/FLOPs/latency for original vs mapped — a
//! one-field change on the `SearchRequest`.
//!
//! ```bash
//! cargo run --release --features xla --example ivf_dropin -- --dataset nq-s --size s [--steps N]
//! ```

use amips::api::{recall_against_truth, Effort, MappedSearcher, QueryMode, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::cli::Args;
use amips::index::ivf::IvfIndex;
use amips::runtime::Engine;
use amips::trainer::TrainOpts;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let dataset = args.get_or("dataset", "nq-s").to_string();
    let size = args.get_or("size", "s").to_string();
    let steps = args.get_usize("steps", 0)?;
    let frac = args.get_f32("recall-frac", 0.01)?;
    args.reject_unknown()?;

    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let config = format!("{dataset}.keynet.{size}.l4.c1");
    let ds = fixtures::prepare_dataset(&manifest, &dataset, 1)?;
    let opts = (steps > 0).then(|| TrainOpts {
        steps,
        ..TrainOpts::default()
    });
    let model = fixtures::trained_model(&engine, &manifest, &config, &ds, opts)?;

    let nlist = fixtures::default_nlist(ds.n_keys());
    let index = IvfIndex::build(&ds.keys, nlist, 15, 42);
    let searcher = MappedSearcher::mapped(&index, &model);
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();
    let k = ((ds.n_keys() as f32 * frac).ceil() as usize).max(1);

    let mut rep = Report::new(&format!(
        "IVF drop-in: {config} vs orig (nlist={nlist}, Recall@{:.2}%={k})",
        frac * 100.0
    ));
    rep.header(&[
        "nprobe", "orig R", "mapped R", "orig MFLOP", "mapped MFLOP", "orig ms/q", "mapped ms/q",
    ]);
    for nprobe in [1usize, 2, 4, 8, 16, 32] {
        if nprobe > nlist {
            break;
        }
        let req = SearchRequest::top_k(k).effort(Effort::Probes(nprobe));
        let orig = searcher.search(&ds.val.x, &req)?;
        let mapped = searcher.search(&ds.val.x, &req.mode(QueryMode::Mapped))?;
        rep.row(&[
            nprobe.to_string(),
            pct(recall_against_truth(&orig.hits, &truth, k)),
            pct(recall_against_truth(&mapped.hits, &truth, k)),
            format!("{:.3}", orig.flops_per_query() / 1e6),
            format!("{:.3}", mapped.flops_per_query() / 1e6),
            format!("{:.3}", orig.seconds_per_query() * 1e3),
            format!("{:.3}", mapped.seconds_per_query() * 1e3),
        ]);
    }
    rep.emit("ivf_dropin");
    Ok(())
}
