//! Build once, serve many: the index-lifecycle tour — pure Rust, no
//! artifacts or XLA required.
//!
//! 1. Parse typed `IndexSpec`s and build three backbones into a
//!    `Catalog` of versioned artifacts (the expensive k-means/PQ
//!    training happens exactly once, here).
//! 2. Drop everything and reopen the catalog from disk — pure
//!    deserialization, the path every serving replica takes.
//! 3. Query the reloaded collections through the same `Searcher` API,
//!    then put one behind the threaded coordinator `Server`.
//!
//! ```bash
//! cargo run --release --example build_serve
//! ```

use amips::api::{Effort, SearchRequest, Searcher};
use amips::coordinator::{BatchPolicy, Server, ServerConfig};
use amips::index::{BuildCtx, Catalog, IndexSpec, VectorIndex};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::{Rng, TempDir, Timer};
use anyhow::Result;

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    Rng::new(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

fn main() -> Result<()> {
    let tmp = TempDir::new("amips-build-serve"); // removed on drop, even after a crash mid-run
    let root = tmp.join("catalog");
    let keys = unit(&[10_000, 32], 1);
    let sample = unit(&[256, 32], 2);
    let queries = unit(&[16, 32], 3);

    // 1. build once: typed specs -> persisted artifacts (the sharded
    //    spec partitions the keys and builds one IVF per shard)
    {
        let mut catalog = Catalog::create(&root)?;
        for spec_str in [
            "ivf(nlist=64)",
            "scann(nlist=64,eta=4)",
            "leanvec(d_low=8,nlist=64)",
            "sharded(shards=4,inner=ivf(nlist=16))",
        ] {
            let spec: IndexSpec = spec_str.parse()?;
            let timer = Timer::start();
            let entry = catalog.build_collection(
                &format!("docs-{}", spec.name()),
                &spec,
                &keys,
                &BuildCtx {
                    sample_queries: Some(&sample),
                    seed: 42,
                },
            )?;
            println!(
                "built  {:13} {:.2}s -> {}",
                entry.name,
                timer.elapsed_s(),
                entry.path.display()
            );
        }
    } // everything dropped: nothing survives in memory

    // 2. serve many: reopen from disk — no k-means/PQ training runs here
    let timer = Timer::start();
    let catalog = Catalog::open(&root)?;
    println!(
        "\nreopened {} collections in {:.3}s: {:?}",
        catalog.len(),
        timer.elapsed_s(),
        catalog.names()
    );
    let req = SearchRequest::top_k(5).effort(Effort::Probes(4));
    for entry in catalog.entries() {
        let resp = entry.index.search(&queries, &req)?;
        let (id, score) = resp.hits[0].top1().unwrap();
        println!(
            "{:13} [{}] top1(q0) = id {id} score {score:.3}",
            entry.name,
            entry.index.spec()
        );
    }

    // 3. the same artifacts behind the threaded server — the sharded
    //    collection serves through the identical path
    for collection in ["docs-ivf", "docs-sharded"] {
        let (server, handle) = Server::start_from_catalog(
            &catalog,
            collection,
            ServerConfig::unmapped(BatchPolicy::default(), req),
        )?;
        for i in 0..4 {
            let resp = handle.search(queries.row(i).to_vec())?;
            println!(
                "{collection} q{i}: top1 id {:?} ({} keys scanned)",
                resp.hits.ids.first(),
                resp.cost.keys_scanned
            );
        }
        drop(handle);
        server.shutdown()?;
    }
    println!("\nbuild_serve OK");
    Ok(())
}
