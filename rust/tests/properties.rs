//! Randomized property tests over the coordinator and index invariants
//! (proptest is not available offline; these use the repo's deterministic
//! PRNG to sweep hundreds of generated cases per property).
//!
//! Case counts scale with `AMIPS_PROP_CASES` (see
//! `amips::util::prop_cases`): PR runs use the fast defaults, the
//! scheduled CI deep sweep sets 2000.
//! Sweeps are deterministic in the case index, so a failure reproduces
//! with the same env value and prints its case number.

use amips::api::{Effort, SearchRequest, Searcher};
use amips::coordinator::batcher::{BatchPolicy, Batcher};
use amips::coordinator::router::{routing_accuracy, CentroidRouter, Router, RoutingDecision};
use amips::data::ground_truth;
use amips::index::traits::{TopK, VectorIndex};
use amips::index::{flat::FlatIndex, ivf::IvfIndex, kmeans::KMeans, soar::SoarIndex};
use amips::index::{BuildCtx, IndexSpec};
use amips::tensor::{dot, normalize_rows, Tensor};
use amips::util::{prop_cases, test_rng};
use std::time::Duration;

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    test_rng(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

// ---------------------------------------------------------------------------
// TopK: equivalent to full sort + truncate, for arbitrary inputs
// ---------------------------------------------------------------------------

#[test]
fn prop_topk_offer_matches_push() {
    // the scan-loop fast path (early-reject against floor()) must be
    // result-identical to naive push on any stream — including NaN
    // (fails every comparison), ±inf, and heavy ties at the floor
    let mut rng = test_rng(512);
    for case in 0..prop_cases(300) {
        let n = 1 + rng.below(300);
        let k = 1 + rng.below(24);
        let scores: Vec<f32> = (0..n)
            .map(|_| match rng.below(12) {
                0 => f32::NAN,
                1 => f32::NEG_INFINITY,
                2 => f32::INFINITY,
                // coarse grid => frequent exact ties
                _ => (rng.normal() as f32 * 4.0).round() / 2.0,
            })
            .collect();
        let mut naive = TopK::new(k);
        let mut fast = TopK::new(k);
        for (i, &s) in scores.iter().enumerate() {
            naive.push(s, i as u32);
            fast.offer(s, i as u32);
        }
        assert_eq!(
            naive.into_sorted(),
            fast.into_sorted(),
            "case {case}: n={n} k={k}"
        );
    }
}

#[test]
fn prop_topk_matches_sort() {
    let mut rng = test_rng(100);
    for case in 0..prop_cases(300) {
        let n = 1 + rng.below(200);
        let k = 1 + rng.below(20);
        let scores: Vec<f32> = (0..n).map(|_| (rng.normal() as f32 * 10.0).round() / 4.0).collect();
        let mut topk = TopK::new(k);
        for (i, &s) in scores.iter().enumerate() {
            topk.push(s, i as u32);
        }
        let (got_ids, got_scores) = topk.into_sorted();
        // oracle: stable sort desc by (score, -id)
        let mut oracle: Vec<(f32, u32)> = scores
            .iter()
            .enumerate()
            .map(|(i, &s)| (s, i as u32))
            .collect();
        oracle.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        oracle.truncate(k);
        assert_eq!(
            got_ids,
            oracle.iter().map(|e| e.1).collect::<Vec<_>>(),
            "case {case}: n={n} k={k}"
        );
        assert_eq!(got_scores, oracle.iter().map(|e| e.0).collect::<Vec<_>>());
    }
}

// ---------------------------------------------------------------------------
// IVF invariants: permutation-completeness and nprobe monotonicity
// ---------------------------------------------------------------------------

#[test]
fn prop_ivf_results_subset_of_keys_and_sorted() {
    let mut rng = test_rng(200);
    for case in 0..prop_cases(30) as u64 {
        let n = 50 + rng.below(400);
        let d = 8 + 8 * rng.below(4);
        let nlist = 2 + rng.below(12);
        let keys = unit(&[n, d], 1000 + case);
        let ivf = IvfIndex::build(&keys, nlist, 8, case);
        let q = unit(&[1, d], 2000 + case);
        let nprobe = 1 + rng.below(nlist);
        let res = ivf.search_effort(q.row(0), 10, Effort::Probes(nprobe));
        assert!(res.ids.iter().all(|&id| (id as usize) < n));
        for w in res.scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
        // scores must be true inner products of the claimed ids
        for (id, s) in res.ids.iter().zip(&res.scores) {
            let want = dot(q.row(0), keys.row(*id as usize));
            assert!((want - s).abs() < 1e-4);
        }
    }
}

#[test]
fn prop_ivf_recall_monotone_in_nprobe() {
    // Top-1 score found can only improve as more cells are probed.
    let mut rng = test_rng(300);
    for case in 0..prop_cases(20) as u64 {
        let n = 100 + rng.below(300);
        let keys = unit(&[n, 16], 3000 + case);
        let nlist = 8;
        let ivf = IvfIndex::build(&keys, nlist, 8, case);
        let q = unit(&[1, 16], 4000 + case);
        let mut prev = f32::NEG_INFINITY;
        for nprobe in 1..=nlist {
            let res = ivf.search_effort(q.row(0), 1, Effort::Probes(nprobe));
            let s = res.scores[0];
            assert!(
                s >= prev - 1e-5,
                "case {case}: nprobe {nprobe} got {s} < {prev}"
            );
            prev = prev.max(s);
        }
    }
}

#[test]
fn prop_soar_full_probe_equals_flat_and_never_duplicates() {
    let mut rng = test_rng(400);
    for case in 0..prop_cases(15) as u64 {
        let n = 80 + rng.below(200);
        let keys = unit(&[n, 12], 5000 + case);
        let nlist = 6;
        let soar = SoarIndex::build(&keys, nlist, 3, case);
        let flat = FlatIndex::new(keys.clone());
        let q = unit(&[1, 12], 6000 + case);
        let a = soar.search_effort(q.row(0), 5, Effort::Exhaustive);
        let b = flat.search_effort(q.row(0), 5, Effort::Exhaustive);
        assert_eq!(a.ids, b.ids, "case {case}");
        let mut ids = a.ids.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), a.ids.len());
    }
}

#[test]
fn prop_parallel_batch_search_matches_sequential() {
    // the blanket Searcher impl fans the batch out over the thread pool;
    // results must be identical to one-query-at-a-time scans, in order
    let mut rng = test_rng(450);
    for case in 0..prop_cases(10) as u64 {
        let n = 100 + rng.below(300);
        let nq = 1 + rng.below(60);
        let keys = unit(&[n, 16], 12_000 + case);
        let ivf = IvfIndex::build(&keys, 8, 8, case);
        let q = unit(&[nq, 16], 13_000 + case);
        let nprobe = 1 + rng.below(8);
        let req = SearchRequest::top_k(5).effort(Effort::Probes(nprobe));
        let resp = ivf.search(&q, &req).unwrap();
        assert_eq!(resp.n_queries(), nq, "case {case}");
        let mut total_scanned = 0u64;
        for i in 0..nq {
            let single = ivf.search_effort(q.row(i), 5, Effort::Probes(nprobe));
            assert_eq!(resp.hits[i].ids, single.ids, "case {case} q {i}");
            assert_eq!(resp.hits[i].scores, single.scores);
            total_scanned += single.cost.keys_scanned;
        }
        assert_eq!(resp.cost.keys_scanned, total_scanned);
    }
}

// ---------------------------------------------------------------------------
// k-means invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_kmeans_partition_is_total_and_consistent() {
    let mut rng = test_rng(500);
    for case in 0..prop_cases(10) as u64 {
        let n = 60 + rng.below(300);
        let c = 2 + rng.below(8);
        let x = unit(&[n, 16], 7000 + case);
        let km = KMeans::fit(&x, c, 10, case);
        assert_eq!(km.assign.len(), n);
        assert!(km.assign.iter().all(|&a| (a as usize) < c));
        assert_eq!(km.sizes.iter().sum::<usize>(), n);
        // every point's assigned centroid must be its argmax centroid
        for i in 0..n {
            let mut best = (0usize, f32::NEG_INFINITY);
            for j in 0..c {
                let s = dot(x.row(i), km.centroids.row(j));
                if s > best.1 {
                    best = (j, s);
                }
            }
            // Lloyd updates centroids after the final assignment, so the
            // stored labels are argmax w.r.t. the *previous* centroids;
            // allow the one-step drift but require near-optimality.
            let assigned = dot(x.row(i), km.centroids.row(km.assign[i] as usize));
            assert!(assigned >= best.1 - 0.15, "case {case} point {i}");
        }
    }
}

// ---------------------------------------------------------------------------
// Ground truth: per-cluster tops dominate their cluster members
// ---------------------------------------------------------------------------

#[test]
fn prop_ground_truth_is_argmax_within_cluster() {
    let mut rng = test_rng(600);
    for case in 0..prop_cases(10) as u64 {
        let n = 50 + rng.below(150);
        let c = 1 + rng.below(5);
        let keys = unit(&[n, 8], 8000 + case);
        let queries = unit(&[12, 8], 9000 + case);
        let assign: Vec<u32> = (0..n).map(|i| (i % c) as u32).collect();
        let gt = ground_truth::compute(
            &queries,
            &keys,
            c,
            if c > 1 { Some(&assign) } else { None },
        );
        for q in 0..12 {
            for j in 0..c {
                let best = gt.idx(q, j);
                assert_eq!(assign[best] as usize % c, j % c);
                for m in 0..n {
                    if assign[m] as usize == j {
                        assert!(dot(queries.row(q), keys.row(m)) <= gt.score(q, j) + 1e-5);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Router invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_centroid_router_accuracy_monotone_in_k() {
    let mut rng = test_rng(700);
    for case in 0..prop_cases(10) as u64 {
        let c = 4 + rng.below(8);
        let centroids = unit(&[c, 16], 10_000 + case);
        let router = CentroidRouter::new(centroids.clone());
        let queries = unit(&[64, 16], 11_000 + case);
        let truth: Vec<usize> = (0..64).map(|i| i % c).collect();
        let mut prev = 0.0;
        for k in 1..=c {
            let dec = router.route_batch(&queries, k).unwrap();
            // decisions have exactly k distinct clusters
            for d in &dec {
                assert_eq!(d.clusters.len(), k);
                let mut u = d.clusters.clone();
                u.sort_unstable();
                u.dedup();
                assert_eq!(u.len(), k);
            }
            let acc = routing_accuracy(&dec, &truth);
            assert!(acc >= prev - 1e-9, "case {case} k={k}");
            prev = acc;
        }
        // k = c must always hit
        assert!((prev - 1.0).abs() < 1e-9);
    }
}

#[test]
fn prop_routing_accuracy_bounds() {
    let dec: Vec<RoutingDecision> = (0..50)
        .map(|i| RoutingDecision {
            clusters: vec![(i % 3) as u32],
            selection_flops: 0,
        })
        .collect();
    let truth: Vec<usize> = (0..50).map(|i| i % 3).collect();
    assert_eq!(routing_accuracy(&dec, &truth), 1.0);
    let wrong: Vec<usize> = (0..50).map(|i| (i + 1) % 3).collect();
    assert_eq!(routing_accuracy(&dec, &wrong), 0.0);
}

// ---------------------------------------------------------------------------
// Batcher: no loss, no duplication, order preserved, under random load
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_items() {
    let mut rng = test_rng(800);
    for case in 0..prop_cases(20) {
        let total = 1 + rng.below(500);
        let max_batch = 1 + rng.below(64);
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..total {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(1),
            },
        );
        let mut seen = Vec::new();
        while let Some((batch, _)) = b.next_batch() {
            assert!(batch.len() <= max_batch, "case {case}");
            seen.extend(batch);
        }
        assert_eq!(seen, (0..total).collect::<Vec<_>>(), "case {case}");
    }
}

// ---------------------------------------------------------------------------
// TopK merge invariant: merging per-shard top-k lists equals top-k over
// the concatenated stream — exactly what ShardedIndex's merger relies on
// ---------------------------------------------------------------------------

/// Drain a [`TopK`] and re-push its survivors into `into` — the shard
/// merger's merge step.
fn merge_into(from: TopK, into: &mut TopK) {
    let (ids, scores) = from.into_sorted();
    for (id, score) in ids.into_iter().zip(scores) {
        into.push(score, id);
    }
}

#[test]
fn prop_topk_shard_merge_equals_concatenated_stream() {
    let mut rng = test_rng(150);
    for case in 0..prop_cases(300) {
        let n = 1 + rng.below(300);
        let k = 1 + rng.below(25);
        let shards = 1 + rng.below(8);
        // coarse-quantized scores force frequent ties; ~5% NaN exercises
        // the worst-ranked mapping through the merge
        let items: Vec<(f32, u32)> = (0..n)
            .map(|i| {
                let s = if rng.below(20) == 0 {
                    f32::NAN
                } else {
                    (rng.normal() as f32 * 8.0).round() / 4.0
                };
                (s, i as u32)
            })
            .collect();
        let mut global = TopK::new(k);
        for &(s, id) in &items {
            global.push(s, id);
        }
        let want = global.into_sorted();
        // round-robin partition -> per-shard TopK -> merge the survivors
        let mut merged = TopK::new(k);
        for s in 0..shards {
            let mut local = TopK::new(k);
            for &(score, id) in items.iter().skip(s).step_by(shards) {
                local.push(score, id);
            }
            merge_into(local, &mut merged);
        }
        let got = merged.into_sorted();
        assert_eq!(got, want, "case {case}: n={n} k={k} shards={shards}");
    }
}

#[test]
fn topk_merge_edge_cases() {
    // k > len: the merge returns every element exactly once
    let mut a = TopK::new(10);
    a.push(0.5, 0);
    a.push(0.25, 2);
    let mut b = TopK::new(10);
    b.push(0.75, 1);
    let mut m = TopK::new(10);
    merge_into(a, &mut m);
    merge_into(b, &mut m);
    let (ids, scores) = m.into_sorted();
    assert_eq!(ids, vec![1, 0, 2]);
    assert_eq!(scores, vec![0.75, 0.5, 0.25]);

    // all-tied scores: the merged tiebreak is still ascending id, no
    // matter which shard each id came from
    let mut m = TopK::new(3);
    for shard in 0..3u32 {
        let mut t = TopK::new(3);
        for j in 0..3u32 {
            t.push(1.0, shard + 3 * j);
        }
        merge_into(t, &mut m);
    }
    let (ids, scores) = m.into_sorted();
    assert_eq!(ids, vec![0, 1, 2]);
    assert_eq!(scores, vec![1.0; 3]);

    // NaN-laced shards: NaNs rank worst (as -inf) but still fill slots
    // below the real results, lowest id first
    let mut a = TopK::new(2);
    a.push(f32::NAN, 4);
    a.push(0.9, 5);
    let mut b = TopK::new(2);
    b.push(f32::NAN, 1);
    b.push(f32::NAN, 3);
    let mut m = TopK::new(2);
    merge_into(a, &mut m);
    merge_into(b, &mut m);
    let (ids, scores) = m.into_sorted();
    assert_eq!(ids, vec![5, 1]);
    assert_eq!(scores[0], 0.9);
    assert_eq!(scores[1], f32::NEG_INFINITY);
}

// ---------------------------------------------------------------------------
// ShardedIndex: sharded flat at Exhaustive is bit-identical to unsharded
// flat (ISSUE 3 acceptance sweep: dim, n, k and shard count all vary)
// ---------------------------------------------------------------------------

#[test]
fn prop_sharded_flat_exhaustive_bit_identical_to_flat() {
    let mut rng = test_rng(160);
    for case in 0..prop_cases(120) as u64 {
        let n = 1 + rng.below(250);
        let d = 1 + rng.below(24);
        let k = 1 + rng.below(16);
        let shards = 1 + rng.below(n.min(8));
        let assign = if rng.below(2) == 0 {
            "round_robin"
        } else {
            "contiguous"
        };
        let keys = unit(&[n, d], 20_000 + case);
        let spec: IndexSpec = format!("sharded(shards={shards},assign={assign},inner=flat)")
            .parse()
            .unwrap();
        let sharded = spec.build(&keys, &BuildCtx::seeded(case)).unwrap();
        let flat = FlatIndex::new(keys.clone());
        let q = unit(&[2, d], 21_000 + case);
        for i in 0..2 {
            let a = sharded.search_effort(q.row(i), k, Effort::Exhaustive);
            let b = flat.search_effort(q.row(i), k, Effort::Exhaustive);
            assert_eq!(
                a.ids, b.ids,
                "case {case}: n={n} d={d} k={k} shards={shards} assign={assign} q{i}"
            );
            assert_eq!(a.scores, b.scores, "case {case} q{i}");
            // every shard scanned everything: summed cost equals flat's
            assert_eq!(a.cost.keys_scanned, b.cost.keys_scanned, "case {case}");
            assert_eq!(a.cost.flops, b.cost.flops, "case {case}");
        }
    }
}

// ---------------------------------------------------------------------------
// Tensor IO: roundtrip for arbitrary shapes
// ---------------------------------------------------------------------------

#[test]
fn prop_tensor_io_roundtrip() {
    let mut rng = test_rng(900);
    for case in 0..prop_cases(50) {
        let rank = rng.below(3) + 1;
        let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(20)).collect();
        let mut t = Tensor::zeros(&shape);
        rng.fill_normal(t.data_mut(), 3.0);
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let back = Tensor::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(t, back, "case {case} shape {shape:?}");
    }
}

// ---------------------------------------------------------------------------
// Kernel dispatch: every available tier agrees with the scalar reference
// within the documented tolerance, on remainder-lane dims and non-finite
// inputs alike (the contract in `tensor::kernels`' module docs)
// ---------------------------------------------------------------------------

#[test]
fn prop_dot_tiers_agree_with_scalar_within_contract() {
    use amips::tensor::kernels::{self, Tier};
    let dims = [1usize, 3, 7, 8, 15, 64, 100, 127];
    let mut rng = test_rng(1000);
    for case in 0..prop_cases(60) {
        let d = dims[rng.below(dims.len())];
        let scale = [0.1f32, 1.0, 100.0][rng.below(3)];
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        rng.fill_normal(&mut a, scale);
        rng.fill_normal(&mut b, scale);
        let want = kernels::dot_with(Tier::Scalar, &a, &b);
        let bound = 16.0 * f32::EPSILON
            * a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f32>()
            + 1e-6;
        for t in kernels::available_tiers() {
            let got = kernels::dot_with(t, &a, &b);
            assert!(
                (got - want).abs() <= bound,
                "case {case} {t:?} d={d}: {got} vs {want} (bound {bound})"
            );
        }
        // the public dispatched entry point must agree with *some* tier's
        // answer (it is one of them by construction)
        let dispatched = kernels::dot(&a, &b);
        assert!(
            (dispatched - want).abs() <= bound,
            "case {case} dispatched d={d}"
        );
    }
}

#[test]
fn prop_dot_tiers_propagate_non_finite_in_kind() {
    use amips::tensor::kernels;
    let dims = [1usize, 3, 7, 8, 15, 64, 100, 127];
    let mut rng = test_rng(1001);
    for case in 0..prop_cases(40) {
        let d = dims[rng.below(dims.len())];
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let poison = rng.below(d);
        let (val, check): (f32, fn(f32) -> bool) = match case % 3 {
            0 => (f32::NAN, f32::is_nan),
            1 => {
                b[poison] = 1.0;
                (f32::INFINITY, |s: f32| s == f32::INFINITY)
            }
            _ => {
                b[poison] = 1.0;
                (f32::NEG_INFINITY, |s: f32| s == f32::NEG_INFINITY)
            }
        };
        a[poison] = val;
        for t in kernels::available_tiers() {
            let got = kernels::dot_with(t, &a, &b);
            assert!(check(got), "case {case} {t:?} d={d} poison={poison}: {got}");
        }
    }
}
