//! Fault-injection and hostile-peer tests for the net stack.
//!
//! Server side: a seeded [`FaultyStream`] sweep tears client frames at
//! arbitrary byte boundaries, injects delays, and half-writes then
//! drops mid-frame; every outcome must be a correct reply or a typed
//! error — never a panic, a desynced stream, or a wedged shutdown.
//!
//! Client side: [`NetClient`] against hostile servers — a mid-reply
//! connection drop, an oversized Hits frame (must be a typed error
//! before any allocation), and a legacy server rejecting wire v2 (the
//! client downgrades to v1 transparently).
//!
//! Metrics listener: seeded garbage on the scrape port must never
//! hang, panic, or corrupt a snapshot (the listener never reads).
//!
//! Every random choice derives from `amips::util::test_rng`, so any
//! failure replays with `AMIPS_TEST_SEED=<printed seed>`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use amips::api::Effort;
use amips::coordinator::net::wire::{self, ErrorCode, ErrorFrame, Frame, HitsFrame, SearchFrame};
use amips::coordinator::net::{
    FaultPlan, FaultyStream, NetClient, NetError, NetServer, NetServerConfig, Tenant, WireError,
};
use amips::coordinator::BatchPolicy;
use amips::index::ivf::IvfIndex;
use amips::index::VectorIndex;
use amips::tensor::{normalize_rows, Tensor};
use amips::util::{test_rng, Rng};

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    Rng::new(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

/// One-collection server over a small IVF index.
fn small_server(cfg: NetServerConfig) -> (NetServer, String, Arc<IvfIndex>) {
    let keys = unit(&[500, 8], 41);
    let index = Arc::new(IvfIndex::build(&keys, 4, 4, 42));
    let tenant = Tenant::start(
        "docs",
        index.clone() as Arc<dyn VectorIndex>,
        None,
        BatchPolicy::default(),
        256,
    )
    .unwrap();
    let mut tenants = BTreeMap::new();
    tenants.insert("docs".to_string(), tenant);
    let server = NetServer::serve(tenants, "127.0.0.1:0", cfg).unwrap();
    let addr = server.local_addr().to_string();
    (server, addr, index)
}

fn search_frame(id: u64, query: &[f32]) -> Frame {
    Frame::Search(SearchFrame {
        request_id: id,
        collection: "docs".to_string(),
        k: 3,
        effort: Effort::Exhaustive,
        mode: amips::api::QueryMode::Original,
        deadline_micros: 0,
        query: query.to_vec(),
    })
}

#[test]
fn splitter_sweep_torn_frames_still_get_correct_replies() {
    let (server, addr, index) = small_server(NetServerConfig::default());
    let queries = unit(&[6, 8], 43);
    let mut seed_rng = test_rng(0xFA01);
    for round in 0..5 {
        let seed = seed_rng.below(1 << 31) as u64;
        let stream = TcpStream::connect(addr.as_str()).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .unwrap();
        // every write crosses the wire in 1..=3 byte fragments with
        // injected delays: the server's decoder sees every possible
        // partial-header/partial-payload boundary
        let mut fs = FaultyStream::new(stream, FaultPlan::splitter(seed));
        wire::write_frame_versioned(&mut fs, &Frame::Ping { token: round }, wire::VERSION)
            .unwrap_or_else(|e| panic!("seed {seed}: ping write: {e}"));
        match wire::read_frame(&mut fs) {
            Ok(Frame::Pong { token }) => assert_eq!(token, round, "seed {seed}"),
            other => panic!("seed {seed}: wanted Pong, got {other:?}"),
        }
        for (i, qi) in (0..queries.rows()).enumerate() {
            let q = queries.row(qi);
            let id = 100 + i as u64;
            wire::write_frame_versioned(&mut fs, &search_frame(id, q), wire::VERSION)
                .unwrap_or_else(|e| panic!("seed {seed}: search write: {e}"));
            match wire::read_frame(&mut fs) {
                Ok(Frame::Hits(h)) => {
                    let direct = index.search_effort(q, 3, Effort::Exhaustive);
                    assert_eq!(h.request_id, id, "seed {seed}, query {qi}");
                    assert_eq!(h.ids, direct.ids, "seed {seed}, query {qi}");
                    assert_eq!(h.scores, direct.scores, "seed {seed}, query {qi}");
                }
                other => panic!("seed {seed}: wanted Hits, got {other:?}"),
            }
        }
    }
    server.shutdown();
}

#[test]
fn cutter_sweep_half_written_frames_never_wedge_the_server() {
    let (server, addr, index) = small_server(NetServerConfig::default());
    let q = unit(&[1, 8], 44);
    let mut seed_rng = test_rng(0xFA02);
    // cut points spanning torn-magic, torn-header, and torn-payload
    for cut_after in [1u64, 4, 9, 10, 13, 27, 48] {
        let seed = seed_rng.below(1 << 31) as u64;
        let stream = TcpStream::connect(addr.as_str()).unwrap();
        let mut fs = FaultyStream::new(stream, FaultPlan::cutter(seed, cut_after));
        // the frame dies mid-wire; the client crashes (drops the socket)
        let err = wire::write_frame_versioned(&mut fs, &search_frame(1, q.row(0)), wire::VERSION)
            .expect_err("the cut must surface as a write error");
        assert_eq!(
            err.kind(),
            std::io::ErrorKind::BrokenPipe,
            "seed {seed}, cut {cut_after}"
        );
        drop(fs); // torn frame left on the server's read side
    }
    // the server took 7 torn frames and still serves healthy clients
    let mut healthy = NetClient::connect(addr.as_str()).unwrap();
    healthy.set_timeout(Some(Duration::from_secs(20))).unwrap();
    healthy.ping().unwrap();
    let hits = healthy
        .search(
            "docs",
            q.row(0),
            amips::coordinator::net::SearchOptions::top_k(3).effort(Effort::Exhaustive),
        )
        .unwrap();
    let direct = index.search_effort(q.row(0), 3, Effort::Exhaustive);
    assert_eq!(hits.ids, direct.ids);
    // ... and shutdown is not wedged by the torn connections
    let start = Instant::now();
    server.shutdown();
    assert!(
        start.elapsed() < Duration::from_secs(15),
        "shutdown wedged after torn frames ({}s)",
        start.elapsed().as_secs()
    );
}

/// Bind a one-connection hostile server; `behave` gets the accepted
/// stream.
fn hostile_server<F>(behave: F) -> (SocketAddr, std::thread::JoinHandle<()>)
where
    F: FnOnce(TcpStream) + Send + 'static,
{
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .unwrap();
        behave(stream);
    });
    (addr, handle)
}

/// Answer the client's negotiation probe as a v2 server would.
fn answer_probe(s: &mut TcpStream) {
    match wire::read_frame(s).unwrap() {
        Frame::Ping { token } => {
            wire::write_frame_versioned(s, &Frame::Pong { token }, wire::VERSION).unwrap()
        }
        other => panic!("hostile server wanted the probe Ping, got {other:?}"),
    }
}

#[test]
fn mid_reply_connection_drop_is_a_typed_wire_error() {
    let (addr, handle) = hostile_server(|mut s| {
        answer_probe(&mut s);
        let _search = wire::read_frame(&mut s).unwrap();
        // encode a full Hits reply, send half of it, vanish
        let mut buf = Vec::new();
        let hits = Frame::Hits(HitsFrame {
            request_id: 1,
            ids: vec![1, 2, 3],
            scores: vec![0.5, 0.4, 0.3],
            ..HitsFrame::default()
        });
        wire::write_frame_versioned(&mut buf, &hits, wire::VERSION).unwrap();
        s.write_all(&buf[..buf.len() / 2]).unwrap();
        let _ = s.flush();
        // drop: the client is left with half a frame
    });
    let mut client = NetClient::connect(addr).unwrap();
    client.set_timeout(Some(Duration::from_secs(10))).unwrap();
    let q = [0.5f32; 8];
    let err = client
        .search(
            "docs",
            &q,
            amips::coordinator::net::SearchOptions::top_k(3),
        )
        .expect_err("half a reply must not parse");
    assert!(
        matches!(err, NetError::Wire(_)),
        "mid-reply drop must be a wire error, got {err}"
    );
    handle.join().unwrap();
}

#[test]
fn oversized_hits_from_a_hostile_server_is_typed_before_allocation() {
    let (addr, handle) = hostile_server(|mut s| {
        answer_probe(&mut s);
        let _search = wire::read_frame(&mut s).unwrap();
        // header declaring a 4 GiB payload; a client that trusted it
        // would try to allocate that much before reading a byte
        let mut header = Vec::new();
        header.extend_from_slice(&wire::MAGIC);
        header.push(wire::VERSION);
        header.push(2); // Hits tag
        header.extend_from_slice(&u32::MAX.to_le_bytes());
        s.write_all(&header).unwrap();
        let _ = s.flush();
    });
    let mut client = NetClient::connect(addr).unwrap();
    client.set_timeout(Some(Duration::from_secs(10))).unwrap();
    let q = [0.5f32; 8];
    let err = client
        .search(
            "docs",
            &q,
            amips::coordinator::net::SearchOptions::top_k(3),
        )
        .expect_err("an oversized reply must be rejected");
    match err {
        NetError::Wire(WireError::Oversized { declared, cap, .. }) => {
            assert!(declared > cap, "declared {declared} vs cap {cap}");
        }
        other => panic!("wanted a typed Oversized wire error, got {other}"),
    }
    handle.join().unwrap();
}

#[test]
fn legacy_server_rejecting_v2_downgrades_the_client_to_v1() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        // connection 1: the v2 probe. A legacy server fails the header
        // version check and answers a typed Unsupported at v1, then
        // closes — exactly what the PR-era v1 server does.
        let (mut s1, _) = listener.accept().unwrap();
        let mut header = [0u8; 10];
        s1.read_exact(&mut header).unwrap();
        assert_eq!(&header[..4], &wire::MAGIC, "client spoke AMTP");
        assert_eq!(header[4], wire::VERSION, "probe is the newest version");
        wire::write_frame_versioned(
            &mut s1,
            &Frame::Error(ErrorFrame::conn(
                ErrorCode::Unsupported,
                "unsupported wire version 2".into(),
            )),
            wire::V1,
        )
        .unwrap();
        drop(s1);
        // connection 2: the downgraded v1 session
        let (mut s2, _) = listener.accept().unwrap();
        while let Ok(Frame::Ping { token }) = wire::read_frame(&mut s2) {
            wire::write_frame_versioned(&mut s2, &Frame::Pong { token }, wire::V1).unwrap();
        }
    });
    let mut client = NetClient::connect(addr).unwrap();
    assert_eq!(client.version(), wire::V1, "negotiation downgraded");
    client.set_timeout(Some(Duration::from_secs(10))).unwrap();
    client.ping().unwrap();
    drop(client);
    handle.join().unwrap();
}

#[test]
fn metrics_listener_survives_seeded_garbage() {
    let cfg = NetServerConfig {
        metrics_addr: Some("127.0.0.1:0".parse().unwrap()),
        ..NetServerConfig::default()
    };
    let (server, _addr, _index) = small_server(cfg);
    let maddr = server.metrics_addr().expect("metrics listener configured");
    let mut seed_rng = test_rng(0xFA03);
    for _ in 0..8 {
        let seed = seed_rng.below(1 << 31) as u64;
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(512);
        let garbage: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let mut s = TcpStream::connect(maddr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // the listener never reads, so any bytes — HTTP, AMTP, noise —
        // are inert; the write may fail once the snapshot side closes,
        // which is also fine
        let _ = s.write_all(&garbage);
        let mut body = String::new();
        s.read_to_string(&mut body)
            .unwrap_or_else(|e| panic!("seed {seed}: scrape read failed: {e}"));
        assert!(
            body.contains("amips_build_info"),
            "seed {seed}: snapshot missing build info: {body:?}"
        );
        // the detected kernel dispatch tier is exported as a build_info
        // label (satellite of the SIMD-dispatch PR)
        assert!(
            body.contains(&format!(
                "kernel=\"{}\"",
                amips::tensor::kernels::tier_name()
            )),
            "seed {seed}: snapshot missing kernel tier label: {body:?}"
        );
        assert!(
            body.contains("amips_tenant_served_total{collection=\"docs\"}"),
            "seed {seed}: snapshot missing per-tenant lines: {body:?}"
        );
    }
    let start = Instant::now();
    server.shutdown();
    assert!(
        start.elapsed() < Duration::from_secs(10),
        "metrics listener wedged shutdown"
    );
}
