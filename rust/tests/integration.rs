//! Integration tests over the full AOT pipeline: artifact loading, PJRT
//! execution, the training loop, inference handles, routing and the
//! serving coordinator. Requires the `xla` feature (pointing at a real
//! xla-rs) and `make artifacts` (skips itself gracefully otherwise).
#![cfg(feature = "xla")]

use amips::api::{Effort, MappedSearcher, QueryMode, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::coordinator::router::{routing_accuracy, AmortizedRouter, CentroidRouter, Router};
use amips::coordinator::{BatchPolicy, Server, ServerConfig};
use amips::data::dataset::PrepareOpts;
use amips::data::Dataset;
use amips::index::ivf::IvfIndex;
use amips::index::VectorIndex;
use amips::model::XlaModel;
use amips::runtime::{Engine, Manifest};
use amips::tensor::dot;
use amips::trainer::{self, TrainOpts};
use std::sync::Arc;

fn manifest_or_skip() -> Option<Manifest> {
    match fixtures::load_manifest() {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping integration test: run `make artifacts` first");
            None
        }
    }
}

fn tiny_dataset(manifest: &Manifest, name: &str, c: usize) -> Dataset {
    // smaller than the bench fixture: fast exact targets for tests
    let mut spec = manifest.dataset(name).unwrap().to_corpus_spec();
    spec.n_queries = 600;
    Dataset::prepare(
        &spec,
        &PrepareOpts {
            c,
            augment: 1,
            val_queries: 128,
            kmeans_restarts: 1,
            ..Default::default()
        },
    )
}

fn quick_opts(steps: usize) -> TrainOpts {
    TrainOpts {
        steps,
        eval_every: 0,
        log_every: steps,
        ..Default::default()
    }
}

#[test]
fn artifact_metas_parse_and_match_manifest() {
    let Some(m) = manifest_or_skip() else { return };
    assert!(!m.configs.is_empty());
    for config in m.configs.iter().take(12) {
        let meta = m.meta(config).expect(config);
        assert_eq!(&meta.name, config);
        assert!(meta.h >= 8);
        assert_eq!(meta.n_state_tensors, 4 * meta.n_param_tensors + 1);
        // every advertised artifact file must exist
        for part in ["init", "train", "fwd", "eval"] {
            let p = m.dir.join(format!("{config}.{part}.hlo.txt"));
            assert!(p.exists(), "{}", p.display());
        }
    }
}

#[test]
fn init_artifact_produces_valid_state() {
    let Some(m) = manifest_or_skip() else { return };
    let engine = Engine::new(m.dir.clone()).unwrap();
    let config = "fiqa-s.keynet.xs.l2.c1";
    let meta = m.meta(config).unwrap();
    let init = engine.load(&format!("{config}.init")).unwrap();
    let seed = amips::runtime::lit_scalar_u32(3).unwrap();
    let state = init.run(&[&seed]).unwrap();
    assert_eq!(state.len(), meta.n_state_tensors);
    // params (first block) should be finite and not all zero
    let p0 = amips::runtime::literal_to_vec(&state[0]).unwrap();
    assert!(p0.iter().all(|v| v.is_finite()));
    assert!(p0.iter().any(|&v| v != 0.0));
    // different seeds give different params
    let seed2 = amips::runtime::lit_scalar_u32(4).unwrap();
    let state2 = init.run(&[&seed2]).unwrap();
    let p1 = amips::runtime::literal_to_vec(&state2[0]).unwrap();
    assert_ne!(p0, p1);
}

#[test]
fn training_reduces_loss_and_checkpoints_roundtrip() {
    let Some(m) = manifest_or_skip() else { return };
    let engine = Engine::new(m.dir.clone()).unwrap();
    let config = "fiqa-s.keynet.xs.l2.c1";
    let meta = m.meta(config).unwrap();
    let ds = tiny_dataset(&m, "fiqa-s", 1);
    let mut opts = quick_opts(150);
    opts.log_every = 10;
    let out = trainer::train(&engine, &meta, &ds, &opts).unwrap();
    let first = out.curve.train.first().unwrap().loss;
    let last = out.curve.train.last().unwrap().loss;
    assert!(
        last < first * 0.8,
        "loss did not improve: {first} -> {last}"
    );
    // checkpoint roundtrip preserves params exactly
    let path = std::env::temp_dir().join("amips_it_ckpt.amts");
    out.params.save(&meta, &path).unwrap();
    let back = amips::model::ParamSet::load(&meta, &path).unwrap();
    assert_eq!(back.tensors[0], out.params.tensors[0]);
    let _ = std::fs::remove_file(path);
}

#[test]
fn supportnet_grad_satisfies_euler_identity() {
    // <grad f(x), x> == f(x) for the homogenized SupportNet — checks the
    // fwd and grad artifacts agree with each other through PJRT.
    let Some(m) = manifest_or_skip() else { return };
    let engine = Engine::new(m.dir.clone()).unwrap();
    let config = "fiqa-s.supportnet.xs.l2.c1";
    let meta = m.meta(config).unwrap();
    let ds = tiny_dataset(&m, "fiqa-s", 1);
    let out = trainer::train(&engine, &meta, &ds, &quick_opts(30)).unwrap();
    let model = XlaModel::load(&engine, meta.clone(), &out.params).unwrap();
    let (scores, keys) = model.scores_and_keys(&ds.val.x).unwrap();
    let d = meta.d;
    for q in 0..16 {
        let f = scores.row(q)[0];
        let g = &keys.data()[q * d..(q + 1) * d];
        let euler = dot(g, ds.val.x.row(q));
        assert!(
            (euler - f).abs() < 1e-2 * f.abs().max(1.0),
            "q={q}: <grad,x>={euler} vs f={f}"
        );
    }
}

#[test]
fn keynet_scores_consistent_with_keys() {
    let Some(m) = manifest_or_skip() else { return };
    let engine = Engine::new(m.dir.clone()).unwrap();
    let config = "fiqa-s.keynet.xs.l2.c1";
    let meta = m.meta(config).unwrap();
    let ds = tiny_dataset(&m, "fiqa-s", 1);
    let out = trainer::train(&engine, &meta, &ds, &quick_opts(30)).unwrap();
    let model = XlaModel::load(&engine, meta.clone(), &out.params).unwrap();
    let (scores, keys) = model.scores_and_keys(&ds.val.x).unwrap();
    let d = meta.d;
    for q in 0..16 {
        let k = &keys.data()[q * d..(q + 1) * d];
        let want = dot(k, ds.val.x.row(q));
        let got = scores.row(q)[0];
        assert!((got - want).abs() < 1e-4, "q={q}: {got} vs {want}");
    }
}

#[test]
fn clustered_training_and_routing_beats_nothing() {
    let Some(m) = manifest_or_skip() else { return };
    let engine = Engine::new(m.dir.clone()).unwrap();
    let config = "quora-s.keynet.xs.l4.c10";
    let meta = m.meta(config).unwrap();
    let ds = tiny_dataset(&m, "quora-s", 10);
    let out = trainer::train(&engine, &meta, &ds, &quick_opts(250)).unwrap();
    let model = XlaModel::load(&engine, meta, &out.params).unwrap();
    let router = AmortizedRouter::new(model);
    let baseline = CentroidRouter::new(ds.centroids.clone());
    let tc: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.top_cluster(q))
        .collect();
    let learned = routing_accuracy(&router.route_batch(&ds.val.x, 2).unwrap(), &tc);
    let cent = routing_accuracy(&baseline.route_batch(&ds.val.x, 2).unwrap(), &tc);
    // a briefly-trained router should already be in the baseline's league
    assert!(learned > 0.5, "learned router accuracy {learned}");
    assert!(cent > 0.5);
}

#[test]
fn mapped_pipeline_runs_on_every_backend() {
    let Some(m) = manifest_or_skip() else { return };
    let engine = Engine::new(m.dir.clone()).unwrap();
    let config = "fiqa-s.keynet.xs.l2.c1";
    let meta = m.meta(config).unwrap();
    let ds = tiny_dataset(&m, "fiqa-s", 1);
    let out = trainer::train(&engine, &meta, &ds, &quick_opts(30)).unwrap();
    let model = XlaModel::load(&engine, meta, &out.params).unwrap();
    let nlist = 8;
    let backends: Vec<Box<dyn amips::index::VectorIndex>> = vec![
        Box::new(IvfIndex::build(&ds.keys, nlist, 8, 1)),
        Box::new(amips::index::scann::ScannIndex::build(
            &ds.keys, nlist, 8, 10, 4.0, 8, 1,
        )),
        Box::new(amips::index::soar::SoarIndex::build(&ds.keys, nlist, 4, 1)),
        Box::new(amips::index::leanvec::LeanVecIndex::build(
            &ds.keys,
            16,
            nlist,
            None,
            amips::index::Storage::F32,
            1,
        )),
    ];
    let req = SearchRequest::top_k(5)
        .effort(Effort::Probes(2))
        .mode(QueryMode::Mapped);
    for idx in &backends {
        let searcher = MappedSearcher::mapped(idx.as_ref(), &model);
        let out = searcher.search(&ds.val.x, &req).unwrap();
        assert_eq!(out.n_queries(), ds.val.x.rows(), "{}", idx.name());
        assert!(out.hits.iter().all(|h| !h.is_empty()));
        assert!(out.cost.map_flops > 0);
    }
}

#[test]
fn server_end_to_end_under_concurrent_load() {
    let Some(m) = manifest_or_skip() else { return };
    let config = "fiqa-s.keynet.xs.l2.c1";
    let meta = m.meta(config).unwrap();
    let ds = tiny_dataset(&m, "fiqa-s", 1);
    let params = {
        let engine = Engine::new(m.dir.clone()).unwrap();
        trainer::train(&engine, &meta, &ds, &quick_opts(30))
            .unwrap()
            .params
    };
    let index = Arc::new(IvfIndex::build(&ds.keys, 8, 8, 1));
    let default_request = SearchRequest::top_k(5)
        .effort(Effort::Probes(2))
        .mode(QueryMode::Mapped);
    let (server, handle) = Server::start(
        ServerConfig::with_model(
            m.dir.clone(),
            meta,
            params,
            BatchPolicy {
                max_batch: 64,
                max_wait: std::time::Duration::from_millis(1),
            },
            default_request,
        ),
        index,
    )
    .unwrap();
    let total = 64usize;
    std::thread::scope(|s| {
        for c in 0..4usize {
            let handle = handle.clone();
            let ds = &ds;
            s.spawn(move || {
                for i in (c..total).step_by(4) {
                    let resp = handle
                        .search(ds.val.x.row(i % ds.val.x.rows()).to_vec())
                        .unwrap();
                    assert_eq!(resp.hits.len(), 5);
                    assert!(resp.cost.map_flops > 0);
                }
            });
        }
    });
    let stats = server.latency_stats();
    assert_eq!(stats.count(), total as u64);
    drop(handle);
    server.shutdown().unwrap();
}

#[test]
fn failure_injection_bad_inputs_are_rejected() {
    let Some(m) = manifest_or_skip() else { return };
    let engine = Engine::new(m.dir.clone()).unwrap();
    // unknown artifact
    assert!(engine.load("no.such.artifact").is_err());
    // checkpoint/meta shape mismatch
    let meta_a = m.meta("fiqa-s.keynet.xs.l2.c1").unwrap();
    let meta_b = m.meta("fiqa-s.keynet.s.l4.c1").unwrap();
    let ds = tiny_dataset(&m, "fiqa-s", 1);
    let out = trainer::train(&engine, &meta_a, &ds, &quick_opts(10)).unwrap();
    assert!(out.params.validate(&meta_b).is_err());
    // wrong dataset c for a clustered model
    let meta_c10 = m.meta("quora-s.keynet.xs.l4.c10").unwrap();
    assert!(trainer::train(&engine, &meta_c10, &ds, &quick_opts(5)).is_err());
    // wrong query dimensionality through the model handle
    let model = XlaModel::load(&engine, meta_a, &out.params).unwrap();
    let bad = amips::tensor::Tensor::zeros(&[4, 3]);
    assert!(model.scores(&bad).is_err());
}
