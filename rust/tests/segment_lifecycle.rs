//! Mutable-collection lifecycle properties (pure Rust — default
//! features): randomized insert/delete/upsert traces must make a
//! [`MutableCollection`] *bit-identical* at `Effort::Exhaustive` to a
//! from-scratch [`FlatIndex`] over the post-trace key set — before and
//! after `commit()`/`compact()` and across a reopen — plus concurrent
//! search-during-compaction consistency and the crash-recovery
//! contract for generations.

use amips::api::Effort;
use amips::index::flat::FlatIndex;
use amips::index::{IndexSpec, MutableCollection, VectorIndex};
use amips::tensor::Tensor;
use amips::util::{prop_cases, test_rng, Rng, TempDir};
use std::collections::BTreeMap;
use std::sync::Arc;

const D: usize = 12;

fn rand_rows(rng: &mut Rng, n: usize) -> Tensor {
    let mut t = Tensor::zeros(&[n, D]);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

/// The oracle: the live `(gid, key)` set, gid-sorted (which is exactly
/// the order a compacted segment stores), as a flat index plus the
/// local→global id map.
fn reference(model: &BTreeMap<u32, Vec<f32>>) -> Option<(FlatIndex, Vec<u32>)> {
    if model.is_empty() {
        return None;
    }
    let gids: Vec<u32> = model.keys().copied().collect();
    let mut data = Vec::with_capacity(model.len() * D);
    for row in model.values() {
        data.extend_from_slice(row);
    }
    Some((FlatIndex::new(Tensor::from_vec(&[gids.len(), D], data)), gids))
}

/// Exhaustive search on the collection must match the oracle bit-for-bit
/// (ids after the local→global remap, scores exactly).
fn assert_matches_reference(
    coll: &MutableCollection,
    model: &BTreeMap<u32, Vec<f32>>,
    queries: &Tensor,
    label: &str,
) {
    assert_eq!(coll.len(), model.len(), "{label}: live count");
    let Some((flat, gids)) = reference(model) else {
        let got = coll.search_effort(queries.row(0), 3, Effort::Exhaustive);
        assert!(got.ids.is_empty(), "{label}: empty collection returned hits");
        return;
    };
    for q in 0..queries.rows() {
        for k in [1usize, 5, 17] {
            let want = flat.search_effort(queries.row(q), k, Effort::Exhaustive);
            let want_ids: Vec<u32> = want.ids.iter().map(|&l| gids[l as usize]).collect();
            let got = coll.search_effort(queries.row(q), k, Effort::Exhaustive);
            assert_eq!(got.ids, want_ids, "{label}: q{q} k{k} ids");
            assert_eq!(got.scores, want.scores, "{label}: q{q} k{k} scores");
        }
    }
}

/// Satellite: the randomized-trace equivalence property. Traces mix
/// inserts, deletes (live, repeated and unknown ids), upserts (existing
/// and fresh ids) with interleaved commits and compactions; equivalence
/// is checked mid-trace, post-trace, after commit, after compact and
/// after a fresh-process reopen.
#[test]
fn random_trace_matches_flat_rebuild_before_and_after_compaction() {
    for case in 0..prop_cases(8) {
        let seed = 0x5E6 + case as u64;
        let mut rng = test_rng(seed);
        let tmp = TempDir::new("amips-seg-trace");
        let dir = tmp.join("c.seg");
        let spec = IndexSpec::default_for("flat").unwrap();
        let coll = MutableCollection::create(&dir, spec.clone(), D, seed).unwrap();
        let mut model: BTreeMap<u32, Vec<f32>> = BTreeMap::new();
        let queries = rand_rows(&mut rng, 4);

        for step in 0..40 {
            match rng.below(10) {
                // inserts dominate so the collection actually grows
                0..=4 => {
                    let n = 1 + rng.below(6);
                    let vecs = rand_rows(&mut rng, n);
                    let ids = coll.insert(&vecs).unwrap();
                    assert_eq!(ids.len(), n);
                    for (r, gid) in ids.into_iter().enumerate() {
                        assert!(
                            model.insert(gid, vecs.row(r).to_vec()).is_none(),
                            "id {gid} reused"
                        );
                    }
                }
                5 | 6 => {
                    // deletes: live ids, already-deleted ids and ids
                    // never assigned — all legal, only live ones count
                    let live: Vec<u32> = model.keys().copied().collect();
                    let mut ids = Vec::new();
                    for _ in 0..1 + rng.below(3) {
                        if !live.is_empty() && rng.below(4) != 0 {
                            ids.push(live[rng.below(live.len())]);
                        } else {
                            ids.push(9_000_000 + rng.below(100) as u32);
                        }
                    }
                    coll.delete(&ids).unwrap();
                    for gid in ids {
                        model.remove(&gid);
                    }
                }
                7 | 8 => {
                    // upserts: half replace a live id, half mint a
                    // chosen (possibly far-ahead) id
                    let live: Vec<u32> = model.keys().copied().collect();
                    let n = 1 + rng.below(3);
                    let vecs = rand_rows(&mut rng, n);
                    let mut ids = Vec::new();
                    for i in 0..n {
                        let gid = if !live.is_empty() && rng.below(2) == 0 {
                            live[rng.below(live.len())]
                        } else {
                            1_000_000 + (step * 10 + i) as u32
                        };
                        ids.push(gid);
                    }
                    coll.upsert(&ids, &vecs).unwrap();
                    for (r, &gid) in ids.iter().enumerate() {
                        // later duplicates within one call win, exactly
                        // like the map insert here
                        model.insert(gid, vecs.row(r).to_vec());
                    }
                }
                _ => {
                    if rng.below(2) == 0 {
                        coll.commit().unwrap();
                    } else {
                        coll.compact().unwrap();
                    }
                }
            }
            if step % 13 == 12 {
                assert_matches_reference(&coll, &model, &queries, &format!("case {case} step {step}"));
            }
        }

        assert_matches_reference(&coll, &model, &queries, &format!("case {case} post-trace"));
        coll.commit().unwrap();
        assert_matches_reference(&coll, &model, &queries, &format!("case {case} post-commit"));
        coll.compact().unwrap();
        assert_matches_reference(&coll, &model, &queries, &format!("case {case} post-compact"));

        // fresh-process stand-in: reopen from disk. Everything was
        // committed by the compact, so the reopened collection is the
        // same key set.
        drop(coll);
        let reopened = MutableCollection::open(&dir, spec).unwrap();
        assert_matches_reference(&reopened, &model, &queries, &format!("case {case} reopened"));
    }
}

/// Searches racing a compaction must always see a complete consistent
/// key set: the old generation until the O(1) swap, the new one after.
/// With no concurrent mutations both are the same set, so every result
/// must equal the oracle bit-for-bit *throughout* the fold.
#[test]
fn searches_stay_consistent_across_generation_swap() {
    let mut rng = test_rng(77);
    let tmp = TempDir::new("amips-seg-swap");
    let spec = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
    let coll = Arc::new(MutableCollection::create(&tmp.join("c.seg"), spec, D, 77).unwrap());
    let mut model = BTreeMap::new();
    let vecs = rand_rows(&mut rng, 300);
    let ids = coll.insert(&vecs).unwrap();
    for (r, gid) in ids.iter().enumerate() {
        model.insert(*gid, vecs.row(r).to_vec());
    }
    coll.delete(&ids[250..]).unwrap();
    for gid in &ids[250..] {
        model.remove(gid);
    }
    let (flat, gids) = reference(&model).unwrap();
    let query = rand_rows(&mut rng, 1);
    let want = flat.search_effort(query.row(0), 10, Effort::Exhaustive);
    let want_ids: Vec<u32> = want.ids.iter().map(|&l| gids[l as usize]).collect();

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let searcher = {
        let (coll, stop, query) = (coll.clone(), stop.clone(), query.clone());
        let (want_ids, want_scores) = (want_ids.clone(), want.scores.clone());
        std::thread::spawn(move || {
            let mut checked = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Acquire) {
                let got = coll.search_effort(query.row(0), 10, Effort::Exhaustive);
                assert_eq!(got.ids, want_ids, "racing search diverged");
                assert_eq!(got.scores, want_scores, "racing search diverged");
                checked += 1;
            }
            checked
        })
    };
    // several full folds while the searcher hammers away
    for _ in 0..4 {
        coll.compact().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Release);
    let checked = searcher.join().unwrap();
    assert!(checked > 0, "searcher never ran");
    assert_eq!(coll.len(), 250);
}

/// Acceptance: a kill at any point during compaction leaves a layout
/// that reopens to the last *committed* generation. Simulated by
/// snapshotting the directory before a compact and replaying every
/// torn variant: output segment only, segment + torn tmp manifest,
/// segment + truncated committed manifest.
#[test]
fn kill_during_compaction_recovers_last_committed_generation() {
    let mut rng = test_rng(99);
    let tmp = TempDir::new("amips-seg-kill");
    let dir = tmp.join("c.seg");
    let spec = IndexSpec::default_for("flat").unwrap();
    let coll = MutableCollection::create(&dir, spec.clone(), D, 99).unwrap();
    let vecs = rand_rows(&mut rng, 60);
    let ids = coll.insert(&vecs).unwrap();
    coll.delete(&ids[..5]).unwrap();
    let committed = coll.commit().unwrap();
    let query = rand_rows(&mut rng, 1);
    let want = coll.search_effort(query.row(0), 8, Effort::Exhaustive);
    drop(coll);

    // the compaction sequence is: write seg-<n+1>-000.ams, write
    // gen-<n+1>.tsv.tmp, rename to gen-<n+1>.tsv. A kill between any
    // two steps leaves one of these layouts:
    let next = committed + 1;
    let torn_layouts: Vec<Vec<(String, Vec<u8>)>> = vec![
        // after the segment write only
        vec![(format!("seg-{next:06}-000.ams"), b"AMSG\x01torn".to_vec())],
        // after segment + tmp manifest
        vec![
            (format!("seg-{next:06}-000.ams"), b"AMSG\x01torn".to_vec()),
            (format!("gen-{next:06}.tsv.tmp"), b"# amips generation".to_vec()),
        ],
        // a torn rename target (e.g. power loss mid-write on a
        // filesystem without atomic rename durability)
        vec![(
            format!("gen-{next:06}.tsv"),
            b"# amips generation manifest v1\ngen\t".to_vec(),
        )],
    ];
    for (case, files) in torn_layouts.iter().enumerate() {
        for (name, bytes) in files {
            std::fs::write(dir.join(name), bytes).unwrap();
        }
        let reopened = MutableCollection::open(&dir, spec.clone()).unwrap();
        assert_eq!(
            reopened.generation(),
            committed,
            "case {case}: must recover to the committed generation"
        );
        assert_eq!(reopened.len(), 55, "case {case}");
        let got = reopened.search_effort(query.row(0), 8, Effort::Exhaustive);
        assert_eq!(got.ids, want.ids, "case {case}");
        assert_eq!(got.scores, want.scores, "case {case}");
        for (name, _) in files {
            std::fs::remove_file(dir.join(name)).ok();
        }
    }

    // and a *completed* compaction (all three steps) moves forward
    let coll = MutableCollection::open(&dir, spec.clone()).unwrap();
    let done = coll.compact().unwrap();
    assert_eq!(done, committed + 1);
    drop(coll);
    let reopened = MutableCollection::open(&dir, spec).unwrap();
    assert_eq!(reopened.generation(), committed + 1);
    let got = reopened.search_effort(query.row(0), 8, Effort::Exhaustive);
    assert_eq!(got.ids, want.ids);
    assert_eq!(got.scores, want.scores);
}

/// Ids are never reused across delete/compact cycles — the uniqueness
/// guarantee callers key caches on.
#[test]
fn ids_are_never_reused_across_generations() {
    let mut rng = test_rng(3);
    let tmp = TempDir::new("amips-seg-ids");
    let spec = IndexSpec::default_for("flat").unwrap();
    let coll = MutableCollection::create(&tmp.join("c.seg"), spec, D, 3).unwrap();
    let mut seen = std::collections::HashSet::new();
    for _ in 0..6 {
        let ids = coll.insert(&rand_rows(&mut rng, 10)).unwrap();
        for gid in &ids {
            assert!(seen.insert(*gid), "id {gid} reused");
        }
        coll.delete(&ids).unwrap();
        coll.compact().unwrap();
        assert_eq!(coll.len(), 0);
    }
}
