//! Versioned model artifacts (pure Rust — runs on default features):
//! save → load → bit-identical inference for both model kinds, typed
//! error paths for corrupt headers / byte flips / truncations (seeded
//! fuzz sweep scaled by `AMIPS_PROP_CASES`, mirroring
//! `index_artifacts.rs`), and the catalog mapper round trip.

use amips::model::{artifact, AmortizedModel, RustModel};
use amips::nn::{ModelKind, NetSpec};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::{prop_cases, Rng, TempDir};

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    Rng::new(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

fn sample_models() -> Vec<RustModel> {
    let mut out = Vec::new();
    for (i, kind) in [ModelKind::SupportNet, ModelKind::KeyNet].into_iter().enumerate() {
        let mut spec = NetSpec::new(kind, 8, 1, 12, 3);
        spec.residual = i == 0;
        out.push(RustModel::init(format!("fuzz.{kind}"), spec, 31 + i as u64).unwrap());
        let multi = NetSpec::new(kind, 6, 4, 8, 2);
        out.push(RustModel::init(format!("fuzz.{kind}.c4"), multi, 47 + i as u64).unwrap());
    }
    out
}

fn bytes_of(model: &RustModel) -> Vec<u8> {
    let mut buf = Vec::new();
    artifact::write_to(&mut buf, model).unwrap();
    buf
}

#[test]
fn save_load_round_trip_is_bit_identical() {
    for model in sample_models() {
        let buf = bytes_of(&model);
        let back = artifact::load_from(&mut buf.as_slice())
            .unwrap_or_else(|e| panic!("{}: {e:#}", model.label()));
        assert_eq!(back.label(), model.label());
        assert_eq!(back.spec(), model.spec());
        let q = unit(&[5, model.dim()], 7);
        let (s1, k1) = model.scores_and_keys(&q).unwrap();
        let (s2, k2) = back.scores_and_keys(&q).unwrap();
        // bit-identical inference, not approximately-equal
        assert_eq!(s1.data(), s2.data(), "{}", model.label());
        assert_eq!(k1.data(), k2.data(), "{}", model.label());
    }
}

#[test]
fn disk_round_trip_and_typed_open_errors() {
    let tmp = TempDir::new("amips-model-artifacts");
    let models = sample_models();
    let model = &models[0];
    let path = tmp.join("m.amm");
    artifact::save(&path, model).unwrap();
    let back = artifact::load(&path).unwrap();
    assert_eq!(back.label(), model.label());
    // missing file is an error with the path in the message
    let missing = artifact::load(&tmp.join("nope.amm")).unwrap_err();
    assert!(format!("{missing:#}").contains("nope.amm"));
}

#[test]
fn header_corruptions_are_typed_errors() {
    let models = sample_models();
    let buf = bytes_of(&models[0]);
    // bad magic
    let mut bad = buf.clone();
    bad[0] ^= 0xFF;
    assert!(artifact::load_from(&mut bad.as_slice()).is_err());
    // unsupported version
    let mut bad = buf.clone();
    bad[4] = 0xEE;
    assert!(artifact::load_from(&mut bad.as_slice()).is_err());
    // unknown kind tag: corrupt the first byte of the kind string
    let mut bad = buf.clone();
    bad[12] = b'z';
    assert!(artifact::load_from(&mut bad.as_slice()).is_err());
}

#[test]
fn byte_flip_fuzz_never_panics_and_never_lies() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let models = sample_models();
    let mut rng = Rng::new(0xF1A9);
    for case in 0..prop_cases(80) {
        let model = &models[case % models.len()];
        let clean = bytes_of(model);
        let mut bad = clean.clone();
        let pos = rng.below(bad.len());
        let bit = 1u8 << rng.below(8);
        bad[pos] ^= bit;
        let outcome = catch_unwind(AssertUnwindSafe(|| artifact::load_from(&mut bad.as_slice())));
        let loaded = outcome.unwrap_or_else(|_| panic!("case {case}: panic at byte {pos}"));
        if let Ok(back) = loaded {
            // the payload is checksummed and the header fully parsed, so
            // a load that survives a flip (e.g. in the label bytes) must
            // still describe the original architecture and serve
            // inference without panicking
            assert_eq!(back.spec(), model.spec(), "case {case}: flip at {pos}");
            let q = unit(&[2, back.dim()], 70);
            let res = catch_unwind(AssertUnwindSafe(|| back.scores_and_keys(&q)));
            assert!(
                res.unwrap_or_else(|_| panic!("case {case}: inference panicked")).is_ok(),
                "case {case}: inference failed after flip at {pos}"
            );
        }
    }
}

#[test]
fn truncation_fuzz_never_panics() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let models = sample_models();
    let mut rng = Rng::new(0x7C07);
    for case in 0..prop_cases(60) {
        let model = &models[case % models.len()];
        let clean = bytes_of(model);
        let cut = rng.below(clean.len());
        let outcome =
            catch_unwind(AssertUnwindSafe(|| artifact::load_from(&mut &clean[..cut])));
        let loaded = outcome.unwrap_or_else(|_| panic!("case {case}: panic at cut {cut}"));
        assert!(
            loaded.is_err(),
            "case {case}: a truncated artifact (cut {cut}/{}) must not load",
            clean.len()
        );
    }
}

#[test]
fn catalog_collections_carry_a_mapper() {
    use amips::index::{BuildCtx, Catalog, IndexSpec};

    let tmp = TempDir::new("amips-catalog-mapper");
    let root = tmp.join("catalog");
    let keys = unit(&[200, 8], 61);
    let spec = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
    {
        let mut catalog = Catalog::create(&root).unwrap();
        catalog
            .build_collection("docs", &spec, &keys, &BuildCtx::seeded(62))
            .unwrap();
    }
    let model =
        RustModel::init("docs.keynet", NetSpec::new(ModelKind::KeyNet, 8, 1, 10, 2), 63).unwrap();
    let mpath = Catalog::attach_mapper(&root, "docs", &model).unwrap();
    assert!(mpath.exists());

    // reopen: the mapper rides along and maps queries bit-identically
    let entry = Catalog::open_collection(&root, "docs").unwrap();
    let mapper = entry.mapper.as_ref().expect("mapper attached");
    let q = unit(&[3, 8], 64);
    assert_eq!(
        mapper.map_queries(&q).unwrap().data(),
        model.map_queries(&q).unwrap().data()
    );
    // full-open sees it too, and plain collections stay mapper-less
    let catalog = Catalog::open(&root).unwrap();
    assert!(catalog.get("docs").unwrap().mapper.is_some());

    // attaching a wrong-dimension mapper is a typed error
    let wrong =
        RustModel::init("wrong", NetSpec::new(ModelKind::KeyNet, 9, 1, 10, 2), 65).unwrap();
    assert!(Catalog::attach_mapper(&root, "docs", &wrong).is_err());
    // as is attaching to a missing collection
    assert!(Catalog::attach_mapper(&root, "nope", &model).is_err());
    // and a multi-head model
    let multi =
        RustModel::init("multi", NetSpec::new(ModelKind::SupportNet, 8, 3, 10, 2), 66).unwrap();
    assert!(Catalog::attach_mapper(&root, "docs", &multi).is_err());
}
