//! End-to-end tests for wire-v2 pipelining: one connection keeps many
//! id-tagged requests in flight, completions arrive out of order, and
//! every reply is matched back to its request by id.
//!
//! The load-bearing claims:
//!  * a pipelined workload (interleaved Search and Mutate, replies
//!    claimed in shuffled order) is **bit-identical** to the same
//!    workload run sequentially over the one-shot API;
//!  * admission past `max_inflight` and duplicate in-flight ids are
//!    *typed* errors echoing the offending id — the connection
//!    survives;
//!  * a server draining mid-pipeline yields only correct replies or
//!    typed retryable errors, and the retried requests succeed against
//!    a second server.
//!
//! All randomness (claim-order shuffles) derives from
//! `amips::util::test_rng`, so `AMIPS_TEST_SEED` replays a failure.

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use amips::api::{Effort, QueryMode};
use amips::coordinator::net::wire::{self, Frame, SearchFrame};
use amips::coordinator::net::{
    ErrorCode, NetClient, NetError, NetServer, NetServerConfig, SearchOptions, Tenant,
};
use amips::coordinator::BatchPolicy;
use amips::index::ivf::IvfIndex;
use amips::index::{BuildCtx, Catalog, IndexSpec, VectorIndex};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::{test_rng, Rng, TempDir};

const D: usize = 8;

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    Rng::new(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

/// Fisher–Yates with the repo RNG (no std shuffle to stay seedable).
fn shuffled(n: usize, rng: &mut Rng) -> Vec<usize> {
    let mut v: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        v.swap(i, rng.below(i + 1));
    }
    v
}

/// Catalog with an immutable IVF collection ("docs") and an empty
/// mutable one ("scratch").
fn catalog_fixture(tmp: &TempDir) -> Catalog {
    let root = tmp.join("catalog");
    let keys = unit(&[240, D], 11);
    {
        let mut catalog = Catalog::create(&root).unwrap();
        let ivf = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
        catalog
            .build_collection("docs", &ivf, &keys, &BuildCtx::seeded(13))
            .unwrap();
    }
    let spec = IndexSpec::default_for("flat").unwrap();
    Catalog::create_mutable(&root, "scratch", &spec, D, 14).unwrap();
    Catalog::open(&root).unwrap()
}

#[test]
fn pipelined_interleaved_matches_one_shot_bit_for_bit() {
    let tmp = TempDir::new("amips-net-pipeline");
    let catalog = catalog_fixture(&tmp);
    let cfg = NetServerConfig {
        max_inflight: 16,
        ..NetServerConfig::default()
    };
    let server = NetServer::serve_catalog(&catalog, "127.0.0.1:0", cfg).unwrap();
    let addr = server.local_addr().to_string();

    let rounds = 2usize;
    let per_round = 16usize; // == max_inflight: the full window
    let queries = unit(&[rounds * per_round, D], 31);
    let opts = SearchOptions::top_k(5).effort(Effort::Exhaustive);

    // sequential one-shot baseline over its own connection
    let baseline: Vec<_> = {
        let mut one = NetClient::connect(addr.as_str()).unwrap();
        one.set_timeout(Some(Duration::from_secs(20))).unwrap();
        (0..queries.rows())
            .map(|i| one.search("docs", queries.row(i), opts).unwrap())
            .collect()
    };

    let mut client = NetClient::connect(addr.as_str()).unwrap();
    client.set_timeout(Some(Duration::from_secs(20))).unwrap();
    assert_eq!(client.version(), wire::VERSION, "negotiation picked v2");
    let mut rng = test_rng(0x91BE);

    for round in 0..rounds {
        // fill the window, interleaving blocking mutations of the
        // *other* collection between submissions (their Mutated replies
        // arrive tagged and may interleave with search completions; the
        // demux must buffer, not drop)
        let mut ids = Vec::with_capacity(per_round);
        let mut inserted = 0u64;
        for j in 0..per_round {
            let q = round * per_round + j;
            ids.push(client.submit_search("docs", queries.row(q), opts).unwrap());
            if j % 5 == 2 {
                let vecs = unit(&[3, D], 40 + (round * per_round + j) as u64);
                let done = client.insert("scratch", &vecs).unwrap();
                inserted += 3;
                assert_eq!(done.ids.len(), 3, "round {round} insert {j}");
                assert!(done.len >= inserted, "round {round}: len must grow");
            }
        }
        // claim every reply in a shuffled order: out-of-order claims
        // exercise the completion buffer in both directions
        for &j in &shuffled(per_round, &mut rng) {
            let q = round * per_round + j;
            let hits = client.wait_search(ids[j]).unwrap();
            assert_eq!(hits.request_id, ids[j], "echoed id, query {q}");
            assert_eq!(hits.ids, baseline[q].ids, "ids, query {q}");
            assert_eq!(hits.scores, baseline[q].scores, "scores, query {q}");
        }
        assert_eq!(client.outstanding(), 0, "round {round} fully claimed");
    }

    // the same connection still serves one-shot traffic afterwards
    client.ping().unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.errors, 0);
    server.shutdown();
}

/// Slow single tenant: a big exhaustive corpus behind a long batch
/// window, so admitted requests stay in flight long enough to observe
/// cap and duplicate-id behavior deterministically.
fn slow_server(max_inflight: usize, max_wait: Duration) -> (NetServer, String, Arc<IvfIndex>) {
    let keys = unit(&[20_000, 16], 18);
    let index = Arc::new(IvfIndex::build(&keys, 8, 4, 19));
    let tenant = Tenant::start(
        "docs",
        index.clone() as Arc<dyn VectorIndex>,
        None,
        BatchPolicy {
            max_batch: 64,
            max_wait,
        },
        1024,
    )
    .unwrap();
    let mut tenants = BTreeMap::new();
    tenants.insert("docs".to_string(), tenant);
    let cfg = NetServerConfig {
        max_inflight,
        ..NetServerConfig::default()
    };
    let server = NetServer::serve(tenants, "127.0.0.1:0", cfg).unwrap();
    let addr = server.local_addr().to_string();
    (server, addr, index)
}

#[test]
fn admission_past_max_inflight_is_typed_overloaded_echoing_the_id() {
    let (server, addr, _index) = slow_server(2, Duration::from_millis(150));
    let q = unit(&[1, 16], 20);
    let opts = SearchOptions::top_k(3).effort(Effort::Exhaustive);

    let mut client = NetClient::connect(addr.as_str()).unwrap();
    client.set_timeout(Some(Duration::from_secs(20))).unwrap();
    // 6 submits land within the 150ms batch window: 2 admitted, 4 over
    // the cap — each rejection a typed Overloaded echoing its own id
    let ids: Vec<u64> = (0..6)
        .map(|_| client.submit_search("docs", q.row(0), opts).unwrap())
        .collect();
    let (mut ok, mut overloaded) = (0usize, 0usize);
    for _ in 0..ids.len() {
        let reply = client.recv_any().unwrap();
        assert!(ids.contains(&reply.request_id), "unknown id echoed");
        match reply.reply {
            Ok(hits) => {
                assert_eq!(hits.request_id, reply.request_id);
                ok += 1;
            }
            Err(e) => {
                assert_eq!(e.code, ErrorCode::Overloaded, "only typed overload");
                assert_eq!(e.request_id, reply.request_id);
                overloaded += 1;
            }
        }
    }
    assert_eq!(ok, 2, "exactly max_inflight admitted");
    assert_eq!(overloaded, 4, "the rest typed-rejected");
    // the connection survived the rejections
    client.ping().unwrap();
    assert!(client.search("docs", q.row(0), opts).is_ok());
    server.shutdown();
}

#[test]
fn duplicate_inflight_id_is_typed_bad_request() {
    let (server, addr, _index) = slow_server(8, Duration::from_millis(150));
    let q = unit(&[1, 16], 21);

    // hand-rolled frames: NetClient never reuses ids, so speak wire
    // directly to force the duplicate
    let mut stream = TcpStream::connect(addr.as_str()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    let frame = Frame::Search(SearchFrame {
        request_id: 7,
        collection: "docs".to_string(),
        k: 3,
        effort: Effort::Exhaustive,
        mode: QueryMode::Original,
        deadline_micros: 0,
        query: q.row(0).to_vec(),
    });
    wire::write_frame_versioned(&mut stream, &frame, wire::VERSION).unwrap();
    wire::write_frame_versioned(&mut stream, &frame, wire::VERSION).unwrap();

    // the duplicate is rejected immediately (typed, echoing id 7) while
    // the original is still in its batch window; the original then
    // completes normally
    let (mut got_hits, mut got_dup) = (false, false);
    for _ in 0..2 {
        match wire::read_frame(&mut stream).unwrap() {
            Frame::Error(e) => {
                assert_eq!(e.code, ErrorCode::BadRequest);
                assert_eq!(e.request_id, 7, "rejection echoes the duplicate id");
                got_dup = true;
            }
            Frame::Hits(h) => {
                assert_eq!(h.request_id, 7);
                got_hits = true;
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert!(got_hits && got_dup);
    // the connection survived: the id is free again after completion
    wire::write_frame_versioned(&mut stream, &frame, wire::VERSION).unwrap();
    match wire::read_frame(&mut stream).unwrap() {
        Frame::Hits(h) => assert_eq!(h.request_id, 7),
        other => panic!("id 7 should be reusable after completion, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn draining_mid_pipeline_is_retryable_and_retries_succeed() {
    // two servers over the same index: A drains mid-pipeline, failed
    // requests retry against B; every query must end up served with
    // results bit-identical to a direct index search
    let (server_a, addr_a, index) = slow_server(16, Duration::from_millis(1));
    let keys_dim = 16usize;
    let tenant_b = Tenant::start(
        "docs",
        index.clone() as Arc<dyn VectorIndex>,
        None,
        BatchPolicy::default(),
        1024,
    )
    .unwrap();
    let mut tenants = BTreeMap::new();
    tenants.insert("docs".to_string(), tenant_b);
    let server_b = NetServer::serve(tenants, "127.0.0.1:0", NetServerConfig::default()).unwrap();
    let addr_b = server_b.local_addr().to_string();

    let total = 3000usize;
    let window = 8usize;
    let queries = unit(&[64, keys_dim], 22);
    let opts = SearchOptions::top_k(3).effort(Effort::Probes(2));

    let mut client = NetClient::connect(addr_a.as_str()).unwrap();
    client.set_timeout(Some(Duration::from_secs(20))).unwrap();

    let stop = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(25));
        server_a.shutdown();
    });

    let mut results: Vec<Option<amips::coordinator::net::HitsFrame>> = vec![None; total];
    let mut inflight: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
    let mut next = 0usize;
    let mut failed: Vec<usize> = Vec::new();
    'outer: while next < total || !inflight.is_empty() {
        while next < total && inflight.len() < window {
            match client.submit_search("docs", queries.row(next % queries.rows()), opts) {
                Ok(id) => {
                    inflight.insert(id, next);
                    next += 1;
                }
                Err(e) => {
                    assert!(
                        e.is_retryable() || matches!(e, NetError::Wire(_)),
                        "mid-drain submit failed non-retryably: {e}"
                    );
                    break 'outer;
                }
            }
        }
        match client.recv_any() {
            Ok(reply) => {
                let slot = inflight.remove(&reply.request_id).expect("known id");
                match reply.reply {
                    Ok(hits) => results[slot] = Some(hits),
                    Err(e) => {
                        assert_eq!(
                            e.code,
                            ErrorCode::ShuttingDown,
                            "mid-drain per-request errors must be the typed drain"
                        );
                        failed.push(slot);
                    }
                }
            }
            Err(e) => {
                assert!(
                    e.is_retryable() || matches!(e, NetError::Wire(_)),
                    "mid-drain failure must be retryable or a clean close: {e}"
                );
                break;
            }
        }
    }
    stop.join().unwrap();

    // everything not served by A retries on B (pipelined there too)
    failed.extend(inflight.into_values());
    failed.extend(next..total);
    let served_by_a = total - failed.len();
    let mut retry = NetClient::connect(addr_b.as_str()).unwrap();
    retry.set_timeout(Some(Duration::from_secs(20))).unwrap();
    let retry_queries: Vec<&[f32]> = failed
        .iter()
        .map(|&slot| queries.row(slot % queries.rows()))
        .collect();
    let retried = retry
        .search_many("docs", &retry_queries, opts, window)
        .unwrap();
    for (k, r) in retried.into_iter().enumerate() {
        results[failed[k]] = Some(r.expect("retry against a healthy server succeeds"));
    }

    // all served, bit-identical to the direct index search
    for (slot, hits) in results.iter().enumerate() {
        let hits = hits.as_ref().expect("every slot served by A or B");
        let direct = index.search_effort(queries.row(slot % queries.rows()), 3, Effort::Probes(2));
        assert_eq!(hits.ids, direct.ids, "slot {slot}");
        assert_eq!(hits.scores, direct.scores, "slot {slot}");
    }
    assert!(
        served_by_a > 0,
        "shutdown raced ahead of the whole workload; nothing exercised the drain"
    );
    server_b.shutdown();
}

#[test]
fn v1_client_still_works_against_a_v2_server() {
    let tmp = TempDir::new("amips-net-v1-compat");
    let catalog = catalog_fixture(&tmp);
    let server =
        NetServer::serve_catalog(&catalog, "127.0.0.1:0", NetServerConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let queries = unit(&[4, D], 33);
    let opts = SearchOptions::top_k(5).effort(Effort::Exhaustive);

    let mut v2 = NetClient::connect(addr.as_str()).unwrap();
    v2.set_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut v1 = NetClient::connect_v1(addr.as_str()).unwrap();
    v1.set_timeout(Some(Duration::from_secs(20))).unwrap();
    assert_eq!(v1.version(), wire::V1);

    for i in 0..queries.rows() {
        let a = v1.search("docs", queries.row(i), opts).unwrap();
        let b = v2.search("docs", queries.row(i), opts).unwrap();
        assert_eq!(a.request_id, 0, "v1 replies carry no id");
        assert_eq!(a.ids, b.ids, "query {i}");
        assert_eq!(a.scores, b.scores, "query {i}");
    }
    // pipelined mode is a typed local error on a v1 connection, not a
    // protocol desync
    assert!(matches!(
        v1.submit_search("docs", queries.row(0), opts),
        Err(NetError::Unexpected(_))
    ));
    v1.ping().unwrap();
    server.shutdown();
}
