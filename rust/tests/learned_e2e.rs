//! End-to-end learned-model acceptance (pure Rust — runs on default
//! features): short trainings on the synthetic testbed must give both
//! of the paper's models a nonzero top-1 match rate — SupportNet
//! recovering keys via its input gradient, KeyNet via direct
//! regression — and the trained KeyNet must serve mapped queries
//! through the catalog + server deployment path.

use amips::api::{Effort, KeyNetQueryMap, MappedSearcher, QueryMode, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::coordinator::{BatchPolicy, Server, ServerConfig};
use amips::data::Dataset;
use amips::model::AmortizedModel;
use amips::nn::{ModelKind, NetSpec};
use amips::trainer::{self, rust::train, TrainOpts};
use amips::util::TempDir;

const N_KEYS: usize = 240;
const D: usize = 8;
const VAL_Q: usize = 80;

fn testbed(c: usize) -> Dataset {
    fixtures::synth_dataset(N_KEYS, D, VAL_Q, c, 1234)
}

fn quick_opts(steps: usize) -> TrainOpts {
    TrainOpts {
        steps,
        batch: 48,
        eval_every: 0,
        log_every: steps / 4,
        ..TrainOpts::default()
    }
}

#[test]
fn keynet_regression_reaches_nonzero_match_rate() {
    let ds = testbed(1);
    let spec = NetSpec::new(ModelKind::KeyNet, D, 1, 24, 2);
    let out = train(&spec, "e2e.keynet", &ds, &quick_opts(350)).unwrap();
    let (rm, e_rel) = trainer::validation_retrieval(&out.model, &ds).unwrap();
    assert!(
        rm.match_rate > 0.0,
        "KeyNet top-1 match rate is zero after training (E_rel {e_rel})"
    );
    // the trained predictor must beat the identity transport (E_rel < 0)
    assert!(e_rel < -0.1, "KeyNet E_rel {e_rel} not better than identity");
    // and the training loss must actually have decreased
    let c = &out.curve;
    assert!(c.final_loss().unwrap() < c.train.first().unwrap().loss);
}

#[test]
fn supportnet_input_gradient_reaches_nonzero_match_rate() {
    let ds = testbed(1);
    let spec = NetSpec::new(ModelKind::SupportNet, D, 1, 24, 2);
    assert!(spec.homogenize, "supportnet defaults to the wrapper");
    let out = train(&spec, "e2e.supportnet", &ds, &quick_opts(450)).unwrap();
    let (rm, e_rel) = trainer::validation_retrieval(&out.model, &ds).unwrap();
    assert!(
        rm.match_rate > 0.0,
        "SupportNet key recovery match rate is zero after training (E_rel {e_rel})"
    );
    assert!(e_rel < 0.0, "SupportNet E_rel {e_rel} not better than identity");
}

#[test]
fn trained_keynet_serves_mapped_queries_from_a_catalog() {
    use amips::index::{BuildCtx, Catalog, IndexSpec, VectorIndex};
    use std::time::Duration;

    let ds = testbed(1);
    let spec = NetSpec::new(ModelKind::KeyNet, D, 1, 16, 2);
    let out = train(&spec, "e2e.serve.keynet", &ds, &quick_opts(150)).unwrap();

    // build the index over the SAME keys, attach the trained mapper
    let tmp = TempDir::new("amips-learned-e2e");
    let root = tmp.join("catalog");
    let ispec = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
    {
        let mut catalog = Catalog::create(&root).unwrap();
        catalog
            .build_collection("docs", &ispec, &ds.keys, &BuildCtx::seeded(9))
            .unwrap();
    }
    Catalog::attach_mapper(&root, "docs", &out.model).unwrap();

    let entry = Catalog::open_collection(&root, "docs").unwrap();
    let mapper = entry.mapper.as_ref().expect("mapper round-trips").clone();
    let model = (*mapper).clone();
    let expect_mapped = model.map_queries(&ds.val.x).unwrap();

    // serve mapped queries through the server, as `amips serve --catalog`
    let req = SearchRequest::top_k(5)
        .effort(Effort::Exhaustive)
        .mode(QueryMode::Mapped);
    let cfg = ServerConfig::with_keynet(
        model,
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        },
        req,
    );
    let (server, handle) = Server::start(cfg, entry.index.clone()).unwrap();
    for i in 0..4 {
        let resp = handle.search(ds.val.x.row(i).to_vec()).unwrap();
        let direct = entry
            .index
            .search_effort(expect_mapped.row(i), 5, Effort::Exhaustive);
        assert_eq!(resp.hits.ids, direct.ids, "query {i}");
        assert!(resp.cost.map_flops > 0);
    }
    drop(handle);
    server.shutdown().unwrap();
}

#[test]
fn keynet_query_map_conforms_to_mapped_searcher_contract() {
    use amips::index::{flat::FlatIndex, ivf::IvfIndex};

    let ds = testbed(1);
    let spec = NetSpec::new(ModelKind::KeyNet, D, 1, 16, 2);
    let out = train(&spec, "e2e.map.keynet", &ds, &quick_opts(120)).unwrap();
    let key_flops = out.model.key_flops();
    let pre_mapped = out.model.map_queries(&ds.val.x).unwrap();
    let map = KeyNetQueryMap::new(out.model).unwrap();

    let flat = FlatIndex::new(ds.keys.clone());
    let ivf = IvfIndex::build(&ds.keys, 4, 10, 3);
    let nq = ds.val.x.rows();
    for (label, index) in [
        ("flat", &flat as &dyn amips::index::VectorIndex),
        ("ivf", &ivf as &dyn amips::index::VectorIndex),
    ] {
        let searcher = MappedSearcher::mapped(index, &map);
        let req = SearchRequest::top_k(5).effort(Effort::Exhaustive);

        // Original mode is a pure passthrough with zero map cost
        let orig = searcher.search(&ds.val.x, &req).unwrap();
        let direct = index.search(&ds.val.x, &req).unwrap();
        for q in 0..nq {
            assert_eq!(orig.hits[q], direct.hits[q], "{label} q{q}");
        }
        assert_eq!(orig.cost.map_flops, 0, "{label}");

        // Mapped mode equals searching the pre-mapped batch directly,
        // and charges the model's per-query key flops
        let mapped = searcher
            .search(&ds.val.x, &req.mode(QueryMode::Mapped))
            .unwrap();
        let via_premap = index.search(&pre_mapped, &req).unwrap();
        for q in 0..nq {
            assert_eq!(mapped.hits[q].ids, via_premap.hits[q].ids, "{label} q{q}");
            assert_eq!(
                mapped.hits[q].scores, via_premap.hits[q].scores,
                "{label} q{q}"
            );
        }
        assert_eq!(mapped.cost.map_flops, key_flops * nq as u64, "{label}");
        assert!(mapped.cost.map_seconds >= 0.0);
    }
}
