//! Gradient checks for the pure-Rust nn layer (runs on default
//! features): manual backprop vs central finite differences — including
//! the double-backprop path through the SupportNet gradient-matching
//! loss — plus the homogenization wrapper's analytic invariants
//! (`f(αx) = α·f(x)` for α>0 and Euler's identity `⟨∇f(x), x⟩ = f(x)`).
//!
//! Sweeps are seeded and scaled by `AMIPS_PROP_CASES` (same contract as
//! `properties.rs`): cases are drawn from one deterministic stream, so
//! a failing case number reproduces exactly.

use amips::nn::{Lambdas, ModelKind, NetSpec, Network};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::{prop_cases, Rng};

fn unit(shape: &[usize], rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

/// Random tiny architecture for one sweep case.
fn random_spec(kind: ModelKind, rng: &mut Rng) -> NetSpec {
    let d = 2 + rng.below(3); // 2..=4
    let c = 1 + rng.below(2); // 1..=2
    let h = 3 + rng.below(4); // 3..=6
    let layers = 1 + rng.below(3); // 1..=3
    let mut spec = NetSpec::new(kind, d, c, h, layers);
    spec.nx = rng.below(layers + 1);
    spec.residual = rng.below(2) == 1;
    if kind == ModelKind::SupportNet {
        // exercise both the homogenized and the raw trunk
        spec.homogenize = rng.below(2) == 1;
    }
    spec
}

fn random_batch(spec: &NetSpec, rng: &mut Rng) -> (Tensor, Tensor, Tensor) {
    let b = 2 + rng.below(3); // 2..=4
    let (c, d) = (spec.c, spec.d);
    let x = unit(&[b, d], rng);
    let y = unit(&[b * c, d], rng).reshape(&[b, c, d]);
    let mut s = Tensor::zeros(&[b, c]);
    rng.fill_normal(s.data_mut(), 0.5);
    (x, y, s)
}

const LAM: Lambdas = Lambdas {
    lam_a: 0.3,
    lam_b: 1.0,
    lam_icnn: 0.05,
};

fn loss_of(net: &Network, x: &Tensor, y: &Tensor, s: &Tensor) -> f64 {
    net.loss_and_grads(x, y, s, &LAM).unwrap().0.total as f64
}

/// Directional derivative check: FD along a random unit direction over
/// *all* parameters vs `⟨grad, dir⟩`. Far more robust in f32 than
/// per-element FD, and it covers every parameter at once.
fn directional_check(kind: ModelKind, case: usize, rng: &mut Rng) {
    let spec = random_spec(kind, rng);
    let net = Network::init(spec.clone(), rng.next_u64()).unwrap();
    let (x, y, s) = random_batch(&spec, rng);
    let (_, grads) = net.loss_and_grads(&x, &y, &s, &LAM).unwrap();

    // random direction, normalized over the whole parameter vector
    let mut dir: Vec<Tensor> = grads
        .iter()
        .map(|g| {
            let mut t = Tensor::zeros(g.shape());
            rng.fill_normal(t.data_mut(), 1.0);
            t
        })
        .collect();
    let norm: f32 = dir
        .iter()
        .flat_map(|t| t.data())
        .map(|v| v * v)
        .sum::<f32>()
        .sqrt()
        .max(1e-12);
    for t in &mut dir {
        for v in t.data_mut() {
            *v /= norm;
        }
    }
    let analytic: f64 = grads
        .iter()
        .zip(&dir)
        .map(|(g, v)| {
            g.data()
                .iter()
                .zip(v.data())
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum::<f64>()
        })
        .sum();

    let eps = 1e-2f32;
    let shift = |sign: f32| -> Network {
        let params: Vec<Tensor> = net
            .params()
            .iter()
            .zip(&dir)
            .map(|(p, v)| {
                let mut t = p.clone();
                for (pe, &ve) in t.data_mut().iter_mut().zip(v.data()) {
                    *pe += sign * eps * ve;
                }
                t
            })
            .collect();
        Network::new(spec.clone(), params).unwrap()
    };
    let fd = (loss_of(&shift(1.0), &x, &y, &s) - loss_of(&shift(-1.0), &x, &y, &s))
        / (2.0 * eps as f64);
    let tol = 1e-3 + 3e-2 * fd.abs().max(analytic.abs());
    assert!(
        (fd - analytic).abs() < tol,
        "case {case} {kind:?} {spec:?}: directional fd {fd} vs backprop {analytic}"
    );
}

#[test]
fn keynet_backprop_matches_finite_differences() {
    let mut rng = Rng::new(0xC0FE);
    for case in 0..prop_cases(30) {
        directional_check(ModelKind::KeyNet, case, &mut rng);
    }
}

#[test]
fn supportnet_backprop_matches_finite_differences() {
    // this is the double-backprop path: the loss contains the
    // hand-derived input gradient, so dLoss/dθ uses σ''
    let mut rng = Rng::new(0x5EED);
    for case in 0..prop_cases(30) {
        directional_check(ModelKind::SupportNet, case, &mut rng);
    }
}

#[test]
fn per_element_gradients_match_on_a_fixed_tiny_net() {
    for kind in [ModelKind::SupportNet, ModelKind::KeyNet] {
        let spec = NetSpec::new(kind, 3, 1, 4, 2);
        let net = Network::init(spec.clone(), 11).unwrap();
        let mut rng = Rng::new(12);
        let (x, y, s) = random_batch(&spec, &mut rng);
        let (_, grads) = net.loss_and_grads(&x, &y, &s, &LAM).unwrap();
        let eps = 1e-2f32;
        for (ti, g) in grads.iter().enumerate() {
            for e in 0..g.len() {
                let probe = |sign: f32| -> f64 {
                    let mut params = net.params().to_vec();
                    params[ti].data_mut()[e] += sign * eps;
                    loss_of(&Network::new(spec.clone(), params).unwrap(), &x, &y, &s)
                };
                let fd = (probe(1.0) - probe(-1.0)) / (2.0 * eps as f64);
                let an = g.data()[e] as f64;
                assert!(
                    (fd - an).abs() < 2e-3 + 5e-2 * fd.abs().max(an.abs()),
                    "{kind:?} tensor {ti} elem {e}: fd {fd} vs {an}"
                );
            }
        }
    }
}

#[test]
fn homogenized_scores_scale_linearly() {
    let mut rng = Rng::new(21);
    for case in 0..prop_cases(40) {
        let mut spec = random_spec(ModelKind::SupportNet, &mut rng);
        spec.homogenize = true;
        let net = Network::init(spec.clone(), rng.next_u64()).unwrap();
        let x = unit(&[3, spec.d], &mut rng);
        let alpha = 0.25 + rng.uniform() as f32 * 4.0;
        let mut ax = x.clone();
        for v in ax.data_mut() {
            *v *= alpha;
        }
        let s1 = net.scores(&x).unwrap();
        let s2 = net.scores(&ax).unwrap();
        for (a, b) in s1.data().iter().zip(s2.data()) {
            assert!(
                (b - alpha * a).abs() < 1e-4 * (1.0 + a.abs() * alpha),
                "case {case}: f(αx)={b} vs α·f(x)={}",
                alpha * a
            );
        }
    }
}

#[test]
fn euler_identity_links_values_and_gradients() {
    let mut rng = Rng::new(22);
    for case in 0..prop_cases(40) {
        let mut spec = random_spec(ModelKind::SupportNet, &mut rng);
        spec.homogenize = true;
        let net = Network::init(spec.clone(), rng.next_u64()).unwrap();
        let x = unit(&[3, spec.d], &mut rng);
        let (scores, keys) = net.scores_and_keys(&x).unwrap();
        for b in 0..3 {
            for j in 0..spec.c {
                let off = (b * spec.c + j) * spec.d;
                let dotv: f32 = keys.data()[off..off + spec.d]
                    .iter()
                    .zip(x.row(b))
                    .map(|(k, q)| k * q)
                    .sum();
                let f = scores.row(b)[j];
                assert!(
                    (dotv - f).abs() < 1e-4 * (1.0 + f.abs()),
                    "case {case}: Euler ⟨∇f,x⟩={dotv} vs f={f}"
                );
            }
        }
    }
}

#[test]
fn supportnet_keys_are_the_input_gradient() {
    // the served key must equal the finite-difference gradient of the
    // served score w.r.t. the query — the paper's Sec. 3.1 claim
    let mut rng = Rng::new(23);
    for case in 0..prop_cases(15) {
        let spec = random_spec(ModelKind::SupportNet, &mut rng);
        let net = Network::init(spec.clone(), rng.next_u64()).unwrap();
        let x = unit(&[2, spec.d], &mut rng);
        let (_, keys) = net.scores_and_keys(&x).unwrap();
        let eps = 1e-2f32;
        for b in 0..2 {
            for j in 0..spec.c {
                for e in 0..spec.d {
                    let probe = |sign: f32| -> f32 {
                        let mut xp = x.clone();
                        xp.row_mut(b)[e] += sign * eps;
                        net.scores(&xp).unwrap().row(b)[j]
                    };
                    let fd = (probe(1.0) - probe(-1.0)) / (2.0 * eps);
                    let an = keys.data()[(b * spec.c + j) * spec.d + e];
                    assert!(
                        (fd - an).abs() < 2e-3 + 5e-2 * fd.abs().max(an.abs()),
                        "case {case} q{b} head {j} dim {e}: fd {fd} vs key {an} ({spec:?})"
                    );
                }
            }
        }
    }
}
