//! End-to-end tests for the TCP serving subsystem: a real catalog on
//! disk (one collection with an attached query mapper), a real
//! `NetServer` on an ephemeral port, real `NetClient` connections.
//!
//! The load-bearing claim is bit-identity: a search answered over the
//! wire must equal the same search run directly against the collection
//! index — the network layer may batch and reorder, but never change
//! results. On top of that: typed errors for every client-caused
//! failure (unknown collection, expired deadline, full queue, garbage
//! bytes) and a graceful shutdown that leaves no socket hanging.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use amips::api::{Effort, QueryMode};
use amips::coordinator::net::{
    ErrorCode, Frame, NetClient, NetError, NetServer, NetServerConfig, SearchOptions, Tenant,
};
use amips::coordinator::BatchPolicy;
use amips::index::ivf::IvfIndex;
use amips::index::{BuildCtx, Catalog, IndexSpec, VectorIndex};
use amips::model::{AmortizedModel, RustModel};
use amips::nn::{ModelKind, NetSpec};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::{Rng, TempDir};

const D: usize = 8;

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    Rng::new(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

/// Build a two-collection catalog ("docs" = ivf + keynet mapper,
/// "code" = flat) and reopen it from disk.
fn catalog_fixture(tmp: &TempDir) -> (Catalog, RustModel) {
    let root = tmp.join("catalog");
    let docs_keys = unit(&[240, D], 11);
    let code_keys = unit(&[160, D], 12);
    {
        let mut catalog = Catalog::create(&root).unwrap();
        let ivf = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
        catalog
            .build_collection("docs", &ivf, &docs_keys, &BuildCtx::seeded(13))
            .unwrap();
        let flat = IndexSpec::default_for("flat").unwrap();
        catalog
            .build_collection("code", &flat, &code_keys, &BuildCtx::seeded(14))
            .unwrap();
    }
    let mapper = RustModel::init(
        "net.keynet",
        NetSpec::new(ModelKind::KeyNet, D, 1, 8, 2),
        15,
    )
    .unwrap();
    Catalog::attach_mapper(&root, "docs", &mapper).unwrap();
    (Catalog::open(&root).unwrap(), mapper)
}

#[test]
fn concurrent_clients_match_direct_search_bit_for_bit() {
    let tmp = TempDir::new("amips-net-e2e");
    let (catalog, mapper) = catalog_fixture(&tmp);
    let server =
        NetServer::serve_catalog(&catalog, "127.0.0.1:0", NetServerConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    let queries = unit(&[12, D], 16);
    let mapped_expect = mapper.map_queries(&queries).unwrap();
    let docs = catalog.get("docs").unwrap().index.clone();
    let code = catalog.get("code").unwrap().index.clone();

    std::thread::scope(|s| {
        for c in 0..4usize {
            let (addr, queries, mapped_expect, docs, code) =
                (&addr, &queries, &mapped_expect, &docs, &code);
            s.spawn(move || {
                let mut client = NetClient::connect(addr.as_str()).unwrap();
                client.set_timeout(Some(Duration::from_secs(20))).unwrap();
                for i in (c..queries.rows()).step_by(4) {
                    let q = queries.row(i);
                    // original mode against both collections
                    for (name, index) in [("docs", docs), ("code", code)] {
                        let hits = client
                            .search(name, q, SearchOptions::top_k(5).effort(Effort::Exhaustive))
                            .unwrap();
                        let direct = index.search_effort(q, 5, Effort::Exhaustive);
                        assert_eq!(hits.ids, direct.ids, "{name} ids, query {i}");
                        assert_eq!(hits.scores, direct.scores, "{name} scores, query {i}");
                        assert!(hits.keys_scanned > 0);
                    }
                    // mapped mode on the mapper-carrying collection:
                    // identical to searching the index at the
                    // model-mapped point
                    let hits = client
                        .search(
                            "docs",
                            q,
                            SearchOptions::top_k(5)
                                .effort(Effort::Exhaustive)
                                .mode(QueryMode::Mapped),
                        )
                        .unwrap();
                    let direct = docs.search_effort(mapped_expect.row(i), 5, Effort::Exhaustive);
                    assert_eq!(hits.ids, direct.ids, "mapped ids, query {i}");
                    assert_eq!(hits.scores, direct.scores, "mapped scores, query {i}");
                    assert!(hits.map_flops > 0, "mapped search must report map cost");
                }
            });
        }
    });

    // server-side stats saw the traffic on both collections
    let stats = server.stats();
    assert!(stats.served >= 36, "served {} of >= 36", stats.served);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.collections.len(), 2);
    assert!(stats.p50_s > 0.0);
    server.shutdown();
}

#[test]
fn typed_errors_unknown_collection_deadline_and_garbage() {
    let tmp = TempDir::new("amips-net-errors");
    let (catalog, _mapper) = catalog_fixture(&tmp);
    // default policy: max_wait 2ms >> the 1us deadline below
    let server =
        NetServer::serve_catalog(&catalog, "127.0.0.1:0", NetServerConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let q = unit(&[1, D], 17);

    let mut client = NetClient::connect(addr.as_str()).unwrap();
    client.set_timeout(Some(Duration::from_secs(10))).unwrap();

    // unknown collection: typed, names what is available
    let err = client
        .search("nope", q.row(0), SearchOptions::top_k(3))
        .unwrap_err();
    let e = err.server_error().expect("typed server error");
    assert_eq!(e.code, ErrorCode::UnknownCollection);
    assert!(e.message.contains("docs") && e.message.contains("code"));

    // an already-expired deadline fast-fails with a typed error (the
    // batcher's max_wait alone guarantees >1us of queueing)
    let err = client
        .search(
            "docs",
            q.row(0),
            SearchOptions::top_k(3).deadline(Duration::from_micros(1)),
        )
        .unwrap_err();
    let e = err.server_error().expect("typed server error");
    assert_eq!(e.code, ErrorCode::DeadlineExpired);

    // wrong query dimension: typed BadRequest
    let err = client
        .search("docs", &[0.0; 3], SearchOptions::top_k(3))
        .unwrap_err();
    assert_eq!(err.server_error().unwrap().code, ErrorCode::BadRequest);

    // hostile k (would be a ~34 GB TopK allocation if admitted): typed
    // BadRequest at admission, nothing allocated, connection survives
    for k in [0usize, (1 << 20) + 1, u32::MAX as usize] {
        let err = client
            .search("docs", q.row(0), SearchOptions { k, ..SearchOptions::top_k(1) })
            .unwrap_err();
        assert_eq!(err.server_error().unwrap().code, ErrorCode::BadRequest, "k={k}");
    }

    // the connection survived all typed errors
    client.ping().unwrap();

    // garbage magic bytes: typed reply, then the server closes that
    // connection — and keeps serving others
    let mut garbage = NetClient::connect(addr.as_str()).unwrap();
    garbage.set_timeout(Some(Duration::from_secs(10))).unwrap();
    match garbage.send_raw(b"NOPE\x01\x04\x00\x00\x00\x00").unwrap() {
        Frame::Error(e) => assert_eq!(e.code, ErrorCode::BadRequest),
        other => panic!("wanted a typed error, got {other:?}"),
    }

    // oversized declared length: typed reply before any allocation
    let mut oversized = NetClient::connect(addr.as_str()).unwrap();
    oversized.set_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"AMTP\x01\x01");
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    match oversized.send_raw(&bytes).unwrap() {
        Frame::Error(e) => assert_eq!(e.code, ErrorCode::BadRequest),
        other => panic!("wanted a typed error, got {other:?}"),
    }

    client.ping().unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.errors >= 1, "the dim error is counted per tenant");
    assert!(stats.expired >= 1, "the deadline failure counts as expired");
    server.shutdown();
}

#[test]
fn full_queue_answers_overloaded_while_admitted_work_succeeds() {
    // tiny admission queue + serial worker + a corpus big enough that
    // each exhaustive scan takes real time: concurrent clients must see
    // both outcomes — admitted requests served, excess typed Overloaded
    let keys = unit(&[30_000, 16], 18);
    let index = Arc::new(IvfIndex::build(&keys, 8, 4, 19));
    let tenant = Tenant::start(
        "docs",
        index as Arc<dyn VectorIndex>,
        None,
        BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
        },
        1,
    )
    .unwrap();
    let mut tenants = BTreeMap::new();
    tenants.insert("docs".to_string(), tenant);
    let server = NetServer::serve(tenants, "127.0.0.1:0", NetServerConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let queries = unit(&[8, 16], 20);

    let (ok, overloaded, other): (usize, usize, usize) = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for c in 0..8usize {
            let (addr, queries) = (&addr, &queries);
            joins.push(s.spawn(move || {
                let mut client = NetClient::connect(addr.as_str()).unwrap();
                client.set_timeout(Some(Duration::from_secs(30))).unwrap();
                let (mut ok, mut over, mut other) = (0usize, 0usize, 0usize);
                for _ in 0..20 {
                    match client.search(
                        "docs",
                        queries.row(c),
                        SearchOptions::top_k(3).effort(Effort::Exhaustive),
                    ) {
                        Ok(_) => ok += 1,
                        Err(NetError::Server(e)) if e.code == ErrorCode::Overloaded => over += 1,
                        Err(_) => other += 1,
                    }
                }
                (ok, over, other)
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).fold(
            (0, 0, 0),
            |(a, b, c), (x, y, z)| (a + x, b + y, c + z),
        )
    });

    assert_eq!(other, 0, "only success or typed Overloaded are allowed");
    assert!(ok >= 1, "admitted requests must still be served");
    assert!(
        overloaded >= 1,
        "a cap-1 queue under 8 hammering clients must shed load ({ok} ok)"
    );
    let stats = server.stats();
    assert_eq!(stats.served as usize, ok);
    assert_eq!(stats.overloaded as usize, overloaded);
    server.shutdown();
}

#[test]
fn shutdown_completes_under_ping_spam() {
    // a client pinging faster than the idle timeout must not pin its
    // connection thread: every frame type checks the drain flag, so
    // shutdown() returns promptly instead of spinning on
    // live_connections forever
    let tmp = TempDir::new("amips-net-ping-spam");
    let (catalog, _mapper) = catalog_fixture(&tmp);
    let server =
        NetServer::serve_catalog(&catalog, "127.0.0.1:0", NetServerConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut client = NetClient::connect(addr.as_str()).unwrap();
            client.set_timeout(Some(Duration::from_secs(10))).unwrap();
            client.ping().unwrap();
            ready_tx.send(()).unwrap();
            // hammer pings until the server starts draining
            loop {
                match client.ping() {
                    Ok(()) => {}
                    Err(NetError::Draining(e)) => {
                        assert_eq!(e.code, ErrorCode::ShuttingDown);
                        break;
                    }
                    Err(_) => break, // closed under us: also clean
                }
            }
        });
        ready_rx.recv().unwrap();
        let start = std::time::Instant::now();
        server.shutdown();
        assert!(
            start.elapsed() < Duration::from_secs(8),
            "shutdown stalled {}s against a ping-spamming client",
            start.elapsed().as_secs()
        );
    });
}

#[test]
fn graceful_shutdown_drains_and_closes_cleanly() {
    let tmp = TempDir::new("amips-net-shutdown");
    let (catalog, _mapper) = catalog_fixture(&tmp);
    let server =
        NetServer::serve_catalog(&catalog, "127.0.0.1:0", NetServerConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    // traffic before shutdown so there is state to drain
    let q = unit(&[2, D], 21);
    let mut client = NetClient::connect(addr.as_str()).unwrap();
    client.set_timeout(Some(Duration::from_secs(10))).unwrap();
    client
        .search("docs", q.row(0), SearchOptions::top_k(3))
        .unwrap();

    // an idle connection gets a typed ShuttingDown notice (or a clean
    // close) instead of hanging; shutdown() itself must not deadlock on
    // the open socket
    let mut idle = NetClient::connect(addr.as_str()).unwrap();
    idle.set_timeout(Some(Duration::from_secs(10))).unwrap();
    server.shutdown();

    match idle.ping() {
        Ok(()) => panic!("draining server must not answer new pings"),
        Err(NetError::Draining(e)) => {
            assert_eq!(e.code, ErrorCode::ShuttingDown);
        }
        Err(e) => assert!(
            !matches!(e, NetError::Server(_)),
            "drain reply must be the typed retryable variant, got {e}"
        ),
    }

    // the port is released: fresh connections fail, or at best get a
    // typed refusal before close
    match NetClient::connect(addr.as_str()) {
        Err(_) => {}
        Ok(mut late) => {
            late.set_timeout(Some(Duration::from_secs(5))).unwrap();
            assert!(late.ping().is_err(), "a shut-down server must not serve");
        }
    }
}
