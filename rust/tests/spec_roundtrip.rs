//! The typed `IndexSpec` surface (pure Rust — runs on default
//! features): Display/parse round-trips for every backbone, knob
//! validation, the named LeanVec target-dim helper, and
//! `build_backend` ↔ `IndexSpec::build` equivalence during the
//! deprecation window.

use amips::api::{Effort, SearchRequest, Searcher};
use amips::index::{
    auto_pq_m, build_backend, leanvec_target_dim, BuildCtx, IndexSpec, VectorIndex, BACKBONES,
};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::Rng;

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    Rng::new(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

#[test]
fn display_parse_round_trip_for_every_backbone() {
    for name in BACKBONES {
        let spec = IndexSpec::default_for(name).unwrap();
        let text = spec.to_string();
        let back: IndexSpec = text.parse().unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(back, spec, "{name}: '{text}'");
        assert_eq!(back.name(), name);
        // Display is a fixpoint under parse
        assert_eq!(back.to_string(), text, "{name}");
    }
}

#[test]
fn explicit_knobs_round_trip_verbatim() {
    for text in [
        "flat",
        "sq8",
        "ivf(nlist=32,iters=7)",
        "pq(m=4,iters=3,eta=2.5)",
        "pq(m=auto,iters=10,eta=1)",
        "scann(nlist=16,m=8,iters=5,eta=4)",
        "soar(nlist=24,spill=3)",
        "leanvec(d_low=12,nlist=16,query_aware=false)",
        "leanvec(d_low=auto,nlist=64,query_aware=true)",
        "sharded(shards=8,assign=round_robin,inner=ivf(nlist=64,iters=15))",
        "sharded(shards=2,assign=contiguous,inner=scann(nlist=16,m=8,iters=5,eta=4))",
        "sharded(shards=4,assign=round_robin,inner=flat)",
    ] {
        let spec: IndexSpec = text.parse().unwrap_or_else(|e| panic!("{text}: {e:#}"));
        assert_eq!(spec.to_string(), text, "'{text}' did not round-trip");
    }
}

#[test]
fn parse_fills_missing_knobs_with_defaults() {
    let a: IndexSpec = "ivf(nlist=12)".parse().unwrap();
    let b = IndexSpec::default_for("ivf").unwrap().with_nlist(12);
    assert_eq!(a, b);
    // effort knobs untouched by nlist-only overrides
    let c: IndexSpec = "scann()".parse().unwrap();
    assert_eq!(c, IndexSpec::default_for("scann").unwrap());
}

#[test]
fn parse_rejects_invalid_specs() {
    for bad in [
        "",
        "hnsw",
        "ivf(nlist=0)",
        "ivf(iters=0)",
        "ivf(bogus=1)",
        "ivf(nlist=x)",
        "ivf(nlist=4",
        "ivf(nlist=4,nlist=5)",
        "ivf(nlist)",
        "pq(m=0)",
        "pq(eta=0)",
        "pq(eta=nan)",
        "scann(eta=-1)",
        "soar(spill=0)",
        "leanvec(d_low=0)",
        "leanvec(query_aware=maybe)",
        "sharded(shards=0)",
        "sharded(inner=hnsw)",
        "sharded(inner=sharded(inner=flat))",
        "sharded(assign=hash)",
        "sharded(shards=2,inner=ivf(nlist=4)",
    ] {
        assert!(bad.parse::<IndexSpec>().is_err(), "'{bad}' should not parse");
    }
}

#[test]
fn sharded_parse_defaults_and_shorthand() {
    // the ISSUE-3 headline spec parses, fills defaults, and round-trips
    // through its canonical Display form
    let s: IndexSpec = "sharded(shards=8,inner=ivf(nlist=64))".parse().unwrap();
    assert_eq!(s.name(), "sharded");
    assert_eq!(s.nlist(), Some(64));
    let text = s.to_string();
    assert_eq!(
        text,
        "sharded(shards=8,assign=round_robin,inner=ivf(nlist=64,iters=15))"
    );
    assert_eq!(text.parse::<IndexSpec>().unwrap(), s);
    // bare name gets the composite defaults
    let bare: IndexSpec = "sharded".parse().unwrap();
    assert_eq!(bare, IndexSpec::default_for("sharded").unwrap());
}

#[test]
fn leanvec_target_dim_matches_previous_inline_expression() {
    // the helper replaces `(d / 2).clamp(1, d).max(4.min(d))`
    for d in 1..=256 {
        assert_eq!(leanvec_target_dim(d), (d / 2).clamp(1, d).max(4.min(d)), "d={d}");
    }
    assert_eq!(leanvec_target_dim(32), 16);
    assert_eq!(leanvec_target_dim(6), 4);
    assert_eq!(leanvec_target_dim(3), 3);
}

#[test]
fn auto_pq_m_prefers_largest_divisor() {
    assert_eq!(auto_pq_m(32), 8);
    assert_eq!(auto_pq_m(20), 4);
    assert_eq!(auto_pq_m(10), 2);
    assert_eq!(auto_pq_m(9), 1);
}

#[test]
fn build_backend_matches_index_spec_build() {
    // deprecation-window contract: the stringly shim and the typed path
    // produce identical indexes (same defaults, same seeds, same hits)
    let keys = unit(&[300, 16], 1);
    let queries = unit(&[10, 16], 2);
    for name in BACKBONES {
        let legacy = build_backend(name, &keys, Some(&queries), 6, 9).unwrap();
        let typed = IndexSpec::default_for(name)
            .unwrap()
            .with_nlist(6)
            .build(
                &keys,
                &BuildCtx {
                    sample_queries: Some(&queries),
                    seed: 9,
                },
            )
            .unwrap();
        assert_eq!(typed.spec(), legacy.spec(), "{name}");
        for effort in [Effort::Probes(2), Effort::Exhaustive] {
            let req = SearchRequest::top_k(5).effort(effort);
            let a = legacy.search(&queries, &req).unwrap();
            let b = typed.search(&queries, &req).unwrap();
            for q in 0..10 {
                assert_eq!(a.hits[q].ids, b.hits[q].ids, "{name} {effort:?} q{q}");
                assert_eq!(a.hits[q].scores, b.hits[q].scores, "{name} {effort:?} q{q}");
            }
        }
    }
}

#[test]
fn spec_echo_resolves_auto_knobs() {
    let keys = unit(&[200, 16], 3);
    let ctx = BuildCtx::seeded(4);
    let pq = IndexSpec::default_for("pq").unwrap().build(&keys, &ctx).unwrap();
    assert_eq!(pq.spec().to_string(), "pq(m=8,iters=10,eta=1)");
    let lv = "leanvec(nlist=4)"
        .parse::<IndexSpec>()
        .unwrap()
        .build(&keys, &ctx)
        .unwrap();
    // d=16 -> d_low=8; no query sample was provided, so the echo says so
    assert_eq!(
        lv.spec().to_string(),
        "leanvec(d_low=8,nlist=4,query_aware=false)"
    );
}
