//! Versioned index artifacts (pure Rust — runs on default features):
//! save → load → search round-trips with bit-identical hits for all
//! seven backbones plus the composite sharded backbone, corrupt-header /
//! truncated-file / checksum error paths, a seeded corruption fuzz
//! sweep (scaled by `AMIPS_PROP_CASES`), and the catalog's build-once /
//! serve-many flow.

use amips::api::{Effort, SearchRequest, Searcher};
use amips::coordinator::{BatchPolicy, Server, ServerConfig};
use amips::index::{load_from, BuildCtx, Catalog, IndexSpec, VectorIndex, BACKBONES};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::{prop_cases, test_rng, TempDir};
use std::time::Duration;

const N: usize = 400;
const D: usize = 16;
const NLIST: usize = 8;

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    test_rng(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

/// Sharded wrappers over every leaf backbone (small per-shard knobs so
/// each of the 3 shards of `N` keys can host the inner index).
fn sharded_specs() -> Vec<String> {
    BACKBONES
        .iter()
        .map(|name| {
            let inner = IndexSpec::default_for(name).unwrap().with_nlist(NLIST);
            format!("sharded(shards=3,inner={inner})")
        })
        .collect()
}

fn build(name: &str, keys: &Tensor, queries: &Tensor) -> Box<dyn VectorIndex> {
    IndexSpec::default_for(name)
        .unwrap()
        .with_nlist(NLIST)
        .build(
            keys,
            &BuildCtx {
                sample_queries: Some(queries),
                seed: 42,
            },
        )
        .unwrap()
}

fn save_bytes(idx: &dyn VectorIndex) -> Vec<u8> {
    let mut buf = Vec::new();
    idx.save(&mut buf).unwrap();
    buf
}

fn assert_round_trips(orig: &dyn VectorIndex, queries: &Tensor, label: &str) {
    let bytes = save_bytes(orig);
    let loaded = load_from(&mut bytes.as_slice()).unwrap_or_else(|e| panic!("{label}: {e:#}"));
    assert_eq!(loaded.name(), orig.name(), "{label}");
    assert_eq!(loaded.len(), orig.len(), "{label}");
    assert_eq!(loaded.dim(), orig.dim(), "{label}");
    assert_eq!(loaded.n_cells(), orig.n_cells(), "{label}");
    assert_eq!(loaded.spec(), orig.spec(), "{label}");
    for effort in [
        Effort::Probes(1),
        Effort::Probes(3),
        Effort::Auto,
        Effort::Frac(0.5),
        Effort::Exhaustive,
    ] {
        let req = SearchRequest::top_k(5).effort(effort);
        let a = orig.search(queries, &req).unwrap();
        let b = loaded.search(queries, &req).unwrap();
        for q in 0..queries.rows() {
            assert_eq!(a.hits[q].ids, b.hits[q].ids, "{label} {effort:?} q{q}");
            assert_eq!(a.hits[q].scores, b.hits[q].scores, "{label} {effort:?} q{q}");
        }
        assert_eq!(a.cost.keys_scanned, b.cost.keys_scanned, "{label} {effort:?}");
        assert_eq!(a.cost.cells_probed, b.cost.cells_probed, "{label} {effort:?}");
    }
}

#[test]
fn every_backbone_round_trips_with_bit_identical_hits() {
    let keys = unit(&[N, D], 1);
    let queries = unit(&[12, D], 2);
    for name in BACKBONES {
        let orig = build(name, &keys, &queries);
        assert_eq!(orig.name(), name);
        assert_round_trips(orig.as_ref(), &queries, name);
    }
}

#[test]
fn sharded_variants_round_trip_with_bit_identical_hits() {
    let keys = unit(&[N, D], 1);
    let queries = unit(&[12, D], 2);
    for spec_str in sharded_specs() {
        let spec: IndexSpec = spec_str.parse().unwrap();
        let orig = spec
            .build(
                &keys,
                &BuildCtx {
                    sample_queries: Some(&queries),
                    seed: 42,
                },
            )
            .unwrap_or_else(|e| panic!("{spec_str}: {e:#}"));
        assert_eq!(orig.name(), "sharded");
        assert_round_trips(orig.as_ref(), &queries, &spec_str);
    }
    // contiguous assignment persists too
    let spec: IndexSpec = "sharded(shards=2,assign=contiguous,inner=ivf(nlist=4))"
        .parse()
        .unwrap();
    let orig = spec.build(&keys, &BuildCtx::seeded(7)).unwrap();
    assert_round_trips(orig.as_ref(), &queries, "sharded-contiguous");
}

#[test]
fn saving_twice_is_deterministic() {
    let keys = unit(&[150, D], 3);
    let idx = build("scann", &keys, &keys);
    assert_eq!(save_bytes(idx.as_ref()), save_bytes(idx.as_ref()));
}

#[test]
fn file_round_trip_via_path_helpers() {
    let tmp = TempDir::new("amips-artifact");
    let keys = unit(&[200, D], 4);
    let queries = unit(&[5, D], 5);
    let idx = build("leanvec", &keys, &queries);
    let path = tmp.join("index.ami");
    amips::index::save(&path, idx.as_ref()).unwrap();
    let loaded = amips::index::load(&path).unwrap();
    let req = SearchRequest::top_k(3).effort(Effort::Exhaustive);
    let a = idx.search(&queries, &req).unwrap();
    let b = loaded.search(&queries, &req).unwrap();
    for q in 0..5 {
        assert_eq!(a.hits[q].ids, b.hits[q].ids, "q{q}");
        assert_eq!(a.hits[q].scores, b.hits[q].scores, "q{q}");
    }
    std::fs::remove_file(&path).ok();
    // missing file is an error with the path in it
    let err = amips::index::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("index.ami"), "{err:#}");
}

#[test]
fn corrupt_and_truncated_artifacts_are_rejected() {
    let keys = unit(&[120, D], 6);
    let idx = build("ivf", &keys, &keys);
    let bytes = save_bytes(idx.as_ref());

    // pristine copy loads
    assert!(load_from(&mut bytes.as_slice()).is_ok());

    // bad magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    assert!(load_from(&mut bad.as_slice()).is_err());

    // unsupported format version
    let mut bad = bytes.clone();
    bad[4] = 0xEE;
    let err = load_from(&mut bad.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "{err:#}");

    // unknown backbone tag (corrupt the tag byte; checksum covers only
    // the payload, so this reaches the dispatch)
    let mut bad = bytes.clone();
    bad[12] = b'z';
    let err = load_from(&mut bad.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("backbone"), "{err:#}");

    // flipped payload byte -> checksum mismatch
    let mut bad = bytes.clone();
    let p = bad.len() - 9; // last payload byte (checksum is the final 8)
    bad[p] ^= 0x01;
    let err = load_from(&mut bad.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("checksum"), "{err:#}");

    // truncation at assorted prefixes, including mid-header,
    // mid-payload and a missing checksum tail
    for cut in [0usize, 3, 7, 16, bytes.len() / 2, bytes.len() - 12, bytes.len() - 1] {
        assert!(
            load_from(&mut &bytes[..cut]).is_err(),
            "cut at {cut} of {} should fail",
            bytes.len()
        );
    }
}

/// Seeded corruption fuzz over every backbone (sharded included): flip
/// random bytes and truncate at random prefixes of a valid artifact,
/// and require `index::load` to return a typed error or a consistent
/// index — never panic, never OOM. A flip can land in a region the
/// loader does not interpret (the header's spec echo — the payload
/// itself is fully covered by the checksum), so a successful load is
/// legal, but it must still describe the original index and survive a
/// search.
#[test]
fn artifact_corruption_fuzz_never_panics() {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let keys = unit(&[160, D], 21);
    let queries = unit(&[2, D], 22);
    let mut rng = test_rng(23);
    let mut labels: Vec<String> = BACKBONES.iter().map(|n| n.to_string()).collect();
    labels.push("sharded(shards=3,inner=ivf(nlist=4))".to_string());
    labels.push("sharded(shards=2,assign=contiguous,inner=flat)".to_string());
    for label in labels {
        let spec = match IndexSpec::default_for(&label) {
            Ok(s) => s.with_nlist(NLIST),
            Err(_) => label.parse().unwrap(),
        };
        let idx = spec
            .build(
                &keys,
                &BuildCtx {
                    sample_queries: Some(&queries),
                    seed: 24,
                },
            )
            .unwrap();
        let bytes = save_bytes(idx.as_ref());
        let (n_orig, d_orig) = (idx.len(), idx.dim());

        // single-byte flips anywhere in the artifact
        for case in 0..prop_cases(60) {
            let mut bad = bytes.clone();
            let pos = rng.below(bad.len());
            bad[pos] ^= (1 + rng.below(255)) as u8;
            let outcome = catch_unwind(AssertUnwindSafe(|| load_from(&mut bad.as_slice())));
            let loaded = outcome.unwrap_or_else(|_| {
                panic!("{label} case {case}: load panicked after flipping byte {pos}")
            });
            if let Ok(loaded) = loaded {
                assert_eq!(
                    (loaded.len(), loaded.dim()),
                    (n_orig, d_orig),
                    "{label} case {case}: flip at {pos} loaded an inconsistent index"
                );
                let res = catch_unwind(AssertUnwindSafe(|| {
                    loaded.search_effort(queries.row(0), 3, Effort::Exhaustive)
                }));
                assert!(
                    res.is_ok(),
                    "{label} case {case}: search panicked after flip at {pos}"
                );
            }
        }

        // truncation at random prefixes always errors (part of the
        // checksum tail is gone at minimum), and never panics
        for case in 0..prop_cases(40) {
            let cut = rng.below(bytes.len());
            let outcome = catch_unwind(AssertUnwindSafe(|| load_from(&mut &bytes[..cut])));
            let loaded = outcome.unwrap_or_else(|_| {
                panic!("{label} case {case}: load panicked on truncation at {cut}")
            });
            assert!(
                loaded.is_err(),
                "{label} case {case}: truncation at {cut} of {} must fail",
                bytes.len()
            );
        }
    }
}

/// ISSUE 3 acceptance: a sharded collection survives
/// build → save → catalog-load → search with identical results.
#[test]
fn sharded_collection_round_trips_through_catalog() {
    let tmp = TempDir::new("amips-catalog-sharded");
    let root = tmp.join("catalog");
    let keys = unit(&[360, D], 25);
    let queries = unit(&[8, D], 26);
    let spec: IndexSpec = "sharded(shards=4,inner=ivf(nlist=8))".parse().unwrap();
    let req = SearchRequest::top_k(6).effort(Effort::Exhaustive);
    let want = {
        let mut catalog = Catalog::create(&root).unwrap();
        let entry = catalog
            .build_collection("docs", &spec, &keys, &BuildCtx::seeded(27))
            .unwrap();
        assert_eq!(entry.index.name(), "sharded");
        entry.index.search(&queries, &req).unwrap()
    };

    // reopen from disk: the manifest spec parses back to the sharded
    // spec and the artifact deserializes into an identical index
    let catalog = Catalog::open(&root).unwrap();
    let entry = catalog.get("docs").unwrap();
    assert_eq!(entry.spec, spec);
    let got = entry.index.search(&queries, &req).unwrap();
    for q in 0..queries.rows() {
        assert_eq!(got.hits[q].ids, want.hits[q].ids, "q{q}");
        assert_eq!(got.hits[q].scores, want.hits[q].scores, "q{q}");
    }

    // and the single-collection serve path loads it too
    let solo = Catalog::open_collection(&root, "docs").unwrap();
    assert_eq!(solo.index.name(), "sharded");
    assert_eq!(solo.index.len(), 360);
}

#[test]
fn catalog_build_once_serve_many() {
    let tmp = TempDir::new("amips-catalog-it");
    let root = tmp.join("catalog");
    let keys = unit(&[300, D], 7);
    let queries = unit(&[6, D], 8);
    let req = SearchRequest::top_k(4).effort(Effort::Probes(3));

    // --- build once -----------------------------------------------------
    {
        let mut catalog = Catalog::create(&root).unwrap();
        for name in ["ivf", "scann"] {
            let spec = IndexSpec::default_for(name).unwrap().with_nlist(NLIST);
            let entry = catalog
                .build_collection(&format!("col-{name}"), &spec, &keys, &BuildCtx::seeded(11))
                .unwrap();
            assert!(entry.path.exists(), "{name}");
        }
        // duplicate and malformed names are typed errors
        let flat = IndexSpec::default_for("flat").unwrap();
        assert!(catalog
            .build_collection("col-ivf", &flat, &keys, &BuildCtx::default())
            .is_err());
        assert!(catalog
            .build_collection("bad/name", &flat, &keys, &BuildCtx::default())
            .is_err());
    }

    // create() must refuse to clobber the populated catalog
    assert!(Catalog::create(&root).is_err());

    // --- serve many (fresh process stand-in: reopen from disk) ----------
    let catalog = Catalog::open(&root).unwrap();
    assert_eq!(catalog.names(), vec!["col-ivf", "col-scann"]);
    assert_eq!(
        Catalog::names_on_disk(&root).unwrap(),
        vec!["col-ivf".to_string(), "col-scann".to_string()]
    );

    // single-collection load path: only the requested artifact is read
    let solo = Catalog::open_collection(&root, "col-ivf").unwrap();
    assert_eq!(solo.name, "col-ivf");
    assert_eq!(solo.index.name(), "ivf");
    let missing = Catalog::open_collection(&root, "nope").unwrap_err();
    assert!(format!("{missing:#}").contains("col-ivf"), "{missing:#}");
    for name in ["ivf", "scann"] {
        let entry = catalog.get(&format!("col-{name}")).unwrap();
        // manifest keeps the registered spec; the index echoes resolved knobs
        assert_eq!(
            entry.spec,
            IndexSpec::default_for(name).unwrap().with_nlist(NLIST)
        );
        let fresh = IndexSpec::default_for(name)
            .unwrap()
            .with_nlist(NLIST)
            .build(&keys, &BuildCtx::seeded(11))
            .unwrap();
        assert_eq!(entry.index.spec(), fresh.spec(), "{name}");
        let a = entry.index.search(&queries, &req).unwrap();
        let b = fresh.search(&queries, &req).unwrap();
        for q in 0..6 {
            assert_eq!(a.hits[q].ids, b.hits[q].ids, "{name} q{q}");
            assert_eq!(a.hits[q].scores, b.hits[q].scores, "{name} q{q}");
        }
    }

    // the threaded server starts straight from the catalog
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
    };
    let (server, handle) =
        Server::start_from_catalog(&catalog, "col-ivf", ServerConfig::unmapped(policy, req))
            .unwrap();
    let resp = handle.search(queries.row(0).to_vec()).unwrap();
    assert_eq!(resp.hits.len(), 4);
    drop(handle);
    server.shutdown().unwrap();
}

#[test]
fn append_collection_is_manifest_only_and_creates_catalogs() {
    let tmp = TempDir::new("amips-catalog-append");
    let root = tmp.join("catalog");
    let keys = unit(&[150, D], 10);
    let ivf = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
    // creates the catalog on first append
    Catalog::append_collection(&root, "a", &ivf, &keys, &BuildCtx::seeded(1)).unwrap();
    // appending must work even when an existing artifact is unreadable:
    // it parses the manifest but never deserializes sibling artifacts
    let a_path = root.join("a.ami");
    std::fs::write(&a_path, b"garbage").unwrap();
    let flat = IndexSpec::default_for("flat").unwrap();
    Catalog::append_collection(&root, "b", &flat, &keys, &BuildCtx::seeded(2)).unwrap();
    assert_eq!(
        Catalog::names_on_disk(&root).unwrap(),
        vec!["a".to_string(), "b".to_string()]
    );
    // duplicate names still rejected from the manifest alone
    assert!(Catalog::append_collection(&root, "b", &flat, &keys, &BuildCtx::seeded(3)).is_err());
    // collection b is individually loadable despite a's corruption
    let b = Catalog::open_collection(&root, "b").unwrap();
    assert_eq!(b.index.len(), 150);
    assert!(Catalog::open_collection(&root, "a").is_err());
}

// ---------------------------------------------------------------------------
// Mutable-collection artifacts: generation manifests + sealed segments
// ---------------------------------------------------------------------------

/// A mutable collection with two sealed segments, live tombstones and a
/// non-zero delta history: the richest on-disk layout the generation
/// format produces (several `gen-*.tsv` + `seg-*.ams` files).
fn churned_mutable(dir: &std::path::Path) -> amips::index::MutableCollection {
    use amips::index::MutableCollection;
    let spec = IndexSpec::default_for("flat").unwrap();
    let coll = MutableCollection::create(dir, spec, D, 31).unwrap();
    coll.insert(&unit(&[80, D], 32)).unwrap();
    coll.commit().unwrap(); // gen 1: one sealed segment
    coll.insert(&unit(&[40, D], 33)).unwrap();
    coll.delete(&[3, 9, 27]).unwrap();
    coll.upsert(&[11, 85], &unit(&[2, D], 34)).unwrap();
    coll.commit().unwrap(); // gen 2: two segments + tombstones
    coll
}

/// Satellite: corruption fuzz over generation manifests. Any byte flip
/// or truncation of the newest manifest must yield a typed error *or*
/// clean recovery to an older committed generation — never a panic,
/// never a half-loaded collection.
#[test]
fn generation_manifest_corruption_fuzz_never_panics() {
    use amips::index::MutableCollection;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let tmp = TempDir::new("amips-gen-fuzz");
    let dir = tmp.join("c.seg");
    let coll = churned_mutable(&dir);
    let live = coll.len();
    let spec = IndexSpec::default_for("flat").unwrap();
    drop(coll);

    let newest = dir.join("gen-000002.tsv");
    let pristine = std::fs::read(&newest).unwrap();
    let mut rng = test_rng(35);
    for case in 0..prop_cases(60) {
        let mut bad = pristine.clone();
        if case % 3 == 2 {
            bad.truncate(rng.below(bad.len()));
        } else {
            let pos = rng.below(bad.len());
            bad[pos] ^= (1 + rng.below(255)) as u8;
        }
        std::fs::write(&newest, &bad).unwrap();
        let spec2 = spec.clone();
        let outcome = catch_unwind(AssertUnwindSafe(|| MutableCollection::open(&dir, spec2)));
        let opened = outcome
            .unwrap_or_else(|_| panic!("case {case}: open panicked on corrupt gen manifest"));
        match opened {
            // recovery: an older committed generation took over (or the
            // flip happened to keep the manifest fully valid)
            Ok(c) => {
                assert!(c.generation() <= 2, "case {case}");
                assert!(c.len() == live || c.len() == 80, "case {case}: len {}", c.len());
                c.search_effort(unit(&[1, D], 36).row(0), 3, Effort::Exhaustive);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(!msg.is_empty(), "case {case}");
            }
        }
    }
    // restore: the pristine layout still opens at the newest generation
    std::fs::write(&newest, &pristine).unwrap();
    let c = MutableCollection::open(&dir, spec).unwrap();
    assert_eq!((c.generation(), c.len()), (2, live));
}

/// Satellite: torn sealed segments. Flips/truncations of a `seg-*.ams`
/// payload must be caught by the container checksum (typed error or
/// fallback to an older generation), never a panic.
#[test]
fn torn_segment_corruption_fuzz_never_panics() {
    use amips::index::MutableCollection;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let tmp = TempDir::new("amips-seg-fuzz");
    let dir = tmp.join("c.seg");
    let coll = churned_mutable(&dir);
    let live = coll.len();
    let spec = IndexSpec::default_for("flat").unwrap();
    drop(coll);

    // corrupt the newest generation's *second* segment (the sealed
    // delta) so recovery to gen 1 — which doesn't reference it — works
    let manifest = std::fs::read_to_string(dir.join("gen-000002.tsv")).unwrap();
    let seg_file = manifest
        .lines()
        .filter_map(|l| l.strip_prefix("segment\t"))
        .last()
        .expect("gen 2 lists segments")
        .to_string();
    let seg_path = dir.join(&seg_file);
    let pristine = std::fs::read(&seg_path).unwrap();
    let mut rng = test_rng(37);
    for case in 0..prop_cases(60) {
        let mut bad = pristine.clone();
        if case % 3 == 2 {
            bad.truncate(rng.below(bad.len()));
        } else {
            let pos = rng.below(bad.len());
            bad[pos] ^= (1 + rng.below(255)) as u8;
        }
        std::fs::write(&seg_path, &bad).unwrap();
        let spec2 = spec.clone();
        let outcome = catch_unwind(AssertUnwindSafe(|| MutableCollection::open(&dir, spec2)));
        let opened =
            outcome.unwrap_or_else(|_| panic!("case {case}: open panicked on torn segment"));
        if let Ok(c) = opened {
            // either gen 2 survived (flip in checksum-exempt bytes is
            // impossible — the whole container is covered — but a flip
            // can be a no-op only if write() restored identical bytes)
            // or we fell back to gen 1
            assert!(c.generation() <= 2, "case {case}");
            c.search_effort(unit(&[1, D], 38).row(0), 3, Effort::Exhaustive);
        }
    }
    std::fs::write(&seg_path, &pristine).unwrap();
    let c = MutableCollection::open(&dir, spec).unwrap();
    assert_eq!((c.generation(), c.len()), (2, live));
}

/// Satellite: the stale-generation-plus-orphan layout a mid-compaction
/// kill leaves behind — an orphan segment file, a torn `.tmp` manifest
/// and a corrupt next-generation manifest. Open must recover to the
/// last committed generation with its exact contents.
#[test]
fn stale_generation_plus_orphan_recovers_cleanly() {
    use amips::index::MutableCollection;

    let tmp = TempDir::new("amips-gen-orphan");
    let dir = tmp.join("c.seg");
    let coll = churned_mutable(&dir);
    let live = coll.len();
    let query = unit(&[1, D], 39);
    let want = coll.search_effort(query.row(0), 5, Effort::Exhaustive);
    let spec = IndexSpec::default_for("flat").unwrap();
    drop(coll);

    // simulate the kill: compaction wrote its output segment and was
    // killed between manifest write and rename (torn .tmp), then a
    // *second* crash scenario where the rename landed but the file is
    // truncated mid-line
    std::fs::write(dir.join("seg-000003-000.ams"), b"AMSGnot really a segment").unwrap();
    std::fs::write(dir.join("gen-000003.tsv.tmp"), b"# amips generation man").unwrap();
    std::fs::write(
        dir.join("gen-000003.tsv"),
        b"# amips generation manifest v1\ngen\t3\ndim\t16",
    )
    .unwrap();

    let c = MutableCollection::open(&dir, spec).unwrap();
    assert_eq!((c.generation(), c.len()), (2, live), "recovered generation");
    let got = c.search_effort(query.row(0), 5, Effort::Exhaustive);
    assert_eq!(got.ids, want.ids, "recovered results");
    assert_eq!(got.scores, want.scores, "recovered results");

    // committing from the recovered state replaces the poisoned gen-3
    // manifest (write-then-rename) with a valid one: a reopen now lands
    // on generation 3 with the new rows
    c.insert(&unit(&[4, D], 40)).unwrap();
    let gen = c.commit().unwrap();
    assert_eq!(gen, 3, "commit rewrites the poisoned generation");
    let spec = IndexSpec::default_for("flat").unwrap();
    let reopened = MutableCollection::open(&dir, spec).unwrap();
    assert_eq!((reopened.generation(), reopened.len()), (3, live + 4));
}

#[test]
fn catalog_open_rejects_manifest_artifact_mismatch() {
    let tmp = TempDir::new("amips-catalog-bad");
    let root = tmp.join("catalog");
    let keys = unit(&[100, D], 9);
    {
        let mut catalog = Catalog::create(&root).unwrap();
        let spec = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
        catalog
            .build_collection("docs", &spec, &keys, &BuildCtx::seeded(12))
            .unwrap();
    }
    // lie about the backbone in the manifest (a *valid* spec of another
    // backbone): open() must refuse the tag mismatch
    let manifest = root.join("catalog.tsv");
    let text = std::fs::read_to_string(&manifest).unwrap();
    assert!(text.contains("ivf(nlist=4,iters=15)"), "{text}");
    std::fs::write(
        &manifest,
        text.replace("ivf(nlist=4,iters=15)", "soar(nlist=4,spill=6)"),
    )
    .unwrap();
    assert!(Catalog::open(&root).is_err());
    // a malformed line is rejected too
    std::fs::write(&manifest, "only-one-field\n").unwrap();
    assert!(Catalog::open(&root).is_err());
}

// ---------------------------------------------------------------------------
// Version-2 compact-storage payloads (storage=f16 / bits=4) and the v1
// backwards-compatibility contract
// ---------------------------------------------------------------------------

/// Round-trip + corruption fuzz for every compact-storage variant: the
/// v2 payload fields (f16 key rows, 4-bit packed codes) must survive
/// save → load with bit-identical hits, and byte flips / truncations
/// must yield typed errors or consistent indexes — never panics.
#[test]
fn compact_storage_artifacts_round_trip_and_survive_corruption() {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let keys = unit(&[160, D], 52);
    let queries = unit(&[4, D], 53);
    let specs = [
        "flat(storage=f16)",
        "pq(bits=4)",
        "scann(nlist=8,bits=4)",
        "leanvec(nlist=8,storage=f16)",
    ];
    let mut rng = test_rng(55);
    for spec_str in specs {
        let spec: IndexSpec = spec_str.parse().unwrap();
        let idx = spec
            .build(
                &keys,
                &BuildCtx {
                    sample_queries: Some(&queries),
                    seed: 54,
                },
            )
            .unwrap_or_else(|e| panic!("{spec_str}: {e:#}"));
        assert_round_trips(idx.as_ref(), &queries, spec_str);

        let bytes = save_bytes(idx.as_ref());
        let (n_orig, d_orig) = (idx.len(), idx.dim());
        for case in 0..prop_cases(40) {
            let mut bad = bytes.clone();
            let pos = rng.below(bad.len());
            bad[pos] ^= (1 + rng.below(255)) as u8;
            let outcome = catch_unwind(AssertUnwindSafe(|| load_from(&mut bad.as_slice())));
            let loaded = outcome.unwrap_or_else(|_| {
                panic!("{spec_str} case {case}: load panicked after flipping byte {pos}")
            });
            if let Ok(loaded) = loaded {
                assert_eq!(
                    (loaded.len(), loaded.dim()),
                    (n_orig, d_orig),
                    "{spec_str} case {case}: flip at {pos} loaded an inconsistent index"
                );
                let res = catch_unwind(AssertUnwindSafe(|| {
                    loaded.search_effort(queries.row(0), 3, Effort::Exhaustive)
                }));
                assert!(
                    res.is_ok(),
                    "{spec_str} case {case}: search panicked after flip at {pos}"
                );
            }
        }
        for case in 0..prop_cases(30) {
            let cut = rng.below(bytes.len());
            let outcome = catch_unwind(AssertUnwindSafe(|| load_from(&mut &bytes[..cut])));
            let loaded = outcome.unwrap_or_else(|_| {
                panic!("{spec_str} case {case}: load panicked on truncation at {cut}")
            });
            assert!(
                loaded.is_err(),
                "{spec_str} case {case}: truncation at {cut} of {} must fail",
                bytes.len()
            );
        }
    }
}

/// Local FNV-1a (the artifact checksum) so the tests below can reframe
/// payloads without crate-private helpers.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Offset just past the spec echo — the end of the version-independent
/// header fields (magic, version u32, tag str, dim u64, len u64, spec
/// str).
fn header_end(bytes: &[u8]) -> usize {
    let tag_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let off = 12 + tag_len + 16;
    let spec_len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
    off + 4 + spec_len
}

/// Offset + length of the payload inside a framed artifact. v3 frames
/// carry a self-describing pad (u32 length + zeros) between the spec
/// echo and the payload length; v1/v2 frames go straight to the length.
fn frame_payload(bytes: &[u8]) -> (usize, usize) {
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    let mut off = header_end(bytes);
    if version >= 3 {
        let pad = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4 + pad;
    }
    let plen = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()) as usize;
    (off + 8, plen)
}

/// Rebuild the artifact as `version` (pad-free v1/v2 framing) around a
/// hand-edited payload: header fields copied, version field rewritten,
/// length + checksum redone.
fn reframe(bytes: &[u8], version: u32, new_payload: &[u8]) -> Vec<u8> {
    let mut out = bytes[..header_end(bytes)].to_vec();
    out[4..8].copy_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(new_payload.len() as u64).to_le_bytes());
    out.extend_from_slice(new_payload);
    out.extend_from_slice(&fnv1a64(new_payload).to_le_bytes());
    out
}

/// Down-convert the aligned v3 section at the head of `cur` (count u64,
/// pad u32 + zeros, `elem`-byte LE data) to the legacy
/// u64-length-prefixed array encoding. Returns (legacy bytes, v3 bytes
/// consumed).
fn de_section(cur: &[u8], elem: usize) -> (Vec<u8>, usize) {
    let count = u64::from_le_bytes(cur[..8].try_into().unwrap()) as usize;
    let pad = u32::from_le_bytes(cur[8..12].try_into().unwrap()) as usize;
    let start = 12 + pad;
    let end = start + count * elem;
    let mut out = cur[..8].to_vec();
    out.extend_from_slice(&cur[start..end]);
    (out, end)
}

/// Down-convert the v3 tensor at the head of `cur` (rank u32 + dims
/// u64s + aligned f32 section) to the legacy `AMT1` encoding (magic +
/// rank + dims + raw data — the element count is implied by the dims).
fn de_tensor(cur: &[u8]) -> (Vec<u8>, usize) {
    let rank = u32::from_le_bytes(cur[..4].try_into().unwrap()) as usize;
    let dims_end = 4 + rank * 8;
    let (sec, sec_len) = de_section(&cur[dims_end..], 4);
    let mut out = b"AMT1".to_vec();
    out.extend_from_slice(&cur[..dims_end]);
    out.extend_from_slice(&sec[8..]);
    (out, dims_end + sec_len)
}

/// Bytes consumed by one tensor at the head of `cur`.
fn tensor_len(cur: &[u8]) -> usize {
    let mut r: &[u8] = cur;
    Tensor::read_from(&mut r).unwrap();
    cur.len() - r.len()
}

/// Bytes consumed by one u64-length-prefixed array of `elem`-byte items.
fn arr_len(cur: &[u8], elem: usize) -> usize {
    8 + u64::from_le_bytes(cur[..8].try_into().unwrap()) as usize * elem
}

fn assert_loads_identically(
    v1: &[u8],
    orig: &dyn VectorIndex,
    queries: &Tensor,
    label: &str,
) {
    let loaded = load_from(&mut &v1[..]).unwrap_or_else(|e| panic!("{label}: {e:#}"));
    assert_eq!(loaded.spec(), orig.spec(), "{label}");
    let req = SearchRequest::top_k(5).effort(Effort::Exhaustive);
    let a = orig.search(queries, &req).unwrap();
    let b = loaded.search(queries, &req).unwrap();
    for q in 0..queries.rows() {
        assert_eq!(a.hits[q].ids, b.hits[q].ids, "{label} q{q}");
        assert_eq!(a.hits[q].scores, b.hits[q].scores, "{label} q{q}");
    }
}

/// The binding v1 contract: version-1 artifacts (which predate the
/// storage tag, the PQ `bits` field and the aligned v3 sections) must
/// load bit-identically to the f32/8-bit build that would have written
/// them. v1 streams are constructed by hand here — current writers
/// always emit v3, so this is exactly the archived-artifact scenario.
#[test]
fn hand_built_v1_artifacts_load_bit_identically() {
    let keys = unit(&[N, D], 50);
    let queries = unit(&[8, D], 51);

    // flat: the v1 payload is the bare legacy f32 key tensor (v2+
    // prefix a u32 storage tag; v3 stores the rows in an aligned
    // section)
    let flat = build("flat", &keys, &queries);
    let v3 = save_bytes(flat.as_ref());
    let (pstart, plen) = frame_payload(&v3);
    let payload = &v3[pstart..pstart + plen];
    assert_eq!(&payload[..4], &0u32.to_le_bytes(), "f32 storage tag");
    let (keys_t, used) = de_tensor(&payload[4..]);
    assert_eq!(4 + used, plen, "flat payload is tag + key tensor");
    let v1 = reframe(&v3, 1, &keys_t);
    assert_loads_identically(&v1, flat.as_ref(), &queries, "flat v1");

    // pq: the v1 payload lacks the `bits` u64 between (d, m, dsub) and
    // the codebooks, stores codes as a legacy byte array and keys as a
    // legacy tensor
    let pq = build("pq", &keys, &queries);
    let v3 = save_bytes(pq.as_ref());
    let (pstart, plen) = frame_payload(&v3);
    let payload = &v3[pstart..pstart + plen];
    assert_eq!(
        &payload[24..32],
        &8u64.to_le_bytes(),
        "bits field after d/m/dsub"
    );
    let mut p1 = payload[..24].to_vec(); // d, m, dsub (bits dropped)
    let mut off = 32;
    off += arr_len(&payload[off..], 4); // codebooks (version-stable)
    p1.extend_from_slice(&payload[32..off]);
    let (codes, used) = de_section(&payload[off..], 1);
    off += used;
    p1.extend_from_slice(&codes);
    let (keys_t, used) = de_tensor(&payload[off..]);
    off += used;
    p1.extend_from_slice(&keys_t);
    p1.extend_from_slice(&payload[off..plen]); // rerank, iters, eta
    let v1 = reframe(&v3, 1, &p1);
    assert_loads_identically(&v1, pq.as_ref(), &queries, "pq v1");

    // scann: its payload is version-stable apart from the `bits` u64 —
    // remove it after centroids/packed tensors, the codes/ids/offsets
    // arrays and the quantizer's (m, dsub)
    let scann = build("scann", &keys, &queries);
    let v3 = save_bytes(scann.as_ref());
    let (pstart, plen) = frame_payload(&v3);
    let payload = &v3[pstart..pstart + plen];
    let mut off = tensor_len(payload); // centroids
    off += tensor_len(&payload[off..]); // packed keys
    off += arr_len(&payload[off..], 1); // codes
    off += arr_len(&payload[off..], 4); // ids
    off += arr_len(&payload[off..], 8); // offsets
    off += 16; // m, dsub
    assert_eq!(&payload[off..off + 8], &8u64.to_le_bytes(), "scann bits");
    let mut p1 = payload[..off].to_vec();
    p1.extend_from_slice(&payload[off + 8..plen]);
    let v1 = reframe(&v3, 1, &p1);
    assert_loads_identically(&v1, scann.as_ref(), &queries, "scann v1");

    // leanvec: the v1 payload stores the re-rank keys as a bare legacy
    // tensor — drop the u32 storage tag after the comps tensor + mean
    // array and de-align the key rows
    let lv = build("leanvec", &keys, &queries);
    let v3 = save_bytes(lv.as_ref());
    let (pstart, plen) = frame_payload(&v3);
    let payload = &v3[pstart..pstart + plen];
    let mut off = tensor_len(payload); // comps
    off += arr_len(&payload[off..], 4); // mean
    assert_eq!(&payload[off..off + 4], &0u32.to_le_bytes(), "leanvec tag");
    let mut p1 = payload[..off].to_vec();
    let (keys_t, used) = de_tensor(&payload[off + 4..]);
    p1.extend_from_slice(&keys_t);
    p1.extend_from_slice(&payload[off + 4 + used..plen]);
    let v1 = reframe(&v3, 1, &p1);
    assert_loads_identically(&v1, lv.as_ref(), &queries, "leanvec v1");
}

/// The v2 contract: version-2 artifacts (tagged key stores and the PQ
/// `bits` field, but unaligned arrays — the PR 9 layout) must load
/// bit-identically. Hand-built by de-aligning the v3 writer output for
/// both section flavors (u8 code matrices, u16 f16 rows) and the v3
/// tensor codec.
#[test]
fn hand_built_v2_artifacts_load_bit_identically() {
    let keys = unit(&[N, D], 56);
    let queries = unit(&[8, D], 57);

    // flat f32: storage tag + legacy tensor
    let flat = build("flat", &keys, &queries);
    let v3 = save_bytes(flat.as_ref());
    let (pstart, plen) = frame_payload(&v3);
    let payload = &v3[pstart..pstart + plen];
    let mut p2 = payload[..4].to_vec();
    let (keys_t, used) = de_tensor(&payload[4..]);
    assert_eq!(4 + used, plen);
    p2.extend_from_slice(&keys_t);
    let v2 = reframe(&v3, 2, &p2);
    assert_loads_identically(&v2, flat.as_ref(), &queries, "flat v2");

    // flat f16: storage tag 1 + n + d + legacy u16 array
    let spec: IndexSpec = "flat(storage=f16)".parse().unwrap();
    let f16 = spec
        .build(
            &keys,
            &BuildCtx {
                sample_queries: Some(&queries),
                seed: 58,
            },
        )
        .unwrap();
    let v3 = save_bytes(f16.as_ref());
    let (pstart, plen) = frame_payload(&v3);
    let payload = &v3[pstart..pstart + plen];
    assert_eq!(&payload[..4], &1u32.to_le_bytes(), "f16 storage tag");
    let mut p2 = payload[..20].to_vec(); // tag, n, d
    let (rows, used) = de_section(&payload[20..], 2);
    assert_eq!(20 + used, plen);
    p2.extend_from_slice(&rows);
    let v2 = reframe(&v3, 2, &p2);
    assert_loads_identically(&v2, f16.as_ref(), &queries, "flat-f16 v2");

    // sq8: d + legacy code bytes + lo/scale arrays + legacy tensor +
    // rerank
    let sq = build("sq8", &keys, &queries);
    let v3 = save_bytes(sq.as_ref());
    let (pstart, plen) = frame_payload(&v3);
    let payload = &v3[pstart..pstart + plen];
    let mut p2 = payload[..8].to_vec(); // d
    let (codes, used) = de_section(&payload[8..], 1);
    let mut off = 8 + used;
    p2.extend_from_slice(&codes);
    let lo = arr_len(&payload[off..], 4);
    let lo_scale = lo + arr_len(&payload[off + lo..], 4);
    p2.extend_from_slice(&payload[off..off + lo_scale]);
    off += lo_scale;
    let (keys_t, used) = de_tensor(&payload[off..]);
    off += used;
    p2.extend_from_slice(&keys_t);
    assert_eq!(plen - off, 8, "rerank is the final u64");
    p2.extend_from_slice(&payload[off..plen]);
    let v2 = reframe(&v3, 2, &p2);
    assert_loads_identically(&v2, sq.as_ref(), &queries, "sq8 v2");
}

// ---------------------------------------------------------------------------
// Version-3 aligned layout: zero-copy file loads
// ---------------------------------------------------------------------------

/// Tentpole acceptance: an artifact loaded back through the file path
/// searches bit-identically to the in-RAM index it was saved from, and
/// under `--features mmap` the scan matrices (f32/f16 key rows, SQ8/PQ
/// code matrices) are borrowed views of the mapping — no decoded copy.
#[test]
fn file_loads_are_bit_identical_and_zero_copy_under_mmap() {
    let tmp = TempDir::new("amips-zero-copy");
    let keys = unit(&[N, D], 60);
    let queries = unit(&[6, D], 61);
    for (i, spec_str) in ["flat", "flat(storage=f16)", "sq8", "pq", "leanvec"]
        .iter()
        .enumerate()
    {
        let spec: IndexSpec = match IndexSpec::default_for(spec_str) {
            Ok(s) => s.with_nlist(NLIST),
            Err(_) => spec_str.parse().unwrap(),
        };
        let idx = spec
            .build(
                &keys,
                &BuildCtx {
                    sample_queries: Some(&queries),
                    seed: 62,
                },
            )
            .unwrap_or_else(|e| panic!("{spec_str}: {e:#}"));
        let path = tmp.join(format!("zc-{i}.ami"));
        amips::index::save(&path, idx.as_ref()).unwrap();
        let loaded = amips::index::load(&path).unwrap();
        // page-aligned mappings + the 64-byte section contract mean the
        // bulk matrices must come back as views, not copies
        #[cfg(feature = "mmap")]
        assert!(
            loaded.zero_copy(),
            "{spec_str}: scan matrices should be borrowed from the mapping"
        );
        let req = SearchRequest::top_k(5).effort(Effort::Exhaustive);
        let a = idx.search(&queries, &req).unwrap();
        let b = loaded.search(&queries, &req).unwrap();
        for q in 0..queries.rows() {
            assert_eq!(a.hits[q].ids, b.hits[q].ids, "{spec_str} q{q}");
            assert_eq!(a.hits[q].scores, b.hits[q].scores, "{spec_str} q{q}");
        }
    }
}

/// Corruption fuzz over the aligned v3 layout through the *file* load
/// path. Under `--features mmap` this exercises the lazy-open rule —
/// the payload checksum is skipped for real mappings, so the structural
/// checks (section pads, lengths, shape cross-checks) alone must turn
/// every flip into a typed error or a consistent, searchable index.
/// Never a panic. (NaN scores from a flipped key byte are fine: TopK
/// ranks NaN as -inf.)
#[test]
fn mapped_corruption_fuzz_never_panics() {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let tmp = TempDir::new("amips-map-fuzz");
    let keys = unit(&[160, D], 63);
    let queries = unit(&[2, D], 64);
    let path = tmp.join("fuzz.ami");
    let mut rng = test_rng(65);
    for spec_str in ["flat", "flat(storage=f16)", "sq8", "pq"] {
        let spec: IndexSpec = spec_str.parse().unwrap();
        let idx = spec
            .build(
                &keys,
                &BuildCtx {
                    sample_queries: Some(&queries),
                    seed: 66,
                },
            )
            .unwrap();
        amips::index::save(&path, idx.as_ref()).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let (n_orig, d_orig) = (idx.len(), idx.dim());
        for case in 0..prop_cases(30) {
            let mut bad = bytes.clone();
            if case % 3 == 2 {
                bad.truncate(rng.below(bad.len()));
            } else {
                let pos = rng.below(bad.len());
                bad[pos] ^= (1 + rng.below(255)) as u8;
            }
            std::fs::write(&path, &bad).unwrap();
            let outcome = catch_unwind(AssertUnwindSafe(|| amips::index::load(&path)));
            let loaded = outcome.unwrap_or_else(|_| {
                panic!("{spec_str} case {case}: mapped load panicked")
            });
            if let Ok(loaded) = loaded {
                assert_eq!(
                    (loaded.len(), loaded.dim()),
                    (n_orig, d_orig),
                    "{spec_str} case {case}: corrupt file loaded an inconsistent index"
                );
                let res = catch_unwind(AssertUnwindSafe(|| {
                    loaded.search_effort(queries.row(0), 3, Effort::Exhaustive)
                }));
                assert!(
                    res.is_ok(),
                    "{spec_str} case {case}: search panicked on a lazily-opened corrupt file"
                );
            }
        }
        std::fs::remove_file(&path).ok();
    }
}
