//! Versioned index artifacts (pure Rust — runs on default features):
//! save → load → search round-trips with bit-identical hits for all
//! seven backbones, corrupt-header / truncated-file / checksum error
//! paths, and the catalog's build-once / serve-many flow.

use amips::api::{Effort, SearchRequest, Searcher};
use amips::coordinator::{BatchPolicy, Server, ServerConfig};
use amips::index::{load_from, BuildCtx, Catalog, IndexSpec, VectorIndex, BACKBONES};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::Rng;
use std::time::Duration;

const N: usize = 400;
const D: usize = 16;
const NLIST: usize = 8;

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    Rng::new(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

fn build(name: &str, keys: &Tensor, queries: &Tensor) -> Box<dyn VectorIndex> {
    IndexSpec::default_for(name)
        .unwrap()
        .with_nlist(NLIST)
        .build(
            keys,
            &BuildCtx {
                sample_queries: Some(queries),
                seed: 42,
            },
        )
        .unwrap()
}

fn save_bytes(idx: &dyn VectorIndex) -> Vec<u8> {
    let mut buf = Vec::new();
    idx.save(&mut buf).unwrap();
    buf
}

#[test]
fn every_backbone_round_trips_with_bit_identical_hits() {
    let keys = unit(&[N, D], 1);
    let queries = unit(&[12, D], 2);
    for name in BACKBONES {
        let orig = build(name, &keys, &queries);
        let bytes = save_bytes(orig.as_ref());
        let loaded = load_from(&mut bytes.as_slice()).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(loaded.name(), name);
        assert_eq!(loaded.len(), orig.len(), "{name}");
        assert_eq!(loaded.dim(), orig.dim(), "{name}");
        assert_eq!(loaded.n_cells(), orig.n_cells(), "{name}");
        assert_eq!(loaded.spec(), orig.spec(), "{name}");
        for effort in [
            Effort::Probes(1),
            Effort::Probes(3),
            Effort::Auto,
            Effort::Frac(0.5),
            Effort::Exhaustive,
        ] {
            let req = SearchRequest::top_k(5).effort(effort);
            let a = orig.search(&queries, &req).unwrap();
            let b = loaded.search(&queries, &req).unwrap();
            for q in 0..12 {
                assert_eq!(a.hits[q].ids, b.hits[q].ids, "{name} {effort:?} q{q}");
                assert_eq!(a.hits[q].scores, b.hits[q].scores, "{name} {effort:?} q{q}");
            }
            assert_eq!(a.cost.keys_scanned, b.cost.keys_scanned, "{name} {effort:?}");
            assert_eq!(a.cost.cells_probed, b.cost.cells_probed, "{name} {effort:?}");
        }
    }
}

#[test]
fn saving_twice_is_deterministic() {
    let keys = unit(&[150, D], 3);
    let idx = build("scann", &keys, &keys);
    assert_eq!(save_bytes(idx.as_ref()), save_bytes(idx.as_ref()));
}

#[test]
fn file_round_trip_via_path_helpers() {
    let keys = unit(&[200, D], 4);
    let queries = unit(&[5, D], 5);
    let idx = build("leanvec", &keys, &queries);
    let path = std::env::temp_dir().join(format!("amips-artifact-{}.ami", std::process::id()));
    amips::index::save(&path, idx.as_ref()).unwrap();
    let loaded = amips::index::load(&path).unwrap();
    let req = SearchRequest::top_k(3).effort(Effort::Exhaustive);
    let a = idx.search(&queries, &req).unwrap();
    let b = loaded.search(&queries, &req).unwrap();
    for q in 0..5 {
        assert_eq!(a.hits[q].ids, b.hits[q].ids, "q{q}");
        assert_eq!(a.hits[q].scores, b.hits[q].scores, "q{q}");
    }
    std::fs::remove_file(&path).ok();
    // missing file is an error with the path in it
    let err = amips::index::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("amips-artifact"), "{err:#}");
}

#[test]
fn corrupt_and_truncated_artifacts_are_rejected() {
    let keys = unit(&[120, D], 6);
    let idx = build("ivf", &keys, &keys);
    let bytes = save_bytes(idx.as_ref());

    // pristine copy loads
    assert!(load_from(&mut bytes.as_slice()).is_ok());

    // bad magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    assert!(load_from(&mut bad.as_slice()).is_err());

    // unsupported format version
    let mut bad = bytes.clone();
    bad[4] = 0xEE;
    let err = load_from(&mut bad.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "{err:#}");

    // unknown backbone tag (corrupt the tag byte; checksum covers only
    // the payload, so this reaches the dispatch)
    let mut bad = bytes.clone();
    bad[12] = b'z';
    let err = load_from(&mut bad.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("backbone"), "{err:#}");

    // flipped payload byte -> checksum mismatch
    let mut bad = bytes.clone();
    let p = bad.len() - 9; // last payload byte (checksum is the final 8)
    bad[p] ^= 0x01;
    let err = load_from(&mut bad.as_slice()).unwrap_err();
    assert!(format!("{err:#}").contains("checksum"), "{err:#}");

    // truncation at assorted prefixes, including mid-header,
    // mid-payload and a missing checksum tail
    for cut in [0usize, 3, 7, 16, bytes.len() / 2, bytes.len() - 12, bytes.len() - 1] {
        assert!(
            load_from(&mut &bytes[..cut]).is_err(),
            "cut at {cut} of {} should fail",
            bytes.len()
        );
    }
}

#[test]
fn catalog_build_once_serve_many() {
    let root = std::env::temp_dir().join(format!("amips-catalog-it-{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let keys = unit(&[300, D], 7);
    let queries = unit(&[6, D], 8);
    let req = SearchRequest::top_k(4).effort(Effort::Probes(3));

    // --- build once -----------------------------------------------------
    {
        let mut catalog = Catalog::create(&root).unwrap();
        for name in ["ivf", "scann"] {
            let spec = IndexSpec::default_for(name).unwrap().with_nlist(NLIST);
            let entry = catalog
                .build_collection(&format!("col-{name}"), &spec, &keys, &BuildCtx::seeded(11))
                .unwrap();
            assert!(entry.path.exists(), "{name}");
        }
        // duplicate and malformed names are typed errors
        let flat = IndexSpec::default_for("flat").unwrap();
        assert!(catalog
            .build_collection("col-ivf", &flat, &keys, &BuildCtx::default())
            .is_err());
        assert!(catalog
            .build_collection("bad/name", &flat, &keys, &BuildCtx::default())
            .is_err());
    }

    // create() must refuse to clobber the populated catalog
    assert!(Catalog::create(&root).is_err());

    // --- serve many (fresh process stand-in: reopen from disk) ----------
    let catalog = Catalog::open(&root).unwrap();
    assert_eq!(catalog.names(), vec!["col-ivf", "col-scann"]);
    assert_eq!(
        Catalog::names_on_disk(&root).unwrap(),
        vec!["col-ivf".to_string(), "col-scann".to_string()]
    );

    // single-collection load path: only the requested artifact is read
    let solo = Catalog::open_collection(&root, "col-ivf").unwrap();
    assert_eq!(solo.name, "col-ivf");
    assert_eq!(solo.index.name(), "ivf");
    let missing = Catalog::open_collection(&root, "nope").unwrap_err();
    assert!(format!("{missing:#}").contains("col-ivf"), "{missing:#}");
    for name in ["ivf", "scann"] {
        let entry = catalog.get(&format!("col-{name}")).unwrap();
        // manifest keeps the registered spec; the index echoes resolved knobs
        assert_eq!(
            entry.spec,
            IndexSpec::default_for(name).unwrap().with_nlist(NLIST)
        );
        let fresh = IndexSpec::default_for(name)
            .unwrap()
            .with_nlist(NLIST)
            .build(&keys, &BuildCtx::seeded(11))
            .unwrap();
        assert_eq!(entry.index.spec(), fresh.spec(), "{name}");
        let a = entry.index.search(&queries, &req).unwrap();
        let b = fresh.search(&queries, &req).unwrap();
        for q in 0..6 {
            assert_eq!(a.hits[q].ids, b.hits[q].ids, "{name} q{q}");
            assert_eq!(a.hits[q].scores, b.hits[q].scores, "{name} q{q}");
        }
    }

    // the threaded server starts straight from the catalog
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
    };
    let (server, handle) =
        Server::start_from_catalog(&catalog, "col-ivf", ServerConfig::unmapped(policy, req))
            .unwrap();
    let resp = handle.search(queries.row(0).to_vec()).unwrap();
    assert_eq!(resp.hits.len(), 4);
    drop(handle);
    server.shutdown().unwrap();

    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn append_collection_is_manifest_only_and_creates_catalogs() {
    let root = std::env::temp_dir().join(format!("amips-catalog-append-{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let keys = unit(&[150, D], 10);
    let ivf = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
    // creates the catalog on first append
    Catalog::append_collection(&root, "a", &ivf, &keys, &BuildCtx::seeded(1)).unwrap();
    // appending must work even when an existing artifact is unreadable:
    // it parses the manifest but never deserializes sibling artifacts
    let a_path = root.join("a.ami");
    std::fs::write(&a_path, b"garbage").unwrap();
    let flat = IndexSpec::default_for("flat").unwrap();
    Catalog::append_collection(&root, "b", &flat, &keys, &BuildCtx::seeded(2)).unwrap();
    assert_eq!(
        Catalog::names_on_disk(&root).unwrap(),
        vec!["a".to_string(), "b".to_string()]
    );
    // duplicate names still rejected from the manifest alone
    assert!(Catalog::append_collection(&root, "b", &flat, &keys, &BuildCtx::seeded(3)).is_err());
    // collection b is individually loadable despite a's corruption
    let b = Catalog::open_collection(&root, "b").unwrap();
    assert_eq!(b.index.len(), 150);
    assert!(Catalog::open_collection(&root, "a").is_err());
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn catalog_open_rejects_manifest_artifact_mismatch() {
    let root = std::env::temp_dir().join(format!("amips-catalog-bad-{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let keys = unit(&[100, D], 9);
    {
        let mut catalog = Catalog::create(&root).unwrap();
        let spec = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
        catalog
            .build_collection("docs", &spec, &keys, &BuildCtx::seeded(12))
            .unwrap();
    }
    // lie about the backbone in the manifest (a *valid* spec of another
    // backbone): open() must refuse the tag mismatch
    let manifest = root.join("catalog.tsv");
    let text = std::fs::read_to_string(&manifest).unwrap();
    assert!(text.contains("ivf(nlist=4,iters=15)"), "{text}");
    std::fs::write(
        &manifest,
        text.replace("ivf(nlist=4,iters=15)", "soar(nlist=4,spill=6)"),
    )
    .unwrap();
    assert!(Catalog::open(&root).is_err());
    // a malformed line is rejected too
    std::fs::write(&manifest, "only-one-field\n").unwrap();
    assert!(Catalog::open(&root).is_err());
    std::fs::remove_dir_all(&root).ok();
}
