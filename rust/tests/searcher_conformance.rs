//! Cross-backend conformance suite for the unified `amips::api` surface
//! (pure Rust — runs on default features).
//!
//! * every backbone behind `Searcher` matches `FlatIndex` top-1 exactly
//!   at `Effort::Exhaustive` on synthetic data;
//! * `CostBreakdown` components are monotone in `Effort::Probes`;
//! * `MappedSearcher` and `RoutedSearcher` reproduce the seed
//!   pipeline/router behavior (same ids/scores) on a fixed-seed dataset.

use amips::api::{
    Effort, LinearQueryMap, MappedSearcher, QueryMode, RoutedSearcher, SearchRequest, Searcher,
};
use amips::coordinator::router::CentroidRouter;
use amips::index::ivf::IvfIndex;
use amips::index::{flat::FlatIndex, BuildCtx, IndexSpec, VectorIndex, BACKBONES};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::{prop_cases, test_rng};

fn unit(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    test_rng(seed).fill_normal(t.data_mut(), 1.0);
    normalize_rows(&mut t);
    t
}

const N: usize = 500;
const D: usize = 16;
const NQ: usize = 25;
const NLIST: usize = 8;

/// The canonical typed build path (what `build_backend` now shims to).
fn build(name: &str, keys: &Tensor, queries: Option<&Tensor>, seed: u64) -> Box<dyn VectorIndex> {
    IndexSpec::default_for(name)
        .unwrap()
        .with_nlist(NLIST)
        .build(
            keys,
            &BuildCtx {
                sample_queries: queries,
                seed,
            },
        )
        .unwrap()
}

/// A 3-shard wrapper over `name` with the same per-shard knobs — the
/// Searcher-API guarantees must hold for `ShardedIndex` over every leaf
/// backbone, not just for the leaves themselves.
fn build_sharded(
    name: &str,
    keys: &Tensor,
    queries: Option<&Tensor>,
    seed: u64,
) -> Box<dyn VectorIndex> {
    let inner = IndexSpec::default_for(name).unwrap().with_nlist(NLIST);
    let spec: IndexSpec = format!("sharded(shards=3,inner={inner})").parse().unwrap();
    spec.build(
        keys,
        &BuildCtx {
            sample_queries: queries,
            seed,
        },
    )
    .unwrap_or_else(|e| panic!("sharded({name}): {e:#}"))
}

/// Shared conformance assertions: exact top-1 at `Effort::Exhaustive`,
/// hit lists sorted descending, duplicate-free and in-bounds.
fn assert_matches_flat_at_max_effort(
    index: &dyn VectorIndex,
    label: &str,
    queries: &Tensor,
    truth: &amips::api::SearchResponse,
    req: &SearchRequest,
) {
    assert_eq!(index.num_keys(), N, "{label}");
    let resp = index.search(queries, req).unwrap();
    assert_eq!(resp.n_queries(), NQ, "{label}");
    for q in 0..NQ {
        assert_eq!(
            resp.hits[q].ids[0], truth.hits[q].ids[0],
            "{label}: top-1 mismatch on query {q}"
        );
        let (got, want) = (resp.hits[q].scores[0], truth.hits[q].scores[0]);
        assert!(
            (got - want).abs() < 1e-5,
            "{label}: top-1 score {got} vs flat {want} on query {q}"
        );
        // hit lists are sorted descending, duplicate-free and in-bounds
        for w in resp.hits[q].scores.windows(2) {
            assert!(w[0] >= w[1], "{label}");
        }
        assert!(
            resp.hits[q].ids.iter().all(|&id| (id as usize) < N),
            "{label}: out-of-bounds id on query {q}"
        );
        let mut ids = resp.hits[q].ids.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), resp.hits[q].ids.len(), "{label}");
    }
}

#[test]
fn every_backbone_matches_flat_top1_at_max_effort() {
    let keys = unit(&[N, D], 1);
    let queries = unit(&[NQ, D], 2);
    let flat = FlatIndex::new(keys.clone());
    let req = SearchRequest::top_k(3).effort(Effort::Exhaustive);
    let truth = flat.search(&queries, &req).unwrap();
    for name in BACKBONES {
        let index = build(name, &keys, Some(&queries), 42);
        assert_matches_flat_at_max_effort(index.as_ref(), name, &queries, &truth, &req);
    }
}

#[test]
fn every_sharded_backbone_matches_flat_top1_at_max_effort() {
    let keys = unit(&[N, D], 1);
    let queries = unit(&[NQ, D], 2);
    let flat = FlatIndex::new(keys.clone());
    let req = SearchRequest::top_k(3).effort(Effort::Exhaustive);
    let truth = flat.search(&queries, &req).unwrap();
    for name in BACKBONES {
        let index = build_sharded(name, &keys, Some(&queries), 42);
        let label = format!("sharded({name})");
        assert_matches_flat_at_max_effort(index.as_ref(), &label, &queries, &truth, &req);
    }
}

#[test]
fn batched_is_bit_identical_to_per_query_everywhere() {
    // The fused-kernel acceptance sweep: for every backbone — all eight,
    // i.e. the seven leaves plus sharded wrappers — across effort levels
    // and batch sizes, `search_batch_effort` must return bit-identical
    // ids, scores AND per-query SearchCost (flops, keys_scanned,
    // cells_probed) to one-at-a-time `search_effort`, and the threaded
    // `Searcher::search` path must agree with both. Case count scales
    // with AMIPS_PROP_CASES (each case re-seeds keys/queries and
    // rebuilds every backbone).
    let cases = prop_cases(1);
    let efforts = [
        Effort::Probes(1),
        Effort::Probes(2),
        Effort::Frac(0.4),
        Effort::Auto,
        Effort::Exhaustive,
    ];
    for case in 0..cases {
        let seed = 200 + case as u64 * 13;
        let keys = unit(&[N, D], seed);
        let queries = unit(&[NQ, D], seed + 1);
        let mut indexes: Vec<(String, Box<dyn VectorIndex>)> = Vec::new();
        for name in BACKBONES {
            indexes.push((name.to_string(), build(name, &keys, Some(&queries), seed + 2)));
            indexes.push((
                format!("sharded({name})"),
                build_sharded(name, &keys, Some(&queries), seed + 2),
            ));
        }
        for (label, index) in &indexes {
            for effort in efforts {
                for b in [1usize, 5, NQ] {
                    let qb = queries.gather_rows(&(0..b).collect::<Vec<_>>());
                    let batched = index.search_batch_effort(&qb, 4, effort);
                    assert_eq!(batched.len(), b, "case {case} {label}");
                    let req = SearchRequest::top_k(4).effort(effort);
                    let resp = index.search(&qb, &req).unwrap();
                    for q in 0..b {
                        let single = index.search_effort(qb.row(q), 4, effort);
                        let ctx = format!("case {case} {label} {effort:?} b={b} q{q}");
                        assert_eq!(batched[q].ids, single.ids, "{ctx}");
                        assert_eq!(batched[q].scores, single.scores, "{ctx}");
                        assert_eq!(batched[q].cost, single.cost, "{ctx}");
                        assert_eq!(resp.hits[q].ids, single.ids, "searcher {ctx}");
                        assert_eq!(resp.hits[q].scores, single.scores, "searcher {ctx}");
                    }
                }
            }
        }
    }
}

#[test]
fn sharded_batch_search_matches_sequential() {
    // the blanket Searcher impl must agree with one-at-a-time fan-out
    // on the composite backbone too (ids, scores and summed cost)
    let keys = unit(&[N, D], 21);
    let queries = unit(&[NQ, D], 22);
    let index = build_sharded("ivf", &keys, None, 23);
    for effort in [Effort::Probes(2), Effort::Auto, Effort::Exhaustive] {
        let req = SearchRequest::top_k(5).effort(effort);
        let resp = index.search(&queries, &req).unwrap();
        let mut total_scanned = 0u64;
        for q in 0..NQ {
            let single = index.search_effort(queries.row(q), 5, effort);
            assert_eq!(resp.hits[q].ids, single.ids, "{effort:?} q{q}");
            assert_eq!(resp.hits[q].scores, single.scores, "{effort:?} q{q}");
            total_scanned += single.cost.keys_scanned;
        }
        assert_eq!(resp.cost.keys_scanned, total_scanned, "{effort:?}");
    }
}

#[test]
fn cost_breakdown_monotone_in_probes() {
    let keys = unit(&[N, D], 3);
    let queries = unit(&[NQ, D], 4);
    for name in ["ivf", "scann", "soar", "leanvec"] {
        for (label, index) in [
            (name.to_string(), build(name, &keys, None, 43)),
            (
                format!("sharded({name})"),
                build_sharded(name, &keys, None, 43),
            ),
        ] {
            assert!(index.n_cells() > 1, "{label}");
            let mut prev: Option<amips::api::CostBreakdown> = None;
            for probes in 1..=NLIST {
                let req = SearchRequest::top_k(5).effort(Effort::Probes(probes));
                let resp = index.search(&queries, &req).unwrap();
                let cost = resp.cost;
                if let Some(p) = prev {
                    assert!(
                        cost.keys_scanned >= p.keys_scanned,
                        "{label}: keys_scanned dropped at probes={probes}"
                    );
                    assert!(
                        cost.cells_probed >= p.cells_probed,
                        "{label}: cells_probed dropped at probes={probes}"
                    );
                    assert!(
                        cost.scan_flops >= p.scan_flops,
                        "{label}: scan_flops dropped at probes={probes}"
                    );
                }
                prev = Some(cost);
            }
        }
    }
}

#[test]
fn effort_frac_and_auto_resolve_sensibly() {
    let keys = unit(&[N, D], 5);
    let queries = unit(&[4, D], 6);
    let index = build("ivf", &keys, None, 44);
    let full = index
        .search(&queries, &SearchRequest::top_k(2).effort(Effort::Frac(1.0)))
        .unwrap();
    assert_eq!(full.cost.cells_probed, (4 * NLIST) as u64);
    let half = index
        .search(&queries, &SearchRequest::top_k(2).effort(Effort::Frac(0.5)))
        .unwrap();
    assert_eq!(half.cost.cells_probed, (4 * NLIST / 2) as u64);
    let auto = index
        .search(&queries, &SearchRequest::top_k(2).effort(Effort::Auto))
        .unwrap();
    assert!(auto.cost.cells_probed >= 4);
}

#[test]
fn mapped_searcher_reproduces_seed_pipeline_semantics() {
    // Seed parity: MappedSearchPipeline::original was a passthrough, and
    // the mapped variant equaled map(queries) -> index scan. Both are
    // reproduced by MappedSearcher on a fixed-seed dataset.
    let keys = unit(&[N, D], 7);
    let queries = unit(&[NQ, D], 8);
    let ivf = IvfIndex::build(&keys, NLIST, 10, 9);
    let req = SearchRequest::top_k(5).effort(Effort::Probes(3));

    // passthrough == direct index search
    let map = LinearQueryMap::identity(D);
    let searcher = MappedSearcher::mapped(&ivf, &map);
    let direct = ivf.search(&queries, &req).unwrap();
    let passthrough = searcher.search(&queries, &req).unwrap();
    for q in 0..NQ {
        assert_eq!(passthrough.hits[q].ids, direct.hits[q].ids);
        assert_eq!(passthrough.hits[q].scores, direct.hits[q].scores);
    }

    // mapped == manually mapping the batch, then searching
    let mut w = Tensor::zeros(&[D, D]);
    let mut rng = test_rng(10);
    rng.fill_normal(w.data_mut(), 0.3);
    let map = LinearQueryMap::new("rand", w);
    let searcher = MappedSearcher::mapped(&ivf, &map);
    use amips::api::QueryMap;
    let manual_q = map.map(&queries).unwrap();
    let manual = ivf.search(&manual_q, &req).unwrap();
    let mapped = searcher.search(&queries, &req.mode(QueryMode::Mapped)).unwrap();
    for q in 0..NQ {
        assert_eq!(mapped.hits[q].ids, manual.hits[q].ids, "query {q}");
        assert_eq!(mapped.hits[q].scores, manual.hits[q].scores);
    }
    // the map stage is billed
    assert_eq!(
        mapped.cost.map_flops,
        map.map_flops_per_query() * NQ as u64
    );
    assert_eq!(manual.cost.map_flops, 0);
}

#[test]
fn keynet_query_map_conforms_on_every_backbone() {
    // The learned map must honor the same MappedSearcher contract as
    // LinearQueryMap on every leaf backbone: passthrough in Original
    // mode, mapped == map(queries) -> index scan, model flops billed.
    // (Training quality is covered by learned_e2e.rs; an initialized
    // model exercises the contract at zero training cost.)
    use amips::api::{KeyNetQueryMap, QueryMap};
    use amips::model::RustModel;
    use amips::nn::{ModelKind, NetSpec};

    let keys = unit(&[N, D], 30);
    let queries = unit(&[NQ, D], 31);
    let model =
        RustModel::init("conf.keynet", NetSpec::new(ModelKind::KeyNet, D, 1, 12, 2), 32).unwrap();
    let map = KeyNetQueryMap::new(model).unwrap();
    let manual_q = map.map(&queries).unwrap();
    let req = SearchRequest::top_k(5).effort(Effort::Exhaustive);
    for name in BACKBONES {
        let index = build(name, &keys, Some(&queries), 33);
        let searcher = MappedSearcher::mapped(index.as_ref(), &map);
        let direct = index.search(&queries, &req).unwrap();
        let passthrough = searcher.search(&queries, &req).unwrap();
        let mapped = searcher
            .search(&queries, &req.mode(QueryMode::Mapped))
            .unwrap();
        let manual = index.search(&manual_q, &req).unwrap();
        for q in 0..NQ {
            assert_eq!(passthrough.hits[q].ids, direct.hits[q].ids, "{name} q{q}");
            assert_eq!(mapped.hits[q].ids, manual.hits[q].ids, "{name} q{q}");
            assert_eq!(mapped.hits[q].scores, manual.hits[q].scores, "{name} q{q}");
        }
        assert_eq!(
            mapped.cost.map_flops,
            map.map_flops_per_query() * NQ as u64,
            "{name}"
        );
        assert_eq!(passthrough.cost.map_flops, 0, "{name}");
        assert!(searcher.label().contains("conf.keynet"), "{name}");
    }
}

#[test]
fn routed_searcher_reproduces_centroid_routing() {
    // Seed parity: the centroid router over the index's own centroids is
    // exactly IVF's coarse ranking, so routed search == plain IVF search
    // (same ids and scores) at every probe level.
    let keys = unit(&[N, D], 11);
    let queries = unit(&[NQ, D], 12);
    let ivf = IvfIndex::build(&keys, NLIST, 10, 13);
    let router = CentroidRouter::new(ivf.centroids().clone());
    let routed = RoutedSearcher::new(&router, &ivf).unwrap();
    for probes in 1..=NLIST {
        let req = SearchRequest::top_k(4).effort(Effort::Probes(probes));
        let via_router = routed.search(&queries, &req.mode(QueryMode::Routed)).unwrap();
        let plain = ivf.search(&queries, &req).unwrap();
        for q in 0..NQ {
            assert_eq!(
                via_router.hits[q].ids, plain.hits[q].ids,
                "probes {probes} query {q}"
            );
            assert_eq!(via_router.hits[q].scores, plain.hits[q].scores);
        }
        assert_eq!(via_router.cost.keys_scanned, plain.cost.keys_scanned);
        // selection cost is split into the route stage
        assert_eq!(
            via_router.cost.route_flops,
            (NQ * NLIST * D * 2) as u64,
            "probes {probes}"
        );
    }
}

#[test]
fn searcher_trait_objects_compose() {
    // Box<dyn VectorIndex> and wrapper searchers share one call site.
    let keys = unit(&[N, D], 14);
    let queries = unit(&[6, D], 15);
    let req = SearchRequest::top_k(3).effort(Effort::Exhaustive);
    let index = build("ivf", &keys, None, 45);
    let map = LinearQueryMap::identity(D);
    let wrapper = MappedSearcher::mapped(index.as_ref(), &map);
    let searchers: Vec<&dyn Searcher> = vec![&wrapper];
    for s in searchers {
        let resp = s.search(&queries, &req).unwrap();
        assert_eq!(resp.n_queries(), 6);
        assert!(s.label().contains("ivf"));
        assert_eq!(s.num_keys(), N);
    }
    // search_one mirrors the batch path
    let one = index
        .search_one(queries.row(0), &req)
        .unwrap();
    let batch = index.search(&queries, &req).unwrap();
    assert_eq!(one.hits[0].ids, batch.hits[0].ids);
}

// ---------------------------------------------------------------------------
// Compact key storage (storage=f16 / bits=4): tolerance-tiered conformance
// ---------------------------------------------------------------------------

fn build_spec(spec: &str, keys: &Tensor, queries: &Tensor, seed: u64) -> Box<dyn VectorIndex> {
    spec.parse::<IndexSpec>()
        .unwrap_or_else(|e| panic!("{spec}: {e:#}"))
        .build(
            keys,
            &BuildCtx {
                sample_queries: Some(queries),
                seed,
            },
        )
        .unwrap_or_else(|e| panic!("{spec}: {e:#}"))
}

#[test]
fn four_bit_pq_variants_stay_exact_at_max_effort() {
    // Exact tier of the tolerance contract: 4-bit codes only steer the
    // candidate pass; Effort::Exhaustive re-ranks every candidate
    // against the exact f32 keys, so the f32 flat truth must still be
    // matched exactly.
    let keys = unit(&[N, D], 60);
    let queries = unit(&[NQ, D], 61);
    let req = SearchRequest::top_k(3).effort(Effort::Exhaustive);
    let truth = FlatIndex::new(keys.clone()).search(&queries, &req).unwrap();
    for spec in ["pq(bits=4)".to_string(), format!("scann(nlist={NLIST},bits=4)")] {
        let index = build_spec(&spec, &keys, &queries, 62);
        assert!(index.spec().to_string().contains("bits=4"), "{spec}");
        assert_matches_flat_at_max_effort(index.as_ref(), &spec, &queries, &truth, &req);
    }
}

#[test]
fn f16_storage_variants_agree_with_f16_flat_truth() {
    // Tolerance tier: f16 storage rounds each key element once, so the
    // ground truth for id agreement is the f16 flat scan itself (same
    // rounded keys, exhaustive), while scores must sit inside the
    // binary16 rounding envelope of the f32 truth. Exact id-set
    // agreement at Exhaustive is still required — just against the
    // storage-matched truth.
    let keys = unit(&[N, D], 63);
    let queries = unit(&[NQ, D], 64);
    let req = SearchRequest::top_k(3).effort(Effort::Exhaustive);
    let f32_truth = FlatIndex::new(keys.clone()).search(&queries, &req).unwrap();
    let f16_flat = build_spec("flat(storage=f16)", &keys, &queries, 65);
    let f16_truth = f16_flat.search(&queries, &req).unwrap();
    // unit vectors, d=16: per-score f16 rounding error is bounded by
    // ||q||·||k||·2^-11 ≈ 5e-4; 1e-2 leaves a wide margin
    for q in 0..NQ {
        for (got, want) in f16_truth.hits[q].scores.iter().zip(&f32_truth.hits[q].scores) {
            assert!(
                (got - want).abs() <= 1e-2 * (1.0 + want.abs()),
                "flat(storage=f16) q{q}: {got} vs f32 {want}"
            );
        }
    }
    let lv = build_spec(
        &format!("leanvec(nlist={NLIST},storage=f16)"),
        &keys,
        &queries,
        66,
    );
    let resp = lv.search(&queries, &req).unwrap();
    for q in 0..NQ {
        let mut a = resp.hits[q].ids.clone();
        let mut b = f16_truth.hits[q].ids.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "leanvec(storage=f16) q{q}: id set vs f16 flat truth");
        for (got, want) in resp.hits[q].scores.iter().zip(&f32_truth.hits[q].scores) {
            assert!(
                (got - want).abs() <= 1e-2 * (1.0 + want.abs()),
                "leanvec(storage=f16) q{q}: {got} vs f32 {want}"
            );
        }
    }
}

#[test]
fn compact_storage_batched_is_bit_identical_to_per_query() {
    // The PR 5 fused-path contract extends to every compact-storage
    // variant: same dispatched kernel per (query, key) pair on both
    // paths, so ids, scores and costs match bitwise.
    let keys = unit(&[N, D], 67);
    let queries = unit(&[NQ, D], 68);
    let specs = [
        "flat(storage=f16)".to_string(),
        "pq(bits=4)".to_string(),
        format!("scann(nlist={NLIST},bits=4)"),
        format!("leanvec(nlist={NLIST},storage=f16)"),
    ];
    for spec in &specs {
        let index = build_spec(spec, &keys, &queries, 69);
        for effort in [Effort::Probes(2), Effort::Auto, Effort::Exhaustive] {
            for b in [1usize, 5, NQ] {
                let qb = queries.gather_rows(&(0..b).collect::<Vec<_>>());
                let batched = index.search_batch_effort(&qb, 4, effort);
                for q in 0..b {
                    let single = index.search_effort(qb.row(q), 4, effort);
                    let ctx = format!("{spec} {effort:?} b={b} q{q}");
                    assert_eq!(batched[q].ids, single.ids, "{ctx}");
                    assert_eq!(batched[q].scores, single.scores, "{ctx}");
                    assert_eq!(batched[q].cost, single.cost, "{ctx}");
                }
            }
        }
    }
}
