//! Minimal, dependency-free stand-in for the `anyhow` crate, vendored so
//! the workspace builds with zero network access (this environment has no
//! crates.io mirror). It implements the subset the repo uses:
//!
//! * [`Error`] / [`Result`] with `?`-conversion from any
//!   `std::error::Error + Send + Sync + 'static`
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros
//! * the [`Context`] extension trait (`.context(..)` / `.with_context(..)`)
//!   on both `Result` and `Option`
//!
//! Errors are stored as a message plus a context chain; `{:#}` prints the
//! chain joined by `: ` like real anyhow's alternate Display.

use std::fmt;

/// An error message with a chain of added context, newest first.
pub struct Error {
    /// context[0] is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse()?; // From<ParseIntError>
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_chains_and_formats() {
        let e = parse("x").context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
    }

    #[test]
    fn macros() {
        fn f(flag: bool) -> Result<u8> {
            ensure!(flag, "flag was {}", flag);
            bail!("unreachable {}", 1);
        }
        assert!(f(false).is_err());
        assert!(f(true).is_err());
        let e: Error = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
