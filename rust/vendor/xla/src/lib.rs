//! Compile-only stub of the `xla` (xla-rs) PJRT bindings.
//!
//! This vendored crate exists so `cargo build --features xla` type-checks
//! in environments without an XLA/PJRT installation: it mirrors exactly
//! the API surface `amips` uses and returns a descriptive error from
//! every entry point that would touch the real runtime. To execute the
//! AOT artifacts for real, point the `xla` dependency at an xla-rs
//! checkout, e.g. in the workspace `Cargo.toml`:
//!
//! ```toml
//! [patch."crates-io"]            # or replace the path dependency
//! xla = { path = "/path/to/xla-rs" }
//! ```

use std::fmt;

/// Error returned by every stubbed runtime entry point.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "XLA runtime unavailable: {what} called on the vendored compile-only \
         stub; patch the `xla` dependency to a real xla-rs checkout to run \
         PJRT (see rust/vendor/xla/src/lib.rs)"
    )))
}

/// Element dtypes of the literals amips builds (f32 tensors, u32 seeds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U32,
}

/// Host-side typed buffer.
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Device-side buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (CPU in this repo).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (text interchange).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("XLA runtime unavailable"));
    }
}
