//! §Perf: cold-open cost of the serving path — the time from "artifact
//! on disk, nothing decoded" to a ready index, and the resident memory
//! that readiness costs, as the corpus grows. With the aligned v3
//! layout under `--features mmap`, open is O(headers): the key matrix
//! stays in the page cache and faults in on first search, so the
//! cold-open row should be flat in `n` while the decode-into-RAM build
//! (default features) grows linearly. The first-query row then pays the
//! page-fault bill exactly once.
//!
//! Rows land in `BENCH_startup.json` (modes `cold_open` / `first_query`
//! / `warm_query`); CI merges them into the uploaded
//! `BENCH_hotpath.json` via `scripts/bench_merge.py`. They carry no
//! `gflops` field value, so `scripts/bench_gate.py` skips them — these
//! are trajectory rows, not gated ones.
//!
//! Corpus sizes scale with `AMIPS_STARTUP_NS` (comma-separated, default
//! `2000,8000,32000`) and `AMIPS_BENCH_D` (default 32).

use amips::api::Effort;
use amips::bench_support::fixtures;
use amips::bench_support::report::{JsonRows, JsonVal, Report};
use amips::index::{BuildCtx, Catalog, IndexSpec};
use amips::util::timer::{time_reps, Stats};
use amips::util::TempDir;
use anyhow::Result;
use std::hint::black_box;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_ns() -> Vec<usize> {
    std::env::var("AMIPS_STARTUP_NS")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .filter(|ns: &Vec<usize>| !ns.is_empty())
        .unwrap_or_else(|| vec![2000, 8000, 32000])
}

/// (VmRSS, VmHWM) in KiB from /proc/self/status — 0 off linux, where
/// the RSS columns are merely absent from the trajectory.
fn rss_kb() -> (u64, u64) {
    #[cfg(target_os = "linux")]
    {
        if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
            let grab = |key: &str| {
                s.lines()
                    .find(|l| l.starts_with(key))
                    .and_then(|l| l.split_whitespace().nth(1))
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(0)
            };
            return (grab("VmRSS:"), grab("VmHWM:"));
        }
    }
    (0, 0)
}

#[allow(clippy::too_many_arguments)]
fn push_row(
    json: &mut JsonRows,
    mode: &str,
    n: usize,
    d: usize,
    t: &Stats,
    rss_kb_now: u64,
    rss_kb_delta: u64,
) {
    json.push(&[
        ("backend", JsonVal::S("flat".into())),
        ("mode", JsonVal::S(mode.into())),
        ("kernel", JsonVal::S("-".into())),
        ("batch", JsonVal::I(1)),
        ("n", JsonVal::I(n as u64)),
        ("d", JsonVal::I(d as u64)),
        ("mean_s", JsonVal::F(t.mean)),
        ("p95_s", JsonVal::F(t.p95)),
        // no throughput: the gate keys on finite positive gflops, so
        // these rows ride along ungated
        ("gflops", JsonVal::F(f64::NAN)),
        ("qps", JsonVal::F(1.0 / t.mean)),
        ("rss_kb", JsonVal::I(rss_kb_now)),
        ("rss_delta_kb", JsonVal::I(rss_kb_delta)),
    ]);
}

fn main() -> Result<()> {
    let ns = env_ns();
    let d = env_usize("AMIPS_BENCH_D", 32);
    let mapped = cfg!(feature = "mmap");

    let mut rep = Report::new("§Perf: cold-open time + resident memory vs corpus size");
    rep.header(&["corpus", "phase", "mean", "p95", "RSS", "ΔRSS"]);
    let mut json = JsonRows::new("startup");

    let mut open_means = Vec::new();
    for &n in &ns {
        let tmp = TempDir::new("amips-startup");
        let root = tmp.join("catalog");
        {
            let keys = fixtures::synth_keys(n, d, 42);
            let spec: IndexSpec = "flat".parse()?;
            let mut catalog = Catalog::create(&root)?;
            catalog.build_collection("docs", &spec, &keys, &BuildCtx::seeded(7))?;
        } // builder state dropped: only the on-disk artifact survives

        // cold open, repeated: each rep re-opens from the path and drops
        // the entry. The page cache is warm (we just wrote the file) —
        // what's measured is decode work, the thing the zero-copy layout
        // removes.
        let (rss0, _) = rss_kb();
        let reps = 10;
        let open = Stats::from(&time_reps(1, reps, || {
            black_box(Catalog::open_collection(&root, "docs").unwrap());
        }));
        let (rss_open, _) = rss_kb();
        open_means.push(open.mean);

        // hold one open entry and pay the first (faulting) query, then a
        // warm one
        let entry = Catalog::open_collection(&root, "docs")?;
        let query = fixtures::synth_keys(1, d, 9);
        let first = Stats::from(&time_reps(1, 1, || {
            black_box(entry.index.search_effort(query.row(0), 10, Effort::Exhaustive));
        }));
        let (rss_first, hwm) = rss_kb();
        let warm = Stats::from(&time_reps(1, 5, || {
            black_box(entry.index.search_effort(query.row(0), 10, Effort::Exhaustive));
        }));

        let fmt_ms = |t: &Stats| format!("{:.3} ms", t.mean * 1e3);
        let fmt_p95 = |t: &Stats| format!("{:.3} ms", t.p95 * 1e3);
        for (phase, t, rss, delta) in [
            ("cold_open", &open, rss_open, rss_open.saturating_sub(rss0)),
            ("first_query", &first, rss_first, rss_first.saturating_sub(rss_open)),
            ("warm_query", &warm, rss_first, 0),
        ] {
            rep.row(&[
                format!("{n}x{d}"),
                phase.to_string(),
                fmt_ms(t),
                fmt_p95(t),
                format!("{} KiB", rss),
                format!("{} KiB", delta),
            ]);
            push_row(&mut json, phase, n, d, t, rss, delta);
        }
        let _ = hwm; // VmHWM is process-wide; the per-size delta is the signal
    }

    if let (Some(first), Some(last)) = (open_means.first(), open_means.last()) {
        let ratio = last / first.max(1e-9);
        rep.note(format!(
            "cold-open scaling: {:.2}x from n={} to n={} (mapped={mapped}; \
             a zero-copy open should stay near 1x, a decode-into-RAM open \
             grows with the corpus)",
            ratio,
            ns.first().unwrap(),
            ns.last().unwrap(),
        ));
    }
    rep.note(
        "AMIPS_STARTUP_NS / AMIPS_BENCH_D to rescale; RSS columns read \
         /proc/self/status (0 off linux)"
            .to_string(),
    );
    rep.emit("bench_startup");
    json.emit();
    Ok(())
}
