//! Fig. 28 (App. A.9): database-scale study on bioasq-s (the largest
//! corpus, 2x hotpot-s / 4x nq-s here; 15M keys in the paper). XS KeyNet
//! + FAISS-IVF-analog, all three cost axes.

use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::coordinator::pipeline::{recall_against_truth, MappedSearchPipeline};
use amips::index::ivf::IvfIndex;
use amips::runtime::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let ds = fixtures::prepare_dataset(&manifest, "bioasq-s", 1)?;
    let config = "bioasq-s.keynet.xs.l4.c1";
    let model = fixtures::trained_model(&engine, &manifest, config, &ds, None)?;
    let nlist = fixtures::default_nlist(ds.n_keys());
    let index = IvfIndex::build(&ds.keys, nlist, 12, 42);
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();
    let k = (ds.n_keys() / 40).max(10);

    let mut rep = Report::new(&format!(
        "Fig 28: scale study on bioasq-s (n={}, nlist={nlist}, Recall@2.5%={k})",
        ds.n_keys()
    ));
    rep.header(&["variant", "nprobe", "recall", "MFLOP/q", "ms/q"]);
    let nq = ds.val.x.rows() as f64;
    for nprobe in [1usize, 2, 4, 8, 16] {
        for mapped in [false, true] {
            let pipe = if mapped {
                MappedSearchPipeline::mapped(&index, &model)
            } else {
                MappedSearchPipeline::original(&index)
            };
            let out = pipe.run(&ds.val.x, k, nprobe)?;
            rep.row(&[
                pipe.label().to_string(),
                nprobe.to_string(),
                pct(recall_against_truth(&out.results, &truth, k)),
                format!(
                    "{:.3}",
                    (out.results[0].cost.flops + out.map_flops_per_query) as f64 / 1e6
                ),
                format!("{:.3}", ((out.map_seconds + out.search_seconds) / nq) * 1e3),
            ]);
        }
    }
    rep.note("paper shape: the relative orig/mapped gap does not collapse at the largest scale; absolute recall shifts down with the larger pool");
    rep.emit("fig28_scale");
    Ok(())
}
