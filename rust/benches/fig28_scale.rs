//! Fig. 28 (App. A.9): database-scale study on bioasq-s (the largest
//! corpus, 2x hotpot-s / 4x nq-s here; 15M keys in the paper). XS KeyNet
//! + FAISS-IVF-analog, all three cost axes.

use amips::api::{recall_against_truth, Effort, MappedSearcher, QueryMode, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::index::ivf::IvfIndex;
use amips::runtime::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let ds = fixtures::prepare_dataset(&manifest, "bioasq-s", 1)?;
    let config = "bioasq-s.keynet.xs.l4.c1";
    let model = fixtures::trained_model(&engine, &manifest, config, &ds, None)?;
    let nlist = fixtures::default_nlist(ds.n_keys());
    let index = IvfIndex::build(&ds.keys, nlist, 12, 42);
    let searcher = MappedSearcher::mapped(&index, &model);
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();
    let k = (ds.n_keys() / 40).max(10);

    let mut rep = Report::new(&format!(
        "Fig 28: scale study on bioasq-s (n={}, nlist={nlist}, Recall@2.5%={k})",
        ds.n_keys()
    ));
    rep.header(&["variant", "nprobe", "recall", "MFLOP/q", "ms/q"]);
    for nprobe in [1usize, 2, 4, 8, 16] {
        for mode in [QueryMode::Original, QueryMode::Mapped] {
            let req = SearchRequest::top_k(k)
                .effort(Effort::Probes(nprobe))
                .mode(mode);
            let out = searcher.search(&ds.val.x, &req)?;
            rep.row(&[
                if mode == QueryMode::Mapped {
                    "mapped".to_string()
                } else {
                    "orig".to_string()
                },
                nprobe.to_string(),
                pct(recall_against_truth(&out.hits, &truth, k)),
                format!("{:.3}", out.flops_per_query() / 1e6),
                format!("{:.3}", out.seconds_per_query() * 1e3),
            ]);
        }
    }
    rep.note("paper shape: the relative orig/mapped gap does not collapse at the largest scale; absolute recall shifts down with the larger pool");
    rep.emit("fig28_scale");
    Ok(())
}
