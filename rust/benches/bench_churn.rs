//! §Churn: open-loop load generator for *mutable* collections over the
//! TCP front-end. Poisson arrivals mix searches, inserts and deletes
//! across several blocking [`NetClient`] connections while a background
//! thread issues periodic `Compact` frames, so generation swaps happen
//! under live traffic. Two correctness gates ride along with the
//! latency numbers:
//!
//!   * zero tombstone violations — a search must never return an id the
//!     same client has already seen acknowledged as deleted (ids are
//!     never reused, so any reappearance is a masking bug);
//!   * at least one search must succeed (an all-error run is a failed
//!     deployment, not an empty report).
//!
//! Reports per-op counts + search latency quantiles and emits
//! machine-readable `BENCH_churn.json`.
//!
//! Knobs (env):
//!   AMIPS_CHURN_ADDR        target a running `amips serve --listen`
//!                           server instead of the in-process default
//!   AMIPS_CHURN_COLLECTION  collection name (default "docs")
//!   AMIPS_CHURN_N/_D        initial corpus size (default 4096 x 32)
//!   AMIPS_CHURN_OPS         offered load, ops/s (default 1500)
//!   AMIPS_CHURN_SECONDS     run length (default 3)
//!   AMIPS_CHURN_CLIENTS     connections (default 4)
//!   AMIPS_CHURN_COMPACT_MS  explicit compact period (default 500)

use amips::api::Effort;
use amips::bench_support::fixtures;
use amips::bench_support::report::{JsonRows, JsonVal, Report};
use amips::coordinator::net::{NetClient, NetServer, NetServerConfig, SearchOptions};
use amips::index::{IndexSpec, MutableCollection};
use amips::tensor::{normalize_rows, Tensor};
use amips::util::{Rng, TempDir};
use anyhow::Result;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Exact quantile over a sorted sample (nearest-rank).
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((q * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
    sorted[i]
}

#[derive(Default)]
struct ClientOutcome {
    search_latencies_s: Vec<f64>,
    searches_ok: usize,
    inserts_ok: usize,
    deletes_ok: usize,
    rows_inserted: usize,
    retryable: usize,
    other_errors: usize,
    violations: usize,
}

fn main() -> Result<()> {
    let external_addr = std::env::var("AMIPS_CHURN_ADDR").ok();
    let collection =
        std::env::var("AMIPS_CHURN_COLLECTION").unwrap_or_else(|_| "docs".to_string());
    let n = env_usize("AMIPS_CHURN_N", 4096);
    let d = env_usize("AMIPS_CHURN_D", 32);
    let ops = env_f64("AMIPS_CHURN_OPS", 1500.0).max(1.0);
    let seconds = env_f64("AMIPS_CHURN_SECONDS", 3.0).max(0.1);
    let clients = env_usize("AMIPS_CHURN_CLIENTS", 4).max(1);
    let compact_ms = env_usize("AMIPS_CHURN_COMPACT_MS", 500).max(1);
    let seed = 0xC4u64;

    // in-process default: one mutable collection seeded with the shared
    // synthetic corpus, served by the same NetServer the CLI uses (its
    // tenant worker handles searches, the mutable map handles writes)
    let _tmp; // keeps the collection directory alive for the run
    let (server, addr) = match &external_addr {
        Some(a) => {
            _tmp = None::<TempDir>;
            (None, a.clone())
        }
        None => {
            let tmp = TempDir::new("amips-churn");
            let dir = tmp.join("c.seg");
            let spec = IndexSpec::default_for("ivf")?.with_nlist(fixtures::default_nlist(n));
            let coll = Arc::new(MutableCollection::create(&dir, spec, d, seed)?);
            coll.insert(&fixtures::synth_keys(n, d, seed))?;
            coll.commit()?;
            let tenant = amips::coordinator::net::Tenant::start(
                &collection,
                coll.clone() as Arc<dyn amips::index::VectorIndex>,
                None,
                amips::coordinator::BatchPolicy::default(),
                1024,
            )?;
            let mut tenants = std::collections::BTreeMap::new();
            tenants.insert(collection.clone(), tenant);
            let mut mutables = std::collections::BTreeMap::new();
            mutables.insert(collection.clone(), coll);
            let server = NetServer::serve_mutable(
                tenants,
                mutables,
                "127.0.0.1:0",
                NetServerConfig::default(),
            )?;
            let addr = server.local_addr().to_string();
            _tmp = Some(tmp);
            (Some(server), addr)
        }
    };

    // unit-norm gaussian query pool + per-client insert material
    let n_queries = 256usize;
    let mut pool = Tensor::zeros(&[n_queries, d]);
    Rng::new(seed ^ 1).fill_normal(pool.data_mut(), 1.0);
    normalize_rows(&mut pool);

    // Poisson arrival schedule shared by all op kinds; client c takes
    // arrivals c, c+C, ... (thinned Poisson stays Poisson)
    let total = ((ops * seconds).round() as usize).max(1);
    let mut arrivals = Vec::with_capacity(total);
    {
        let mut rng = Rng::new(seed ^ 2);
        let mut t = 0.0f64;
        for _ in 0..total {
            t += -(1.0 - rng.uniform()).ln() / ops;
            arrivals.push(t);
        }
    }
    let opts = SearchOptions::top_k(10).effort(Effort::Exhaustive);

    println!(
        "bench_churn: {total} mixed ops at {ops:.0} ops/s over {clients} connections -> {addr} (compact every {compact_ms}ms)"
    );
    let t0 = Instant::now();
    let stop_compactor = Arc::new(AtomicBool::new(false));
    let compactor = {
        let (addr, collection, stop) = (addr.clone(), collection.clone(), stop_compactor.clone());
        std::thread::spawn(move || -> (usize, usize) {
            let Ok(mut client) = NetClient::connect(addr.as_str()) else {
                return (0, 1);
            };
            client.set_timeout(Some(Duration::from_secs(60))).ok();
            let (mut passes, mut failures) = (0usize, 0usize);
            while !stop.load(Ordering::Acquire) {
                std::thread::sleep(Duration::from_millis(compact_ms as u64));
                if stop.load(Ordering::Acquire) {
                    break;
                }
                match client.compact(&collection) {
                    Ok(_) => passes += 1,
                    Err(_) => failures += 1,
                }
            }
            (passes, failures)
        })
    };

    let outcomes: Vec<ClientOutcome> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for c in 0..clients {
            let (addr, collection, arrivals, pool) = (&addr, &collection, &arrivals, &pool);
            joins.push(s.spawn(move || -> Result<ClientOutcome> {
                let mut client = NetClient::connect(addr.as_str())?;
                client.set_timeout(Some(Duration::from_secs(30)))?;
                let mut rng = Rng::new(seed ^ (0x10 + c as u64));
                let mut out = ClientOutcome::default();
                // ids this client inserted and still believes live /
                // has seen acknowledged as deleted
                let mut own_live: Vec<u32> = Vec::new();
                let mut own_deleted: HashSet<u32> = HashSet::new();
                for i in (c..arrivals.len()).step_by(clients) {
                    let scheduled = t0 + Duration::from_secs_f64(arrivals[i]);
                    let now = Instant::now();
                    if scheduled > now {
                        std::thread::sleep(scheduled - now);
                    }
                    // 60% search / 25% insert / 15% delete
                    let dice = rng.below(20);
                    if dice < 12 {
                        let q = pool.row(i % pool.rows());
                        match client.search(collection, q, opts) {
                            Ok(hits) => {
                                out.searches_ok += 1;
                                out.search_latencies_s
                                    .push(scheduled.elapsed().as_secs_f64());
                                // the correctness gate: a deleted id in
                                // the results is a tombstone-masking bug
                                for id in &hits.ids {
                                    if own_deleted.contains(id) {
                                        out.violations += 1;
                                    }
                                }
                            }
                            Err(e) if e.is_retryable() => out.retryable += 1,
                            Err(_) => out.other_errors += 1,
                        }
                    } else if dice < 17 || own_live.is_empty() {
                        let rows = 1 + rng.below(4);
                        let mut vecs = Tensor::zeros(&[rows, d]);
                        rng.fill_normal(vecs.data_mut(), 1.0);
                        normalize_rows(&mut vecs);
                        match client.insert(collection, &vecs) {
                            Ok(m) => {
                                out.inserts_ok += 1;
                                out.rows_inserted += m.ids.len();
                                own_live.extend(m.ids);
                            }
                            Err(e) if e.is_retryable() => out.retryable += 1,
                            Err(_) => out.other_errors += 1,
                        }
                    } else {
                        let take = (1 + rng.below(3)).min(own_live.len());
                        let ids: Vec<u32> =
                            (0..take).map(|_| own_live.swap_remove(rng.below(own_live.len()))).collect();
                        match client.delete(collection, &ids) {
                            Ok(_) => {
                                out.deletes_ok += 1;
                                own_deleted.extend(ids);
                            }
                            Err(e) if e.is_retryable() => out.retryable += 1,
                            // on failure the delete may or may not have
                            // landed server-side, so the ids go to
                            // neither set: not live (already removed),
                            // not deleted (can't claim a violation)
                            Err(_) => out.other_errors += 1,
                        }
                    }
                }
                Ok(out)
            }));
        }
        joins
            .into_iter()
            .map(|j| j.join().expect("client thread panicked"))
            .collect::<Result<Vec<_>>>()
    })?;
    let wall = t0.elapsed().as_secs_f64();
    stop_compactor.store(true, Ordering::Release);
    let (compact_passes, compact_failures) = compactor.join().expect("compactor thread panicked");

    let mut latencies: Vec<f64> = Vec::new();
    let mut sum = ClientOutcome::default();
    for o in outcomes {
        latencies.extend(o.search_latencies_s);
        sum.searches_ok += o.searches_ok;
        sum.inserts_ok += o.inserts_ok;
        sum.deletes_ok += o.deletes_ok;
        sum.rows_inserted += o.rows_inserted;
        sum.retryable += o.retryable;
        sum.other_errors += o.other_errors;
        sum.violations += o.violations;
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99, p999) = (
        quantile(&latencies, 0.5),
        quantile(&latencies, 0.99),
        quantile(&latencies, 0.999),
    );
    let achieved = (sum.searches_ok + sum.inserts_ok + sum.deletes_ok) as f64 / wall;

    let mut rep = Report::new(&format!(
        "bench_churn: open-loop Poisson {ops:.0} ops/s x {seconds}s, {clients} conns ({collection})"
    ));
    rep.header(&[
        "searches",
        "inserts",
        "deletes",
        "compacts",
        "violations",
        "retry",
        "errors",
        "p50 ms",
        "p99 ms",
    ]);
    rep.row(&[
        sum.searches_ok.to_string(),
        format!("{} ({} rows)", sum.inserts_ok, sum.rows_inserted),
        sum.deletes_ok.to_string(),
        compact_passes.to_string(),
        sum.violations.to_string(),
        sum.retryable.to_string(),
        (sum.other_errors + compact_failures).to_string(),
        format!("{:.2}", p50 * 1e3),
        format!("{:.2}", p99 * 1e3),
    ]);
    rep.note("violations = acknowledged-deleted ids that reappeared in search results (must be 0)");
    rep.note("search latency measured from the scheduled Poisson arrival (open-loop)");
    rep.emit("bench_churn");

    let mut json = JsonRows::new("churn");
    json.push(&[
        ("row", JsonVal::S("summary".into())),
        ("ops_target", JsonVal::F(ops)),
        ("ops_achieved", JsonVal::F(achieved)),
        ("searches_ok", JsonVal::I(sum.searches_ok as u64)),
        ("inserts_ok", JsonVal::I(sum.inserts_ok as u64)),
        ("rows_inserted", JsonVal::I(sum.rows_inserted as u64)),
        ("deletes_ok", JsonVal::I(sum.deletes_ok as u64)),
        ("compact_passes", JsonVal::I(compact_passes as u64)),
        ("violations", JsonVal::I(sum.violations as u64)),
        ("retryable", JsonVal::I(sum.retryable as u64)),
        ("errors", JsonVal::I((sum.other_errors + compact_failures) as u64)),
        ("clients", JsonVal::I(clients as u64)),
    ]);
    for (name, v) in [("p50", p50), ("p99", p99), ("p999", p999)] {
        json.push(&[
            ("row", JsonVal::S("quantile".into())),
            ("quantile", JsonVal::S(name.into())),
            ("search_latency_ms", JsonVal::F(v * 1e3)),
        ]);
    }
    json.emit();

    if let Some(server) = server {
        server.shutdown();
    }
    if sum.searches_ok == 0 {
        eprintln!("bench_churn: no search succeeded");
        std::process::exit(1);
    }
    if sum.violations > 0 {
        eprintln!(
            "bench_churn: {} tombstoned ids reappeared in search results",
            sum.violations
        );
        std::process::exit(1);
    }
    Ok(())
}
