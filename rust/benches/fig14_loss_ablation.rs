//! Fig. 14 (App. A.6): loss-weight ablation on nq-s — grads-only,
//! scores-only, and the combined default, at two peak LRs, for both
//! model families. Because the lambdas are *runtime inputs* to the AOT
//! train step, no artifact is re-exported.
//!
//! Reported per run: final gradient/key error vs score error — the two
//! axes of the paper's scatter.

use amips::bench_support::fixtures;
use amips::bench_support::report::Report;
use amips::runtime::Engine;
use amips::trainer::{self, TrainOpts};
use anyhow::Result;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();
    let ds = fixtures::prepare_dataset(&manifest, "nq-s", 1)?;
    let steps = if quick { 500 } else { 2000 };

    // (label, lam_a = score/consist weight, lam_b = grad/key weight)
    let configs_loss: &[(&str, f32, f32)] = &[
        ("grads-only", 0.0, 1.0),
        ("scores-only", 0.01, 0.0),
        ("combined", 0.01, 1.0),
    ];
    let lrs: &[f32] = if quick { &[1e-2] } else { &[3e-3, 1e-2] };

    let mut rep = Report::new("Fig 14: loss-weight ablation on nq-s (final val errors)");
    rep.header(&["model", "loss config", "peak lr", "key/grad mse", "score mse"]);
    for mdl in ["supportnet", "keynet"] {
        let config = format!("nq-s.{mdl}.s.l4.c1");
        let meta = manifest.meta(&config)?;
        for (label, la, lb) in configs_loss {
            for &lr in lrs {
                let opts = TrainOpts {
                    steps,
                    peak_lr: lr,
                    lam_a: *la,
                    lam_b: *lb,
                    eval_every: 0, // only final eval
                    ..Default::default()
                };
                let out = trainer::train(&engine, &meta, &ds, &opts)?;
                let last = out.curve.eval.last().unwrap();
                rep.row(&[
                    mdl.to_string(),
                    label.to_string(),
                    format!("{lr:.0e}"),
                    format!("{:.4}", last.mse_key),
                    format!("{:.4}", last.mse_score),
                ]);
            }
        }
    }
    rep.note("paper shape: single-objective runs land in opposite corners; combined sits near grads-only on key error while reducing score error");
    rep.emit("fig14_loss_ablation");
    Ok(())
}
