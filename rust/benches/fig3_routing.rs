//! Fig. 3: routing accuracy vs FLOPs on quora-s and nq-s with c=10,
//! sweeping model family (SupportNet / KeyNet), size (xs/s/m) and the
//! sparse-reinjection variant, against the centroid baseline; top-k from
//! 1 to 5 traces each router's Pareto curve.

use amips::bench_support::fixtures;
use amips::bench_support::pareto::{pareto_front, ParetoPoint};
use amips::bench_support::report::{pct, Report};
use amips::coordinator::router::{routing_accuracy, AmortizedRouter, CentroidRouter, Router};
use amips::metrics::flops;
use amips::runtime::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();

    for dataset in ["quora-s", "nq-s"] {
        let ds = fixtures::prepare_dataset(&manifest, dataset, 10)?;
        let true_clusters: Vec<usize> = (0..ds.val.gt.n_queries())
            .map(|q| ds.val.gt.top_cluster(q))
            .collect();
        let mut sizes = vec![0usize; ds.c];
        for &a in &ds.assign {
            sizes[a as usize] += 1;
        }
        let cost_of = |dec: &[amips::coordinator::router::RoutingDecision]| -> f64 {
            dec.iter()
                .map(|d| {
                    let picked: Vec<usize> =
                        d.clusters.iter().map(|&c| sizes[c as usize]).collect();
                    flops::routing_total_flops(d.selection_flops, &picked, ds.d()) as f64
                })
                .sum::<f64>()
                / dec.len() as f64
        };

        let mut rep = Report::new(&format!("Fig 3: routing accuracy vs FLOPs on {dataset} (c=10)"));
        rep.header(&["router", "k", "accuracy", "kFLOP/q"]);
        let mut points: Vec<ParetoPoint> = Vec::new();

        // centroid baseline
        let baseline = CentroidRouter::new(ds.centroids.clone());
        for k in 1..=5usize {
            let dec = baseline.route_batch(&ds.val.x, k)?;
            let acc = routing_accuracy(&dec, &true_clusters);
            let cost = cost_of(&dec);
            rep.row(&["centroid".into(), k.to_string(), pct(acc), format!("{:.1}", cost / 1e3)]);
            points.push(ParetoPoint {
                label: format!("centroid k={k}"),
                cost,
                value: acc,
            });
        }

        // learned routers across the sweep
        let mut variants: Vec<String> = Vec::new();
        for mdl in ["supportnet", "keynet"] {
            for size in ["xs", "s", "m"] {
                variants.push(format!("{dataset}.{mdl}.{size}.l4.c10"));
            }
            variants.push(format!("{dataset}.{mdl}.s.l4.c10.nx1"));
        }
        if quick {
            variants.truncate(2);
        }
        for config in variants {
            let model = match fixtures::trained_model(&engine, &manifest, &config, &ds, None) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("skip {config}: {e}");
                    continue;
                }
            };
            let router = AmortizedRouter::new(model);
            for k in 1..=5usize {
                let dec = router.route_batch(&ds.val.x, k)?;
                let acc = routing_accuracy(&dec, &true_clusters);
                let cost = cost_of(&dec);
                rep.row(&[
                    config.clone(),
                    k.to_string(),
                    pct(acc),
                    format!("{:.1}", cost / 1e3),
                ]);
                points.push(ParetoPoint {
                    label: format!("{config} k={k}"),
                    cost,
                    value: acc,
                });
            }
        }

        let front = pareto_front(&points);
        let learned_on_front = front
            .iter()
            .filter(|p| !p.label.starts_with("centroid"))
            .count();
        rep.note(format!(
            "Pareto front: {} points, {} learned (paper: learned routers dominate at higher budgets)",
            front.len(),
            learned_on_front
        ));
        rep.emit("fig3_routing");
    }
    Ok(())
}
