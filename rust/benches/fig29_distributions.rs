//! Figs. 29-30 (App. A.10): distribution diagnostics — 2D PCA occupancy
//! grids of keys vs queries (query-side modes with no key density) and
//! top-1 MIPS score histograms with mean/median, across the three main
//! corpora.

use amips::bench_support::fixtures;
use amips::bench_support::report::{f, pct, Report};
use amips::data::SynthCorpus;
use amips::metrics::histogram::{Grid2d, Histogram};
use amips::tensor::{pca_project, power_iteration_pca};
use anyhow::Result;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let mut rep = Report::new("Fig 29/30: query-vs-key distribution diagnostics");
    rep.header(&[
        "dataset",
        "top1 mean",
        "top1 median",
        "query mass w/o key density",
    ]);
    for dataset in ["quora-s", "nq-s", "hotpot-s"] {
        let spec = manifest.dataset(dataset)?.to_corpus_spec();
        let corpus = SynthCorpus::generate(&spec);

        // Fig 29: project into the leading 2 PCs of the KEYS.
        let (comps, mean) = power_iteration_pca(&corpus.keys, 2, 15, 0);
        let pk = pca_project(&corpus.keys, &comps, &mean);
        let pq = pca_project(&corpus.queries, &comps, &mean);
        let bound = pk
            .data()
            .iter()
            .chain(pq.data().iter())
            .fold(0.0f32, |m, v| m.max(v.abs())) as f64;
        let mut gk = Grid2d::new([-bound, -bound], [bound, bound], 24);
        let mut gq = Grid2d::new([-bound, -bound], [bound, bound], 24);
        for i in 0..pk.rows() {
            gk.record(pk.row(i)[0] as f64, pk.row(i)[1] as f64);
        }
        for i in 0..pq.rows() {
            gq.record(pq.row(i)[0] as f64, pq.row(i)[1] as f64);
        }

        // Fig 30: top-1 MIPS score histogram.
        let gt = amips::data::ground_truth::compute(&corpus.queries, &corpus.keys, 1, None);
        let mut h = Histogram::new(0.0, 1.0, 20);
        for q in 0..gt.n_queries() {
            h.record(gt.score(q, 0) as f64);
        }
        rep.row(&[
            dataset.to_string(),
            f(h.mean()),
            f(h.median()),
            pct(gq.mass_outside(&gk)),
        ]);
        rep.note(format!("{dataset} keys density:\n{}", gk.render()));
        rep.note(format!("{dataset} queries density:\n{}", gq.render()));
        rep.note(format!("{dataset} top-1 histogram:\n{}", h.render(40)));
    }
    rep.note("paper shape: quora concentrated near 1.0 (mean .86 paper / aligned here); nq & hotpot peak lower with query-side-only modes visible");
    rep.emit("fig29_distributions");
    Ok(())
}
