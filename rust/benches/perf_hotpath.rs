//! §Perf: microbenchmarks of the request-path hot spots — exhaustive
//! scan throughput (flat index), IVF probe, the parallel batched
//! `Searcher` path, model forward, and end-to-end serving throughput.
//! Before/after numbers live in EXPERIMENTS.md §Perf.

use amips::api::{Effort, QueryMode, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::bench_support::report::Report;
use amips::coordinator::{BatchPolicy, Server, ServerConfig};
use amips::index::{flat::FlatIndex, ivf::IvfIndex, traits::VectorIndex};
use amips::runtime::Engine;
use amips::tensor::{gemm_nt, Tensor};
use amips::trainer::{self, TrainOpts};
use amips::util::timer::{time_reps, Stats};
use anyhow::Result;
use std::sync::Arc;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let ds = fixtures::prepare_dataset(&manifest, "nq-s", 1)?;
    let (n, d) = (ds.n_keys(), ds.d());
    let mut rep = Report::new("§Perf: hot-path microbenchmarks");
    rep.header(&["path", "unit", "mean", "p95", "throughput"]);

    // ---- 1. dot-product scan (the flat/ivf inner loop) -----------------
    let flat = FlatIndex::new(ds.keys.clone());
    let q = ds.val.x.row(0).to_vec();
    let t = Stats::from(&time_reps(3, 30, || {
        std::hint::black_box(flat.search_effort(&q, 10, Effort::Exhaustive));
    }));
    rep.row(&[
        "flat scan".into(),
        format!("{n} keys"),
        format!("{:.3} ms", t.mean * 1e3),
        format!("{:.3} ms", t.p95 * 1e3),
        format!("{:.2} GFLOP/s", (n * d * 2) as f64 / t.mean / 1e9),
    ]);

    // ---- 2. gemm_nt batch scoring --------------------------------------
    let qb = ds.val.x.gather_rows(&(0..64).collect::<Vec<_>>());
    let mut out = Tensor::zeros(&[64, n]);
    let t = Stats::from(&time_reps(2, 10, || {
        gemm_nt(&qb, &ds.keys, &mut out);
    }));
    rep.row(&[
        "gemm_nt".into(),
        format!("64x{n}"),
        format!("{:.2} ms", t.mean * 1e3),
        format!("{:.2} ms", t.p95 * 1e3),
        format!("{:.2} GFLOP/s", (64 * n * d * 2) as f64 / t.mean / 1e9),
    ]);

    // ---- 3. IVF probe ----------------------------------------------------
    let ivf = IvfIndex::build(&ds.keys, fixtures::default_nlist(n), 15, 42);
    for nprobe in [1usize, 8] {
        let t = Stats::from(&time_reps(3, 50, || {
            std::hint::black_box(ivf.search_effort(&q, 10, Effort::Probes(nprobe)));
        }));
        rep.row(&[
            format!("ivf probe={nprobe}"),
            "1 query".into(),
            format!("{:.1} us", t.mean * 1e6),
            format!("{:.1} us", t.p95 * 1e6),
            format!("{:.0} q/s", 1.0 / t.mean),
        ]);
    }

    // ---- 4. parallel batched Searcher over the thread pool --------------
    let req = SearchRequest::top_k(10).effort(Effort::Probes(8));
    let t = Stats::from(&time_reps(2, 10, || {
        std::hint::black_box(ivf.search(&ds.val.x, &req).unwrap());
    }));
    let nq = ds.val.x.rows();
    rep.row(&[
        "ivf batch (Searcher)".into(),
        format!("{nq} queries"),
        format!("{:.2} ms", t.mean * 1e3),
        format!("{:.2} ms", t.p95 * 1e3),
        format!("{:.0} q/s", nq as f64 / t.mean),
    ]);

    // ---- 5. model forward (batched inference) ---------------------------
    let config = "nq-s.keynet.xs.l4.c1";
    let model = fixtures::trained_model(&engine, &manifest, config, &ds, None)?;
    let batch = ds.val.x.gather_rows(&(0..256).collect::<Vec<_>>());
    let t = Stats::from(&time_reps(2, 20, || {
        std::hint::black_box(model.map_queries(&batch).unwrap());
    }));
    rep.row(&[
        "keynet fwd".into(),
        "256 queries".into(),
        format!("{:.2} ms", t.mean * 1e3),
        format!("{:.2} ms", t.p95 * 1e3),
        format!("{:.0} q/s", 256.0 / t.mean),
    ]);

    // ---- 6. end-to-end serving ------------------------------------------
    let meta = manifest.meta(config)?;
    let params = trainer::train_or_load(
        &engine,
        &meta,
        &ds,
        &TrainOpts {
            steps: fixtures::default_steps(&meta.size),
            ..Default::default()
        },
    )?
    .params;
    drop(engine); // server builds its own engine on the runner thread
    let default_request = SearchRequest::top_k(10)
        .effort(Effort::Probes(4))
        .mode(QueryMode::Mapped);
    let (server, handle) = Server::start(
        ServerConfig::with_model(
            manifest.dir.clone(),
            meta,
            params,
            BatchPolicy::default(),
            default_request,
        ),
        Arc::new(ivf),
    )?;
    let reqs = 512usize;
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..4usize {
            let handle = handle.clone();
            let ds = &ds;
            s.spawn(move || {
                for i in (c..reqs).step_by(4) {
                    let _ = handle.search(ds.val.x.row(i % ds.val.x.rows()).to_vec());
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.latency_stats();
    drop(handle);
    server.shutdown()?;
    rep.row(&[
        "serve e2e".into(),
        format!("{reqs} reqs"),
        format!("{:.2} ms p50", stats.quantile_s(0.5) * 1e3),
        format!("{:.2} ms p95", stats.quantile_s(0.95) * 1e3),
        format!("{:.0} q/s", reqs as f64 / wall),
    ]);

    rep.emit("perf_hotpath");
    Ok(())
}
