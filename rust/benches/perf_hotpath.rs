//! §Perf: microbenchmarks of the request-path hot spots, pure Rust
//! (default features). The headline rows compare the *per-query* scan
//! path against the *fused batched* path (`search_batch_effort`) for
//! flat / PQ / IVF at batch sizes B ∈ {1, 8, 64} — the kernels are
//! bit-identical in results, so any ratio is pure memory/cache
//! behavior. A machine-readable `BENCH_hotpath.json` is emitted next to
//! the aligned-text table so the bench trajectory can be tracked across
//! commits (`scripts/bench_gate.py` compares it against the committed
//! baseline in CI).
//!
//! Every row carries a `kernel` field naming the dispatch tier it ran
//! under (`avx2fma` / `neon` / `scalar`), and the whole suite runs
//! twice in one artifact — once on the detected SIMD tier, once
//! force-pinned to scalar — so one JSON file captures both the SIMD
//! speedup and the portable floor. Under `AMIPS_FORCE_SCALAR=1` only
//! the scalar pass runs.
//!
//! Corpus size scales with `AMIPS_BENCH_N` / `AMIPS_BENCH_D` (CI's
//! perf-smoke job runs a tiny synthetic corpus; local runs default to a
//! cache-straining 32768 x 64).

use amips::api::{Effort, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::bench_support::report::{JsonRows, JsonVal, Report};
use amips::index::pq::Pq;
use amips::index::{flat::FlatIndex, ivf::IvfIndex, pq::PqIndex, traits::VectorIndex};
use amips::tensor::{gemm_nt, kernels, normalize_rows, Tensor};
use amips::util::timer::{time_reps, Stats};
use amips::util::Rng;
use anyhow::Result;
use std::hint::black_box;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Time per-query and fused-batched scans of `index` over the first `b`
/// queries, emitting one text row + one JSON row per mode. Flops come
/// from the index's own SearchCost (identical on both paths).
#[allow(clippy::too_many_arguments)]
fn bench_pair(
    rep: &mut Report,
    json: &mut JsonRows,
    kernel: &str,
    backend: &str,
    index: &dyn VectorIndex,
    queries: &Tensor,
    b: usize,
    effort: Effort,
) {
    let reps = match b {
        1 => 20,
        8 => 8,
        _ => 4,
    };
    let qb = queries.gather_rows(&(0..b).collect::<Vec<_>>());
    let flops: u64 = (0..b)
        .map(|i| index.search_effort(qb.row(i), 10, effort).cost.flops)
        .sum();
    let per_query = Stats::from(&time_reps(1, reps, || {
        for i in 0..b {
            black_box(index.search_effort(qb.row(i), 10, effort));
        }
    }));
    let batched = Stats::from(&time_reps(1, reps, || {
        black_box(index.search_batch_effort(&qb, 10, effort));
    }));
    for (mode, t) in [("per_query", per_query), ("batched", batched)] {
        let gflops = flops as f64 / t.mean / 1e9;
        let qps = b as f64 / t.mean;
        rep.row(&[
            format!("{backend} {mode} [{kernel}]"),
            format!("B={b}"),
            format!("{:.3} ms", t.mean * 1e3),
            format!("{:.3} ms", t.p95 * 1e3),
            format!("{gflops:.2} GFLOP/s"),
            format!("{qps:.0} q/s"),
        ]);
        json.push(&[
            ("backend", JsonVal::S(backend.to_string())),
            ("mode", JsonVal::S(mode.to_string())),
            ("kernel", JsonVal::S(kernel.to_string())),
            ("batch", JsonVal::I(b as u64)),
            ("n", JsonVal::I(index.len() as u64)),
            ("d", JsonVal::I(index.dim() as u64)),
            ("mean_s", JsonVal::F(t.mean)),
            ("p95_s", JsonVal::F(t.p95)),
            ("gflops", JsonVal::F(gflops)),
            ("qps", JsonVal::F(qps)),
        ]);
    }
}

/// One full pass of the suite under the currently pinned dispatch tier.
#[allow(clippy::too_many_arguments)]
fn run_suite(
    rep: &mut Report,
    json: &mut JsonRows,
    kernel: &str,
    keys: &Tensor,
    queries: &Tensor,
    flat: &FlatIndex,
    pq: &PqIndex,
    ivf: &IvfIndex,
    pq_m: usize,
) {
    let (n, d) = (keys.rows(), keys.row_width());
    let nq = queries.rows();

    // ---- 1. batched vs per-query scans: flat / PQ / IVF ----------------
    let backends: [(&str, &dyn VectorIndex, Effort); 3] = [
        ("flat", flat, Effort::Exhaustive),
        ("pq", pq, Effort::Auto),
        ("ivf", ivf, Effort::Probes(8)),
    ];
    for (backend, index, effort) in backends {
        for b in [1usize, 8, 64] {
            bench_pair(rep, json, kernel, backend, index, queries, b, effort);
        }
    }

    // ---- 2. raw gemm_nt batch scoring (kernel ceiling) -----------------
    let mut out = Tensor::zeros(&[nq, n]);
    let t = Stats::from(&time_reps(1, 4, || {
        gemm_nt(queries, keys, &mut out);
    }));
    let gflops = (nq * n * d * 2) as f64 / t.mean / 1e9;
    rep.row(&[
        format!("gemm_nt [{kernel}]"),
        format!("{nq}x{n}"),
        format!("{:.2} ms", t.mean * 1e3),
        format!("{:.2} ms", t.p95 * 1e3),
        format!("{gflops:.2} GFLOP/s"),
        String::new(),
    ]);
    json.push(&[
        ("backend", JsonVal::S("gemm_nt".into())),
        ("mode", JsonVal::S("kernel".into())),
        ("kernel", JsonVal::S(kernel.to_string())),
        ("batch", JsonVal::I(nq as u64)),
        ("n", JsonVal::I(n as u64)),
        ("d", JsonVal::I(d as u64)),
        ("mean_s", JsonVal::F(t.mean)),
        ("p95_s", JsonVal::F(t.p95)),
        ("gflops", JsonVal::F(gflops)),
        ("qps", JsonVal::F(nq as f64 / t.mean)),
    ]);

    // ---- 3. raw ADC code-matrix scans (8-bit and 4-bit packed) ---------
    // A lookup+add is counted as 2 "flops" so the tiers compare on one
    // scale; the interesting number is rows/s anyway.
    for bits in [8usize, 4] {
        let pqq = Pq::train_with_bits(keys, pq_m, 3, 1.0, bits, 42);
        let codes = pqq.encode(keys);
        let cw = pqq.code_width();
        let table = pqq.adc_table(queries.row(0));
        let t = Stats::from(&time_reps(1, 8, || {
            let mut acc = 0.0f32;
            for i in 0..n {
                acc += pqq.adc_score(&table, &codes[i * cw..(i + 1) * cw]);
            }
            black_box(acc);
        }));
        let gflops = (n * pq_m * 2) as f64 / t.mean / 1e9;
        let backend = format!("adc_scan{bits}");
        rep.row(&[
            format!("{backend} [{kernel}]"),
            format!("{n}x{pq_m}"),
            format!("{:.3} ms", t.mean * 1e3),
            format!("{:.3} ms", t.p95 * 1e3),
            format!("{gflops:.2} GFLOP/s"),
            format!("{:.0} Mrow/s", n as f64 / t.mean / 1e6),
        ]);
        json.push(&[
            ("backend", JsonVal::S(backend)),
            ("mode", JsonVal::S("kernel".into())),
            ("kernel", JsonVal::S(kernel.to_string())),
            ("batch", JsonVal::I(1)),
            ("n", JsonVal::I(n as u64)),
            ("d", JsonVal::I(d as u64)),
            ("mean_s", JsonVal::F(t.mean)),
            ("p95_s", JsonVal::F(t.p95)),
            ("gflops", JsonVal::F(gflops)),
            ("qps", JsonVal::F(1.0 / t.mean)),
        ]);
    }

    // ---- 4. threaded batched Searcher over the pool --------------------
    let req = SearchRequest::top_k(10).effort(Effort::Probes(8));
    let t = Stats::from(&time_reps(1, 4, || {
        black_box(ivf.search(queries, &req).unwrap());
    }));
    rep.row(&[
        format!("ivf batch (Searcher) [{kernel}]"),
        format!("{nq} queries"),
        format!("{:.2} ms", t.mean * 1e3),
        format!("{:.2} ms", t.p95 * 1e3),
        String::new(),
        format!("{:.0} q/s", nq as f64 / t.mean),
    ]);
    json.push(&[
        ("backend", JsonVal::S("ivf".into())),
        ("mode", JsonVal::S("searcher_threaded".into())),
        ("kernel", JsonVal::S(kernel.to_string())),
        ("batch", JsonVal::I(nq as u64)),
        ("n", JsonVal::I(n as u64)),
        ("d", JsonVal::I(d as u64)),
        ("mean_s", JsonVal::F(t.mean)),
        ("p95_s", JsonVal::F(t.p95)),
        ("gflops", JsonVal::F(f64::NAN)),
        ("qps", JsonVal::F(nq as f64 / t.mean)),
    ]);
}

fn main() -> Result<()> {
    let n = env_usize("AMIPS_BENCH_N", 32_768);
    let d = env_usize("AMIPS_BENCH_D", 64);
    let nq = 64usize;
    let keys = fixtures::synth_keys(n, d, 42);
    let mut queries = Tensor::zeros(&[nq, d]);
    Rng::new(7).fill_normal(queries.data_mut(), 1.0);
    normalize_rows(&mut queries);

    let mut rep = Report::new("§Perf: hot-path microbenchmarks (batched vs per-query)");
    rep.header(&["path", "unit", "mean", "p95", "throughput", "rate"]);
    let mut json = JsonRows::new("hotpath");

    // Indexes are built once (training quality is not what's timed) and
    // scanned under each dispatch tier.
    let flat = FlatIndex::new(keys.clone());
    let pq_m = [8usize, 4, 2, 1].into_iter().find(|m| d % m == 0).unwrap_or(1);
    let pq = PqIndex::build(&keys, pq_m, 3, 1.0, 8, 42);
    let ivf = IvfIndex::build(&keys, fixtures::default_nlist(n), 10, 42);

    // Detected tier first, then the forced-scalar floor (skipped when
    // the detected tier already is scalar, e.g. AMIPS_FORCE_SCALAR=1).
    let detected = kernels::tier_name().to_string();
    let mut modes = vec![(false, detected.clone())];
    if detected != "scalar" {
        modes.push((true, "scalar".to_string()));
    }
    for (force, kernel) in &modes {
        kernels::force_scalar(*force);
        run_suite(
            &mut rep, &mut json, kernel, &keys, &queries, &flat, &pq, &ivf, pq_m,
        );
    }
    kernels::force_scalar(false);

    rep.note(format!(
        "corpus {n}x{d} (AMIPS_BENCH_N/AMIPS_BENCH_D to rescale); detected \
         kernel tier: {detected}; batched and per-query paths are \
         bit-identical in results per tier, so ratios are pure \
         kernel/cache effects"
    ));
    rep.emit("perf_hotpath");
    json.emit();
    Ok(())
}
