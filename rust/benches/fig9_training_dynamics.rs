//! Fig. 9 (+ Fig. 11 analog): E_rel training trajectories on quora-s for
//! KeyNet across sizes; `--dim 128` switches to the higher-dimensional
//! corpus (App. A.5).

use amips::bench_support::fixtures;
use amips::bench_support::report::Report;
use amips::cli::Args;
use amips::runtime::Engine;
use amips::trainer::{self, TrainOpts};
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let dim = args.get_usize("dim", 64)?;
    args.reject_unknown()?;
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();

    let (dataset, sizes): (&str, &[&str]) = if dim == 128 {
        ("nq-s-d128", &["xs", "s"])
    } else {
        ("quora-s", &["xs", "s", "m"])
    };
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let ds = fixtures::prepare_dataset(&manifest, dataset, 1)?;

    let mut rep = Report::new(&format!("Fig 9/11: E_rel training dynamics on {dataset} (KeyNet)"));
    rep.header(&["size", "step", "E_rel"]);
    for size in sizes {
        let config = format!("{dataset}.keynet.{size}.l4.c1");
        let meta = manifest.meta(&config)?;
        let steps = if quick { 600 } else { fixtures::default_steps(size) };
        let opts = TrainOpts {
            steps,
            eval_every: (steps / 10).max(1),
            ..Default::default()
        };
        let out = trainer::train(&engine, &meta, &ds, &opts)?;
        for e in &out.curve.eval {
            rep.row(&[size.to_string(), e.step.to_string(), format!("{:.4}", e.e_rel)]);
        }
        rep.note(format!(
            "{size}: curve {}  final E_rel {:.3}",
            out.curve.e_rel_sparkline(),
            out.curve.final_e_rel().unwrap_or(f32::NAN)
        ));
    }
    rep.note("paper shape: curves separate by capacity; larger sizes reach lower E_rel; no divergence");
    rep.emit("fig9_training_dynamics");
    Ok(())
}
