//! §Serve: load generator for the TCP front-end, in two phases.
//!
//! **Open loop**: Poisson arrivals at a target QPS are fanned over
//! several blocking [`NetClient`] connections; per-request latency is
//! measured from the *scheduled* arrival time (open-loop semantics: a
//! server that falls behind accrues queueing delay instead of silently
//! throttling the offered load).
//!
//! **Closed loop, pipelined**: one connection keeps `inflight ∈
//! {1, 4, 16}` wire-v2 requests outstanding (inflight=1 is the
//! strict-alternation one-shot baseline); throughput and claim latency
//! per window size land in `row="pipelined"` JSON rows, so the
//! pipelining win at equal offered load is a diffable number.
//!
//! Reports client-side p50/p99/p999 + throughput and emits
//! machine-readable `BENCH_serve.json`.
//!
//! Knobs (env):
//!   AMIPS_SERVE_ADDR        target an already-running `amips serve
//!                           --listen` server instead of the in-process
//!                           one this bench spins up by default
//!   AMIPS_SERVE_COLLECTION  collection name (default "docs")
//!   AMIPS_SERVE_N/_D        in-process corpus size (default 8192 x 32)
//!   AMIPS_SERVE_QPS         offered load (default 2000)
//!   AMIPS_SERVE_SECONDS     run length (default 3)
//!   AMIPS_SERVE_CLIENTS     connections (default 4)
//!   AMIPS_SERVE_DEADLINE_MS per-request deadline (default none)
//!   AMIPS_SERVE_PIPELINE_REQUESTS  closed-loop requests per window
//!                           (default 2000; 0 skips the sweep)
//!
//! Exits nonzero when no request succeeds — CI's serve-smoke job treats
//! that as a failed deployment, not an empty report.

use amips::api::Effort;
use amips::bench_support::fixtures;
use amips::bench_support::report::{JsonRows, JsonVal, Report};
use amips::coordinator::net::{NetClient, NetError, NetServer, NetServerConfig, SearchOptions};
use amips::index::ivf::IvfIndex;
use amips::tensor::{normalize_rows, Tensor};
use amips::util::Rng;
use anyhow::Result;
use std::time::{Duration, Instant};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Exact quantile over a sorted sample (nearest-rank).
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((q * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
    sorted[i]
}

struct ClientOutcome {
    latencies_s: Vec<f64>,
    ok: usize,
    overloaded: usize,
    expired: usize,
    other_errors: usize,
}

/// One closed-loop run: keep `window` requests in flight on one
/// connection until `requests` have completed. `window == 1` (or a v1
/// server) is the strict-alternation one-shot baseline; otherwise the
/// wire-v2 submit/claim pipeline. Latency is submit→claim per request.
fn closed_loop(
    addr: &str,
    collection: &str,
    pool: &Tensor,
    requests: usize,
    window: usize,
    opts: SearchOptions,
) -> Result<(ClientOutcome, f64, u8)> {
    use std::collections::HashMap;
    let mut client = NetClient::connect(addr)?;
    client.set_timeout(Some(Duration::from_secs(30)))?;
    let version = client.version();
    let pipelined = window > 1 && version >= 2;
    let mut out = ClientOutcome {
        latencies_s: Vec::new(),
        ok: 0,
        overloaded: 0,
        expired: 0,
        other_errors: 0,
    };
    let count_err = |e: &NetError, out: &mut ClientOutcome| {
        use amips::coordinator::net::ErrorCode;
        match e.server_error().map(|f| f.code) {
            Some(ErrorCode::Overloaded) => out.overloaded += 1,
            Some(ErrorCode::DeadlineExpired) => out.expired += 1,
            _ => out.other_errors += 1,
        }
    };
    let t0 = Instant::now();
    if !pipelined {
        for i in 0..requests {
            let t = Instant::now();
            match client.search(collection, pool.row(i % pool.rows()), opts) {
                Ok(_) => {
                    out.ok += 1;
                    out.latencies_s.push(t.elapsed().as_secs_f64());
                }
                Err(e) => count_err(&e, &mut out),
            }
        }
    } else {
        let mut inflight: HashMap<u64, Instant> = HashMap::new();
        let mut submitted = 0usize;
        let mut done = 0usize;
        while done < requests {
            while submitted < requests && inflight.len() < window {
                let id =
                    client.submit_search(collection, pool.row(submitted % pool.rows()), opts)?;
                inflight.insert(id, Instant::now());
                submitted += 1;
            }
            let reply = client.recv_any()?;
            let since = inflight
                .remove(&reply.request_id)
                .ok_or_else(|| anyhow::anyhow!("completion for unknown id"))?;
            match reply.reply {
                Ok(_) => {
                    out.ok += 1;
                    out.latencies_s.push(since.elapsed().as_secs_f64());
                }
                Err(e) => count_err(&NetError::Server(e), &mut out),
            }
            done += 1;
        }
    }
    Ok((out, t0.elapsed().as_secs_f64(), version))
}

fn main() -> Result<()> {
    let external_addr = std::env::var("AMIPS_SERVE_ADDR").ok();
    let collection =
        std::env::var("AMIPS_SERVE_COLLECTION").unwrap_or_else(|_| "docs".to_string());
    let n = env_usize("AMIPS_SERVE_N", 8192);
    let d = env_usize("AMIPS_SERVE_D", 32);
    let qps = env_f64("AMIPS_SERVE_QPS", 2000.0).max(1.0);
    let seconds = env_f64("AMIPS_SERVE_SECONDS", 3.0).max(0.1);
    let clients = env_usize("AMIPS_SERVE_CLIENTS", 4).max(1);
    let deadline_ms = env_usize("AMIPS_SERVE_DEADLINE_MS", 0);
    let seed = 0x5E12u64;

    // the in-process server (default): one IVF collection over the
    // shared synthetic corpus, same NetServer the CLI listener uses
    let (server, addr) = match &external_addr {
        Some(a) => (None, a.clone()),
        None => {
            let keys = fixtures::synth_keys(n, d, seed);
            let index = IvfIndex::build(&keys, fixtures::default_nlist(n), 10, seed);
            let tenant = amips::coordinator::net::Tenant::start(
                &collection,
                std::sync::Arc::new(index),
                None,
                amips::coordinator::BatchPolicy::default(),
                1024,
            )?;
            let mut tenants = std::collections::BTreeMap::new();
            tenants.insert(collection.clone(), tenant);
            let server = NetServer::serve(tenants, "127.0.0.1:0", NetServerConfig::default())?;
            let addr = server.local_addr().to_string();
            (Some(server), addr)
        }
    };

    // unit-norm gaussian query pool
    let n_queries = 256usize;
    let mut pool = Tensor::zeros(&[n_queries, d]);
    Rng::new(seed ^ 1).fill_normal(pool.data_mut(), 1.0);
    normalize_rows(&mut pool);

    // Poisson arrival schedule: exponential inter-arrivals at `qps`,
    // deterministic in the seed. Client c serves arrivals c, c+C, ...
    // (thinning a Poisson process keeps each sub-stream Poisson).
    let total = ((qps * seconds).round() as usize).max(1);
    let mut arrivals = Vec::with_capacity(total);
    {
        let mut rng = Rng::new(seed ^ 2);
        let mut t = 0.0f64;
        for _ in 0..total {
            t += -(1.0 - rng.uniform()).ln() / qps;
            arrivals.push(t);
        }
    }
    let opts = {
        let o = SearchOptions::top_k(10).effort(Effort::Probes(4));
        if deadline_ms > 0 {
            o.deadline(Duration::from_millis(deadline_ms as u64))
        } else {
            o
        }
    };

    println!(
        "bench_serve: {total} requests at {qps:.0} qps over {clients} connections -> {addr}"
    );
    let t0 = Instant::now();
    let outcomes: Vec<ClientOutcome> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for c in 0..clients {
            let (addr, collection, arrivals, pool) = (&addr, &collection, &arrivals, &pool);
            joins.push(s.spawn(move || -> Result<ClientOutcome> {
                let mut client = NetClient::connect(addr.as_str())?;
                client.set_timeout(Some(Duration::from_secs(30)))?;
                let mut out = ClientOutcome {
                    latencies_s: Vec::new(),
                    ok: 0,
                    overloaded: 0,
                    expired: 0,
                    other_errors: 0,
                };
                for i in (c..arrivals.len()).step_by(clients) {
                    let scheduled = t0 + Duration::from_secs_f64(arrivals[i]);
                    let now = Instant::now();
                    if scheduled > now {
                        std::thread::sleep(scheduled - now);
                    }
                    let q = pool.row(i % pool.rows());
                    match client.search(collection, q, opts) {
                        Ok(_hits) => {
                            out.ok += 1;
                            // open-loop latency: reply time minus the
                            // *scheduled* arrival
                            out.latencies_s
                                .push(scheduled.elapsed().as_secs_f64());
                        }
                        Err(NetError::Server(e)) => {
                            use amips::coordinator::net::ErrorCode;
                            match e.code {
                                ErrorCode::Overloaded => out.overloaded += 1,
                                ErrorCode::DeadlineExpired => out.expired += 1,
                                _ => out.other_errors += 1,
                            }
                        }
                        Err(_) => out.other_errors += 1,
                    }
                }
                Ok(out)
            }));
        }
        joins
            .into_iter()
            .map(|j| j.join().expect("client thread panicked"))
            .collect::<Result<Vec<_>>>()
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let mut latencies: Vec<f64> = Vec::new();
    let (mut ok, mut overloaded, mut expired, mut other) = (0usize, 0usize, 0usize, 0usize);
    for o in outcomes {
        latencies.extend(o.latencies_s);
        ok += o.ok;
        overloaded += o.overloaded;
        expired += o.expired;
        other += o.other_errors;
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99, p999) = (
        quantile(&latencies, 0.5),
        quantile(&latencies, 0.99),
        quantile(&latencies, 0.999),
    );
    let achieved = ok as f64 / wall;

    // server-side view (typed Stats frame) for comparison
    let server_stats = NetClient::connect(addr.as_str())
        .and_then(|mut c| {
            c.set_timeout(Some(Duration::from_secs(5)))?;
            c.stats()
        })
        .ok();

    let mut rep = Report::new(&format!(
        "bench_serve: open-loop Poisson {qps:.0} qps x {seconds}s, {clients} conns ({collection})"
    ));
    rep.header(&[
        "ok", "overload", "expired", "errors", "qps", "p50 ms", "p99 ms", "p999 ms",
    ]);
    rep.row(&[
        format!("{ok}/{total}"),
        overloaded.to_string(),
        expired.to_string(),
        other.to_string(),
        format!("{achieved:.0}"),
        format!("{:.2}", p50 * 1e3),
        format!("{:.2}", p99 * 1e3),
        format!("{:.2}", p999 * 1e3),
    ]);
    if let Some(s) = &server_stats {
        rep.note(format!(
            "server view: served={} p50={:.2}ms p99={:.2}ms p999={:.2}ms queue_depth={}",
            s.served,
            s.p50_s * 1e3,
            s.p99_s * 1e3,
            s.p999_s * 1e3,
            s.queue_depth
        ));
    }
    rep.note("latency measured from the scheduled Poisson arrival (open-loop: server lag shows up as queueing delay)");
    rep.emit("bench_serve");

    let mut json = JsonRows::new("serve");
    json.push(&[
        ("row", JsonVal::S("summary".into())),
        ("qps_target", JsonVal::F(qps)),
        ("qps_achieved", JsonVal::F(achieved)),
        ("requests", JsonVal::I(total as u64)),
        ("ok", JsonVal::I(ok as u64)),
        ("overloaded", JsonVal::I(overloaded as u64)),
        ("expired", JsonVal::I(expired as u64)),
        ("errors", JsonVal::I(other as u64)),
        ("clients", JsonVal::I(clients as u64)),
    ]);
    for (name, v) in [("p50", p50), ("p99", p99), ("p999", p999)] {
        json.push(&[
            ("row", JsonVal::S("quantile".into())),
            ("quantile", JsonVal::S(name.into())),
            ("latency_ms", JsonVal::F(v * 1e3)),
            ("server_latency_ms", match &server_stats {
                Some(s) => JsonVal::F(
                    match name {
                        "p50" => s.p50_s,
                        "p99" => s.p99_s,
                        _ => s.p999_s,
                    } * 1e3,
                ),
                None => JsonVal::F(f64::NAN), // rendered as null
            }),
        ]);
    }
    // closed-loop pipelined sweep: same server, one connection, fixed
    // request count per window; inflight=1 is the one-shot baseline
    // the pipelined rows are compared against
    let pipeline_requests = env_usize("AMIPS_SERVE_PIPELINE_REQUESTS", 2000);
    if pipeline_requests > 0 {
        let mut prep = Report::new(&format!(
            "bench_serve: closed-loop pipelined sweep, {pipeline_requests} requests/window ({collection})"
        ));
        prep.header(&["inflight", "ok", "errors", "qps", "p50 ms", "p99 ms", "mode"]);
        for window in [1usize, 4, 16] {
            let (out, wall, version) = closed_loop(
                &addr,
                &collection,
                &pool,
                pipeline_requests,
                window,
                opts,
            )?;
            let mut lats = out.latencies_s.clone();
            lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let (p50, p99) = (quantile(&lats, 0.5), quantile(&lats, 0.99));
            let errors = out.overloaded + out.expired + out.other_errors;
            let qps_closed = out.ok as f64 / wall.max(1e-9);
            let mode = if window > 1 && version >= 2 {
                "pipelined"
            } else {
                "one-shot"
            };
            prep.row(&[
                window.to_string(),
                format!("{}/{pipeline_requests}", out.ok),
                errors.to_string(),
                format!("{qps_closed:.0}"),
                format!("{:.2}", p50 * 1e3),
                format!("{:.2}", p99 * 1e3),
                mode.into(),
            ]);
            json.push(&[
                ("row", JsonVal::S("pipelined".into())),
                ("inflight", JsonVal::I(window as u64)),
                ("wire_version", JsonVal::I(version as u64)),
                ("requests", JsonVal::I(pipeline_requests as u64)),
                ("ok", JsonVal::I(out.ok as u64)),
                ("overloaded", JsonVal::I(out.overloaded as u64)),
                ("expired", JsonVal::I(out.expired as u64)),
                ("errors", JsonVal::I(out.other_errors as u64)),
                ("qps_achieved", JsonVal::F(qps_closed)),
                ("p50_ms", JsonVal::F(p50 * 1e3)),
                ("p99_ms", JsonVal::F(p99 * 1e3)),
            ]);
        }
        prep.note("claim latency is submit->claim on one connection; throughput scales with the in-flight window until the batcher saturates");
        prep.emit("bench_serve_pipelined");
    }
    json.emit();

    if let Some(server) = server {
        server.shutdown();
    }
    if ok == 0 {
        eprintln!("bench_serve: no request succeeded");
        std::process::exit(1);
    }
    Ok(())
}
