//! Fig. 4: routing accuracy vs FLOPs at c=128 on nq-s, XS SupportNet
//! (L=8, sparse reinjection) vs the centroid baseline, k ∈ {1..32}.
//!
//! Paper claims to reproduce: the learned router dominates the low-FLOPs
//! regime (≈72% vs ≈56% at k=1 in the paper), and reaches at k≈4 what
//! centroids need k≈16 for. KeyNet is absent by design: its c·d output
//! head would dwarf the router (the paper's argument for SupportNet here).

use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::coordinator::router::{routing_accuracy, AmortizedRouter, CentroidRouter, Router};
use amips::metrics::flops;
use amips::runtime::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let config = "nq-s.supportnet.xs.l8.c128";
    let ds = fixtures::prepare_dataset(&manifest, "nq-s", 128)?;
    let model = fixtures::trained_model(&engine, &manifest, config, &ds, None)?;
    let learned = AmortizedRouter::new(model);
    let baseline = CentroidRouter::new(ds.centroids.clone());
    let true_clusters: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.top_cluster(q))
        .collect();
    let mut sizes = vec![0usize; ds.c];
    for &a in &ds.assign {
        sizes[a as usize] += 1;
    }

    let mut rep = Report::new("Fig 4: c=128 routing on nq-s, XS SupportNet L=8 vs centroid");
    rep.header(&["router", "k", "accuracy", "kFLOP/q"]);
    let mut crossover: Vec<(String, usize, f64)> = Vec::new();
    for &k in &[1usize, 2, 4, 8, 16, 32] {
        for router in [&learned as &dyn Router, &baseline as &dyn Router] {
            let dec = router.route_batch(&ds.val.x, k)?;
            let acc = routing_accuracy(&dec, &true_clusters);
            let cost: f64 = dec
                .iter()
                .map(|d| {
                    let picked: Vec<usize> =
                        d.clusters.iter().map(|&c| sizes[c as usize]).collect();
                    flops::routing_total_flops(d.selection_flops, &picked, ds.d()) as f64
                })
                .sum::<f64>()
                / dec.len() as f64;
            rep.row(&[
                router.name().to_string(),
                k.to_string(),
                pct(acc),
                format!("{:.1}", cost / 1e3),
            ]);
            crossover.push((router.name().to_string(), k, acc));
        }
    }
    // paper-shape check: learned@small-k vs centroid@small-k
    let get = |name: &str, k: usize| {
        crossover
            .iter()
            .find(|(n, kk, _)| n.starts_with(name) && *kk == k)
            .map(|(_, _, a)| *a)
            .unwrap_or(0.0)
    };
    rep.note(format!(
        "k=1: learned {} vs centroid {} (paper: 72% vs 56%); learned@4 {} vs centroid@16 {}",
        pct(get("amortized", 1)),
        pct(get("centroid", 1)),
        pct(get("amortized", 4)),
        pct(get("centroid", 16)),
    ));
    rep.emit("fig4_c128_routing");
    Ok(())
}
