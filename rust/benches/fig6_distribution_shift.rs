//! Figs. 6-8: robustness to query distribution shift. Re-runs the IVF
//! integration with test queries perturbed by Gaussian noise
//! σ ∈ {0 .. 0.06} (train-time augmentation used σ=0.02), reporting
//! original / mapped / gap per (σ, nprobe).
//!
//! `--dataset quora-s` reproduces the Fig. 8 variant.

use amips::api::{recall_against_truth, Effort, MappedSearcher, QueryMode, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::cli::Args;
use amips::index::ivf::IvfIndex;
use amips::runtime::Engine;
use amips::tensor::{normalize_rows, Tensor};
use amips::util::Rng;
use anyhow::Result;

fn perturb(x: &Tensor, sigma: f32, seed: u64) -> Tensor {
    let mut out = x.clone();
    let mut rng = Rng::new(seed);
    for v in out.data_mut().iter_mut() {
        *v += rng.normal() as f32 * sigma;
    }
    normalize_rows(&mut out);
    out
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let dataset = args.get_or("dataset", "nq-s").to_string();
    args.reject_unknown()?;
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();

    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let ds = fixtures::prepare_dataset(&manifest, &dataset, 1)?;
    let config = format!("{dataset}.keynet.xs.l4.c1");
    let model = fixtures::trained_model(&engine, &manifest, &config, &ds, None)?;
    let nlist = fixtures::default_nlist(ds.n_keys());
    let index = IvfIndex::build(&ds.keys, nlist, 15, 42);
    let searcher = MappedSearcher::mapped(&index, &model);
    let k = (ds.n_keys() / 40).max(10);

    let sigmas: &[f32] = if quick {
        &[0.0, 0.03]
    } else {
        &[0.0, 0.01, 0.02, 0.03, 0.04, 0.05, 0.06]
    };
    let mut rep = Report::new(&format!(
        "Fig 6-8: shift robustness on {dataset} (XS KeyNet, Recall@2.5%={k})"
    ));
    rep.header(&["sigma", "nprobe", "orig", "mapped", "gap(orig-mapped)"]);
    for &sigma in sigmas {
        let qx = perturb(&ds.val.x, sigma, 0x5611F7 + (sigma * 1e3) as u64);
        // recompute truth for the perturbed queries (exact MIPS)
        let gt = amips::data::ground_truth::compute(&qx, &ds.keys, 1, None);
        let truth: Vec<usize> = (0..gt.n_queries()).map(|q| gt.idx(q, 0)).collect();
        for nprobe in [1usize, 2, 4, 8] {
            let req = SearchRequest::top_k(k).effort(Effort::Probes(nprobe));
            let orig = searcher.search(&qx, &req)?;
            let mapped = searcher.search(&qx, &req.mode(QueryMode::Mapped))?;
            let ro = recall_against_truth(&orig.hits, &truth, k);
            let rm = recall_against_truth(&mapped.hits, &truth, k);
            rep.row(&[
                format!("{sigma:.2}"),
                nprobe.to_string(),
                pct(ro),
                pct(rm),
                format!("{:+.1}pp", (ro - rm) * 100.0),
            ]);
        }
    }
    rep.note("paper shape: degradation grows with sigma but is not catastrophic; mapped advantage persists at low nprobe through sigma~0.03");
    rep.emit("fig6_distribution_shift");
    Ok(())
}
