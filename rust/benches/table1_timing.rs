//! Table 1: score vs gradient wall-clock for SupportNet and KeyNet,
//! batch 4096, across datasets and parameter fractions.
//!
//! Paper claim to reproduce: SupportNet's *grad* time ≈ 2x its *score*
//! time (backward pass), while KeyNet's grad time ≈ its score time
//! (keys come from the same forward).

use amips::bench_support::fixtures;
use amips::bench_support::report::Report;
use amips::runtime::engine::lit_f32;
use amips::runtime::Engine;
use amips::util::timer::{time_reps, Stats};
use amips::util::Rng;
use anyhow::Result;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let reps = std::env::var("AMIPS_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20usize);

    let mut rep = Report::new("Table 1: batch-4096 score/grad seconds (paper: GPU; here: 1-core CPU PJRT)");
    rep.header(&["dataset", "size", "model", "score s", "grad s", "grad/score"]);

    for dataset in ["quora-s", "nq-s", "hotpot-s"] {
        let d = manifest.dataset(dataset)?.d;
        // random batch — timing does not depend on trained weights
        let mut x = vec![0.0f32; manifest.timing_batch * d];
        Rng::new(1).fill_normal(&mut x, 1.0);
        let xlit = lit_f32(&[manifest.timing_batch, d], &x)?;
        for size in ["s", "m", "l"] {
            for model in ["supportnet", "keynet"] {
                let config = format!("{dataset}.{model}.{size}.l4.c1");
                let meta = match manifest.meta(&config) {
                    Ok(m) => m,
                    Err(_) => continue,
                };
                if meta.timing_batch == 0 {
                    continue;
                }
                // random params with the right shapes
                let mut rng = Rng::new(7);
                let plits: Vec<xla::Literal> = meta
                    .params
                    .iter()
                    .map(|(_, s)| {
                        let n: usize = s.iter().product::<usize>().max(1);
                        let mut v = vec![0.0f32; n];
                        rng.fill_normal(&mut v, 0.05);
                        lit_f32(s, &v).unwrap()
                    })
                    .collect();
                let mut inputs: Vec<&xla::Literal> = plits.iter().collect();
                inputs.push(&xlit);

                let fwd = engine.load(&format!("{config}.fwd4096"))?;
                let score_t = Stats::from(&time_reps(2, reps, || {
                    fwd.run(&inputs).unwrap();
                }));
                // grad = the artifact that yields keys: grad4096 for
                // SupportNet (backward), the same fwd4096 for KeyNet.
                let grad_t = if meta.model == "supportnet" {
                    let grad = engine.load(&format!("{config}.grad4096"))?;
                    Stats::from(&time_reps(2, reps, || {
                        grad.run(&inputs).unwrap();
                    }))
                } else {
                    score_t
                };
                rep.row(&[
                    dataset.to_string(),
                    size.to_string(),
                    meta.model.clone(),
                    format!("{:.4}", score_t.mean),
                    format!("{:.4}", grad_t.mean),
                    format!("{:.2}", grad_t.mean / score_t.mean),
                ]);
            }
        }
    }
    rep.note("expected shape: supportnet grad/score in 1.5-3x, keynet ~1x (Table 1)");
    rep.emit("table1_timing");
    Ok(())
}
