//! Fig. 15 (App. A.7): training-horizon sweep for the S KeyNet on nq-s —
//! train loss keeps falling with longer horizons while downstream
//! E_rel / MRR plateau (the paper's "~3B samples is the sweet spot",
//! scaled to this testbed's step budget).

use amips::bench_support::fixtures;
use amips::bench_support::report::{f, Report};
use amips::metrics::retrieval;
use amips::model::XlaModel;
use amips::runtime::Engine;
use amips::trainer::{self, TrainOpts};
use anyhow::Result;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();
    let ds = fixtures::prepare_dataset(&manifest, "nq-s", 1)?;
    let config = "nq-s.keynet.s.l4.c1";
    let meta = manifest.meta(config)?;
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();

    let horizons: &[usize] = if quick {
        &[500, 1500]
    } else {
        &[1000, 3000, 5000, 7000]
    };
    let mut rep = Report::new("Fig 15: horizon sweep, S KeyNet on nq-s (fresh cosine schedule per horizon)");
    rep.header(&["steps", "final train loss", "exp(E_rel)", "MRR"]);
    for &steps in horizons {
        let opts = TrainOpts {
            steps,
            eval_every: 0,
            ..Default::default()
        };
        let out = trainer::train(&engine, &meta, &ds, &opts)?;
        let model = XlaModel::load(&engine, meta.clone(), &out.params)?;
        let pred = model.map_queries(&ds.val.x)?;
        let rm = retrieval::evaluate(&pred, &ds.keys, &truth);
        let e_rel = out.curve.eval.last().map(|e| e.e_rel).unwrap_or(f32::NAN);
        rep.row(&[
            steps.to_string(),
            out.curve
                .final_loss()
                .map(|v| format!("{v:.5}"))
                .unwrap_or_default(),
            f((e_rel as f64).exp()),
            f(rm.mrr),
        ]);
    }
    rep.note("paper shape: loss falls monotonically with horizon; exp(E_rel)/MRR show diminishing returns past the mid horizon");
    rep.emit("fig15_horizon");
    Ok(())
}
