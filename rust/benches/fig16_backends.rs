//! Figs. 16-27 (App. A.8): the backend × dataset × recall grid. One
//! parameterized harness replaces the paper's twelve panels: every
//! backbone (ivf / pq / sq8 / scann / soar / leanvec) × dataset ×
//! Recall@{1%,2.5%,5%} × cost axes, original vs XS/S-mapped queries —
//! one `Searcher` loop for all of them.
//!
//! ```bash
//! cargo bench --features xla --bench fig16_backends -- --backend scann --dataset nq-s
//! ```
//! Without flags it sweeps a representative subset; AMIPS_BENCH_QUICK=1
//! shrinks it further.

use amips::api::{recall_against_truth, Effort, MappedSearcher, QueryMode, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::cli::Args;
use amips::runtime::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let backend_filter = args.get("backend").map(str::to_string);
    let dataset_filter = args.get("dataset").map(str::to_string);
    args.reject_unknown()?;
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();

    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;

    // entries are backbone names or full spec strings (anything with a
    // '(' is parsed as a spec; bare names get the dataset-scaled nlist)
    let backends: Vec<String> = match &backend_filter {
        Some(b) => vec![b.clone()],
        None if quick => vec!["ivf".into(), "scann".into()],
        None => vec![
            "ivf".into(),
            "pq".into(),
            "sq8".into(),
            "scann".into(),
            "soar".into(),
            "leanvec".into(),
            "sharded".into(),
        ],
    };
    let datasets: Vec<&str> = match &dataset_filter {
        Some(d) => vec![d.as_str()],
        None if quick => vec!["quora-s"],
        None => vec!["quora-s", "nq-s", "hotpot-s"],
    };
    let fracs = [0.01f64, 0.025, 0.05];

    for dataset in datasets {
        let ds = fixtures::prepare_dataset(&manifest, dataset, 1)?;
        let nlist = fixtures::default_nlist(ds.n_keys());
        let truth: Vec<usize> = (0..ds.val.gt.n_queries())
            .map(|q| ds.val.gt.global_top1(q).0)
            .collect();
        let sizes: &[&str] = if quick { &["xs"] } else { &["xs", "s"] };
        let models: Vec<_> = sizes
            .iter()
            .filter_map(|size| {
                let config = format!("{dataset}.keynet.{size}.l4.c1");
                fixtures::trained_model(&engine, &manifest, &config, &ds, None)
                    .map(|m| (size.to_string(), m))
                    .map_err(|e| eprintln!("skip {config}: {e}"))
                    .ok()
            })
            .collect();

        for backend in &backends {
            // "sharded" expands to 4 shards of IVF with the coarse-cell
            // budget split across them (same total cells as plain ivf)
            let spec: amips::index::IndexSpec = if backend == "sharded" {
                format!("sharded(shards=4,inner=ivf(nlist={}))", (nlist / 4).max(1)).parse()?
            } else if backend.contains('(') {
                backend.parse()?
            } else {
                amips::index::IndexSpec::default_for(backend)?.with_nlist(nlist)
            };
            let index = spec.build(
                &ds.keys,
                &amips::index::BuildCtx {
                    sample_queries: Some(&ds.train.x),
                    seed: 42,
                },
            )?;
            let mut rep = Report::new(&format!(
                "Fig 16-27 grid: {backend} on {dataset} (nlist={nlist})"
            ));
            rep.header(&["variant", "nprobe", "R@1%", "R@2.5%", "R@5%", "MFLOP/q", "ms/q"]);
            let kmax = ((ds.n_keys() as f64 * 0.05).ceil()) as usize;
            for nprobe in [1usize, 2, 4, 8, 16] {
                let mut run_variant = |label: String,
                                       searcher: &dyn Searcher,
                                       mode: QueryMode|
                 -> Result<()> {
                    let req = SearchRequest::top_k(kmax)
                        .effort(Effort::Probes(nprobe))
                        .mode(mode);
                    let out = searcher.search(&ds.val.x, &req)?;
                    let recalls: Vec<String> = fracs
                        .iter()
                        .map(|fr| {
                            let k = ((ds.n_keys() as f64 * fr).ceil() as usize).max(1);
                            pct(recall_against_truth(&out.hits, &truth, k))
                        })
                        .collect();
                    rep.row(&[
                        label,
                        nprobe.to_string(),
                        recalls[0].clone(),
                        recalls[1].clone(),
                        recalls[2].clone(),
                        format!("{:.3}", out.flops_per_query() / 1e6),
                        format!("{:.3}", out.seconds_per_query() * 1e3),
                    ]);
                    Ok(())
                };
                // wrap the bare backbone so the variants share one
                // &dyn Searcher call site
                let orig = MappedSearcher::original(index.as_ref());
                run_variant("orig".into(), &orig, QueryMode::Original)?;
                for (size, model) in &models {
                    let searcher = MappedSearcher::mapped(index.as_ref(), model);
                    run_variant(format!("keynet-{size}"), &searcher, QueryMode::Mapped)?;
                }
            }
            rep.note("paper shape: ordering of orig vs mapped stable across backends; SOAR narrows the regime; gains largest on shifted datasets");
            rep.emit("fig16_backends");
        }
    }
    Ok(())
}
