//! Figs. 16-27 (App. A.8): the backend × dataset × recall grid. One
//! parameterized harness replaces the paper's twelve panels: every
//! backbone (flat / ivf / pq / sq8 / scann / soar / leanvec / sharded) ×
//! dataset × Recall@{1%,2.5%,5%} × cost axes, original vs XS/S-mapped
//! queries — one `Searcher` loop for all of them.
//!
//! Pure Rust end to end: the KeyNet mappers are trained in-process by
//! `trainer::rust` (paper sizing rule, xs/s budgets), so the bench runs
//! on default features with no artifacts. Alongside the human-readable
//! tables it writes `BENCH_fig16.json` — one row per (dataset, backend,
//! variant, nprobe) with recall/latency/flops — so the bench trajectory
//! is tracked across commits.
//!
//! ```bash
//! cargo bench --bench fig16_backends -- --backend scann --dataset nq-s
//! ```
//! Without flags it sweeps a representative subset; AMIPS_BENCH_QUICK=1
//! shrinks it further.

use amips::api::{
    recall_against_truth, Effort, KeyNetQueryMap, MappedSearcher, QueryMode, SearchRequest,
    Searcher,
};
use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, JsonRows, JsonVal, Report};
use amips::cli::Args;
use amips::nn::{ModelKind, NetSpec};
use amips::trainer::{self, TrainOpts};
use anyhow::Result;

/// Paper size names -> parameter-budget fraction rho (Sec. 4.1).
fn rho_of(size: &str) -> f64 {
    match size {
        "xs" => 0.01,
        "s" => 0.05,
        _ => 0.01,
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let backend_filter = args.get("backend").map(str::to_string);
    let dataset_filter = args.get("dataset").map(str::to_string);
    args.reject_unknown()?;
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();

    // entries are backbone names or full spec strings (anything with a
    // '(' is parsed as a spec; bare names get the dataset-scaled nlist)
    let backends: Vec<String> = match &backend_filter {
        Some(b) => vec![b.clone()],
        None if quick => vec!["flat".into(), "ivf".into(), "scann".into()],
        None => vec![
            "flat".into(),
            "ivf".into(),
            "pq".into(),
            "sq8".into(),
            "scann".into(),
            "soar".into(),
            "leanvec".into(),
            "sharded".into(),
        ],
    };
    let datasets: Vec<&str> = match &dataset_filter {
        Some(d) => vec![d.as_str()],
        None if quick => vec!["quora-s"],
        None => vec!["quora-s", "nq-s", "hotpot-s"],
    };
    let fracs = [0.01f64, 0.025, 0.05];
    let mut json = JsonRows::new("fig16");

    for dataset in datasets {
        let ds = fixtures::prepare_dataset_or_builtin(dataset, 1)?;
        let nlist = fixtures::default_nlist(ds.n_keys());
        let truth: Vec<usize> = (0..ds.val.gt.n_queries())
            .map(|q| ds.val.gt.global_top1(q).0)
            .collect();
        // pure-Rust KeyNet mappers at the paper's xs/s budgets
        let sizes: &[&str] = if quick { &["xs"] } else { &["xs", "s"] };
        let models: Vec<(String, KeyNetQueryMap)> = sizes
            .iter()
            .filter_map(|size| {
                let spec =
                    NetSpec::sized(ModelKind::KeyNet, ds.d(), 1, ds.n_keys(), rho_of(size), 4);
                let opts = TrainOpts {
                    steps: if quick { 400 } else { fixtures::default_steps(size) },
                    ..TrainOpts::default()
                };
                let label = format!("{dataset}.keynet.{size}");
                trainer::rust::train(&spec, &label, &ds, &opts)
                    .and_then(|out| KeyNetQueryMap::new(out.model))
                    .map(|map| (size.to_string(), map))
                    .map_err(|e| eprintln!("skip {label}: {e:#}"))
                    .ok()
            })
            .collect();

        for backend in &backends {
            // "sharded" expands to 4 shards of IVF with the coarse-cell
            // budget split across them (same total cells as plain ivf)
            let spec: amips::index::IndexSpec = if backend == "sharded" {
                format!("sharded(shards=4,inner=ivf(nlist={}))", (nlist / 4).max(1)).parse()?
            } else if backend.contains('(') {
                backend.parse()?
            } else {
                amips::index::IndexSpec::default_for(backend)?.with_nlist(nlist)
            };
            let index = spec.build(
                &ds.keys,
                &amips::index::BuildCtx {
                    sample_queries: Some(&ds.train.x),
                    seed: 42,
                },
            )?;
            let mut rep = Report::new(&format!(
                "Fig 16-27 grid: {backend} on {dataset} (nlist={nlist})"
            ));
            rep.header(&["variant", "nprobe", "R@1%", "R@2.5%", "R@5%", "MFLOP/q", "ms/q"]);
            let kmax = ((ds.n_keys() as f64 * 0.05).ceil()) as usize;
            for nprobe in [1usize, 2, 4, 8, 16] {
                let mut run_variant = |label: String,
                                       searcher: &dyn Searcher,
                                       mode: QueryMode|
                 -> Result<()> {
                    let req = SearchRequest::top_k(kmax)
                        .effort(Effort::Probes(nprobe))
                        .mode(mode);
                    let out = searcher.search(&ds.val.x, &req)?;
                    let recalls: Vec<f64> = fracs
                        .iter()
                        .map(|fr| {
                            let k = ((ds.n_keys() as f64 * fr).ceil() as usize).max(1);
                            recall_against_truth(&out.hits, &truth, k)
                        })
                        .collect();
                    rep.row(&[
                        label.clone(),
                        nprobe.to_string(),
                        pct(recalls[0]),
                        pct(recalls[1]),
                        pct(recalls[2]),
                        format!("{:.3}", out.flops_per_query() / 1e6),
                        format!("{:.3}", out.seconds_per_query() * 1e3),
                    ]);
                    json.push(&[
                        ("dataset", JsonVal::S(dataset.to_string())),
                        ("backend", JsonVal::S(backend.clone())),
                        ("variant", JsonVal::S(label)),
                        ("nprobe", JsonVal::I(nprobe as u64)),
                        ("recall_1pct", JsonVal::F(recalls[0])),
                        ("recall_2_5pct", JsonVal::F(recalls[1])),
                        ("recall_5pct", JsonVal::F(recalls[2])),
                        ("mflop_per_query", JsonVal::F(out.flops_per_query() / 1e6)),
                        ("ms_per_query", JsonVal::F(out.seconds_per_query() * 1e3)),
                        (
                            "map_ms_per_query",
                            JsonVal::F(out.cost.map_seconds / out.n_queries().max(1) as f64 * 1e3),
                        ),
                    ]);
                    Ok(())
                };
                // wrap the bare backbone so the variants share one
                // &dyn Searcher call site
                let orig = MappedSearcher::original(index.as_ref());
                run_variant("orig".into(), &orig, QueryMode::Original)?;
                for (size, map) in &models {
                    let searcher = MappedSearcher::mapped(index.as_ref(), map);
                    run_variant(format!("keynet-{size}"), &searcher, QueryMode::Mapped)?;
                }
            }
            rep.note("paper shape: ordering of orig vs mapped stable across backends; SOAR narrows the regime; gains largest on shifted datasets");
            rep.note("mappers trained in-process (pure Rust); keynet→flat is the paper's drop-in MIPS replacement, keynet→ivf its ANN integration");
            rep.emit("fig16_backends");
        }
    }
    json.emit();
    Ok(())
}
