//! Figs. 16-27 (App. A.8): the backend × dataset × recall grid. One
//! parameterized harness replaces the paper's twelve panels: every
//! backbone (ivf / scann / soar / leanvec) × dataset × Recall@{1%,2.5%,5%}
//! × cost axes, original vs XS/S-mapped queries.
//!
//! ```bash
//! cargo bench --bench fig16_backends -- --backend scann --dataset nq-s
//! ```
//! Without flags it sweeps a representative subset; AMIPS_BENCH_QUICK=1
//! shrinks it further.

use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::cli::Args;
use amips::coordinator::pipeline::{recall_against_truth, MappedSearchPipeline};
use amips::index::{
    ivf::IvfIndex, leanvec::LeanVecIndex, scann::ScannIndex, soar::SoarIndex, traits::VectorIndex,
};
use amips::runtime::Engine;
use anyhow::Result;

fn build_backend(name: &str, ds: &amips::data::Dataset, nlist: usize) -> Box<dyn VectorIndex> {
    match name {
        "ivf" => Box::new(IvfIndex::build(&ds.keys, nlist, 15, 42)),
        "scann" => Box::new(ScannIndex::build(&ds.keys, nlist, 8, 4.0, 42)),
        "soar" => Box::new(SoarIndex::build(&ds.keys, nlist, 6, 42)),
        "leanvec" => Box::new(LeanVecIndex::build(
            &ds.keys,
            (ds.d() / 2).max(8),
            nlist,
            Some(&ds.train.x),
            42,
        )),
        other => panic!("unknown backend {other}"),
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let backend_filter = args.get("backend").map(str::to_string);
    let dataset_filter = args.get("dataset").map(str::to_string);
    args.reject_unknown()?;
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();

    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;

    let backends: Vec<&str> = match &backend_filter {
        Some(b) => vec![b.as_str()],
        None if quick => vec!["ivf", "scann"],
        None => vec!["ivf", "scann", "soar", "leanvec"],
    };
    let datasets: Vec<&str> = match &dataset_filter {
        Some(d) => vec![d.as_str()],
        None if quick => vec!["quora-s"],
        None => vec!["quora-s", "nq-s", "hotpot-s"],
    };
    let fracs = [0.01f64, 0.025, 0.05];

    for dataset in datasets {
        let ds = fixtures::prepare_dataset(&manifest, dataset, 1)?;
        let nlist = fixtures::default_nlist(ds.n_keys());
        let truth: Vec<usize> = (0..ds.val.gt.n_queries())
            .map(|q| ds.val.gt.global_top1(q).0)
            .collect();
        let sizes: &[&str] = if quick { &["xs"] } else { &["xs", "s"] };
        let models: Vec<_> = sizes
            .iter()
            .filter_map(|size| {
                let config = format!("{dataset}.keynet.{size}.l4.c1");
                fixtures::trained_model(&engine, &manifest, &config, &ds, None)
                    .map(|m| (size.to_string(), m))
                    .map_err(|e| eprintln!("skip {config}: {e}"))
                    .ok()
            })
            .collect();

        for backend in &backends {
            let index = build_backend(backend, &ds, nlist);
            let mut rep = Report::new(&format!(
                "Fig 16-27 grid: {backend} on {dataset} (nlist={nlist})"
            ));
            rep.header(&["variant", "nprobe", "R@1%", "R@2.5%", "R@5%", "MFLOP/q", "ms/q"]);
            let nq = ds.val.x.rows() as f64;
            let kmax = ((ds.n_keys() as f64 * 0.05).ceil()) as usize;
            for nprobe in [1usize, 2, 4, 8, 16] {
                let mut run_variant =
                    |label: String, pipe: MappedSearchPipeline| -> Result<()> {
                        let out = pipe.run(&ds.val.x, kmax, nprobe)?;
                        let recalls: Vec<String> = fracs
                            .iter()
                            .map(|fr| {
                                let k = ((ds.n_keys() as f64 * fr).ceil() as usize).max(1);
                                pct(recall_against_truth(&out.results, &truth, k))
                            })
                            .collect();
                        rep.row(&[
                            label,
                            nprobe.to_string(),
                            recalls[0].clone(),
                            recalls[1].clone(),
                            recalls[2].clone(),
                            format!(
                                "{:.3}",
                                (out.results[0].cost.flops + out.map_flops_per_query) as f64
                                    / 1e6
                            ),
                            format!(
                                "{:.3}",
                                ((out.map_seconds + out.search_seconds) / nq) * 1e3
                            ),
                        ]);
                        Ok(())
                    };
                run_variant("orig".into(), MappedSearchPipeline::original(index.as_ref()))?;
                for (size, model) in &models {
                    run_variant(
                        format!("keynet-{size}"),
                        MappedSearchPipeline::mapped(index.as_ref(), model),
                    )?;
                }
            }
            rep.note("paper shape: ordering of orig vs mapped stable across backends; SOAR narrows the regime; gains largest on shifted datasets");
            rep.emit("fig16_backends");
        }
    }
    Ok(())
}
