//! Fig. 5: FAISS-IVF-analog integration on hotpot-s — Recall vs three
//! cost axes (wall-clock latency, search budget nprobe, FLOPs) for
//! KeyNet sizes XS/S/M/L vs the unmodified query, all through
//! `api::{MappedSearcher, SearchRequest}`.
//!
//! `--dim 128` reruns on the d=128 corpus (App. A.5 analog, Figs 12-13).

use amips::api::{recall_against_truth, Effort, MappedSearcher, QueryMode, SearchRequest, Searcher};
use amips::bench_support::fixtures;
use amips::bench_support::report::{pct, Report};
use amips::cli::Args;
use amips::index::ivf::IvfIndex;
use amips::runtime::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let dataset = args.get_or("dataset", "hotpot-s").to_string();
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();
    args.reject_unknown()?;

    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let ds = fixtures::prepare_dataset(&manifest, &dataset, 1)?;
    let nlist = fixtures::default_nlist(ds.n_keys());
    let index = IvfIndex::build(&ds.keys, nlist, 15, 42);
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();
    // Recall@2.5% keeps the paper's *absolute* candidate counts (~100s)
    // at our ~100x-smaller corpus scale (DESIGN.md §3).
    let k = (ds.n_keys() / 40).max(10);

    let sizes: &[&str] = if quick { &["s"] } else { &["xs", "s", "m", "l"] };
    let mut rep = Report::new(&format!(
        "Fig 5: IVF integration on {dataset} (nlist={nlist}, Recall@2.5%={k})"
    ));
    rep.header(&["variant", "nprobe", "recall", "MFLOP/q", "ms/q"]);

    for nprobe in [1usize, 2, 4, 8, 16, 32] {
        let req = SearchRequest::top_k(k).effort(Effort::Probes(nprobe));
        let out = index.search(&ds.val.x, &req)?;
        rep.row(&[
            "orig".into(),
            nprobe.to_string(),
            pct(recall_against_truth(&out.hits, &truth, k)),
            format!("{:.3}", out.flops_per_query() / 1e6),
            format!("{:.3}", out.seconds_per_query() * 1e3),
        ]);
    }
    for size in sizes {
        let config = format!("{dataset}.keynet.{size}.l4.c1");
        let model = match fixtures::trained_model(&engine, &manifest, &config, &ds, None) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("skip {config}: {e}");
                continue;
            }
        };
        let searcher = MappedSearcher::mapped(&index, &model);
        for nprobe in [1usize, 2, 4, 8, 16, 32] {
            let req = SearchRequest::top_k(k)
                .effort(Effort::Probes(nprobe))
                .mode(QueryMode::Mapped);
            let out = searcher.search(&ds.val.x, &req)?;
            rep.row(&[
                format!("keynet-{size}"),
                nprobe.to_string(),
                pct(recall_against_truth(&out.hits, &truth, k)),
                format!("{:.3}", out.flops_per_query() / 1e6),
                format!("{:.3}", out.seconds_per_query() * 1e3),
            ]);
        }
    }
    rep.note("paper shape: mapped wins the low-nprobe (routing-limited) regime; XS/S best per-FLOP; orig catches up at high budget");
    rep.emit("fig5_ivf_integration");
    Ok(())
}
