//! Fig. 10: E_rel vs MRR at end of training on fiqa-s, across model
//! families, sizes and depths (lower-right = best).

use amips::bench_support::fixtures;
use amips::bench_support::report::{f, Report};
use amips::metrics::{retrieval, transport};
use amips::runtime::Engine;
use amips::tensor::Tensor;
use anyhow::Result;

fn main() -> Result<()> {
    let manifest = fixtures::load_manifest()?;
    let engine = Engine::new(manifest.dir.clone())?;
    let quick = std::env::var("AMIPS_BENCH_QUICK").is_ok();
    let ds = fixtures::prepare_dataset(&manifest, "fiqa-s", 1)?;
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();
    let tgt: Tensor = ds.keys.gather_rows(&truth);

    let mut rep = Report::new("Fig 10: E_rel vs MRR on fiqa-s (end of training)");
    rep.header(&["model", "size", "L", "E_rel", "MRR", "match"]);
    let sizes: &[&str] = if quick { &["s"] } else { &["xs", "s", "m"] };
    let depths: &[usize] = if quick { &[4] } else { &[2, 4] };
    for mdl in ["supportnet", "keynet"] {
        for size in sizes {
            for &layers in depths {
                let config = format!("fiqa-s.{mdl}.{size}.l{layers}.c1");
                let model =
                    match fixtures::trained_model(&engine, &manifest, &config, &ds, None) {
                        Ok(m) => m,
                        Err(e) => {
                            eprintln!("skip {config}: {e}");
                            continue;
                        }
                    };
                let (_s, keys) = model.scores_and_keys(&ds.val.x)?;
                let n = ds.val.x.rows();
                let pred = keys.reshape(&[n, ds.d()]);
                let rm = retrieval::evaluate(&pred, &ds.keys, &truth);
                let e_rel = transport::relative_transport_error(&pred, &ds.val.x, &tgt);
                rep.row(&[
                    mdl.to_string(),
                    size.to_string(),
                    layers.to_string(),
                    f(e_rel),
                    f(rm.mrr),
                    f(rm.match_rate),
                ]);
            }
        }
    }
    rep.note("paper shape: size is the main driver (improves both metrics); shallower >= deeper at small scale");
    rep.emit("fig10_tradeoffs");
    Ok(())
}
