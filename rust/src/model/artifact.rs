//! Versioned binary model artifacts (`.amm`): persist a trained
//! SupportNet/KeyNet next to the index artifacts so `Catalog`
//! collections can carry a query mapper and serving replicas reload
//! trained models without retraining.
//!
//! Layout mirrors the index artifact framing (`crate::index::artifact`),
//! little-endian throughout:
//!
//! ```text
//! magic    b"AMNN"
//! version  u32 (currently 1)
//! kind     len-prefixed utf8 tag ("supportnet" | "keynet")
//! label    len-prefixed utf8 model label
//! payload  u64 length + spec block + named parameter tensors
//! checksum u64 FNV-1a over the payload
//! ```
//!
//! The payload holds the [`NetSpec`] knobs (d, c, h, layers, nx,
//! residual, homogenize, alpha, beta) followed by the parameter tensors
//! in ABI order, each name-prefixed so drift between spec and checkpoint
//! is a typed error. Corrupt headers, short reads, checksum mismatches
//! and spec/tensor mismatches all fail loading — never panic — and a
//! reloaded model is bit-identical to the saved one.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::index::artifact::{
    fnv1a64, r_bool, r_f32, r_str, r_tensor, r_u32, r_u64, w_bool, w_f32, w_str, w_tensor, w_u32,
    w_u64,
};
use crate::model::RustModel;
use crate::nn::{ModelKind, NetSpec, Network};

/// Model-artifact magic bytes.
pub const MAGIC: &[u8; 4] = b"AMNN";
/// Current model artifact format version.
pub const VERSION: u32 = 1;
/// Conventional file extension for model artifacts.
pub const EXTENSION: &str = "amm";

fn write_payload(w: &mut dyn Write, model: &RustModel) -> Result<()> {
    let spec = model.spec();
    w_u32(w, spec.d as u32)?;
    w_u32(w, spec.c as u32)?;
    w_u32(w, spec.h as u32)?;
    w_u32(w, spec.layers as u32)?;
    w_u32(w, spec.nx as u32)?;
    w_bool(w, spec.residual)?;
    w_bool(w, spec.homogenize)?;
    w_f32(w, spec.alpha)?;
    w_f32(w, spec.beta)?;
    let specs = spec.param_specs();
    w_u32(w, specs.len() as u32)?;
    for ((name, _), tensor) in specs.iter().zip(model.params()) {
        w_str(w, name)?;
        w_tensor(w, tensor)?;
    }
    Ok(())
}

fn read_payload(r: &mut dyn Read, kind: ModelKind, label: &str) -> Result<RustModel> {
    let d = r_u32(r)? as usize;
    let c = r_u32(r)? as usize;
    let h = r_u32(r)? as usize;
    let layers = r_u32(r)? as usize;
    let nx = r_u32(r)? as usize;
    let residual = r_bool(r)?;
    let homogenize = r_bool(r)?;
    let alpha = r_f32(r)?;
    let beta = r_f32(r)?;
    let spec = NetSpec {
        model: kind,
        d,
        c,
        h,
        layers,
        nx,
        residual,
        homogenize,
        alpha,
        beta,
    };
    spec.validate()
        .with_context(|| format!("model artifact '{label}' carries an invalid spec"))?;
    let want = spec.param_specs();
    let n = r_u32(r)? as usize;
    ensure!(
        n == want.len(),
        "model artifact '{label}' holds {n} tensors, spec wants {}",
        want.len()
    );
    let mut params = Vec::with_capacity(n);
    for (want_name, _) in &want {
        let got_name = r_str(r)?;
        ensure!(
            &got_name == want_name,
            "model artifact '{label}': tensor '{got_name}' where '{want_name}' expected"
        );
        params.push(r_tensor(r)?);
    }
    // Network::new re-validates every tensor shape against the spec.
    let net = Network::new(spec, params)
        .with_context(|| format!("model artifact '{label}' payload inconsistent"))?;
    Ok(RustModel::new(label, net))
}

/// Write the complete framed artifact to any writer.
pub fn write_to(w: &mut dyn Write, model: &RustModel) -> Result<()> {
    let mut payload = Vec::new();
    write_payload(&mut payload, model)?;
    w.write_all(MAGIC)?;
    w_u32(w, VERSION)?;
    w_str(w, crate::model::AmortizedModel::kind(model).as_str())?;
    w_str(w, crate::model::AmortizedModel::label(model))?;
    w_u64(w, payload.len() as u64)?;
    w.write_all(&payload)?;
    w_u64(w, fnv1a64(&payload))?;
    Ok(())
}

/// Load a model from any reader, verifying the checksum before a single
/// payload byte is interpreted.
pub fn load_from(r: &mut dyn Read) -> Result<RustModel> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)
        .context("reading model artifact magic")?;
    ensure!(
        &magic == MAGIC,
        "bad model artifact magic {magic:?} (expected {MAGIC:?})"
    );
    let version = r_u32(r)?;
    ensure!(
        version == VERSION,
        "unsupported model artifact version {version} (this build reads version {VERSION})"
    );
    let kind = ModelKind::parse(&r_str(r)?)?;
    let label = r_str(r)?;
    let plen = r_u64(r)?;
    ensure!(
        plen <= 1 << 31,
        "implausible model artifact payload length {plen}"
    );
    let mut payload = vec![0u8; plen as usize];
    r.read_exact(&mut payload)
        .with_context(|| format!("model artifact truncated: expected a {plen}-byte payload"))?;
    let want = r_u64(r).context("model artifact truncated: missing checksum")?;
    let got = fnv1a64(&payload);
    ensure!(
        got == want,
        "model artifact checksum mismatch (stored {want:#018x}, computed {got:#018x}): corrupt file"
    );
    let mut cur: &[u8] = &payload;
    let model = read_payload(&mut cur, kind, &label)?;
    ensure!(
        cur.is_empty(),
        "model artifact '{label}' has {} trailing payload bytes",
        cur.len()
    );
    Ok(model)
}

/// Save a model artifact to disk.
pub fn save(path: &Path, model: &RustModel) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating model artifact {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    write_to(&mut w, model)?;
    w.flush()
        .with_context(|| format!("flushing model artifact {}", path.display()))?;
    Ok(())
}

/// Load a model artifact from disk.
pub fn load(path: &Path) -> Result<RustModel> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening model artifact {}", path.display()))?;
    let mut r = std::io::BufReader::new(f);
    load_from(&mut r).with_context(|| format!("loading model artifact {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::AmortizedModel;

    fn sample(kind: ModelKind) -> RustModel {
        let spec = NetSpec::new(kind, 6, 2, 8, 3);
        RustModel::init(format!("unit.{kind}"), spec, 42).unwrap()
    }

    fn bytes_of(model: &RustModel) -> Vec<u8> {
        let mut buf = Vec::new();
        write_to(&mut buf, model).unwrap();
        buf
    }

    #[test]
    fn round_trip_preserves_everything() {
        for kind in [ModelKind::SupportNet, ModelKind::KeyNet] {
            let model = sample(kind);
            let buf = bytes_of(&model);
            let back = load_from(&mut buf.as_slice()).unwrap();
            assert_eq!(back.label(), model.label());
            assert_eq!(back.spec(), model.spec());
            for (a, b) in back.params().iter().zip(model.params()) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn checksum_valid_but_inconsistent_payload_is_an_error() {
        // hand-frame a payload whose first tensor carries the wrong name:
        // the checksum passes, the semantic validation must not
        let model = sample(ModelKind::KeyNet);
        let mut payload = Vec::new();
        write_payload(&mut payload, &model).unwrap();
        // payload layout: 5 u32 + 2 bool(u32) + 2 f32 + n_tensors u32,
        // then the first name "wx0" as len-prefixed utf8 at offset 40+4
        let name_off = 9 * 4 + 4 + 4; // spec block + n_tensors + name len
        assert_eq!(&payload[name_off..name_off + 3], b"wx0");
        payload[name_off] = b'q';
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, VERSION).unwrap();
        w_str(&mut buf, "keynet").unwrap();
        w_str(&mut buf, "tampered").unwrap();
        w_u64(&mut buf, payload.len() as u64).unwrap();
        buf.extend_from_slice(&payload);
        w_u64(&mut buf, fnv1a64(&payload)).unwrap();
        let err = load_from(&mut buf.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("expected"), "{err:#}");
    }

    #[test]
    fn homogenized_keynet_tag_is_rejected() {
        // a keynet artifact whose payload claims homogenize=true must be
        // a typed spec error (NetSpec::validate), not a served model
        let model = sample(ModelKind::KeyNet);
        let mut payload = Vec::new();
        write_payload(&mut payload, &model).unwrap();
        let homog_off = 6 * 4; // after d,c,h,layers,nx,residual
        assert_eq!(payload[homog_off], 0);
        payload[homog_off] = 1;
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, VERSION).unwrap();
        w_str(&mut buf, "keynet").unwrap();
        w_str(&mut buf, "tampered").unwrap();
        w_u64(&mut buf, payload.len() as u64).unwrap();
        buf.extend_from_slice(&payload);
        w_u64(&mut buf, fnv1a64(&payload)).unwrap();
        assert!(load_from(&mut buf.as_slice()).is_err());
    }
}
