//! Model-side runtime objects: parameter sets (checkpoint IO) and the
//! user-facing amortized-model handles (SupportNet / KeyNet inference).

pub mod amortized;
pub mod params;

pub use amortized::AmortizedModel;
pub use params::ParamSet;
