//! Model-side runtime objects: parameter sets (checkpoint IO, pure Rust)
//! and the user-facing amortized-model handles (SupportNet / KeyNet
//! inference through PJRT, behind the `xla` feature).

#[cfg(feature = "xla")]
pub mod amortized;
pub mod params;

#[cfg(feature = "xla")]
pub use amortized::AmortizedModel;
pub use params::ParamSet;
