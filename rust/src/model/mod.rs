//! Model-side runtime objects, backend-agnostic: the [`AmortizedModel`]
//! inference trait with a pure-Rust implementation ([`RustModel`], the
//! default build) and an XLA/PJRT implementation ([`XlaModel`], behind
//! the `xla` feature, unchanged semantics); parameter checkpoints
//! ([`ParamSet`]); and versioned, checksummed model artifacts
//! ([`artifact`]) that persist trained models next to index artifacts.

#[cfg(feature = "xla")]
pub mod amortized;
pub mod artifact;
pub mod params;
pub mod rust_model;

use anyhow::{ensure, Result};

use crate::tensor::Tensor;

pub use crate::nn::ModelKind;
#[cfg(feature = "xla")]
pub use amortized::XlaModel;
pub use params::ParamSet;
pub use rust_model::RustModel;

/// A trained amortized model (SupportNet or KeyNet) ready for batched
/// inference on the request path — the paper's two approaches behind one
/// backend-agnostic surface. Implemented by the pure-Rust [`RustModel`]
/// and, behind the `xla` feature, by the PJRT-backed [`XlaModel`].
///
/// Deliberately *not* `Send`-bounded: the PJRT implementation pins to
/// one thread. [`RustModel`] itself is `Send + Sync`, so pure-Rust
/// callers (the server's mapper factory, the catalog) can move it across
/// threads as the concrete type.
pub trait AmortizedModel {
    /// Human-readable label (config/artifact name) for reports.
    fn label(&self) -> &str;

    /// SupportNet or KeyNet.
    fn kind(&self) -> ModelKind;

    /// Embedding dimension `d`.
    fn dim(&self) -> usize;

    /// Number of output heads `c` (clusters routed over; 1 for the
    /// mapped query path).
    fn n_heads(&self) -> usize;

    /// FLOPs for scoring one query (paper's cost axes).
    fn score_flops(&self) -> u64;

    /// FLOPs for recovering keys for one query (SupportNet pays the
    /// per-head backward pass, Sec. 4.4).
    fn key_flops(&self) -> u64;

    /// Per-cluster support scores for a batch of queries: `[n, c]`.
    fn scores(&self, queries: &Tensor) -> Result<Tensor>;

    /// Scores **and** predicted keys: `([n, c], [n, c, d])`.
    fn scores_and_keys(&self, queries: &Tensor) -> Result<(Tensor, Tensor)>;

    /// Predicted top key per query, flattened to `[n, d]` (`c` must
    /// be 1): the drop-in replacement vector `ŷ(x)` of Sec. 4.4.
    fn map_queries(&self, queries: &Tensor) -> Result<Tensor> {
        ensure!(
            self.n_heads() == 1,
            "map_queries requires a c=1 model, got c={}",
            self.n_heads()
        );
        let (_, keys) = self.scores_and_keys(queries)?;
        let n = queries.rows();
        Ok(keys.reshape(&[n, self.dim()]))
    }
}
