//! The XLA/PJRT-backed [`crate::model::AmortizedModel`] implementation:
//! load a trained SupportNet or KeyNet from the AOT artifacts and run
//! batched inference on the request path.
//!
//! Inference uses the AOT artifacts: `fwd` (scores, + keys for KeyNet;
//! the Pallas L1 kernel lowered inside) and `grad` (SupportNet key
//! recovery via autodiff). Queries are processed in fixed-size chunks of
//! the artifact batch `B`, padding the tail — the same discipline the
//! serving batcher uses.

use anyhow::{bail, Result};
use std::rc::Rc;

use crate::runtime::engine::{lit_f32, literal_to_vec, Engine, Executable};
use crate::runtime::ArtifactMeta;
use crate::tensor::Tensor;

/// A loaded amortized model (SupportNet or KeyNet) with trained params,
/// executing through PJRT. Pinned to the thread that built its engine
/// (`!Send`); the pure-Rust counterpart is [`crate::model::RustModel`].
pub struct XlaModel {
    pub meta: ArtifactMeta,
    fwd: Rc<Executable>,
    /// SupportNet only: scores+keys via input-gradient.
    grad: Option<Rc<Executable>>,
    /// Parameter literals in ABI order, kept ready for execution.
    param_lits: Vec<xla::Literal>,
}

/// Batched inference output.
pub struct Inference {
    /// [n, c] per-cluster support scores.
    pub scores: Tensor,
    /// [n, c, d] predicted keys (None for SupportNet via fwd-only path).
    pub keys: Option<Tensor>,
}

impl XlaModel {
    /// Load from engine + metadata + trained parameters.
    pub fn load(engine: &Engine, meta: ArtifactMeta, params: &crate::model::ParamSet) -> Result<XlaModel> {
        params.validate(&meta)?;
        let fwd = engine.load(&format!("{}.fwd", meta.name))?;
        let grad = if meta.model == "supportnet" {
            Some(engine.load(&format!("{}.grad", meta.name))?)
        } else {
            None
        };
        let param_lits = params
            .tensors
            .iter()
            .map(|t| lit_f32(t.shape(), t.data()))
            .collect::<Result<Vec<_>>>()?;
        Ok(XlaModel {
            meta,
            fwd,
            grad,
            param_lits,
        })
    }

    pub fn is_supportnet(&self) -> bool {
        self.meta.model == "supportnet"
    }

    /// FLOPs for scoring one query (paper's cost axes).
    pub fn score_flops(&self) -> u64 {
        self.meta.fwd_flops
    }

    /// FLOPs for recovering keys for one query.
    pub fn key_flops(&self) -> u64 {
        if self.is_supportnet() {
            // fwd + c backward passes (paper Sec. 4.4: bwd ~ 2x fwd)
            self.meta.grad_flops
        } else {
            self.meta.fwd_flops
        }
    }

    fn run_chunked(
        &self,
        exe: &Executable,
        queries: &Tensor,
        want_keys: bool,
    ) -> Result<Inference> {
        let (n, d) = (queries.rows(), queries.row_width());
        if d != self.meta.d {
            bail!("query dim {d} != model dim {}", self.meta.d);
        }
        let b = self.meta.train_batch;
        let c = self.meta.c;
        let mut scores = Tensor::zeros(&[n, c]);
        let mut keys = if want_keys {
            Some(Tensor::zeros(&[n, c, d]))
        } else {
            None
        };
        let mut chunk = vec![0.0f32; b * d];
        let mut start = 0;
        while start < n {
            let end = (start + b).min(n);
            let take = end - start;
            // pad the tail chunk by repeating the last row
            chunk[..take * d].copy_from_slice(&queries.data()[start * d..end * d]);
            for p in take..b {
                chunk.copy_within((take - 1) * d..take * d, p * d);
            }
            let x = lit_f32(&[b, d], &chunk)?;
            let mut inputs: Vec<&xla::Literal> = self.param_lits.iter().collect();
            inputs.push(&x);
            let out = exe.run(&inputs)?;
            let s = literal_to_vec(&out[0])?;
            scores.data_mut()[start * c..end * c].copy_from_slice(&s[..take * c]);
            if want_keys {
                let kv = literal_to_vec(&out[1])?;
                keys.as_mut().unwrap().data_mut()[start * c * d..end * c * d]
                    .copy_from_slice(&kv[..take * c * d]);
            }
            start = end;
        }
        Ok(Inference { scores, keys })
    }

    /// Per-cluster support scores for a batch of queries: [n, c].
    ///
    /// SupportNet reads them from the forward pass; KeyNet derives them
    /// as ⟨F_j(x), x⟩ (computed in-graph).
    pub fn scores(&self, queries: &Tensor) -> Result<Tensor> {
        let want_keys = !self.is_supportnet();
        let inf = self.run_chunked(&self.fwd, queries, want_keys)?;
        Ok(inf.scores)
    }

    /// Scores **and** predicted keys: ([n,c], [n,c,d]).
    ///
    /// SupportNet pays the backward pass here (the paper's Table-1
    /// asymmetry); KeyNet gets keys from the same forward.
    pub fn scores_and_keys(&self, queries: &Tensor) -> Result<(Tensor, Tensor)> {
        let exe = match &self.grad {
            Some(g) => g.clone(),
            None => self.fwd.clone(),
        };
        let inf = self.run_chunked(&exe, queries, true)?;
        Ok((inf.scores, inf.keys.unwrap()))
    }

    /// Predicted top-key per query, flattened to [n, d] (c must be 1):
    /// the drop-in replacement vector ŷ(x) of Sec. 4.4.
    pub fn map_queries(&self, queries: &Tensor) -> Result<Tensor> {
        if self.meta.c != 1 {
            bail!("map_queries requires a c=1 model, got c={}", self.meta.c);
        }
        let (_, keys) = self.scores_and_keys(queries)?;
        let n = queries.rows();
        let d = self.meta.d;
        Ok(keys.reshape(&[n, d]))
    }
}

impl crate::model::AmortizedModel for XlaModel {
    fn label(&self) -> &str {
        &self.meta.name
    }

    fn kind(&self) -> crate::nn::ModelKind {
        if self.is_supportnet() {
            crate::nn::ModelKind::SupportNet
        } else {
            crate::nn::ModelKind::KeyNet
        }
    }

    fn dim(&self) -> usize {
        self.meta.d
    }

    fn n_heads(&self) -> usize {
        self.meta.c
    }

    fn score_flops(&self) -> u64 {
        XlaModel::score_flops(self)
    }

    fn key_flops(&self) -> u64 {
        XlaModel::key_flops(self)
    }

    fn scores(&self, queries: &Tensor) -> Result<Tensor> {
        XlaModel::scores(self, queries)
    }

    fn scores_and_keys(&self, queries: &Tensor) -> Result<(Tensor, Tensor)> {
        XlaModel::scores_and_keys(self, queries)
    }

    fn map_queries(&self, queries: &Tensor) -> Result<Tensor> {
        XlaModel::map_queries(self, queries)
    }
}

/// A trained c=1 KeyNet is the canonical [`crate::api::QueryMap`]: it
/// plugs into [`crate::api::MappedSearcher`] in front of any backbone.
impl crate::api::QueryMap for XlaModel {
    fn label(&self) -> &str {
        &self.meta.name
    }

    fn map_flops_per_query(&self) -> u64 {
        self.key_flops()
    }

    fn map(&self, queries: &Tensor) -> Result<Tensor> {
        self.map_queries(queries)
    }
}
