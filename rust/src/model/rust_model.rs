//! The pure-Rust [`AmortizedModel`]: a labelled [`nn::Network`] — the
//! default-build implementation that [`crate::trainer::rust`] produces
//! and [`crate::model::artifact`] persists. `Send + Sync`, so the
//! serving coordinator can build it anywhere (unlike the PJRT handle).

use anyhow::Result;

use crate::model::AmortizedModel;
use crate::nn::{ModelKind, NetSpec, Network};
use crate::tensor::Tensor;

/// A trained pure-Rust SupportNet or KeyNet.
#[derive(Clone, Debug)]
pub struct RustModel {
    label: String,
    net: Network,
}

impl RustModel {
    pub fn new(label: impl Into<String>, net: Network) -> RustModel {
        RustModel {
            label: label.into(),
            net,
        }
    }

    /// Freshly initialized (untrained) model — tests and demos.
    pub fn init(label: impl Into<String>, spec: NetSpec, seed: u64) -> Result<RustModel> {
        Ok(RustModel::new(label, Network::init(spec, seed)?))
    }

    pub fn spec(&self) -> &NetSpec {
        self.net.spec()
    }

    pub fn network(&self) -> &Network {
        &self.net
    }

    pub fn params(&self) -> &[Tensor] {
        self.net.params()
    }
}

impl AmortizedModel for RustModel {
    fn label(&self) -> &str {
        &self.label
    }

    fn kind(&self) -> ModelKind {
        self.net.spec().model
    }

    fn dim(&self) -> usize {
        self.net.spec().d
    }

    fn n_heads(&self) -> usize {
        self.net.spec().c
    }

    fn score_flops(&self) -> u64 {
        self.net.spec().forward_flops()
    }

    fn key_flops(&self) -> u64 {
        self.net.spec().key_flops()
    }

    fn scores(&self, queries: &Tensor) -> Result<Tensor> {
        self.net.scores(queries)
    }

    fn scores_and_keys(&self, queries: &Tensor) -> Result<(Tensor, Tensor)> {
        self.net.scores_and_keys(queries)
    }
}

/// Static guarantee the serving coordinator relies on: the pure-Rust
/// model crosses threads (its factory closure must be `Send`).
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<RustModel>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn trait_surface_delegates_to_network() {
        let spec = NetSpec::new(ModelKind::KeyNet, 6, 1, 8, 2);
        let m = RustModel::init("t.keynet", spec, 1).unwrap();
        assert_eq!(m.label(), "t.keynet");
        assert_eq!((m.dim(), m.n_heads()), (6, 1));
        assert_eq!(m.kind(), ModelKind::KeyNet);
        assert_eq!(m.score_flops(), m.key_flops()); // keynet: keys from fwd
        let q = unit(&[3, 6], 2);
        let mapped = m.map_queries(&q).unwrap();
        assert_eq!(mapped.shape(), &[3, 6]);
        let (_, keys) = m.scores_and_keys(&q).unwrap();
        assert_eq!(mapped.data(), keys.data());
    }

    #[test]
    fn map_queries_requires_single_head() {
        let spec = NetSpec::new(ModelKind::SupportNet, 4, 3, 6, 2);
        let m = RustModel::init("router", spec, 3).unwrap();
        assert!(m.key_flops() > m.score_flops()); // supportnet pays bwd
        assert!(m.map_queries(&unit(&[2, 4], 4)).is_err());
    }
}
