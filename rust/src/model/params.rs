//! Parameter sets: the ordered tensor list crossing the AOT ABI, with
//! checkpoint save/load in the `.amts` container format.

use anyhow::{bail, Result};
use std::path::Path;

use crate::runtime::ArtifactMeta;
use crate::tensor::{load_tensor_set, save_tensor_set, Tensor};

/// Ordered parameter tensors for one model (ABI order = meta order).
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub tensors: Vec<Tensor>,
}

impl ParamSet {
    /// Validate against the artifact metadata's declared shapes.
    pub fn validate(&self, meta: &ArtifactMeta) -> Result<()> {
        if self.tensors.len() != meta.params.len() {
            bail!(
                "{}: checkpoint has {} tensors, meta wants {}",
                meta.name,
                self.tensors.len(),
                meta.params.len()
            );
        }
        for (t, (pname, shape)) in self.tensors.iter().zip(&meta.params) {
            if t.shape() != &shape[..] {
                bail!(
                    "{}: param {pname} shape {:?} != meta {:?}",
                    meta.name,
                    t.shape(),
                    shape
                );
            }
        }
        Ok(())
    }

    /// Total parameter count.
    pub fn n_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    pub fn save(&self, meta: &ArtifactMeta, path: &Path) -> Result<()> {
        let items: Vec<(String, &Tensor)> = meta
            .params
            .iter()
            .map(|(n, _)| n.clone())
            .zip(self.tensors.iter())
            .collect();
        save_tensor_set(path, &items)
    }

    pub fn load(meta: &ArtifactMeta, path: &Path) -> Result<ParamSet> {
        let items = load_tensor_set(path)?;
        if items.len() != meta.params.len() {
            bail!(
                "checkpoint {} has {} tensors, meta {} wants {}",
                path.display(),
                items.len(),
                meta.name,
                meta.params.len()
            );
        }
        // Enforce name order to catch ABI drift between exports.
        for ((got_name, _), (want_name, _)) in items.iter().zip(&meta.params) {
            if got_name != want_name {
                bail!(
                    "checkpoint {}: tensor {got_name} where {want_name} expected",
                    path.display()
                );
            }
        }
        let ps = ParamSet {
            tensors: items.into_iter().map(|(_, t)| t).collect(),
        };
        ps.validate(meta)?;
        Ok(ps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ArtifactMeta;

    fn meta() -> ArtifactMeta {
        ArtifactMeta::parse(
            "name t\ndataset t\nmodel keynet\nd 4\nc 1\nh 8\nlayers 2\nnx 2\ninject 1\nresidual 0\nhomogenize 0\nalpha 0.1\nbeta 20.0\nsize xs\nrho 0.01\ntrain_batch 4\neval_batch 8\ntiming_batch 0\nn_params 10\nn_param_tensors 2\nn_state_tensors 9\nfwd_flops 1\ngrad_flops 2\nparam wx0 4,8\nparam b0 8\n",
        )
        .unwrap()
    }

    #[test]
    fn validate_accepts_matching() {
        let ps = ParamSet {
            tensors: vec![Tensor::zeros(&[4, 8]), Tensor::zeros(&[8])],
        };
        ps.validate(&meta()).unwrap();
    }

    #[test]
    fn validate_rejects_wrong_shape() {
        let ps = ParamSet {
            tensors: vec![Tensor::zeros(&[4, 8]), Tensor::zeros(&[9])],
        };
        assert!(ps.validate(&meta()).is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = meta();
        let mut t0 = Tensor::zeros(&[4, 8]);
        t0.data_mut()[3] = 1.5;
        let ps = ParamSet {
            tensors: vec![t0, Tensor::zeros(&[8])],
        };
        let path = std::env::temp_dir().join("amips_params_test.amts");
        ps.save(&m, &path).unwrap();
        let back = ParamSet::load(&m, &path).unwrap();
        assert_eq!(back.tensors[0].data()[3], 1.5);
        let _ = std::fs::remove_file(path);
    }
}
