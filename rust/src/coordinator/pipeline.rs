//! The drop-in integration pipeline (paper Sec. 4.4): optionally map
//! each query x -> ŷ(x) with a c=1 KeyNet, then hand the (mapped) vector
//! to an *unmodified* index backbone. Cost accounting covers both stages
//! so the FLOPs Pareto axes include the forward-pass overhead.

use anyhow::Result;

use crate::index::traits::{SearchResult, VectorIndex};
use crate::model::AmortizedModel;
use crate::tensor::Tensor;
use crate::util::Timer;

/// Search pipeline with an optional learned query map.
pub struct MappedSearchPipeline<'a> {
    pub index: &'a dyn VectorIndex,
    /// None = "Original" baseline (query goes straight to the index).
    pub mapper: Option<&'a AmortizedModel>,
}

/// Batch outcome with aggregate cost/latency.
pub struct PipelineOutcome {
    pub results: Vec<SearchResult>,
    /// mapping flops per query (0 for the baseline)
    pub map_flops_per_query: u64,
    /// wall-clock for the mapping stage (whole batch)
    pub map_seconds: f64,
    /// wall-clock for the search stage (whole batch)
    pub search_seconds: f64,
}

impl<'a> MappedSearchPipeline<'a> {
    pub fn original(index: &'a dyn VectorIndex) -> Self {
        MappedSearchPipeline {
            index,
            mapper: None,
        }
    }

    pub fn mapped(index: &'a dyn VectorIndex, model: &'a AmortizedModel) -> Self {
        MappedSearchPipeline {
            index,
            mapper: Some(model),
        }
    }

    pub fn label(&self) -> &'static str {
        if self.mapper.is_some() {
            "mapped"
        } else {
            "orig"
        }
    }

    /// Run the batch through (map?) -> index.search.
    pub fn run(&self, queries: &Tensor, k: usize, nprobe: usize) -> Result<PipelineOutcome> {
        let (mapped, map_flops, map_seconds) = match self.mapper {
            Some(model) => {
                let t = Timer::start();
                let m = model.map_queries(queries)?;
                (Some(m), model.key_flops(), t.elapsed_s())
            }
            None => (None, 0, 0.0),
        };
        let effective = mapped.as_ref().unwrap_or(queries);
        let t = Timer::start();
        let results = self.index.search_batch(effective, k, nprobe);
        let search_seconds = t.elapsed_s();
        Ok(PipelineOutcome {
            results,
            map_flops_per_query: map_flops,
            map_seconds,
            search_seconds,
        })
    }
}

/// Recall@k of a pipeline outcome against exact top-1 targets: the
/// paper's "Recall@f%" metric is recall of y* within the top ⌈f·n⌉
/// returned candidates.
pub fn recall_against_truth(results: &[SearchResult], truth: &[usize], k: usize) -> f64 {
    assert_eq!(results.len(), truth.len());
    if results.is_empty() {
        return 0.0;
    }
    let hits = results
        .iter()
        .zip(truth)
        .filter(|(r, &t)| r.ids.iter().take(k).any(|&id| id as usize == t))
        .count();
    hits as f64 / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::flat::FlatIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn original_pipeline_is_passthrough() {
        let keys = unit(&[100, 8], 1);
        let idx = FlatIndex::new(keys.clone());
        let pipe = MappedSearchPipeline::original(&idx);
        let q = unit(&[5, 8], 2);
        let out = pipe.run(&q, 3, 0).unwrap();
        assert_eq!(out.results.len(), 5);
        assert_eq!(out.map_flops_per_query, 0);
        // matches a direct index call
        let direct = idx.search(q.row(0), 3, 0);
        assert_eq!(out.results[0].ids, direct.ids);
    }

    #[test]
    fn recall_counts_prefix_hits() {
        let keys = unit(&[50, 8], 3);
        let idx = FlatIndex::new(keys.clone());
        let pipe = MappedSearchPipeline::original(&idx);
        // queries exactly equal to keys 7 and 9
        let q = keys.gather_rows(&[7, 9]);
        let out = pipe.run(&q, 1, 0).unwrap();
        assert_eq!(recall_against_truth(&out.results, &[7, 9], 1), 1.0);
        assert_eq!(recall_against_truth(&out.results, &[7, 0], 1), 0.5);
    }
}
