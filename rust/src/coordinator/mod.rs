//! The serving coordinator — L3's contribution: cluster routing with
//! learned support functions, dynamic batching, and a threaded request
//! loop speaking the [`crate::api`] request/response types. Query
//! mapping (the old `MappedSearchPipeline`) lives in
//! [`crate::api::MappedSearcher`]; routed search over IVF cells in
//! [`crate::api::RoutedSearcher`]. Python never appears here; the models
//! are AOT artifacts loaded through `crate::runtime` (behind the `xla`
//! feature).
//!
//! Deployment: [`Server::start_from_catalog`] serves a prebuilt
//! collection from an [`crate::index::Catalog`] of persisted index
//! artifacts — the build-once / serve-many path (`amips build` +
//! `amips serve --catalog`).

pub mod batcher;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
#[cfg(feature = "xla")]
pub use router::AmortizedRouter;
pub use router::{CentroidRouter, Router, RoutingDecision};
pub use server::{MapperFactory, Response, Server, ServerConfig, ServerHandle};
