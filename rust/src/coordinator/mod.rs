//! The serving coordinator — L3's contribution: cluster routing with
//! learned support functions, query mapping with KeyNet, dynamic
//! batching, and a threaded request loop. Python never appears here;
//! the models are the AOT artifacts loaded through [`crate::runtime`].

pub mod batcher;
pub mod pipeline;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use pipeline::MappedSearchPipeline;
pub use router::{AmortizedRouter, CentroidRouter, Router, RoutingDecision};
pub use server::{Server, ServerConfig, ServerHandle};
