//! The serving coordinator — L3's contribution: cluster routing with
//! learned support functions, dynamic batching, and a threaded request
//! loop speaking the [`crate::api`] request/response types. Query
//! mapping (the old `MappedSearchPipeline`) lives in
//! [`crate::api::MappedSearcher`]; routed search over IVF cells in
//! [`crate::api::RoutedSearcher`]. The learned router and the server's
//! KeyNet mapper run on any [`crate::model::AmortizedModel`] backend —
//! pure Rust by default, PJRT-backed under the `xla` feature.
//!
//! Deployment: [`Server::start_from_catalog`] serves a prebuilt
//! collection from an [`crate::index::Catalog`] of persisted index
//! artifacts — the build-once / serve-many path (`amips build` +
//! `amips serve --catalog`), including a persisted model artifact as
//! the collection's query mapper. The [`net`] module puts a TCP
//! front-end on the same batching path (`amips serve --catalog
//! --listen <addr>`): framed wire protocol, deadline-aware batching,
//! bounded admission, multi-tenant routing over the whole catalog.

pub mod batcher;
pub mod net;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use net::{NetClient, NetServer, NetServerConfig};
pub use router::{AmortizedRouter, CentroidRouter, Router, RoutingDecision};
pub use server::{MapperFactory, Response, Server, ServerConfig, ServerHandle};
