//! Deterministic fault injection for the net stack (test support).
//!
//! [`FaultyStream`] wraps any `Read + Write` transport and misbehaves
//! on purpose, driven entirely by a seeded [`Rng`] so every failure a
//! sweep finds is replayable from its printed seed:
//!
//! * **byte-split writes** — each write forwards a random 1..=`max_chunk`
//!   prefix, so frames cross the wire in arbitrary fragments and the
//!   peer's decoder sees every possible partial-header/partial-payload
//!   boundary;
//! * **injected delays** — with probability `delay_prob` a chunk (or a
//!   read) first sleeps `delay`, simulating a slow or bursty peer;
//! * **half-write-then-drop** — after `cut_after` total bytes the
//!   stream forwards one final short write and then fails every
//!   subsequent operation with `BrokenPipe`; dropping the wrapper then
//!   closes the inner transport mid-frame, which is exactly the torn
//!   state a crashed client leaves behind;
//! * **stalled reads** — the same `delay` machinery applies on the
//!   read path (slow-loris from the server's perspective).
//!
//! The wrapper lives in the library (not under `#[cfg(test)]`) so
//! integration tests and benches can use it, but it is test support:
//! nothing in the serving path constructs one.

use std::io::{Read, Write};
use std::time::Duration;

use crate::util::Rng;

/// What misbehavior to inject, and with what RNG seed. The default
/// plan is a no-op passthrough.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// Seed for the wrapper's private RNG (print it on failure).
    pub seed: u64,
    /// Forward at most this many bytes per write call (0 or
    /// `usize::MAX` disables splitting; 1 = strict byte-at-a-time).
    pub max_chunk: usize,
    /// Per-operation probability, in permille (0..=1000), of sleeping
    /// `delay` before the operation proceeds.
    pub delay_permille: u32,
    /// The injected sleep.
    pub delay: Duration,
    /// Fail every operation after this many bytes have been written
    /// (the crossing write is forwarded short first: a half-written
    /// frame, then the drop). `u64::MAX` disables.
    pub cut_after: u64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0,
            max_chunk: 0,
            delay_permille: 0,
            delay: Duration::from_millis(0),
            cut_after: u64::MAX,
        }
    }
}

impl FaultPlan {
    /// A frame-tearing plan: tiny write chunks with occasional short
    /// delays, no cut. Exercises every partial-frame boundary.
    pub fn splitter(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            max_chunk: 3,
            delay_permille: 100,
            delay: Duration::from_micros(200),
            ..FaultPlan::default()
        }
    }

    /// A crash plan: byte-split writes that die after `cut_after`
    /// bytes, leaving a torn frame on the wire.
    pub fn cutter(seed: u64, cut_after: u64) -> FaultPlan {
        FaultPlan {
            seed,
            max_chunk: 5,
            cut_after,
            ..FaultPlan::default()
        }
    }
}

/// A misbehaving transport. See the module doc.
pub struct FaultyStream<S> {
    inner: S,
    rng: Rng,
    plan: FaultPlan,
    written: u64,
    cut: bool,
}

impl<S> FaultyStream<S> {
    pub fn new(inner: S, plan: FaultPlan) -> FaultyStream<S> {
        FaultyStream {
            inner,
            rng: Rng::new(plan.seed),
            plan,
            written: 0,
            cut: false,
        }
    }

    /// True once the cut point has been crossed (every further
    /// operation fails with `BrokenPipe`).
    pub fn is_cut(&self) -> bool {
        self.cut
    }

    /// Total bytes forwarded to the inner writer.
    pub fn bytes_written(&self) -> u64 {
        self.written
    }

    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    pub fn into_inner(self) -> S {
        self.inner
    }

    fn maybe_delay(&mut self) {
        if self.plan.delay_permille > 0
            && (self.rng.below(1000) as u32) < self.plan.delay_permille
        {
            std::thread::sleep(self.plan.delay);
        }
    }

    fn broken() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::BrokenPipe, "fault injection: stream cut")
    }
}

impl<S: Write> Write for FaultyStream<S> {
    /// Forward a random-size prefix of `buf` (callers' `write_all`
    /// loops re-enter for the rest, so a frame crosses in fragments).
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.cut {
            return Err(Self::broken());
        }
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        self.maybe_delay();
        let mut n = match self.plan.max_chunk {
            0 | usize::MAX => buf.len(),
            cap => 1 + self.rng.below(cap.min(buf.len())),
        };
        // crossing the cut point: forward the short remainder, then die
        if self.written + n as u64 >= self.plan.cut_after {
            n = (self.plan.cut_after - self.written) as usize;
            self.cut = true;
            if n == 0 {
                return Err(Self::broken());
            }
        }
        let n = self.inner.write(&buf[..n])?;
        self.written += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if self.cut {
            return Err(Self::broken());
        }
        self.inner.flush()
    }
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.cut {
            return Err(Self::broken());
        }
        self.maybe_delay();
        self.inner.read(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passthrough_by_default() {
        let mut s = FaultyStream::new(Vec::new(), FaultPlan::default());
        s.write_all(b"hello world").unwrap();
        assert_eq!(s.get_ref().as_slice(), b"hello world");
        assert!(!s.is_cut());
    }

    #[test]
    fn splitter_preserves_bytes_and_is_deterministic() {
        let payload: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let mut first_chunks = None;
        for _ in 0..2 {
            let mut s = FaultyStream::new(CountingWriter::default(), FaultPlan::splitter(7));
            s.write_all(&payload).unwrap();
            let w = s.into_inner();
            assert_eq!(w.bytes, payload, "splitting must not reorder or drop");
            assert!(w.calls > payload.len() / 3, "writes were not split");
            // same seed, same fragmentation
            match &first_chunks {
                None => first_chunks = Some(w.calls),
                Some(c) => assert_eq!(*c, w.calls, "same seed must split identically"),
            }
        }
    }

    #[test]
    fn cutter_half_writes_then_fails() {
        let mut s = FaultyStream::new(Vec::new(), FaultPlan::cutter(3, 10));
        let err = s.write_all(&[0xAB; 64]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);
        assert!(s.is_cut());
        assert_eq!(s.bytes_written(), 10, "exactly cut_after bytes escape");
        assert_eq!(s.get_ref().len(), 10);
        // everything after the cut fails too
        assert!(s.write(&[1]).is_err());
        assert!(s.flush().is_err());
    }

    #[derive(Default)]
    struct CountingWriter {
        bytes: Vec<u8>,
        calls: usize,
    }

    impl Write for CountingWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.calls += 1;
            self.bytes.extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }
}
