//! The AMTP wire format: a versioned, length-framed binary protocol for
//! serving MIPS over TCP.
//!
//! Every frame is self-delimiting:
//!
//! ```text
//! [magic "AMTP" (4)] [version u8] [tag u8] [len u32 LE] [payload: len bytes]
//! ```
//!
//! and every multi-byte integer/float in a payload is little-endian.
//! Decoding is defensive in the style of [`crate::tensor::Tensor::read_from`]:
//! declared lengths and element counts are capped *before* any
//! allocation (`checked_mul`, remaining-byte checks), unknown tags and
//! version mismatches are typed [`WireError`]s, and trailing payload
//! bytes are rejected so a desynchronized stream fails fast instead of
//! silently mis-parsing the next frame. A crafted or corrupted frame can
//! therefore cost at most [`MAX_FRAME_LEN`] bytes of memory and never
//! panics the decoder (fuzz-tested below).
//!
//! Frame types: `Search` (collection + query + k/effort/mode + optional
//! deadline) answered by `Hits` or `Error`; `Ping` answered by `Pong`;
//! `StatsRequest` answered by `Stats` (server-wide latency percentiles,
//! queue depth and per-collection counters); `Mutate`
//! (insert/upsert/delete against a mutable collection) and `Compact`
//! answered by `Mutated` or `Error`. Error replies carry a stable
//! [`ErrorCode`] so clients can react to `Overloaded` /
//! `DeadlineExpired` / `ShuttingDown` without string matching.
//!
//! **Versioning.** Two wire versions coexist; the header's version byte
//! selects the payload layout *per frame*:
//!
//! * **v1** — strict request/reply alternation, no request ids (the
//!   PR 6/7 protocol, kept bit-compatible for legacy clients).
//! * **v2** — `Search`/`Mutate`/`Compact` payloads begin with a
//!   client-assigned `request_id: u64`, echoed at the head of
//!   `Hits`/`Mutated`/`Error` replies. Ids make replies self-describing,
//!   so a connection may keep many requests in flight and receive
//!   completions out of order. `Ping`/`Pong`/`StatsRequest`/`Stats`
//!   payloads are identical in both versions (the ping token already
//!   serves as a correlation id).
//!
//! A server replies in the version of the frame it is answering; a
//! client discovers the server's ceiling by sending a v2 `Ping` at
//! connect and downgrading on a typed version rejection (see
//! `NetClient::connect`).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::api::{Effort, QueryMode};

/// Per-frame magic bytes ("AMips Transport Protocol").
pub const MAGIC: [u8; 4] = *b"AMTP";
/// Newest protocol version spoken by this build (request ids,
/// out-of-order completion).
pub const VERSION: u8 = 2;
/// The legacy strict-alternation protocol (no request ids).
pub const V1: u8 = 1;
/// Oldest version this build still decodes.
pub const MIN_VERSION: u8 = 1;
/// Frame header size: magic + version + tag + payload length.
pub const HEADER_LEN: usize = 10;
/// Hard cap on one frame's payload (guards decoder allocations).
pub const MAX_FRAME_LEN: u32 = 1 << 24; // 16 MiB
/// Cap on collection-name bytes.
pub const MAX_NAME_LEN: usize = 256;
/// Cap on error-message bytes.
pub const MAX_MSG_LEN: usize = 4096;
/// Cap on query dimensionality over the wire.
pub const MAX_DIM: usize = 1 << 20;
/// Cap on hits per reply, and therefore on an admissible request `k`
/// (the server rejects larger `k` with `BadRequest` before allocating
/// anything, so every legitimately-admitted reply is encodable).
pub const MAX_HITS: usize = 1 << 20;
/// Cap on per-collection stats entries in one `Stats` frame.
pub const MAX_COLLECTIONS: usize = 4096;

/// Frame tags (the `tag` header byte).
mod tag {
    pub const SEARCH: u8 = 1;
    pub const HITS: u8 = 2;
    pub const ERROR: u8 = 3;
    pub const PING: u8 = 4;
    pub const PONG: u8 = 5;
    pub const STATS_REQUEST: u8 = 6;
    pub const STATS: u8 = 7;
    pub const MUTATE: u8 = 8;
    pub const MUTATED: u8 = 9;
    pub const COMPACT: u8 = 10;
}

/// Stable error codes carried by `Error` frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum ErrorCode {
    /// Malformed frame or invalid request parameters (wrong dim, …).
    BadRequest = 1,
    /// The named collection is not served here.
    UnknownCollection = 2,
    /// The request's deadline passed before its batch was scanned.
    DeadlineExpired = 3,
    /// Admission control rejected the request (bounded queue full).
    Overloaded = 4,
    /// The server is draining; retry against another replica.
    ShuttingDown = 5,
    /// Frame type or query mode not supported by this server.
    Unsupported = 6,
    /// Server-side failure while serving an admitted request.
    Internal = 7,
}

impl ErrorCode {
    pub fn from_u16(v: u16) -> Option<ErrorCode> {
        Some(match v {
            1 => ErrorCode::BadRequest,
            2 => ErrorCode::UnknownCollection,
            3 => ErrorCode::DeadlineExpired,
            4 => ErrorCode::Overloaded,
            5 => ErrorCode::ShuttingDown,
            6 => ErrorCode::Unsupported,
            7 => ErrorCode::Internal,
            _ => return None,
        })
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::UnknownCollection => "unknown-collection",
            ErrorCode::DeadlineExpired => "deadline-expired",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::ShuttingDown => "shutting-down",
            ErrorCode::Unsupported => "unsupported",
            ErrorCode::Internal => "internal",
        };
        f.write_str(s)
    }
}

/// Typed decode/transport failure. Decoding never panics: every
/// malformed input maps to one of these.
#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    /// Peer closed the connection at a frame boundary.
    Closed,
    BadMagic([u8; 4]),
    BadVersion(u8),
    UnknownTag(u8),
    /// A declared length exceeds its cap (rejected before allocating).
    Oversized { what: &'static str, declared: u64, cap: u64 },
    /// Payload ended before the declared content.
    Truncated { what: &'static str },
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io error: {e}"),
            WireError::Closed => write!(f, "connection closed"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:?} (expected {MAGIC:?})"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (this build speaks {VERSION})")
            }
            WireError::UnknownTag(t) => write!(f, "unknown frame tag {t}"),
            WireError::Oversized { what, declared, cap } => {
                write!(f, "declared {what} length {declared} exceeds cap {cap}")
            }
            WireError::Truncated { what } => write!(f, "frame truncated while reading {what}"),
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

impl WireError {
    /// The error code a server should reply with for this decode error.
    pub fn reply_code(&self) -> ErrorCode {
        match self {
            WireError::UnknownTag(_) | WireError::BadVersion(_) => ErrorCode::Unsupported,
            _ => ErrorCode::BadRequest,
        }
    }
}

/// A search request over the wire. `deadline_micros` is the client's
/// latency budget relative to frame send (0 = none); the server
/// fast-fails the request with [`ErrorCode::DeadlineExpired`] if its
/// batch is drained after the budget has elapsed.
#[derive(Clone, Debug, PartialEq)]
pub struct SearchFrame {
    /// Client-assigned correlation id (v2 only; 0 on decoded v1 frames).
    pub request_id: u64,
    pub collection: String,
    pub k: u32,
    pub effort: Effort,
    pub mode: QueryMode,
    pub deadline_micros: u64,
    pub query: Vec<f32>,
}

/// A successful search reply: hits plus the per-request cost counters
/// and the server-observed latency.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HitsFrame {
    /// Echo of the request's id (v2 only; 0 over v1).
    pub request_id: u64,
    pub ids: Vec<u32>,
    pub scores: Vec<f32>,
    pub keys_scanned: u64,
    pub cells_probed: u64,
    pub map_flops: u64,
    pub scan_flops: u64,
    pub server_micros: u64,
}

/// A typed error reply.
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorFrame {
    /// Echo of the failing request's id (v2 only). 0 when the failure
    /// predates id extraction (undecodable frame, connection-level
    /// notice) — pipelined clients treat id-0 errors as
    /// connection-scoped rather than request-scoped.
    pub request_id: u64,
    pub code: ErrorCode,
    pub message: String,
}

impl ErrorFrame {
    /// Connection-scoped error (no specific request to blame).
    pub fn conn(code: ErrorCode, message: String) -> ErrorFrame {
        ErrorFrame {
            request_id: 0,
            code,
            message,
        }
    }
}

/// Per-collection row inside a [`StatsFrame`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CollectionStats {
    pub name: String,
    pub served: u64,
    pub errors: u64,
    pub overloaded: u64,
    pub expired: u64,
    pub queue_depth: u64,
}

/// Server-wide health/statistics reply: request counters, queue depth
/// and the rolled-up latency histogram percentiles (seconds).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsFrame {
    pub served: u64,
    pub errors: u64,
    pub overloaded: u64,
    pub expired: u64,
    pub queue_depth: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
    pub max_s: f64,
    pub collections: Vec<CollectionStats>,
}

/// Mutation kinds carried by a [`MutateFrame`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MutateOp {
    /// Append `vectors` as new rows; the reply's ids are the assigned
    /// global ids. `ids` must be empty.
    Insert,
    /// Replace-or-create: `ids[i]` gets `vectors` row `i`.
    Upsert,
    /// Remove `ids`; `vectors` must be empty.
    Delete,
}

/// A mutation request against a mutable collection. `vectors` is
/// row-major `rows × dim`; the decoder enforces `vectors.len()` to be
/// a multiple of `dim` (and empty exactly when `dim` is 0), so a
/// decoded frame always has a well-defined row count.
#[derive(Clone, Debug, PartialEq)]
pub struct MutateFrame {
    /// Client-assigned correlation id (v2 only; 0 on decoded v1 frames).
    pub request_id: u64,
    pub collection: String,
    pub op: MutateOp,
    pub ids: Vec<u32>,
    pub dim: u32,
    pub vectors: Vec<f32>,
}

/// Reply to `Mutate`/`Compact`: the affected (or assigned) ids, the
/// collection's live row count and committed-or-swapped generation
/// after the operation, and the server-observed latency.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MutatedFrame {
    /// Echo of the request's id (v2 only; 0 over v1).
    pub request_id: u64,
    pub ids: Vec<u32>,
    pub len: u64,
    pub gen: u64,
    pub server_micros: u64,
}

/// A compaction request: fold the named collection's delta + sealed
/// segments + tombstones into a fresh sealed generation.
#[derive(Clone, Debug, PartialEq)]
pub struct CompactFrame {
    /// Client-assigned correlation id (v2 only; 0 on decoded v1 frames).
    pub request_id: u64,
    pub collection: String,
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Search(SearchFrame),
    Hits(HitsFrame),
    Error(ErrorFrame),
    Ping { token: u64 },
    Pong { token: u64 },
    StatsRequest,
    Stats(StatsFrame),
    Mutate(MutateFrame),
    Mutated(MutatedFrame),
    Compact(CompactFrame),
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(b: &mut Vec<u8>, v: f32) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

fn encode_effort(b: &mut Vec<u8>, e: Effort) {
    match e {
        Effort::Exhaustive => b.push(0),
        Effort::Probes(p) => {
            b.push(1);
            put_u32(b, p.min(u32::MAX as usize) as u32);
        }
        Effort::Frac(f) => {
            b.push(2);
            put_f32(b, f);
        }
        Effort::Auto => b.push(3),
    }
}

fn encode_mode(b: &mut Vec<u8>, m: QueryMode) {
    b.push(match m {
        QueryMode::Original => 0,
        QueryMode::Mapped => 1,
        QueryMode::Routed => 2,
    });
}

/// Encode one frame's `(tag, payload)` pair for the given wire
/// `version`. In v2 the six request/reply tags that correlate by id
/// lead their payload with the `request_id: u64`; in v1 that field is
/// simply omitted (legacy layout, id information is lost).
pub(crate) fn encode_payload(frame: &Frame, version: u8) -> (u8, Vec<u8>) {
    let mut b = Vec::new();
    let v2 = version >= 2;
    let t = match frame {
        Frame::Search(s) => {
            if v2 {
                put_u64(&mut b, s.request_id);
            }
            put_str(&mut b, &s.collection);
            put_u32(&mut b, s.k);
            encode_effort(&mut b, s.effort);
            encode_mode(&mut b, s.mode);
            put_u64(&mut b, s.deadline_micros);
            put_u32(&mut b, s.query.len() as u32);
            for &v in &s.query {
                put_f32(&mut b, v);
            }
            tag::SEARCH
        }
        Frame::Hits(h) => {
            if v2 {
                put_u64(&mut b, h.request_id);
            }
            // enforce the decoder's own caps at encode time: a frame we
            // emit must be one our decoder accepts (ids/scores lengths
            // can only disagree through a server bug; emit the prefix
            // both agree on rather than a self-desyncing frame)
            let n = h.ids.len().min(h.scores.len()).min(MAX_HITS);
            put_u32(&mut b, n as u32);
            for &id in &h.ids[..n] {
                put_u32(&mut b, id);
            }
            for &sc in &h.scores[..n] {
                put_f32(&mut b, sc);
            }
            put_u64(&mut b, h.keys_scanned);
            put_u64(&mut b, h.cells_probed);
            put_u64(&mut b, h.map_flops);
            put_u64(&mut b, h.scan_flops);
            put_u64(&mut b, h.server_micros);
            tag::HITS
        }
        Frame::Error(e) => {
            if v2 {
                put_u64(&mut b, e.request_id);
            }
            put_u16(&mut b, e.code as u16);
            let mut cut = e.message.len().min(MAX_MSG_LEN);
            while cut > 0 && !e.message.is_char_boundary(cut) {
                cut -= 1;
            }
            put_str(&mut b, &e.message[..cut]);
            tag::ERROR
        }
        Frame::Ping { token } => {
            put_u64(&mut b, *token);
            tag::PING
        }
        Frame::Pong { token } => {
            put_u64(&mut b, *token);
            tag::PONG
        }
        Frame::StatsRequest => tag::STATS_REQUEST,
        Frame::Stats(s) => {
            put_u64(&mut b, s.served);
            put_u64(&mut b, s.errors);
            put_u64(&mut b, s.overloaded);
            put_u64(&mut b, s.expired);
            put_u64(&mut b, s.queue_depth);
            put_f64(&mut b, s.mean_s);
            put_f64(&mut b, s.p50_s);
            put_f64(&mut b, s.p99_s);
            put_f64(&mut b, s.p999_s);
            put_f64(&mut b, s.max_s);
            let nc = s.collections.len().min(MAX_COLLECTIONS);
            put_u32(&mut b, nc as u32);
            for c in &s.collections[..nc] {
                put_str(&mut b, &c.name);
                put_u64(&mut b, c.served);
                put_u64(&mut b, c.errors);
                put_u64(&mut b, c.overloaded);
                put_u64(&mut b, c.expired);
                put_u64(&mut b, c.queue_depth);
            }
            tag::STATS
        }
        Frame::Mutate(m) => {
            if v2 {
                put_u64(&mut b, m.request_id);
            }
            put_str(&mut b, &m.collection);
            b.push(match m.op {
                MutateOp::Insert => 0,
                MutateOp::Upsert => 1,
                MutateOp::Delete => 2,
            });
            let ni = m.ids.len().min(MAX_HITS);
            put_u32(&mut b, ni as u32);
            for &id in &m.ids[..ni] {
                put_u32(&mut b, id);
            }
            put_u32(&mut b, m.dim);
            // emit whole rows only: a ragged tail (or floats with a
            // zero dim) would be rejected by our own decoder
            let nf = match m.dim as usize {
                0 => 0,
                d => (m.vectors.len() / d) * d,
            };
            put_u32(&mut b, nf as u32);
            for &v in &m.vectors[..nf] {
                put_f32(&mut b, v);
            }
            tag::MUTATE
        }
        Frame::Mutated(m) => {
            if v2 {
                put_u64(&mut b, m.request_id);
            }
            let ni = m.ids.len().min(MAX_HITS);
            put_u32(&mut b, ni as u32);
            for &id in &m.ids[..ni] {
                put_u32(&mut b, id);
            }
            put_u64(&mut b, m.len);
            put_u64(&mut b, m.gen);
            put_u64(&mut b, m.server_micros);
            tag::MUTATED
        }
        Frame::Compact(cf) => {
            if v2 {
                put_u64(&mut b, cf.request_id);
            }
            put_str(&mut b, &cf.collection);
            tag::COMPACT
        }
    };
    (t, b)
}

/// Write one frame (header + payload) in a single buffered write, at
/// the latest protocol version.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    write_frame_versioned(w, frame, VERSION)
}

/// Write one frame at an explicit wire version (servers echo the
/// version of the request they are answering; downgraded clients pin
/// v1).
pub fn write_frame_versioned<W: Write>(
    w: &mut W,
    frame: &Frame,
    version: u8,
) -> std::io::Result<()> {
    let (t, payload) = encode_payload(frame, version);
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.push(version);
    buf.push(t);
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&payload);
    w.write_all(&buf)?;
    w.flush()
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked payload cursor: every read is validated against the
/// remaining bytes before it happens, so decoders can't over-read or
/// allocate past the (already capped) payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { what });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, WireError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, WireError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, WireError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    fn f32(&mut self, what: &'static str) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    fn f64(&mut self, what: &'static str) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// A `u32`-length-prefixed UTF-8 string, capped at `cap` bytes.
    fn string(&mut self, cap: usize, what: &'static str) -> Result<String, WireError> {
        let n = self.u32(what)? as usize;
        if n > cap {
            return Err(WireError::Oversized {
                what,
                declared: n as u64,
                cap: cap as u64,
            });
        }
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed(format!("{what} is not valid utf-8")))
    }

    /// Validate an element count against a cap *and* the bytes actually
    /// present (`count * elem_size`, checked) before any allocation.
    fn count(
        &self,
        declared: usize,
        cap: usize,
        elem_size: usize,
        what: &'static str,
    ) -> Result<usize, WireError> {
        if declared > cap {
            return Err(WireError::Oversized {
                what,
                declared: declared as u64,
                cap: cap as u64,
            });
        }
        match declared.checked_mul(elem_size) {
            Some(bytes) if bytes <= self.remaining() => Ok(declared),
            _ => Err(WireError::Truncated { what }),
        }
    }

    fn finish(self, what: &'static str) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after {what} payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

fn decode_effort(c: &mut Cur) -> Result<Effort, WireError> {
    Ok(match c.u8("effort tag")? {
        0 => Effort::Exhaustive,
        1 => Effort::Probes(c.u32("probes")? as usize),
        2 => Effort::Frac(c.f32("frac")?),
        3 => Effort::Auto,
        t => return Err(WireError::Malformed(format!("unknown effort tag {t}"))),
    })
}

fn decode_mode(c: &mut Cur) -> Result<QueryMode, WireError> {
    Ok(match c.u8("mode")? {
        0 => QueryMode::Original,
        1 => QueryMode::Mapped,
        2 => QueryMode::Routed,
        t => return Err(WireError::Malformed(format!("unknown query mode {t}"))),
    })
}

/// Decode one payload at the given wire `version`. Public within the
/// crate so fuzz tests can hit the decoder without a socket. Decoded
/// v1 frames carry `request_id == 0`.
pub(crate) fn decode_payload(t: u8, payload: &[u8], version: u8) -> Result<Frame, WireError> {
    let mut c = Cur::new(payload);
    let v2 = version >= 2;
    let frame = match t {
        tag::SEARCH => {
            let request_id = if v2 { c.u64("request id")? } else { 0 };
            let collection = c.string(MAX_NAME_LEN, "collection name")?;
            let k = c.u32("k")?;
            let effort = decode_effort(&mut c)?;
            let mode = decode_mode(&mut c)?;
            let deadline_micros = c.u64("deadline")?;
            let dim = c.u32("query dim")? as usize;
            let dim = c.count(dim, MAX_DIM, 4, "query dim")?;
            let mut query = Vec::with_capacity(dim);
            for _ in 0..dim {
                query.push(c.f32("query values")?);
            }
            Frame::Search(SearchFrame {
                request_id,
                collection,
                k,
                effort,
                mode,
                deadline_micros,
                query,
            })
        }
        tag::HITS => {
            let request_id = if v2 { c.u64("request id")? } else { 0 };
            let n = c.u32("hit count")? as usize;
            let n = c.count(n, MAX_HITS, 8, "hit count")?;
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(c.u32("hit ids")?);
            }
            let mut scores = Vec::with_capacity(n);
            for _ in 0..n {
                scores.push(c.f32("hit scores")?);
            }
            Frame::Hits(HitsFrame {
                request_id,
                ids,
                scores,
                keys_scanned: c.u64("keys_scanned")?,
                cells_probed: c.u64("cells_probed")?,
                map_flops: c.u64("map_flops")?,
                scan_flops: c.u64("scan_flops")?,
                server_micros: c.u64("server_micros")?,
            })
        }
        tag::ERROR => {
            let request_id = if v2 { c.u64("request id")? } else { 0 };
            let raw = c.u16("error code")?;
            let code = ErrorCode::from_u16(raw)
                .ok_or_else(|| WireError::Malformed(format!("unknown error code {raw}")))?;
            let message = c.string(MAX_MSG_LEN, "error message")?;
            Frame::Error(ErrorFrame {
                request_id,
                code,
                message,
            })
        }
        tag::PING => Frame::Ping {
            token: c.u64("ping token")?,
        },
        tag::PONG => Frame::Pong {
            token: c.u64("pong token")?,
        },
        tag::STATS_REQUEST => Frame::StatsRequest,
        tag::STATS => {
            let served = c.u64("served")?;
            let errors = c.u64("errors")?;
            let overloaded = c.u64("overloaded")?;
            let expired = c.u64("expired")?;
            let queue_depth = c.u64("queue_depth")?;
            let mean_s = c.f64("mean_s")?;
            let p50_s = c.f64("p50_s")?;
            let p99_s = c.f64("p99_s")?;
            let p999_s = c.f64("p999_s")?;
            let max_s = c.f64("max_s")?;
            let n = c.u32("collection count")? as usize;
            // each entry is at least 44 bytes (4-byte name length + five u64s)
            let n = c.count(n, MAX_COLLECTIONS, 44, "collection count")?;
            let mut collections = Vec::with_capacity(n);
            for _ in 0..n {
                collections.push(CollectionStats {
                    name: c.string(MAX_NAME_LEN, "collection name")?,
                    served: c.u64("coll served")?,
                    errors: c.u64("coll errors")?,
                    overloaded: c.u64("coll overloaded")?,
                    expired: c.u64("coll expired")?,
                    queue_depth: c.u64("coll queue_depth")?,
                });
            }
            Frame::Stats(StatsFrame {
                served,
                errors,
                overloaded,
                expired,
                queue_depth,
                mean_s,
                p50_s,
                p99_s,
                p999_s,
                max_s,
                collections,
            })
        }
        tag::MUTATE => {
            let request_id = if v2 { c.u64("request id")? } else { 0 };
            let collection = c.string(MAX_NAME_LEN, "collection name")?;
            let op = match c.u8("mutate op")? {
                0 => MutateOp::Insert,
                1 => MutateOp::Upsert,
                2 => MutateOp::Delete,
                o => return Err(WireError::Malformed(format!("unknown mutate op {o}"))),
            };
            let ni = c.u32("mutate id count")? as usize;
            let ni = c.count(ni, MAX_HITS, 4, "mutate id count")?;
            let mut ids = Vec::with_capacity(ni);
            for _ in 0..ni {
                ids.push(c.u32("mutate ids")?);
            }
            let dim = c.u32("mutate dim")?;
            if dim as usize > MAX_DIM {
                return Err(WireError::Oversized {
                    what: "mutate dim",
                    declared: dim as u64,
                    cap: MAX_DIM as u64,
                });
            }
            let nf = c.u32("mutate vector count")? as usize;
            let nf = c.count(nf, MAX_FRAME_LEN as usize / 4, 4, "mutate vector count")?;
            // structural invariants, so decoded frames always have a
            // well-defined row count: floats come in whole rows, and a
            // zero dim means no floats at all
            if dim == 0 && nf != 0 {
                return Err(WireError::Malformed(
                    "mutate vectors present but dim is 0".into(),
                ));
            }
            if dim > 0 && nf % dim as usize != 0 {
                return Err(WireError::Malformed(format!(
                    "mutate vector count {nf} is not a multiple of dim {dim}"
                )));
            }
            let mut vectors = Vec::with_capacity(nf);
            for _ in 0..nf {
                vectors.push(c.f32("mutate vectors")?);
            }
            Frame::Mutate(MutateFrame {
                request_id,
                collection,
                op,
                ids,
                dim,
                vectors,
            })
        }
        tag::MUTATED => {
            let request_id = if v2 { c.u64("request id")? } else { 0 };
            let ni = c.u32("mutated id count")? as usize;
            let ni = c.count(ni, MAX_HITS, 4, "mutated id count")?;
            let mut ids = Vec::with_capacity(ni);
            for _ in 0..ni {
                ids.push(c.u32("mutated ids")?);
            }
            Frame::Mutated(MutatedFrame {
                request_id,
                ids,
                len: c.u64("mutated len")?,
                gen: c.u64("mutated gen")?,
                server_micros: c.u64("server_micros")?,
            })
        }
        tag::COMPACT => {
            let request_id = if v2 { c.u64("request id")? } else { 0 };
            Frame::Compact(CompactFrame {
                request_id,
                collection: c.string(MAX_NAME_LEN, "collection name")?,
            })
        }
        t => return Err(WireError::UnknownTag(t)),
    };
    c.finish("frame")?;
    Ok(frame)
}

/// Validate a frame header, returning `(version, tag, payload_len)`.
/// Any version in `MIN_VERSION..=VERSION` is accepted; the caller
/// decodes the payload at the frame's own version.
fn decode_header(h: &[u8; HEADER_LEN]) -> Result<(u8, u8, usize), WireError> {
    let magic: [u8; 4] = h[0..4].try_into().expect("4 bytes");
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    if !(MIN_VERSION..=VERSION).contains(&h[4]) {
        return Err(WireError::BadVersion(h[4]));
    }
    let len = u32::from_le_bytes(h[6..10].try_into().expect("4 bytes"));
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversized {
            what: "frame payload",
            declared: len as u64,
            cap: MAX_FRAME_LEN as u64,
        });
    }
    Ok((h[4], h[5], len as usize))
}

fn read_exact_or<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<(), WireError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Closed
        } else {
            WireError::Io(e)
        }
    })
}

/// Blocking read of one frame (client side and tests).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    read_frame_versioned(r).map(|(f, _)| f)
}

/// Blocking read of one frame plus the wire version it arrived at, so
/// a server can echo the request's version on its reply.
pub fn read_frame_versioned<R: Read>(r: &mut R) -> Result<(Frame, u8), WireError> {
    let mut header = [0u8; HEADER_LEN];
    read_exact_or(r, &mut header)?;
    let (v, t, len) = decode_header(&header)?;
    let mut payload = vec![0u8; len];
    read_exact_or(r, &mut payload)?;
    decode_payload(t, &payload, v).map(|f| (f, v))
}

/// True when `e` is a read-timeout error (both kinds platforms use).
pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Server-side frame read with two timescales: wait up to `idle` for the
/// *first* byte (returning `Ok(None)` on a quiet socket so the caller
/// can poll its shutdown flag), then require the rest of the frame
/// within `frame_timeout` (a slow-loris guard — a peer that stalls
/// mid-frame gets a typed timeout error instead of pinning the
/// connection thread).
pub fn read_frame_idle(
    stream: &mut TcpStream,
    idle: Duration,
    frame_timeout: Duration,
) -> Result<Option<(Frame, u8)>, WireError> {
    stream.set_read_timeout(Some(idle.max(Duration::from_millis(1))))?;
    let mut header = [0u8; HEADER_LEN];
    match stream.read(&mut header) {
        Ok(0) => return Err(WireError::Closed),
        Ok(n) => {
            stream.set_read_timeout(Some(frame_timeout.max(Duration::from_millis(1))))?;
            if n < HEADER_LEN {
                read_exact_or(stream, &mut header[n..])?;
            }
        }
        Err(e) if is_timeout(&e) => return Ok(None),
        Err(e) => return Err(WireError::Io(e)),
    }
    let (v, t, len) = decode_header(&header)?;
    stream.set_read_timeout(Some(frame_timeout.max(Duration::from_millis(1))))?;
    let mut payload = vec![0u8; len];
    read_exact_or(stream, &mut payload)?;
    decode_payload(t, &payload, v).map(|f| Some((f, v)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop_cases;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Search(SearchFrame {
                request_id: 0xDEAD_BEEF_0001,
                collection: "docs".into(),
                k: 10,
                effort: Effort::Probes(4),
                mode: QueryMode::Mapped,
                deadline_micros: 2_000,
                query: vec![0.25, -1.5, 3.0],
            }),
            Frame::Search(SearchFrame {
                request_id: u64::MAX,
                collection: "x".into(),
                k: 1,
                effort: Effort::Frac(0.5),
                mode: QueryMode::Original,
                deadline_micros: 0,
                query: vec![],
            }),
            Frame::Hits(HitsFrame {
                request_id: 17,
                ids: vec![7, 3, 9],
                scores: vec![0.9, 0.5, -0.25],
                keys_scanned: 123,
                cells_probed: 4,
                map_flops: 55,
                scan_flops: 999,
                server_micros: 1234,
            }),
            Frame::Error(ErrorFrame {
                request_id: 3,
                code: ErrorCode::Overloaded,
                message: "queue full".into(),
            }),
            Frame::Ping { token: 42 },
            Frame::Pong { token: 42 },
            Frame::StatsRequest,
            Frame::Stats(StatsFrame {
                served: 10,
                errors: 1,
                overloaded: 2,
                expired: 3,
                queue_depth: 4,
                mean_s: 1e-3,
                p50_s: 0.5e-3,
                p99_s: 2e-3,
                p999_s: 3e-3,
                max_s: 4e-3,
                collections: vec![CollectionStats {
                    name: "docs".into(),
                    served: 10,
                    errors: 1,
                    overloaded: 2,
                    expired: 3,
                    queue_depth: 4,
                }],
            }),
            Frame::Mutate(MutateFrame {
                request_id: 21,
                collection: "docs".into(),
                op: MutateOp::Insert,
                ids: vec![],
                dim: 4,
                vectors: vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8],
            }),
            Frame::Mutate(MutateFrame {
                request_id: 22,
                collection: "docs".into(),
                op: MutateOp::Upsert,
                ids: vec![7, 9],
                dim: 2,
                vectors: vec![1.0, 2.0, 3.0, 4.0],
            }),
            Frame::Mutate(MutateFrame {
                request_id: 23,
                collection: "docs".into(),
                op: MutateOp::Delete,
                ids: vec![3, 5, 8],
                dim: 0,
                vectors: vec![],
            }),
            Frame::Mutated(MutatedFrame {
                request_id: 23,
                ids: vec![40, 41],
                len: 12,
                gen: 3,
                server_micros: 250,
            }),
            Frame::Compact(CompactFrame {
                request_id: 24,
                collection: "docs".into(),
            }),
        ]
    }

    /// The same frame with its correlation id zeroed — what a v1
    /// round-trip is expected to preserve.
    fn without_id(frame: &Frame) -> Frame {
        let mut f = frame.clone();
        match &mut f {
            Frame::Search(s) => s.request_id = 0,
            Frame::Hits(h) => h.request_id = 0,
            Frame::Error(e) => e.request_id = 0,
            Frame::Mutate(m) => m.request_id = 0,
            Frame::Mutated(m) => m.request_id = 0,
            Frame::Compact(cf) => cf.request_id = 0,
            _ => {}
        }
        f
    }

    #[test]
    fn round_trip_every_frame_type() {
        for frame in sample_frames() {
            let mut buf = Vec::new();
            write_frame(&mut buf, &frame).unwrap();
            let (back, v) = read_frame_versioned(&mut buf.as_slice()).unwrap();
            assert_eq!(v, VERSION);
            assert_eq!(frame, back, "{frame:?}");
        }
    }

    #[test]
    fn v1_round_trip_drops_request_ids() {
        // the legacy layout has no id field: encoding at v1 and reading
        // back must yield the same frame with the id zeroed, and the
        // reader must report the frame's own version
        for frame in sample_frames() {
            let mut buf = Vec::new();
            write_frame_versioned(&mut buf, &frame, V1).unwrap();
            let (back, v) = read_frame_versioned(&mut buf.as_slice()).unwrap();
            assert_eq!(v, V1);
            assert_eq!(without_id(&frame), back, "{frame:?}");
        }
    }

    #[test]
    fn v1_and_v2_encodings_differ_only_by_id_prefix() {
        // the six correlated tags gain exactly 8 leading payload bytes
        // in v2; control frames are bit-identical across versions
        for frame in sample_frames() {
            let (t1, p1) = encode_payload(&frame, V1);
            let (t2, p2) = encode_payload(&frame, VERSION);
            assert_eq!(t1, t2);
            match frame {
                Frame::Ping { .. }
                | Frame::Pong { .. }
                | Frame::StatsRequest
                | Frame::Stats(_) => assert_eq!(p1, p2, "{frame:?}"),
                _ => {
                    assert_eq!(p2.len(), p1.len() + 8, "{frame:?}");
                    assert_eq!(&p2[8..], &p1[..], "{frame:?}");
                }
            }
        }
    }

    #[test]
    fn effort_variants_round_trip() {
        for effort in [
            Effort::Exhaustive,
            Effort::Probes(0),
            Effort::Probes(1 << 20),
            Effort::Frac(0.0),
            Effort::Frac(1.0),
            Effort::Auto,
        ] {
            let f = Frame::Search(SearchFrame {
                request_id: 1,
                collection: "c".into(),
                k: 3,
                effort,
                mode: QueryMode::Original,
                deadline_micros: 1,
                query: vec![1.0],
            });
            let mut buf = Vec::new();
            write_frame(&mut buf, &f).unwrap();
            assert_eq!(read_frame(&mut buf.as_slice()).unwrap(), f);
        }
    }

    #[test]
    fn bad_magic_version_and_tag_are_typed() {
        let frame = Frame::Ping { token: 1 };
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        // magic
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(
            read_frame(&mut bad.as_slice()),
            Err(WireError::BadMagic(_))
        ));
        // versions outside MIN_VERSION..=VERSION, both sides
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(matches!(
            read_frame(&mut bad.as_slice()),
            Err(WireError::BadVersion(99))
        ));
        let mut bad = buf.clone();
        bad[4] = 0;
        assert!(matches!(
            read_frame(&mut bad.as_slice()),
            Err(WireError::BadVersion(0))
        ));
        // tag
        let mut bad = buf.clone();
        bad[5] = 200;
        assert!(matches!(
            read_frame(&mut bad.as_slice()),
            Err(WireError::UnknownTag(200))
        ));
    }

    #[test]
    fn oversized_declared_lengths_rejected_before_allocation() {
        // frame payload length over the cap
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.push(VERSION);
        buf.push(4); // ping
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            read_frame(&mut buf.as_slice()),
            Err(WireError::Oversized { .. })
        ));
        // query dim larger than the bytes present: must not allocate it
        let f = Frame::Search(SearchFrame {
            request_id: 1,
            collection: "c".into(),
            k: 1,
            effort: Effort::Auto,
            mode: QueryMode::Original,
            deadline_micros: 0,
            query: vec![1.0, 2.0],
        });
        for version in [V1, VERSION] {
            let (t, mut payload) = encode_payload(&f, version);
            // the dim field sits 4 bytes before the two query floats
            let dim_off = payload.len() - 8 - 4;
            payload[dim_off..dim_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
            match decode_payload(t, &payload, version) {
                Err(WireError::Oversized { .. }) | Err(WireError::Truncated { .. }) => {}
                other => panic!("expected typed cap error, got {other:?}"),
            }
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        for version in [V1, VERSION] {
            let (t, mut payload) = encode_payload(&Frame::Ping { token: 7 }, version);
            payload.push(0);
            assert!(matches!(
                decode_payload(t, &payload, version),
                Err(WireError::Malformed(_))
            ));
        }
    }

    #[test]
    fn closed_and_truncated_streams_are_typed() {
        assert!(matches!(
            read_frame(&mut (&[] as &[u8])),
            Err(WireError::Closed)
        ));
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Ping { token: 3 }).unwrap();
        for cut in 1..buf.len() {
            match read_frame(&mut buf[..cut].as_ref()) {
                Err(_) => {}
                Ok(f) => panic!("truncated stream decoded to {f:?}"),
            }
        }
    }

    #[test]
    fn fuzz_decoder_never_panics() {
        // random byte flips and truncations over every frame type *in
        // both wire versions*, plus pure-noise payloads under every tag
        // at each version: the decoder must return a typed result
        // (flips inside float payloads may still decode Ok) and never
        // panic or over-allocate.
        let cases = prop_cases(200);
        let mut rng = crate::util::test_rng(0xA317);
        let frames = sample_frames();
        for case in 0..cases {
            let base = &frames[case % frames.len()];
            let version = if rng.below(2) == 0 { V1 } else { VERSION };
            let mut buf = Vec::new();
            write_frame_versioned(&mut buf, base, version).unwrap();
            let mut mutated = buf.clone();
            for _ in 0..1 + rng.below(4) {
                let i = rng.below(mutated.len());
                mutated[i] ^= 1 << rng.below(8);
            }
            if rng.below(3) == 0 {
                mutated.truncate(rng.below(mutated.len() + 1));
            }
            let res = std::panic::catch_unwind(move || {
                let _ = read_frame(&mut mutated.as_slice());
            });
            assert!(res.is_ok(), "decoder panicked on case {case}");
            // pure noise straight into the payload decoder
            let tag = (rng.below(14) + 1) as u8; // valid tags 1..=10 plus a few unknown
            let noise: Vec<u8> = (0..rng.below(64)).map(|_| rng.below(256) as u8).collect();
            let res = std::panic::catch_unwind(move || {
                let _ = decode_payload(tag, &noise, version);
            });
            assert!(res.is_ok(), "payload decoder panicked on case {case}");
            // cross-version confusion: bytes encoded at one version,
            // decoded at the other — must stay typed, never panic
            let (t, payload) = encode_payload(base, version);
            let other = if version == V1 { VERSION } else { V1 };
            let res = std::panic::catch_unwind(move || {
                let _ = decode_payload(t, &payload, other);
            });
            assert!(res.is_ok(), "cross-version decode panicked on case {case}");
        }
    }

    #[test]
    fn encoded_hits_always_satisfy_decode_caps() {
        // over-long hit vectors are truncated at encode time so the
        // reply stays decodable instead of desyncing the client
        let big = MAX_HITS + 3;
        let frame = Frame::Hits(HitsFrame {
            ids: (0..big as u32).collect(),
            scores: vec![0.5; big],
            ..HitsFrame::default()
        });
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        match read_frame(&mut buf.as_slice()).unwrap() {
            Frame::Hits(h) => {
                assert_eq!(h.ids.len(), MAX_HITS);
                assert_eq!(h.scores.len(), MAX_HITS);
            }
            other => panic!("expected hits, got {other:?}"),
        }
        // mismatched ids/scores lengths encode the common prefix
        let frame = Frame::Hits(HitsFrame {
            ids: vec![1, 2, 3],
            scores: vec![0.9, 0.8],
            ..HitsFrame::default()
        });
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        match read_frame(&mut buf.as_slice()).unwrap() {
            Frame::Hits(h) => {
                assert_eq!(h.ids, vec![1, 2]);
                assert_eq!(h.scores, vec![0.9, 0.8]);
            }
            other => panic!("expected hits, got {other:?}"),
        }
    }

    #[test]
    fn mutate_structural_invariants_enforced() {
        // ragged float tail is truncated to whole rows at encode time
        let f = Frame::Mutate(MutateFrame {
            request_id: 1,
            collection: "c".into(),
            op: MutateOp::Insert,
            ids: vec![],
            dim: 3,
            vectors: vec![1.0, 2.0, 3.0, 4.0], // 1⅓ rows
        });
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        match read_frame(&mut buf.as_slice()).unwrap() {
            Frame::Mutate(m) => assert_eq!(m.vectors, vec![1.0, 2.0, 3.0]),
            other => panic!("expected mutate, got {other:?}"),
        }
        // zero dim with floats attached: dropped at encode, rejected at decode
        let f = Frame::Mutate(MutateFrame {
            request_id: 2,
            collection: "c".into(),
            op: MutateOp::Delete,
            ids: vec![1],
            dim: 0,
            vectors: vec![9.0],
        });
        let (t, payload) = encode_payload(&f, VERSION);
        match decode_payload(t, &payload, VERSION).unwrap() {
            Frame::Mutate(m) => assert!(m.vectors.is_empty()),
            other => panic!("expected mutate, got {other:?}"),
        }
        // hand-build a ragged frame (legacy v1 layout, no id prefix):
        // decoder must reject it as malformed
        let mut p = Vec::new();
        put_str(&mut p, "c");
        p.push(0); // insert
        put_u32(&mut p, 0); // no ids
        put_u32(&mut p, 3); // dim 3
        put_u32(&mut p, 4); // but 4 floats
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            put_f32(&mut p, v);
        }
        assert!(matches!(
            decode_payload(tag::MUTATE, &p, V1),
            Err(WireError::Malformed(_))
        ));
        // unknown op byte
        let mut p = Vec::new();
        put_str(&mut p, "c");
        p.push(7);
        put_u32(&mut p, 0);
        put_u32(&mut p, 0);
        put_u32(&mut p, 0);
        assert!(matches!(
            decode_payload(tag::MUTATE, &p, V1),
            Err(WireError::Malformed(_))
        ));
        // oversized dim is a typed cap error
        let mut p = Vec::new();
        put_str(&mut p, "c");
        p.push(0);
        put_u32(&mut p, 0);
        put_u32(&mut p, (MAX_DIM as u32) + 1);
        put_u32(&mut p, 0);
        assert!(matches!(
            decode_payload(tag::MUTATE, &p, V1),
            Err(WireError::Oversized { .. })
        ));
        // declared id count past the bytes present must not allocate
        let mut p = Vec::new();
        put_str(&mut p, "c");
        p.push(2);
        put_u32(&mut p, u32::MAX);
        match decode_payload(tag::MUTATE, &p, V1) {
            Err(WireError::Oversized { .. }) | Err(WireError::Truncated { .. }) => {}
            other => panic!("expected typed cap error, got {other:?}"),
        }
    }

    #[test]
    fn error_code_round_trip() {
        for code in [
            ErrorCode::BadRequest,
            ErrorCode::UnknownCollection,
            ErrorCode::DeadlineExpired,
            ErrorCode::Overloaded,
            ErrorCode::ShuttingDown,
            ErrorCode::Unsupported,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::from_u16(code as u16), Some(code));
        }
        assert_eq!(ErrorCode::from_u16(0), None);
        assert_eq!(ErrorCode::from_u16(999), None);
    }
}
