//! Client SDK for the AMTP wire protocol: a blocking one-shot API
//! (unchanged since v1) plus a pipelined mode over wire v2.
//!
//! A [`NetClient`] wraps one TCP connection. At connect it negotiates
//! the wire version by sending a v2 `Ping`: a v2 server answers
//! `Pong`, a legacy v1 server rejects the version with a typed
//! `Unsupported` (or just closes), and the client transparently
//! reconnects pinned to v1. [`NetClient::version`] reports the result.
//!
//! **One-shot mode** (any version): [`NetClient::search`] and friends
//! are synchronous request/reply. Over v1 the protocol is strictly
//! alternating; over v2 the same calls ride the id-tagged frames, so
//! mixing them with pipelined traffic is safe.
//!
//! **Pipelined mode** (v2 only): [`NetClient::submit_search`] sends a
//! request and returns its client-assigned id without waiting;
//! completions arrive in whatever order the server finishes them and
//! are claimed by [`NetClient::wait_search`] (replies for other ids
//! are buffered, never lost) or drained in completion order with
//! [`NetClient::recv_any`]. [`NetClient::search_many`] wraps the
//! window-keeping loop: up to `window` requests in flight, results
//! returned in input order.
//!
//! A draining server answers every frame with `ShuttingDown`; the
//! client surfaces that as the distinct, retryable
//! [`NetError::Draining`] so callers can reconnect elsewhere (or
//! later) instead of treating the drain window as a hard failure. A
//! connection-scoped error (request id 0, e.g. the drain notice)
//! fails *every* outstanding pipelined request with that same typed
//! error — retryable ones can be re-submitted on a fresh connection.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::api::{Effort, QueryMode};
use crate::coordinator::net::wire::{
    read_frame, write_frame_versioned, CompactFrame, ErrorCode, ErrorFrame, Frame, HitsFrame,
    MutateFrame, MutateOp, MutatedFrame, SearchFrame, StatsFrame, WireError, MAX_FRAME_LEN,
    MAX_HITS, V1, VERSION,
};
use crate::tensor::Tensor;

/// Client-side failure: a transport/protocol error, a typed server
/// error reply, or an unexpected frame type.
#[derive(Debug)]
pub enum NetError {
    /// Transport or frame-decode failure.
    Wire(WireError),
    /// The server replied with a typed error frame.
    Server(ErrorFrame),
    /// The server is draining for shutdown (`ShuttingDown` reply). The
    /// request was *not* served; retry against another replica or after
    /// the restart completes.
    Draining(ErrorFrame),
    /// The server replied with a frame that doesn't answer the request.
    Unexpected(&'static str),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Wire(e) => write!(f, "wire error: {e}"),
            NetError::Server(e) => write!(f, "server error [{}]: {}", e.code, e.message),
            NetError::Draining(e) => write!(f, "server draining (retryable): {}", e.message),
            NetError::Unexpected(what) => write!(f, "unexpected reply frame: {what}"),
        }
    }
}

impl std::error::Error for NetError {}

impl From<WireError> for NetError {
    fn from(e: WireError) -> NetError {
        NetError::Wire(e)
    }
}

impl From<std::io::Error> for NetError {
    fn from(e: std::io::Error) -> NetError {
        NetError::Wire(WireError::Io(e))
    }
}

impl NetError {
    /// Split a server error reply into the retryable drain case and
    /// everything else.
    fn from_reply(e: ErrorFrame) -> NetError {
        if e.code == ErrorCode::ShuttingDown {
            NetError::Draining(e)
        } else {
            NetError::Server(e)
        }
    }

    /// The server's error frame, when that's what this is (including
    /// the drain reply).
    pub fn server_error(&self) -> Option<&ErrorFrame> {
        match self {
            NetError::Server(e) | NetError::Draining(e) => Some(e),
            _ => None,
        }
    }

    /// True when retrying the same request (against another replica or
    /// after a backoff) can succeed without changing it.
    pub fn is_retryable(&self) -> bool {
        match self {
            NetError::Draining(_) => true,
            NetError::Server(e) => e.code == ErrorCode::Overloaded,
            _ => false,
        }
    }
}

/// Per-request knobs for [`NetClient::search`].
#[derive(Clone, Copy, Debug)]
pub struct SearchOptions {
    pub k: usize,
    pub effort: Effort,
    pub mode: QueryMode,
    /// Client latency budget; the server fast-fails the request with a
    /// typed `DeadlineExpired` once it lapses. `None` = no deadline.
    pub deadline: Option<Duration>,
}

impl SearchOptions {
    pub fn top_k(k: usize) -> SearchOptions {
        SearchOptions {
            k: k.max(1),
            effort: Effort::Auto,
            mode: QueryMode::Original,
            deadline: None,
        }
    }

    pub fn effort(mut self, effort: Effort) -> SearchOptions {
        self.effort = effort;
        self
    }

    pub fn mode(mut self, mode: QueryMode) -> SearchOptions {
        self.mode = mode;
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> SearchOptions {
        self.deadline = Some(deadline);
        self
    }
}

/// One completed pipelined request, claimed in completion order by
/// [`NetClient::recv_any`].
#[derive(Debug)]
pub struct PipelineReply {
    pub request_id: u64,
    pub reply: Result<HitsFrame, ErrorFrame>,
}

/// One connection to an `amips serve --listen` server.
pub struct NetClient {
    stream: TcpStream,
    next_token: u64,
    /// Wire version negotiated at connect (v1 against legacy servers).
    version: u8,
    /// Client-assigned request ids, never reused within a connection.
    next_id: u64,
    /// Ids submitted and not yet completed.
    inflight: std::collections::HashSet<u64>,
    /// Completions that arrived while waiting for a different id (or a
    /// control reply); claimed later without another read.
    pending: Vec<(u64, Frame)>,
    /// Set when a connection-scoped server error (request id 0, e.g.
    /// the drain notice) arrives: every outstanding and future request
    /// on this connection fails with this same typed error.
    poisoned: Option<ErrorFrame>,
}

impl NetClient {
    /// Connect to a serving address (e.g. `"127.0.0.1:7771"`),
    /// negotiating the newest wire version the server speaks.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient, NetError> {
        let stream = TcpStream::connect(addr)?;
        let peer = stream.peer_addr()?;
        let mut client = NetClient::from_stream(stream, VERSION);
        // a v2 probe: Pong = the server speaks v2; a typed version
        // rejection (legacy servers answer Unsupported, then close) or
        // a bare close = reconnect pinned to v1
        match client.ping() {
            Ok(()) => Ok(client),
            Err(NetError::Server(e)) if e.code == ErrorCode::Unsupported => {
                NetClient::connect_v1(peer)
            }
            Err(NetError::Wire(WireError::BadVersion(_)))
            | Err(NetError::Wire(WireError::Closed)) => NetClient::connect_v1(peer),
            Err(e) => Err(e),
        }
    }

    /// Connect pinned to the legacy v1 protocol (no pipelining). Used
    /// by the negotiation fallback; public for tests and for talking
    /// to old servers without the probe round-trip.
    pub fn connect_v1(addr: impl ToSocketAddrs) -> Result<NetClient, NetError> {
        let stream = TcpStream::connect(addr)?;
        Ok(NetClient::from_stream(stream, V1))
    }

    fn from_stream(stream: TcpStream, version: u8) -> NetClient {
        let _ = stream.set_nodelay(true);
        NetClient {
            stream,
            next_token: 1,
            version,
            next_id: 1,
            inflight: std::collections::HashSet::new(),
            pending: Vec::new(),
            poisoned: None,
        }
    }

    /// The negotiated wire version (2, or 1 against a legacy server).
    pub fn version(&self) -> u8 {
        self.version
    }

    /// Pipelined requests submitted and not yet claimed.
    pub fn outstanding(&self) -> usize {
        self.inflight.len() + self.pending.len()
    }

    /// Bound how long any single reply may take (`None` = wait forever).
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<(), NetError> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    fn check_poisoned(&self) -> Result<(), NetError> {
        match &self.poisoned {
            Some(e) => Err(NetError::from_reply(e.clone())),
            None => Ok(()),
        }
    }

    fn send(&mut self, frame: &Frame) -> Result<(), NetError> {
        self.check_poisoned()?;
        write_frame_versioned(&mut self.stream, frame, self.version).map_err(WireError::Io)?;
        Ok(())
    }

    /// Read one frame and sort it: `Ok(Some(..))` is an id-tagged
    /// completion for an outstanding request, `Ok(None)` is a control
    /// reply handed back through `out`. Conn-scoped errors poison the
    /// connection.
    fn read_sorted(&mut self) -> Result<SortedFrame, NetError> {
        self.check_poisoned()?;
        let frame = read_frame(&mut self.stream)?;
        let id = match &frame {
            Frame::Hits(h) => h.request_id,
            Frame::Mutated(m) => m.request_id,
            Frame::Error(e) => e.request_id,
            _ => return Ok(SortedFrame::Control(frame)),
        };
        if let Frame::Error(e) = &frame {
            // id 0 = connection-scoped (drain notice, decode eviction):
            // no single request is being answered, every outstanding
            // one is dead. Over v1 all errors are id-0 by construction
            // and there is no pipeline, so the error is simply the
            // current request's reply.
            if id == 0 && self.version >= 2 {
                self.poisoned = Some(e.clone());
                return Err(NetError::from_reply(e.clone()));
            }
        }
        if self.version < 2 || (id == 0 && !self.inflight.contains(&0)) {
            // v1 (or an untracked id-0 reply): strict alternation, the
            // frame answers the one request in flight
            return Ok(SortedFrame::Control(frame));
        }
        if !self.inflight.remove(&id) {
            return Err(NetError::Unexpected("reply for an id that is not in flight"));
        }
        Ok(SortedFrame::Tagged(id, frame))
    }

    /// Block for the reply to a specific outstanding id, buffering
    /// completions for other ids as they arrive.
    fn wait_tagged(&mut self, id: u64) -> Result<Frame, NetError> {
        if let Some(pos) = self.pending.iter().position(|(pid, _)| *pid == id) {
            return Ok(self.pending.swap_remove(pos).1);
        }
        loop {
            match self.read_sorted()? {
                SortedFrame::Tagged(got, frame) if got == id => return Ok(frame),
                SortedFrame::Tagged(got, frame) => self.pending.push((got, frame)),
                SortedFrame::Control(_) => {
                    return Err(NetError::Unexpected("control frame while waiting for an id"))
                }
            }
        }
    }

    /// Block for a control reply (Pong/Stats), buffering pipelined
    /// completions that land first.
    fn wait_control(&mut self) -> Result<Frame, NetError> {
        loop {
            match self.read_sorted()? {
                SortedFrame::Control(frame) => return Ok(frame),
                SortedFrame::Tagged(id, frame) => self.pending.push((id, frame)),
            }
        }
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn search_frame(collection: &str, query: &[f32], opts: SearchOptions, id: u64) -> Frame {
        let deadline_micros = opts
            .deadline
            .map(|d| (d.as_micros().min(u64::MAX as u128) as u64).max(1))
            .unwrap_or(0);
        Frame::Search(SearchFrame {
            request_id: id,
            collection: collection.to_string(),
            k: opts.k as u32,
            effort: opts.effort,
            mode: opts.mode,
            deadline_micros,
            query: query.to_vec(),
        })
    }

    // -----------------------------------------------------------------
    // Pipelined mode (wire v2)
    // -----------------------------------------------------------------

    /// Submit a search without waiting for its reply; returns the
    /// request id to claim it with ([`NetClient::wait_search`] /
    /// [`NetClient::recv_any`]). Requires a v2 server.
    pub fn submit_search(
        &mut self,
        collection: &str,
        query: &[f32],
        opts: SearchOptions,
    ) -> Result<u64, NetError> {
        if self.version < 2 {
            return Err(NetError::Unexpected(
                "server speaks wire v1: pipelined mode unavailable",
            ));
        }
        let id = self.fresh_id();
        let frame = Self::search_frame(collection, query, opts, id);
        self.send(&frame)?;
        self.inflight.insert(id);
        Ok(id)
    }

    /// Claim the reply to one submitted search (blocking; replies for
    /// other ids that arrive first are buffered, not lost).
    pub fn wait_search(&mut self, id: u64) -> Result<HitsFrame, NetError> {
        match self.wait_tagged(id)? {
            Frame::Hits(h) => Ok(h),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("search wants Hits or Error")),
        }
    }

    /// Claim the next completion in whatever order the server finished
    /// them. Errors that answer a specific request come back as
    /// `Ok(PipelineReply { reply: Err(..) })`; connection-level
    /// failures are `Err`.
    pub fn recv_any(&mut self) -> Result<PipelineReply, NetError> {
        if let Some((request_id, frame)) = self.pending.pop() {
            return Self::into_pipeline_reply(request_id, frame);
        }
        loop {
            match self.read_sorted()? {
                SortedFrame::Tagged(id, frame) => return Self::into_pipeline_reply(id, frame),
                SortedFrame::Control(_) => {
                    return Err(NetError::Unexpected("control frame while draining completions"))
                }
            }
        }
    }

    fn into_pipeline_reply(request_id: u64, frame: Frame) -> Result<PipelineReply, NetError> {
        let reply = match frame {
            Frame::Hits(h) => Ok(h),
            Frame::Error(e) => Err(e),
            _ => return Err(NetError::Unexpected("completion wants Hits or Error")),
        };
        Ok(PipelineReply { request_id, reply })
    }

    /// Pipelined batch search: keep up to `window` requests in flight
    /// on this one connection, return per-query results in input
    /// order. Over a v1 server this degrades to sequential one-shot
    /// requests (window 1), so callers need no version check.
    ///
    /// Transport-level failures abort the whole call (`Err`); typed
    /// per-request server errors land in that query's slot.
    pub fn search_many(
        &mut self,
        collection: &str,
        queries: &[&[f32]],
        opts: SearchOptions,
        window: usize,
    ) -> Result<Vec<Result<HitsFrame, NetError>>, NetError> {
        let window = window.max(1);
        if self.version < 2 || window == 1 {
            let mut out = Vec::with_capacity(queries.len());
            for q in queries {
                out.push(self.search(collection, q, opts));
            }
            return Ok(out);
        }
        let mut results: Vec<Option<Result<HitsFrame, NetError>>> =
            (0..queries.len()).map(|_| None).collect();
        let mut id_to_slot: HashMap<u64, usize> = HashMap::new();
        let mut next = 0usize;
        let mut done = 0usize;
        while done < queries.len() {
            // fill the window
            while next < queries.len() && id_to_slot.len() < window {
                match self.submit_search(collection, queries[next], opts) {
                    Ok(id) => {
                        id_to_slot.insert(id, next);
                        next += 1;
                    }
                    Err(e) => {
                        // a failed *send* is connection-fatal (the
                        // frame may be half-written); a poisoned
                        // connection fails outstanding slots below
                        return Err(e);
                    }
                }
            }
            match self.recv_any() {
                Ok(done_reply) => {
                    let Some(slot) = id_to_slot.remove(&done_reply.request_id) else {
                        return Err(NetError::Unexpected("completion for an unknown id"));
                    };
                    results[slot] = Some(done_reply.reply.map_err(NetError::from_reply));
                    done += 1;
                }
                Err(NetError::Draining(_)) | Err(NetError::Server(_)) => {
                    // connection-scoped typed error: every outstanding
                    // slot gets the same typed failure (retryable for
                    // drains), already-completed slots keep their hits
                    for (_, slot) in id_to_slot.drain() {
                        results[slot] = Some(Err(self
                            .poisoned
                            .clone()
                            .map(NetError::from_reply)
                            .unwrap_or(NetError::Unexpected("connection failed"))));
                        done += 1;
                    }
                    // unsent queries also fail with the same error
                    for slot in next..queries.len() {
                        results[slot] = Some(Err(self
                            .poisoned
                            .clone()
                            .map(NetError::from_reply)
                            .unwrap_or(NetError::Unexpected("connection failed"))));
                        done += 1;
                    }
                    next = queries.len();
                }
                Err(e) => return Err(e),
            }
        }
        Ok(results.into_iter().map(|r| r.expect("all slots filled")).collect())
    }

    // -----------------------------------------------------------------
    // One-shot mode (any version)
    // -----------------------------------------------------------------

    /// Top-`k` search of `query` against `collection` (blocking).
    pub fn search(
        &mut self,
        collection: &str,
        query: &[f32],
        opts: SearchOptions,
    ) -> Result<HitsFrame, NetError> {
        if self.version >= 2 {
            let id = self.submit_search(collection, query, opts)?;
            return self.wait_search(id);
        }
        let frame = Self::search_frame(collection, query, opts, 0);
        self.send(&frame)?;
        match self.wait_control()? {
            Frame::Hits(h) => Ok(h),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("search wants Hits or Error")),
        }
    }

    /// Liveness check: round-trips a token through `Ping`/`Pong`.
    pub fn ping(&mut self) -> Result<(), NetError> {
        let token = self.next_token;
        self.next_token += 1;
        self.send(&Frame::Ping { token })?;
        match self.wait_control()? {
            Frame::Pong { token: t } if t == token => Ok(()),
            Frame::Pong { .. } => Err(NetError::Unexpected("pong token mismatch")),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("ping wants Pong")),
        }
    }

    /// Fetch server-wide stats (latency percentiles, queue depth,
    /// per-collection counters).
    pub fn stats(&mut self) -> Result<StatsFrame, NetError> {
        self.send(&Frame::StatsRequest)?;
        match self.wait_control()? {
            Frame::Stats(s) => Ok(s),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("stats wants Stats")),
        }
    }

    /// Check a mutation's size against the wire caps *before* sending,
    /// so an oversized batch is a typed local error instead of a frame
    /// the server rejects (or a desynced stream).
    fn check_mutation_size(n_ids: usize, n_floats: usize) -> Result<(), NetError> {
        if n_ids > MAX_HITS {
            return Err(NetError::Wire(WireError::Oversized {
                what: "mutation payload",
                declared: n_ids as u64,
                cap: MAX_HITS as u64,
            }));
        }
        // conservative frame-size bound: 4 bytes per id/float plus
        // generous header room
        let bytes = 4 * (n_ids as u64 + n_floats as u64) + 1024;
        if bytes > MAX_FRAME_LEN as u64 {
            return Err(NetError::Wire(WireError::Oversized {
                what: "mutation payload",
                declared: bytes,
                cap: MAX_FRAME_LEN as u64,
            }));
        }
        Ok(())
    }

    /// Wait for the Mutated reply to `id` (v2) or the next control
    /// reply (v1).
    fn wait_mutated(&mut self, id: u64) -> Result<MutatedFrame, NetError> {
        let frame = if self.version >= 2 {
            self.wait_tagged(id)?
        } else {
            self.wait_control()?
        };
        match frame {
            Frame::Mutated(m) => Ok(m),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("mutate wants Mutated or Error")),
        }
    }

    fn mutate(&mut self, mut frame: MutateFrame) -> Result<MutatedFrame, NetError> {
        Self::check_mutation_size(frame.ids.len(), frame.vectors.len())?;
        let id = if self.version >= 2 { self.fresh_id() } else { 0 };
        frame.request_id = id;
        self.send(&Frame::Mutate(frame))?;
        if self.version >= 2 {
            self.inflight.insert(id);
        }
        self.wait_mutated(id)
    }

    /// Append `vecs` (rows × dim) to a mutable collection; returns the
    /// assigned ids (in row order) plus post-mutation len/generation.
    pub fn insert(&mut self, collection: &str, vecs: &Tensor) -> Result<MutatedFrame, NetError> {
        self.mutate(MutateFrame {
            request_id: 0,
            collection: collection.to_string(),
            op: MutateOp::Insert,
            ids: Vec::new(),
            dim: vecs.shape().last().copied().unwrap_or(0) as u32,
            vectors: vecs.data().to_vec(),
        })
    }

    /// Replace-or-create: `ids[i]` gets row `i` of `vecs`. The reply
    /// echoes the ids.
    pub fn upsert(
        &mut self,
        collection: &str,
        ids: &[u32],
        vecs: &Tensor,
    ) -> Result<MutatedFrame, NetError> {
        self.mutate(MutateFrame {
            request_id: 0,
            collection: collection.to_string(),
            op: MutateOp::Upsert,
            ids: ids.to_vec(),
            dim: vecs.shape().last().copied().unwrap_or(0) as u32,
            vectors: vecs.data().to_vec(),
        })
    }

    /// Tombstone `ids` (idempotent; unknown ids are ignored server-side).
    pub fn delete(&mut self, collection: &str, ids: &[u32]) -> Result<MutatedFrame, NetError> {
        self.mutate(MutateFrame {
            request_id: 0,
            collection: collection.to_string(),
            op: MutateOp::Delete,
            ids: ids.to_vec(),
            dim: 0,
            vectors: Vec::new(),
        })
    }

    /// Fold the collection's delta + tombstones into a fresh sealed
    /// generation (blocks until the new generation is committed).
    pub fn compact(&mut self, collection: &str) -> Result<MutatedFrame, NetError> {
        let id = if self.version >= 2 { self.fresh_id() } else { 0 };
        self.send(&Frame::Compact(CompactFrame {
            request_id: id,
            collection: collection.to_string(),
        }))?;
        if self.version >= 2 {
            self.inflight.insert(id);
        }
        self.wait_mutated(id)
    }

    /// Escape hatch for probes and tests: send raw bytes, then try to
    /// read one frame.
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<Frame, NetError> {
        use std::io::Write;
        self.stream.write_all(bytes)?;
        self.stream.flush()?;
        Ok(read_frame(&mut self.stream)?)
    }
}

/// How [`NetClient::read_sorted`] classified one incoming frame.
enum SortedFrame {
    /// A completion for an outstanding request id.
    Tagged(u64, Frame),
    /// A control reply (Pong/Stats), or any v1 frame.
    Control(Frame),
}
