//! Blocking client SDK for the AMTP wire protocol.
//!
//! A [`NetClient`] wraps one TCP connection. Calls are synchronous
//! request/reply (the protocol is strictly alternating per connection);
//! open several clients for concurrency — the server batches across
//! connections, which is where the fused-scan amortization comes from.
//!
//! A draining server answers every frame with `ShuttingDown`; the
//! client surfaces that as the distinct, retryable
//! [`NetError::Draining`] so callers can reconnect elsewhere (or later)
//! instead of treating the drain window as a hard failure.

use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::api::{Effort, QueryMode};
use crate::coordinator::net::wire::{
    read_frame, write_frame, CompactFrame, ErrorCode, ErrorFrame, Frame, HitsFrame, MutateFrame,
    MutateOp, MutatedFrame, SearchFrame, StatsFrame, WireError, MAX_FRAME_LEN, MAX_HITS,
};
use crate::tensor::Tensor;

/// Client-side failure: a transport/protocol error, a typed server
/// error reply, or an unexpected frame type.
#[derive(Debug)]
pub enum NetError {
    /// Transport or frame-decode failure.
    Wire(WireError),
    /// The server replied with a typed error frame.
    Server(ErrorFrame),
    /// The server is draining for shutdown (`ShuttingDown` reply). The
    /// request was *not* served; retry against another replica or after
    /// the restart completes.
    Draining(ErrorFrame),
    /// The server replied with a frame that doesn't answer the request.
    Unexpected(&'static str),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Wire(e) => write!(f, "wire error: {e}"),
            NetError::Server(e) => write!(f, "server error [{}]: {}", e.code, e.message),
            NetError::Draining(e) => write!(f, "server draining (retryable): {}", e.message),
            NetError::Unexpected(what) => write!(f, "unexpected reply frame: {what}"),
        }
    }
}

impl std::error::Error for NetError {}

impl From<WireError> for NetError {
    fn from(e: WireError) -> NetError {
        NetError::Wire(e)
    }
}

impl From<std::io::Error> for NetError {
    fn from(e: std::io::Error) -> NetError {
        NetError::Wire(WireError::Io(e))
    }
}

impl NetError {
    /// Split a server error reply into the retryable drain case and
    /// everything else.
    fn from_reply(e: ErrorFrame) -> NetError {
        if e.code == ErrorCode::ShuttingDown {
            NetError::Draining(e)
        } else {
            NetError::Server(e)
        }
    }

    /// The server's error frame, when that's what this is (including
    /// the drain reply).
    pub fn server_error(&self) -> Option<&ErrorFrame> {
        match self {
            NetError::Server(e) | NetError::Draining(e) => Some(e),
            _ => None,
        }
    }

    /// True when retrying the same request (against another replica or
    /// after a backoff) can succeed without changing it.
    pub fn is_retryable(&self) -> bool {
        match self {
            NetError::Draining(_) => true,
            NetError::Server(e) => e.code == ErrorCode::Overloaded,
            _ => false,
        }
    }
}

/// Per-request knobs for [`NetClient::search`].
#[derive(Clone, Copy, Debug)]
pub struct SearchOptions {
    pub k: usize,
    pub effort: Effort,
    pub mode: QueryMode,
    /// Client latency budget; the server fast-fails the request with a
    /// typed `DeadlineExpired` once it lapses. `None` = no deadline.
    pub deadline: Option<Duration>,
}

impl SearchOptions {
    pub fn top_k(k: usize) -> SearchOptions {
        SearchOptions {
            k: k.max(1),
            effort: Effort::Auto,
            mode: QueryMode::Original,
            deadline: None,
        }
    }

    pub fn effort(mut self, effort: Effort) -> SearchOptions {
        self.effort = effort;
        self
    }

    pub fn mode(mut self, mode: QueryMode) -> SearchOptions {
        self.mode = mode;
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> SearchOptions {
        self.deadline = Some(deadline);
        self
    }
}

/// One blocking connection to an `amips serve --listen` server.
pub struct NetClient {
    stream: TcpStream,
    next_token: u64,
}

impl NetClient {
    /// Connect to a serving address (e.g. `"127.0.0.1:7771"`).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient, NetError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient {
            stream,
            next_token: 1,
        })
    }

    /// Bound how long any single reply may take (`None` = wait forever).
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<(), NetError> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    fn round_trip(&mut self, frame: &Frame) -> Result<Frame, NetError> {
        write_frame(&mut self.stream, frame).map_err(WireError::Io)?;
        Ok(read_frame(&mut self.stream)?)
    }

    /// Top-`k` search of `query` against `collection`.
    pub fn search(
        &mut self,
        collection: &str,
        query: &[f32],
        opts: SearchOptions,
    ) -> Result<HitsFrame, NetError> {
        let deadline_micros = opts
            .deadline
            .map(|d| (d.as_micros().min(u64::MAX as u128) as u64).max(1))
            .unwrap_or(0);
        let frame = Frame::Search(SearchFrame {
            collection: collection.to_string(),
            k: opts.k as u32,
            effort: opts.effort,
            mode: opts.mode,
            deadline_micros,
            query: query.to_vec(),
        });
        match self.round_trip(&frame)? {
            Frame::Hits(h) => Ok(h),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("search wants Hits or Error")),
        }
    }

    /// Liveness check: round-trips a token through `Ping`/`Pong`.
    pub fn ping(&mut self) -> Result<(), NetError> {
        let token = self.next_token;
        self.next_token += 1;
        match self.round_trip(&Frame::Ping { token })? {
            Frame::Pong { token: t } if t == token => Ok(()),
            Frame::Pong { .. } => Err(NetError::Unexpected("pong token mismatch")),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("ping wants Pong")),
        }
    }

    /// Fetch server-wide stats (latency percentiles, queue depth,
    /// per-collection counters).
    pub fn stats(&mut self) -> Result<StatsFrame, NetError> {
        match self.round_trip(&Frame::StatsRequest)? {
            Frame::Stats(s) => Ok(s),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("stats wants Stats")),
        }
    }

    /// Check a mutation's size against the wire caps *before* sending,
    /// so an oversized batch is a typed local error instead of a frame
    /// the server rejects (or a desynced stream).
    fn check_mutation_size(n_ids: usize, n_floats: usize) -> Result<(), NetError> {
        if n_ids > MAX_HITS {
            return Err(NetError::Wire(WireError::Oversized {
                what: "mutation payload",
                declared: n_ids as u64,
                cap: MAX_HITS as u64,
            }));
        }
        // conservative frame-size bound: 4 bytes per id/float plus
        // generous header room
        let bytes = 4 * (n_ids as u64 + n_floats as u64) + 1024;
        if bytes > MAX_FRAME_LEN as u64 {
            return Err(NetError::Wire(WireError::Oversized {
                what: "mutation payload",
                declared: bytes,
                cap: MAX_FRAME_LEN as u64,
            }));
        }
        Ok(())
    }

    fn mutate(&mut self, frame: MutateFrame) -> Result<MutatedFrame, NetError> {
        Self::check_mutation_size(frame.ids.len(), frame.vectors.len())?;
        match self.round_trip(&Frame::Mutate(frame))? {
            Frame::Mutated(m) => Ok(m),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("mutate wants Mutated or Error")),
        }
    }

    /// Append `vecs` (rows × dim) to a mutable collection; returns the
    /// assigned ids (in row order) plus post-mutation len/generation.
    pub fn insert(&mut self, collection: &str, vecs: &Tensor) -> Result<MutatedFrame, NetError> {
        self.mutate(MutateFrame {
            collection: collection.to_string(),
            op: MutateOp::Insert,
            ids: Vec::new(),
            dim: vecs.shape().last().copied().unwrap_or(0) as u32,
            vectors: vecs.data().to_vec(),
        })
    }

    /// Replace-or-create: `ids[i]` gets row `i` of `vecs`. The reply
    /// echoes the ids.
    pub fn upsert(
        &mut self,
        collection: &str,
        ids: &[u32],
        vecs: &Tensor,
    ) -> Result<MutatedFrame, NetError> {
        self.mutate(MutateFrame {
            collection: collection.to_string(),
            op: MutateOp::Upsert,
            ids: ids.to_vec(),
            dim: vecs.shape().last().copied().unwrap_or(0) as u32,
            vectors: vecs.data().to_vec(),
        })
    }

    /// Tombstone `ids` (idempotent; unknown ids are ignored server-side).
    pub fn delete(&mut self, collection: &str, ids: &[u32]) -> Result<MutatedFrame, NetError> {
        self.mutate(MutateFrame {
            collection: collection.to_string(),
            op: MutateOp::Delete,
            ids: ids.to_vec(),
            dim: 0,
            vectors: Vec::new(),
        })
    }

    /// Fold the collection's delta + tombstones into a fresh sealed
    /// generation (blocks until the new generation is committed).
    pub fn compact(&mut self, collection: &str) -> Result<MutatedFrame, NetError> {
        match self.round_trip(&Frame::Compact(CompactFrame {
            collection: collection.to_string(),
        }))? {
            Frame::Mutated(m) => Ok(m),
            Frame::Error(e) => Err(NetError::from_reply(e)),
            _ => Err(NetError::Unexpected("compact wants Mutated or Error")),
        }
    }

    /// Escape hatch for probes and tests: send raw bytes, then try to
    /// read one frame.
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<Frame, NetError> {
        use std::io::Write;
        self.stream.write_all(bytes)?;
        self.stream.flush()?;
        Ok(read_frame(&mut self.stream)?)
    }
}
