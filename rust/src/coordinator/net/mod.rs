//! TCP serving subsystem: the process boundary around the batching
//! coordinator. The in-process [`crate::coordinator::Server`] stays the
//! embedded API; this module makes the same fused-batch serving path
//! reachable from other processes over a versioned length-framed wire
//! protocol ([`wire`]), with deadline-aware batching, bounded-queue
//! admission control and per-collection multi-tenant routing off a
//! [`crate::index::Catalog`] ([`engine`], [`server`]), plus a blocking
//! client SDK ([`client`]).
//!
//! Entry points: `amips serve --catalog <dir> --listen <addr>` on the
//! CLI, [`NetServer::serve_catalog`] in the library, [`NetClient`] on
//! the client side, and the `bench_serve` load generator for open-loop
//! latency/throughput measurement.

pub mod client;
pub mod engine;
pub mod server;
pub mod wire;

pub use client::{NetClient, NetError, SearchOptions};
pub use engine::{NetReply, NetRequest, SubmitError, Tenant, TenantStats};
pub use server::{NetServer, NetServerConfig};
pub use wire::{
    CollectionStats, CompactFrame, ErrorCode, ErrorFrame, Frame, HitsFrame, MutateFrame, MutateOp,
    MutatedFrame, SearchFrame, StatsFrame, WireError,
};
