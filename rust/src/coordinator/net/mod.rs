//! TCP serving subsystem: the process boundary around the batching
//! coordinator. The in-process [`crate::coordinator::Server`] stays the
//! embedded API; this module makes the same fused-batch serving path
//! reachable from other processes over a versioned length-framed wire
//! protocol ([`wire`]), with deadline-aware batching, bounded-queue
//! admission control and per-collection multi-tenant routing off a
//! [`crate::index::Catalog`] ([`engine`], [`server`]), plus a blocking
//! client SDK ([`client`]).
//!
//! Entry points: `amips serve --catalog <dir> --listen <addr>
//! [--metrics-port <p>]` on the CLI, [`NetServer::serve_catalog`] in
//! the library, [`NetClient`] on the client side (blocking one-shot
//! and pipelined modes), and the `bench_serve` load generator for
//! open-loop latency/throughput and closed-loop pipelined measurement.
//!
//! Wire protocol v2 carries a client-assigned `request_id` on
//! Search/Mutate/Compact frames, echoed on Hits/Mutated/Error, so one
//! connection can keep up to `max_inflight` requests in flight and
//! receive completions out of order ([`wire`], [`server`]). v1 clients
//! keep working unchanged (strict request/reply alternation; the
//! server answers every frame in the version it arrived at). A
//! separate metrics listener ([`metrics`]) exports per-tenant
//! latency/queue/in-flight counters as plain text. [`fault`] provides
//! the seeded fault-injection stream wrapper the net test suites use.

pub mod client;
pub mod engine;
pub mod fault;
pub mod metrics;
pub mod server;
pub mod wire;

pub use client::{NetClient, NetError, PipelineReply, SearchOptions};
pub use engine::{NetReply, NetRequest, ReplySink, SubmitError, TaggedReply, Tenant, TenantStats};
pub use fault::{FaultPlan, FaultyStream};
pub use metrics::{MetricsListener, MetricsSource};
pub use server::{NetServer, NetServerConfig};
pub use wire::{
    CollectionStats, CompactFrame, ErrorCode, ErrorFrame, Frame, HitsFrame, MutateFrame, MutateOp,
    MutatedFrame, SearchFrame, StatsFrame, WireError,
};
