//! The TCP front-end: accept loop, per-connection frame loop with
//! request pipelining, tenant routing, stats aggregation, the
//! metrics listener, and graceful shutdown.
//!
//! ```text
//!  TcpListener (nonblocking poll, shutdown-aware)
//!     └── connection reader thread per client (capped)
//!           ├── read_frame_idle: idle-poll for the stop flag without
//!           │   desyncing mid-frame; slow-loris frame timeout
//!           ├── draining? -> every frame answers ShuttingDown + close
//!           ├── Ping -> Pong, StatsRequest -> Stats (direct write)
//!           ├── Search v2 -> dup-id / max_inflight admission ->
//!           │   Tenant::submit with a Queued reply sink; completions
//!           │   flow out of order through the writer thread below
//!           ├── Search v1 -> Tenant::submit -> block on reply
//!           │   (legacy strict alternation, unchanged)
//!           └── Mutate/Compact -> route to the mutable collection,
//!               apply on the reader thread in arrival order (the
//!               collection's own mutation mutex serializes writers;
//!               searches keep serving the old generation until the
//!               swap commits)
//!     └── connection writer thread: drains a bounded reply queue of
//!         id-tagged completions; every frame write (reader- or
//!         writer-side) goes through one shared stream mutex so frames
//!         never interleave
//!  Tenant (one per catalog collection)
//!     └── worker thread: Batcher -> deadline triage -> map pass ->
//!         fused (k, effort) group scans -> per-request replies
//!  Metrics TcpListener (optional, --metrics-port)
//!     └── write-only text scrape per connection; never contends with
//!         the data plane
//! ```
//!
//! **Pipelining invariant.** A connection may have at most
//! `max_inflight` v2 searches admitted at once; its reply queue holds
//! exactly `max_inflight` slots, and the per-connection in-flight
//! count is decremented only *after* a reply has been drained from the
//! queue. Each in-flight request therefore contributes at most one
//! queued reply and the tenant worker's queued send can never block —
//! a slow-reading client stalls its own writer thread (bounded by the
//! stream write timeout), never a shared tenant worker. Admission past
//! the cap is a typed [`ErrorCode::Overloaded`] echoing the request
//! id, not an unbounded buffer.
//!
//! Every failure a client can cause — unknown collection, bad frame,
//! full queue, expired deadline, draining server — is answered with a
//! typed [`ErrorFrame`] before the connection is (at worst) closed;
//! nothing hangs a socket and nothing allocates beyond the wire caps.

use std::collections::{BTreeMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::net::engine::{NetRequest, ReplySink, TaggedReply, Tenant};
use crate::coordinator::net::metrics::{self, MetricsListener, MetricsSource};
use crate::coordinator::net::wire::{
    read_frame_idle, write_frame_versioned, ErrorCode, ErrorFrame, Frame, MutateFrame, MutateOp,
    MutatedFrame, SearchFrame, StatsFrame, WireError, MAX_HITS, V1, VERSION,
};
use crate::index::catalog::Catalog;
use crate::index::segment::{Compactor, CompactorConfig, MutableCollection};
use crate::index::VectorIndex;
use crate::tensor::Tensor;
use crate::util::timer::LatencyHistogram;

/// Tuning knobs for the TCP front-end.
#[derive(Clone, Copy, Debug)]
pub struct NetServerConfig {
    /// Batch policy shared by every tenant worker.
    pub policy: BatchPolicy,
    /// Bounded admission queue per tenant; a full queue answers
    /// [`ErrorCode::Overloaded`].
    pub queue_cap: usize,
    /// Concurrent connection cap; excess connects get a typed
    /// `Overloaded` reply and are closed.
    pub max_connections: usize,
    /// How long a quiet connection sleeps between stop-flag polls.
    pub idle_timeout: Duration,
    /// Once a frame has started arriving, how long the rest may take
    /// (slow-loris guard).
    pub frame_timeout: Duration,
    /// How long [`NetServer::shutdown`] waits for connection threads to
    /// notice the stop flag before proceeding without them (they exit
    /// on their own; shutdown just stops blocking on stragglers).
    pub drain_timeout: Duration,
    /// Per-connection cap on concurrently admitted v2 searches; the
    /// cap also sizes the connection's bounded reply queue. Admission
    /// past it answers a typed [`ErrorCode::Overloaded`] echoing the
    /// request id.
    pub max_inflight: usize,
    /// When set, a second listener on this address serves plain-text
    /// metrics scrapes (one snapshot per connection, then close) so
    /// scrapers never touch the data plane.
    pub metrics_addr: Option<SocketAddr>,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            policy: BatchPolicy::default(),
            queue_cap: 1024,
            max_connections: 256,
            idle_timeout: Duration::from_millis(50),
            frame_timeout: Duration::from_secs(2),
            drain_timeout: Duration::from_secs(10),
            max_inflight: 32,
            metrics_addr: None,
        }
    }
}

/// Pass/error counter handles of one background compaction worker,
/// published to the metrics listener.
struct CompactorCounters {
    collection: String,
    passes: Arc<AtomicU64>,
    errors: Arc<AtomicU64>,
}

struct Shared {
    tenants: BTreeMap<String, Arc<Tenant>>,
    /// Mutable collections by name (a subset of `tenants`' names):
    /// searches go through the tenant worker like any collection, while
    /// Mutate/Compact frames route here. The collection's own mutation
    /// mutex serializes writers, so connection threads apply directly.
    mutables: BTreeMap<String, Arc<MutableCollection>>,
    shutting: AtomicBool,
    live_connections: AtomicUsize,
    /// Server-wide count of pipelined searches currently admitted into
    /// tenant queues (exported by the metrics listener).
    inflight: AtomicUsize,
    /// Filled in by [`NetServer::serve_catalog`] after the compaction
    /// workers spawn.
    compactor_counters: Mutex<Vec<CompactorCounters>>,
    cfg: NetServerConfig,
}

impl Shared {
    /// Roll per-tenant counters and latency snapshots up into one
    /// server-wide stats frame.
    fn stats_frame(&self) -> StatsFrame {
        let mut hist = LatencyHistogram::new();
        let mut out = StatsFrame::default();
        for tenant in self.tenants.values() {
            let c = tenant.collection_stats();
            out.served += c.served;
            out.errors += c.errors;
            out.overloaded += c.overloaded;
            out.expired += c.expired;
            out.queue_depth += c.queue_depth;
            out.collections.push(c);
            hist.merge(&tenant.stats().latency.lock().unwrap().snapshot());
        }
        out.mean_s = hist.mean_s();
        out.p50_s = hist.p50_s();
        out.p99_s = hist.p99_s();
        out.p999_s = hist.p999_s();
        out.max_s = hist.max_s();
        out
    }

    /// Render one plain-text metrics snapshot (`key value` /
    /// `key{label="x"} value` lines, Prometheus-style). Collection
    /// names come from the catalog (trusted, wire-capped); quotes and
    /// backslashes are escaped anyway so a hostile name can't break a
    /// scraper's line parser.
    fn render_metrics(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut out = String::with_capacity(1024);
        out.push_str(&format!(
            "amips_build_info{{version=\"{}\",wire_version=\"{VERSION}\",kernel=\"{}\"}} 1\n",
            env!("CARGO_PKG_VERSION"),
            crate::tensor::kernels::tier_name()
        ));
        out.push_str(&format!(
            "amips_connections {}\n",
            self.live_connections.load(Ordering::Relaxed)
        ));
        out.push_str(&format!(
            "amips_inflight_requests {}\n",
            self.inflight.load(Ordering::Relaxed)
        ));
        out.push_str(&format!("amips_max_inflight {}\n", self.cfg.max_inflight));
        out.push_str(&format!(
            "amips_draining {}\n",
            self.shutting.load(Ordering::SeqCst) as u8
        ));
        // zero-copy accounting (process-wide): bytes served as borrowed
        // views of mapped containers vs bytes decoded into fresh RAM
        out.push_str(&format!(
            "amips_mapped_bytes {}\n",
            crate::tensor::mapped::stats::mapped_bytes()
        ));
        out.push_str(&format!(
            "amips_copied_bytes {}\n",
            crate::tensor::mapped::stats::copied_bytes()
        ));
        for (name, tenant) in &self.tenants {
            let name = esc(name);
            let c = tenant.collection_stats();
            let label = format!("{{collection=\"{name}\"}}");
            out.push_str(&format!("amips_tenant_served_total{label} {}\n", c.served));
            out.push_str(&format!("amips_tenant_errors_total{label} {}\n", c.errors));
            out.push_str(&format!(
                "amips_tenant_overloaded_total{label} {}\n",
                c.overloaded
            ));
            out.push_str(&format!(
                "amips_tenant_expired_total{label} {}\n",
                c.expired
            ));
            out.push_str(&format!(
                "amips_tenant_queue_depth{label} {}\n",
                c.queue_depth
            ));
            let hist = tenant.stats().latency.lock().unwrap().snapshot();
            for (q, v) in [
                ("0.5", hist.p50_s()),
                ("0.99", hist.p99_s()),
                ("0.999", hist.p999_s()),
            ] {
                out.push_str(&format!(
                    "amips_tenant_latency_seconds{{collection=\"{name}\",quantile=\"{q}\"}} {v}\n"
                ));
            }
            out.push_str(&format!(
                "amips_tenant_latency_seconds_max{label} {}\n",
                hist.max_s()
            ));
        }
        for (name, coll) in &self.mutables {
            let label = format!("{{collection=\"{}\"}}", esc(name));
            let (mapped, copied) = coll.segment_open_stats();
            out.push_str(&format!(
                "amips_tenant_segments_mapped{label} {mapped}\n"
            ));
            out.push_str(&format!(
                "amips_tenant_segments_copied{label} {copied}\n"
            ));
        }
        for c in self.compactor_counters.lock().unwrap().iter() {
            let label = format!("{{collection=\"{}\"}}", esc(&c.collection));
            out.push_str(&format!(
                "amips_compactor_passes_total{label} {}\n",
                c.passes.load(Ordering::Relaxed)
            ));
            out.push_str(&format!(
                "amips_compactor_errors_total{label} {}\n",
                c.errors.load(Ordering::Relaxed)
            ));
        }
        out
    }
}

impl MetricsSource for Shared {
    fn render(&self) -> String {
        self.render_metrics()
    }

    fn shutting(&self) -> bool {
        self.shutting.load(Ordering::SeqCst)
    }
}

/// A running TCP search server over a catalog of collections.
pub struct NetServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// One background compaction worker per mutable collection
    /// (stopped and joined by [`NetServer::shutdown`] / drop).
    compactors: Vec<Compactor>,
    /// The optional metrics listener (`cfg.metrics_addr`).
    metrics: Option<MetricsListener>,
}

impl NetServer {
    /// Serve every collection of an opened [`Catalog`] on `addr`
    /// (`127.0.0.1:0` binds an ephemeral port — read it back from
    /// [`NetServer::local_addr`]). Collections with an attached mapper
    /// serve `mode=mapped` traffic.
    pub fn serve_catalog(
        catalog: &Catalog,
        addr: impl ToSocketAddrs,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        let mut tenants = BTreeMap::new();
        let mut mutables = BTreeMap::new();
        for entry in catalog.entries() {
            let tenant = Tenant::start(
                &entry.name,
                entry.index.clone(),
                entry.mapper.clone(),
                cfg.policy,
                cfg.queue_cap,
            )
            .with_context(|| format!("starting worker for collection '{}'", entry.name))?;
            tenants.insert(entry.name.clone(), tenant);
            if let Some(coll) = &entry.mutable {
                mutables.insert(entry.name.clone(), coll.clone());
            }
        }
        anyhow::ensure!(!tenants.is_empty(), "catalog has no collections to serve");
        let mut server = NetServer::serve_mutable(tenants, mutables, addr, cfg)?;
        // one background compaction worker per mutable collection; a
        // worker only ever calls `compact()`, which swaps generations
        // under a brief write lock, so searches are never blocked
        for (name, coll) in &server.shared.mutables {
            let compactor = Compactor::spawn(coll.clone(), CompactorConfig::default())?;
            let (passes, errors) = compactor.counter_handles();
            server
                .shared
                .compactor_counters
                .lock()
                .unwrap()
                .push(CompactorCounters {
                    collection: name.clone(),
                    passes,
                    errors,
                });
            server.compactors.push(compactor);
        }
        Ok(server)
    }

    /// Serve an explicit tenant map (the catalog-free entry point used
    /// by tests and embedded setups). Mutate/Compact frames answer
    /// `Unsupported` — use [`NetServer::serve_mutable`] to accept them.
    pub fn serve(
        tenants: BTreeMap<String, Arc<Tenant>>,
        addr: impl ToSocketAddrs,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        NetServer::serve_mutable(tenants, BTreeMap::new(), addr, cfg)
    }

    /// [`NetServer::serve`] plus a map of mutable collections that
    /// accept Mutate/Compact frames. Every mutable name should also be
    /// a tenant (that is what serves its searches); no compaction
    /// workers are spawned here — callers own that policy.
    pub fn serve_mutable(
        tenants: BTreeMap<String, Arc<Tenant>>,
        mutables: BTreeMap<String, Arc<MutableCollection>>,
        addr: impl ToSocketAddrs,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).context("binding listener")?;
        let local_addr = listener.local_addr()?;
        // nonblocking accept so the loop can poll the shutdown flag
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            tenants,
            mutables,
            shutting: AtomicBool::new(false),
            live_connections: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            compactor_counters: Mutex::new(Vec::new()),
            cfg,
        });
        let metrics = match cfg.metrics_addr {
            Some(addr) => Some(
                metrics::spawn(addr, shared.clone() as Arc<dyn MetricsSource>)
                    .context("binding metrics listener")?,
            ),
            None => None,
        };
        let shared2 = shared.clone();
        let accept_thread = std::thread::Builder::new()
            .name("amips-net-accept".into())
            .spawn(move || accept_loop(listener, shared2))?;
        Ok(NetServer {
            shared,
            local_addr,
            accept_thread: Some(accept_thread),
            compactors: Vec::new(),
            metrics,
        })
    }

    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The metrics listener's bound address, when one was configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics.as_ref().map(|m| m.local_addr())
    }

    /// Snapshot server-wide stats (same data as the wire `Stats` frame).
    pub fn stats(&self) -> StatsFrame {
        self.shared.stats_frame()
    }

    /// Graceful shutdown: stop accepting, let connection threads answer
    /// in-flight frames (new Search frames get `ShuttingDown`), drain
    /// every admitted request through the tenant workers with real
    /// replies, then join everything.
    pub fn shutdown(mut self) {
        self.shared.shutting.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // accept loop joined => no new connections; connection threads
        // answer every frame decoded after this point (any type) with
        // `ShuttingDown` and exit, so waiting is bounded by one frame
        // cycle — but bound it anyway so a pathological peer can only
        // delay shutdown, never wedge it.
        let drain_deadline = Instant::now() + self.shared.cfg.drain_timeout;
        while self.shared.live_connections.load(Ordering::SeqCst) > 0
            && Instant::now() < drain_deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        for tenant in self.shared.tenants.values() {
            tenant.begin_shutdown();
        }
        for tenant in self.shared.tenants.values() {
            tenant.join();
        }
        // stop compaction workers, then seal whatever delta state is
        // left so a restart reopens everything this process accepted
        for c in self.compactors.drain(..) {
            c.stop();
        }
        for (name, coll) in &self.shared.mutables {
            if let Err(e) = coll.commit() {
                eprintln!("amips serve: final commit of '{name}' failed: {e:#}");
            }
        }
        if let Some(m) = self.metrics.take() {
            m.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shared.shutting.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(m) = self.metrics.take() {
            m.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.shutting.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if shared.live_connections.load(Ordering::SeqCst) >= shared.cfg.max_connections {
                    let mut stream = stream;
                    // written at v1: decodable by every client vintage
                    let _ = write_frame_versioned(
                        &mut stream,
                        &Frame::Error(ErrorFrame::conn(
                            ErrorCode::Overloaded,
                            "connection limit reached".into(),
                        )),
                        V1,
                    );
                    continue;
                }
                shared.live_connections.fetch_add(1, Ordering::SeqCst);
                let shared2 = shared.clone();
                // detached: shutdown() waits on live_connections with a
                // bounded drain deadline rather than joining each thread,
                // so one stuck peer can't wedge the accept-thread join
                let spawned = std::thread::Builder::new()
                    .name("amips-net-conn".into())
                    .spawn(move || {
                        handle_connection(stream, &shared2);
                        shared2.live_connections.fetch_sub(1, Ordering::SeqCst);
                    });
                if spawned.is_err() {
                    shared.live_connections.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if crate::coordinator::net::wire::is_timeout(&e) => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Per-connection pipelining state. The reader thread owns the
/// decoding loop; `write` is a `try_clone` of the same socket shared
/// with the writer thread, and *every* frame write goes through its
/// mutex so frames never interleave on the wire.
struct Conn {
    write: Arc<Mutex<TcpStream>>,
    /// v2 searches currently admitted into tenant queues. Incremented
    /// at admission (reader thread), decremented by the writer thread
    /// only after the reply has been drained from the queue — that
    /// ordering is what makes queued sends non-blocking (see the
    /// module doc).
    inflight: Arc<AtomicUsize>,
    /// In-flight request ids; a duplicate is a client bug answered
    /// with a typed `BadRequest` echoing the id.
    ids: Arc<Mutex<HashSet<u64>>>,
    /// Cleared by the writer thread when the peer stops accepting
    /// writes; the reader polls it and closes.
    alive: Arc<AtomicBool>,
    /// Owned (not cloned into long-lived state) so it drops when the
    /// reader exits: once every in-flight [`ReplySink`] clone is gone
    /// too, the channel disconnects and the writer thread exits.
    reply_tx: SyncSender<TaggedReply>,
}

impl Conn {
    /// Clone the socket and spawn the detached writer thread. The
    /// writer outlives the reader on purpose: replies still queued at
    /// reader exit (client gone, drain, desync) are flushed
    /// best-effort before the channel disconnects.
    fn start(stream: &TcpStream, shared: &Arc<Shared>) -> std::io::Result<Conn> {
        let write = Arc::new(Mutex::new(stream.try_clone()?));
        let (reply_tx, reply_rx) =
            sync_channel::<TaggedReply>(shared.cfg.max_inflight.max(1));
        let conn = Conn {
            write: write.clone(),
            inflight: Arc::new(AtomicUsize::new(0)),
            ids: Arc::new(Mutex::new(HashSet::new())),
            alive: Arc::new(AtomicBool::new(true)),
            reply_tx,
        };
        let (inflight, ids, alive) = (conn.inflight.clone(), conn.ids.clone(), conn.alive.clone());
        let shared = shared.clone();
        std::thread::Builder::new()
            .name("amips-net-writer".into())
            .spawn(move || {
                while let Ok(done) = reply_rx.recv() {
                    if alive.load(Ordering::SeqCst) {
                        let frame = match done.reply {
                            Ok(hits) => Frame::Hits(hits),
                            Err(e) => Frame::Error(e),
                        };
                        // queued replies only exist on v2 connections
                        let mut w = write.lock().unwrap();
                        if write_frame_versioned(&mut *w, &frame, VERSION).is_err() {
                            alive.store(false, Ordering::SeqCst);
                        }
                    }
                    // free the slot only after the drain: the queue can
                    // never hold more replies than admitted requests
                    ids.lock().unwrap().remove(&done.request_id);
                    inflight.fetch_sub(1, Ordering::SeqCst);
                    shared.inflight.fetch_sub(1, Ordering::SeqCst);
                }
            })?;
        Ok(conn)
    }

    /// Write one frame under the stream mutex, echoing the request's
    /// wire version. `false` means the peer is unreachable.
    fn write(&self, frame: &Frame, version: u8) -> bool {
        let mut w = self.write.lock().unwrap();
        let ok = write_frame_versioned(&mut *w, frame, version).is_ok();
        if !ok {
            self.alive.store(false, Ordering::SeqCst);
        }
        ok
    }

    /// Best-effort typed error reply (the peer may already be gone).
    fn send_error(&self, version: u8, request_id: u64, code: ErrorCode, message: String) {
        self.write(
            &Frame::Error(ErrorFrame {
                request_id,
                code,
                message,
            }),
            version,
        );
    }
}

fn handle_connection(stream: TcpStream, shared: &Arc<Shared>) {
    let mut stream = stream;
    let _ = stream.set_nodelay(true);
    // a peer that stops reading can stall a write (and the shared
    // write mutex) for at most this long before the connection dies
    let _ = stream.set_write_timeout(Some(shared.cfg.frame_timeout.max(Duration::from_millis(1))));
    let Ok(conn) = Conn::start(&stream, shared) else {
        return;
    };
    loop {
        if !conn.alive.load(Ordering::SeqCst) {
            return;
        }
        let (frame, version) = match read_frame_idle(
            &mut stream,
            shared.cfg.idle_timeout,
            shared.cfg.frame_timeout,
        ) {
            Ok(Some(fv)) => fv,
            Ok(None) => {
                // quiet socket: poll the shutdown flag and keep waiting
                if shared.shutting.load(Ordering::SeqCst) {
                    conn.send_error(V1, 0, ErrorCode::ShuttingDown, "server is draining".into());
                    return;
                }
                continue;
            }
            Err(WireError::Closed) => return,
            Err(e) => {
                // a decode error desyncs the stream: typed reply, close.
                // Written at v1 (no id to echo anyway) so every client
                // vintage can decode its eviction notice.
                conn.send_error(V1, 0, e.reply_code(), e.to_string());
                return;
            }
        };
        // once draining, EVERY frame type gets ShuttingDown and a close
        // — a client spamming Ping/Stats faster than the idle timeout
        // must not keep its thread (and thus shutdown()) alive forever.
        // In-flight pipelined replies still flush through the writer.
        if shared.shutting.load(Ordering::SeqCst) {
            conn.send_error(version, 0, ErrorCode::ShuttingDown, "server is draining".into());
            return;
        }
        match frame {
            Frame::Ping { token } => {
                if !conn.write(&Frame::Pong { token }, version) {
                    return;
                }
            }
            Frame::StatsRequest => {
                if !conn.write(&Frame::Stats(shared.stats_frame()), version) {
                    return;
                }
            }
            // v2: admit into the pipeline, reply routed by id later
            Frame::Search(s) if version >= 2 => {
                if !admit_pipelined_search(s, version, &conn, shared) {
                    return;
                }
            }
            // v1: legacy strict alternation, block for the reply
            Frame::Search(s) => {
                let frame = match serve_search_blocking(s, shared) {
                    Ok(hits) => Frame::Hits(hits),
                    Err(e) => Frame::Error(e),
                };
                if !conn.write(&frame, version) {
                    return;
                }
            }
            Frame::Mutate(m) => {
                let id = m.request_id;
                let frame = match serve_mutate(m, shared) {
                    Ok(mut done) => {
                        done.request_id = id;
                        Frame::Mutated(done)
                    }
                    Err(mut e) => {
                        e.request_id = id;
                        Frame::Error(e)
                    }
                };
                if !conn.write(&frame, version) {
                    return;
                }
            }
            Frame::Compact(cf) => {
                let id = cf.request_id;
                let frame = match serve_compact(&cf.collection, shared) {
                    Ok(mut done) => {
                        done.request_id = id;
                        Frame::Mutated(done)
                    }
                    Err(mut e) => {
                        e.request_id = id;
                        Frame::Error(e)
                    }
                };
                if !conn.write(&frame, version) {
                    return;
                }
            }
            // server-to-client frames arriving here are protocol abuse
            Frame::Hits(_)
            | Frame::Error(_)
            | Frame::Pong { .. }
            | Frame::Stats(_)
            | Frame::Mutated(_) => {
                conn.send_error(
                    version,
                    0,
                    ErrorCode::BadRequest,
                    "client sent a server-side frame".into(),
                );
                return;
            }
        }
    }
}

/// Admit one v2 search into the connection's pipeline: duplicate-id
/// and `max_inflight` checks, then a tenant submit with a queued reply
/// sink. Rejections are answered directly (they never held a queue
/// slot). Returns `false` when the connection is unwritable.
fn admit_pipelined_search(
    s: SearchFrame,
    version: u8,
    conn: &Conn,
    shared: &Arc<Shared>,
) -> bool {
    let id = s.request_id;
    if conn.inflight.load(Ordering::SeqCst) >= shared.cfg.max_inflight {
        let msg = format!(
            "connection already has {} requests in flight (max_inflight {})",
            conn.inflight.load(Ordering::SeqCst),
            shared.cfg.max_inflight
        );
        conn.send_error(version, id, ErrorCode::Overloaded, msg);
        return conn.alive.load(Ordering::SeqCst);
    }
    if !conn.ids.lock().unwrap().insert(id) {
        let msg = format!("request id {id} is already in flight on this connection");
        conn.send_error(version, id, ErrorCode::BadRequest, msg);
        return conn.alive.load(Ordering::SeqCst);
    }
    conn.inflight.fetch_add(1, Ordering::SeqCst);
    shared.inflight.fetch_add(1, Ordering::SeqCst);
    let sink = ReplySink::Queued {
        request_id: id,
        tx: conn.reply_tx.clone(),
    };
    if let Err(e) = admit_search(s, shared, sink) {
        // never admitted: no reply will flow through the queue, so
        // undo the slot accounting and answer directly
        conn.ids.lock().unwrap().remove(&id);
        conn.inflight.fetch_sub(1, Ordering::SeqCst);
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
        let mut e = e;
        e.request_id = id;
        conn.write(&Frame::Error(e), version);
        return conn.alive.load(Ordering::SeqCst);
    }
    true
}

/// Validate one search frame and submit it to its tenant with the
/// given reply sink. `Err` means the request was never admitted (the
/// caller replies directly); `Ok` means exactly one reply will reach
/// the sink.
fn admit_search(s: SearchFrame, shared: &Shared, sink: ReplySink) -> Result<(), ErrorFrame> {
    let Some(tenant) = shared.tenants.get(&s.collection) else {
        return Err(ErrorFrame::conn(
            ErrorCode::UnknownCollection,
            format!(
                "no collection '{}' (serving: {})",
                s.collection,
                shared
                    .tenants
                    .keys()
                    .map(String::as_str)
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        ));
    };
    // reject a hostile k at admission, before anything downstream can
    // use it as an allocation size (the tenant triage re-checks for
    // callers that bypass the wire)
    if s.k == 0 || s.k as usize > MAX_HITS {
        return Err(ErrorFrame::conn(
            ErrorCode::BadRequest,
            format!("k {} outside [1, {MAX_HITS}]", s.k),
        ));
    }
    let enqueued = Instant::now();
    let deadline = if s.deadline_micros > 0 {
        Some(enqueued + Duration::from_micros(s.deadline_micros))
    } else {
        None
    };
    let req = NetRequest {
        query: s.query,
        k: s.k as usize,
        effort: s.effort,
        mode: s.mode,
        deadline,
        enqueued,
        reply: sink,
    };
    tenant.submit(req).map_err(|e| {
        ErrorFrame::conn(
            e.code(),
            match e {
                crate::coordinator::net::engine::SubmitError::Overloaded => {
                    format!("collection '{}' queue is full", s.collection)
                }
                crate::coordinator::net::engine::SubmitError::ShuttingDown => {
                    "server is draining".into()
                }
            },
        )
    })
}

/// Route one v1 search frame to its tenant and block for the reply.
fn serve_search_blocking(
    s: SearchFrame,
    shared: &Shared,
) -> Result<crate::coordinator::net::wire::HitsFrame, ErrorFrame> {
    let (rtx, rrx) = sync_channel(1);
    admit_search(s, shared, ReplySink::Oneshot(rtx))?;
    match rrx.recv() {
        Ok(reply) => reply,
        Err(_) => Err(ErrorFrame::conn(
            ErrorCode::Internal,
            "worker dropped the request".into(),
        )),
    }
}

/// Find the named mutable collection, distinguishing "immutable" from
/// "unknown" so clients get an actionable error.
fn find_mutable<'a>(
    name: &str,
    shared: &'a Shared,
) -> Result<&'a Arc<MutableCollection>, ErrorFrame> {
    match shared.mutables.get(name) {
        Some(coll) => Ok(coll),
        None if shared.tenants.contains_key(name) => Err(ErrorFrame::conn(
            ErrorCode::Unsupported,
            format!("collection '{name}' is immutable (built artifact, not .seg)"),
        )),
        None => Err(ErrorFrame::conn(
            ErrorCode::UnknownCollection,
            format!("no collection '{name}'"),
        )),
    }
}

/// Apply one mutation frame on the connection thread. The collection's
/// internal mutation mutex serializes concurrent writers per collection;
/// searches proceed under the read lock throughout.
fn serve_mutate(m: MutateFrame, shared: &Shared) -> Result<MutatedFrame, ErrorFrame> {
    let coll = find_mutable(&m.collection, shared)?;
    let bad = |message: String| ErrorFrame::conn(ErrorCode::BadRequest, message);
    let dim = m.dim as usize;
    // the decoder already guaranteed vectors.len() % dim == 0 (and
    // dim == 0 ⟹ no vectors); here we check op-specific shape rules
    let rows = if dim > 0 { m.vectors.len() / dim } else { 0 };
    let started = Instant::now();
    let ids = match m.op {
        MutateOp::Insert => {
            if !m.ids.is_empty() {
                return Err(bad("insert must not carry ids (they are assigned)".into()));
            }
            if rows == 0 {
                return Err(bad("insert carries no vectors".into()));
            }
            let vecs = Tensor::from_vec(&[rows, dim], m.vectors);
            coll.insert(&vecs).map_err(|e| bad(format!("{e:#}")))?
        }
        MutateOp::Upsert => {
            if rows == 0 {
                return Err(bad("upsert carries no vectors".into()));
            }
            if m.ids.len() != rows {
                return Err(bad(format!(
                    "upsert has {} ids for {} vector rows",
                    m.ids.len(),
                    rows
                )));
            }
            let vecs = Tensor::from_vec(&[rows, dim], m.vectors);
            coll.upsert(&m.ids, &vecs).map_err(|e| bad(format!("{e:#}")))?;
            m.ids
        }
        MutateOp::Delete => {
            if m.ids.is_empty() {
                return Err(bad("delete carries no ids".into()));
            }
            if rows != 0 {
                return Err(bad("delete must not carry vectors".into()));
            }
            coll.delete(&m.ids).map_err(|e| bad(format!("{e:#}")))?;
            m.ids
        }
    };
    Ok(MutatedFrame {
        request_id: 0, // stamped by the caller from the request frame
        ids,
        len: coll.len() as u64,
        gen: coll.generation(),
        server_micros: started.elapsed().as_micros() as u64,
    })
}

/// Fold the named collection's delta + tombstones into a fresh sealed
/// generation. Runs on the connection thread; searches keep serving the
/// old generation until the swap commits.
fn serve_compact(name: &str, shared: &Shared) -> Result<MutatedFrame, ErrorFrame> {
    let coll = find_mutable(name, shared)?;
    let started = Instant::now();
    let gen = coll
        .compact()
        .map_err(|e| ErrorFrame::conn(ErrorCode::Internal, format!("compaction failed: {e:#}")))?;
    Ok(MutatedFrame {
        request_id: 0, // stamped by the caller from the request frame
        ids: Vec::new(),
        len: coll.len() as u64,
        gen,
        server_micros: started.elapsed().as_micros() as u64,
    })
}
