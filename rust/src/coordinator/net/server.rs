//! The TCP front-end: accept loop, per-connection frame loop, tenant
//! routing, stats aggregation and graceful shutdown.
//!
//! ```text
//!  TcpListener (nonblocking poll, shutdown-aware)
//!     └── connection thread per client (capped)
//!           ├── read_frame_idle: idle-poll for the stop flag without
//!           │   desyncing mid-frame; slow-loris frame timeout
//!           ├── draining? -> every frame answers ShuttingDown + close
//!           ├── Ping -> Pong, StatsRequest -> Stats
//!           ├── Search -> validate k -> Tenant::submit (bounded) ->
//!           │   block on reply
//!           └── Mutate/Compact -> route to the mutable collection,
//!               apply on the connection thread (the collection's own
//!               mutation mutex serializes writers; searches keep
//!               serving the old generation until the swap commits)
//!  Tenant (one per catalog collection)
//!     └── worker thread: Batcher -> deadline triage -> map pass ->
//!         fused (k, effort) group scans -> per-request replies
//! ```
//!
//! Every failure a client can cause — unknown collection, bad frame,
//! full queue, expired deadline, draining server — is answered with a
//! typed [`ErrorFrame`] before the connection is (at worst) closed;
//! nothing hangs a socket and nothing allocates beyond the wire caps.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::net::engine::{NetRequest, Tenant};
use crate::coordinator::net::wire::{
    read_frame_idle, write_frame, ErrorCode, ErrorFrame, Frame, MutateFrame, MutateOp,
    MutatedFrame, StatsFrame, WireError, MAX_HITS,
};
use crate::index::catalog::Catalog;
use crate::index::segment::{Compactor, CompactorConfig, MutableCollection};
use crate::index::VectorIndex;
use crate::tensor::Tensor;
use crate::util::timer::LatencyHistogram;

/// Tuning knobs for the TCP front-end.
#[derive(Clone, Copy, Debug)]
pub struct NetServerConfig {
    /// Batch policy shared by every tenant worker.
    pub policy: BatchPolicy,
    /// Bounded admission queue per tenant; a full queue answers
    /// [`ErrorCode::Overloaded`].
    pub queue_cap: usize,
    /// Concurrent connection cap; excess connects get a typed
    /// `Overloaded` reply and are closed.
    pub max_connections: usize,
    /// How long a quiet connection sleeps between stop-flag polls.
    pub idle_timeout: Duration,
    /// Once a frame has started arriving, how long the rest may take
    /// (slow-loris guard).
    pub frame_timeout: Duration,
    /// How long [`NetServer::shutdown`] waits for connection threads to
    /// notice the stop flag before proceeding without them (they exit
    /// on their own; shutdown just stops blocking on stragglers).
    pub drain_timeout: Duration,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            policy: BatchPolicy::default(),
            queue_cap: 1024,
            max_connections: 256,
            idle_timeout: Duration::from_millis(50),
            frame_timeout: Duration::from_secs(2),
            drain_timeout: Duration::from_secs(10),
        }
    }
}

struct Shared {
    tenants: BTreeMap<String, Arc<Tenant>>,
    /// Mutable collections by name (a subset of `tenants`' names):
    /// searches go through the tenant worker like any collection, while
    /// Mutate/Compact frames route here. The collection's own mutation
    /// mutex serializes writers, so connection threads apply directly.
    mutables: BTreeMap<String, Arc<MutableCollection>>,
    shutting: AtomicBool,
    live_connections: AtomicUsize,
    cfg: NetServerConfig,
}

impl Shared {
    /// Roll per-tenant counters and latency snapshots up into one
    /// server-wide stats frame.
    fn stats_frame(&self) -> StatsFrame {
        let mut hist = LatencyHistogram::new();
        let mut out = StatsFrame::default();
        for tenant in self.tenants.values() {
            let c = tenant.collection_stats();
            out.served += c.served;
            out.errors += c.errors;
            out.overloaded += c.overloaded;
            out.expired += c.expired;
            out.queue_depth += c.queue_depth;
            out.collections.push(c);
            hist.merge(&tenant.stats().latency.lock().unwrap().snapshot());
        }
        out.mean_s = hist.mean_s();
        out.p50_s = hist.p50_s();
        out.p99_s = hist.p99_s();
        out.p999_s = hist.p999_s();
        out.max_s = hist.max_s();
        out
    }
}

/// A running TCP search server over a catalog of collections.
pub struct NetServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// One background compaction worker per mutable collection
    /// (stopped and joined by [`NetServer::shutdown`] / drop).
    compactors: Vec<Compactor>,
}

impl NetServer {
    /// Serve every collection of an opened [`Catalog`] on `addr`
    /// (`127.0.0.1:0` binds an ephemeral port — read it back from
    /// [`NetServer::local_addr`]). Collections with an attached mapper
    /// serve `mode=mapped` traffic.
    pub fn serve_catalog(
        catalog: &Catalog,
        addr: impl ToSocketAddrs,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        let mut tenants = BTreeMap::new();
        let mut mutables = BTreeMap::new();
        for entry in catalog.entries() {
            let tenant = Tenant::start(
                &entry.name,
                entry.index.clone(),
                entry.mapper.clone(),
                cfg.policy,
                cfg.queue_cap,
            )
            .with_context(|| format!("starting worker for collection '{}'", entry.name))?;
            tenants.insert(entry.name.clone(), tenant);
            if let Some(coll) = &entry.mutable {
                mutables.insert(entry.name.clone(), coll.clone());
            }
        }
        anyhow::ensure!(!tenants.is_empty(), "catalog has no collections to serve");
        let mut server = NetServer::serve_mutable(tenants, mutables, addr, cfg)?;
        // one background compaction worker per mutable collection; a
        // worker only ever calls `compact()`, which swaps generations
        // under a brief write lock, so searches are never blocked
        for coll in server.shared.mutables.values() {
            server
                .compactors
                .push(Compactor::spawn(coll.clone(), CompactorConfig::default())?);
        }
        Ok(server)
    }

    /// Serve an explicit tenant map (the catalog-free entry point used
    /// by tests and embedded setups). Mutate/Compact frames answer
    /// `Unsupported` — use [`NetServer::serve_mutable`] to accept them.
    pub fn serve(
        tenants: BTreeMap<String, Arc<Tenant>>,
        addr: impl ToSocketAddrs,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        NetServer::serve_mutable(tenants, BTreeMap::new(), addr, cfg)
    }

    /// [`NetServer::serve`] plus a map of mutable collections that
    /// accept Mutate/Compact frames. Every mutable name should also be
    /// a tenant (that is what serves its searches); no compaction
    /// workers are spawned here — callers own that policy.
    pub fn serve_mutable(
        tenants: BTreeMap<String, Arc<Tenant>>,
        mutables: BTreeMap<String, Arc<MutableCollection>>,
        addr: impl ToSocketAddrs,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).context("binding listener")?;
        let local_addr = listener.local_addr()?;
        // nonblocking accept so the loop can poll the shutdown flag
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            tenants,
            mutables,
            shutting: AtomicBool::new(false),
            live_connections: AtomicUsize::new(0),
            cfg,
        });
        let shared2 = shared.clone();
        let accept_thread = std::thread::Builder::new()
            .name("amips-net-accept".into())
            .spawn(move || accept_loop(listener, shared2))?;
        Ok(NetServer {
            shared,
            local_addr,
            accept_thread: Some(accept_thread),
            compactors: Vec::new(),
        })
    }

    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Snapshot server-wide stats (same data as the wire `Stats` frame).
    pub fn stats(&self) -> StatsFrame {
        self.shared.stats_frame()
    }

    /// Graceful shutdown: stop accepting, let connection threads answer
    /// in-flight frames (new Search frames get `ShuttingDown`), drain
    /// every admitted request through the tenant workers with real
    /// replies, then join everything.
    pub fn shutdown(mut self) {
        self.shared.shutting.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // accept loop joined => no new connections; connection threads
        // answer every frame decoded after this point (any type) with
        // `ShuttingDown` and exit, so waiting is bounded by one frame
        // cycle — but bound it anyway so a pathological peer can only
        // delay shutdown, never wedge it.
        let drain_deadline = Instant::now() + self.shared.cfg.drain_timeout;
        while self.shared.live_connections.load(Ordering::SeqCst) > 0
            && Instant::now() < drain_deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        for tenant in self.shared.tenants.values() {
            tenant.begin_shutdown();
        }
        for tenant in self.shared.tenants.values() {
            tenant.join();
        }
        // stop compaction workers, then seal whatever delta state is
        // left so a restart reopens everything this process accepted
        for c in self.compactors.drain(..) {
            c.stop();
        }
        for (name, coll) in &self.shared.mutables {
            if let Err(e) = coll.commit() {
                eprintln!("amips serve: final commit of '{name}' failed: {e:#}");
            }
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shared.shutting.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.shutting.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if shared.live_connections.load(Ordering::SeqCst) >= shared.cfg.max_connections {
                    let mut stream = stream;
                    let _ = write_frame(
                        &mut stream,
                        &Frame::Error(ErrorFrame {
                            code: ErrorCode::Overloaded,
                            message: "connection limit reached".into(),
                        }),
                    );
                    continue;
                }
                shared.live_connections.fetch_add(1, Ordering::SeqCst);
                let shared2 = shared.clone();
                // detached: shutdown() waits on live_connections with a
                // bounded drain deadline rather than joining each thread,
                // so one stuck peer can't wedge the accept-thread join
                let spawned = std::thread::Builder::new()
                    .name("amips-net-conn".into())
                    .spawn(move || {
                        handle_connection(stream, &shared2);
                        shared2.live_connections.fetch_sub(1, Ordering::SeqCst);
                    });
                if spawned.is_err() {
                    shared.live_connections.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if crate::coordinator::net::wire::is_timeout(&e) => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Best-effort typed error reply (the peer may already be gone).
fn send_error(stream: &mut TcpStream, code: ErrorCode, message: String) {
    let _ = write_frame(stream, &Frame::Error(ErrorFrame { code, message }));
}

fn handle_connection(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_nodelay(true);
    loop {
        let frame = match read_frame_idle(
            &mut stream,
            shared.cfg.idle_timeout,
            shared.cfg.frame_timeout,
        ) {
            Ok(Some(f)) => f,
            Ok(None) => {
                // quiet socket: poll the shutdown flag and keep waiting
                if shared.shutting.load(Ordering::SeqCst) {
                    send_error(
                        &mut stream,
                        ErrorCode::ShuttingDown,
                        "server is draining".into(),
                    );
                    return;
                }
                continue;
            }
            Err(WireError::Closed) => return,
            Err(e) => {
                // a decode error desyncs the stream: typed reply, close
                send_error(&mut stream, e.reply_code(), e.to_string());
                return;
            }
        };
        // once draining, EVERY frame type gets ShuttingDown and a close
        // — a client spamming Ping/Stats faster than the idle timeout
        // must not keep its thread (and thus shutdown()) alive forever
        if shared.shutting.load(Ordering::SeqCst) {
            send_error(
                &mut stream,
                ErrorCode::ShuttingDown,
                "server is draining".into(),
            );
            return;
        }
        match frame {
            Frame::Ping { token } => {
                if write_frame(&mut stream, &Frame::Pong { token }).is_err() {
                    return;
                }
            }
            Frame::StatsRequest => {
                if write_frame(&mut stream, &Frame::Stats(shared.stats_frame())).is_err() {
                    return;
                }
            }
            Frame::Search(s) => {
                let reply = serve_search(s, shared);
                let frame = match reply {
                    Ok(hits) => Frame::Hits(hits),
                    Err(e) => Frame::Error(e),
                };
                if write_frame(&mut stream, &frame).is_err() {
                    return;
                }
            }
            Frame::Mutate(m) => {
                let frame = match serve_mutate(m, shared) {
                    Ok(done) => Frame::Mutated(done),
                    Err(e) => Frame::Error(e),
                };
                if write_frame(&mut stream, &frame).is_err() {
                    return;
                }
            }
            Frame::Compact(cf) => {
                let frame = match serve_compact(&cf.collection, shared) {
                    Ok(done) => Frame::Mutated(done),
                    Err(e) => Frame::Error(e),
                };
                if write_frame(&mut stream, &frame).is_err() {
                    return;
                }
            }
            // server-to-client frames arriving here are protocol abuse
            Frame::Hits(_)
            | Frame::Error(_)
            | Frame::Pong { .. }
            | Frame::Stats(_)
            | Frame::Mutated(_) => {
                send_error(
                    &mut stream,
                    ErrorCode::BadRequest,
                    "client sent a server-side frame".into(),
                );
                return;
            }
        }
    }
}

/// Route one search frame to its tenant and block for the reply.
fn serve_search(
    s: crate::coordinator::net::wire::SearchFrame,
    shared: &Shared,
) -> Result<crate::coordinator::net::wire::HitsFrame, ErrorFrame> {
    let Some(tenant) = shared.tenants.get(&s.collection) else {
        return Err(ErrorFrame {
            code: ErrorCode::UnknownCollection,
            message: format!(
                "no collection '{}' (serving: {})",
                s.collection,
                shared
                    .tenants
                    .keys()
                    .map(String::as_str)
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        });
    };
    // reject a hostile k at admission, before anything downstream can
    // use it as an allocation size (the tenant triage re-checks for
    // callers that bypass the wire)
    if s.k == 0 || s.k as usize > MAX_HITS {
        return Err(ErrorFrame {
            code: ErrorCode::BadRequest,
            message: format!("k {} outside [1, {MAX_HITS}]", s.k),
        });
    }
    let enqueued = Instant::now();
    let deadline = if s.deadline_micros > 0 {
        Some(enqueued + Duration::from_micros(s.deadline_micros))
    } else {
        None
    };
    let (rtx, rrx) = sync_channel(1);
    let req = NetRequest {
        query: s.query,
        k: s.k as usize,
        effort: s.effort,
        mode: s.mode,
        deadline,
        enqueued,
        reply: rtx,
    };
    if let Err(e) = tenant.submit(req) {
        return Err(ErrorFrame {
            code: e.code(),
            message: match e {
                crate::coordinator::net::engine::SubmitError::Overloaded => {
                    format!("collection '{}' queue is full", s.collection)
                }
                crate::coordinator::net::engine::SubmitError::ShuttingDown => {
                    "server is draining".into()
                }
            },
        });
    }
    match rrx.recv() {
        Ok(reply) => reply,
        Err(_) => Err(ErrorFrame {
            code: ErrorCode::Internal,
            message: "worker dropped the request".into(),
        }),
    }
}

/// Find the named mutable collection, distinguishing "immutable" from
/// "unknown" so clients get an actionable error.
fn find_mutable<'a>(
    name: &str,
    shared: &'a Shared,
) -> Result<&'a Arc<MutableCollection>, ErrorFrame> {
    match shared.mutables.get(name) {
        Some(coll) => Ok(coll),
        None if shared.tenants.contains_key(name) => Err(ErrorFrame {
            code: ErrorCode::Unsupported,
            message: format!("collection '{name}' is immutable (built artifact, not .seg)"),
        }),
        None => Err(ErrorFrame {
            code: ErrorCode::UnknownCollection,
            message: format!("no collection '{name}'"),
        }),
    }
}

/// Apply one mutation frame on the connection thread. The collection's
/// internal mutation mutex serializes concurrent writers per collection;
/// searches proceed under the read lock throughout.
fn serve_mutate(m: MutateFrame, shared: &Shared) -> Result<MutatedFrame, ErrorFrame> {
    let coll = find_mutable(&m.collection, shared)?;
    let bad = |message: String| ErrorFrame {
        code: ErrorCode::BadRequest,
        message,
    };
    let dim = m.dim as usize;
    // the decoder already guaranteed vectors.len() % dim == 0 (and
    // dim == 0 ⟹ no vectors); here we check op-specific shape rules
    let rows = if dim > 0 { m.vectors.len() / dim } else { 0 };
    let started = Instant::now();
    let ids = match m.op {
        MutateOp::Insert => {
            if !m.ids.is_empty() {
                return Err(bad("insert must not carry ids (they are assigned)".into()));
            }
            if rows == 0 {
                return Err(bad("insert carries no vectors".into()));
            }
            let vecs = Tensor::from_vec(&[rows, dim], m.vectors);
            coll.insert(&vecs).map_err(|e| bad(format!("{e:#}")))?
        }
        MutateOp::Upsert => {
            if rows == 0 {
                return Err(bad("upsert carries no vectors".into()));
            }
            if m.ids.len() != rows {
                return Err(bad(format!(
                    "upsert has {} ids for {} vector rows",
                    m.ids.len(),
                    rows
                )));
            }
            let vecs = Tensor::from_vec(&[rows, dim], m.vectors);
            coll.upsert(&m.ids, &vecs).map_err(|e| bad(format!("{e:#}")))?;
            m.ids
        }
        MutateOp::Delete => {
            if m.ids.is_empty() {
                return Err(bad("delete carries no ids".into()));
            }
            if rows != 0 {
                return Err(bad("delete must not carry vectors".into()));
            }
            coll.delete(&m.ids).map_err(|e| bad(format!("{e:#}")))?;
            m.ids
        }
    };
    Ok(MutatedFrame {
        ids,
        len: coll.len() as u64,
        gen: coll.generation(),
        server_micros: started.elapsed().as_micros() as u64,
    })
}

/// Fold the named collection's delta + tombstones into a fresh sealed
/// generation. Runs on the connection thread; searches keep serving the
/// old generation until the swap commits.
fn serve_compact(name: &str, shared: &Shared) -> Result<MutatedFrame, ErrorFrame> {
    let coll = find_mutable(name, shared)?;
    let started = Instant::now();
    let gen = coll.compact().map_err(|e| ErrorFrame {
        code: ErrorCode::Internal,
        message: format!("compaction failed: {e:#}"),
    })?;
    Ok(MutatedFrame {
        ids: Vec::new(),
        len: coll.len() as u64,
        gen,
        server_micros: started.elapsed().as_micros() as u64,
    })
}
