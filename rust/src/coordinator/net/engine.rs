//! Per-collection serving engine behind the TCP front-end: a bounded
//! admission queue feeding the existing [`Batcher`] →
//! fused-batched-scan path, with deadline fast-fail and per-tenant
//! statistics.
//!
//! One [`Tenant`] per catalog collection. Connection threads
//! [`Tenant::submit`] decoded requests; admission is a bounded
//! `sync_channel`, so a saturated tenant answers with a typed
//! [`ErrorCode::Overloaded`] instead of growing an unbounded queue
//! (backpressure is part of the protocol, not an OOM). A dedicated
//! worker thread drains the queue through the shared
//! [`Batcher`] policy and runs each `(k, effort)` group through the
//! same fused [`search_batch_parallel`] path the in-process
//! coordinator uses — per-request hits stay bit-identical to solo
//! [`VectorIndex::search_effort`] calls.
//!
//! Requests carry an optional absolute deadline (decoded from the
//! frame's relative budget). Expired requests are failed *before* the
//! scan — a client that has already given up never costs key traffic.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::{Effort, QueryMap, QueryMode};
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::net::wire::{CollectionStats, ErrorCode, ErrorFrame, HitsFrame, MAX_HITS};
use crate::index::traits::VectorIndex;
use crate::model::RustModel;
use crate::tensor::Tensor;
use crate::util::timer::LatencyHistogram;

/// Reply to one admitted request: hits or a typed error.
pub type NetReply = Result<HitsFrame, ErrorFrame>;

/// A completed reply tagged with the request id it answers, bound for
/// a pipelined connection's writer thread.
pub struct TaggedReply {
    pub request_id: u64,
    pub reply: NetReply,
}

/// Where a completed request's reply goes. Strict-alternation (v1)
/// connections and in-process callers block on a one-shot channel;
/// pipelined (v2) connections route the reply — stamped with its
/// request id — into the connection's bounded reply queue, where a
/// dedicated writer thread serializes completions in whatever order
/// they finish.
#[derive(Clone)]
pub enum ReplySink {
    Oneshot(SyncSender<NetReply>),
    Queued {
        request_id: u64,
        tx: SyncSender<TaggedReply>,
    },
}

impl ReplySink {
    /// Deliver the reply. For queued sinks the frame's `request_id` is
    /// stamped here, so tenant workers never need to know which id (or
    /// wire version) a request arrived under. A send to a
    /// disconnected sink is a no-op: the connection is gone and the
    /// reply has nowhere to go.
    ///
    /// Queued sends use the *blocking* `send`, but can never actually
    /// block: a connection admits at most `max_inflight` requests and
    /// its reply queue holds `max_inflight` slots, and a slot is only
    /// reused after its previous reply has been drained by the writer.
    pub fn send(&self, mut reply: NetReply) {
        match self {
            ReplySink::Oneshot(tx) => {
                let _ = tx.send(reply);
            }
            ReplySink::Queued { request_id, tx } => {
                match &mut reply {
                    Ok(h) => h.request_id = *request_id,
                    Err(e) => e.request_id = *request_id,
                }
                let _ = tx.send(TaggedReply {
                    request_id: *request_id,
                    reply,
                });
            }
        }
    }
}

/// One admitted search request queued for a tenant worker.
pub struct NetRequest {
    pub query: Vec<f32>,
    pub k: usize,
    pub effort: Effort,
    pub mode: QueryMode,
    /// Absolute expiry; checked when the batch is drained, before scan.
    pub deadline: Option<Instant>,
    pub enqueued: Instant,
    pub reply: ReplySink,
}

/// Why [`Tenant::submit`] refused a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue full: typed backpressure, retry later.
    Overloaded,
    /// The tenant worker is draining for shutdown.
    ShuttingDown,
}

impl SubmitError {
    pub fn code(self) -> ErrorCode {
        match self {
            SubmitError::Overloaded => ErrorCode::Overloaded,
            SubmitError::ShuttingDown => ErrorCode::ShuttingDown,
        }
    }
}

/// Lock-free counters plus the latency histogram for one tenant.
#[derive(Default)]
pub struct TenantStats {
    pub served: AtomicU64,
    pub errors: AtomicU64,
    pub overloaded: AtomicU64,
    pub expired: AtomicU64,
    pub queue_depth: AtomicUsize,
    pub latency: Mutex<LatencyHistogram>,
}

impl TenantStats {
    fn new() -> TenantStats {
        TenantStats {
            latency: Mutex::new(LatencyHistogram::new()),
            ..Default::default()
        }
    }
}

/// One served collection: bounded admission into a worker thread that
/// batches and scans a shared index (optionally through its attached
/// query mapper).
pub struct Tenant {
    pub name: String,
    dim: usize,
    /// `None` once shutdown has begun: dropping the sender disconnects
    /// the receiver, so the worker's [`Batcher`] drains what's queued
    /// (every queued request still gets a real reply) and exits.
    tx: Mutex<Option<SyncSender<NetRequest>>>,
    stats: Arc<TenantStats>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Tenant {
    /// Start a tenant worker over `index`. `mapper` is the collection's
    /// attached c=1 model (serves [`QueryMode::Mapped`] traffic);
    /// `queue_cap` bounds the admission queue.
    pub fn start(
        name: &str,
        index: Arc<dyn VectorIndex>,
        mapper: Option<Arc<RustModel>>,
        policy: BatchPolicy,
        queue_cap: usize,
    ) -> std::io::Result<Arc<Tenant>> {
        let (tx, rx) = sync_channel::<NetRequest>(queue_cap.max(1));
        let stats = Arc::new(TenantStats::new());
        let tenant = Arc::new(Tenant {
            name: name.to_string(),
            dim: index.dim(),
            tx: Mutex::new(Some(tx)),
            stats: stats.clone(),
            worker: Mutex::new(None),
        });
        let worker_name = format!("amips-net-{name}");
        let handle = std::thread::Builder::new().name(worker_name).spawn(move || {
            // The query map is built on the worker thread (mirrors the
            // in-process server's MapperFactory contract).
            let map: Option<crate::api::KeyNetQueryMap> = mapper.and_then(|m| {
                // catalog loading already validated c=1; a failure here
                // degrades Mapped traffic to typed errors, not a panic
                crate::api::KeyNetQueryMap::new((*m).clone()).ok()
            });
            let batcher = Batcher::new(rx, policy);
            while let Some((batch, _reason)) = batcher.next_batch() {
                // every drained request was counted before its send (see
                // submit), so an unclamped subtract can never underflow
                stats.queue_depth.fetch_sub(batch.len(), Ordering::Relaxed);
                serve_net_batch(batch, index.as_ref(), &map, &stats);
            }
        })?;
        *tenant.worker.lock().unwrap() = Some(handle);
        Ok(tenant)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn stats(&self) -> &TenantStats {
        &self.stats
    }

    /// Non-blocking admission. `Err` means the caller should reply with
    /// the matching typed error frame; the request is never queued.
    pub fn submit(&self, req: NetRequest) -> Result<(), SubmitError> {
        let guard = self.tx.lock().unwrap();
        let Some(tx) = guard.as_ref() else {
            return Err(SubmitError::ShuttingDown);
        };
        // count *before* the send: once the request is in the channel
        // the worker may drain it at any moment, and its unclamped
        // decrement must always find this increment already applied
        self.stats.queue_depth.fetch_add(1, Ordering::Relaxed);
        match tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => {
                self.stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
                self.stats.overloaded.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::ShuttingDown)
            }
        }
    }

    /// Begin shutdown: drop the queue sender so the worker drains every
    /// already-admitted request (real replies, not cancellations) and
    /// exits. Subsequent [`Tenant::submit`] calls get `ShuttingDown`.
    pub fn begin_shutdown(&self) {
        self.tx.lock().unwrap().take();
    }

    /// Join the worker after [`Tenant::begin_shutdown`].
    pub fn join(&self) {
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    /// Snapshot this tenant's counters as a wire stats row.
    pub fn collection_stats(&self) -> CollectionStats {
        CollectionStats {
            name: self.name.clone(),
            served: self.stats.served.load(Ordering::Relaxed),
            errors: self.stats.errors.load(Ordering::Relaxed),
            overloaded: self.stats.overloaded.load(Ordering::Relaxed),
            expired: self.stats.expired.load(Ordering::Relaxed),
            queue_depth: self.stats.queue_depth.load(Ordering::Relaxed) as u64,
        }
    }
}

fn reply_err(req: &NetRequest, stats: &TenantStats, code: ErrorCode, message: String) {
    if code == ErrorCode::DeadlineExpired {
        stats.expired.fetch_add(1, Ordering::Relaxed);
    } else {
        stats.errors.fetch_add(1, Ordering::Relaxed);
    }
    // id 0 here; queued sinks stamp the real request id on send
    req.reply.send(Err(ErrorFrame::conn(code, message)));
}

/// Serve one drained batch: deadline fast-fail and validation first,
/// then one fused map pass over the mapped rows, then one fused scan
/// per `(k, effort)` group, then per-request replies + stats.
fn serve_net_batch(
    batch: Vec<NetRequest>,
    index: &dyn VectorIndex,
    mapper: &Option<crate::api::KeyNetQueryMap>,
    stats: &TenantStats,
) {
    let d = index.dim();
    let now = Instant::now();
    // triage before any scan work
    let mut valid: Vec<NetRequest> = Vec::with_capacity(batch.len());
    for mut req in batch {
        if let Some(dl) = req.deadline {
            if now >= dl {
                let msg = format!(
                    "deadline expired {}us before scan",
                    now.duration_since(dl).as_micros()
                );
                reply_err(&req, stats, ErrorCode::DeadlineExpired, msg);
                continue;
            }
        }
        if req.query.len() != d {
            let msg = format!("query dim {} != index dim {d}", req.query.len());
            reply_err(&req, stats, ErrorCode::BadRequest, msg);
            continue;
        }
        // wire-supplied k must be validated before it sizes anything: a
        // hostile k would otherwise reach TopK::new(k) as an allocation
        if req.k == 0 || req.k > MAX_HITS {
            let msg = format!("k {} outside [1, {MAX_HITS}]", req.k);
            reply_err(&req, stats, ErrorCode::BadRequest, msg);
            continue;
        }
        // an index never returns more than its corpus, so clamping here
        // changes no result but bounds per-request scratch by the index
        req.k = req.k.min(index.len().max(1));
        match req.mode {
            QueryMode::Original => valid.push(req),
            QueryMode::Mapped if mapper.is_some() => valid.push(req),
            QueryMode::Mapped => {
                reply_err(
                    &req,
                    stats,
                    ErrorCode::Unsupported,
                    "collection has no attached query mapper; send mode=original".into(),
                );
            }
            QueryMode::Routed => {
                reply_err(
                    &req,
                    stats,
                    ErrorCode::Unsupported,
                    "routed mode is not served over the wire".into(),
                );
            }
        }
    }
    if valid.is_empty() {
        return;
    }
    let mut q = Tensor::zeros(&[valid.len(), d]);
    for (i, r) in valid.iter().enumerate() {
        q.row_mut(i).copy_from_slice(&r.query);
    }
    // one fused mapping pass over the rows that request it
    let mapped_rows: Vec<usize> = valid
        .iter()
        .enumerate()
        .filter(|(_, r)| r.mode == QueryMode::Mapped)
        .map(|(i, _)| i)
        .collect();
    let mut map_err: Option<String> = None;
    let mapped: Option<Tensor> = if mapped_rows.is_empty() {
        None
    } else {
        let m = mapper.as_ref().expect("mapped rows imply a mapper");
        match m.map(&q.gather_rows(&mapped_rows)) {
            Ok(t) if t.row_width() == d => Some(t),
            Ok(t) => {
                map_err = Some(format!(
                    "query map produced dim {} but index expects {d}",
                    t.row_width()
                ));
                None
            }
            Err(e) => {
                map_err = Some(format!("query mapping failed: {e:#}"));
                None
            }
        }
    };
    // slot of each valid row in the mapped sub-batch
    let mapped_slot: Vec<Option<usize>> = {
        let mut slots = vec![None; valid.len()];
        for (pos, &row) in mapped_rows.iter().enumerate() {
            slots[row] = Some(pos);
        }
        slots
    };
    // group by (k, effort); one fused parallel scan per group
    let mut groups: Vec<(usize, Effort, Vec<usize>)> = Vec::new();
    for (i, r) in valid.iter().enumerate() {
        if r.mode == QueryMode::Mapped && mapped.is_none() {
            continue; // map failed; replied below
        }
        match groups
            .iter_mut()
            .find(|(gk, ge, _)| *gk == r.k && *ge == r.effort)
        {
            Some((_, _, members)) => members.push(i),
            None => groups.push((r.k, r.effort, vec![i])),
        }
    }
    let map_flops = mapper.as_ref().map_or(0, |m| m.map_flops_per_query());
    let mut replies: Vec<Option<HitsFrame>> = (0..valid.len()).map(|_| None).collect();
    for (k, effort, members) in &groups {
        let mut gq = Tensor::zeros(&[members.len(), d]);
        for (gi, &i) in members.iter().enumerate() {
            let row = match mapped_slot[i] {
                Some(pos) => mapped.as_ref().expect("group rows have mapped tensor").row(pos),
                None => q.row(i),
            };
            gq.row_mut(gi).copy_from_slice(row);
        }
        let results = crate::api::search_batch_parallel(index, &gq, *k, *effort);
        for (&i, res) in members.iter().zip(results) {
            replies[i] = Some(HitsFrame {
                request_id: 0, // stamped by the reply sink
                ids: res.ids,
                scores: res.scores,
                keys_scanned: res.cost.keys_scanned,
                cells_probed: res.cost.cells_probed,
                map_flops: if mapped_slot[i].is_some() { map_flops } else { 0 },
                scan_flops: res.cost.flops,
                server_micros: 0, // stamped per request below
            });
        }
    }
    for (req, reply) in valid.into_iter().zip(replies) {
        match reply {
            Some(mut hits) => {
                let latency = req.enqueued.elapsed();
                hits.server_micros = latency.as_micros().min(u64::MAX as u128) as u64;
                stats.served.fetch_add(1, Ordering::Relaxed);
                stats.latency.lock().unwrap().record(latency.as_secs_f64());
                req.reply.send(Ok(hits));
            }
            None => {
                let msg = map_err.clone().unwrap_or_else(|| "internal error".into());
                reply_err(&req, stats, ErrorCode::Internal, msg);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::flat::FlatIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;
    use std::time::Duration;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    fn request(query: Vec<f32>, k: usize) -> (NetRequest, Receiver<NetReply>) {
        let (rtx, rrx) = sync_channel(1);
        (
            NetRequest {
                query,
                k,
                effort: Effort::Exhaustive,
                mode: QueryMode::Original,
                deadline: None,
                enqueued: Instant::now(),
                reply: ReplySink::Oneshot(rtx),
            },
            rrx,
        )
    }

    /// A tenant whose worker never starts: admission behavior becomes
    /// deterministic (nothing drains the queue).
    fn detached_tenant(queue_cap: usize) -> (Tenant, Receiver<NetRequest>) {
        let (tx, rx) = sync_channel(queue_cap);
        (
            Tenant {
                name: "t".into(),
                dim: 4,
                tx: Mutex::new(Some(tx)),
                stats: Arc::new(TenantStats::new()),
                worker: Mutex::new(None),
            },
            rx,
        )
    }

    #[test]
    fn queued_sink_stamps_request_ids() {
        let (tx, rx) = sync_channel(2);
        let sink = ReplySink::Queued { request_id: 42, tx };
        sink.send(Ok(HitsFrame::default()));
        sink.send(Err(ErrorFrame::conn(ErrorCode::Internal, "x".into())));
        let a = rx.recv().unwrap();
        assert_eq!(a.request_id, 42);
        assert_eq!(a.reply.unwrap().request_id, 42);
        let b = rx.recv().unwrap();
        assert_eq!(b.request_id, 42);
        assert_eq!(b.reply.unwrap_err().request_id, 42);
        // disconnected sink: send is a silent no-op, not a panic
        drop(rx);
        sink.send(Ok(HitsFrame::default()));
    }

    #[test]
    fn bounded_queue_rejects_with_overloaded() {
        let (tenant, _rx) = detached_tenant(2);
        let mut receivers = Vec::new();
        for _ in 0..2 {
            let (req, rrx) = request(vec![0.0; 4], 1);
            assert_eq!(tenant.submit(req), Ok(()));
            receivers.push(rrx);
        }
        // queue full: typed rejection, counter bumped, depth unchanged
        let (req, _rrx) = request(vec![0.0; 4], 1);
        assert_eq!(tenant.submit(req), Err(SubmitError::Overloaded));
        assert_eq!(tenant.stats().overloaded.load(Ordering::Relaxed), 1);
        assert_eq!(tenant.stats().queue_depth.load(Ordering::Relaxed), 2);
        assert_eq!(SubmitError::Overloaded.code(), ErrorCode::Overloaded);
    }

    #[test]
    fn shutdown_disconnect_rejects_with_shutting_down() {
        let (tenant, rx) = detached_tenant(2);
        tenant.begin_shutdown();
        let (req, _rrx) = request(vec![0.0; 4], 1);
        assert_eq!(tenant.submit(req), Err(SubmitError::ShuttingDown));
        // also when the receiver died without an orderly shutdown
        let (tenant, rx2) = detached_tenant(2);
        drop(rx);
        drop(rx2);
        let (req, _rrx) = request(vec![0.0; 4], 1);
        assert_eq!(tenant.submit(req), Err(SubmitError::ShuttingDown));
    }

    #[test]
    fn worker_serves_identical_to_direct_search() {
        let keys = unit(&[80, 4], 1);
        let index = Arc::new(FlatIndex::new(keys));
        let tenant = Tenant::start(
            "docs",
            index.clone(),
            None,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            16,
        )
        .unwrap();
        let q = unit(&[6, 4], 2);
        let mut receivers = Vec::new();
        for i in 0..6 {
            let (req, rrx) = request(q.row(i).to_vec(), 3);
            tenant.submit(req).unwrap();
            receivers.push(rrx);
        }
        for (i, rrx) in receivers.into_iter().enumerate() {
            let hits = rrx.recv().unwrap().unwrap();
            let direct = index.search_effort(q.row(i), 3, Effort::Exhaustive);
            assert_eq!(hits.ids, direct.ids, "request {i}");
            assert_eq!(hits.scores, direct.scores);
            assert_eq!(hits.keys_scanned, direct.cost.keys_scanned);
            assert_eq!(hits.scan_flops, direct.cost.flops);
        }
        assert_eq!(tenant.stats().served.load(Ordering::Relaxed), 6);
        assert_eq!(tenant.collection_stats().served, 6);
        tenant.begin_shutdown();
        tenant.join();
    }

    #[test]
    fn expired_deadline_fast_fails_before_scan() {
        let keys = unit(&[50, 4], 3);
        let index = Arc::new(FlatIndex::new(keys));
        let tenant = Tenant::start(
            "docs",
            index,
            None,
            BatchPolicy {
                max_batch: 4,
                // wide window guarantees the 1us budget below expires
                // before the batch drains
                max_wait: Duration::from_millis(5),
            },
            8,
        )
        .unwrap();
        let (rtx, rrx) = sync_channel(1);
        tenant
            .submit(NetRequest {
                query: vec![0.5; 4],
                k: 1,
                effort: Effort::Exhaustive,
                mode: QueryMode::Original,
                deadline: Some(Instant::now() + Duration::from_micros(1)),
                enqueued: Instant::now(),
                reply: ReplySink::Oneshot(rtx),
            })
            .unwrap();
        let err = rrx.recv().unwrap().unwrap_err();
        assert_eq!(err.code, ErrorCode::DeadlineExpired);
        assert_eq!(tenant.stats().expired.load(Ordering::Relaxed), 1);
        assert_eq!(tenant.stats().served.load(Ordering::Relaxed), 0);
        tenant.begin_shutdown();
        tenant.join();
    }

    #[test]
    fn queued_requests_get_replies_after_shutdown_begins() {
        // requests admitted before shutdown drain with real answers
        let keys = unit(&[60, 4], 5);
        let index = Arc::new(FlatIndex::new(keys));
        let tenant = Tenant::start(
            "docs",
            index.clone(),
            None,
            BatchPolicy {
                max_batch: 32,
                max_wait: Duration::from_millis(1),
            },
            32,
        )
        .unwrap();
        let q = unit(&[5, 4], 6);
        let mut receivers = Vec::new();
        for i in 0..5 {
            let (req, rrx) = request(q.row(i).to_vec(), 2);
            tenant.submit(req).unwrap();
            receivers.push(rrx);
        }
        tenant.begin_shutdown();
        tenant.join();
        for (i, rrx) in receivers.into_iter().enumerate() {
            let hits = rrx.recv().unwrap().unwrap();
            let direct = index.search_effort(q.row(i), 2, Effort::Exhaustive);
            assert_eq!(hits.ids, direct.ids, "request {i}");
        }
    }

    #[test]
    fn invalid_requests_get_typed_errors() {
        let keys = unit(&[40, 4], 7);
        let tenant = Tenant::start(
            "docs",
            Arc::new(FlatIndex::new(keys)),
            None,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            8,
        )
        .unwrap();
        // wrong dimension
        let (req, rrx) = request(vec![0.0; 3], 1);
        tenant.submit(req).unwrap();
        assert_eq!(rrx.recv().unwrap().unwrap_err().code, ErrorCode::BadRequest);
        // hostile k: rejected before it can size any allocation
        for k in [0usize, MAX_HITS + 1, u32::MAX as usize] {
            let (req, rrx) = request(vec![0.0; 4], k);
            tenant.submit(req).unwrap();
            assert_eq!(
                rrx.recv().unwrap().unwrap_err().code,
                ErrorCode::BadRequest,
                "k={k}"
            );
        }
        // an in-range k larger than the corpus is clamped, not failed
        let (req, rrx) = request(vec![0.5; 4], 1000);
        tenant.submit(req).unwrap();
        assert_eq!(rrx.recv().unwrap().unwrap().ids.len(), 40);
        // mapped mode without a mapper
        let (rtx, rrx) = sync_channel(1);
        tenant
            .submit(NetRequest {
                query: vec![0.0; 4],
                k: 1,
                effort: Effort::Auto,
                mode: QueryMode::Mapped,
                deadline: None,
                enqueued: Instant::now(),
                reply: ReplySink::Oneshot(rtx),
            })
            .unwrap();
        assert_eq!(
            rrx.recv().unwrap().unwrap_err().code,
            ErrorCode::Unsupported
        );
        assert_eq!(tenant.stats().errors.load(Ordering::Relaxed), 5);
        tenant.begin_shutdown();
        tenant.join();
    }
}
