//! The metrics side-listener: a second TCP port serving plain-text
//! health/metrics scrapes so monitoring never contends with the data
//! plane (no shared listener, no shared connection threads, no AMTP
//! framing to negotiate).
//!
//! The protocol is deliberately trivial — connect, receive one
//! snapshot of `key value` / `key{label="x"} value` lines, connection
//! closes. No request is read at all (`nc host port` works, and so
//! does any Prometheus-style line scraper pointed at the raw stream).
//! Because the listener never reads, hostile input is structurally
//! harmless: any bytes a client sends are simply never looked at, the
//! snapshot is written under a write timeout, and the socket is shut
//! down — no parser to crash, no read to hang on.

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

/// What the listener scrapes: a metrics snapshot plus the shutdown
/// flag that tells the accept loop to exit. Implemented by the net
/// server's shared state; tests can provide their own.
pub trait MetricsSource: Send + Sync {
    /// Render one plain-text snapshot (newline-terminated lines).
    fn render(&self) -> String;
    /// True once the owning server is draining; the listener exits.
    fn shutting(&self) -> bool;
}

/// A running metrics listener (join it after the source starts
/// reporting `shutting() == true`).
pub struct MetricsListener {
    local_addr: SocketAddr,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsListener {
    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Join the accept thread. Returns promptly once the source's
    /// `shutting()` flag is up (the accept loop polls it).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// How long one scrape write may take before the connection is
/// abandoned (a stalled scraper must not pin the accept thread).
const SCRAPE_WRITE_TIMEOUT: Duration = Duration::from_secs(1);

/// Poll cadence for the shutdown flag on a quiet listener.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Bind `addr` and serve snapshots of `source` until it reports
/// shutting down.
pub fn spawn(
    addr: impl ToSocketAddrs,
    source: Arc<dyn MetricsSource>,
) -> std::io::Result<MetricsListener> {
    let listener = TcpListener::bind(addr)?;
    let local_addr = listener.local_addr()?;
    // nonblocking accept so the loop can poll the shutdown flag
    listener.set_nonblocking(true)?;
    let handle = std::thread::Builder::new()
        .name("amips-metrics".into())
        .spawn(move || accept_loop(listener, source))?;
    Ok(MetricsListener {
        local_addr,
        handle: Some(handle),
    })
}

fn accept_loop(listener: TcpListener, source: Arc<dyn MetricsSource>) {
    loop {
        if source.shutting() {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                // one snapshot per connection; errors (peer gone, write
                // timeout) just drop the connection — the next scrape
                // gets a fresh one
                let _ = stream.set_write_timeout(Some(SCRAPE_WRITE_TIMEOUT));
                let _ = stream.set_nodelay(true);
                let body = source.render();
                if stream.write_all(body.as_bytes()).is_ok() {
                    let _ = stream.flush();
                }
                let _ = stream.shutdown(Shutdown::Both);
            }
            Err(e) if crate::coordinator::net::wire::is_timeout(&e) => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    struct FakeSource {
        stop: AtomicBool,
    }

    impl MetricsSource for FakeSource {
        fn render(&self) -> String {
            "amips_test_metric 1\namips_test_gauge{collection=\"docs\"} 2\n".into()
        }
        fn shutting(&self) -> bool {
            self.stop.load(Ordering::SeqCst)
        }
    }

    fn scrape(addr: SocketAddr, send_garbage: bool) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        if send_garbage {
            // the listener never reads: arbitrary bytes must not hang,
            // panic, or corrupt the snapshot
            let _ = s.write_all(b"GET / HTTP/1.1\r\n\x00\xff garbage \r\n\r\n");
        }
        let mut body = String::new();
        s.read_to_string(&mut body).unwrap();
        body
    }

    #[test]
    fn serves_snapshot_and_ignores_input() {
        let source = Arc::new(FakeSource {
            stop: AtomicBool::new(false),
        });
        let listener = spawn("127.0.0.1:0", source.clone() as Arc<dyn MetricsSource>).unwrap();
        let addr = listener.local_addr();
        for garbage in [false, true, true, false] {
            let body = scrape(addr, garbage);
            assert!(body.contains("amips_test_metric 1"), "{body:?}");
            assert!(
                body.contains("amips_test_gauge{collection=\"docs\"} 2"),
                "{body:?}"
            );
        }
        source.stop.store(true, Ordering::SeqCst);
        listener.join();
    }
}
