//! Cluster routing (paper Sec. 4.3): given a query, pick the top-k
//! database partitions to search.
//!
//! * [`CentroidRouter`] — the IVF-style baseline: score the query against
//!   each cluster centroid (Fig. 1 left).
//! * [`AmortizedRouter`] — the paper's contribution: a multi-output
//!   SupportNet (or KeyNet) predicts per-cluster support values
//!   σ_{Y_j}(x); clusters are ranked by predicted attainable score, not
//!   centroid alignment (Fig. 1 middle).

use anyhow::Result;

use crate::index::traits::TopK;
use crate::metrics::flops;
use crate::model::AmortizedModel;
use crate::tensor::{dot, Tensor};

/// Routed clusters for one query, with selection cost.
#[derive(Clone, Debug)]
pub struct RoutingDecision {
    /// cluster ids, best first
    pub clusters: Vec<u32>,
    /// flops spent on the selection itself
    pub selection_flops: u64,
}

/// A batched cluster router.
pub trait Router {
    fn name(&self) -> &str;
    /// Number of clusters this router ranks over.
    fn n_clusters(&self) -> usize;
    /// Route every query to its top-k clusters.
    fn route_batch(&self, queries: &Tensor, k: usize) -> Result<Vec<RoutingDecision>>;
}

/// Baseline: rank clusters by ⟨x, centroid_j⟩.
pub struct CentroidRouter {
    centroids: Tensor, // [c, d]
}

impl CentroidRouter {
    pub fn new(centroids: Tensor) -> Self {
        CentroidRouter { centroids }
    }
}

impl Router for CentroidRouter {
    fn name(&self) -> &str {
        "centroid"
    }

    fn n_clusters(&self) -> usize {
        self.centroids.rows()
    }

    fn route_batch(&self, queries: &Tensor, k: usize) -> Result<Vec<RoutingDecision>> {
        let c = self.centroids.rows();
        let d = self.centroids.row_width();
        let k = k.clamp(1, c);
        let cost = flops::centroid_routing_flops(c, d);
        Ok((0..queries.rows())
            .map(|i| {
                let q = queries.row(i);
                let mut top = TopK::new(k);
                for j in 0..c {
                    top.push(dot(q, self.centroids.row(j)), j as u32);
                }
                RoutingDecision {
                    clusters: top.into_sorted().0,
                    selection_flops: cost,
                }
            })
            .collect())
    }
}

/// Learned router: rank clusters by predicted support value. Takes any
/// [`AmortizedModel`] backend — a pure-Rust multi-head SupportNet or
/// KeyNet in the default build, the PJRT-backed model under `xla`.
pub struct AmortizedRouter {
    model: Box<dyn AmortizedModel>,
    label: String,
}

impl AmortizedRouter {
    pub fn new(model: impl AmortizedModel + 'static) -> Self {
        Self::from_boxed(Box::new(model))
    }

    pub fn from_boxed(model: Box<dyn AmortizedModel>) -> Self {
        let label = format!("amortized-{}", model.kind());
        AmortizedRouter { model, label }
    }

    pub fn model(&self) -> &dyn AmortizedModel {
        self.model.as_ref()
    }
}

impl Router for AmortizedRouter {
    fn name(&self) -> &str {
        &self.label
    }

    fn n_clusters(&self) -> usize {
        self.model.n_heads()
    }

    fn route_batch(&self, queries: &Tensor, k: usize) -> Result<Vec<RoutingDecision>> {
        let c = self.model.n_heads();
        let k = k.clamp(1, c);
        // One fused forward for the whole batch (the amortized win):
        // per-query cost is the model's forward flops.
        let scores = self.model.scores(queries)?;
        let cost = self.model.score_flops();
        Ok((0..queries.rows())
            .map(|i| {
                let row = scores.row(i);
                let mut top = TopK::new(k);
                for (j, &s) in row.iter().enumerate() {
                    top.push(s, j as u32);
                }
                RoutingDecision {
                    clusters: top.into_sorted().0,
                    selection_flops: cost,
                }
            })
            .collect())
    }
}

/// Routing accuracy (Sec. 4.3): fraction of queries whose true top-1
/// key's cluster is among the selected clusters.
pub fn routing_accuracy(decisions: &[RoutingDecision], true_clusters: &[usize]) -> f64 {
    assert_eq!(decisions.len(), true_clusters.len());
    if decisions.is_empty() {
        return 0.0;
    }
    let hits = decisions
        .iter()
        .zip(true_clusters)
        .filter(|(dec, &t)| dec.clusters.iter().any(|&c| c as usize == t))
        .count();
    hits as f64 / decisions.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn centroid_router_picks_best_centroid() {
        let centroids = unit(&[6, 8], 1);
        let router = CentroidRouter::new(centroids.clone());
        // query = centroid 4 exactly
        let q = centroids.gather_rows(&[4]);
        let dec = router.route_batch(&q, 2).unwrap();
        assert_eq!(dec[0].clusters[0], 4);
        assert_eq!(dec[0].selection_flops, 6 * 8 * 2);
    }

    #[test]
    fn routing_accuracy_counts_topk() {
        let d1 = RoutingDecision {
            clusters: vec![2, 0],
            selection_flops: 0,
        };
        let d2 = RoutingDecision {
            clusters: vec![1],
            selection_flops: 0,
        };
        let acc = routing_accuracy(&[d1, d2], &[0, 0]);
        assert!((acc - 0.5).abs() < 1e-9);
    }

    #[test]
    fn k_clamped_to_cluster_count() {
        let centroids = unit(&[3, 4], 2);
        let router = CentroidRouter::new(centroids);
        let q = unit(&[2, 4], 3);
        let dec = router.route_batch(&q, 10).unwrap();
        assert_eq!(dec[0].clusters.len(), 3);
    }

    #[test]
    fn amortized_router_ranks_by_model_scores() {
        use crate::model::{AmortizedModel, RustModel};
        use crate::nn::{ModelKind, NetSpec};

        let model =
            RustModel::init("router", NetSpec::new(ModelKind::SupportNet, 6, 5, 8, 2), 4).unwrap();
        let q = unit(&[3, 6], 5);
        let expected = model.scores(&q).unwrap();
        let flops = model.score_flops();
        let router = AmortizedRouter::new(model);
        assert_eq!(router.name(), "amortized-supportnet");
        assert_eq!(router.n_clusters(), 5);
        let dec = router.route_batch(&q, 2).unwrap();
        for (i, d) in dec.iter().enumerate() {
            assert_eq!(d.clusters.len(), 2);
            assert_eq!(d.selection_flops, flops);
            // the top-ranked cluster is the argmax of the model scores
            let row = expected.row(i);
            let best = (0..5).max_by(|&a, &b| row[a].total_cmp(&row[b])).unwrap();
            assert_eq!(d.clusters[0] as usize, best);
        }
    }
}
