//! Dynamic batching: group request-path queries into fixed-size model
//! batches under a latency deadline. The AOT artifacts have a static
//! batch dimension `B`, so the batcher's job is to fill as much of `B`
//! as arrives within `max_wait`, then flush (padding is the model
//! runner's concern, not the batcher's).
//!
//! Generic over the item type so the policy is testable without PJRT.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Flush policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Hard cap = the artifact's batch dimension.
    pub max_batch: usize,
    /// Deadline from the *first* queued item.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Pull-based batcher over an mpsc receiver.
pub struct Batcher<T> {
    rx: Receiver<T>,
    pub policy: BatchPolicy,
}

/// Why a batch was flushed (telemetry).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    Full,
    Deadline,
    Disconnected,
}

impl<T> Batcher<T> {
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy }
    }

    /// Block for the next batch. Returns `None` when the channel is
    /// closed *and* drained.
    pub fn next_batch(&self) -> Option<(Vec<T>, FlushReason)> {
        // Block indefinitely for the first item.
        let first = match self.rx.recv() {
            Ok(v) => v,
            Err(_) => return None,
        };
        let mut batch = Vec::with_capacity(self.policy.max_batch);
        batch.push(first);
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                return Some((batch, FlushReason::Deadline));
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(v) => batch.push(v),
                Err(RecvTimeoutError::Timeout) => {
                    return Some((batch, FlushReason::Deadline));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Some((batch, FlushReason::Disconnected));
                }
            }
        }
        Some((batch, FlushReason::Full))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn flushes_full_batch() {
        let (tx, rx) = channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
            },
        );
        let (batch, reason) = b.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(reason, FlushReason::Full);
        let (rest, _) = b.next_batch().unwrap();
        assert_eq!(rest, vec![4]);
    }

    #[test]
    fn flushes_on_deadline() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 100,
                max_wait: Duration::from_millis(5),
            },
        );
        let t0 = Instant::now();
        let (batch, reason) = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert_eq!(reason, FlushReason::Deadline);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn drains_after_disconnect() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 10,
                max_wait: Duration::from_millis(1),
            },
        );
        let (batch, _) = b.next_batch().unwrap();
        assert_eq!(batch, vec![7, 8]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn waits_blocking_for_first_item() {
        let (tx, rx) = channel();
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
            },
        );
        let h = std::thread::spawn(move || b.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        tx.send(42).unwrap();
        let (batch, _) = h.join().unwrap().unwrap();
        assert_eq!(batch, vec![42]);
    }
}
