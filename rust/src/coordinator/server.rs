//! Threaded serving loop: the deployable shape of the system, speaking
//! the [`crate::api`] request/response types end to end.
//!
//! Architecture (vLLM-router-like, scaled to one box):
//!
//! ```text
//!  clients --> mpsc --> [batcher thread] --(dynamic batch)--> query map
//!                         |                (QueryMap built here via the
//!                         |                 MapperFactory: PJRT handles
//!                         |                 are !Send)
//!                         +--> index search (shared Arc<dyn VectorIndex>)
//!                         +--> per-request reply channel + latency stats
//! ```
//!
//! Clients send a `Vec<f32>` query plus a [`SearchRequest`]; the batcher
//! groups requests, runs the mapping stage once per batch (for requests
//! in [`QueryMode::Mapped`]), then groups servable requests by
//! `(k, effort)` and scans each group through the index's *fused
//! batched* path (`search_batch_effort`, split into per-worker
//! sub-batches) — keys stream once per drained batch instead of once
//! per request, while per-request hits and `SearchCost` stay identical
//! to a solo scan. Responses carry [`Hits`] plus a [`CostBreakdown`].

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::{CostBreakdown, Effort, Hits, QueryMap, QueryMode, SearchRequest};
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::index::catalog::Catalog;
use crate::index::traits::{SearchResult, VectorIndex};
use crate::tensor::Tensor;
use crate::util::timer::LatencyHistogram;
use crate::util::Timer;

/// One queued search request.
struct Request {
    query: Vec<f32>,
    request: SearchRequest,
    enqueued: Instant,
    reply: SyncSender<Result<Response>>,
}

/// One search response.
#[derive(Clone, Debug)]
pub struct Response {
    pub hits: Hits,
    pub cost: CostBreakdown,
    /// end-to-end latency as measured by the server
    pub latency: Duration,
}

/// Builds the optional query map *on the runner thread* — the PJRT-backed
/// [`QueryMap`] (`model::XlaModel`) is `!Send`, so construction must
/// happen where it runs. Pure-Rust maps can be built anywhere but
/// follow the same path for uniformity.
pub type MapperFactory = Box<dyn FnOnce() -> Result<Option<Box<dyn QueryMap>>> + Send>;

/// Server configuration.
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// Request template used by [`ServerHandle::search`].
    pub default_request: SearchRequest,
    pub mapper: MapperFactory,
}

impl ServerConfig {
    /// A server with no query map: every request runs in
    /// [`QueryMode::Original`] semantics (Mapped requests error).
    pub fn unmapped(policy: BatchPolicy, default_request: SearchRequest) -> ServerConfig {
        ServerConfig {
            policy,
            default_request,
            mapper: Box::new(|| Ok(None)),
        }
    }

    /// A server with an explicit mapper factory.
    pub fn with_mapper(
        policy: BatchPolicy,
        default_request: SearchRequest,
        mapper: MapperFactory,
    ) -> ServerConfig {
        ServerConfig {
            policy,
            default_request,
            mapper,
        }
    }

    /// A server that maps queries through a trained c=1 pure-Rust model
    /// (Sec. 4.4 drop-in integration) — the default-build learned
    /// serving path: the model is `Send`, so it is simply moved onto
    /// the runner thread and wrapped as a [`crate::api::KeyNetQueryMap`].
    pub fn with_keynet(
        model: crate::model::RustModel,
        policy: BatchPolicy,
        default_request: SearchRequest,
    ) -> ServerConfig {
        ServerConfig {
            policy,
            default_request,
            mapper: Box::new(move || {
                Ok(Some(Box::new(crate::api::KeyNetQueryMap::new(model)?) as Box<dyn QueryMap>))
            }),
        }
    }

    /// A server that maps queries through a trained c=1 KeyNet loaded
    /// from the AOT artifacts (Sec. 4.4). The engine and model are
    /// constructed on the runner thread.
    #[cfg(feature = "xla")]
    pub fn with_model(
        artifacts_dir: std::path::PathBuf,
        meta: crate::runtime::ArtifactMeta,
        params: crate::model::ParamSet,
        policy: BatchPolicy,
        default_request: SearchRequest,
    ) -> ServerConfig {
        ServerConfig {
            policy,
            default_request,
            mapper: Box::new(move || {
                let engine = crate::runtime::Engine::new(artifacts_dir)?;
                let model = crate::model::XlaModel::load(&engine, meta, &params)?;
                Ok(Some(Box::new(EnginePinnedMap {
                    _engine: engine,
                    model,
                }) as Box<dyn QueryMap>))
            }),
        }
    }
}

/// Keeps the engine alive next to the model it compiled for.
#[cfg(feature = "xla")]
struct EnginePinnedMap {
    _engine: crate::runtime::Engine,
    model: crate::model::XlaModel,
}

#[cfg(feature = "xla")]
impl QueryMap for EnginePinnedMap {
    fn label(&self) -> &str {
        &self.model.meta.name
    }

    fn map_flops_per_query(&self) -> u64 {
        self.model.key_flops()
    }

    fn map(&self, queries: &Tensor) -> Result<Tensor> {
        self.model.map_queries(queries)
    }
}

/// Running server with its worker thread.
pub struct Server {
    handle_tx: Sender<Request>,
    join: Option<std::thread::JoinHandle<Result<()>>>,
    stats: Arc<Mutex<LatencyHistogram>>,
    stop: Arc<AtomicBool>,
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
    default_request: SearchRequest,
}

impl ServerHandle {
    /// Blocking search with the server's default request template.
    pub fn search(&self, query: Vec<f32>) -> Result<Response> {
        self.search_with(query, self.default_request)
    }

    /// Blocking search with an explicit per-request [`SearchRequest`].
    pub fn search_with(&self, query: Vec<f32>, request: SearchRequest) -> Result<Response> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Request {
                query,
                request,
                enqueued: Instant::now(),
                reply: rtx,
            })
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    /// The server's request template (what [`ServerHandle::search`] uses).
    pub fn default_request(&self) -> SearchRequest {
        self.default_request
    }
}

/// Serve one drained batch: map once, scan once per `(k, effort)` group
/// through the fused batched path, reply per request.
fn serve_batch(
    batch: Vec<Request>,
    index: &dyn VectorIndex,
    mapper: &Option<Box<dyn QueryMap>>,
    stats: &Mutex<LatencyHistogram>,
) {
    let d = index.dim();
    // split off malformed requests first so tensor rows align with `valid`
    let mut valid: Vec<Request> = Vec::with_capacity(batch.len());
    for req in batch {
        if req.query.len() == d {
            valid.push(req);
        } else {
            let msg = format!("query dim {} != index dim {d}", req.query.len());
            let _ = req.reply.send(Err(anyhow!("{msg}")));
        }
    }
    if valid.is_empty() {
        return;
    }
    let mut q = Tensor::zeros(&[valid.len(), d]);
    for (i, r) in valid.iter().enumerate() {
        q.row_mut(i).copy_from_slice(&r.query);
    }
    // One fused mapping pass per batch (the amortized win) — but only
    // over the rows that actually request mapping, so Original traffic
    // never pays for the model forward.
    let mapped_rows: Vec<usize> = valid
        .iter()
        .enumerate()
        .filter(|(_, r)| r.request.mode == QueryMode::Mapped)
        .map(|(i, _)| i)
        .collect();
    let mut map_err: Option<String> = None;
    let mut map_seconds = 0.0;
    let mapped: Option<Tensor> = if mapped_rows.is_empty() {
        None
    } else {
        match mapper {
            Some(m) => {
                let sub = q.gather_rows(&mapped_rows);
                let t = Timer::start();
                match m.map(&sub) {
                    Ok(t_mapped) => {
                        map_seconds = t.elapsed_s();
                        if t_mapped.row_width() == d {
                            Some(t_mapped)
                        } else {
                            map_err = Some(format!(
                                "query map produced dim {} but index expects {d}",
                                t_mapped.row_width()
                            ));
                            None
                        }
                    }
                    Err(e) => {
                        map_err = Some(format!("query mapping failed: {e:#}"));
                        None
                    }
                }
            }
            None => None,
        }
    };
    let n_mapped = mapped_rows.len().max(1);
    // Resolve each request's effective query row (original tensor row or
    // its slot in the mapped sub-batch) and per-request mapping flops;
    // mode errors are caught here and replied below.
    enum RowSrc {
        Orig(usize),
        Mapped(usize),
    }
    let mut mapped_cursor = 0usize;
    let resolved: Vec<Result<(RowSrc, u64)>> = valid
        .iter()
        .enumerate()
        .map(|(i, req)| match req.request.mode {
            QueryMode::Original => Ok((RowSrc::Orig(i), 0)),
            QueryMode::Mapped => match (mapper, &mapped) {
                (Some(m), Some(_)) => {
                    let pos = mapped_cursor;
                    mapped_cursor += 1;
                    Ok((RowSrc::Mapped(pos), m.map_flops_per_query()))
                }
                (None, _) => Err(anyhow!("server has no query map; send QueryMode::Original")),
                (Some(_), None) => Err(anyhow!(
                    "{}",
                    map_err.as_deref().unwrap_or("query mapping failed")
                )),
            },
            QueryMode::Routed => Err(anyhow!(
                "server index has no router; QueryMode::Routed is unsupported"
            )),
        })
        .collect();
    // Group servable requests by (k, effort) — typical traffic shares
    // the server's request template, so the whole drained batch lands in
    // one group — and run each group through the fused batched search
    // path (sub-batches over the thread pool). Per-query SearchCost is
    // bit-identical to per-request search_effort calls; the scan
    // wall-clock is amortized evenly over the group like map_seconds.
    let mut groups: Vec<(usize, Effort, Vec<usize>)> = Vec::new();
    for (i, r) in resolved.iter().enumerate() {
        if r.is_ok() {
            let (k, eff) = (valid[i].request.k, valid[i].request.effort);
            match groups.iter_mut().find(|(gk, ge, _)| *gk == k && *ge == eff) {
                Some((_, _, members)) => members.push(i),
                None => groups.push((k, eff, vec![i])),
            }
        }
    }
    let mut scans: Vec<Option<(SearchResult, f64)>> = (0..valid.len()).map(|_| None).collect();
    for (k, effort, members) in &groups {
        let mut gq = Tensor::zeros(&[members.len(), d]);
        for (gi, &i) in members.iter().enumerate() {
            let row = match resolved[i].as_ref().expect("grouped request is Ok").0 {
                RowSrc::Orig(r) => q.row(r),
                RowSrc::Mapped(p) => mapped.as_ref().expect("mapped rows resolved").row(p),
            };
            gq.row_mut(gi).copy_from_slice(row);
        }
        let t = Timer::start();
        let results = crate::api::search_batch_parallel(index, &gq, *k, *effort);
        let per_req_seconds = t.elapsed_s() / members.len() as f64;
        for (&i, res) in members.iter().zip(results) {
            scans[i] = Some((res, per_req_seconds));
        }
    }
    for ((req, res), scan) in valid.into_iter().zip(resolved).zip(scans) {
        let outcome: Result<Response> = match res {
            Err(e) => Err(e),
            Ok((_, map_flops)) => {
                let (sr, search_seconds) = scan.expect("servable request was scanned");
                let mut cost = CostBreakdown {
                    map_flops,
                    // amortize the batch mapping wall-clock over its users
                    map_seconds: if map_flops > 0 {
                        map_seconds / n_mapped as f64
                    } else {
                        0.0
                    },
                    search_seconds,
                    ..CostBreakdown::default()
                };
                cost.absorb_scan(&sr.cost);
                Ok(Response {
                    hits: Hits {
                        ids: sr.ids,
                        scores: sr.scores,
                    },
                    cost,
                    latency: req.enqueued.elapsed(),
                })
            }
        };
        if let Ok(resp) = &outcome {
            stats.lock().unwrap().record(resp.latency.as_secs_f64());
        }
        // client may have given up; ignore send errors
        let _ = req.reply.send(outcome);
    }
}

impl Server {
    /// Spawn the batcher/model-runner thread over a shared index.
    pub fn start(cfg: ServerConfig, index: Arc<dyn VectorIndex>) -> Result<(Server, ServerHandle)> {
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(Mutex::new(LatencyHistogram::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let stats2 = stats.clone();
        let stop2 = stop.clone();
        let default_request = cfg.default_request;
        let join = std::thread::Builder::new()
            .name("amips-runner".into())
            .spawn(move || -> Result<()> {
                // The query map must be constructed on this thread
                // (PJRT handles are !Send).
                let mapper: Option<Box<dyn QueryMap>> = (cfg.mapper)()?;
                let batcher = Batcher::new(rx, cfg.policy);
                while let Some((batch, _reason)) = batcher.next_batch() {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    serve_batch(batch, index.as_ref(), &mapper, &stats2);
                }
                Ok(())
            })?;
        let handle = ServerHandle {
            tx: tx.clone(),
            default_request,
        };
        Ok((
            Server {
                handle_tx: tx,
                join: Some(join),
                stats,
                stop,
            },
            handle,
        ))
    }

    /// Start a server over a prebuilt collection from a [`Catalog`] —
    /// the build-once / serve-many path: the index was deserialized from
    /// its artifact, so no k-means/PQ training runs here.
    pub fn start_from_catalog(
        catalog: &Catalog,
        collection: &str,
        cfg: ServerConfig,
    ) -> Result<(Server, ServerHandle)> {
        let entry = catalog.get(collection).ok_or_else(|| {
            anyhow!(
                "catalog has no collection '{collection}' (available: {})",
                catalog.names().join(", ")
            )
        })?;
        Server::start(cfg, entry.index.clone())
    }

    /// Snapshot latency statistics.
    pub fn latency_stats(&self) -> LatencyHistogram {
        self.stats.lock().unwrap().clone()
    }

    /// Stop the server and join the worker. Note: the runner drains its
    /// channel, so it exits once every [`ServerHandle`] clone (which each
    /// hold a sender) is dropped too.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::Relaxed);
        // Replace our sender with a dangling channel so the receiver can
        // disconnect (Self implements Drop, so fields can't be moved out).
        let (dangling, _) = channel::<Request>();
        let _ = std::mem::replace(&mut self.handle_tx, dangling);
        if let Some(j) = self.join.take() {
            match j.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow!("runner thread panicked")),
            }
        } else {
            Ok(())
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Effort, LinearQueryMap};
    use crate::index::ivf::IvfIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    fn policy() -> BatchPolicy {
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
        }
    }

    #[test]
    fn unmapped_server_round_trip() {
        let keys = unit(&[200, 8], 1);
        let index = Arc::new(IvfIndex::build(&keys, 8, 10, 2));
        let req = SearchRequest::top_k(5).effort(Effort::Probes(8));
        let (server, handle) = Server::start(ServerConfig::unmapped(policy(), req), index).unwrap();
        let q = unit(&[4, 8], 3);
        for i in 0..4 {
            let resp = handle.search(q.row(i).to_vec()).unwrap();
            assert_eq!(resp.hits.len(), 5);
            assert!(resp.cost.keys_scanned > 0);
            assert_eq!(resp.cost.map_flops, 0);
        }
        assert_eq!(server.latency_stats().count(), 4);
        drop(handle);
        server.shutdown().unwrap();
    }

    #[test]
    fn mapped_server_uses_query_map() {
        let keys = unit(&[150, 8], 4);
        let index = Arc::new(IvfIndex::build(&keys, 4, 10, 5));
        let req = SearchRequest::top_k(3)
            .effort(Effort::Exhaustive)
            .mode(QueryMode::Mapped);
        let cfg = ServerConfig::with_mapper(
            policy(),
            req,
            Box::new(|| Ok(Some(Box::new(LinearQueryMap::identity(8)) as Box<dyn QueryMap>))),
        );
        let (server, handle) = Server::start(cfg, index).unwrap();
        let q = unit(&[3, 8], 6);
        for i in 0..3 {
            let mapped = handle.search(q.row(i).to_vec()).unwrap();
            assert!(mapped.cost.map_flops > 0);
            // identity map: same hits as an Original-mode request
            let orig = handle
                .search_with(q.row(i).to_vec(), req.mode(QueryMode::Original))
                .unwrap();
            assert_eq!(mapped.hits.ids, orig.hits.ids);
            assert_eq!(orig.cost.map_flops, 0);
        }
        drop(handle);
        server.shutdown().unwrap();
    }

    #[test]
    fn keynet_mapped_server_serves_from_rust_model() {
        use crate::model::{AmortizedModel, RustModel};
        use crate::nn::{ModelKind, NetSpec};

        let keys = unit(&[150, 8], 40);
        let index = Arc::new(IvfIndex::build(&keys, 4, 10, 41));
        let model =
            RustModel::init("srv.keynet", NetSpec::new(ModelKind::KeyNet, 8, 1, 8, 2), 42).unwrap();
        let q = unit(&[3, 8], 43);
        let mapped_expect = model.map_queries(&q).unwrap();
        let map_flops = model.key_flops();
        let req = SearchRequest::top_k(3)
            .effort(Effort::Exhaustive)
            .mode(QueryMode::Mapped);
        let cfg = ServerConfig::with_keynet(model, policy(), req);
        let (server, handle) = Server::start(cfg, index.clone()).unwrap();
        for i in 0..3 {
            let resp = handle.search(q.row(i).to_vec()).unwrap();
            // the served answer equals searching the index at the
            // model-mapped point directly
            let direct = index.search_effort(mapped_expect.row(i), 3, Effort::Exhaustive);
            assert_eq!(resp.hits.ids, direct.ids);
            assert_eq!(resp.hits.scores, direct.scores);
            assert_eq!(resp.cost.map_flops, map_flops);
        }
        drop(handle);
        server.shutdown().unwrap();
    }

    #[test]
    fn bad_requests_get_error_replies_not_crashes() {
        let keys = unit(&[100, 8], 7);
        let index = Arc::new(IvfIndex::build(&keys, 4, 8, 8));
        let req = SearchRequest::top_k(2).effort(Effort::Probes(2));
        let (server, handle) = Server::start(ServerConfig::unmapped(policy(), req), index).unwrap();
        // wrong dimension
        assert!(handle.search(vec![0.0; 5]).is_err());
        // mapped mode without a mapper
        assert!(handle
            .search_with(vec![0.0; 8], req.mode(QueryMode::Mapped))
            .is_err());
        // routed mode unsupported on the server
        assert!(handle
            .search_with(vec![0.0; 8], req.mode(QueryMode::Routed))
            .is_err());
        // the server is still alive afterwards
        let ok = handle.search(unit(&[1, 8], 9).row(0).to_vec());
        assert!(ok.is_ok());
        drop(handle);
        server.shutdown().unwrap();
    }

    #[test]
    fn server_starts_from_a_prebuilt_catalog() {
        use crate::index::{BuildCtx, Catalog, IndexSpec};
        use crate::util::TempDir;
        let tmp = TempDir::new("amips-server-catalog");
        let root = tmp.join("catalog");
        let keys = unit(&[200, 8], 20);
        let spec = IndexSpec::default_for("ivf").unwrap().with_nlist(4);
        {
            let mut catalog = Catalog::create(&root).unwrap();
            catalog
                .build_collection("docs", &spec, &keys, &BuildCtx::seeded(21))
                .unwrap();
        }
        // reopen: pure deserialization, then serve
        let catalog = Catalog::open(&root).unwrap();
        let req = SearchRequest::top_k(3).effort(Effort::Exhaustive);
        let (server, handle) =
            Server::start_from_catalog(&catalog, "docs", ServerConfig::unmapped(policy(), req))
                .unwrap();
        let q = unit(&[2, 8], 22);
        for i in 0..2 {
            let resp = handle.search(q.row(i).to_vec()).unwrap();
            // exhaustive effort on the reloaded index is still exact
            let direct = catalog.get("docs").unwrap().index.search_effort(
                q.row(i),
                3,
                Effort::Exhaustive,
            );
            assert_eq!(resp.hits.ids, direct.ids);
            assert_eq!(resp.hits.scores, direct.scores);
        }
        drop(handle);
        server.shutdown().unwrap();
        // unknown collection is a typed error, not a panic
        assert!(Server::start_from_catalog(
            &catalog,
            "nope",
            ServerConfig::unmapped(policy(), req)
        )
        .is_err());
    }

    #[test]
    fn server_serves_a_sharded_collection() {
        use crate::index::{BuildCtx, Catalog, IndexSpec};
        use crate::util::TempDir;
        let tmp = TempDir::new("amips-server-sharded");
        let root = tmp.join("catalog");
        let keys = unit(&[240, 8], 30);
        let spec: IndexSpec = "sharded(shards=4,inner=ivf(nlist=4))".parse().unwrap();
        {
            let mut catalog = Catalog::create(&root).unwrap();
            catalog
                .build_collection("docs", &spec, &keys, &BuildCtx::seeded(31))
                .unwrap();
        }
        let catalog = Catalog::open(&root).unwrap();
        let entry = catalog.get("docs").unwrap();
        assert_eq!(entry.index.name(), "sharded");
        let req = SearchRequest::top_k(5).effort(Effort::Exhaustive);
        let (server, handle) =
            Server::start_from_catalog(&catalog, "docs", ServerConfig::unmapped(policy(), req))
                .unwrap();
        let q = unit(&[3, 8], 32);
        for i in 0..3 {
            let resp = handle.search(q.row(i).to_vec()).unwrap();
            // the server answer equals a direct fan-out over the same index
            let direct = entry.index.search_effort(q.row(i), 5, Effort::Exhaustive);
            assert_eq!(resp.hits.ids, direct.ids);
            assert_eq!(resp.hits.scores, direct.scores);
            // merged cost sums every shard's exhaustive scan
            assert_eq!(resp.cost.keys_scanned, 240);
        }
        drop(handle);
        server.shutdown().unwrap();
    }

    #[test]
    fn heterogeneous_requests_in_one_batch_each_honored() {
        // mixed (k, effort) requests issued from concurrent clients with
        // a wide batch window, so drained batches really hold several
        // fused groups at once — each reply must equal a direct
        // per-query scan no matter how the batcher slices the traffic
        let keys = unit(&[250, 8], 50);
        let index = Arc::new(IvfIndex::build(&keys, 8, 8, 51));
        let default = SearchRequest::top_k(3).effort(Effort::Probes(2));
        let wide = BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(20),
        };
        let (server, handle) =
            Server::start(ServerConfig::unmapped(wide, default), index.clone()).unwrap();
        let q = unit(&[12, 8], 52);
        let reqs = [
            SearchRequest::top_k(1).effort(Effort::Probes(1)),
            SearchRequest::top_k(4).effort(Effort::Probes(3)),
            SearchRequest::top_k(2).effort(Effort::Exhaustive),
        ];
        std::thread::scope(|s| {
            for c in 0..4usize {
                let handle = handle.clone();
                let (q, index, reqs) = (&q, &index, &reqs);
                s.spawn(move || {
                    for i in (c..12).step_by(4) {
                        let r = reqs[i % reqs.len()];
                        let resp = handle.search_with(q.row(i).to_vec(), r).unwrap();
                        let direct = index.search_effort(q.row(i), r.k, r.effort);
                        assert_eq!(resp.hits.ids, direct.ids, "request {i}");
                        assert_eq!(resp.hits.scores, direct.scores, "request {i}");
                        assert_eq!(resp.cost.keys_scanned, direct.cost.keys_scanned);
                        assert_eq!(resp.cost.cells_probed, direct.cost.cells_probed);
                    }
                });
            }
        });
        assert_eq!(server.latency_stats().count(), 12);
        drop(handle);
        server.shutdown().unwrap();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let keys = unit(&[300, 8], 10);
        let index = Arc::new(IvfIndex::build(&keys, 8, 8, 11));
        let req = SearchRequest::top_k(4).effort(Effort::Probes(4));
        let (server, handle) = Server::start(ServerConfig::unmapped(policy(), req), index).unwrap();
        let q = unit(&[32, 8], 12);
        std::thread::scope(|s| {
            for c in 0..4usize {
                let handle = handle.clone();
                let q = &q;
                s.spawn(move || {
                    for i in (c..32).step_by(4) {
                        let resp = handle.search(q.row(i).to_vec()).unwrap();
                        assert_eq!(resp.hits.len(), 4);
                    }
                });
            }
        });
        assert_eq!(server.latency_stats().count(), 32);
        drop(handle);
        server.shutdown().unwrap();
    }
}
