//! Threaded serving loop: the deployable shape of the system.
//!
//! Architecture (vLLM-router-like, scaled to one box):
//!
//! ```text
//!  clients --> mpsc --> [batcher thread] --(dynamic batch)--> model runner
//!                         |                (Engine confined here: PJRT
//!                         |                 handles are !Send)
//!                         +--> index search (shared Arc<dyn VectorIndex>)
//!                         +--> per-request reply channel + latency stats
//! ```
//!
//! The runner thread owns the `Engine`, the compiled KeyNet executable
//! and the trained parameters; requests only carry `Vec<f32>` queries.

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::index::traits::VectorIndex;
use crate::model::{AmortizedModel, ParamSet};
use crate::runtime::{ArtifactMeta, Engine};
use crate::tensor::Tensor;
use crate::util::timer::LatencyHistogram;

/// One search request.
struct Request {
    query: Vec<f32>,
    k: usize,
    nprobe: usize,
    enqueued: Instant,
    reply: SyncSender<Response>,
}

/// One search response.
#[derive(Clone, Debug)]
pub struct Response {
    pub ids: Vec<u32>,
    pub scores: Vec<f32>,
    /// end-to-end latency as measured by the server
    pub latency: Duration,
}

/// Server configuration.
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub meta: ArtifactMeta,
    pub params: ParamSet,
    pub policy: BatchPolicy,
    /// map queries through KeyNet before searching (Sec. 4.4) —
    /// disable for an "original queries" baseline server.
    pub map_queries: bool,
    pub nprobe_default: usize,
}

/// Running server with its worker thread.
pub struct Server {
    handle_tx: Sender<Request>,
    join: Option<std::thread::JoinHandle<Result<()>>>,
    stats: Arc<Mutex<LatencyHistogram>>,
    stop: Arc<AtomicBool>,
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
    nprobe_default: usize,
}

impl ServerHandle {
    /// Blocking query.
    pub fn query(&self, query: Vec<f32>, k: usize) -> Result<Response> {
        self.query_nprobe(query, k, self.nprobe_default)
    }

    pub fn query_nprobe(&self, query: Vec<f32>, k: usize, nprobe: usize) -> Result<Response> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Request {
                query,
                k,
                nprobe,
                enqueued: Instant::now(),
                reply: rtx,
            })
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))
    }
}

impl Server {
    /// Spawn the model-runner/batcher thread over a shared index.
    pub fn start(cfg: ServerConfig, index: Arc<dyn VectorIndex>) -> Result<(Server, ServerHandle)> {
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(Mutex::new(LatencyHistogram::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let stats2 = stats.clone();
        let stop2 = stop.clone();
        let nprobe_default = cfg.nprobe_default;
        let join = std::thread::Builder::new()
            .name("amips-runner".into())
            .spawn(move || -> Result<()> {
                // Engine must be constructed on this thread (!Send).
                let engine = Engine::new(cfg.artifacts_dir.clone())?;
                let model = if cfg.map_queries {
                    Some(AmortizedModel::load(&engine, cfg.meta.clone(), &cfg.params)?)
                } else {
                    None
                };
                let d = cfg.meta.d;
                let batcher = Batcher::new(rx, cfg.policy);
                while let Some((batch, _reason)) = batcher.next_batch() {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    // assemble the query matrix
                    let mut q = Tensor::zeros(&[batch.len(), d]);
                    for (i, r) in batch.iter().enumerate() {
                        anyhow::ensure!(r.query.len() == d, "query dim {}", r.query.len());
                        q.row_mut(i).copy_from_slice(&r.query);
                    }
                    let effective = match &model {
                        Some(m) => m.map_queries(&q)?,
                        None => q,
                    };
                    // search + reply per request
                    for (i, req) in batch.into_iter().enumerate() {
                        let res = index.search(effective.row(i), req.k, req.nprobe);
                        let latency = req.enqueued.elapsed();
                        stats2.lock().unwrap().record(latency.as_secs_f64());
                        // client may have given up; ignore send errors
                        let _ = req.reply.send(Response {
                            ids: res.ids,
                            scores: res.scores,
                            latency,
                        });
                    }
                }
                Ok(())
            })?;
        let handle = ServerHandle {
            tx: tx.clone(),
            nprobe_default,
        };
        Ok((
            Server {
                handle_tx: tx,
                join: Some(join),
                stats,
                stop,
            },
            handle,
        ))
    }

    /// Snapshot latency statistics.
    pub fn latency_stats(&self) -> LatencyHistogram {
        self.stats.lock().unwrap().clone()
    }

    /// Stop the server and join the worker. Note: the runner drains its
    /// channel, so it exits once every [`ServerHandle`] clone (which each
    /// hold a sender) is dropped too.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::Relaxed);
        // Replace our sender with a dangling channel so the receiver can
        // disconnect (Self implements Drop, so fields can't be moved out).
        let (dangling, _) = channel::<Request>();
        let _ = std::mem::replace(&mut self.handle_tx, dangling);
        if let Some(j) = self.join.take() {
            match j.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow!("runner thread panicked")),
            }
        } else {
            Ok(())
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}
