//! Hand-rolled CLI argument parsing (no clap offline).

pub mod args;

pub use args::Args;
