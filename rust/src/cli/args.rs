//! Minimal `--flag value` / `--switch` argument parser: subcommand-first,
//! typed getters with defaults, unknown-flag detection.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (first = subcommand unless it
    /// starts with `-`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.command = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    args.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, name: &str) {
        self.consumed.borrow_mut().push(name.to_string());
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.mark(name);
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
            None => Ok(default),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
            None => Ok(default),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.mark(name);
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    /// Require a flag to be present.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required --{name}"))
    }

    /// Error on flags nobody consumed (typo protection).
    pub fn reject_unknown(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !consumed.contains(k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown flags: {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --config nq-s.keynet.xs.l4.c1 --steps 100 --verbose");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("nq-s.keynet.xs.l4.c1"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("eval --k=5 --name=x");
        assert_eq!(a.get_usize("k", 0).unwrap(), 5);
        assert_eq!(a.get("name"), Some("x"));
    }

    #[test]
    fn defaults_and_require() {
        let a = parse("run");
        assert_eq!(a.get_usize("steps", 42).unwrap(), 42);
        assert!(a.require("config").is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("run --known 1 --typo 2");
        let _ = a.get("known");
        assert!(a.reject_unknown().is_err());
        let _ = a.get("typo");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("x --fast --n 3");
        assert!(a.has("fast"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.command, None);
        assert!(a.has("help"));
    }
}
