//! The unified search API: one typed request/response surface over every
//! index backbone, mapped (KeyNet) pipelines, learned routers, and the
//! serving coordinator.
//!
//! The paper's central systems claim is that amortized models are
//! *drop-in*: the same index is queried either with the original `x` or
//! with KeyNet's mapped `ŷ(x)` (Sec. 4.4), and routing swaps centroid
//! scoring for learned support values (Sec. 4.3). This module makes that
//! claim an API contract instead of ad-hoc glue:
//!
//! * [`SearchRequest`] — `k`, a typed [`Effort`] knob (replacing the old
//!   positional `nprobe` that every backbone interpreted differently),
//!   and a [`QueryMode`] selecting original / mapped / routed execution.
//! * [`SearchResponse`] — per-query [`Hits`] plus one [`CostBreakdown`]
//!   covering the route, map and scan stages (flops, keys scanned, cells
//!   probed, stage wall-clock).
//! * [`Searcher`] — the batch-first polymorphic search trait. A blanket
//!   impl covers every [`crate::index::VectorIndex`] backbone (with the
//!   batch parallelized over the [`crate::util::threads`] pool);
//!   [`MappedSearcher`] composes a [`QueryMap`] in front of any backbone;
//!   [`RoutedSearcher`] composes any [`crate::coordinator::Router`] with
//!   IVF cells. The serving coordinator speaks the same types
//!   ([`crate::coordinator::ServerHandle::search`]).
//!
//! Backbones are built from typed [`crate::index::IndexSpec`]s and can
//! be persisted/reloaded as versioned artifacts — a reloaded index (or
//! a whole [`crate::index::Catalog`] of them) serves this API
//! identically to a freshly built one. That includes the composite
//! sharded backbone (`"sharded(shards=8,inner=ivf(nlist=64))"`), which
//! fans each query out across per-partition indexes and merges their
//! top-k — callers see one [`Searcher`] with summed costs either way.
//!
//! ```no_run
//! use amips::api::{Effort, SearchRequest, Searcher};
//! use amips::index::ivf::IvfIndex;
//! # let keys = amips::tensor::Tensor::zeros(&[100, 8]);
//! # let queries = amips::tensor::Tensor::zeros(&[4, 8]);
//! let index = IvfIndex::build(&keys, 16, 15, 42);
//! let req = SearchRequest::top_k(10).effort(Effort::Probes(4));
//! let resp = index.search(&queries, &req).unwrap();
//! println!("{} hits, {} flops", resp.hits.len(), resp.cost.total_flops());
//! ```

mod mapped;
mod request;
mod response;
mod routed;
mod searcher;

pub use mapped::{KeyNetQueryMap, LinearQueryMap, MappedSearcher, QueryMap};
pub use request::{Effort, QueryMode, SearchRequest};
pub use response::{recall_against_truth, CostBreakdown, Hits, SearchResponse};
pub use routed::RoutedSearcher;
pub use searcher::Searcher;

// the ordered fan-out helpers behind the blanket Searcher impl, shared
// with other batched call sites: `batch_map` (per-item fan-out, e.g.
// the sharded single-query path) and `search_batch_parallel` (fused
// sub-batch execution, e.g. the serving coordinator)
pub(crate) use searcher::{batch_map, search_batch_parallel};
