//! Routed search (paper Sec. 4.3): any [`Router`] — the centroid
//! baseline or a learned `AmortizedRouter` — selects IVF cells, and the
//! cells are scanned exactly. The [`Effort`] knob controls how many
//! cells the router may pick, so learned and baseline routing trace the
//! same Pareto axes through one request type.

use anyhow::{bail, ensure, Result};

use crate::api::searcher::sub_batches;
use crate::api::{CostBreakdown, QueryMode, SearchRequest, SearchResponse, Searcher};
use crate::coordinator::router::Router;
use crate::index::ivf::IvfIndex;
use crate::tensor::Tensor;
use crate::util::Timer;

/// A [`Searcher`] that pairs a cluster [`Router`] with IVF cell storage.
///
/// * [`QueryMode::Routed`] — the router picks `Effort::resolve(nlist)`
///   cells per query; only those cells are scanned. Selection cost lands
///   in [`CostBreakdown::route_flops`].
/// * [`QueryMode::Original`] — plain IVF search (centroid coarse
///   ranking), the baseline the router is measured against.
pub struct RoutedSearcher<'a> {
    router: &'a dyn Router,
    index: &'a IvfIndex,
}

impl<'a> RoutedSearcher<'a> {
    pub fn new(router: &'a dyn Router, index: &'a IvfIndex) -> Result<RoutedSearcher<'a>> {
        ensure!(
            router.n_clusters() == index.nlist,
            "router ranks {} clusters but index has {} cells",
            router.n_clusters(),
            index.nlist
        );
        Ok(RoutedSearcher { router, index })
    }
}

impl Searcher for RoutedSearcher<'_> {
    fn label(&self) -> String {
        format!("routed[{}->ivf]", self.router.name())
    }

    fn num_keys(&self) -> usize {
        self.index.len()
    }

    fn search(&self, queries: &Tensor, request: &SearchRequest) -> Result<SearchResponse> {
        match request.mode {
            QueryMode::Mapped => bail!(
                "RoutedSearcher cannot serve QueryMode::Mapped; use a MappedSearcher"
            ),
            QueryMode::Original => self.index.search(queries, request),
            QueryMode::Routed => {
                let n_cells = request.effort.resolve(self.index.nlist);
                let timer = Timer::start();
                let decisions = self.router.route_batch(queries, n_cells)?;
                let route_seconds = timer.elapsed_s();
                ensure!(
                    decisions.len() == queries.rows(),
                    "router returned {} decisions for {} queries",
                    decisions.len(),
                    queries.rows()
                );
                // Fused scan: per-worker sub-batches, each grouping its
                // queries by routed cell so a cell's keys stream once for
                // every query routed to it (bit-identical to per-query
                // `search_cells` — see `IvfIndex::search_cells_batch`).
                let timer = Timer::start();
                let results = sub_batches(queries, |sub, start, end| {
                    let cells: Vec<&[u32]> = decisions[start..end]
                        .iter()
                        .map(|d| d.clusters.as_slice())
                        .collect();
                    self.index.search_cells_batch(sub, &cells, request.k)
                });
                let mut cost = CostBreakdown {
                    route_seconds,
                    search_seconds: timer.elapsed_s(),
                    ..CostBreakdown::default()
                };
                for dec in &decisions {
                    cost.route_flops += dec.selection_flops;
                }
                Ok(SearchResponse::from_results(results, cost))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Effort;
    use crate::coordinator::router::CentroidRouter;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn centroid_routing_matches_plain_ivf() {
        // Routing through a CentroidRouter over the index's own centroids
        // must reproduce plain IVF exactly: same cell ranking, same scan.
        let keys = unit(&[300, 16], 1);
        let ivf = IvfIndex::build(&keys, 8, 10, 2);
        let router = CentroidRouter::new(ivf.centroids().clone());
        let searcher = RoutedSearcher::new(&router, &ivf).unwrap();
        let q = unit(&[12, 16], 3);
        for probes in [1usize, 3, 8] {
            let req = SearchRequest::top_k(5).effort(Effort::Probes(probes));
            let routed = searcher.search(&q, &req.mode(QueryMode::Routed)).unwrap();
            let plain = ivf.search(&q, &req).unwrap();
            for i in 0..12 {
                assert_eq!(routed.hits[i].ids, plain.hits[i].ids, "probes {probes} q {i}");
                assert_eq!(routed.hits[i].scores, plain.hits[i].scores);
            }
            // same keys scanned; selection flops split out of the scan stage
            assert_eq!(routed.cost.keys_scanned, plain.cost.keys_scanned);
            assert!(routed.cost.route_flops > 0);
        }
    }

    #[test]
    fn cluster_count_mismatch_rejected() {
        let keys = unit(&[100, 8], 4);
        let ivf = IvfIndex::build(&keys, 6, 8, 5);
        let router = CentroidRouter::new(unit(&[4, 8], 6));
        assert!(RoutedSearcher::new(&router, &ivf).is_err());
    }

    #[test]
    fn mapped_mode_rejected() {
        let keys = unit(&[100, 8], 7);
        let ivf = IvfIndex::build(&keys, 4, 8, 8);
        let router = CentroidRouter::new(ivf.centroids().clone());
        let searcher = RoutedSearcher::new(&router, &ivf).unwrap();
        let q = unit(&[2, 8], 9);
        let req = SearchRequest::top_k(1).mode(QueryMode::Mapped);
        assert!(searcher.search(&q, &req).is_err());
    }
}
