//! Query mapping (paper Sec. 4.4): a [`QueryMap`] turns `x` into the
//! predicted key `ŷ(x)`, and a [`MappedSearcher`] feeds the mapped batch
//! to an *unmodified* backbone — the paper's drop-in claim as a
//! composable [`Searcher`] wrapper.

use anyhow::{bail, ensure, Result};

use crate::api::{QueryMode, SearchRequest, SearchResponse, Searcher};
use crate::index::traits::VectorIndex;
use crate::model::AmortizedModel;
use crate::tensor::{gemm_nt, Tensor};
use crate::util::Timer;

/// A batched query transform `x -> ŷ(x)`.
///
/// Implemented by [`KeyNetQueryMap`] (any trained c=1
/// [`AmortizedModel`], pure Rust or XLA-backed) and by the pure-Rust
/// [`LinearQueryMap`] used for tests and offline demos. Deliberately
/// *not* `Send`: the PJRT-backed model pins to one thread; the server
/// builds its map on the runner thread via a factory.
pub trait QueryMap {
    /// Human-readable label for reports.
    fn label(&self) -> &str;

    /// Flops charged per query for the mapping forward pass.
    fn map_flops_per_query(&self) -> u64;

    /// Map the whole batch: `[n, d] -> [n, d']`.
    fn map(&self, queries: &Tensor) -> Result<Tensor>;
}

/// A pure-Rust linear query map `ŷ(x) = W x` (rows of `w` are output
/// dims). `LinearQueryMap::identity(d)` is the no-op used by tests to
/// exercise the mapped path without a trained model.
pub struct LinearQueryMap {
    label: String,
    w: Tensor, // [d_out, d]
}

impl LinearQueryMap {
    pub fn new(label: impl Into<String>, w: Tensor) -> LinearQueryMap {
        LinearQueryMap {
            label: label.into(),
            w,
        }
    }

    /// The identity map in `d` dimensions.
    pub fn identity(d: usize) -> LinearQueryMap {
        let mut w = Tensor::zeros(&[d, d]);
        for i in 0..d {
            w.row_mut(i)[i] = 1.0;
        }
        LinearQueryMap::new("identity", w)
    }
}

impl QueryMap for LinearQueryMap {
    fn label(&self) -> &str {
        &self.label
    }

    fn map_flops_per_query(&self) -> u64 {
        (self.w.rows() * self.w.row_width() * 2) as u64
    }

    fn map(&self, queries: &Tensor) -> Result<Tensor> {
        ensure!(
            queries.row_width() == self.w.row_width(),
            "query dim {} != map dim {}",
            queries.row_width(),
            self.w.row_width()
        );
        let mut out = Tensor::zeros(&[queries.rows(), self.w.rows()]);
        gemm_nt(queries, &self.w, &mut out);
        Ok(out)
    }
}

/// The canonical learned [`QueryMap`] (paper Sec. 4.4): a trained c=1
/// amortized model predicts the optimal key `ŷ(x)` and the *unmodified*
/// backbone is searched at that point. Works with any
/// [`AmortizedModel`] backend — the pure-Rust
/// [`crate::model::RustModel`] in the default build (cheap forward for
/// KeyNet, input-gradient recovery for a c=1 SupportNet) or the
/// PJRT-backed model behind the `xla` feature.
pub struct KeyNetQueryMap {
    model: Box<dyn AmortizedModel>,
}

impl KeyNetQueryMap {
    pub fn new(model: impl AmortizedModel + 'static) -> Result<KeyNetQueryMap> {
        Self::from_boxed(Box::new(model))
    }

    pub fn from_boxed(model: Box<dyn AmortizedModel>) -> Result<KeyNetQueryMap> {
        ensure!(
            model.n_heads() == 1,
            "a query map needs a c=1 model, '{}' has c={}",
            model.label(),
            model.n_heads()
        );
        Ok(KeyNetQueryMap { model })
    }

    /// The wrapped model.
    pub fn model(&self) -> &dyn AmortizedModel {
        self.model.as_ref()
    }
}

impl QueryMap for KeyNetQueryMap {
    fn label(&self) -> &str {
        self.model.label()
    }

    fn map_flops_per_query(&self) -> u64 {
        self.model.key_flops()
    }

    fn map(&self, queries: &Tensor) -> Result<Tensor> {
        self.model.map_queries(queries)
    }
}

/// A [`Searcher`] that optionally maps queries before handing them to an
/// unmodified index backbone. With no map (or [`QueryMode::Original`])
/// it is a pure passthrough, so the original-vs-mapped comparison is a
/// one-field change in the request.
pub struct MappedSearcher<'a> {
    index: &'a dyn VectorIndex,
    map: Option<&'a dyn QueryMap>,
}

impl<'a> MappedSearcher<'a> {
    /// Baseline: queries go straight to the index.
    pub fn original(index: &'a dyn VectorIndex) -> MappedSearcher<'a> {
        MappedSearcher { index, map: None }
    }

    /// Drop-in integration: queries run through `map` first when the
    /// request asks for [`QueryMode::Mapped`].
    pub fn mapped(index: &'a dyn VectorIndex, map: &'a dyn QueryMap) -> MappedSearcher<'a> {
        MappedSearcher {
            index,
            map: Some(map),
        }
    }
}

impl Searcher for MappedSearcher<'_> {
    fn label(&self) -> String {
        match self.map {
            Some(m) => format!("mapped[{}->{}]", m.label(), self.index.name()),
            None => self.index.name().to_string(),
        }
    }

    fn num_keys(&self) -> usize {
        self.index.len()
    }

    fn search(&self, queries: &Tensor, request: &SearchRequest) -> Result<SearchResponse> {
        match request.mode {
            QueryMode::Routed => bail!(
                "MappedSearcher cannot serve QueryMode::Routed; use a RoutedSearcher"
            ),
            QueryMode::Original => {
                // passthrough baseline: same index, unmapped queries
                self.index.search(queries, &request.mode(QueryMode::Original))
            }
            QueryMode::Mapped => {
                let Some(map) = self.map else {
                    bail!("no query map configured; build with MappedSearcher::mapped")
                };
                let timer = Timer::start();
                let mapped = map.map(queries)?;
                let map_seconds = timer.elapsed_s();
                ensure!(
                    mapped.row_width() == self.index.dim(),
                    "query map '{}' produced dim {} but index '{}' expects {}",
                    map.label(),
                    mapped.row_width(),
                    self.index.name(),
                    self.index.dim()
                );
                let inner = request.mode(QueryMode::Original);
                let mut resp = self.index.search(&mapped, &inner)?;
                resp.cost.map_flops += map.map_flops_per_query() * queries.rows() as u64;
                resp.cost.map_seconds += map_seconds;
                Ok(resp)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Effort;
    use crate::index::flat::FlatIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn original_mode_is_passthrough() {
        let keys = unit(&[100, 8], 1);
        let idx = FlatIndex::new(keys.clone());
        let map = LinearQueryMap::identity(8);
        let searcher = MappedSearcher::mapped(&idx, &map);
        let q = unit(&[5, 8], 2);
        let req = SearchRequest::top_k(3).effort(Effort::Exhaustive);
        let via_wrapper = searcher.search(&q, &req).unwrap();
        let direct = idx.search(&q, &req).unwrap();
        for i in 0..5 {
            assert_eq!(via_wrapper.hits[i], direct.hits[i]);
        }
        assert_eq!(via_wrapper.cost.map_flops, 0);
    }

    #[test]
    fn identity_map_reproduces_unmapped_hits_with_map_cost() {
        let keys = unit(&[100, 8], 3);
        let idx = FlatIndex::new(keys);
        let map = LinearQueryMap::identity(8);
        let searcher = MappedSearcher::mapped(&idx, &map);
        let q = unit(&[7, 8], 4);
        let base = SearchRequest::top_k(4).effort(Effort::Exhaustive);
        let orig = searcher.search(&q, &base).unwrap();
        let mapped = searcher
            .search(&q, &base.mode(QueryMode::Mapped))
            .unwrap();
        for i in 0..7 {
            assert_eq!(orig.hits[i].ids, mapped.hits[i].ids, "query {i}");
        }
        assert_eq!(mapped.cost.map_flops, 7 * 8 * 8 * 2);
        assert_eq!(orig.cost.map_flops, 0);
    }

    #[test]
    fn dimension_changing_map_is_rejected() {
        // a map whose output dim != index dim must error, not silently
        // score truncated vectors
        let idx = FlatIndex::new(unit(&[20, 8], 10));
        let map = LinearQueryMap::new("narrow", Tensor::zeros(&[4, 8]));
        let searcher = MappedSearcher::mapped(&idx, &map);
        let q = unit(&[2, 8], 11);
        let req = SearchRequest::top_k(1).mode(QueryMode::Mapped);
        assert!(searcher.search(&q, &req).is_err());
    }

    #[test]
    fn mapped_mode_without_map_errors() {
        let idx = FlatIndex::new(unit(&[10, 4], 5));
        let searcher = MappedSearcher::original(&idx);
        let q = unit(&[1, 4], 6);
        let req = SearchRequest::top_k(1).mode(QueryMode::Mapped);
        assert!(searcher.search(&q, &req).is_err());
    }

    #[test]
    fn keynet_query_map_matches_model_inference() {
        use crate::model::RustModel;
        use crate::nn::{ModelKind, NetSpec};

        let model = RustModel::init("map.keynet", NetSpec::new(ModelKind::KeyNet, 8, 1, 8, 2), 7)
            .unwrap();
        let expect = model.map_queries(&unit(&[4, 8], 8)).unwrap();
        let map = KeyNetQueryMap::new(model).unwrap();
        let got = map.map(&unit(&[4, 8], 8)).unwrap();
        assert_eq!(got.data(), expect.data());
        assert!(map.map_flops_per_query() > 0);
        assert_eq!(map.label(), "map.keynet");
        // multi-head models are rejected up front
        let router =
            RustModel::init("router", NetSpec::new(ModelKind::SupportNet, 8, 4, 8, 2), 9).unwrap();
        assert!(KeyNetQueryMap::new(router).is_err());
    }

    #[test]
    fn linear_map_applies_matrix() {
        // W swaps the two coordinates
        let mut w = Tensor::zeros(&[2, 2]);
        w.row_mut(0)[1] = 1.0;
        w.row_mut(1)[0] = 1.0;
        let map = LinearQueryMap::new("swap", w);
        let q = Tensor::from_vec(&[1, 2], vec![3.0, 5.0]);
        let out = map.map(&q).unwrap();
        assert_eq!(out.row(0), &[5.0, 3.0]);
        assert_eq!(map.map_flops_per_query(), 8);
        // dim mismatch rejected
        assert!(map.map(&Tensor::zeros(&[1, 3])).is_err());
    }
}
