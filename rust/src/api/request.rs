//! Typed search requests: the effort knob and query mode that used to be
//! positional arguments (`search(query, k, nprobe)`) with per-backbone
//! folklore semantics.

/// How much work a backbone may spend on one query.
///
/// Each backbone translates the effort into its native knob via
/// [`Effort::resolve`] against its own cell count: IVF-family backbones
/// probe that many coarse cells; exhaustive backbones (flat / pq / sq8)
/// have one "cell" and instead widen their exact re-rank to the whole
/// database under [`Effort::Exhaustive`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Effort {
    /// Maximum effort: probe every cell and re-rank exactly. Every
    /// backbone returns the exact MIPS answer at this level.
    Exhaustive,
    /// Probe exactly `n` coarse cells (clamped to the backbone's count).
    Probes(usize),
    /// Probe `ceil(f * n_cells)` cells, `f` in (0, 1].
    Frac(f32),
    /// Backbone-chosen default (≈ √cells, the classic IVF guidance).
    Auto,
}

impl Effort {
    /// Translate into a probe count against `n_cells` partitions.
    /// Always returns a value in `1..=max(n_cells, 1)`.
    pub fn resolve(self, n_cells: usize) -> usize {
        let n = n_cells.max(1);
        match self {
            Effort::Exhaustive => n,
            Effort::Probes(p) => p.clamp(1, n),
            Effort::Frac(f) => {
                let f = if f.is_finite() { f.max(0.0) } else { 1.0 };
                ((f as f64 * n as f64).ceil() as usize).clamp(1, n)
            }
            Effort::Auto => ((n as f64).sqrt().round() as usize).clamp(1, n),
        }
    }

    /// True when this effort level demands the exact answer.
    pub fn is_exhaustive(self) -> bool {
        matches!(self, Effort::Exhaustive)
    }
}

/// Which query vector the searcher should score with (paper Fig. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryMode {
    /// Score with the raw query `x` (the baseline).
    Original,
    /// Map `x -> ŷ(x)` through a [`crate::api::QueryMap`] first
    /// (Sec. 4.4 drop-in integration). Requires a mapped searcher.
    Mapped,
    /// Select cells with a learned [`crate::coordinator::Router`] instead
    /// of centroid scoring (Sec. 4.3). Requires a routed searcher.
    Routed,
}

/// One batched search request: built with a tiny fluent builder so call
/// sites read as `SearchRequest::top_k(10).effort(Effort::Probes(4))`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchRequest {
    /// Number of hits to return per query.
    pub k: usize,
    pub effort: Effort,
    pub mode: QueryMode,
}

impl SearchRequest {
    /// Request the top `k` hits at default effort in original mode.
    pub fn top_k(k: usize) -> SearchRequest {
        SearchRequest {
            k: k.max(1),
            effort: Effort::Auto,
            mode: QueryMode::Original,
        }
    }

    /// Set the effort level.
    pub fn effort(mut self, effort: Effort) -> SearchRequest {
        self.effort = effort;
        self
    }

    /// Set the query mode.
    pub fn mode(mut self, mode: QueryMode) -> SearchRequest {
        self.mode = mode;
        self
    }
}

impl Default for SearchRequest {
    fn default() -> Self {
        SearchRequest::top_k(10)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_clamps_and_scales() {
        assert_eq!(Effort::Exhaustive.resolve(16), 16);
        assert_eq!(Effort::Probes(4).resolve(16), 4);
        assert_eq!(Effort::Probes(0).resolve(16), 1);
        assert_eq!(Effort::Probes(99).resolve(16), 16);
        assert_eq!(Effort::Frac(0.25).resolve(16), 4);
        assert_eq!(Effort::Frac(0.0).resolve(16), 1);
        assert_eq!(Effort::Frac(1.0).resolve(16), 16);
        assert_eq!(Effort::Auto.resolve(16), 4);
        // exhaustive-only backbones have a single cell
        for e in [Effort::Exhaustive, Effort::Probes(7), Effort::Auto] {
            assert_eq!(e.resolve(1), 1);
            assert_eq!(e.resolve(0), 1);
        }
    }

    #[test]
    fn builder_reads_fluently() {
        let r = SearchRequest::top_k(5)
            .effort(Effort::Probes(2))
            .mode(QueryMode::Mapped);
        assert_eq!(r.k, 5);
        assert_eq!(r.effort, Effort::Probes(2));
        assert_eq!(r.mode, QueryMode::Mapped);
        assert_eq!(SearchRequest::top_k(0).k, 1);
    }

    #[test]
    fn probes_resolution_is_monotone() {
        let mut prev = 0;
        for p in 1..=32 {
            let r = Effort::Probes(p).resolve(16);
            assert!(r >= prev);
            prev = r;
        }
    }
}
