//! The batch-first [`Searcher`] trait and its blanket implementation over
//! every index backbone — including the composite
//! [`crate::index::ShardedIndex`], whose per-query shard fan-out nests
//! inside the batch parallelism here. Wrappers
//! ([`crate::api::MappedSearcher`], [`crate::api::RoutedSearcher`],
//! future cached searchers) implement the same trait, so every bench,
//! example and the server compose against one polymorphic surface.

use anyhow::{bail, Result};
use std::sync::Mutex;

use crate::api::{CostBreakdown, Effort, QueryMode, SearchRequest, SearchResponse};
use crate::index::traits::{SearchResult, VectorIndex};
use crate::tensor::Tensor;
use crate::util::threads::{in_parallel_region, num_threads, parallel_chunks};
use crate::util::Timer;

/// A polymorphic batched MIPS searcher.
///
/// `search` takes the whole query batch at once — implementations are
/// free to fuse stage work across the batch (one model forward for all
/// queries, parallel scans) and report one [`CostBreakdown`] covering it.
pub trait Searcher {
    /// Human-readable label ("ivf", "mapped[keynet->ivf]", …).
    fn label(&self) -> String;

    /// Number of database keys served.
    fn num_keys(&self) -> usize;

    /// Batched top-k search.
    fn search(&self, queries: &Tensor, request: &SearchRequest) -> Result<SearchResponse>;

    /// Single-query convenience wrapper around [`Searcher::search`].
    fn search_one(&self, query: &[f32], request: &SearchRequest) -> Result<SearchResponse> {
        let q = Tensor::from_vec(&[1, query.len()], query.to_vec());
        self.search(&q, request)
    }
}

/// Reassemble the `(start, block)` parts produced by parallel chunk
/// workers into input order. Shared by every ordered fan-out here.
fn merge_ordered_parts<T>(parts: Mutex<Vec<(usize, Vec<T>)>>, n: usize) -> Vec<T> {
    let mut parts = parts.into_inner().unwrap();
    parts.sort_by_key(|(start, _)| *start);
    let mut out = Vec::with_capacity(n);
    for (_, block) in parts {
        out.extend(block);
    }
    out
}

/// Run `f(item_index)` for every item in `0..n` on the shared thread
/// pool, preserving input order in the output. Used for per-query and
/// per-shard fan-out where each item produces one independent result.
pub(crate) fn batch_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    // ~4 chunks per worker: enough slack for uneven per-item cost
    // without drowning in coordination.
    let chunk = n.div_ceil(num_threads().max(1) * 4).max(1);
    let parts: Mutex<Vec<(usize, Vec<T>)>> = Mutex::new(Vec::new());
    parallel_chunks(n, chunk, |_, start, end| {
        let block: Vec<T> = (start..end).map(&f).collect();
        parts.lock().unwrap().push((start, block));
    });
    merge_ordered_parts(parts, n)
}

/// Split `queries` into contiguous per-worker sub-batches on the shared
/// thread pool and run `f(sub_batch, start, end)` on each, preserving
/// query order in the output. Sub-batches are sized at two per worker —
/// large enough that fused kernels amortize key/table loads across the
/// rows, small enough to absorb uneven per-query cost. A single worker
/// (or a nested call from inside the pool) takes the whole batch in one
/// fused pass, with no copy.
pub(crate) fn sub_batches<F>(queries: &Tensor, f: F) -> Vec<SearchResult>
where
    F: Fn(&Tensor, usize, usize) -> Vec<SearchResult> + Sync,
{
    let n = queries.rows();
    if n == 0 {
        return Vec::new();
    }
    let workers = num_threads().max(1);
    let chunk = n.div_ceil(workers * 2).max(1);
    if workers <= 1 || chunk >= n || in_parallel_region() {
        return f(queries, 0, n);
    }
    let d = queries.row_width();
    let parts: Mutex<Vec<(usize, Vec<SearchResult>)>> = Mutex::new(Vec::new());
    parallel_chunks(n, chunk, |_, start, end| {
        let sub = Tensor::from_vec(&[end - start, d], queries.data()[start * d..end * d].to_vec());
        let block = f(&sub, start, end);
        debug_assert_eq!(block.len(), end - start);
        parts.lock().unwrap().push((start, block));
    });
    merge_ordered_parts(parts, n)
}

/// The batched execution path behind the blanket [`Searcher`] impl and
/// the serving coordinator: split the batch into per-worker sub-batches
/// and run the backbone's fused
/// [`VectorIndex::search_batch_effort`] on each. Per-query results are
/// bit-identical to one-at-a-time `search_effort` calls.
pub(crate) fn search_batch_parallel<T: VectorIndex + ?Sized>(
    index: &T,
    queries: &Tensor,
    k: usize,
    effort: Effort,
) -> Vec<SearchResult> {
    sub_batches(queries, |sub, _, _| index.search_batch_effort(sub, k, effort))
}

/// Every index backbone is a [`Searcher`] serving [`QueryMode::Original`]
/// directly; the batch is parallelized over the `util::threads` pool.
/// Mapped/routed modes need the corresponding wrapper, which owns the
/// extra stage (and its cost accounting).
impl<T: VectorIndex + ?Sized> Searcher for T {
    fn label(&self) -> String {
        self.name().to_string()
    }

    fn num_keys(&self) -> usize {
        self.len()
    }

    fn search(&self, queries: &Tensor, request: &SearchRequest) -> Result<SearchResponse> {
        if request.mode != QueryMode::Original {
            bail!(
                "backbone '{}' serves QueryMode::Original only; wrap it in a \
                 MappedSearcher or RoutedSearcher for {:?}",
                self.name(),
                request.mode
            );
        }
        let timer = Timer::start();
        let results = search_batch_parallel(self, queries, request.k, request.effort);
        let cost = CostBreakdown {
            search_seconds: timer.elapsed_s(),
            ..CostBreakdown::default()
        };
        Ok(SearchResponse::from_results(results, cost))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Effort;
    use crate::index::flat::FlatIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn blanket_impl_matches_per_query_scan() {
        let keys = unit(&[120, 8], 1);
        let idx = FlatIndex::new(keys);
        let q = unit(&[33, 8], 2);
        let req = SearchRequest::top_k(5).effort(Effort::Exhaustive);
        let resp = idx.search(&q, &req).unwrap();
        assert_eq!(resp.n_queries(), 33);
        for i in 0..33 {
            let single = idx.search_effort(q.row(i), 5, Effort::Exhaustive);
            assert_eq!(resp.hits[i].ids, single.ids, "query {i}");
            assert_eq!(resp.hits[i].scores, single.scores);
        }
        // cost aggregates the whole batch
        assert_eq!(resp.cost.keys_scanned, 33 * 120);
        assert!(resp.cost.scan_flops > 0);
    }

    #[test]
    fn non_original_mode_is_rejected_on_bare_backbone() {
        let idx = FlatIndex::new(unit(&[10, 4], 3));
        let q = unit(&[2, 4], 4);
        for mode in [QueryMode::Mapped, QueryMode::Routed] {
            let req = SearchRequest::top_k(1).mode(mode);
            assert!(idx.search(&q, &req).is_err(), "{mode:?}");
        }
    }

    #[test]
    fn search_one_wraps_single_query() {
        let keys = unit(&[50, 8], 5);
        let idx = FlatIndex::new(keys);
        let q = unit(&[1, 8], 6);
        let resp = idx
            .search_one(q.row(0), &SearchRequest::top_k(3).effort(Effort::Exhaustive))
            .unwrap();
        assert_eq!(resp.n_queries(), 1);
        assert_eq!(resp.hits[0].len(), 3);
    }

    #[test]
    fn sub_batches_preserve_order_and_row_ranges() {
        // 257 rows force multi-chunk execution on multi-core hosts; each
        // callback must see a contiguous copy of its own row range
        let n = 257;
        let mut q = Tensor::zeros(&[n, 2]);
        for i in 0..n {
            q.row_mut(i)[0] = i as f32;
        }
        let out = sub_batches(&q, |sub, start, end| {
            assert_eq!(sub.rows(), end - start);
            (0..sub.rows())
                .map(|r| {
                    assert_eq!(sub.row(r)[0], (start + r) as f32);
                    SearchResult {
                        ids: vec![(start + r) as u32],
                        scores: vec![sub.row(r)[0]],
                        cost: Default::default(),
                    }
                })
                .collect()
        });
        assert_eq!(out.len(), n);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.ids[0] as usize, i);
        }
        assert!(sub_batches(&Tensor::zeros(&[0, 2]), |_, _, _| unreachable!()).is_empty());
    }

    #[test]
    fn batch_map_preserves_order_under_threads() {
        // force multi-chunk execution regardless of core count
        let n = 257;
        let out = batch_map(n, |i| SearchResult {
            ids: vec![i as u32],
            scores: vec![i as f32],
            cost: Default::default(),
        });
        assert_eq!(out.len(), n);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.ids[0] as usize, i);
        }
        let empty: Vec<SearchResult> = batch_map(0, |_| unreachable!());
        assert!(empty.is_empty());
    }
}
