//! Typed search responses: per-query hit lists plus one unified cost
//! breakdown that subsumes the seed's `SearchCost` / `PipelineOutcome` /
//! `RoutingDecision` cost triplicate.

use crate::index::traits::{SearchCost, SearchResult};

/// Result list for one query: key ids sorted by descending score.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Hits {
    pub ids: Vec<u32>,
    pub scores: Vec<f32>,
}

impl Hits {
    /// Best hit, if any.
    pub fn top1(&self) -> Option<(u32, f32)> {
        Some((*self.ids.first()?, *self.scores.first()?))
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

impl From<SearchResult> for Hits {
    fn from(r: SearchResult) -> Hits {
        Hits {
            ids: r.ids,
            scores: r.scores,
        }
    }
}

/// Cost accounting for one [`SearchResponse`], accumulated over the whole
/// batch. Stages follow the request path: *route* (cell selection, by
/// centroids or a learned router), *map* (KeyNet query mapping), *scan*
/// (candidate scoring + re-rank inside the backbone). Flops count
/// multiply-add pairs as 2, matching `metrics::flops`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    /// Flops spent selecting cells in an explicit routing stage.
    /// Backbone-internal coarse ranking (e.g. plain IVF centroid scoring)
    /// is accounted under `scan_flops` instead.
    pub route_flops: u64,
    /// Flops spent mapping queries (`x -> ŷ(x)`).
    pub map_flops: u64,
    /// Flops spent scoring candidates inside the backbone.
    pub scan_flops: u64,
    /// Database vectors fully scored.
    pub keys_scanned: u64,
    /// Coarse cells probed.
    pub cells_probed: u64,
    /// Wall-clock of the routing stage (whole batch).
    pub route_seconds: f64,
    /// Wall-clock of the mapping stage (whole batch).
    pub map_seconds: f64,
    /// Wall-clock of the scan stage (whole batch).
    pub search_seconds: f64,
}

impl CostBreakdown {
    /// Total flops across all stages.
    pub fn total_flops(&self) -> u64 {
        self.route_flops + self.map_flops + self.scan_flops
    }

    /// Total wall-clock across all stages.
    pub fn total_seconds(&self) -> f64 {
        self.route_seconds + self.map_seconds + self.search_seconds
    }

    /// Fold one backbone scan cost into the scan stage.
    pub fn absorb_scan(&mut self, cost: &SearchCost) {
        self.scan_flops += cost.flops;
        self.keys_scanned += cost.keys_scanned;
        self.cells_probed += cost.cells_probed;
    }

    /// Accumulate another breakdown (e.g. sharded / staged searchers).
    pub fn add(&mut self, other: &CostBreakdown) {
        self.route_flops += other.route_flops;
        self.map_flops += other.map_flops;
        self.scan_flops += other.scan_flops;
        self.keys_scanned += other.keys_scanned;
        self.cells_probed += other.cells_probed;
        self.route_seconds += other.route_seconds;
        self.map_seconds += other.map_seconds;
        self.search_seconds += other.search_seconds;
    }
}

/// Batched response: one [`Hits`] per query plus the aggregate cost.
#[derive(Clone, Debug, Default)]
pub struct SearchResponse {
    pub hits: Vec<Hits>,
    pub cost: CostBreakdown,
}

impl SearchResponse {
    /// Build from per-query backbone results, absorbing their scan costs
    /// into `cost`.
    pub fn from_results(results: Vec<SearchResult>, mut cost: CostBreakdown) -> SearchResponse {
        for r in &results {
            cost.absorb_scan(&r.cost);
        }
        SearchResponse {
            hits: results.into_iter().map(Hits::from).collect(),
            cost,
        }
    }

    pub fn n_queries(&self) -> usize {
        self.hits.len()
    }

    /// Mean flops per query across all stages.
    pub fn flops_per_query(&self) -> f64 {
        if self.hits.is_empty() {
            0.0
        } else {
            self.cost.total_flops() as f64 / self.hits.len() as f64
        }
    }

    /// Mean wall-clock seconds per query across all stages.
    pub fn seconds_per_query(&self) -> f64 {
        if self.hits.is_empty() {
            0.0
        } else {
            self.cost.total_seconds() / self.hits.len() as f64
        }
    }
}

/// Recall@k of a batch of hits against exact top-1 targets: the paper's
/// "Recall@f%" metric is recall of `y*` within the top `⌈f·n⌉` returned
/// candidates.
pub fn recall_against_truth(hits: &[Hits], truth: &[usize], k: usize) -> f64 {
    assert_eq!(hits.len(), truth.len());
    if hits.is_empty() {
        return 0.0;
    }
    let found = hits
        .iter()
        .zip(truth)
        .filter(|(h, &t)| h.ids.iter().take(k).any(|&id| id as usize == t))
        .count();
    found as f64 / hits.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_totals_and_absorb() {
        let mut c = CostBreakdown::default();
        c.absorb_scan(&SearchCost {
            flops: 100,
            keys_scanned: 10,
            cells_probed: 2,
        });
        c.route_flops = 7;
        c.map_flops = 5;
        assert_eq!(c.total_flops(), 112);
        assert_eq!(c.keys_scanned, 10);
        let mut sum = CostBreakdown::default();
        sum.add(&c);
        sum.add(&c);
        assert_eq!(sum.total_flops(), 224);
        assert_eq!(sum.cells_probed, 4);
    }

    #[test]
    fn from_results_collects_hits() {
        let r = SearchResult {
            ids: vec![3, 1],
            scores: vec![0.9, 0.5],
            cost: SearchCost {
                flops: 8,
                keys_scanned: 4,
                cells_probed: 1,
            },
        };
        let resp = SearchResponse::from_results(vec![r.clone(), r], CostBreakdown::default());
        assert_eq!(resp.n_queries(), 2);
        assert_eq!(resp.hits[0].top1(), Some((3, 0.9)));
        assert_eq!(resp.cost.scan_flops, 16);
        assert_eq!(resp.flops_per_query(), 8.0);
    }

    #[test]
    fn recall_counts_prefix_hits() {
        let h = |ids: &[u32]| Hits {
            ids: ids.to_vec(),
            scores: vec![0.0; ids.len()],
        };
        let hits = vec![h(&[7, 2]), h(&[9, 4])];
        assert_eq!(recall_against_truth(&hits, &[7, 9], 1), 1.0);
        assert_eq!(recall_against_truth(&hits, &[2, 9], 1), 0.5);
        assert_eq!(recall_against_truth(&hits, &[2, 4], 2), 1.0);
        assert_eq!(recall_against_truth(&[], &[], 3), 0.0);
    }
}
