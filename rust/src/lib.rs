//! # AMIPS — Amortized Maximum Inner Product Search
//!
//! Rust + JAX + Pallas reproduction of *"Amortizing Maximum Inner Product
//! Search with Learned Support Functions"* (Olausson et al., 2026).
//!
//! ## The unified search API
//!
//! Every query path goes through [`api`]: build a typed
//! [`api::SearchRequest`] (`k`, an [`api::Effort`] knob, an
//! [`api::QueryMode`]), hand it to anything implementing
//! [`api::Searcher`], and get an [`api::SearchResponse`] with per-query
//! hits plus one [`api::CostBreakdown`] across the route/map/scan stages:
//!
//! * all seven [`index`] backbones (flat, ivf, pq, sq8, scann, soar,
//!   leanvec) are `Searcher`s via a blanket impl — the batch runs in
//!   parallel on the [`util::threads`] pool — and the composite
//!   [`index::ShardedIndex`] (`"sharded(shards=8,inner=ivf(nlist=64))"`)
//!   partitions the keys, fans each query out per shard and merges the
//!   per-shard top-k behind the same trait;
//! * [`api::MappedSearcher`] composes a KeyNet query map (Sec. 4.4
//!   drop-in integration) in front of any backbone;
//! * [`api::RoutedSearcher`] composes a learned or centroid
//!   [`coordinator::Router`] with IVF cells (Sec. 4.3);
//! * the serving [`coordinator`] accepts the same request type over its
//!   client handle and returns the same cost breakdown; its
//!   [`coordinator::net`] module exposes the same fused batching path
//!   over TCP — a framed wire protocol with deadline-aware batching,
//!   bounded admission and multi-tenant catalog routing (`amips serve
//!   --listen`, [`coordinator::NetClient`]).
//!
//! ## The typed build/persist lifecycle
//!
//! Construction mirrors the query surface: a parseable
//! [`index::IndexSpec`] (`"scann(nlist=64,eta=4)"`) carries every
//! backbone knob and builds through one entry point
//! ([`index::IndexSpec::build`]). Built indexes serialize to versioned,
//! checksummed artifacts ([`index::artifact`]) and are served by name
//! from an [`index::Catalog`] — `amips build` once, `amips serve
//! --catalog` on every replica, no k-means/PQ retraining at startup.
//!
//! ```no_run
//! use amips::api::{Effort, SearchRequest, Searcher};
//! use amips::index::ivf::IvfIndex;
//! # let keys = amips::tensor::Tensor::zeros(&[1000, 32]);
//! # let queries = amips::tensor::Tensor::zeros(&[8, 32]);
//! let index = IvfIndex::build(&keys, 32, 15, 42);
//! let resp = index
//!     .search(&queries, &SearchRequest::top_k(10).effort(Effort::Probes(4)))
//!     .unwrap();
//! ```
//!
//! ## The learned-model stack (pure Rust by default)
//!
//! The paper's actual method — amortized MIPS via a learned SupportNet
//! (homogenized ICNN whose input gradient is the optimal key) or KeyNet
//! (direct key regression with the Euler score-consistency loss) — is a
//! first-class scenario of the default build:
//!
//! * [`nn`] — dense layers with manual backprop (finite-difference
//!   checked), the smooth leaky activation, the positive-1-homogeneity
//!   wrapper `f(x) = ‖x‖·g(x/‖x‖)`, and both model heads;
//! * [`trainer`] — Adam + warmup/cosine + EMA driving score-regression
//!   + gradient-matching (SupportNet) or key + consistency (KeyNet)
//!   losses; `amips train | eval | serve` need no XLA;
//! * [`model`] — the backend-agnostic [`model::AmortizedModel`] trait:
//!   [`model::RustModel`] in the default build, the PJRT-backed
//!   `model::XlaModel` behind the `xla` feature;
//! * trained models persist as versioned checksummed artifacts
//!   ([`model::artifact`]) and a [`index::Catalog`] collection can carry
//!   one as its query mapper.
//!
//! ## Layers
//!
//! * **L1** Pallas kernels and **L2** JAX models live under `python/` and
//!   are AOT-lowered to HLO-text artifacts by `make artifacts`.
//! * **L3** (this crate) is the runtime system: the data pipeline
//!   ([`data`]), every index substrate the paper evaluates against
//!   ([`index`]), the unified search surface ([`api`]), the learned
//!   models ([`nn`], [`model`], [`trainer`]), the serving coordinator
//!   ([`coordinator`]), and the metrics/benchmark machinery
//!   ([`metrics`], [`bench_support`]).
//! * The **`xla` cargo feature** is an optional accelerator backend: it
//!   enables the PJRT [`runtime`] engine, the AOT training loop and
//!   `model::XlaModel` inference over the same trait surface. The
//!   default build is pure Rust and fully testable on machines without
//!   XLA.
//!
//! Python never runs on the request path: the pure-Rust `amips` binary
//! is self-contained, and even the XLA path only needs Python offline
//! (`make artifacts`).

pub mod api;
pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod index;
pub mod metrics;
pub mod model;
pub mod nn;
pub mod runtime;
pub mod tensor;
pub mod trainer;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifacts directory, overridable with `AMIPS_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("AMIPS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
