//! # AMIPS — Amortized Maximum Inner Product Search
//!
//! Rust + JAX + Pallas reproduction of *"Amortizing Maximum Inner Product
//! Search with Learned Support Functions"* (Olausson et al., 2026).
//!
//! Three layers (DESIGN.md):
//! * **L1** Pallas kernels and **L2** JAX models live under `python/` and
//!   are AOT-lowered to HLO-text artifacts by `make artifacts`.
//! * **L3** (this crate) is the runtime system: it loads the artifacts via
//!   PJRT ([`runtime`]), owns the data pipeline ([`data`]), every index
//!   substrate the paper evaluates against ([`index`]), the Rust-driven
//!   training loop ([`trainer`]), the serving coordinator
//!   ([`coordinator`]), and the metrics/benchmark machinery
//!   ([`metrics`], [`bench_support`]).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `amips` binary is self-contained.

pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod index;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod tensor;
pub mod trainer;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifacts directory, overridable with `AMIPS_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("AMIPS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
