//! Exact-MIPS ground-truth generation (paper Sec. 3.3): for every query,
//! the per-cluster optimal key index and support value
//!
//! ```text
//! y*_{i,j} = argmax_{y in Y_j} <x_i, y>,   sigma_j(x_i) = <x_i, y*_{i,j}>.
//! ```
//!
//! One fused scan per query computes all clusters simultaneously: the
//! O(n·d) dot products dominate, the per-cluster bookkeeping is O(n).
//! Parallel over queries; single-pass; deterministic ties (lowest index).

use crate::tensor::{dot, Tensor};
use crate::util::threads::parallel_chunks;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Per-query, per-cluster optima.
#[derive(Clone, Debug)]
pub struct GroundTruth {
    pub c: usize,
    /// [n_queries * c] best key index per (query, cluster).
    pub best_idx: Vec<u32>,
    /// [n_queries * c] support value per (query, cluster).
    pub sigma: Vec<f32>,
}

impl GroundTruth {
    pub fn n_queries(&self) -> usize {
        if self.c == 0 {
            0
        } else {
            self.best_idx.len() / self.c
        }
    }

    #[inline]
    pub fn idx(&self, q: usize, j: usize) -> usize {
        self.best_idx[q * self.c + j] as usize
    }

    #[inline]
    pub fn score(&self, q: usize, j: usize) -> f32 {
        self.sigma[q * self.c + j]
    }

    /// Global top-1 key for query `q` (argmax over clusters).
    pub fn global_top1(&self, q: usize) -> (usize, f32) {
        let mut best = (0usize, f32::NEG_INFINITY);
        for j in 0..self.c {
            let s = self.score(q, j);
            if s > best.1 {
                best = (self.idx(q, j), s);
            }
        }
        best
    }

    /// Cluster containing the global top-1 key.
    pub fn top_cluster(&self, q: usize) -> usize {
        let mut best = (0usize, f32::NEG_INFINITY);
        for j in 0..self.c {
            let s = self.score(q, j);
            if s > best.1 {
                best = (j, s);
            }
        }
        best.0
    }
}

/// Compute per-cluster exact tops. `assign[k]` maps key k -> cluster id in
/// [0, c). For the unclustered case pass `c = 1` and `assign = None`.
pub fn compute(queries: &Tensor, keys: &Tensor, c: usize, assign: Option<&[u32]>) -> GroundTruth {
    let nq = queries.rows();
    let n = keys.rows();
    let d = keys.row_width();
    assert_eq!(queries.row_width(), d);
    if let Some(a) = assign {
        assert_eq!(a.len(), n);
        debug_assert!(a.iter().all(|&x| (x as usize) < c));
    } else {
        assert_eq!(c, 1);
    }

    let best_idx: Vec<AtomicUsize> = (0..nq * c).map(|_| AtomicUsize::new(0)).collect();
    // f32 bits stored as usize atomics to avoid locks; written once per
    // (q, j) by exactly one worker, so plain stores are fine.
    let sigma_bits: Vec<AtomicUsize> = (0..nq * c)
        .map(|_| AtomicUsize::new(f32::NEG_INFINITY.to_bits() as usize))
        .collect();

    parallel_chunks(nq, 32, |_, q0, q1| {
        let mut local_val = vec![f32::NEG_INFINITY; c];
        let mut local_idx = vec![0u32; c];
        for q in q0..q1 {
            local_val.iter_mut().for_each(|v| *v = f32::NEG_INFINITY);
            local_idx.iter_mut().for_each(|v| *v = 0);
            let qr = queries.row(q);
            for k in 0..n {
                let s = dot(qr, keys.row(k));
                let j = assign.map_or(0, |a| a[k] as usize);
                if s > local_val[j] {
                    local_val[j] = s;
                    local_idx[j] = k as u32;
                }
            }
            for j in 0..c {
                best_idx[q * c + j].store(local_idx[j] as usize, Ordering::Relaxed);
                sigma_bits[q * c + j].store(local_val[j].to_bits() as usize, Ordering::Relaxed);
            }
        }
    });

    GroundTruth {
        c,
        best_idx: best_idx
            .into_iter()
            .map(|a| a.into_inner() as u32)
            .collect(),
        sigma: sigma_bits
            .into_iter()
            .map(|a| f32::from_bits(a.into_inner() as u32))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn matches_bruteforce_single_cluster() {
        let q = randt(&[13, 24], 1);
        let k = randt(&[101, 24], 2);
        let gt = compute(&q, &k, 1, None);
        for i in 0..13 {
            let mut best = (0usize, f32::NEG_INFINITY);
            for j in 0..101 {
                let s = dot(q.row(i), k.row(j));
                if s > best.1 {
                    best = (j, s);
                }
            }
            assert_eq!(gt.idx(i, 0), best.0);
            assert!((gt.score(i, 0) - best.1).abs() < 1e-5);
        }
    }

    #[test]
    fn per_cluster_tops_partition_correctly() {
        let q = randt(&[9, 16], 3);
        let k = randt(&[60, 16], 4);
        let assign: Vec<u32> = (0..60).map(|i| (i % 4) as u32).collect();
        let gt = compute(&q, &k, 4, Some(&assign));
        for i in 0..9 {
            for j in 0..4 {
                // the reported best must belong to cluster j …
                assert_eq!(assign[gt.idx(i, j)] as usize, j);
                // … and beat every other member of cluster j.
                for m in 0..60 {
                    if assign[m] as usize == j {
                        assert!(dot(q.row(i), k.row(m)) <= gt.score(i, j) + 1e-5);
                    }
                }
            }
        }
    }

    #[test]
    fn global_top1_consistent_with_flat() {
        let q = randt(&[5, 8], 5);
        let k = randt(&[40, 8], 6);
        let assign: Vec<u32> = (0..40).map(|i| (i % 3) as u32).collect();
        let clustered = compute(&q, &k, 3, Some(&assign));
        let flat = compute(&q, &k, 1, None);
        for i in 0..5 {
            let (gi, gs) = clustered.global_top1(i);
            assert_eq!(gi, flat.idx(i, 0));
            assert!((gs - flat.score(i, 0)).abs() < 1e-5);
        }
    }

    #[test]
    fn top_cluster_contains_top_key() {
        let q = randt(&[7, 8], 8);
        let k = randt(&[50, 8], 9);
        let assign: Vec<u32> = (0..50).map(|i| (i % 5) as u32).collect();
        let gt = compute(&q, &k, 5, Some(&assign));
        for i in 0..7 {
            let (gidx, _) = gt.global_top1(i);
            assert_eq!(assign[gidx] as usize, gt.top_cluster(i));
        }
    }
}
