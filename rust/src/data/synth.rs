//! Synthetic BEIR-like corpus generator (DESIGN.md §3 substitution).
//!
//! The paper's datasets are L2-normalized sentence embeddings where the
//! query distribution p_X differs from the key distribution p_Y
//! (App. A.10): queries are short questions, keys long passages. The
//! amortization signal depends on exactly three properties, all of which
//! this generator reproduces with explicit knobs:
//!
//! 1. **clustered keys on the unit sphere** — a mixture of `modes`
//!    anisotropic vMF-like components (`spread` stretches one random
//!    direction per component, producing the outlier keys of Fig. 1 that
//!    defeat centroid routing);
//! 2. **query/key distribution shift** — query components are displaced
//!    copies of key components (`shift` ∈ [0,1] blends the component mean
//!    toward a fresh random direction), plus a `shift`-proportional share
//!    of query-only modes with no key-side counterpart (Fig. 29);
//! 3. **top-1 score headroom** — higher shift lowers typical ⟨q, k*⟩,
//!    matching the Quora (aligned, ≈0.86) vs NQ/HotpotQA (shifted, ≈0.71)
//!    contrast of Fig. 30.

use crate::tensor::{normalize_rows, Tensor};
use crate::util::Rng;

/// Generator parameters (mirrors `python/compile/manifest.py` datasets).
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub name: String,
    pub n_keys: usize,
    pub d: usize,
    pub n_queries: usize,
    /// 0 = queries drawn from the key mixture; 1 = fully displaced.
    pub shift: f32,
    /// Anisotropy factor: dominant within-cluster direction is `spread`x
    /// wider than the others.
    pub spread: f32,
    pub modes: usize,
    pub seed: u64,
}

/// A generated corpus: unit-norm keys and queries.
#[derive(Clone, Debug)]
pub struct SynthCorpus {
    pub spec: CorpusSpec,
    pub keys: Tensor,    // [n, d]
    pub queries: Tensor, // [n_queries, d]
}

// Within-component std before anisotropy. Calibrated so the top-1 MIPS
// score histograms (Fig. 30) land in the paper's observed range:
// aligned corpora (quora-s, shift 0.18) ≈ 0.85 and shifted corpora
// (nq-s/hotpot-s, shift ~0.6) ≈ 0.70 — see bench fig29_distributions.
const BASE_NOISE: f32 = 0.06;

fn unit_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 1.0);
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
    v.iter_mut().for_each(|x| *x /= n);
    v
}

/// Sample one point around `center` with an anisotropic dominant axis.
fn sample_member(rng: &mut Rng, center: &[f32], axis: &[f32], spread: f32, out: &mut [f32]) {
    let d = center.len();
    let along = rng.normal() as f32 * BASE_NOISE * spread;
    for i in 0..d {
        out[i] = center[i] + rng.normal() as f32 * BASE_NOISE + along * axis[i];
    }
}

impl SynthCorpus {
    /// Deterministically generate the corpus from its spec.
    pub fn generate(spec: &CorpusSpec) -> SynthCorpus {
        let mut rng = Rng::new(spec.seed);
        let d = spec.d;
        let m = spec.modes.max(1);

        // Key-side mixture components: center + anisotropic axis + weight.
        let mut centers: Vec<Vec<f32>> = Vec::with_capacity(m);
        let mut axes: Vec<Vec<f32>> = Vec::with_capacity(m);
        let mut weights: Vec<f64> = Vec::with_capacity(m);
        for _ in 0..m {
            centers.push(unit_vec(&mut rng, d));
            axes.push(unit_vec(&mut rng, d));
            weights.push(0.3 + rng.uniform()); // uneven cluster sizes
        }
        let wsum: f64 = weights.iter().sum();
        let cum: Vec<f64> = weights
            .iter()
            .scan(0.0, |acc, w| {
                *acc += w / wsum;
                Some(*acc)
            })
            .collect();
        let pick = |rng: &mut Rng, cum: &[f64]| -> usize {
            let u = rng.uniform();
            cum.iter().position(|&c| u <= c).unwrap_or(cum.len() - 1)
        };

        // Keys.
        let mut keys = Tensor::zeros(&[spec.n_keys, d]);
        for i in 0..spec.n_keys {
            let k = pick(&mut rng, &cum);
            let row_vec = {
                let mut tmp = vec![0.0f32; d];
                sample_member(&mut rng, &centers[k], &axes[k], spec.spread, &mut tmp);
                tmp
            };
            keys.row_mut(i).copy_from_slice(&row_vec);
        }
        normalize_rows(&mut keys);

        // Query-side mixture: displaced key components + query-only modes.
        let shift = spec.shift.clamp(0.0, 1.0);
        let mut q_centers: Vec<Vec<f32>> = Vec::with_capacity(m);
        for c in centers.iter() {
            let fresh = unit_vec(&mut rng, d);
            let mut qc: Vec<f32> = c
                .iter()
                .zip(&fresh)
                .map(|(a, b)| (1.0 - shift) * a + shift * b)
                .collect();
            let n = qc.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
            qc.iter_mut().for_each(|x| *x /= n);
            q_centers.push(qc);
        }
        // query-only modes (no key density underneath), Fig. 29.
        let extra = ((m as f32) * 0.9 * shift).round() as usize;
        for _ in 0..extra {
            q_centers.push(unit_vec(&mut rng, d));
        }
        let qm = q_centers.len();
        let q_cum: Vec<f64> = (1..=qm).map(|i| i as f64 / qm as f64).collect();

        let mut queries = Tensor::zeros(&[spec.n_queries, d]);
        for i in 0..spec.n_queries {
            let k = pick(&mut rng, &q_cum);
            // queries are tighter than keys (short questions vs passages)
            let row_vec = {
                let mut tmp = vec![0.0f32; d];
                let axis = unit_vec(&mut rng, d);
                sample_member(&mut rng, &q_centers[k], &axis, 0.6, &mut tmp);
                tmp
            };
            queries.row_mut(i).copy_from_slice(&row_vec);
        }
        normalize_rows(&mut queries);

        SynthCorpus {
            spec: spec.clone(),
            keys,
            queries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;

    fn spec(shift: f32) -> CorpusSpec {
        CorpusSpec {
            name: "t".into(),
            n_keys: 800,
            d: 32,
            n_queries: 200,
            shift,
            spread: 2.0,
            modes: 8,
            seed: 7,
        }
    }

    #[test]
    fn shapes_and_unit_norm() {
        let c = SynthCorpus::generate(&spec(0.5));
        assert_eq!(c.keys.shape(), &[800, 32]);
        assert_eq!(c.queries.shape(), &[200, 32]);
        for i in 0..800 {
            let n = dot(c.keys.row(i), c.keys.row(i)).sqrt();
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SynthCorpus::generate(&spec(0.5));
        let b = SynthCorpus::generate(&spec(0.5));
        assert_eq!(a.keys.data()[..64], b.keys.data()[..64]);
    }

    fn mean_top1(c: &SynthCorpus) -> f32 {
        let mut total = 0.0;
        for qi in 0..c.queries.rows() {
            let q = c.queries.row(qi);
            let best = (0..c.keys.rows())
                .map(|ki| dot(q, c.keys.row(ki)))
                .fold(f32::NEG_INFINITY, f32::max);
            total += best;
        }
        total / c.queries.rows() as f32
    }

    #[test]
    fn shift_lowers_top1_scores() {
        // Fig 30 analogy: aligned corpus -> high <q,k*>, shifted -> lower.
        let aligned = mean_top1(&SynthCorpus::generate(&spec(0.1)));
        let shifted = mean_top1(&SynthCorpus::generate(&spec(0.8)));
        assert!(
            aligned > shifted + 0.05,
            "aligned {aligned} vs shifted {shifted}"
        );
    }

    #[test]
    fn keys_are_clustered_not_uniform() {
        // Nearest-key similarity should be much higher than random-pair
        // similarity if the mixture structure is real.
        let c = SynthCorpus::generate(&spec(0.5));
        let mut rng = crate::util::Rng::new(3);
        let mut nn = 0.0;
        let mut rand_pair = 0.0;
        let trials = 50;
        for _ in 0..trials {
            let i = rng.below(c.keys.rows());
            let q = c.keys.row(i);
            let mut best = f32::NEG_INFINITY;
            for j in 0..c.keys.rows() {
                if j != i {
                    best = best.max(dot(q, c.keys.row(j)));
                }
            }
            nn += best;
            rand_pair += dot(q, c.keys.row(rng.below(c.keys.rows())));
        }
        assert!(nn / trials as f32 > rand_pair / trials as f32 + 0.2);
    }
}
