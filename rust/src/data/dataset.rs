//! Prepared training datasets (paper Sec. 4.1 "Data Preparation"):
//! generate corpus → (optionally) k-means partition the keys → augment
//! train queries with Gaussian noise + renormalize → exact-MIPS targets.

use crate::data::ground_truth::{self, GroundTruth};
use crate::data::synth::{CorpusSpec, SynthCorpus};
use crate::index::kmeans::KMeans;
use crate::tensor::{normalize_rows, Tensor};
use crate::util::Rng;

/// Targets for one query set against one clustering.
#[derive(Clone, Debug)]
pub struct PreparedTargets {
    pub x: Tensor, // [N, d] unit-norm queries
    pub gt: GroundTruth,
}

/// A fully prepared dataset: keys, clustering, train/val targets.
pub struct Dataset {
    pub name: String,
    pub keys: Tensor, // [n, d]
    pub c: usize,
    /// key -> cluster (all zeros when c == 1)
    pub assign: Vec<u32>,
    /// [c, d] cluster centroids (the routing baseline's scoring table)
    pub centroids: Tensor,
    pub train: PreparedTargets,
    pub val: PreparedTargets,
}

/// Options for dataset preparation.
#[derive(Clone, Debug)]
pub struct PrepareOpts {
    pub c: usize,
    /// Augmentation multiplier for train queries (paper: 5–100x).
    pub augment: usize,
    /// Gaussian augmentation std (paper: 0.02).
    pub aug_sigma: f32,
    /// Validation queries held out from the base query pool.
    pub val_queries: usize,
    /// k-means restarts; the most size-balanced clustering wins (Sec 4.3).
    pub kmeans_restarts: usize,
    pub seed: u64,
}

impl Default for PrepareOpts {
    fn default() -> Self {
        PrepareOpts {
            c: 1,
            augment: 4,
            aug_sigma: 0.02,
            val_queries: 1000,
            kmeans_restarts: 3,
            seed: 0xA11CE,
        }
    }
}

/// Expand `base` queries by `factor` noisy copies each (plus the original)
/// and renormalize to the unit sphere.
pub fn augment_queries(base: &Tensor, factor: usize, sigma: f32, seed: u64) -> Tensor {
    let (n, d) = (base.rows(), base.row_width());
    let copies = factor.max(1);
    let mut out = Tensor::zeros(&[n * copies, d]);
    let mut rng = Rng::new(seed);
    for i in 0..n {
        for c in 0..copies {
            let row_idx = i * copies + c;
            let src = base.row(i).to_vec();
            let dst = out.row_mut(row_idx);
            dst.copy_from_slice(&src);
            if c > 0 {
                for v in dst.iter_mut() {
                    *v += rng.normal() as f32 * sigma;
                }
            }
        }
    }
    normalize_rows(&mut out);
    out
}

impl Dataset {
    /// Full preparation pipeline from a corpus spec.
    pub fn prepare(spec: &CorpusSpec, opts: &PrepareOpts) -> Dataset {
        let corpus = SynthCorpus::generate(spec);
        Self::prepare_from_corpus(corpus, opts)
    }

    pub fn prepare_from_corpus(corpus: SynthCorpus, opts: &PrepareOpts) -> Dataset {
        let d = corpus.keys.row_width();
        // --- clustering --------------------------------------------------
        let (assign, centroids) = if opts.c > 1 {
            let km = KMeans::fit_best_balance(
                &corpus.keys,
                opts.c,
                25,
                opts.kmeans_restarts,
                opts.seed ^ 0xC1u64,
            );
            (km.assign, km.centroids)
        } else {
            (
                vec![0u32; corpus.keys.rows()],
                Tensor::zeros(&[1, d]), // unused for c=1
            )
        };

        // --- query split + augmentation ----------------------------------
        let nq = corpus.queries.rows();
        let val_n = opts.val_queries.min(nq / 4).max(1);
        let train_base_idx: Vec<usize> = (0..nq - val_n).collect();
        let val_idx: Vec<usize> = (nq - val_n..nq).collect();
        let train_base = corpus.queries.gather_rows(&train_base_idx);
        let val_x = corpus.queries.gather_rows(&val_idx);
        let train_x = augment_queries(&train_base, opts.augment, opts.aug_sigma, opts.seed ^ 0xA6);

        // --- exact targets ------------------------------------------------
        let assign_opt = if opts.c > 1 { Some(&assign[..]) } else { None };
        let train_gt = ground_truth::compute(&train_x, &corpus.keys, opts.c.max(1), assign_opt);
        let val_gt = ground_truth::compute(&val_x, &corpus.keys, opts.c.max(1), assign_opt);

        Dataset {
            name: corpus.spec.name.clone(),
            keys: corpus.keys,
            c: opts.c.max(1),
            assign,
            centroids,
            train: PreparedTargets {
                x: train_x,
                gt: train_gt,
            },
            val: PreparedTargets {
                x: val_x,
                gt: val_gt,
            },
        }
    }

    pub fn d(&self) -> usize {
        self.keys.row_width()
    }

    pub fn n_keys(&self) -> usize {
        self.keys.rows()
    }

    /// Materialize a training batch for the AOT train step:
    /// x [B,d], y_star [B,c,d], sigma [B,c] — flattened row-major.
    pub fn batch(
        &self,
        targets: &PreparedTargets,
        indices: &[usize],
        x: &mut Vec<f32>,
        y_star: &mut Vec<f32>,
        sigma: &mut Vec<f32>,
    ) {
        let d = self.d();
        let c = self.c;
        x.clear();
        y_star.clear();
        sigma.clear();
        x.reserve(indices.len() * d);
        y_star.reserve(indices.len() * c * d);
        sigma.reserve(indices.len() * c);
        for &q in indices {
            x.extend_from_slice(targets.x.row(q));
            for j in 0..c {
                let k = targets.gt.idx(q, j);
                y_star.extend_from_slice(self.keys.row(k));
                sigma.push(targets.gt.score(q, j));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;

    fn small_spec() -> CorpusSpec {
        CorpusSpec {
            name: "unit".into(),
            n_keys: 300,
            d: 16,
            n_queries: 80,
            shift: 0.5,
            spread: 2.0,
            modes: 6,
            seed: 11,
        }
    }

    #[test]
    fn augment_expands_and_normalizes() {
        let mut base = Tensor::zeros(&[4, 8]);
        Rng::new(1).fill_normal(base.data_mut(), 1.0);
        normalize_rows(&mut base);
        let aug = augment_queries(&base, 3, 0.05, 2);
        assert_eq!(aug.shape(), &[12, 8]);
        for i in 0..12 {
            let n = dot(aug.row(i), aug.row(i)).sqrt();
            assert!((n - 1.0).abs() < 1e-4);
        }
        // first copy of each is the original
        assert_eq!(aug.row(0), base.row(0));
        assert_ne!(aug.row(1), base.row(0));
    }

    #[test]
    fn prepare_c1_shapes() {
        let ds = Dataset::prepare(
            &small_spec(),
            &PrepareOpts {
                c: 1,
                augment: 2,
                val_queries: 10,
                ..Default::default()
            },
        );
        assert_eq!(ds.c, 1);
        assert_eq!(ds.val.x.rows(), 10);
        assert_eq!(ds.train.x.rows(), 70 * 2);
        assert_eq!(ds.train.gt.n_queries(), 140);
    }

    #[test]
    fn prepare_clustered_consistent() {
        let ds = Dataset::prepare(
            &small_spec(),
            &PrepareOpts {
                c: 4,
                augment: 1,
                val_queries: 8,
                ..Default::default()
            },
        );
        assert_eq!(ds.c, 4);
        assert_eq!(ds.assign.len(), 300);
        assert!(ds.assign.iter().all(|&a| a < 4));
        assert_eq!(ds.centroids.shape(), &[4, 16]);
        // gt best key of cluster j must live in cluster j
        for q in 0..ds.val.gt.n_queries() {
            for j in 0..4 {
                assert_eq!(ds.assign[ds.val.gt.idx(q, j)] as usize, j);
            }
        }
    }

    #[test]
    fn batch_materialization_matches_gt() {
        let ds = Dataset::prepare(
            &small_spec(),
            &PrepareOpts {
                c: 2,
                augment: 1,
                val_queries: 8,
                ..Default::default()
            },
        );
        let (mut x, mut y, mut s) = (Vec::new(), Vec::new(), Vec::new());
        ds.batch(&ds.val, &[0, 3], &mut x, &mut y, &mut s);
        let d = ds.d();
        assert_eq!(x.len(), 2 * d);
        assert_eq!(y.len(), 2 * 2 * d);
        assert_eq!(s.len(), 2 * 2);
        // sigma must equal <x, y*> for each (query, cluster)
        for (bi, &q) in [0usize, 3].iter().enumerate() {
            for j in 0..2 {
                let xrow = &x[bi * d..(bi + 1) * d];
                let yrow = &y[(bi * 2 + j) * d..(bi * 2 + j + 1) * d];
                let got: f32 = xrow.iter().zip(yrow).map(|(a, b)| a * b).sum();
                assert!((got - s[bi * 2 + j]).abs() < 1e-4);
                assert_eq!(s[bi * 2 + j], ds.val.gt.score(q, j));
            }
        }
    }
}
