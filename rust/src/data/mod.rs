//! Data pipeline: synthetic BEIR-like corpora, query augmentation, and
//! exact-MIPS ground-truth target generation (paper Sec. 3.3 / 4.1).

pub mod dataset;
pub mod ground_truth;
pub mod synth;

pub use dataset::{Dataset, PreparedTargets};
pub use synth::{CorpusSpec, SynthCorpus};
