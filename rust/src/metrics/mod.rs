//! Evaluation metrics (paper Sec. 4.2): retrieval metrics over predicted
//! keys, the relative transport error, FLOPs accounting for the Pareto
//! cost axes, and histogram utilities for the Fig. 29/30 diagnostics.

pub mod flops;
pub mod histogram;
pub mod retrieval;
pub mod transport;

pub use retrieval::RetrievalMetrics;
