//! Fixed-range histograms + summary stats for the distribution
//! diagnostics (Fig. 29 PCA densities, Fig. 30 top-1 score histograms).

/// Equal-width histogram over [lo, hi].
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub n: u64,
    sum: f64,
    values: Vec<f32>, // kept for exact median (datasets here are small)
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        Histogram {
            lo,
            hi,
            counts: vec![0; bins.max(1)],
            n: 0,
            sum: 0.0,
            values: Vec::new(),
        }
    }

    pub fn record(&mut self, v: f64) {
        let bins = self.counts.len();
        let t = ((v - self.lo) / (self.hi - self.lo)).clamp(0.0, 1.0);
        let b = ((t * bins as f64) as usize).min(bins - 1);
        self.counts[b] += 1;
        self.n += 1;
        self.sum += v;
        self.values.push(v as f32);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn median(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let mut v = self.values.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2] as f64
    }

    /// Render as an ASCII bar chart (bench reports).
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bins = self.counts.len();
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let x0 = self.lo + (self.hi - self.lo) * i as f64 / bins as f64;
            let bar = "#".repeat((c as f64 / max as f64 * width as f64).round() as usize);
            out.push_str(&format!("{x0:7.3} | {bar} {c}\n"));
        }
        out
    }
}

/// 2D occupancy grid over [lo0,hi0]x[lo1,hi1] — the "kernel density"
/// panel analog of Fig. 29, reported as a coarse grid.
#[derive(Clone, Debug)]
pub struct Grid2d {
    pub bins: usize,
    pub lo: [f64; 2],
    pub hi: [f64; 2],
    pub counts: Vec<u64>,
    pub n: u64,
}

impl Grid2d {
    pub fn new(lo: [f64; 2], hi: [f64; 2], bins: usize) -> Grid2d {
        Grid2d {
            bins,
            lo,
            hi,
            counts: vec![0; bins * bins],
            n: 0,
        }
    }

    pub fn record(&mut self, x: f64, y: f64) {
        let bx = (((x - self.lo[0]) / (self.hi[0] - self.lo[0])).clamp(0.0, 1.0)
            * self.bins as f64) as usize;
        let by = (((y - self.lo[1]) / (self.hi[1] - self.lo[1])).clamp(0.0, 1.0)
            * self.bins as f64) as usize;
        let (bx, by) = (bx.min(self.bins - 1), by.min(self.bins - 1));
        self.counts[by * self.bins + bx] += 1;
        self.n += 1;
    }

    /// Fraction of this grid's mass falling in cells where `other` has
    /// (near-)zero mass — the "query-side modes with no key density"
    /// statistic of Fig. 29.
    pub fn mass_outside(&self, other: &Grid2d) -> f64 {
        assert_eq!(self.bins, other.bins);
        if self.n == 0 {
            return 0.0;
        }
        let mut outside = 0u64;
        for (a, b) in self.counts.iter().zip(&other.counts) {
            if *b == 0 {
                outside += a;
            }
        }
        outside as f64 / self.n as f64
    }

    pub fn render(&self) -> String {
        const SHADES: &[char] = &[' ', '.', ':', '+', '*', '#', '@'];
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for by in (0..self.bins).rev() {
            for bx in 0..self.bins {
                let c = self.counts[by * self.bins + bx];
                let s = (c as f64 / max as f64 * (SHADES.len() - 1) as f64).round() as usize;
                out.push(SHADES[s]);
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_median() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for v in [0.1, 0.2, 0.3, 0.9] {
            h.record(v);
        }
        assert!((h.mean() - 0.375).abs() < 1e-9);
        assert!((h.median() - 0.3).abs() < 1e-6);
        assert_eq!(h.n, 4);
    }

    #[test]
    fn histogram_clamps_out_of_range() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.record(-5.0);
        h.record(5.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
    }

    #[test]
    fn grid_mass_outside() {
        let mut keys = Grid2d::new([0.0, 0.0], [1.0, 1.0], 4);
        let mut queries = Grid2d::new([0.0, 0.0], [1.0, 1.0], 4);
        keys.record(0.1, 0.1);
        queries.record(0.1, 0.1); // overlaps keys
        queries.record(0.9, 0.9); // no key mass there
        assert!((queries.mass_outside(&keys) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn render_shapes() {
        let mut g = Grid2d::new([0.0, 0.0], [1.0, 1.0], 3);
        g.record(0.5, 0.5);
        let r = g.render();
        assert_eq!(r.lines().count(), 3);
        let mut h = Histogram::new(0.0, 1.0, 5);
        h.record(0.5);
        assert_eq!(h.render(10).lines().count(), 5);
    }
}
