//! Relative transport error (paper Eq. 4.1):
//!
//! ```text
//! E_rel = E_x[ log ||ŷ(x) − y*||² / ||x − y*||² ]
//! ```
//!
//! 0 = identity predictor; −1 ≈ e⁻¹ ≈ 0.37× closer; −∞ = perfect.

use crate::tensor::Tensor;

/// E_rel for predictions [n, d] vs queries [n, d] and targets [n, d].
pub fn relative_transport_error(pred: &Tensor, queries: &Tensor, targets: &Tensor) -> f64 {
    let n = pred.rows();
    assert_eq!(queries.rows(), n);
    assert_eq!(targets.rows(), n);
    let d = pred.row_width();
    let mut acc = 0.0f64;
    for i in 0..n {
        let (p, q, t) = (pred.row(i), queries.row(i), targets.row(i));
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for j in 0..d {
            num += ((p[j] - t[j]) as f64).powi(2);
            den += ((q[j] - t[j]) as f64).powi(2);
        }
        acc += (num.max(1e-30) / den.max(1e-30)).ln();
    }
    acc / n as f64
}

/// Per-cluster variant: pred [n, c, d], targets [n, c, d], queries [n, d];
/// averaged over batch and clusters (paper Sec. 4.2).
pub fn relative_transport_error_clustered(
    pred: &Tensor,
    queries: &Tensor,
    targets: &Tensor,
) -> f64 {
    let n = queries.rows();
    let d = queries.row_width();
    let c = pred.len() / (n * d);
    assert_eq!(pred.len(), n * c * d);
    assert_eq!(targets.len(), n * c * d);
    let pd = pred.data();
    let td = targets.data();
    let mut acc = 0.0f64;
    for i in 0..n {
        let q = queries.row(i);
        for j in 0..c {
            let off = (i * c + j) * d;
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for k in 0..d {
                num += ((pd[off + k] - td[off + k]) as f64).powi(2);
                den += ((q[k] - td[off + k]) as f64).powi(2);
            }
            acc += (num.max(1e-30) / den.max(1e-30)).ln();
        }
    }
    acc / (n * c) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn identity_predictor_is_zero() {
        let q = randt(&[20, 8], 1);
        let t = randt(&[20, 8], 2);
        let e = relative_transport_error(&q, &q, &t);
        assert!(e.abs() < 1e-9);
    }

    #[test]
    fn perfect_predictor_is_very_negative() {
        let q = randt(&[20, 8], 3);
        let t = randt(&[20, 8], 4);
        let e = relative_transport_error(&t, &q, &t);
        assert!(e < -20.0);
    }

    #[test]
    fn halfway_is_negative() {
        let q = randt(&[50, 8], 5);
        let t = randt(&[50, 8], 6);
        let mut mid = q.clone();
        for (m, tv) in mid.data_mut().iter_mut().zip(t.data()) {
            *m = 0.5 * *m + 0.5 * tv;
        }
        let e = relative_transport_error(&mid, &q, &t);
        // ||mid - t|| = 0.5 ||q - t|| -> log(0.25) ≈ -1.386
        assert!((e - (-1.386)).abs() < 0.01, "e = {e}");
    }

    #[test]
    fn clustered_matches_flat_for_c1() {
        let q = randt(&[10, 4], 7);
        let t = randt(&[10, 4], 8);
        let p = randt(&[10, 4], 9);
        let flat = relative_transport_error(&p, &q, &t);
        let pc = p.clone().reshape(&[10, 1, 4]);
        let tc = t.clone().reshape(&[10, 1, 4]);
        let clustered = relative_transport_error_clustered(&pc, &q, &tc);
        assert!((flat - clustered).abs() < 1e-9);
    }
}
