//! FLOPs accounting for the paper's cost axes. One multiply-add = 2
//! flops throughout (matches `python/compile/sizing.py`, which stamps the
//! per-query model costs into the artifact metadata).

/// Centroid-routing cost: score the query against `c` centroids.
pub fn centroid_routing_flops(c: usize, d: usize) -> u64 {
    (c * d * 2) as u64
}

/// Exhaustive scan cost over `n` keys.
pub fn exhaustive_flops(n: usize, d: usize) -> u64 {
    (n * d * 2) as u64
}

/// Routing experiment cost (Sec. 4.3): selection + exact search within
/// the chosen clusters (sum of their sizes).
pub fn routing_total_flops(selection_flops: u64, cluster_sizes: &[usize], d: usize) -> u64 {
    let scan: usize = cluster_sizes.iter().sum();
    selection_flops + exhaustive_flops(scan, d)
}

/// Integration experiment cost (Sec. 4.4): optional query mapping +
/// index search cost.
pub fn integration_total_flops(map_flops: u64, index_flops: u64) -> u64 {
    map_flops + index_flops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centroid_cost_linear_in_c() {
        assert_eq!(centroid_routing_flops(10, 64), 10 * 64 * 2);
        assert_eq!(
            centroid_routing_flops(128, 64),
            centroid_routing_flops(10, 64) / 10 * 128
        );
    }

    #[test]
    fn routing_total_adds_scan() {
        let total = routing_total_flops(100, &[50, 30], 8);
        assert_eq!(total, 100 + 80 * 8 * 2);
    }

    #[test]
    fn integration_adds_components() {
        assert_eq!(integration_total_flops(5, 7), 12);
    }
}
