//! Retrieval metrics (Sec. 4.2): match rate, Recall@k and MRR of the
//! predicted key ŷ(x) against the true top-1 key y*(x), ranked by
//! distance from ŷ over the whole database.
//!
//! On unit-norm keys, argmin ||ŷ - y|| == argmax ⟨ŷ, y⟩ up to the keys'
//! (constant) norms, so ranking uses inner products — the same flat-scan
//! primitive as everything else.

use crate::tensor::{dot, Tensor};
use crate::util::threads::parallel_chunks;
use std::sync::atomic::{AtomicU64, Ordering};

/// Aggregate retrieval quality for a set of predictions.
#[derive(Clone, Copy, Debug, Default)]
pub struct RetrievalMetrics {
    /// fraction with nearest key == y*
    pub match_rate: f64,
    /// fraction with y* among the 10 nearest keys
    pub recall_at_10: f64,
    /// fraction with y* among the 100 nearest
    pub recall_at_100: f64,
    /// mean reciprocal rank of y*
    pub mrr: f64,
    pub n: usize,
}

/// Compute metrics for predictions `pred` [n, d] whose true top keys are
/// `target_idx[i]` into `keys`.
pub fn evaluate(pred: &Tensor, keys: &Tensor, target_idx: &[usize]) -> RetrievalMetrics {
    let n = pred.rows();
    assert_eq!(n, target_idx.len());
    let nk = keys.rows();
    let hits1 = AtomicU64::new(0);
    let hits10 = AtomicU64::new(0);
    let hits100 = AtomicU64::new(0);
    let mrr_milli = AtomicU64::new(0); // accumulate MRR * 1e6 as integer

    parallel_chunks(n, 16, |_, q0, q1| {
        for q in q0..q1 {
            let p = pred.row(q);
            let t = target_idx[q];
            let target_score = dot(p, keys.row(t));
            // rank = 1 + number of keys strictly better than the target
            // (ties resolved toward lower index, matching TopK).
            let mut better = 0usize;
            for k in 0..nk {
                let s = dot(p, keys.row(k));
                if s > target_score || (s == target_score && k < t) {
                    better += 1;
                }
            }
            let rank = better + 1;
            if rank == 1 {
                hits1.fetch_add(1, Ordering::Relaxed);
            }
            if rank <= 10 {
                hits10.fetch_add(1, Ordering::Relaxed);
            }
            if rank <= 100 {
                hits100.fetch_add(1, Ordering::Relaxed);
            }
            mrr_milli.fetch_add((1e6 / rank as f64) as u64, Ordering::Relaxed);
        }
    });

    RetrievalMetrics {
        match_rate: hits1.load(Ordering::Relaxed) as f64 / n as f64,
        recall_at_10: hits10.load(Ordering::Relaxed) as f64 / n as f64,
        recall_at_100: hits100.load(Ordering::Relaxed) as f64 / n as f64,
        mrr: mrr_milli.load(Ordering::Relaxed) as f64 / 1e6 / n as f64,
        n,
    }
}

/// Recall@k of a result list against a single ground-truth id.
pub fn hit_at_k(result_ids: &[u32], truth: u32, k: usize) -> bool {
    result_ids.iter().take(k).any(|&id| id == truth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn perfect_predictions_score_one() {
        let keys = unit(&[50, 8], 1);
        let targets: Vec<usize> = (0..10).collect();
        let pred = keys.gather_rows(&targets);
        let m = evaluate(&pred, &keys, &targets);
        assert_eq!(m.match_rate, 1.0);
        assert_eq!(m.mrr, 1.0);
        assert_eq!(m.recall_at_10, 1.0);
    }

    #[test]
    fn random_predictions_score_low() {
        let keys = unit(&[200, 16], 2);
        let pred = unit(&[50, 16], 3);
        let targets: Vec<usize> = (0..50).collect();
        let m = evaluate(&pred, &keys, &targets);
        assert!(m.match_rate < 0.2);
        assert!(m.mrr < 0.3);
        assert!(m.recall_at_100 <= 1.0);
    }

    #[test]
    fn mrr_rank_two_is_half() {
        // Construct: prediction exactly equals key 1, target is key 0,
        // and key 0 is the second-closest.
        let mut keys = Tensor::zeros(&[3, 4]);
        keys.row_mut(0).copy_from_slice(&[0.9, 0.1, 0.0, 0.0]);
        keys.row_mut(1).copy_from_slice(&[1.0, 0.0, 0.0, 0.0]);
        keys.row_mut(2).copy_from_slice(&[0.0, 1.0, 0.0, 0.0]);
        normalize_rows(&mut keys);
        let pred = keys.gather_rows(&[1]);
        let m = evaluate(&pred, &keys, &[0]);
        assert!((m.mrr - 0.5).abs() < 1e-6, "mrr {}", m.mrr);
        assert_eq!(m.match_rate, 0.0);
        assert_eq!(m.recall_at_10, 1.0);
    }

    #[test]
    fn hit_at_k_respects_prefix() {
        assert!(hit_at_k(&[5, 3, 9], 3, 2));
        assert!(!hit_at_k(&[5, 3, 9], 9, 2));
        assert!(hit_at_k(&[5, 3, 9], 9, 3));
    }
}
