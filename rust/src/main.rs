//! `amips` — leader binary: dataset prep, training, evaluation, routing
//! and a serving demo over the AOT artifacts. Every query path speaks
//! `amips::api::{SearchRequest, SearchResponse, Searcher}`.
//!
//! ```text
//! amips list                                  # configs + datasets
//! amips gen-data  --dataset nq-s [--c 10]     # prepare + report a dataset
//! amips search    [--backend ivf | --spec "ivf(nlist=64)"] [--n 20000]
//!                 [--d 32] [--k 10]           # pure-Rust API demo/sweep
//! amips build     --catalog DIR --name NAME [--spec "scann(nlist=64)"]
//!                 [--keys f.amt | --n 20000 --d 32] [--mutable]
//!                 # specs compose: --spec "sharded(shards=8,inner=ivf(nlist=64))"
//!                 #                partitions keys and fans search out per shard
//!                 # --mutable creates a `<name>.seg` mutable collection
//!                 # (delta + sealed segments) instead of a frozen artifact
//!                                             # train once, persist artifact
//! amips upsert    --name NAME (--addr HOST:PORT | --catalog DIR)
//!                 [--ids 1,2,3] [--n ROWS] [--d 32] [--seed S]
//!                 # insert (no --ids) or upsert synthetic rows into a
//!                 # mutable collection; direct --catalog mode commits
//! amips delete    --name NAME (--addr HOST:PORT | --catalog DIR) --ids 1,2,3
//! amips compact   --name NAME (--addr HOST:PORT | --catalog DIR)
//!                 # fold delta + tombstones into a fresh sealed generation
//! amips train     [--model keynet|supportnet] [--n 20000 --d 32 --c 1]
//!                 [--steps N --lr F --h H --layers L] [--out model.amm]
//!                 [--catalog DIR --name NAME [--spec "ivf(nlist=64)"]]
//!                 # pure-Rust training; --catalog builds the index over
//!                 # the same keys and attaches the model as its mapper
//! amips eval      --model model.amm [--n 20000 --d 32]  # match rate/E_rel
//! amips serve     --catalog DIR [--collection NAME] [--requests N]
//!                 # serve prebuilt artifacts; collections with a mapper
//!                 # serve mapped queries (Sec. 4.4) by default
//! amips serve     --catalog DIR --listen ADDR [--port-file F]
//!                 [--serve-seconds S] [--queue-cap N] [--max-conns N]
//!                 [--max-inflight N] [--metrics-port P]
//!                 # TCP front-end over the whole catalog (AMTP framed
//!                 # protocol, wire v2 pipelining); clients use
//!                 # NetClient or bench_serve; --metrics-port binds a
//!                 # second plain-text scrape listener
//! amips probe     --addr HOST:PORT [--metrics HOST:PORT]
//!                 # wire-protocol health probe: ping/stats, malformed-
//!                 # frame robustness checks, optional metrics scrape
//! amips train     --config <name> [--steps N] [--lr F] [--verbose]   (xla)
//! amips eval      --config <name> [--steps N]                        (xla)
//! amips route     --dataset nq-s --config <name> [--topk 1..5]       (xla)
//! amips serve     --config <name> [--requests N] [--nprobe K]        (xla)
//! ```

use amips::bench_support::fixtures;
use amips::bench_support::report::{f, pct, Report};
use amips::cli::Args;
use anyhow::{bail, Result};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("list") => cmd_list(),
        Some("gen-data") => cmd_gen_data(&args),
        Some("search") => cmd_search(&args),
        Some("build") => cmd_build(&args),
        Some("upsert") => cmd_mutate(&args, "upsert"),
        Some("delete") => cmd_mutate(&args, "delete"),
        Some("compact") => cmd_compact(&args),
        // `serve --catalog` is pure Rust (prebuilt artifacts, optional
        // trained mapper); plain `serve` drives the AOT KeyNet mapper
        // and needs `xla`. `train`/`eval` run the pure-Rust backend by
        // default; a `--config` selects the AOT/PJRT path.
        Some("serve") if args.has("catalog") && args.has("listen") => cmd_serve_listen(&args),
        Some("serve") if args.has("catalog") => cmd_serve_catalog(&args),
        Some("probe") => cmd_probe(&args),
        Some("train") if args.has("config") => xla_cmds::cmd_train(&args),
        Some("train") => cmd_train_rust(&args),
        Some("eval") if args.has("config") => xla_cmds::cmd_eval(&args),
        Some("eval") => cmd_eval_rust(&args),
        Some("route") => xla_cmds::cmd_route(&args),
        Some("serve") => xla_cmds::cmd_serve(&args),
        Some(other) => bail!("unknown command {other}; try `amips list`"),
        None => {
            println!("amips {} — amortized MIPS coordinator", amips::version());
            println!(
                "commands: list | gen-data | search | build | upsert | delete | compact | train | eval | serve --catalog [--listen] | probe | route | serve"
            );
            Ok(())
        }
    }
}

fn cmd_list() -> Result<()> {
    let m = fixtures::load_manifest()?;
    println!("datasets:");
    for d in &m.datasets {
        println!(
            "  {:12} n={:<7} d={:<4} queries={:<5} shift={}",
            d.name, d.n, d.d, d.n_queries, d.shift
        );
    }
    println!("configs ({}):", m.configs.len());
    for c in &m.configs {
        println!("  {c}");
    }
    println!("backends: {}", amips::index::BACKBONES.join(" | "));
    println!("composite: sharded(shards=N,assign=round_robin|contiguous,inner=<backend spec>)");
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let m = fixtures::load_manifest()?;
    let name = args.require("dataset")?.to_string();
    let c = args.get_usize("c", 1)?;
    args.reject_unknown()?;
    let ds = fixtures::prepare_dataset(&m, &name, c)?;
    let mut rep = Report::new(&format!("dataset {name} (c={c})"));
    rep.header(&["keys", "d", "train-q", "val-q", "mean top-1 <q,k*>"]);
    let mean_top1: f64 = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).1 as f64)
        .sum::<f64>()
        / ds.val.gt.n_queries() as f64;
    rep.row(&[
        ds.n_keys().to_string(),
        ds.d().to_string(),
        ds.train.x.rows().to_string(),
        ds.val.x.rows().to_string(),
        f(mean_top1),
    ]);
    if c > 1 {
        let sizes: Vec<String> = {
            let mut s = vec![0usize; c];
            for &a in &ds.assign {
                s[a as usize] += 1;
            }
            s.iter().map(|v| v.to_string()).collect()
        };
        rep.note(format!("cluster sizes: {}", sizes.join(", ")));
    }
    rep.emit("gen_data");
    Ok(())
}

/// Pure-Rust demonstration of the unified search API: generate a
/// synthetic corpus, put the chosen backbone behind `Searcher`, and sweep
/// the `Effort` knob — no artifacts or XLA required.
fn cmd_search(args: &Args) -> Result<()> {
    use amips::api::{recall_against_truth, Effort, SearchRequest, Searcher};
    use amips::data::dataset::PrepareOpts;
    use amips::data::Dataset;
    use amips::index::{BuildCtx, IndexSpec, VectorIndex};

    let backend = args.get_or("backend", "ivf").to_string();
    let spec_arg = args.get("spec").map(str::to_string);
    let n = args.get_usize("n", 20_000)?;
    let d = args.get_usize("d", 32)?;
    let nq = args.get_usize("queries", 1_000)?;
    let k = args.get_usize("k", 10)?;
    let seed = args.get_u64("seed", 42)?;
    args.reject_unknown()?;

    // the shared synthetic corpus: same (n, d, seed) => same keys as
    // `amips build`-less train/eval runs
    let spec = fixtures::synth_corpus_spec(n, d, nq * 4, seed);
    let ds = Dataset::prepare(
        &spec,
        &PrepareOpts {
            c: 1,
            augment: 1,
            val_queries: nq,
            kmeans_restarts: 1,
            ..Default::default()
        },
    );
    let nlist = fixtures::default_nlist(ds.n_keys());
    // an explicit --spec carries its own knobs; --backend gets defaults
    // with the dataset-scaled nlist
    let spec = match &spec_arg {
        Some(s) => s.parse::<IndexSpec>()?,
        None => IndexSpec::default_for(&backend)?.with_nlist(nlist),
    };
    let index = spec.build(
        &ds.keys,
        &BuildCtx {
            sample_queries: Some(&ds.train.x),
            seed,
        },
    )?;
    let truth: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).0)
        .collect();

    let mut rep = Report::new(&format!(
        "search sweep: {} over {} keys (d={d}, cells={})",
        index.label(),
        index.num_keys(),
        index.n_cells(),
    ));
    rep.header(&["effort", "R@k", "kFLOP/q", "keys/q", "cells/q", "us/q"]);
    let efforts = [
        Effort::Probes(1),
        Effort::Probes(2),
        Effort::Probes(4),
        Effort::Auto,
        Effort::Frac(0.5),
        Effort::Exhaustive,
    ];
    for effort in efforts {
        let req = SearchRequest::top_k(k).effort(effort);
        let resp = index.search(&ds.val.x, &req)?;
        let nqf = resp.n_queries() as f64;
        rep.row(&[
            format!("{effort:?}"),
            pct(recall_against_truth(&resp.hits, &truth, k)),
            format!("{:.1}", resp.flops_per_query() / 1e3),
            format!("{:.0}", resp.cost.keys_scanned as f64 / nqf),
            format!("{:.1}", resp.cost.cells_probed as f64 / nqf),
            format!("{:.1}", resp.seconds_per_query() * 1e6),
        ]);
    }
    rep.note("Effort::Exhaustive is exact on every backbone; R@k measures the exact top-1 within the returned k");
    rep.note(format!("spec: {}", index.spec()));
    rep.emit("search");
    Ok(())
}

/// Build an index from a typed `IndexSpec` and persist it into a catalog
/// of artifacts — the "build once" half of build-once/serve-many. Pure
/// Rust: keys come from an `.amt` tensor file or a synthetic corpus.
fn cmd_build(args: &Args) -> Result<()> {
    use amips::index::{BuildCtx, Catalog, IndexSpec, VectorIndex};
    use amips::tensor::Tensor;
    use amips::util::Timer;

    let catalog_dir = args.require("catalog")?.to_string();
    let name = args.require("name")?.to_string();
    let mut spec = match args.get("spec") {
        Some(s) => s.parse::<IndexSpec>()?,
        None => IndexSpec::default_for(args.get_or("backend", "ivf"))?,
    };
    if args.has("nlist") {
        spec = spec.with_nlist(args.get_usize("nlist", 0)?);
    }
    let keys_path = args.get("keys").map(str::to_string);
    let queries_path = args.get("queries").map(str::to_string);
    let n = args.get_usize("n", 20_000)?;
    let d = args.get_usize("d", 32)?;
    let seed = args.get_u64("seed", 42)?;
    let mutable = args.has("mutable");
    args.reject_unknown()?;

    // synthetic keys come from the shared corpus generator, so an index
    // built here and a mapper from `amips train` with the same
    // (n, d, seed) really do see the same key set
    let keys = match &keys_path {
        Some(p) => Tensor::load(std::path::Path::new(p))?,
        None => fixtures::synth_keys(n, d, seed),
    };
    let sample_queries = match &queries_path {
        Some(p) => Some(Tensor::load(std::path::Path::new(p))?),
        None => None,
    };
    // manifest-only append: existing artifacts in the catalog are not
    // deserialized just to add one more collection
    let timer = Timer::start();
    let entry = if mutable {
        // mutable lifecycle: create the `<name>.seg` directory, load the
        // keys as the first delta, seal generation 1 so a fresh process
        // (or a crash right after this command) sees all of them
        let entry = Catalog::create_mutable(&catalog_dir, &name, &spec, keys.shape()[1], seed)?;
        let coll = entry.mutable.as_ref().expect("create_mutable entry");
        coll.insert(&keys)?;
        coll.commit()?;
        entry
    } else {
        Catalog::append_collection(
            &catalog_dir,
            &name,
            &spec,
            &keys,
            &BuildCtx {
                sample_queries: sample_queries.as_ref(),
                seed,
            },
        )?
    };
    let build_s = timer.elapsed_s();
    let bytes = if entry.path.is_dir() {
        let mut total = 0u64;
        for f in std::fs::read_dir(&entry.path)? {
            total += f?.metadata()?.len();
        }
        total
    } else {
        std::fs::metadata(&entry.path)?.len()
    };

    let mut rep = Report::new(&format!("build {name} -> {}", entry.path.display()));
    rep.header(&["collection", "spec", "keys", "d", "artifact KiB", "build s"]);
    rep.row(&[
        name.clone(),
        entry.index.spec().to_string(),
        entry.index.len().to_string(),
        entry.index.dim().to_string(),
        format!("{:.1}", bytes as f64 / 1024.0),
        format!("{build_s:.2}"),
    ]);
    rep.note(format!(
        "serve it with: amips serve --catalog {catalog_dir} --collection {name}"
    ));
    rep.emit("build");
    Ok(())
}

/// Comma-separated id list: `--ids 1,2,3`.
fn parse_ids(s: &str) -> Result<Vec<u32>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<u32>()
                .map_err(|e| anyhow::anyhow!("bad id '{t}' in --ids: {e}"))
        })
        .collect()
}

/// `amips upsert` / `amips delete`: apply one mutation to a mutable
/// collection, either over TCP (`--addr`, served by the running
/// process) or directly against the catalog on disk (`--catalog`,
/// commits a new generation before returning so the change is durable).
fn cmd_mutate(args: &Args, op: &str) -> Result<()> {
    use amips::coordinator::net::NetClient;
    use amips::index::{Catalog, VectorIndex};
    use amips::util::Timer;
    use std::time::Duration;

    let name = args.require("name")?.to_string();
    let addr = args.get("addr").map(str::to_string);
    let catalog_dir = args.get("catalog").map(str::to_string);
    anyhow::ensure!(
        addr.is_some() != catalog_dir.is_some(),
        "pass exactly one of --addr HOST:PORT (TCP) or --catalog DIR (direct)"
    );
    let ids: Vec<u32> = match args.get("ids") {
        Some(s) => parse_ids(s)?,
        None => Vec::new(),
    };
    // upsert/insert rows come from the shared synthetic corpus
    // generator, so smoke scripts get deterministic vectors
    let vecs = if op == "delete" {
        anyhow::ensure!(!ids.is_empty(), "delete needs --ids 1,2,3");
        None
    } else {
        let d = args.get_usize("d", 32)?;
        let rows = if ids.is_empty() {
            args.get_usize("n", 1)?
        } else {
            ids.len()
        };
        anyhow::ensure!(rows >= 1, "need at least one row (--n or --ids)");
        let seed = args.get_u64("seed", 42)?;
        Some(fixtures::synth_keys(rows, d, seed))
    };
    args.reject_unknown()?;

    let (done_ids, len, gen, micros, via) = match (&addr, &catalog_dir) {
        (Some(a), None) => {
            let mut client = NetClient::connect(a.as_str())?;
            client.set_timeout(Some(Duration::from_secs(30)))?;
            let m = match &vecs {
                None => client.delete(&name, &ids)?,
                Some(v) if ids.is_empty() => client.insert(&name, v)?,
                Some(v) => client.upsert(&name, &ids, v)?,
            };
            (m.ids, m.len, m.gen, m.server_micros, format!("tcp {a}"))
        }
        (None, Some(dir)) => {
            let catalog = Catalog::open(dir)?;
            let coll = catalog.mutable(&name).ok_or_else(|| {
                anyhow::anyhow!("'{name}' is not a mutable collection in {dir}")
            })?;
            let timer = Timer::start();
            let out_ids = match &vecs {
                None => {
                    coll.delete(&ids)?;
                    ids.clone()
                }
                Some(v) if ids.is_empty() => coll.insert(v)?,
                Some(v) => {
                    coll.upsert(&ids, v)?;
                    ids.clone()
                }
            };
            let gen = coll.commit()?;
            (
                out_ids,
                coll.len() as u64,
                gen,
                (timer.elapsed_s() * 1e6) as u64,
                format!("catalog {dir}"),
            )
        }
        _ => unreachable!("exactly one of addr/catalog ensured above"),
    };

    let effective = if vecs.is_none() {
        "delete"
    } else if ids.is_empty() {
        "insert"
    } else {
        "upsert"
    };
    let mut rep = Report::new(&format!("{effective} {name} via {via}"));
    rep.header(&["op", "rows", "live len", "generation", "micros"]);
    rep.row(&[
        effective.into(),
        done_ids.len().to_string(),
        len.to_string(),
        gen.to_string(),
        micros.to_string(),
    ]);
    if !done_ids.is_empty() {
        let show: Vec<String> = done_ids.iter().take(8).map(u32::to_string).collect();
        let ell = if done_ids.len() > 8 { ", …" } else { "" };
        rep.note(format!("ids: {}{}", show.join(", "), ell));
    }
    rep.emit("mutate");
    Ok(())
}

/// `amips compact`: fold a mutable collection's delta + tombstones into
/// a fresh sealed generation (TCP or direct catalog mode, like
/// [`cmd_mutate`]).
fn cmd_compact(args: &Args) -> Result<()> {
    use amips::coordinator::net::NetClient;
    use amips::index::{Catalog, VectorIndex};
    use amips::util::Timer;
    use std::time::Duration;

    let name = args.require("name")?.to_string();
    let addr = args.get("addr").map(str::to_string);
    let catalog_dir = args.get("catalog").map(str::to_string);
    anyhow::ensure!(
        addr.is_some() != catalog_dir.is_some(),
        "pass exactly one of --addr HOST:PORT (TCP) or --catalog DIR (direct)"
    );
    args.reject_unknown()?;

    let (len, gen, micros, via) = match (&addr, &catalog_dir) {
        (Some(a), None) => {
            let mut client = NetClient::connect(a.as_str())?;
            client.set_timeout(Some(Duration::from_secs(120)))?;
            let m = client.compact(&name)?;
            (m.len, m.gen, m.server_micros, format!("tcp {a}"))
        }
        (None, Some(dir)) => {
            let catalog = Catalog::open(dir)?;
            let coll = catalog.mutable(&name).ok_or_else(|| {
                anyhow::anyhow!("'{name}' is not a mutable collection in {dir}")
            })?;
            let timer = Timer::start();
            let gen = coll.compact()?;
            (
                coll.len() as u64,
                gen,
                (timer.elapsed_s() * 1e6) as u64,
                format!("catalog {dir}"),
            )
        }
        _ => unreachable!("exactly one of addr/catalog ensured above"),
    };

    let mut rep = Report::new(&format!("compact {name} via {via}"));
    rep.header(&["live len", "generation", "micros"]);
    rep.row(&[len.to_string(), gen.to_string(), micros.to_string()]);
    rep.emit("compact");
    Ok(())
}

/// Train a SupportNet/KeyNet with the pure-Rust backend on the shared
/// synthetic corpus; optionally persist the model artifact (`--out`)
/// and/or build an index over the *same keys* into a catalog and attach
/// the model as that collection's query mapper (`--catalog --name`).
fn cmd_train_rust(args: &Args) -> Result<()> {
    use amips::index::{BuildCtx, Catalog, IndexSpec};
    use amips::model::artifact as model_artifact;
    use amips::nn::{ModelKind, NetSpec};
    use amips::trainer::{self, TrainOpts};

    let kind = ModelKind::parse(args.get_or("model", "keynet"))?;
    let n = args.get_usize("n", 20_000)?;
    let d = args.get_usize("d", 32)?;
    let nq = args.get_usize("queries", 1_000)?;
    let c = args.get_usize("c", 1)?;
    let layers = args.get_usize("layers", 3)?;
    let rho = args.get_f32("rho", 0.01)? as f64;
    let seed = args.get_u64("seed", 42)?;

    let mut opts = TrainOpts {
        verbose: args.has("verbose"),
        seed: args.get_u64("train-seed", 7)?,
        ..TrainOpts::default()
    };
    opts.steps = args.get_usize("steps", opts.steps)?;
    opts.batch = args.get_usize("batch", opts.batch)?;
    opts.peak_lr = args.get_f32("lr", opts.peak_lr)?;
    opts.lam_a = args.get_f32("lam-a", opts.lam_a)?;
    opts.lam_b = args.get_f32("lam-b", opts.lam_b)?;
    opts.lam_icnn = args.get_f32("lam-icnn", opts.lam_icnn)?;

    let out_path = args.get("out").map(str::to_string);
    let catalog_dir = args.get("catalog").map(str::to_string);
    let coll_name = args.get("name").map(str::to_string);
    let index_spec = args.get("spec").map(str::to_string);
    let match_floor = args.get_f32("assert-match-floor", -1.0)?;
    let mut spec = NetSpec::sized(kind, d, c, n, rho, layers);
    spec.h = args.get_usize("h", spec.h)?;
    spec.nx = args.get_usize("nx", spec.nx)?;
    spec.residual = args.has("residual");
    args.reject_unknown()?;
    spec.validate()?;

    let label = format!("synth-{n}x{d}.{kind}.c{c}");
    let ds = fixtures::synth_dataset(n, d, nq, c, seed);
    let out = trainer::rust::train(&spec, &label, &ds, &opts)?;
    let (rm, e_rel) = trainer::validation_retrieval(&out.model, &ds)?;

    let mut rep = Report::new(&format!(
        "train {label} (h={}, layers={}, {} params)",
        spec.h,
        spec.layers,
        out.model.spec().n_params()
    ));
    rep.header(&["steps", "final loss", "match", "R@10", "E_rel", "E_rel curve"]);
    rep.row(&[
        out.steps.to_string(),
        out.curve
            .final_loss()
            .map(|v| f(v as f64))
            .unwrap_or_default(),
        pct(rm.match_rate),
        pct(rm.recall_at_10),
        f(e_rel),
        out.curve.e_rel_sparkline(),
    ]);

    if let Some(path) = &out_path {
        model_artifact::save(std::path::Path::new(path), &out.model)?;
        rep.note(format!("model artifact: {path}"));
    }
    match (&catalog_dir, &coll_name) {
        (Some(dir), Some(name)) => {
            anyhow::ensure!(
                c == 1,
                "only c=1 models can be attached as a collection mapper"
            );
            let ispec = match &index_spec {
                Some(s) => s.parse::<IndexSpec>()?,
                None => IndexSpec::default_for("ivf")?
                    .with_nlist(fixtures::default_nlist(ds.n_keys())),
            };
            let entry = Catalog::append_collection(
                dir,
                name,
                &ispec,
                &ds.keys,
                &BuildCtx {
                    sample_queries: Some(&ds.train.x),
                    seed,
                },
            )?;
            let mpath = Catalog::attach_mapper(dir, name, &out.model)?;
            rep.note(format!(
                "collection '{name}' [{}] built over the training keys; mapper: {}",
                entry.index.spec(),
                mpath.display()
            ));
            rep.note(format!(
                "serve mapped queries with: amips serve --catalog {dir} --collection {name}"
            ));
        }
        (None, None) => {}
        _ => bail!("--catalog and --name must be given together"),
    }
    rep.emit("train_rust");

    if match_floor >= 0.0 && rm.match_rate < match_floor as f64 {
        bail!(
            "top-1 match rate {:.4} below the asserted floor {match_floor}",
            rm.match_rate
        );
    }
    Ok(())
}

/// Evaluate a persisted pure-Rust model artifact against the (re)
/// generated synthetic corpus it was trained on.
fn cmd_eval_rust(args: &Args) -> Result<()> {
    use amips::model::{artifact as model_artifact, AmortizedModel};
    use amips::trainer;

    let path = args.require("model")?.to_string();
    let n = args.get_usize("n", 20_000)?;
    let d = args.get_usize("d", 32)?;
    let nq = args.get_usize("queries", 1_000)?;
    let c = args.get_usize("c", 1)?;
    let seed = args.get_u64("seed", 42)?;
    args.reject_unknown()?;

    let model = model_artifact::load(std::path::Path::new(&path))?;
    anyhow::ensure!(
        model.dim() == d && model.n_heads() == c,
        "model '{}' is d={} c={}, dataset flags say d={d} c={c}",
        model.label(),
        model.dim(),
        model.n_heads()
    );
    let ds = fixtures::synth_dataset(n, d, nq, c, seed);
    let (rm, e_rel) = trainer::validation_retrieval(&model, &ds)?;
    let mut rep = Report::new(&format!("eval {} ({})", model.label(), path));
    rep.header(&["match", "R@10", "R@100", "MRR", "E_rel"]);
    rep.row(&[
        pct(rm.match_rate),
        pct(rm.recall_at_10),
        pct(rm.recall_at_100),
        f(rm.mrr),
        f(e_rel),
    ]);
    rep.emit("eval_rust");
    Ok(())
}

/// Serve prebuilt collections straight from a catalog of artifacts —
/// the "serve many" half: no k-means/PQ training runs on startup.
/// Collections carrying a trained mapper serve mapped queries
/// (Sec. 4.4) as their default request mode.
fn cmd_serve_catalog(args: &Args) -> Result<()> {
    use amips::api::{Effort, SearchRequest};
    use amips::coordinator::{BatchPolicy, Server, ServerConfig};
    use amips::index::{Catalog, VectorIndex};
    use amips::tensor::{normalize_rows, Tensor};
    use amips::util::{Rng, Timer};
    use anyhow::ensure;

    let dir = args.require("catalog")?.to_string();
    let collection = args.get("collection").map(str::to_string);
    let requests = args.get_usize("requests", 256)?;
    let k = args.get_usize("k", 10)?;
    let nprobe = args.get_usize("nprobe", 4)?;
    let clients = args.get_usize("clients", 2)?.max(1);
    let seed = args.get_u64("seed", 7)?;
    args.reject_unknown()?;

    // resolve the collection name from the manifest alone, then load
    // exactly that artifact — startup cost scales with the served
    // index, not the whole catalog
    let collection = match collection {
        Some(c) => c,
        None => {
            let names = Catalog::names_on_disk(&dir)?;
            ensure!(
                !names.is_empty(),
                "catalog {dir} has no collections; create one with `amips build`"
            );
            ensure!(
                names.len() == 1,
                "catalog has {} collections ({}); pick one with --collection",
                names.len(),
                names.join(", ")
            );
            names.into_iter().next().unwrap()
        }
    };
    let timer = Timer::start();
    let entry = Catalog::open_collection(&dir, &collection)?;
    let load_s = timer.elapsed_s();
    let d = entry.index.dim();
    // a collection carrying a trained mapper serves mapped queries
    // (Sec. 4.4) as its default mode; bare collections stay Original
    let mut default_request = SearchRequest::top_k(k).effort(Effort::Probes(nprobe));
    let mapper_label = entry.mapper.as_ref().map(|m| {
        use amips::model::AmortizedModel;
        m.label().to_string()
    });
    let cfg = match &entry.mapper {
        Some(m) => {
            default_request = default_request.mode(amips::api::QueryMode::Mapped);
            ServerConfig::with_keynet((**m).clone(), BatchPolicy::default(), default_request)
        }
        None => ServerConfig::unmapped(BatchPolicy::default(), default_request),
    };
    let (server, handle) = Server::start(cfg, entry.index.clone())?;

    // closed-loop demo traffic: unit-norm gaussian queries
    let mut q = Tensor::zeros(&[requests.max(1), d]);
    Rng::new(seed).fill_normal(q.data_mut(), 1.0);
    normalize_rows(&mut q);
    let t0 = std::time::Instant::now();
    let mut served = 0usize;
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        for t in 0..clients {
            let handle = handle.clone();
            let q = &q;
            joins.push(s.spawn(move || -> usize {
                let mut ok = 0;
                for i in (t..requests).step_by(clients) {
                    if handle.search(q.row(i).to_vec()).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        for j in joins {
            served += j.join().unwrap();
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.latency_stats();
    drop(handle);
    server.shutdown()?;

    let mut rep = Report::new(&format!(
        "serve --catalog {dir} :: {collection} [{}]",
        entry.index.spec()
    ));
    rep.header(&["keys", "d", "requests", "qps", "p50 ms", "p95 ms", "load s"]);
    rep.row(&[
        entry.index.len().to_string(),
        d.to_string(),
        format!("{served}/{requests}"),
        format!("{:.0}", requests as f64 / wall.max(1e-9)),
        format!("{:.2}", stats.quantile_s(0.5) * 1e3),
        format!("{:.2}", stats.quantile_s(0.95) * 1e3),
        format!("{load_s:.2}"),
    ]);
    rep.note("no k-means/PQ training ran on startup: the index was deserialized from its artifact");
    if let Some(label) = mapper_label {
        rep.note(format!(
            "queries were mapped through the trained model '{label}' (QueryMode::Mapped)"
        ));
    }
    rep.emit("serve_catalog");
    Ok(())
}

/// Serve a whole catalog over TCP: every collection becomes a tenant of
/// one `NetServer` speaking the AMTP framed protocol (deadline-aware
/// batching, bounded-queue admission, typed errors). Collections with
/// an attached mapper serve `mode=mapped` traffic.
fn cmd_serve_listen(args: &Args) -> Result<()> {
    use amips::coordinator::net::{NetServer, NetServerConfig};
    use amips::coordinator::BatchPolicy;
    use amips::index::Catalog;
    use std::time::Duration;

    let dir = args.require("catalog")?.to_string();
    let listen = args.require("listen")?.to_string();
    let port_file = args.get("port-file").map(str::to_string);
    let serve_seconds = args.get_u64("serve-seconds", 0)?;
    let queue_cap = args.get_usize("queue-cap", 1024)?;
    let max_conns = args.get_usize("max-conns", 256)?;
    let max_batch = args.get_usize("batch-max", 256)?;
    let batch_wait_ms = args.get_u64("batch-wait-ms", 2)?;
    let max_inflight = args.get_usize("max-inflight", 32)?;
    // 0 = metrics listener disabled; any other port binds a second,
    // write-only plain-text listener on the same interface
    let metrics_port = args.get_u64("metrics-port", 0)?;
    args.reject_unknown()?;

    let catalog = Catalog::open(&dir)?;
    let metrics_addr = if metrics_port > 0 {
        use std::net::ToSocketAddrs as _;
        let host = listen.rsplit_once(':').map(|(h, _)| h).unwrap_or("127.0.0.1");
        let spec = format!("{host}:{metrics_port}");
        Some(
            spec.to_socket_addrs()
                .map_err(|e| anyhow::anyhow!("bad --metrics-port ({spec}): {e}"))?
                .next()
                .ok_or_else(|| anyhow::anyhow!("--metrics-port resolved to no address"))?,
        )
    } else {
        None
    };
    let cfg = NetServerConfig {
        policy: BatchPolicy {
            max_batch: max_batch.max(1),
            max_wait: Duration::from_millis(batch_wait_ms),
        },
        queue_cap: queue_cap.max(1),
        max_connections: max_conns.max(1),
        max_inflight: max_inflight.max(1),
        metrics_addr,
        ..NetServerConfig::default()
    };
    let server = NetServer::serve_catalog(&catalog, listen.as_str(), cfg)?;
    let addr = server.local_addr();
    // announce the resolved address first (":0" binds an ephemeral
    // port); scripts either parse this line or read --port-file
    println!("amips serve: listening on {addr}");
    if let Some(m) = server.metrics_addr() {
        println!("amips serve: metrics on {m}");
    }
    let names: Vec<&str> = catalog.names();
    println!("amips serve: collections: {}", names.join(", "));
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    if let Some(pf) = &port_file {
        std::fs::write(pf, format!("{addr}\n"))?;
    }
    if serve_seconds > 0 {
        std::thread::sleep(Duration::from_secs(serve_seconds));
    } else {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    let stats = server.stats();
    server.shutdown();
    let mut rep = Report::new(&format!("serve --listen {addr} ({} collections)", names.len()));
    rep.header(&[
        "served", "errors", "overload", "expired", "p50 ms", "p99 ms", "p999 ms",
    ]);
    rep.row(&[
        stats.served.to_string(),
        stats.errors.to_string(),
        stats.overloaded.to_string(),
        stats.expired.to_string(),
        format!("{:.2}", stats.p50_s * 1e3),
        format!("{:.2}", stats.p99_s * 1e3),
        format!("{:.2}", stats.p999_s * 1e3),
    ]);
    for c in &stats.collections {
        rep.note(format!(
            "{}: served={} errors={} overloaded={} expired={}",
            c.name, c.served, c.errors, c.overloaded, c.expired
        ));
    }
    rep.note("graceful shutdown: queues drained, listeners closed");
    rep.emit("serve_listen");
    Ok(())
}

/// Probe a running `amips serve --listen` server: liveness (ping),
/// stats, and three malformed-frame robustness checks — each must get
/// a *typed* error reply (never a hang or a dropped byte stream), and
/// the server must keep serving healthy clients afterwards.
fn cmd_probe(args: &Args) -> Result<()> {
    use amips::coordinator::net::wire::{self, ErrorCode};
    use amips::coordinator::net::{NetClient, NetError};
    use anyhow::ensure;
    use std::time::Duration;

    let addr = args.require("addr")?.to_string();
    let metrics = args.get("metrics").map(str::to_string);
    args.reject_unknown()?;
    let timeout = Some(Duration::from_secs(5));

    // 1. liveness. A draining server is not *down* — report the drain
    // window distinctly (the typed retryable reply) instead of failing
    // the probe like a dead or misbehaving endpoint.
    let mut client = NetClient::connect(addr.as_str())?;
    client.set_timeout(timeout)?;
    match client.ping() {
        Ok(()) => {}
        Err(NetError::Draining(e)) => {
            let mut rep = Report::new(&format!("probe {addr}"));
            rep.header(&["check", "typed reply"]);
            rep.row(&["ping".into(), format!("draining ({})", e.code)]);
            rep.note("server is shutting down (retryable); re-probe after the restart completes");
            rep.emit("probe");
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    }
    let stats = client.stats()?;

    // 2. malformed-frame probes: each opens a fresh connection (a
    // decode error rightly desyncs + closes the stream) and expects a
    // typed Error frame back
    let mut checks: Vec<(&str, ErrorCode)> = Vec::new();
    {
        // garbage magic
        let mut c = NetClient::connect(addr.as_str())?;
        c.set_timeout(timeout)?;
        let reply = c.send_raw(b"NOPE\x01\x04\x00\x00\x00\x00")?;
        match reply {
            wire::Frame::Error(e) => checks.push(("bad magic", e.code)),
            other => anyhow::bail!("bad-magic probe got non-error reply {other:?}"),
        }
    }
    {
        // oversized declared payload length
        let mut c = NetClient::connect(addr.as_str())?;
        c.set_timeout(timeout)?;
        let mut frame = Vec::new();
        frame.extend_from_slice(&wire::MAGIC);
        frame.push(wire::VERSION);
        frame.push(1); // search tag
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        match c.send_raw(&frame)? {
            wire::Frame::Error(e) => checks.push(("oversized length", e.code)),
            other => anyhow::bail!("oversized-length probe got non-error reply {other:?}"),
        }
    }
    {
        // unknown frame tag
        let mut c = NetClient::connect(addr.as_str())?;
        c.set_timeout(timeout)?;
        let mut frame = Vec::new();
        frame.extend_from_slice(&wire::MAGIC);
        frame.push(wire::VERSION);
        frame.push(200);
        frame.extend_from_slice(&0u32.to_le_bytes());
        match c.send_raw(&frame)? {
            wire::Frame::Error(e) => {
                ensure!(
                    e.code == ErrorCode::Unsupported,
                    "unknown tag should be Unsupported, got {}",
                    e.code
                );
                checks.push(("unknown tag", e.code));
            }
            other => anyhow::bail!("unknown-tag probe got non-error reply {other:?}"),
        }
    }

    // 3. the metrics side-listener, when asked: it must serve a
    // non-empty snapshot even to a client that sends garbage first
    // (the listener never reads, so hostile bytes are structurally
    // inert)
    let metrics_lines = match &metrics {
        Some(maddr) => {
            use std::io::{Read as _, Write as _};
            let mut s = std::net::TcpStream::connect(maddr.as_str())?;
            s.set_read_timeout(timeout)?;
            s.set_write_timeout(timeout)?;
            let _ = s.write_all(b"GET / HTTP/1.1\r\n\x00\xff not a scrape \r\n\r\n");
            let mut body = String::new();
            s.read_to_string(&mut body)?;
            ensure!(
                body.contains("amips_build_info"),
                "metrics scrape missing build info: {body:?}"
            );
            Some(body.lines().count())
        }
        None => None,
    };

    // 4. the server survived every probe
    client.ping().map_err(|e| match e {
        NetError::Wire(w) => anyhow::anyhow!("server unhealthy after probes: {w}"),
        other => anyhow::anyhow!("server unhealthy after probes: {other}"),
    })?;

    let mut rep = Report::new(&format!("probe {addr}"));
    rep.header(&["check", "typed reply"]);
    rep.row(&["ping".into(), format!("pong (wire v{})", client.version())]);
    for (name, code) in &checks {
        rep.row(&[name.to_string(), code.to_string()]);
    }
    if let Some(n) = metrics_lines {
        rep.row(&["metrics scrape".into(), format!("{n} lines")]);
    }
    rep.row(&["ping after probes".into(), "pong".into()]);
    rep.note(format!(
        "server stats: served={} errors={} overloaded={} expired={} queue_depth={} p99={:.2}ms",
        stats.served,
        stats.errors,
        stats.overloaded,
        stats.expired,
        stats.queue_depth,
        stats.p99_s * 1e3
    ));
    rep.emit("probe");
    Ok(())
}

// ---------------------------------------------------------------------------
// PJRT-backed commands (training, evaluation, routing, serving)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
mod xla_cmds {
    use super::*;
    use amips::api::{Effort, QueryMode, SearchRequest};
    use amips::coordinator::router::{routing_accuracy, AmortizedRouter, CentroidRouter, Router};
    use amips::coordinator::{BatchPolicy, Server, ServerConfig};
    use amips::index::ivf::IvfIndex;
    use amips::metrics::{flops, retrieval, transport};
    use amips::runtime::Engine;
    use amips::tensor::Tensor;
    use amips::trainer::{self, TrainOpts};
    use std::sync::Arc;

    fn train_opts_from(args: &Args) -> Result<TrainOpts> {
        let mut o = TrainOpts {
            verbose: args.has("verbose"),
            ..TrainOpts::default()
        };
        o.steps = args.get_usize("steps", o.steps)?;
        o.peak_lr = args.get_f32("lr", o.peak_lr)?;
        o.lam_a = args.get_f32("lam-a", o.lam_a)?;
        o.lam_b = args.get_f32("lam-b", o.lam_b)?;
        o.seed = args.get_u64("seed", o.seed)?;
        Ok(o)
    }

    pub fn cmd_train(args: &Args) -> Result<()> {
        let m = fixtures::load_manifest()?;
        let config = args.require("config")?.to_string();
        let opts = train_opts_from(args)?;
        args.reject_unknown()?;
        let meta = m.meta(&config)?;
        let engine = Engine::new(m.dir.clone())?;
        let ds = fixtures::prepare_dataset(&m, &meta.dataset, meta.c)?;
        let out = trainer::train(&engine, &meta, &ds, &opts)?;
        let path = trainer::trainer::checkpoint_path(engine.dir(), &meta, &opts);
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        out.params.save(&meta, &path)?;
        let mut rep = Report::new(&format!("train {config}"));
        rep.header(&["steps", "final loss", "final E_rel", "E_rel curve"]);
        rep.row(&[
            out.steps.to_string(),
            out.curve.final_loss().map(|v| f(v as f64)).unwrap_or_default(),
            out.curve.final_e_rel().map(|v| f(v as f64)).unwrap_or_default(),
            out.curve.e_rel_sparkline(),
        ]);
        rep.note(format!("checkpoint: {}", path.display()));
        rep.emit("train");
        Ok(())
    }

    pub fn cmd_eval(args: &Args) -> Result<()> {
        let m = fixtures::load_manifest()?;
        let config = args.require("config")?.to_string();
        let steps = args.get_usize("steps", 0)?;
        args.reject_unknown()?;
        let meta = m.meta(&config)?;
        let engine = Engine::new(m.dir.clone())?;
        let ds = fixtures::prepare_dataset(&m, &meta.dataset, meta.c)?;
        let opts = (steps > 0).then(|| TrainOpts {
            steps,
            ..TrainOpts::default()
        });
        let model = fixtures::trained_model(&engine, &m, &config, &ds, opts)?;
        // predicted keys on the validation queries
        let (_scores, keys) = model.scores_and_keys(&ds.val.x)?;
        let n = ds.val.x.rows();
        let d = ds.d();
        // global top-key predictions: for c>1 take the best-scoring cluster's key
        let mut pred = Tensor::zeros(&[n, d]);
        let mut targets = Vec::with_capacity(n);
        for q in 0..n {
            let j = ds.val.gt.top_cluster(q); // evaluate the true-cluster head
            let off = (q * meta.c + j) * d;
            pred.row_mut(q).copy_from_slice(&keys.data()[off..off + d]);
            targets.push(ds.val.gt.global_top1(q).0);
        }
        let rm = retrieval::evaluate(&pred, &ds.keys, &targets);
        let tgt = ds.keys.gather_rows(&targets);
        let e_rel = transport::relative_transport_error(&pred, &ds.val.x, &tgt);
        let mut rep = Report::new(&format!("eval {config}"));
        rep.header(&["match", "R@10", "R@100", "MRR", "E_rel"]);
        rep.row(&[
            pct(rm.match_rate),
            pct(rm.recall_at_10),
            pct(rm.recall_at_100),
            f(rm.mrr),
            f(e_rel),
        ]);
        rep.emit("eval");
        Ok(())
    }

    pub fn cmd_route(args: &Args) -> Result<()> {
        use amips::api::{RoutedSearcher, Searcher};

        let m = fixtures::load_manifest()?;
        let config = args.require("config")?.to_string();
        let topk_max = args.get_usize("topk", 5)?;
        args.reject_unknown()?;
        let meta = m.meta(&config)?;
        if meta.c < 2 {
            bail!("routing needs a clustered config (c>1), got c={}", meta.c);
        }
        let engine = Engine::new(m.dir.clone())?;
        let ds = fixtures::prepare_dataset(&m, &meta.dataset, meta.c)?;
        let model = fixtures::trained_model(&engine, &m, &config, &ds, None)?;
        let learned = AmortizedRouter::new(model);
        let baseline = CentroidRouter::new(ds.centroids.clone());
        let true_clusters: Vec<usize> = (0..ds.val.gt.n_queries())
            .map(|q| ds.val.gt.top_cluster(q))
            .collect();
        let truth: Vec<usize> = (0..ds.val.gt.n_queries())
            .map(|q| ds.val.gt.global_top1(q).0)
            .collect();
        let mut sizes = vec![0usize; ds.c];
        for &a in &ds.assign {
            sizes[a as usize] += 1;
        }
        // routed end-to-end search shares the dataset clustering
        let ivf = IvfIndex::from_clustering(&ds.keys, ds.centroids.clone(), &ds.assign);
        let mut rep = Report::new(&format!("routing {config} vs centroid"));
        rep.header(&["router", "k", "accuracy", "R@10 routed", "flops/query"]);
        for k in 1..=topk_max.min(ds.c) {
            for router in [&learned as &dyn Router, &baseline as &dyn Router] {
                let dec = router.route_batch(&ds.val.x, k)?;
                let acc = routing_accuracy(&dec, &true_clusters);
                // average scan cost of the selected clusters
                let avg_scan: f64 = dec
                    .iter()
                    .map(|dd| {
                        let picked: Vec<usize> =
                            dd.clusters.iter().map(|&c| sizes[c as usize]).collect();
                        flops::routing_total_flops(dd.selection_flops, &picked, ds.d()) as f64
                    })
                    .sum::<f64>()
                    / dec.len() as f64;
                // the same router as an end-to-end Searcher
                let routed = RoutedSearcher::new(router, &ivf)?;
                let resp = routed.search(
                    &ds.val.x,
                    &SearchRequest::top_k(10)
                        .effort(Effort::Probes(k))
                        .mode(QueryMode::Routed),
                )?;
                let recall = amips::api::recall_against_truth(&resp.hits, &truth, 10);
                rep.row(&[
                    router.name().to_string(),
                    k.to_string(),
                    pct(acc),
                    pct(recall),
                    format!("{avg_scan:.0}"),
                ]);
            }
        }
        rep.emit("route");
        Ok(())
    }

    pub fn cmd_serve(args: &Args) -> Result<()> {
        let m = fixtures::load_manifest()?;
        let config = args.require("config")?.to_string();
        let requests = args.get_usize("requests", 512)?;
        let nprobe = args.get_usize("nprobe", 4)?;
        let nlist = args.get_usize("nlist", 32)?;
        args.reject_unknown()?;
        let meta = m.meta(&config)?;
        if meta.c != 1 {
            bail!("serve uses a c=1 KeyNet mapper");
        }
        let engine = Engine::new(m.dir.clone())?;
        let ds = fixtures::prepare_dataset(&m, &meta.dataset, 1)?;
        // train (or load) the mapper, then hand everything to the server
        let opts = TrainOpts {
            steps: fixtures::default_steps(&meta.size),
            ..TrainOpts::default()
        };
        let out = trainer::train_or_load(&engine, &meta, &ds, &opts)?;
        drop(engine); // the server builds its own engine on the runner thread
        let index = Arc::new(IvfIndex::build(&ds.keys, nlist, 15, 99));
        let default_request = SearchRequest::top_k(10)
            .effort(Effort::Probes(nprobe))
            .mode(QueryMode::Mapped);
        let cfg = ServerConfig::with_model(
            m.dir.clone(),
            meta,
            out.params,
            BatchPolicy::default(),
            default_request,
        );
        let (server, handle) = Server::start(cfg, index)?;
        // fire traffic from a couple of client threads
        let nq = ds.val.x.rows();
        let t0 = std::time::Instant::now();
        let mut hits = 0usize;
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for t in 0..2usize {
                let handle = handle.clone();
                let ds = &ds;
                joins.push(s.spawn(move || -> Result<usize> {
                    let mut local_hits = 0;
                    for i in (t..requests).step_by(2) {
                        let q = ds.val.x.row(i % nq).to_vec();
                        let resp = handle.search(q)?;
                        let truth = ds.val.gt.global_top1(i % nq).0 as u32;
                        if resp.hits.ids.contains(&truth) {
                            local_hits += 1;
                        }
                    }
                    Ok(local_hits)
                }));
            }
            for j in joins {
                hits += j.join().unwrap().unwrap_or(0);
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let stats = server.latency_stats();
        drop(handle);
        server.shutdown()?;
        let mut rep = Report::new(&format!(
            "serve {config} (IVF nlist={nlist}, nprobe={nprobe})"
        ));
        rep.header(&["requests", "recall@10", "qps", "p50 ms", "p95 ms"]);
        rep.row(&[
            requests.to_string(),
            pct(hits as f64 / requests as f64),
            format!("{:.0}", requests as f64 / wall),
            format!("{:.2}", stats.quantile_s(0.5) * 1e3),
            format!("{:.2}", stats.quantile_s(0.95) * 1e3),
        ]);
        rep.emit("serve");
        Ok(())
    }
}

#[cfg(not(feature = "xla"))]
mod xla_cmds {
    use super::*;

    fn needs_xla(what: &str) -> Result<()> {
        bail!(
            "`amips {what}` with --config drives the AOT artifacts through PJRT \
             and needs the `xla` feature: rebuild with `cargo build --release \
             --features xla` (see README.md). The pure-Rust backend covers \
             train | eval | serve --catalog without any feature flags."
        )
    }

    pub fn cmd_train(_args: &Args) -> Result<()> {
        needs_xla("train")
    }

    pub fn cmd_eval(_args: &Args) -> Result<()> {
        needs_xla("eval")
    }

    pub fn cmd_route(_args: &Args) -> Result<()> {
        needs_xla("route")
    }

    pub fn cmd_serve(_args: &Args) -> Result<()> {
        needs_xla("serve")
    }
}
