//! `amips` — leader binary: dataset prep, training, evaluation, routing
//! and a serving demo over the AOT artifacts.
//!
//! ```text
//! amips list                                  # configs + datasets
//! amips gen-data  --dataset nq-s [--c 10]     # prepare + report a dataset
//! amips train     --config <name> [--steps N] [--lr F] [--verbose]
//! amips eval      --config <name> [--steps N] # retrieval metrics on val
//! amips route     --dataset nq-s --config <name> [--topk 1..5]
//! amips serve     --config <name> [--requests N] [--nprobe K]
//! ```

use amips::cli::Args;
use amips::coordinator::router::{routing_accuracy, AmortizedRouter, CentroidRouter, Router};
use amips::coordinator::{BatchPolicy, Server, ServerConfig};
use amips::bench_support::fixtures;
use amips::bench_support::report::{f, pct, Report};
use amips::index::ivf::IvfIndex;
use amips::metrics::{flops, retrieval, transport};
use amips::runtime::Engine;
use amips::tensor::Tensor;
use amips::trainer::{self, TrainOpts};
use anyhow::{bail, Result};
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("list") => cmd_list(),
        Some("gen-data") => cmd_gen_data(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("route") => cmd_route(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => bail!("unknown command {other}; try `amips list`"),
        None => {
            println!("amips {} — amortized MIPS coordinator", amips::version());
            println!("commands: list | gen-data | train | eval | route | serve");
            Ok(())
        }
    }
}

fn cmd_list() -> Result<()> {
    let m = fixtures::load_manifest()?;
    println!("datasets:");
    for d in &m.datasets {
        println!(
            "  {:12} n={:<7} d={:<4} queries={:<5} shift={}",
            d.name, d.n, d.d, d.n_queries, d.shift
        );
    }
    println!("configs ({}):", m.configs.len());
    for c in &m.configs {
        println!("  {c}");
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let m = fixtures::load_manifest()?;
    let name = args.require("dataset")?.to_string();
    let c = args.get_usize("c", 1)?;
    args.reject_unknown()?;
    let ds = fixtures::prepare_dataset(&m, &name, c)?;
    let mut rep = Report::new(&format!("dataset {name} (c={c})"));
    rep.header(&["keys", "d", "train-q", "val-q", "mean top-1 <q,k*>"]);
    let mean_top1: f64 = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.global_top1(q).1 as f64)
        .sum::<f64>()
        / ds.val.gt.n_queries() as f64;
    rep.row(&[
        ds.n_keys().to_string(),
        ds.d().to_string(),
        ds.train.x.rows().to_string(),
        ds.val.x.rows().to_string(),
        f(mean_top1),
    ]);
    if c > 1 {
        let sizes: Vec<String> = {
            let mut s = vec![0usize; c];
            for &a in &ds.assign {
                s[a as usize] += 1;
            }
            s.iter().map(|v| v.to_string()).collect()
        };
        rep.note(format!("cluster sizes: {}", sizes.join(", ")));
    }
    rep.emit("gen_data");
    Ok(())
}

fn train_opts_from(args: &Args) -> Result<TrainOpts> {
    let mut o = TrainOpts {
        verbose: args.has("verbose"),
        ..TrainOpts::default()
    };
    o.steps = args.get_usize("steps", o.steps)?;
    o.peak_lr = args.get_f32("lr", o.peak_lr)?;
    o.lam_a = args.get_f32("lam-a", o.lam_a)?;
    o.lam_b = args.get_f32("lam-b", o.lam_b)?;
    o.seed = args.get_u64("seed", o.seed)?;
    Ok(o)
}

fn cmd_train(args: &Args) -> Result<()> {
    let m = fixtures::load_manifest()?;
    let config = args.require("config")?.to_string();
    let opts = train_opts_from(args)?;
    args.reject_unknown()?;
    let meta = m.meta(&config)?;
    let engine = Engine::new(artifacts_dir_of(&m))?;
    let ds = fixtures::prepare_dataset(&m, &meta.dataset, meta.c)?;
    let out = trainer::train(&engine, &meta, &ds, &opts)?;
    let path = trainer::trainer::checkpoint_path(engine.dir(), &meta, &opts);
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    out.params.save(&meta, &path)?;
    let mut rep = Report::new(&format!("train {config}"));
    rep.header(&["steps", "final loss", "final E_rel", "E_rel curve"]);
    rep.row(&[
        out.steps.to_string(),
        out.curve.final_loss().map(|v| f(v as f64)).unwrap_or_default(),
        out.curve.final_e_rel().map(|v| f(v as f64)).unwrap_or_default(),
        out.curve.e_rel_sparkline(),
    ]);
    rep.note(format!("checkpoint: {}", path.display()));
    rep.emit("train");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let m = fixtures::load_manifest()?;
    let config = args.require("config")?.to_string();
    let steps = args.get_usize("steps", 0)?;
    args.reject_unknown()?;
    let meta = m.meta(&config)?;
    let engine = Engine::new(m.dir.clone())?;
    let ds = fixtures::prepare_dataset(&m, &meta.dataset, meta.c)?;
    let opts = if steps > 0 {
        Some(TrainOpts {
            steps,
            ..TrainOpts::default()
        })
    } else {
        None
    };
    let model = fixtures::trained_model(&engine, &m, &config, &ds, opts)?;
    // predicted keys on the validation queries
    let (_scores, keys) = model.scores_and_keys(&ds.val.x)?;
    let n = ds.val.x.rows();
    let d = ds.d();
    // global top-key predictions: for c>1 take the best-scoring cluster's key
    let mut pred = Tensor::zeros(&[n, d]);
    let mut targets = Vec::with_capacity(n);
    for q in 0..n {
        let j = ds.val.gt.top_cluster(q); // evaluate the true-cluster head
        let off = (q * meta.c + j) * d;
        pred.row_mut(q).copy_from_slice(&keys.data()[off..off + d]);
        targets.push(ds.val.gt.global_top1(q).0);
    }
    let rm = retrieval::evaluate(&pred, &ds.keys, &targets);
    let tgt = ds.keys.gather_rows(&targets);
    let e_rel = transport::relative_transport_error(&pred, &ds.val.x, &tgt);
    let mut rep = Report::new(&format!("eval {config}"));
    rep.header(&["match", "R@10", "R@100", "MRR", "E_rel"]);
    rep.row(&[
        pct(rm.match_rate),
        pct(rm.recall_at_10),
        pct(rm.recall_at_100),
        f(rm.mrr),
        f(e_rel),
    ]);
    rep.emit("eval");
    Ok(())
}

fn cmd_route(args: &Args) -> Result<()> {
    let m = fixtures::load_manifest()?;
    let config = args.require("config")?.to_string();
    let topk_max = args.get_usize("topk", 5)?;
    args.reject_unknown()?;
    let meta = m.meta(&config)?;
    if meta.c < 2 {
        bail!("routing needs a clustered config (c>1), got c={}", meta.c);
    }
    let engine = Engine::new(m.dir.clone())?;
    let ds = fixtures::prepare_dataset(&m, &meta.dataset, meta.c)?;
    let model = fixtures::trained_model(&engine, &m, &config, &ds, None)?;
    let learned = AmortizedRouter::new(model);
    let baseline = CentroidRouter::new(ds.centroids.clone());
    let true_clusters: Vec<usize> = (0..ds.val.gt.n_queries())
        .map(|q| ds.val.gt.top_cluster(q))
        .collect();
    let mut sizes = vec![0usize; ds.c];
    for &a in &ds.assign {
        sizes[a as usize] += 1;
    }
    let mut rep = Report::new(&format!("routing {config} vs centroid"));
    rep.header(&["router", "k", "accuracy", "flops/query"]);
    for k in 1..=topk_max.min(ds.c) {
        for router in [&learned as &dyn Router, &baseline as &dyn Router] {
            let dec = router.route_batch(&ds.val.x, k)?;
            let acc = routing_accuracy(&dec, &true_clusters);
            // average scan cost of the selected clusters
            let avg_scan: f64 = dec
                .iter()
                .map(|dd| {
                    let picked: Vec<usize> =
                        dd.clusters.iter().map(|&c| sizes[c as usize]).collect();
                    flops::routing_total_flops(dd.selection_flops, &picked, ds.d()) as f64
                })
                .sum::<f64>()
                / dec.len() as f64;
            rep.row(&[
                router.name().to_string(),
                k.to_string(),
                pct(acc),
                format!("{avg_scan:.0}"),
            ]);
        }
    }
    rep.emit("route");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let m = fixtures::load_manifest()?;
    let config = args.require("config")?.to_string();
    let requests = args.get_usize("requests", 512)?;
    let nprobe = args.get_usize("nprobe", 4)?;
    let nlist = args.get_usize("nlist", 32)?;
    args.reject_unknown()?;
    let meta = m.meta(&config)?;
    if meta.c != 1 {
        bail!("serve uses a c=1 KeyNet mapper");
    }
    let engine = Engine::new(m.dir.clone())?;
    let ds = fixtures::prepare_dataset(&m, &meta.dataset, 1)?;
    // train (or load) the mapper, then hand everything to the server
    let opts = TrainOpts {
        steps: fixtures::default_steps(&meta.size),
        ..TrainOpts::default()
    };
    let out = trainer::train_or_load(&engine, &meta, &ds, &opts)?;
    let index = Arc::new(IvfIndex::build(&ds.keys, nlist, 15, 99));
    let cfg = ServerConfig {
        artifacts_dir: m.dir.clone(),
        meta: meta.clone(),
        params: out.params,
        policy: BatchPolicy::default(),
        map_queries: true,
        nprobe_default: nprobe,
    };
    let (server, handle) = Server::start(cfg, index)?;
    // fire traffic from a couple of client threads
    let nq = ds.val.x.rows();
    let t0 = std::time::Instant::now();
    let mut hits = 0usize;
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        for t in 0..2usize {
            let handle = handle.clone();
            let ds = &ds;
            joins.push(s.spawn(move || -> Result<usize> {
                let mut local_hits = 0;
                for i in (t..requests).step_by(2) {
                    let q = ds.val.x.row(i % nq).to_vec();
                    let resp = handle.query(q, 10)?;
                    let truth = ds.val.gt.global_top1(i % nq).0 as u32;
                    if resp.ids.contains(&truth) {
                        local_hits += 1;
                    }
                }
                Ok(local_hits)
            }));
        }
        for j in joins {
            hits += j.join().unwrap().unwrap_or(0);
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.latency_stats();
    server.shutdown()?;
    let mut rep = Report::new(&format!("serve {config} (IVF nlist={nlist}, nprobe={nprobe})"));
    rep.header(&["requests", "recall@10", "qps", "p50 ms", "p95 ms"]);
    rep.row(&[
        requests.to_string(),
        pct(hits as f64 / requests as f64),
        format!("{:.0}", requests as f64 / wall),
        format!("{:.2}", stats.quantile_s(0.5) * 1e3),
        format!("{:.2}", stats.quantile_s(0.95) * 1e3),
    ]);
    rep.emit("serve");
    Ok(())
}

/// artifacts dir helper shared with Engine::new call sites.
fn artifacts_dir_of(m: &amips::runtime::Manifest) -> std::path::PathBuf {
    m.dir.clone()
}
