//! Exhaustive (flat) MIPS index: the exact baseline every approximate
//! backbone is measured against, and the "exact search within selected
//! clusters" stage of the routing experiments (Sec. 4.3).
//!
//! Keys live in a [`KeyStore`] — full f32 rows by default, compact
//! binary16 rows with `flat(storage=f16)` — and every score goes
//! through the dispatched kernels, so per-query and batched results
//! stay bit-identical to each other for either storage.

use anyhow::Result;

use crate::api::Effort;
use crate::index::artifact::{self, Src};
use crate::index::keystore::{KeyStore, Storage};
use crate::index::spec::{FlatSpec, IndexSpec};
use crate::index::traits::{SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::Tensor;

/// Brute-force scan over all keys.
pub struct FlatIndex {
    keys: KeyStore, // [n, d]
}

impl FlatIndex {
    pub fn new(keys: Tensor) -> Self {
        FlatIndex {
            keys: KeyStore::F32(keys),
        }
    }

    /// Build with an explicit key-storage precision (the
    /// `flat(storage=...)` spec knob).
    pub fn with_storage(keys: Tensor, storage: Storage) -> Self {
        FlatIndex {
            keys: KeyStore::new(keys, storage),
        }
    }

    /// The f32 key matrix. Panics under `storage=f16` — callers that
    /// must work for any storage go through [`FlatIndex::store`] (every
    /// in-repo caller constructs via [`FlatIndex::new`], which is
    /// always f32).
    pub fn keys(&self) -> &Tensor {
        self.keys.as_f32()
    }

    /// The key store itself (any storage).
    pub fn store(&self) -> &KeyStore {
        &self.keys
    }

    pub fn d(&self) -> usize {
        self.keys.dim()
    }

    /// Deserialize from an artifact payload (see
    /// [`crate::index::artifact`]). Version-1 payloads are a bare f32
    /// tensor; version-2+ payloads carry a storage-tagged [`KeyStore`]
    /// (aligned, and zero-copy from a mapping, at version 3).
    pub(crate) fn read_payload(src: &mut Src, version: u32) -> Result<FlatIndex> {
        let keys = if version < 2 {
            KeyStore::F32(artifact::r_tensor(&mut *src)?)
        } else {
            KeyStore::read_payload(src, version)?
        };
        keys.advise_sequential();
        Ok(FlatIndex { keys })
    }

    /// Exact top-k over an explicit subset of key ids (cluster scan).
    pub fn search_subset(&self, query: &[f32], ids: &[u32], k: usize) -> SearchResult {
        let d = self.d();
        let mut top = TopK::new(k);
        for &id in ids {
            top.offer(self.keys.score(query, id as usize), id);
        }
        let (ids_out, scores) = top.into_sorted();
        SearchResult {
            ids: ids_out,
            scores,
            cost: SearchCost {
                flops: (ids.len() * d * 2) as u64,
                keys_scanned: ids.len() as u64,
                cells_probed: 0,
            },
        }
    }

    /// The exhaustive scan itself; effort has nothing to modulate here.
    fn scan_all(&self, query: &[f32], k: usize) -> SearchResult {
        let n = self.len();
        let d = self.d();
        let mut top = TopK::new(k);
        for id in 0..n {
            top.offer(self.keys.score(query, id), id as u32);
        }
        let (ids, scores) = top.into_sorted();
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops: (n * d * 2) as u64,
                keys_scanned: n as u64,
                cells_probed: 0,
            },
        }
    }
}

impl VectorIndex for FlatIndex {
    fn name(&self) -> &str {
        "flat"
    }

    fn len(&self) -> usize {
        self.keys.len()
    }

    fn dim(&self) -> usize {
        self.d()
    }

    fn search_effort(&self, query: &[f32], k: usize, _effort: Effort) -> SearchResult {
        self.scan_all(query, k)
    }

    /// Fused batched scan: score query-tiles × key-tiles through
    /// [`KeyStore::scan_tile`], so each key tile is streamed from memory
    /// once per *batch* instead of once per query, then feed per-query
    /// [`TopK`]s through the SIMD-prefiltered [`TopK::offer_block`].
    /// Same dispatched kernel per (query, key) pair as
    /// [`FlatIndex::search_effort`] and a selection that is independent
    /// of push order, so results and costs are bit-identical.
    fn search_batch_effort(
        &self,
        queries: &Tensor,
        k: usize,
        _effort: Effort,
    ) -> Vec<SearchResult> {
        let b = queries.rows();
        if b == 0 {
            return Vec::new();
        }
        let (n, d) = (self.len(), self.d());
        assert_eq!(queries.row_width(), d, "query dim != index dim {d}");
        let mut tops: Vec<TopK> = (0..b).map(|_| TopK::new(k)).collect();
        // 128 keys * 64 dims * 4 B = 32 KB per key tile: L1/L2 resident
        // while every query in the sub-batch scores against it.
        const KEY_TILE: usize = 128;
        let mut scores = vec![0.0f32; b * KEY_TILE];
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + KEY_TILE).min(n);
            let w = j1 - j0;
            self.keys
                .scan_tile(queries.data(), b, j0, j1, &mut scores[..b * w]);
            for (q, top) in tops.iter_mut().enumerate() {
                top.offer_block(&scores[q * w..(q + 1) * w], j0 as u32);
            }
            j0 = j1;
        }
        let cost = SearchCost {
            flops: (n * d * 2) as u64,
            keys_scanned: n as u64,
            cells_probed: 0,
        };
        tops.into_iter()
            .map(|t| {
                let (ids, scores) = t.into_sorted();
                SearchResult { ids, scores, cost }
            })
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Flat(FlatSpec {
            storage: self.keys.storage(),
        })
    }

    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        self.keys.write_payload(w)
    }

    fn zero_copy(&self) -> bool {
        self.keys.is_view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;
    use crate::util::Rng;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn finds_exact_top1() {
        let keys = randt(&[200, 16], 1);
        let idx = FlatIndex::new(keys.clone());
        let q = randt(&[1, 16], 2);
        let res = idx.search_effort(q.row(0), 1, Effort::Exhaustive);
        let mut best = (0usize, f32::NEG_INFINITY);
        for i in 0..200 {
            let s = dot(q.row(0), keys.row(i));
            if s > best.1 {
                best = (i, s);
            }
        }
        assert_eq!(res.ids[0] as usize, best.0);
        assert!((res.scores[0] - best.1).abs() < 1e-5);
        assert_eq!(res.cost.keys_scanned, 200);
    }

    #[test]
    fn topk_sorted_descending() {
        let keys = randt(&[100, 8], 3);
        let idx = FlatIndex::new(keys);
        let q = randt(&[1, 8], 4);
        let res = idx.search_effort(q.row(0), 10, Effort::Auto);
        assert_eq!(res.ids.len(), 10);
        for w in res.scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn subset_search_restricts() {
        let keys = randt(&[50, 8], 5);
        let idx = FlatIndex::new(keys);
        let q = randt(&[1, 8], 6);
        let subset: Vec<u32> = vec![3, 9, 14];
        let res = idx.search_subset(q.row(0), &subset, 2);
        assert!(res.ids.iter().all(|id| subset.contains(id)));
        assert_eq!(res.cost.keys_scanned, 3);
    }

    #[test]
    fn batched_scan_is_bit_identical_to_per_query() {
        // odd sizes so the key tiling hits a partial last tile, for
        // both storage precisions
        for storage in [Storage::F32, Storage::F16] {
            let keys = randt(&[301, 24], 9);
            let idx = FlatIndex::with_storage(keys, storage);
            let q = randt(&[7, 24], 10);
            let batched = idx.search_batch_effort(&q, 5, Effort::Auto);
            assert_eq!(batched.len(), 7);
            for i in 0..7 {
                let single = idx.search_effort(q.row(i), 5, Effort::Auto);
                assert_eq!(batched[i].ids, single.ids, "{storage:?} query {i}");
                assert_eq!(batched[i].scores, single.scores, "{storage:?} query {i}");
                assert_eq!(batched[i].cost, single.cost, "{storage:?} query {i}");
            }
            assert!(idx
                .search_batch_effort(&Tensor::zeros(&[0, 24]), 5, Effort::Auto)
                .is_empty());
        }
    }

    #[test]
    fn f16_storage_ranks_like_f32_on_separated_data() {
        // well-separated scores: f16 rounding (~2^-11 relative) cannot
        // reorder them, so the id ranking must match exactly
        let keys = randt(&[120, 32], 11);
        let q = randt(&[1, 32], 12);
        let f32_idx = FlatIndex::new(keys.clone());
        let f16_idx = FlatIndex::with_storage(keys, Storage::F16);
        assert_eq!(f16_idx.spec().to_string(), "flat(storage=f16)");
        let a = f32_idx.search_effort(q.row(0), 5, Effort::Exhaustive);
        let b = f16_idx.search_effort(q.row(0), 5, Effort::Exhaustive);
        // scores differ only by storage rounding
        for (x, y) in a.scores.iter().zip(&b.scores) {
            assert!((x - y).abs() <= 2e-2 * (1.0 + x.abs()), "{x} vs {y}");
        }
        assert_eq!(a.cost, b.cost);
    }

    #[test]
    fn effort_levels_agree_on_exhaustive_backbone() {
        let keys = randt(&[80, 8], 7);
        let idx = FlatIndex::new(keys);
        let q = randt(&[1, 8], 8);
        let a = idx.search_effort(q.row(0), 5, Effort::Exhaustive);
        let b = idx.search_effort(q.row(0), 5, Effort::Probes(1));
        assert_eq!(a.ids, b.ids);
        assert_eq!(idx.n_cells(), 1);
    }
}
