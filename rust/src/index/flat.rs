//! Exhaustive (flat) MIPS index: the exact baseline every approximate
//! backbone is measured against, and the "exact search within selected
//! clusters" stage of the routing experiments (Sec. 4.3).

use std::io::{Read, Write};

use anyhow::Result;

use crate::api::Effort;
use crate::index::artifact;
use crate::index::spec::{FlatSpec, IndexSpec};
use crate::index::traits::{SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::{dot, gemm_nt_tile, Tensor};

/// Brute-force scan over all keys.
pub struct FlatIndex {
    keys: Tensor, // [n, d]
}

impl FlatIndex {
    pub fn new(keys: Tensor) -> Self {
        FlatIndex { keys }
    }

    pub fn keys(&self) -> &Tensor {
        &self.keys
    }

    pub fn d(&self) -> usize {
        self.keys.row_width()
    }

    /// Deserialize from an artifact payload (see [`crate::index::artifact`]).
    pub(crate) fn read_payload(r: &mut dyn Read) -> Result<FlatIndex> {
        Ok(FlatIndex {
            keys: artifact::r_tensor(r)?,
        })
    }

    /// Exact top-k over an explicit subset of key ids (cluster scan).
    pub fn search_subset(&self, query: &[f32], ids: &[u32], k: usize) -> SearchResult {
        let d = self.d();
        let mut top = TopK::new(k);
        for &id in ids {
            top.offer(dot(query, self.keys.row(id as usize)), id);
        }
        let (ids_out, scores) = top.into_sorted();
        SearchResult {
            ids: ids_out,
            scores,
            cost: SearchCost {
                flops: (ids.len() * d * 2) as u64,
                keys_scanned: ids.len() as u64,
                cells_probed: 0,
            },
        }
    }

    /// The exhaustive scan itself; effort has nothing to modulate here.
    fn scan_all(&self, query: &[f32], k: usize) -> SearchResult {
        let n = self.len();
        let d = self.d();
        let mut top = TopK::new(k);
        for id in 0..n {
            top.offer(dot(query, self.keys.row(id)), id as u32);
        }
        let (ids, scores) = top.into_sorted();
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops: (n * d * 2) as u64,
                keys_scanned: n as u64,
                cells_probed: 0,
            },
        }
    }
}

impl VectorIndex for FlatIndex {
    fn name(&self) -> &str {
        "flat"
    }

    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn dim(&self) -> usize {
        self.d()
    }

    fn search_effort(&self, query: &[f32], k: usize, _effort: Effort) -> SearchResult {
        self.scan_all(query, k)
    }

    /// Fused batched scan: score query-tiles × key-tiles through the
    /// [`gemm_nt_tile`] kernel, so each key tile is streamed from memory
    /// once per *batch* instead of once per query, then feed per-query
    /// [`TopK`]s. Same `dot` per (query, key) pair as
    /// [`FlatIndex::search_effort`], so results and costs are
    /// bit-identical.
    fn search_batch_effort(
        &self,
        queries: &Tensor,
        k: usize,
        _effort: Effort,
    ) -> Vec<SearchResult> {
        let b = queries.rows();
        if b == 0 {
            return Vec::new();
        }
        let (n, d) = (self.len(), self.d());
        assert_eq!(queries.row_width(), d, "query dim != index dim {d}");
        let mut tops: Vec<TopK> = (0..b).map(|_| TopK::new(k)).collect();
        // 128 keys * 64 dims * 4 B = 32 KB per key tile: L1/L2 resident
        // while every query in the sub-batch scores against it.
        const KEY_TILE: usize = 128;
        let mut scores = vec![0.0f32; b * KEY_TILE];
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + KEY_TILE).min(n);
            let w = j1 - j0;
            gemm_nt_tile(
                queries.data(),
                &self.keys.data()[j0 * d..j1 * d],
                d,
                &mut scores[..b * w],
            );
            for (q, top) in tops.iter_mut().enumerate() {
                for (jj, &s) in scores[q * w..(q + 1) * w].iter().enumerate() {
                    top.offer(s, (j0 + jj) as u32);
                }
            }
            j0 = j1;
        }
        let cost = SearchCost {
            flops: (n * d * 2) as u64,
            keys_scanned: n as u64,
            cells_probed: 0,
        };
        tops.into_iter()
            .map(|t| {
                let (ids, scores) = t.into_sorted();
                SearchResult { ids, scores, cost }
            })
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Flat(FlatSpec)
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        artifact::w_tensor(w, &self.keys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn finds_exact_top1() {
        let keys = randt(&[200, 16], 1);
        let idx = FlatIndex::new(keys.clone());
        let q = randt(&[1, 16], 2);
        let res = idx.search_effort(q.row(0), 1, Effort::Exhaustive);
        let mut best = (0usize, f32::NEG_INFINITY);
        for i in 0..200 {
            let s = dot(q.row(0), keys.row(i));
            if s > best.1 {
                best = (i, s);
            }
        }
        assert_eq!(res.ids[0] as usize, best.0);
        assert!((res.scores[0] - best.1).abs() < 1e-5);
        assert_eq!(res.cost.keys_scanned, 200);
    }

    #[test]
    fn topk_sorted_descending() {
        let keys = randt(&[100, 8], 3);
        let idx = FlatIndex::new(keys);
        let q = randt(&[1, 8], 4);
        let res = idx.search_effort(q.row(0), 10, Effort::Auto);
        assert_eq!(res.ids.len(), 10);
        for w in res.scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn subset_search_restricts() {
        let keys = randt(&[50, 8], 5);
        let idx = FlatIndex::new(keys);
        let q = randt(&[1, 8], 6);
        let subset: Vec<u32> = vec![3, 9, 14];
        let res = idx.search_subset(q.row(0), &subset, 2);
        assert!(res.ids.iter().all(|id| subset.contains(id)));
        assert_eq!(res.cost.keys_scanned, 3);
    }

    #[test]
    fn batched_scan_is_bit_identical_to_per_query() {
        // odd sizes so the key tiling hits a partial last tile
        let keys = randt(&[301, 24], 9);
        let idx = FlatIndex::new(keys);
        let q = randt(&[7, 24], 10);
        let batched = idx.search_batch_effort(&q, 5, Effort::Auto);
        assert_eq!(batched.len(), 7);
        for i in 0..7 {
            let single = idx.search_effort(q.row(i), 5, Effort::Auto);
            assert_eq!(batched[i].ids, single.ids, "query {i}");
            assert_eq!(batched[i].scores, single.scores, "query {i}");
            assert_eq!(batched[i].cost, single.cost, "query {i}");
        }
        assert!(idx.search_batch_effort(&Tensor::zeros(&[0, 24]), 5, Effort::Auto).is_empty());
    }

    #[test]
    fn effort_levels_agree_on_exhaustive_backbone() {
        let keys = randt(&[80, 8], 7);
        let idx = FlatIndex::new(keys);
        let q = randt(&[1, 8], 8);
        let a = idx.search_effort(q.row(0), 5, Effort::Exhaustive);
        let b = idx.search_effort(q.row(0), 5, Effort::Probes(1));
        assert_eq!(a.ids, b.ids);
        assert_eq!(idx.n_cells(), 1);
    }
}
