//! Exhaustive (flat) MIPS index: the exact baseline every approximate
//! backbone is measured against, and the "exact search within selected
//! clusters" stage of the routing experiments (Sec. 4.3).

use std::io::{Read, Write};

use anyhow::Result;

use crate::api::Effort;
use crate::index::artifact;
use crate::index::spec::{FlatSpec, IndexSpec};
use crate::index::traits::{SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::{dot, Tensor};

/// Brute-force scan over all keys.
pub struct FlatIndex {
    keys: Tensor, // [n, d]
}

impl FlatIndex {
    pub fn new(keys: Tensor) -> Self {
        FlatIndex { keys }
    }

    pub fn keys(&self) -> &Tensor {
        &self.keys
    }

    pub fn d(&self) -> usize {
        self.keys.row_width()
    }

    /// Deserialize from an artifact payload (see [`crate::index::artifact`]).
    pub(crate) fn read_payload(r: &mut dyn Read) -> Result<FlatIndex> {
        Ok(FlatIndex {
            keys: artifact::r_tensor(r)?,
        })
    }

    /// Exact top-k over an explicit subset of key ids (cluster scan).
    pub fn search_subset(&self, query: &[f32], ids: &[u32], k: usize) -> SearchResult {
        let d = self.d();
        let mut top = TopK::new(k);
        for &id in ids {
            top.push(dot(query, self.keys.row(id as usize)), id);
        }
        let (ids_out, scores) = top.into_sorted();
        SearchResult {
            ids: ids_out,
            scores,
            cost: SearchCost {
                flops: (ids.len() * d * 2) as u64,
                keys_scanned: ids.len() as u64,
                cells_probed: 0,
            },
        }
    }

    /// The exhaustive scan itself; effort has nothing to modulate here.
    fn scan_all(&self, query: &[f32], k: usize) -> SearchResult {
        let n = self.len();
        let d = self.d();
        let mut top = TopK::new(k);
        for id in 0..n {
            top.push(dot(query, self.keys.row(id)), id as u32);
        }
        let (ids, scores) = top.into_sorted();
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops: (n * d * 2) as u64,
                keys_scanned: n as u64,
                cells_probed: 0,
            },
        }
    }
}

impl VectorIndex for FlatIndex {
    fn name(&self) -> &str {
        "flat"
    }

    fn len(&self) -> usize {
        self.keys.rows()
    }

    fn dim(&self) -> usize {
        self.d()
    }

    fn search_effort(&self, query: &[f32], k: usize, _effort: Effort) -> SearchResult {
        self.scan_all(query, k)
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Flat(FlatSpec)
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        artifact::w_tensor(w, &self.keys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn finds_exact_top1() {
        let keys = randt(&[200, 16], 1);
        let idx = FlatIndex::new(keys.clone());
        let q = randt(&[1, 16], 2);
        let res = idx.search_effort(q.row(0), 1, Effort::Exhaustive);
        let mut best = (0usize, f32::NEG_INFINITY);
        for i in 0..200 {
            let s = dot(q.row(0), keys.row(i));
            if s > best.1 {
                best = (i, s);
            }
        }
        assert_eq!(res.ids[0] as usize, best.0);
        assert!((res.scores[0] - best.1).abs() < 1e-5);
        assert_eq!(res.cost.keys_scanned, 200);
    }

    #[test]
    fn topk_sorted_descending() {
        let keys = randt(&[100, 8], 3);
        let idx = FlatIndex::new(keys);
        let q = randt(&[1, 8], 4);
        let res = idx.search_effort(q.row(0), 10, Effort::Auto);
        assert_eq!(res.ids.len(), 10);
        for w in res.scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn subset_search_restricts() {
        let keys = randt(&[50, 8], 5);
        let idx = FlatIndex::new(keys);
        let q = randt(&[1, 8], 6);
        let subset: Vec<u32> = vec![3, 9, 14];
        let res = idx.search_subset(q.row(0), &subset, 2);
        assert!(res.ids.iter().all(|id| subset.contains(id)));
        assert_eq!(res.cost.keys_scanned, 3);
    }

    #[test]
    fn effort_levels_agree_on_exhaustive_backbone() {
        let keys = randt(&[80, 8], 7);
        let idx = FlatIndex::new(keys);
        let q = randt(&[1, 8], 8);
        let a = idx.search_effort(q.row(0), 5, Effort::Exhaustive);
        let b = idx.search_effort(q.row(0), 5, Effort::Probes(1));
        assert_eq!(a.ids, b.ids);
        assert_eq!(idx.n_cells(), 1);
    }
}
