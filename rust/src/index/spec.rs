//! Typed index build specs: one validated, parseable description per
//! backbone, replacing the stringly `build_backend(name, ..)` dispatch
//! whose knobs (PQ subspaces, Lloyd iterations, anisotropy, spill
//! candidates, projection dim) were frozen inside `index::mod`.
//!
//! An [`IndexSpec`] round-trips through `Display`/`FromStr` — the CLI
//! accepts `--spec "ivf(nlist=64,iters=15)"` — and builds through one
//! entry point, [`IndexSpec::build`]. The spec is echoed into every
//! persisted index artifact (see [`crate::index::artifact`]) and into
//! the serving [`crate::index::Catalog`] manifest, so a deployment can
//! always answer "what exactly is this index?".

use std::fmt;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::index::keystore::Storage;
use crate::index::{flat, ivf, leanvec, pq, scann, shard, soar, sq, VectorIndex, BACKBONES};
use crate::tensor::Tensor;

/// Default coarse-cell count for the IVF-family specs (override with
/// [`IndexSpec::with_nlist`] or the `nlist=` knob).
pub const DEFAULT_NLIST: usize = 64;

/// Build-time context shared by every backbone: the RNG seed for
/// k-means/PQ training and an optional query sample that makes
/// LeanVec's projection query-aware.
#[derive(Clone, Copy, Debug, Default)]
pub struct BuildCtx<'a> {
    pub sample_queries: Option<&'a Tensor>,
    pub seed: u64,
}

impl BuildCtx<'_> {
    /// A context with just a seed (no query sample).
    pub fn seeded(seed: u64) -> BuildCtx<'static> {
        BuildCtx {
            sample_queries: None,
            seed,
        }
    }
}

/// Exhaustive scan. `storage` selects the key-matrix precision
/// (`f32` default, `f16` compact rows scored through the dequantizing
/// kernel).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FlatSpec {
    pub storage: Storage,
}

/// IVF-Flat: `nlist` coarse cells, `iters` Lloyd iterations.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IvfSpec {
    pub nlist: usize,
    pub iters: usize,
}

impl Default for IvfSpec {
    fn default() -> IvfSpec {
        IvfSpec {
            nlist: DEFAULT_NLIST,
            iters: 15,
        }
    }
}

/// Flat product quantization: `m` subspaces (`None` = largest of
/// 8/4/2/1 dividing the key dim), `iters` codebook Lloyd iterations,
/// `eta` anisotropic parallel-error weight (`1` = classic PQ), `bits`
/// per subspace code (8 = 256 codewords, the default; 4 = 16 codewords
/// packed two per byte, halving code storage).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PqSpec {
    pub m: Option<usize>,
    pub iters: usize,
    pub eta: f32,
    pub bits: usize,
}

impl Default for PqSpec {
    fn default() -> PqSpec {
        PqSpec {
            m: None,
            iters: 10,
            eta: 1.0,
            bits: 8,
        }
    }
}

/// SQ8 scalar quantization; ranges are derived from the data.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SqSpec;

/// ScaNN analog: IVF cells + anisotropic PQ scoring. `iters` are the PQ
/// codebook iterations (the coarse quantizer uses the IVF default);
/// `bits` is the per-subspace code width as in [`PqSpec`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScannSpec {
    pub nlist: usize,
    pub m: Option<usize>,
    pub iters: usize,
    pub eta: f32,
    pub bits: usize,
}

impl Default for ScannSpec {
    fn default() -> ScannSpec {
        ScannSpec {
            nlist: DEFAULT_NLIST,
            m: None,
            iters: 10,
            eta: 4.0,
            bits: 8,
        }
    }
}

/// SOAR analog: IVF with spilled secondary assignments chosen among
/// `spill` runner-up centroids.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SoarSpec {
    pub nlist: usize,
    pub spill: usize,
}

impl Default for SoarSpec {
    fn default() -> SoarSpec {
        SoarSpec {
            nlist: DEFAULT_NLIST,
            spill: 6,
        }
    }
}

/// LeanVec analog: PCA projection to `d_low` dims (`None` =
/// [`leanvec_target_dim`]), IVF in the reduced space, full-dim re-rank.
/// `query_aware` fits the projection on keys ∪ sample queries when the
/// build context provides a sample.
/// `storage` selects the precision of the full-dim re-rank rows.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LeanVecSpec {
    pub d_low: Option<usize>,
    pub nlist: usize,
    pub query_aware: bool,
    pub storage: Storage,
}

impl Default for LeanVecSpec {
    fn default() -> LeanVecSpec {
        LeanVecSpec {
            d_low: None,
            nlist: DEFAULT_NLIST,
            query_aware: true,
            storage: Storage::F32,
        }
    }
}

/// How a [`ShardedSpec`] partitions global key ids across shards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardAssign {
    /// Key `i` lands on shard `i % shards` (interleaved; balanced to
    /// within one key for any key count).
    #[default]
    RoundRobin,
    /// Keys are cut into `shards` contiguous ranges (the first
    /// `n % shards` ranges get one extra key).
    Contiguous,
}

impl fmt::Display for ShardAssign {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardAssign::RoundRobin => write!(f, "round_robin"),
            ShardAssign::Contiguous => write!(f, "contiguous"),
        }
    }
}

impl std::str::FromStr for ShardAssign {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ShardAssign> {
        match s {
            "round_robin" => Ok(ShardAssign::RoundRobin),
            "contiguous" => Ok(ShardAssign::Contiguous),
            other => bail!("unknown shard assignment '{other}' (round_robin | contiguous)"),
        }
    }
}

/// Sharded serving: keys are partitioned across `shards` partitions
/// ([`ShardAssign`]), each shard is an independent `inner` backbone, and
/// search fans out across shards and merges per-shard top-k (the
/// partition-then-score backbone of large-scale MIPS serving). The inner
/// spec may be any non-sharded backbone.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardedSpec {
    pub shards: usize,
    pub assign: ShardAssign,
    pub inner: Box<IndexSpec>,
}

impl Default for ShardedSpec {
    fn default() -> ShardedSpec {
        ShardedSpec {
            shards: 8,
            assign: ShardAssign::RoundRobin,
            inner: Box::new(IndexSpec::Flat(FlatSpec::default())),
        }
    }
}

/// Default LeanVec projection dimension for `d`-dim keys: half the
/// input width, floored at 4 (or at `d` itself when `d < 4`), never
/// above `d`.
pub fn leanvec_target_dim(d: usize) -> usize {
    if d == 0 {
        return 0;
    }
    (d / 2).clamp(1, d).max(4.min(d))
}

/// Largest PQ subspace count `<= 8` that divides `d` (the `m=auto`
/// resolution for [`PqSpec`]/[`ScannSpec`]).
pub fn auto_pq_m(d: usize) -> usize {
    for m in [8usize, 4, 2] {
        if d % m == 0 {
            return m;
        }
    }
    1
}

fn resolve_pq_m(m: Option<usize>, d: usize) -> Result<usize> {
    match m {
        Some(m) => {
            ensure!(
                m >= 1 && d % m == 0,
                "pq m={m} must divide the key dim {d} (try m=auto)"
            );
            Ok(m)
        }
        None => Ok(auto_pq_m(d)),
    }
}

/// A typed, validated build description for one of the seven leaf
/// backbones, or a [`ShardedSpec`] composing one of them per shard
/// (recursive through a `Box`, which is why the enum is `Clone` but not
/// `Copy`).
#[derive(Clone, Debug, PartialEq)]
pub enum IndexSpec {
    Flat(FlatSpec),
    Ivf(IvfSpec),
    Pq(PqSpec),
    Sq(SqSpec),
    Scann(ScannSpec),
    Soar(SoarSpec),
    LeanVec(LeanVecSpec),
    Sharded(ShardedSpec),
}

impl IndexSpec {
    /// The backbone tag this spec builds (matches
    /// [`VectorIndex::name`] and [`crate::index::BACKBONES`]).
    pub fn name(&self) -> &'static str {
        match self {
            IndexSpec::Flat(_) => "flat",
            IndexSpec::Ivf(_) => "ivf",
            IndexSpec::Pq(_) => "pq",
            IndexSpec::Sq(_) => "sq8",
            IndexSpec::Scann(_) => "scann",
            IndexSpec::Soar(_) => "soar",
            IndexSpec::LeanVec(_) => "leanvec",
            IndexSpec::Sharded(_) => "sharded",
        }
    }

    /// The default spec for a backbone name.
    pub fn default_for(name: &str) -> Result<IndexSpec> {
        Ok(match name {
            "flat" => IndexSpec::Flat(FlatSpec::default()),
            "ivf" => IndexSpec::Ivf(IvfSpec::default()),
            "pq" => IndexSpec::Pq(PqSpec::default()),
            "sq8" => IndexSpec::Sq(SqSpec),
            "scann" => IndexSpec::Scann(ScannSpec::default()),
            "soar" => IndexSpec::Soar(SoarSpec::default()),
            "leanvec" => IndexSpec::LeanVec(LeanVecSpec::default()),
            "sharded" => IndexSpec::Sharded(ShardedSpec::default()),
            other => {
                bail!("unknown backbone '{other}'; expected one of {BACKBONES:?} or 'sharded'")
            }
        })
    }

    /// Coarse-cell count, for the IVF-family variants. A sharded spec
    /// reports its inner backbone's per-shard `nlist`.
    pub fn nlist(&self) -> Option<usize> {
        match self {
            IndexSpec::Ivf(s) => Some(s.nlist),
            IndexSpec::Scann(s) => Some(s.nlist),
            IndexSpec::Soar(s) => Some(s.nlist),
            IndexSpec::LeanVec(s) => Some(s.nlist),
            IndexSpec::Sharded(s) => s.inner.nlist(),
            _ => None,
        }
    }

    /// Override `nlist` on the IVF-family variants (no-op on the
    /// cell-less backbones; a sharded spec forwards to its inner spec).
    pub fn with_nlist(mut self, nlist: usize) -> IndexSpec {
        match &mut self {
            IndexSpec::Ivf(s) => s.nlist = nlist,
            IndexSpec::Scann(s) => s.nlist = nlist,
            IndexSpec::Soar(s) => s.nlist = nlist,
            IndexSpec::LeanVec(s) => s.nlist = nlist,
            IndexSpec::Sharded(s) => {
                let inner = std::mem::replace(&mut *s.inner, IndexSpec::Flat(FlatSpec::default()));
                *s.inner = inner.with_nlist(nlist);
            }
            _ => {}
        }
        self
    }

    /// Check every knob for internal consistency (data-dependent checks
    /// like `m | d` happen in [`IndexSpec::build`]).
    pub fn validate(&self) -> Result<()> {
        fn pos(v: usize, what: &str, spec: &IndexSpec) -> Result<()> {
            ensure!(v >= 1, "{what} must be >= 1 in '{spec}'");
            Ok(())
        }
        fn eta_ok(eta: f32, spec: &IndexSpec) -> Result<()> {
            ensure!(
                eta.is_finite() && eta > 0.0,
                "eta must be finite and > 0 in '{spec}', got {eta}"
            );
            Ok(())
        }
        fn bits_ok(bits: usize, spec: &IndexSpec) -> Result<()> {
            ensure!(
                bits == 8 || bits == 4,
                "bits must be 4 or 8 in '{spec}', got {bits}"
            );
            Ok(())
        }
        match self {
            IndexSpec::Flat(_) | IndexSpec::Sq(_) => Ok(()),
            IndexSpec::Ivf(s) => {
                pos(s.nlist, "nlist", self)?;
                pos(s.iters, "iters", self)
            }
            IndexSpec::Pq(s) => {
                if let Some(m) = s.m {
                    pos(m, "m", self)?;
                }
                pos(s.iters, "iters", self)?;
                eta_ok(s.eta, self)?;
                bits_ok(s.bits, self)
            }
            IndexSpec::Scann(s) => {
                pos(s.nlist, "nlist", self)?;
                if let Some(m) = s.m {
                    pos(m, "m", self)?;
                }
                pos(s.iters, "iters", self)?;
                eta_ok(s.eta, self)?;
                bits_ok(s.bits, self)
            }
            IndexSpec::Soar(s) => {
                pos(s.nlist, "nlist", self)?;
                pos(s.spill, "spill", self)
            }
            IndexSpec::LeanVec(s) => {
                if let Some(v) = s.d_low {
                    pos(v, "d_low", self)?;
                }
                pos(s.nlist, "nlist", self)
            }
            IndexSpec::Sharded(s) => {
                pos(s.shards, "shards", self)?;
                // same cap the artifact loader enforces — an index that
                // builds must also reload
                ensure!(
                    s.shards <= shard::MAX_SHARDS,
                    "shards={} exceeds the supported maximum {} in '{self}'",
                    s.shards,
                    shard::MAX_SHARDS
                );
                ensure!(
                    !matches!(*s.inner, IndexSpec::Sharded(_)),
                    "nested sharding is not supported in '{self}'"
                );
                s.inner.validate()
            }
        }
    }

    /// Build the backbone this spec describes over `keys` — the one
    /// construction entry point behind the CLI, benches, catalog and
    /// conformance tests. `auto` knobs are resolved against the key
    /// dimensionality here.
    pub fn build(&self, keys: &Tensor, ctx: &BuildCtx) -> Result<Box<dyn VectorIndex>> {
        self.validate()?;
        let n = keys.rows();
        let d = keys.row_width();
        ensure!(n > 0, "cannot build '{}' over an empty key set", self.name());
        if let Some(nlist) = self.nlist() {
            ensure!(
                nlist <= n,
                "nlist={nlist} exceeds the {n} keys available for '{self}'"
            );
        }
        Ok(match self {
            IndexSpec::Flat(s) => {
                Box::new(flat::FlatIndex::with_storage(keys.clone(), s.storage))
            }
            IndexSpec::Ivf(s) => Box::new(ivf::IvfIndex::build(keys, s.nlist, s.iters, ctx.seed)),
            IndexSpec::Pq(s) => {
                let m = resolve_pq_m(s.m, d)?;
                Box::new(pq::PqIndex::build(
                    keys, m, s.iters, s.eta, s.bits, ctx.seed,
                ))
            }
            IndexSpec::Sq(_) => Box::new(sq::SqIndex::build(keys)),
            IndexSpec::Scann(s) => {
                let m = resolve_pq_m(s.m, d)?;
                Box::new(scann::ScannIndex::build(
                    keys, s.nlist, m, s.iters, s.eta, s.bits, ctx.seed,
                ))
            }
            IndexSpec::Soar(s) => {
                Box::new(soar::SoarIndex::build(keys, s.nlist, s.spill, ctx.seed))
            }
            IndexSpec::LeanVec(s) => {
                let d_low = match s.d_low {
                    Some(v) => {
                        ensure!(v <= d, "d_low={v} exceeds the key dim {d} in '{self}'");
                        v
                    }
                    None => leanvec_target_dim(d),
                };
                let queries = if s.query_aware {
                    ctx.sample_queries
                } else {
                    None
                };
                Box::new(leanvec::LeanVecIndex::build(
                    keys, d_low, s.nlist, queries, s.storage, ctx.seed,
                ))
            }
            IndexSpec::Sharded(s) => Box::new(shard::ShardedIndex::build(keys, s, ctx)?),
        })
    }
}

fn fmt_auto(v: Option<usize>) -> String {
    match v {
        Some(v) => v.to_string(),
        None => "auto".to_string(),
    }
}

impl fmt::Display for IndexSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // The compact-storage knobs print only when non-default, so spec
        // echoes persisted before the knobs existed ("flat",
        // "pq(m=4,iters=10,eta=1)") still render and re-parse unchanged.
        match self {
            IndexSpec::Flat(s) => {
                if s.storage == Storage::F32 {
                    write!(f, "flat")
                } else {
                    write!(f, "flat(storage={})", s.storage)
                }
            }
            IndexSpec::Ivf(s) => write!(f, "ivf(nlist={},iters={})", s.nlist, s.iters),
            IndexSpec::Pq(s) => {
                write!(f, "pq(m={},iters={},eta={}", fmt_auto(s.m), s.iters, s.eta)?;
                if s.bits != 8 {
                    write!(f, ",bits={}", s.bits)?;
                }
                write!(f, ")")
            }
            IndexSpec::Sq(_) => write!(f, "sq8"),
            IndexSpec::Scann(s) => {
                write!(
                    f,
                    "scann(nlist={},m={},iters={},eta={}",
                    s.nlist,
                    fmt_auto(s.m),
                    s.iters,
                    s.eta
                )?;
                if s.bits != 8 {
                    write!(f, ",bits={}", s.bits)?;
                }
                write!(f, ")")
            }
            IndexSpec::Soar(s) => write!(f, "soar(nlist={},spill={})", s.nlist, s.spill),
            IndexSpec::LeanVec(s) => {
                write!(
                    f,
                    "leanvec(d_low={},nlist={},query_aware={}",
                    fmt_auto(s.d_low),
                    s.nlist,
                    s.query_aware
                )?;
                if s.storage != Storage::F32 {
                    write!(f, ",storage={}", s.storage)?;
                }
                write!(f, ")")
            }
            IndexSpec::Sharded(s) => write!(
                f,
                "sharded(shards={},assign={},inner={})",
                s.shards, s.assign, s.inner
            ),
        }
    }
}

/// `key=value` knob list parsed out of `name(k=v,...)`; tracks leftover
/// keys so typos are rejected instead of silently ignored.
struct Knobs(Vec<(String, String)>);

/// Split a knob body on commas at parenthesis depth 0 only, so nested
/// specs like `inner=ivf(nlist=64,iters=15)` stay one knob.
fn split_top_level(body: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0;
    for (i, c) in body.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                parts.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&body[start..]);
    parts
}

impl Knobs {
    fn parse(body: &str) -> Result<Knobs> {
        let mut pairs: Vec<(String, String)> = Vec::new();
        for part in split_top_level(body) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("knob '{part}' is not key=value"))?;
            let (k, v) = (k.trim().to_string(), v.trim().to_string());
            ensure!(
                !pairs.iter().any(|(seen, _)| *seen == k),
                "duplicate knob '{k}'"
            );
            pairs.push((k, v));
        }
        Ok(Knobs(pairs))
    }

    fn take(&mut self, key: &str) -> Option<String> {
        self.0
            .iter()
            .position(|(k, _)| k == key)
            .map(|i| self.0.remove(i).1)
    }

    fn usize_or(&mut self, key: &str, default: usize) -> Result<usize> {
        match self.take(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("knob {key}={v}: {e}")),
            None => Ok(default),
        }
    }

    fn f32_or(&mut self, key: &str, default: f32) -> Result<f32> {
        match self.take(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("knob {key}={v}: {e}")),
            None => Ok(default),
        }
    }

    fn bool_or(&mut self, key: &str, default: bool) -> Result<bool> {
        match self.take(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("knob {key}={v}: {e}")),
            None => Ok(default),
        }
    }

    fn storage_or(&mut self, default: Storage) -> Result<Storage> {
        match self.take("storage") {
            Some(v) => v.parse(),
            None => Ok(default),
        }
    }

    fn auto_or(&mut self, key: &str, default: Option<usize>) -> Result<Option<usize>> {
        match self.take(key) {
            Some(v) if v == "auto" => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow!("knob {key}={v}: {e}")),
            None => Ok(default),
        }
    }

    fn finish(self, name: &str) -> Result<()> {
        if !self.0.is_empty() {
            let keys: Vec<&str> = self.0.iter().map(|(k, _)| k.as_str()).collect();
            bail!("unknown knob(s) {keys:?} for backbone '{name}'");
        }
        Ok(())
    }
}

/// Deepest parenthesis nesting a spec string may use. Legitimate specs
/// need 2 (`sharded(inner=ivf(...))`); the bound keeps a crafted
/// `sharded(inner=sharded(inner=…` string — e.g. planted in a catalog
/// manifest — from recursing the parser into a stack-overflow abort
/// instead of a typed error.
const MAX_SPEC_DEPTH: usize = 4;

impl std::str::FromStr for IndexSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<IndexSpec> {
        let s = s.trim();
        let mut depth = 0usize;
        for c in s.chars() {
            if c == '(' {
                depth += 1;
                ensure!(
                    depth <= MAX_SPEC_DEPTH,
                    "index spec nests deeper than {MAX_SPEC_DEPTH} levels"
                );
            } else if c == ')' {
                depth = depth.saturating_sub(1);
            }
        }
        let (name, body) = match s.split_once('(') {
            Some((n, rest)) => {
                let rest = rest.trim_end();
                ensure!(rest.ends_with(')'), "unclosed '(' in index spec '{s}'");
                (n.trim(), &rest[..rest.len() - 1])
            }
            None => (s, ""),
        };
        let mut knobs = Knobs::parse(body)?;
        let spec = match name {
            "flat" => IndexSpec::Flat(FlatSpec {
                storage: knobs.storage_or(Storage::F32)?,
            }),
            "sq8" => IndexSpec::Sq(SqSpec),
            "ivf" => {
                let dflt = IvfSpec::default();
                IndexSpec::Ivf(IvfSpec {
                    nlist: knobs.usize_or("nlist", dflt.nlist)?,
                    iters: knobs.usize_or("iters", dflt.iters)?,
                })
            }
            "pq" => {
                let dflt = PqSpec::default();
                IndexSpec::Pq(PqSpec {
                    m: knobs.auto_or("m", dflt.m)?,
                    iters: knobs.usize_or("iters", dflt.iters)?,
                    eta: knobs.f32_or("eta", dflt.eta)?,
                    bits: knobs.usize_or("bits", dflt.bits)?,
                })
            }
            "scann" => {
                let dflt = ScannSpec::default();
                IndexSpec::Scann(ScannSpec {
                    nlist: knobs.usize_or("nlist", dflt.nlist)?,
                    m: knobs.auto_or("m", dflt.m)?,
                    iters: knobs.usize_or("iters", dflt.iters)?,
                    eta: knobs.f32_or("eta", dflt.eta)?,
                    bits: knobs.usize_or("bits", dflt.bits)?,
                })
            }
            "soar" => {
                let dflt = SoarSpec::default();
                IndexSpec::Soar(SoarSpec {
                    nlist: knobs.usize_or("nlist", dflt.nlist)?,
                    spill: knobs.usize_or("spill", dflt.spill)?,
                })
            }
            "leanvec" => {
                let dflt = LeanVecSpec::default();
                IndexSpec::LeanVec(LeanVecSpec {
                    d_low: knobs.auto_or("d_low", dflt.d_low)?,
                    nlist: knobs.usize_or("nlist", dflt.nlist)?,
                    query_aware: knobs.bool_or("query_aware", dflt.query_aware)?,
                    storage: knobs.storage_or(dflt.storage)?,
                })
            }
            "sharded" => {
                let dflt = ShardedSpec::default();
                let inner = match knobs.take("inner") {
                    Some(v) => Box::new(v.parse::<IndexSpec>().context("knob inner")?),
                    None => dflt.inner,
                };
                let assign = match knobs.take("assign") {
                    Some(v) => v.parse::<ShardAssign>()?,
                    None => dflt.assign,
                };
                IndexSpec::Sharded(ShardedSpec {
                    shards: knobs.usize_or("shards", dflt.shards)?,
                    assign,
                    inner,
                })
            }
            other => {
                bail!("unknown backbone '{other}'; expected one of {BACKBONES:?} or 'sharded'")
            }
        };
        knobs.finish(name)?;
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leanvec_target_dim_halves_with_floor() {
        assert_eq!(leanvec_target_dim(0), 0);
        assert_eq!(leanvec_target_dim(1), 1);
        assert_eq!(leanvec_target_dim(2), 2);
        assert_eq!(leanvec_target_dim(3), 3);
        assert_eq!(leanvec_target_dim(4), 4);
        assert_eq!(leanvec_target_dim(6), 4);
        assert_eq!(leanvec_target_dim(8), 4);
        assert_eq!(leanvec_target_dim(16), 8);
        assert_eq!(leanvec_target_dim(64), 32);
        for d in 1..=128 {
            let t = leanvec_target_dim(d);
            assert!((1..=d).contains(&t), "d={d} -> {t}");
        }
    }

    #[test]
    fn auto_pq_m_divides() {
        assert_eq!(auto_pq_m(16), 8);
        assert_eq!(auto_pq_m(12), 4);
        assert_eq!(auto_pq_m(6), 2);
        assert_eq!(auto_pq_m(7), 1);
    }

    #[test]
    fn defaults_cover_every_backbone() {
        for name in BACKBONES {
            let spec = IndexSpec::default_for(name).unwrap();
            assert_eq!(spec.name(), name);
            spec.validate().unwrap();
        }
        let sharded = IndexSpec::default_for("sharded").unwrap();
        assert_eq!(sharded.name(), "sharded");
        sharded.validate().unwrap();
        assert!(IndexSpec::default_for("hnsw").is_err());
    }

    #[test]
    fn with_nlist_touches_only_cell_backbones() {
        for name in BACKBONES {
            let spec = IndexSpec::default_for(name).unwrap().with_nlist(5);
            match name {
                "flat" | "pq" | "sq8" => assert_eq!(spec.nlist(), None, "{name}"),
                _ => assert_eq!(spec.nlist(), Some(5), "{name}"),
            }
        }
    }

    #[test]
    fn parse_tolerates_whitespace_and_empty_parens() {
        let a: IndexSpec = " ivf( nlist = 8 , iters = 2 ) ".parse().unwrap();
        assert_eq!(
            a,
            IndexSpec::Ivf(IvfSpec { nlist: 8, iters: 2 })
        );
        let b: IndexSpec = "ivf()".parse().unwrap();
        assert_eq!(b, IndexSpec::Ivf(IvfSpec::default()));
        let c: IndexSpec = "flat".parse().unwrap();
        assert_eq!(c, IndexSpec::Flat(FlatSpec::default()));
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in [
            "hnsw",
            "ivf(nlist=0)",
            "ivf(iters=0)",
            "ivf(bogus=1)",
            "ivf(nlist=x)",
            "ivf(nlist=4",
            "ivf(nlist=4,nlist=5)",
            "ivf(nlist)",
            "pq(m=0)",
            "pq(eta=0)",
            "pq(eta=nan)",
            "pq(bits=3)",
            "pq(bits=16)",
            "scann(bits=0)",
            "flat(storage=f64)",
            "flat(bogus=1)",
            "leanvec(storage=f8)",
            "soar(spill=0)",
            "leanvec(d_low=0)",
            "leanvec(query_aware=maybe)",
            "sharded(shards=0)",
            "sharded(shards=2,inner=hnsw)",
            "sharded(inner=ivf(nlist=0))",
            "sharded(inner=sharded(inner=flat))",
            "sharded(assign=diagonal)",
            "sharded(shards=2,inner=ivf(nlist=4)",
            "sharded(shards=70000)",
        ] {
            assert!(bad.parse::<IndexSpec>().is_err(), "{bad}");
        }
        // a crafted deeply-nested spec is a typed error, not a
        // parse-recursion stack overflow
        let deep = format!("{}flat{}", "sharded(inner=".repeat(50_000), ")".repeat(50_000));
        assert!(deep.parse::<IndexSpec>().is_err());
    }

    #[test]
    fn sharded_spec_parses_nests_and_round_trips() {
        let s: IndexSpec = "sharded(shards=8,inner=ivf(nlist=64))".parse().unwrap();
        assert_eq!(
            s,
            IndexSpec::Sharded(ShardedSpec {
                shards: 8,
                assign: ShardAssign::RoundRobin,
                inner: Box::new(IndexSpec::Ivf(IvfSpec {
                    nlist: 64,
                    iters: 15
                })),
            })
        );
        // Display round-trips, including the nested inner knob list
        let text = s.to_string();
        assert_eq!(
            text,
            "sharded(shards=8,assign=round_robin,inner=ivf(nlist=64,iters=15))"
        );
        assert_eq!(text.parse::<IndexSpec>().unwrap(), s);
        // contiguous assignment and defaults
        let c: IndexSpec = "sharded(assign=contiguous)".parse().unwrap();
        assert_eq!(
            c,
            IndexSpec::Sharded(ShardedSpec {
                assign: ShardAssign::Contiguous,
                ..ShardedSpec::default()
            })
        );
        assert_eq!(c.name(), "sharded");
        // nlist views pass through to the inner spec
        assert_eq!(s.nlist(), Some(64));
        let resized = s.with_nlist(16);
        assert_eq!(resized.nlist(), Some(16));
        assert_eq!(
            resized.to_string(),
            "sharded(shards=8,assign=round_robin,inner=ivf(nlist=16,iters=15))"
        );
    }

    #[test]
    fn compact_storage_knobs_round_trip_and_stay_silent_by_default() {
        // default echoes are unchanged from before the knobs existed
        assert_eq!(IndexSpec::Flat(FlatSpec::default()).to_string(), "flat");
        assert_eq!(
            IndexSpec::Pq(PqSpec::default()).to_string(),
            "pq(m=auto,iters=10,eta=1)"
        );
        assert_eq!(
            IndexSpec::LeanVec(LeanVecSpec::default()).to_string(),
            "leanvec(d_low=auto,nlist=64,query_aware=true)"
        );
        // non-default knobs print and round-trip
        for text in [
            "flat(storage=f16)",
            "pq(m=4,iters=10,eta=1,bits=4)",
            "scann(nlist=64,m=auto,iters=10,eta=4,bits=4)",
            "leanvec(d_low=auto,nlist=64,query_aware=true,storage=f16)",
        ] {
            let spec: IndexSpec = text.parse().unwrap();
            assert_eq!(spec.to_string(), text);
        }
        let s: IndexSpec = "pq(bits=4)".parse().unwrap();
        assert_eq!(
            s,
            IndexSpec::Pq(PqSpec {
                bits: 4,
                ..PqSpec::default()
            })
        );
        let s: IndexSpec = "flat(storage=f16)".parse().unwrap();
        assert_eq!(
            s,
            IndexSpec::Flat(FlatSpec {
                storage: Storage::F16
            })
        );
    }

    #[test]
    fn split_top_level_respects_nesting() {
        assert_eq!(
            split_top_level("shards=8,inner=ivf(nlist=64,iters=15),assign=contiguous"),
            vec!["shards=8", "inner=ivf(nlist=64,iters=15)", "assign=contiguous"]
        );
        assert_eq!(split_top_level(""), vec![""]);
        assert_eq!(split_top_level("a=1"), vec!["a=1"]);
    }

    #[test]
    fn build_resolves_auto_knobs_and_checks_data() {
        use crate::tensor::normalize_rows;
        use crate::util::Rng;
        let mut keys = Tensor::zeros(&[60, 12]);
        Rng::new(3).fill_normal(keys.data_mut(), 1.0);
        normalize_rows(&mut keys);
        let ctx = BuildCtx::seeded(7);
        // auto m resolves to 4 for d=12
        let idx = IndexSpec::default_for("pq").unwrap().build(&keys, &ctx).unwrap();
        assert_eq!(
            idx.spec(),
            IndexSpec::Pq(PqSpec {
                m: Some(4),
                ..PqSpec::default()
            })
        );
        // explicit m must divide d
        assert!("pq(m=5)".parse::<IndexSpec>().unwrap().build(&keys, &ctx).is_err());
        // nlist larger than the key count is rejected, not a panic
        assert!("ivf(nlist=100)"
            .parse::<IndexSpec>()
            .unwrap()
            .build(&keys, &ctx)
            .is_err());
        // d_low larger than d is rejected
        assert!("leanvec(d_low=20,nlist=4)"
            .parse::<IndexSpec>()
            .unwrap()
            .build(&keys, &ctx)
            .is_err());
    }
}
