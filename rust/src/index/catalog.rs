//! A catalog of named, persisted index collections — the build-once /
//! serve-many deployment story. `amips build` trains an index from a
//! typed [`IndexSpec`] and writes a versioned artifact plus a manifest
//! line; `amips serve --catalog` (and
//! [`crate::coordinator::Server::start_from_catalog`]) reopen the
//! catalog and serve from the prebuilt artifacts without re-running
//! k-means/PQ training.
//!
//! On-disk layout of a catalog directory:
//!
//! ```text
//! <root>/catalog.tsv     # name<TAB>spec<TAB>artifact[<TAB>mapper], one per line
//! <root>/<name>.ami      # versioned index artifact (index::artifact)
//! <root>/<name>.seg/     # OR a mutable collection directory (index::segment):
//!                        #   gen-<n>.tsv generation manifests + seg-*.ams segments
//! <root>/<name>.map.amm  # optional trained query-map model artifact
//! ```
//!
//! A manifest row whose artifact column ends in `.seg` names a
//! *mutable* collection: the column is a directory managed by
//! [`MutableCollection`] (generation manifests + sealed segments)
//! instead of a monolithic artifact, and the loaded entry exposes the
//! collection through [`CatalogEntry::mutable`] so callers can
//! insert/upsert/delete/compact while the same `Arc` serves searches.
//!
//! The optional fourth manifest column names a persisted c=1 model
//! artifact ([`crate::model::artifact`]); collections carrying one serve
//! mapped queries (paper Sec. 4.4) straight from the catalog — see
//! [`Catalog::attach_mapper`] and `amips train --catalog`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::index::segment::MutableCollection;
use crate::index::spec::{BuildCtx, IndexSpec};
use crate::index::{artifact, VectorIndex};
use crate::model::{self, AmortizedModel, RustModel};
use crate::tensor::Tensor;

/// Manifest file name inside a catalog directory.
pub const MANIFEST_FILE: &str = "catalog.tsv";

/// Artifact-column suffix marking a mutable collection directory.
pub const MUTABLE_SUFFIX: &str = ".seg";

/// One served collection: the spec it was built from, where its
/// artifact lives, and the loaded index (a batched
/// [`crate::api::Searcher`] via the blanket impl).
pub struct CatalogEntry {
    pub name: String,
    /// The spec as registered at build time (`auto` knobs unresolved);
    /// `index.spec()` reports the resolved echo.
    pub spec: IndexSpec,
    pub path: PathBuf,
    pub index: Arc<dyn VectorIndex>,
    /// For mutable collections (`<name>.seg` rows): the same object as
    /// `index`, typed for mutation — insert/upsert/delete/compact.
    /// `None` for immutable artifact-backed collections.
    pub mutable: Option<Arc<MutableCollection>>,
    /// Optional trained query mapper persisted next to the index
    /// artifact ([`Catalog::attach_mapper`]).
    pub mapper_path: Option<PathBuf>,
    pub mapper: Option<Arc<RustModel>>,
}

/// A directory of named collections backed by index artifacts.
pub struct Catalog {
    root: PathBuf,
    entries: BTreeMap<String, CatalogEntry>,
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
}

/// One parsed manifest row; the mapper column is optional.
type ManifestRow = (String, IndexSpec, String, Option<String>);

/// Parse the manifest text into `(name, spec, artifact file, mapper
/// file)` rows without touching any artifact.
fn manifest_rows(text: &str, manifest: &Path) -> Result<Vec<ManifestRow>> {
    let mut rows = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split('\t');
        let (Some(name), Some(spec_str), Some(file), mapper, None) = (
            parts.next(),
            parts.next(),
            parts.next(),
            parts.next(),
            parts.next(),
        ) else {
            bail!(
                "malformed line {} in {}: expected name<TAB>spec<TAB>artifact[<TAB>mapper], got '{line}'",
                lineno + 1,
                manifest.display()
            );
        };
        let spec: IndexSpec = spec_str
            .parse()
            .with_context(|| format!("catalog collection '{name}'"))?;
        rows.push((
            name.to_string(),
            spec,
            file.to_string(),
            mapper.map(str::to_string),
        ));
    }
    Ok(rows)
}

/// Write the manifest for a set of rows (sorted by collection name).
fn write_manifest_rows(root: &Path, rows: &[ManifestRow]) -> Result<()> {
    let mut text = String::from(
        "# amips catalog: name<TAB>spec<TAB>artifact[<TAB>mapper] (one collection per line)\n",
    );
    for (name, spec, file, mapper) in rows {
        match mapper {
            Some(m) => text.push_str(&format!("{name}\t{spec}\t{file}\t{m}\n")),
            None => text.push_str(&format!("{name}\t{spec}\t{file}\n")),
        }
    }
    // write-then-rename so a crash mid-write can't leave a truncated
    // manifest that orphans every intact artifact in the catalog
    let path = root.join(MANIFEST_FILE);
    let tmp = root.join(format!("{MANIFEST_FILE}.tmp"));
    std::fs::write(&tmp, text)
        .with_context(|| format!("writing catalog manifest {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("replacing catalog manifest {}", path.display()))?;
    Ok(())
}

/// Load one manifest row's artifact (and optional mapper) and verify
/// they match the spec and each other.
fn load_entry(
    root: &Path,
    name: &str,
    spec: IndexSpec,
    file: &str,
    mapper_file: Option<&str>,
) -> Result<CatalogEntry> {
    let path = root.join(file);
    let (index, mutable): (Arc<dyn VectorIndex>, Option<Arc<MutableCollection>>) =
        if file.ends_with(MUTABLE_SUFFIX) {
            let coll = Arc::new(MutableCollection::open(&path, spec.clone())?);
            (coll.clone() as Arc<dyn VectorIndex>, Some(coll))
        } else {
            let index = artifact::load(&path)?;
            ensure!(
                index.name() == spec.name(),
                "collection '{name}': artifact {} holds a '{}' backbone but the manifest spec says '{}'",
                path.display(),
                index.name(),
                spec.name()
            );
            (Arc::from(index), None)
        };
    let (mapper_path, mapper) = match mapper_file {
        Some(mf) => {
            let mpath = root.join(mf);
            let model = model::artifact::load(&mpath)?;
            ensure!(
                model.n_heads() == 1,
                "collection '{name}': mapper '{}' has c={}, a query map needs c=1",
                model.label(),
                model.n_heads()
            );
            ensure!(
                model.dim() == index.dim(),
                "collection '{name}': mapper dim {} != index dim {}",
                model.dim(),
                index.dim()
            );
            (Some(mpath), Some(Arc::new(model)))
        }
        None => (None, None),
    };
    Ok(CatalogEntry {
        name: name.to_string(),
        spec,
        path,
        index,
        mutable,
        mapper_path,
        mapper,
    })
}

impl Catalog {
    /// Create an empty catalog directory (with manifest). Refuses to
    /// clobber an existing manifest — reopening (or appending to) a
    /// populated catalog goes through [`Catalog::open`] /
    /// [`Catalog::open_or_create`].
    pub fn create(root: impl Into<PathBuf>) -> Result<Catalog> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating catalog dir {}", root.display()))?;
        let manifest = root.join(MANIFEST_FILE);
        ensure!(
            !manifest.exists(),
            "catalog manifest {} already exists; use Catalog::open (or open_or_create) instead of overwriting it",
            manifest.display()
        );
        let cat = Catalog {
            root,
            entries: BTreeMap::new(),
        };
        cat.write_manifest()?;
        Ok(cat)
    }

    /// Open an existing catalog, loading every artifact it lists. For
    /// serving a single known collection out of a large catalog,
    /// [`Catalog::open_collection`] avoids deserializing the rest.
    pub fn open(root: impl Into<PathBuf>) -> Result<Catalog> {
        let root = root.into();
        let manifest = root.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading catalog manifest {}", manifest.display()))?;
        let mut entries = BTreeMap::new();
        for (name, spec, file, mapper) in manifest_rows(&text, &manifest)? {
            let entry = load_entry(&root, &name, spec, &file, mapper.as_deref())?;
            let prev = entries.insert(name.clone(), entry);
            ensure!(prev.is_none(), "duplicate collection '{name}' in manifest");
        }
        Ok(Catalog { root, entries })
    }

    /// Load exactly one collection from a catalog directory, without
    /// deserializing any other artifact — serve-startup cost scales
    /// with the requested index, not the whole catalog.
    pub fn open_collection(root: impl Into<PathBuf>, name: &str) -> Result<CatalogEntry> {
        let root = root.into();
        let manifest = root.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading catalog manifest {}", manifest.display()))?;
        let rows = manifest_rows(&text, &manifest)?;
        match rows.iter().find(|(n, _, _, _)| n == name) {
            Some((n, spec, file, mapper)) => {
                load_entry(&root, n, spec.clone(), file, mapper.as_deref())
            }
            None => bail!(
                "catalog {} has no collection '{name}' (available: {})",
                root.display(),
                rows.iter()
                    .map(|(n, _, _, _)| n.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        }
    }

    /// List the collection names in a catalog directory by parsing only
    /// the manifest — no artifact is loaded.
    pub fn names_on_disk(root: impl Into<PathBuf>) -> Result<Vec<String>> {
        let root = root.into();
        let manifest = root.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading catalog manifest {}", manifest.display()))?;
        Ok(manifest_rows(&text, &manifest)?
            .into_iter()
            .map(|(n, _, _, _)| n)
            .collect())
    }

    /// Open the catalog at `root`, or create it if no manifest exists.
    pub fn open_or_create(root: impl Into<PathBuf>) -> Result<Catalog> {
        let root = root.into();
        if root.join(MANIFEST_FILE).exists() {
            Self::open(root)
        } else {
            Self::create(root)
        }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Collection names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    pub fn get(&self, name: &str) -> Option<&CatalogEntry> {
        self.entries.get(name)
    }

    /// Iterate collections in name order.
    pub fn entries(&self) -> impl Iterator<Item = &CatalogEntry> {
        self.entries.values()
    }

    /// Build `spec` over `keys`, persist the artifact under the catalog
    /// root and register it as `name`.
    pub fn build_collection(
        &mut self,
        name: &str,
        spec: &IndexSpec,
        keys: &Tensor,
        ctx: &BuildCtx,
    ) -> Result<&CatalogEntry> {
        ensure!(
            valid_name(name),
            "collection name '{name}' must be non-empty and use only [A-Za-z0-9._-]"
        );
        ensure!(
            !self.entries.contains_key(name),
            "collection '{name}' already exists in {}",
            self.root.display()
        );
        let index = spec.build(keys, ctx)?;
        let path = self.root.join(format!("{name}.{}", artifact::EXTENSION));
        artifact::save(&path, index.as_ref())?;
        self.entries.insert(
            name.to_string(),
            CatalogEntry {
                name: name.to_string(),
                spec: spec.clone(),
                path,
                index: Arc::from(index),
                mutable: None,
                mapper_path: None,
                mapper: None,
            },
        );
        self.write_manifest()?;
        Ok(self.entries.get(name).expect("just inserted"))
    }

    /// Build `spec` over `keys` and register it in the catalog at
    /// `root` without deserializing any existing artifact (manifest
    /// rows are parsed, not loaded) — appending to a large catalog
    /// costs only the new index. Creates the catalog if absent.
    pub fn append_collection(
        root: impl Into<PathBuf>,
        name: &str,
        spec: &IndexSpec,
        keys: &Tensor,
        ctx: &BuildCtx,
    ) -> Result<CatalogEntry> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating catalog dir {}", root.display()))?;
        ensure!(
            valid_name(name),
            "collection name '{name}' must be non-empty and use only [A-Za-z0-9._-]"
        );
        let manifest = root.join(MANIFEST_FILE);
        let mut rows = if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading catalog manifest {}", manifest.display()))?;
            manifest_rows(&text, &manifest)?
        } else {
            Vec::new()
        };
        ensure!(
            !rows.iter().any(|(n, _, _, _)| n == name),
            "collection '{name}' already exists in {}",
            root.display()
        );
        let index = spec.build(keys, ctx)?;
        let file = format!("{name}.{}", artifact::EXTENSION);
        let path = root.join(&file);
        artifact::save(&path, index.as_ref())?;
        rows.push((name.to_string(), spec.clone(), file, None));
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        write_manifest_rows(&root, &rows)?;
        Ok(CatalogEntry {
            name: name.to_string(),
            spec: spec.clone(),
            path,
            index: Arc::from(index),
            mutable: None,
            mapper_path: None,
            mapper: None,
        })
    }

    /// Initialize an empty *mutable* collection (generation 0) and
    /// register it in the catalog at `root`. Manifest-append style
    /// like [`Catalog::append_collection`]: no existing artifact is
    /// deserialized, and the catalog is created if absent. The `spec`
    /// is what future compactions build with; `dim` is fixed for the
    /// collection's lifetime.
    pub fn create_mutable(
        root: impl Into<PathBuf>,
        name: &str,
        spec: &IndexSpec,
        dim: usize,
        seed: u64,
    ) -> Result<CatalogEntry> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating catalog dir {}", root.display()))?;
        ensure!(
            valid_name(name),
            "collection name '{name}' must be non-empty and use only [A-Za-z0-9._-]"
        );
        let manifest = root.join(MANIFEST_FILE);
        let mut rows = if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading catalog manifest {}", manifest.display()))?;
            manifest_rows(&text, &manifest)?
        } else {
            Vec::new()
        };
        ensure!(
            !rows.iter().any(|(n, _, _, _)| n == name),
            "collection '{name}' already exists in {}",
            root.display()
        );
        let file = format!("{name}{MUTABLE_SUFFIX}");
        let path = root.join(&file);
        let coll = Arc::new(MutableCollection::create(&path, spec.clone(), dim, seed)?);
        rows.push((name.to_string(), spec.clone(), file, None));
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        write_manifest_rows(&root, &rows)?;
        Ok(CatalogEntry {
            name: name.to_string(),
            spec: spec.clone(),
            path,
            index: coll.clone() as Arc<dyn VectorIndex>,
            mutable: Some(coll),
            mapper_path: None,
            mapper: None,
        })
    }

    /// The mutable handle of a loaded collection, if it is one.
    pub fn mutable(&self, name: &str) -> Option<&Arc<MutableCollection>> {
        self.entries.get(name)?.mutable.as_ref()
    }

    /// Persist `model` as the query mapper of an existing collection:
    /// the model artifact is written next to the index artifact and the
    /// manifest row gains the mapper column. Manifest-only (no index
    /// artifact is deserialized); the mapper must be a c=1 model whose
    /// dimension matches the collection header. Returns the artifact
    /// path.
    pub fn attach_mapper(
        root: impl Into<PathBuf>,
        name: &str,
        model: &RustModel,
    ) -> Result<PathBuf> {
        let root = root.into();
        let manifest = root.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading catalog manifest {}", manifest.display()))?;
        let mut rows = manifest_rows(&text, &manifest)?;
        let row = rows
            .iter_mut()
            .find(|(n, _, _, _)| n == name)
            .ok_or_else(|| {
                anyhow::anyhow!("catalog {} has no collection '{name}'", root.display())
            })?;
        ensure!(
            model.n_heads() == 1,
            "query mapper '{}' must have c=1, got c={}",
            model.label(),
            model.n_heads()
        );
        ensure!(
            !row.2.ends_with(MUTABLE_SUFFIX),
            "collection '{name}' is mutable; attaching query mappers to mutable collections is not supported yet"
        );
        // validate the dimension against the index artifact header only
        // (cheap: no payload is decoded)
        let index_path = root.join(&row.2);
        let f = std::fs::File::open(&index_path)
            .with_context(|| format!("opening index artifact {}", index_path.display()))?;
        let header = artifact::read_header(&mut std::io::BufReader::new(f))
            .with_context(|| format!("reading index artifact {}", index_path.display()))?;
        ensure!(
            model.dim() == header.dim,
            "mapper dim {} != collection '{name}' dim {}",
            model.dim(),
            header.dim
        );
        let file = format!("{name}.map.{}", model::artifact::EXTENSION);
        let path = root.join(&file);
        model::artifact::save(&path, model)?;
        row.3 = Some(file);
        write_manifest_rows(&root, &rows)?;
        Ok(path)
    }

    fn write_manifest(&self) -> Result<()> {
        let rows: Vec<ManifestRow> = self
            .entries
            .values()
            .map(|e| {
                let file = e
                    .path
                    .file_name()
                    .and_then(|f| f.to_str())
                    .context("artifact path has no utf8 file name")?;
                let mapper = match &e.mapper_path {
                    Some(p) => Some(
                        p.file_name()
                            .and_then(|f| f.to_str())
                            .context("mapper path has no utf8 file name")?
                            .to_string(),
                    ),
                    None => None,
                };
                Ok((e.name.clone(), e.spec.clone(), file.to_string(), mapper))
            })
            .collect::<Result<_>>()?;
        write_manifest_rows(&self.root, &rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_validation() {
        assert!(valid_name("docs-v2.ivf_main"));
        assert!(!valid_name(""));
        assert!(!valid_name("has space"));
        assert!(!valid_name("sub/dir"));
        assert!(!valid_name("tab\tname"));
    }

    #[test]
    fn mutable_collection_round_trips_through_manifest() {
        use crate::util::{Rng, TempDir};
        let tmp = TempDir::new("catalog-mut");
        let spec = IndexSpec::default_for("flat").unwrap();
        let entry = Catalog::create_mutable(tmp.path(), "mut", &spec, 8, 7).unwrap();
        assert!(entry.path.is_dir());
        let coll = entry.mutable.as_ref().unwrap();
        let mut keys = Tensor::zeros(&[12, 8]);
        Rng::new(1).fill_normal(keys.data_mut(), 1.0);
        coll.insert(&keys).unwrap();
        coll.commit().unwrap();
        // duplicate registration is refused
        assert!(Catalog::create_mutable(tmp.path(), "mut", &spec, 8, 7).is_err());
        // full reopen loads the committed generation behind the same API
        let cat = Catalog::open(tmp.path()).unwrap();
        let got = cat.get("mut").unwrap();
        assert_eq!((got.index.len(), got.index.dim()), (12, 8));
        assert_eq!(got.index.name(), "mutable");
        assert!(cat.mutable("mut").is_some());
        assert!(cat.mutable("missing").is_none());
        // single-collection open works too and stays typed
        let one = Catalog::open_collection(tmp.path(), "mut").unwrap();
        assert!(one.mutable.is_some());
    }
}
