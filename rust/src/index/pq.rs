//! Product quantization (Jégou et al. 2011) with optional *anisotropic*
//! codebook training (Guo et al. 2020) — the compression engine behind
//! the ScaNN-analog backbone.
//!
//! Vectors are split into `m` subvectors of `dsub = d/m` dims; each
//! subspace gets a 256-entry codebook (one byte per subvector). Scoring a
//! query against a code is `m` table lookups after one table build of
//! `m * 256 * dsub` multiply-adds per query (ADC — asymmetric distance
//! computation).
//!
//! Anisotropic training reweights the k-means objective so error
//! *parallel* to the data vector (which perturbs inner products with
//! correlated queries the most) costs `eta`x more than orthogonal error —
//! the ScaNN insight, implemented here as anisotropically re-weighted
//! Lloyd updates in each subspace.

use std::io::{Read, Write};

use anyhow::{ensure, Result};

use crate::api::Effort;
use crate::index::artifact;
use crate::index::spec::{IndexSpec, PqSpec};
use crate::index::traits::{rerank_depth, SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::{dot, gemm_nt_tile, Tensor};
use crate::util::Rng;

/// Trained product quantizer.
pub struct Pq {
    pub m: usize,
    pub dsub: usize,
    /// [m, 256, dsub] codebooks flattened.
    codebooks: Vec<f32>,
}

pub const CODE_K: usize = 256;

impl Pq {
    /// Train on `x` [n, d]. `eta` > 1 enables anisotropic weighting
    /// (parallel-error penalty); `eta = 1` is classic PQ.
    pub fn train(x: &Tensor, m: usize, iters: usize, eta: f32, seed: u64) -> Pq {
        let (n, d) = (x.rows(), x.row_width());
        assert!(d % m == 0, "d={d} must divide into m={m} subspaces");
        let dsub = d / m;
        let k = CODE_K.min(n.max(2));
        let mut rng = Rng::new(seed);
        let mut codebooks = vec![0.0f32; m * CODE_K * dsub];

        // Precompute per-vector norms for anisotropic weighting.
        let norms: Vec<f32> = (0..n)
            .map(|i| dot(x.row(i), x.row(i)).sqrt().max(1e-9))
            .collect();

        for sub in 0..m {
            let col0 = sub * dsub;
            // init codewords from random samples
            for c in 0..k {
                let pick = rng.below(n);
                let src = &x.row(pick)[col0..col0 + dsub];
                codebooks[(sub * CODE_K + c) * dsub..][..dsub].copy_from_slice(src);
            }
            let mut assign = vec![0usize; n];
            for _ in 0..iters {
                // assignment: nearest codeword by (weighted) L2
                for i in 0..n {
                    let v = &x.row(i)[col0..col0 + dsub];
                    let mut best = (0usize, f32::MAX);
                    for c in 0..k {
                        let cw = &codebooks[(sub * CODE_K + c) * dsub..][..dsub];
                        let err = Self::weighted_err(v, cw, x.row(i), col0, norms[i], eta);
                        if err < best.1 {
                            best = (c, err);
                        }
                    }
                    assign[i] = best.0;
                }
                // update: (weighted) mean per codeword
                let mut sums = vec![0.0f64; k * dsub];
                let mut wsum = vec![0.0f64; k];
                for i in 0..n {
                    let c = assign[i];
                    let v = &x.row(i)[col0..col0 + dsub];
                    // weight anisotropic updates toward high-norm points
                    let w = if eta > 1.0 { norms[i] as f64 } else { 1.0 };
                    wsum[c] += w;
                    for j in 0..dsub {
                        sums[c * dsub + j] += v[j] as f64 * w;
                    }
                }
                for c in 0..k {
                    if wsum[c] > 0.0 {
                        for j in 0..dsub {
                            codebooks[(sub * CODE_K + c) * dsub + j] =
                                (sums[c * dsub + j] / wsum[c]) as f32;
                        }
                    } else {
                        let pick = rng.below(n);
                        let src = &x.row(pick)[col0..col0 + dsub];
                        codebooks[(sub * CODE_K + c) * dsub..][..dsub].copy_from_slice(src);
                    }
                }
            }
        }
        Pq { m, dsub, codebooks }
    }

    /// Anisotropic quantization error for a candidate codeword: decompose
    /// the subspace residual into components parallel/orthogonal to the
    /// (subspace slice of the) data direction, penalize parallel by eta.
    #[inline]
    fn weighted_err(v: &[f32], cw: &[f32], full: &[f32], col0: usize, norm: f32, eta: f32) -> f32 {
        let dsub = v.len();
        if eta <= 1.0 {
            let mut e = 0.0;
            for j in 0..dsub {
                let r = v[j] - cw[j];
                e += r * r;
            }
            return e;
        }
        // residual and its projection on the data direction (subslice)
        let dir = &full[col0..col0 + dsub];
        let mut r2 = 0.0f32;
        let mut rp = 0.0f32;
        for j in 0..dsub {
            let r = v[j] - cw[j];
            r2 += r * r;
            rp += r * dir[j];
        }
        let par = (rp / norm) * (rp / norm);
        let orth = (r2 - par).max(0.0);
        eta * par + orth
    }

    /// Encode all rows of `x` -> [n, m] bytes.
    pub fn encode(&self, x: &Tensor) -> Vec<u8> {
        let (n, d) = (x.rows(), x.row_width());
        assert_eq!(d, self.m * self.dsub);
        let mut codes = vec![0u8; n * self.m];
        for i in 0..n {
            for sub in 0..self.m {
                let col0 = sub * self.dsub;
                let v = &x.row(i)[col0..col0 + self.dsub];
                let mut best = (0usize, f32::MAX);
                for c in 0..CODE_K {
                    let cw = &self.codebooks[(sub * CODE_K + c) * self.dsub..][..self.dsub];
                    let mut e = 0.0;
                    for j in 0..self.dsub {
                        let r = v[j] - cw[j];
                        e += r * r;
                    }
                    if e < best.1 {
                        best = (c, e);
                    }
                }
                codes[i * self.m + sub] = best.0 as u8;
            }
        }
        codes
    }

    /// Build the ADC lookup table for a query: [m, 256] inner products.
    pub fn adc_table(&self, query: &[f32]) -> Vec<f32> {
        assert_eq!(query.len(), self.m * self.dsub);
        let mut table = vec![0.0f32; self.m * CODE_K];
        for sub in 0..self.m {
            let q = &query[sub * self.dsub..(sub + 1) * self.dsub];
            for c in 0..CODE_K {
                let cw = &self.codebooks[(sub * CODE_K + c) * self.dsub..][..self.dsub];
                table[sub * CODE_K + c] = dot(q, cw);
            }
        }
        table
    }

    /// Build the ADC tables for a whole query batch — `[b, m*256]`
    /// rows, each laid out exactly like one [`Pq::adc_table`] — with
    /// one [`gemm_nt_tile`] per subspace over the 256 codewords, so a
    /// subspace codebook is streamed once per *batch* instead of once
    /// per query. Scores go through the same `dot` as `adc_table`, so
    /// each row is bit-identical to the per-query table.
    pub fn adc_tables_batch(&self, queries: &Tensor) -> Vec<f32> {
        let b = queries.rows();
        let (m, dsub) = (self.m, self.dsub);
        assert_eq!(queries.row_width(), m * dsub);
        let mut tables = vec![0.0f32; b * m * CODE_K];
        let mut qsub = vec![0.0f32; b * dsub];
        let mut block = vec![0.0f32; b * CODE_K];
        for sub in 0..m {
            for q in 0..b {
                qsub[q * dsub..(q + 1) * dsub]
                    .copy_from_slice(&queries.row(q)[sub * dsub..(sub + 1) * dsub]);
            }
            let cb = &self.codebooks[sub * CODE_K * dsub..(sub + 1) * CODE_K * dsub];
            gemm_nt_tile(&qsub, cb, dsub, &mut block);
            for q in 0..b {
                tables[q * m * CODE_K + sub * CODE_K..][..CODE_K]
                    .copy_from_slice(&block[q * CODE_K..(q + 1) * CODE_K]);
            }
        }
        tables
    }

    /// Approximate inner product of the query (via its ADC table) with a
    /// stored code.
    #[inline]
    pub fn adc_score(&self, table: &[f32], code: &[u8]) -> f32 {
        let mut s = 0.0;
        for sub in 0..self.m {
            s += table[sub * CODE_K + code[sub] as usize];
        }
        s
    }

    /// FLOPs to build one ADC table.
    pub fn table_flops(&self) -> u64 {
        (self.m * CODE_K * self.dsub * 2) as u64
    }

    /// Reconstruct a vector from its code (testing/diagnostics).
    pub fn decode(&self, code: &[u8]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.m * self.dsub];
        for sub in 0..self.m {
            let cw = &self.codebooks[(sub * CODE_K + code[sub] as usize) * self.dsub..][..self.dsub];
            out[sub * self.dsub..(sub + 1) * self.dsub].copy_from_slice(cw);
        }
        out
    }

    /// Serialize the trained quantizer (shared by PqIndex and ScannIndex
    /// artifacts).
    pub(crate) fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        artifact::w_u64(w, self.m as u64)?;
        artifact::w_u64(w, self.dsub as u64)?;
        artifact::w_f32s(w, &self.codebooks)
    }

    /// Deserialize a trained quantizer from an artifact payload.
    pub(crate) fn read_payload(r: &mut dyn Read) -> Result<Pq> {
        let m = artifact::r_u64(r)? as usize;
        let dsub = artifact::r_u64(r)? as usize;
        ensure!(
            (1..=65_536).contains(&m) && (1..=65_536).contains(&dsub),
            "implausible PQ dims m={m} dsub={dsub}"
        );
        let codebooks = artifact::r_f32s(r)?;
        ensure!(
            codebooks.len() == m * CODE_K * dsub,
            "PQ codebook size {} != m*{CODE_K}*dsub ({m}*{CODE_K}*{dsub})",
            codebooks.len()
        );
        Ok(Pq { m, dsub, codebooks })
    }
}

/// Flat product-quantized index (the FAISS `IndexPQ` analog): one ADC
/// scan over every code, then exact re-rank of the best candidates.
/// No coarse cells — the [`Effort`] knob instead scales the re-rank
/// depth: `Probes(p)` multiplies the base depth by `p`, `Frac(f)`
/// re-ranks `⌈f·n⌉` candidates, and `Exhaustive` re-ranks everything
/// (exact).
pub struct PqIndex {
    d: usize,
    pq: Pq,
    codes: Vec<u8>, // [n, m]
    /// Full-precision keys for exact re-ranking.
    keys: Tensor,
    /// Default re-rank depth under `Effort::Auto` / `Effort::Probes`.
    pub rerank: usize,
    /// Codebook training iterations (spec echo).
    iters: usize,
    /// Anisotropic parallel-error weight (spec echo).
    eta: f32,
}

impl PqIndex {
    pub fn build(keys: &Tensor, m: usize, iters: usize, eta: f32, seed: u64) -> PqIndex {
        let pq = Pq::train(keys, m, iters, eta, seed);
        let codes = pq.encode(keys);
        PqIndex {
            d: keys.row_width(),
            pq,
            codes,
            keys: keys.clone(),
            rerank: 32,
            iters,
            eta,
        }
    }

    /// Deserialize from an artifact payload (see [`crate::index::artifact`]).
    pub(crate) fn read_payload(r: &mut dyn Read) -> Result<PqIndex> {
        let d = artifact::r_u64(r)? as usize;
        let pq = Pq::read_payload(r)?;
        let codes = artifact::r_u8s(r)?;
        let keys = artifact::r_tensor(r)?;
        let rerank = artifact::r_u64(r)? as usize;
        let iters = artifact::r_u64(r)? as usize;
        let eta = artifact::r_f32(r)?;
        ensure!(
            d == pq.m * pq.dsub
                && keys.row_width() == d
                && codes.len() == keys.rows() * pq.m,
            "inconsistent PQ payload: d={d}, m={}, dsub={}, {} codes, {} keys",
            pq.m,
            pq.dsub,
            codes.len(),
            keys.rows()
        );
        Ok(PqIndex {
            d,
            pq,
            codes,
            keys,
            rerank,
            iters,
            eta,
        })
    }

    /// Stage 2 shared by the per-query and batched paths: exact re-rank
    /// of the ADC candidates plus the cost assembly.
    fn rerank_exact(&self, query: &[f32], cand: TopK, k: usize, n: usize) -> SearchResult {
        let (cand_ids, _) = cand.into_sorted();
        let mut top = TopK::new(k);
        for &id in &cand_ids {
            top.offer(dot(query, self.keys.row(id as usize)), id);
        }
        let (ids, scores) = top.into_sorted();
        let flops = self.pq.table_flops()
            + (n * self.pq.m) as u64              // lookups+adds
            + (cand_ids.len() * self.d * 2) as u64; // re-rank
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops,
                keys_scanned: n as u64,
                cells_probed: 0,
            },
        }
    }
}

impl VectorIndex for PqIndex {
    fn name(&self) -> &str {
        "pq"
    }

    fn len(&self) -> usize {
        if self.pq.m == 0 {
            0
        } else {
            self.codes.len() / self.pq.m
        }
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        let n = self.len();
        let m = self.pq.m;
        let rerank = rerank_depth(n, k, self.rerank, effort);
        // 1. ADC scan of every code
        let table = self.pq.adc_table(query);
        let mut cand = TopK::new(rerank);
        for i in 0..n {
            let score = self.pq.adc_score(&table, &self.codes[i * m..(i + 1) * m]);
            cand.offer(score, i as u32);
        }
        // 2. exact re-rank
        self.rerank_exact(query, cand, k, n)
    }

    /// Fused batched ADC: build all tables in one pass
    /// ([`Pq::adc_tables_batch`] — one codeword gemm per subspace), then
    /// scan the code matrix once, scoring every query against each code
    /// row while it is hot. Bit-identical to per-query
    /// [`PqIndex::search_effort`].
    fn search_batch_effort(&self, queries: &Tensor, k: usize, effort: Effort) -> Vec<SearchResult> {
        let b = queries.rows();
        if b == 0 {
            return Vec::new();
        }
        let n = self.len();
        let m = self.pq.m;
        let rerank = rerank_depth(n, k, self.rerank, effort);
        // Exhaustive-depth rerank would hold `b` candidate heaps of
        // capacity n at once; the per-row scan is bit-identical and
        // peaks at one heap (the exact re-rank dominates there anyway).
        if rerank >= n.max(1) {
            return (0..b)
                .map(|q| self.search_effort(queries.row(q), k, effort))
                .collect();
        }
        let tables = self.pq.adc_tables_batch(queries);
        let tw = m * CODE_K;
        let mut cands: Vec<TopK> = (0..b).map(|_| TopK::new(rerank)).collect();
        for i in 0..n {
            let code = &self.codes[i * m..(i + 1) * m];
            for (q, cand) in cands.iter_mut().enumerate() {
                cand.offer(self.pq.adc_score(&tables[q * tw..(q + 1) * tw], code), i as u32);
            }
        }
        cands
            .into_iter()
            .enumerate()
            .map(|(q, cand)| self.rerank_exact(queries.row(q), cand, k, n))
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Pq(PqSpec {
            m: Some(self.pq.m),
            iters: self.iters,
            eta: self.eta,
        })
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        artifact::w_u64(w, self.d as u64)?;
        self.pq.write_payload(w)?;
        artifact::w_u8s(w, &self.codes)?;
        artifact::w_tensor(w, &self.keys)?;
        artifact::w_u64(w, self.rerank as u64)?;
        artifact::w_u64(w, self.iters as u64)?;
        artifact::w_f32(w, self.eta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;

    fn unit_keys(n: usize, d: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn adc_approximates_inner_product() {
        let keys = unit_keys(500, 32, 1);
        let pq = Pq::train(&keys, 8, 8, 1.0, 2);
        let codes = pq.encode(&keys);
        let q = unit_keys(20, 32, 3);
        let mut err = 0.0f64;
        for i in 0..20 {
            let table = pq.adc_table(q.row(i));
            for kidx in 0..500 {
                let approx = pq.adc_score(&table, &codes[kidx * 8..(kidx + 1) * 8]);
                let exact = dot(q.row(i), keys.row(kidx));
                err += ((approx - exact) as f64).abs();
            }
        }
        let mae = err / (20.0 * 500.0);
        assert!(mae < 0.15, "ADC mean abs err {mae}");
    }

    #[test]
    fn decode_roundtrip_close() {
        let keys = unit_keys(300, 16, 4);
        let pq = Pq::train(&keys, 4, 10, 1.0, 5);
        let codes = pq.encode(&keys);
        let mut mse = 0.0f64;
        for i in 0..300 {
            let rec = pq.decode(&codes[i * 4..(i + 1) * 4]);
            for (a, b) in rec.iter().zip(keys.row(i)) {
                mse += ((a - b) as f64).powi(2);
            }
        }
        mse /= 300.0 * 16.0;
        assert!(mse < 0.05, "reconstruction mse {mse}");
    }

    #[test]
    fn anisotropic_beats_plain_on_inner_product() {
        // eta>1 should reduce inner-product estimation error for queries
        // correlated with the keys (the MIPS regime).
        let keys = unit_keys(600, 32, 6);
        let plain = Pq::train(&keys, 4, 10, 1.0, 7);
        let aniso = Pq::train(&keys, 4, 10, 4.0, 7);
        // queries = noisy keys (correlated)
        let mut q = keys.gather_rows(&(0..50).collect::<Vec<_>>());
        Rng::new(8).fill_normal(&mut q.data_mut()[..0], 0.0); // no-op, keep q
        let eval = |pq: &Pq| -> f64 {
            let codes = pq.encode(&keys);
            let mut err = 0.0f64;
            for i in 0..50 {
                let t = pq.adc_table(q.row(i));
                for kidx in 0..600 {
                    let approx = pq.adc_score(&t, &codes[kidx * 4..(kidx + 1) * 4]);
                    let exact = dot(q.row(i), keys.row(kidx));
                    err += ((approx - exact) as f64).powi(2);
                }
            }
            err
        };
        let (ep, ea) = (eval(&plain), eval(&aniso));
        // anisotropic should not be significantly worse
        assert!(ea < ep * 1.25, "plain {ep} aniso {ea}");
    }

    #[test]
    fn table_flops_positive() {
        let keys = unit_keys(300, 16, 9);
        let pq = Pq::train(&keys, 4, 4, 1.0, 10);
        assert_eq!(pq.table_flops(), (4 * 256 * 4 * 2) as u64);
    }

    #[test]
    fn pq_index_exhaustive_is_exact() {
        let keys = unit_keys(400, 32, 11);
        let idx = PqIndex::build(&keys, 8, 8, 1.0, 12);
        let q = unit_keys(10, 32, 13);
        for i in 0..10 {
            let res = idx.search_effort(q.row(i), 1, Effort::Exhaustive);
            // oracle: exact argmax
            let mut best = (0u32, f32::NEG_INFINITY);
            for kidx in 0..400 {
                let s = dot(q.row(i), keys.row(kidx));
                if s > best.1 {
                    best = (kidx as u32, s);
                }
            }
            assert_eq!(res.ids[0], best.0, "query {i}");
            assert!((res.scores[0] - best.1).abs() < 1e-5);
        }
    }

    #[test]
    fn batch_adc_tables_match_per_query_tables() {
        let keys = unit_keys(300, 32, 20);
        let pq = Pq::train(&keys, 8, 6, 1.0, 21);
        let q = unit_keys(9, 32, 22);
        let tables = pq.adc_tables_batch(&q);
        let tw = 8 * CODE_K;
        for i in 0..9 {
            assert_eq!(
                &tables[i * tw..(i + 1) * tw],
                &pq.adc_table(q.row(i))[..],
                "query {i}"
            );
        }
    }

    #[test]
    fn batched_search_is_bit_identical_to_per_query() {
        let keys = unit_keys(250, 16, 23);
        let idx = PqIndex::build(&keys, 4, 6, 1.0, 24);
        let q = unit_keys(6, 16, 25);
        for effort in [Effort::Auto, Effort::Probes(3), Effort::Exhaustive] {
            let batched = idx.search_batch_effort(&q, 4, effort);
            for i in 0..6 {
                let single = idx.search_effort(q.row(i), 4, effort);
                assert_eq!(batched[i].ids, single.ids, "{effort:?} query {i}");
                assert_eq!(batched[i].scores, single.scores, "{effort:?} query {i}");
                assert_eq!(batched[i].cost, single.cost, "{effort:?} query {i}");
            }
        }
    }

    #[test]
    fn pq_index_effort_scales_rerank_cost() {
        let keys = unit_keys(300, 16, 14);
        let idx = PqIndex::build(&keys, 4, 6, 1.0, 15);
        let q = unit_keys(1, 16, 16);
        let cheap = idx.search_effort(q.row(0), 1, Effort::Auto).cost;
        let scaled = idx.search_effort(q.row(0), 1, Effort::Probes(4)).cost;
        let full = idx.search_effort(q.row(0), 1, Effort::Exhaustive).cost;
        // Probes(p) widens the exact re-rank, so the effort axis is real
        assert!(scaled.flops > cheap.flops);
        assert!(full.flops >= scaled.flops);
        assert_eq!(cheap.keys_scanned, 300);
        assert_eq!(full.keys_scanned, 300);
    }
}
