//! Product quantization (Jégou et al. 2011) with optional *anisotropic*
//! codebook training (Guo et al. 2020) — the compression engine behind
//! the ScaNN-analog backbone.
//!
//! Vectors are split into `m` subvectors of `dsub = d/m` dims; each
//! subspace gets a `2^bits`-entry codebook. At the default `bits=8`
//! that is one byte per subvector (256 codewords); `bits=4` packs two
//! subspace codes per byte (16 codewords), halving code storage.
//! Scoring a query against a code is `m` table lookups after one table
//! build of `m * 2^bits * dsub` multiply-adds per query (ADC —
//! asymmetric distance computation). The code-matrix scan dispatches
//! through [`crate::tensor::kernels`] (`adc_scan8`/`adc_scan4`).
//!
//! Anisotropic training reweights the k-means objective so error
//! *parallel* to the data vector (which perturbs inner products with
//! correlated queries the most) costs `eta`x more than orthogonal error —
//! the ScaNN insight, implemented here as anisotropically re-weighted
//! Lloyd updates in each subspace.

use std::io::{Read, Write};

use anyhow::{ensure, Result};

use crate::api::Effort;
use crate::index::artifact::{self, Src};
use crate::index::spec::{IndexSpec, PqSpec};
use crate::index::traits::{rerank_depth, SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::mapped::Section;
use crate::tensor::{dot, gemm_nt_tile, kernels, Tensor};
use crate::util::Rng;

/// Trained product quantizer.
pub struct Pq {
    pub m: usize,
    pub dsub: usize,
    /// Per-subspace code width in bits (8 or 4).
    bits: usize,
    /// [m, 2^bits, dsub] codebooks flattened.
    codebooks: Vec<f32>,
}

/// Codewords per subspace at the default 8-bit code width.
pub const CODE_K: usize = 256;

impl Pq {
    /// Train on `x` [n, d] with the default 8-bit codes. `eta` > 1
    /// enables anisotropic weighting (parallel-error penalty); `eta = 1`
    /// is classic PQ.
    pub fn train(x: &Tensor, m: usize, iters: usize, eta: f32, seed: u64) -> Pq {
        Self::train_with_bits(x, m, iters, eta, 8, seed)
    }

    /// [`Pq::train`] with an explicit per-subspace code width
    /// (`bits` ∈ {4, 8}; the `bits=` spec knob).
    pub fn train_with_bits(
        x: &Tensor,
        m: usize,
        iters: usize,
        eta: f32,
        bits: usize,
        seed: u64,
    ) -> Pq {
        let (n, d) = (x.rows(), x.row_width());
        assert!(d % m == 0, "d={d} must divide into m={m} subspaces");
        assert!(bits == 8 || bits == 4, "bits={bits} must be 4 or 8");
        let dsub = d / m;
        let kk = 1usize << bits;
        let k = kk.min(n.max(2));
        let mut rng = Rng::new(seed);
        let mut codebooks = vec![0.0f32; m * kk * dsub];

        // Precompute per-vector norms for anisotropic weighting.
        let norms: Vec<f32> = (0..n)
            .map(|i| dot(x.row(i), x.row(i)).sqrt().max(1e-9))
            .collect();

        for sub in 0..m {
            let col0 = sub * dsub;
            // init codewords from random samples
            for c in 0..k {
                let pick = rng.below(n);
                let src = &x.row(pick)[col0..col0 + dsub];
                codebooks[(sub * kk + c) * dsub..][..dsub].copy_from_slice(src);
            }
            let mut assign = vec![0usize; n];
            for _ in 0..iters {
                // assignment: nearest codeword by (weighted) L2
                for i in 0..n {
                    let v = &x.row(i)[col0..col0 + dsub];
                    let mut best = (0usize, f32::MAX);
                    for c in 0..k {
                        let cw = &codebooks[(sub * kk + c) * dsub..][..dsub];
                        let err = Self::weighted_err(v, cw, x.row(i), col0, norms[i], eta);
                        if err < best.1 {
                            best = (c, err);
                        }
                    }
                    assign[i] = best.0;
                }
                // update: (weighted) mean per codeword
                let mut sums = vec![0.0f64; k * dsub];
                let mut wsum = vec![0.0f64; k];
                for i in 0..n {
                    let c = assign[i];
                    let v = &x.row(i)[col0..col0 + dsub];
                    // weight anisotropic updates toward high-norm points
                    let w = if eta > 1.0 { norms[i] as f64 } else { 1.0 };
                    wsum[c] += w;
                    for j in 0..dsub {
                        sums[c * dsub + j] += v[j] as f64 * w;
                    }
                }
                for c in 0..k {
                    if wsum[c] > 0.0 {
                        for j in 0..dsub {
                            codebooks[(sub * kk + c) * dsub + j] =
                                (sums[c * dsub + j] / wsum[c]) as f32;
                        }
                    } else {
                        let pick = rng.below(n);
                        let src = &x.row(pick)[col0..col0 + dsub];
                        codebooks[(sub * kk + c) * dsub..][..dsub].copy_from_slice(src);
                    }
                }
            }
        }
        Pq {
            m,
            dsub,
            bits,
            codebooks,
        }
    }

    /// Per-subspace code width in bits (8 or 4).
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Codewords per subspace (`2^bits`).
    #[inline]
    pub fn kk(&self) -> usize {
        1 << self.bits
    }

    /// Bytes per encoded vector: `m` at 8 bits, `⌈m/2⌉` at 4 bits
    /// (two subspace codes per byte, low nibble first).
    #[inline]
    pub fn code_width(&self) -> usize {
        match self.bits {
            8 => self.m,
            _ => self.m.div_ceil(2),
        }
    }

    /// f32 entries in one ADC table: `m * 2^bits`.
    #[inline]
    pub fn table_width(&self) -> usize {
        self.m * self.kk()
    }

    /// The code of subspace `sub` inside one encoded row.
    #[inline]
    fn code_at(&self, code: &[u8], sub: usize) -> usize {
        match self.bits {
            8 => code[sub] as usize,
            _ => {
                let byte = code[sub >> 1];
                (if sub & 1 == 0 { byte & 0x0F } else { byte >> 4 }) as usize
            }
        }
    }

    /// Anisotropic quantization error for a candidate codeword: decompose
    /// the subspace residual into components parallel/orthogonal to the
    /// (subspace slice of the) data direction, penalize parallel by eta.
    #[inline]
    fn weighted_err(v: &[f32], cw: &[f32], full: &[f32], col0: usize, norm: f32, eta: f32) -> f32 {
        let dsub = v.len();
        if eta <= 1.0 {
            let mut e = 0.0;
            for j in 0..dsub {
                let r = v[j] - cw[j];
                e += r * r;
            }
            return e;
        }
        // residual and its projection on the data direction (subslice)
        let dir = &full[col0..col0 + dsub];
        let mut r2 = 0.0f32;
        let mut rp = 0.0f32;
        for j in 0..dsub {
            let r = v[j] - cw[j];
            r2 += r * r;
            rp += r * dir[j];
        }
        let par = (rp / norm) * (rp / norm);
        let orth = (r2 - par).max(0.0);
        eta * par + orth
    }

    /// Encode all rows of `x` -> [n, code_width] bytes (nibble-packed
    /// at 4 bits).
    pub fn encode(&self, x: &Tensor) -> Vec<u8> {
        let (n, d) = (x.rows(), x.row_width());
        assert_eq!(d, self.m * self.dsub);
        let kk = self.kk();
        let cw_len = self.code_width();
        let mut codes = vec![0u8; n * cw_len];
        for i in 0..n {
            for sub in 0..self.m {
                let col0 = sub * self.dsub;
                let v = &x.row(i)[col0..col0 + self.dsub];
                let mut best = (0usize, f32::MAX);
                for c in 0..kk {
                    let cw = &self.codebooks[(sub * kk + c) * self.dsub..][..self.dsub];
                    let mut e = 0.0;
                    for j in 0..self.dsub {
                        let r = v[j] - cw[j];
                        e += r * r;
                    }
                    if e < best.1 {
                        best = (c, e);
                    }
                }
                match self.bits {
                    8 => codes[i * cw_len + sub] = best.0 as u8,
                    _ => {
                        let slot = &mut codes[i * cw_len + (sub >> 1)];
                        if sub & 1 == 0 {
                            *slot |= best.0 as u8;
                        } else {
                            *slot |= (best.0 as u8) << 4;
                        }
                    }
                }
            }
        }
        codes
    }

    /// Build the ADC lookup table for a query: [m, 2^bits] inner
    /// products.
    pub fn adc_table(&self, query: &[f32]) -> Vec<f32> {
        assert_eq!(query.len(), self.m * self.dsub);
        let kk = self.kk();
        let mut table = vec![0.0f32; self.m * kk];
        for sub in 0..self.m {
            let q = &query[sub * self.dsub..(sub + 1) * self.dsub];
            for c in 0..kk {
                let cw = &self.codebooks[(sub * kk + c) * self.dsub..][..self.dsub];
                table[sub * kk + c] = dot(q, cw);
            }
        }
        table
    }

    /// Build the ADC tables for a whole query batch — `[b, m*2^bits]`
    /// rows, each laid out exactly like one [`Pq::adc_table`] — with
    /// one [`gemm_nt_tile`] per subspace over the codewords, so a
    /// subspace codebook is streamed once per *batch* instead of once
    /// per query. Scores go through the same `dot` as `adc_table`, so
    /// each row is bit-identical to the per-query table.
    pub fn adc_tables_batch(&self, queries: &Tensor) -> Vec<f32> {
        let b = queries.rows();
        let (m, dsub, kk) = (self.m, self.dsub, self.kk());
        assert_eq!(queries.row_width(), m * dsub);
        let mut tables = vec![0.0f32; b * m * kk];
        let mut qsub = vec![0.0f32; b * dsub];
        let mut block = vec![0.0f32; b * kk];
        for sub in 0..m {
            for q in 0..b {
                qsub[q * dsub..(q + 1) * dsub]
                    .copy_from_slice(&queries.row(q)[sub * dsub..(sub + 1) * dsub]);
            }
            let cb = &self.codebooks[sub * kk * dsub..(sub + 1) * kk * dsub];
            gemm_nt_tile(&qsub, cb, dsub, &mut block);
            for q in 0..b {
                tables[q * m * kk + sub * kk..][..kk]
                    .copy_from_slice(&block[q * kk..(q + 1) * kk]);
            }
        }
        tables
    }

    /// Approximate inner product of the query (via its ADC table) with a
    /// stored code, through the dispatched scan kernel for this code
    /// width.
    #[inline]
    pub fn adc_score(&self, table: &[f32], code: &[u8]) -> f32 {
        match self.bits {
            8 => kernels::adc_scan8(table, code),
            _ => kernels::adc_scan4(table, code, self.m),
        }
    }

    /// FLOPs to build one ADC table.
    pub fn table_flops(&self) -> u64 {
        (self.table_width() * self.dsub * 2) as u64
    }

    /// Reconstruct a vector from its code (testing/diagnostics).
    pub fn decode(&self, code: &[u8]) -> Vec<f32> {
        let kk = self.kk();
        let mut out = vec![0.0f32; self.m * self.dsub];
        for sub in 0..self.m {
            let c = self.code_at(code, sub);
            let cw = &self.codebooks[(sub * kk + c) * self.dsub..][..self.dsub];
            out[sub * self.dsub..(sub + 1) * self.dsub].copy_from_slice(cw);
        }
        out
    }

    /// Serialize the trained quantizer (shared by PqIndex and ScannIndex
    /// artifacts). Always writes the current (v2) layout, which adds the
    /// `bits` field.
    pub(crate) fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        artifact::w_u64(w, self.m as u64)?;
        artifact::w_u64(w, self.dsub as u64)?;
        artifact::w_u64(w, self.bits as u64)?;
        artifact::w_f32s(w, &self.codebooks)
    }

    /// Deserialize a trained quantizer from an artifact payload.
    /// Version-1 payloads predate the `bits` field and are always 8-bit.
    pub(crate) fn read_payload(r: &mut dyn Read, version: u32) -> Result<Pq> {
        let m = artifact::r_u64(r)? as usize;
        let dsub = artifact::r_u64(r)? as usize;
        let bits = if version < 2 {
            8
        } else {
            artifact::r_u64(r)? as usize
        };
        ensure!(
            (1..=65_536).contains(&m) && (1..=65_536).contains(&dsub),
            "implausible PQ dims m={m} dsub={dsub}"
        );
        ensure!(bits == 8 || bits == 4, "implausible PQ bits={bits}");
        let codebooks = artifact::r_f32s(r)?;
        let kk = 1usize << bits;
        ensure!(
            codebooks.len() == m * kk * dsub,
            "PQ codebook size {} != m*{kk}*dsub ({m}*{kk}*{dsub})",
            codebooks.len()
        );
        Ok(Pq {
            m,
            dsub,
            bits,
            codebooks,
        })
    }
}

/// Flat product-quantized index (the FAISS `IndexPQ` analog): one ADC
/// scan over every code, then exact re-rank of the best candidates.
/// No coarse cells — the [`Effort`] knob instead scales the re-rank
/// depth: `Probes(p)` multiplies the base depth by `p`, `Frac(f)`
/// re-ranks `⌈f·n⌉` candidates, and `Exhaustive` re-ranks everything
/// (exact).
pub struct PqIndex {
    d: usize,
    pq: Pq,
    /// [n, code_width] — a borrowed container view on the zero-copy
    /// artifact read path, owned RAM otherwise.
    codes: Section<u8>,
    /// Full-precision keys for exact re-ranking.
    keys: Tensor,
    /// Default re-rank depth under `Effort::Auto` / `Effort::Probes`.
    pub rerank: usize,
    /// Codebook training iterations (spec echo).
    iters: usize,
    /// Anisotropic parallel-error weight (spec echo).
    eta: f32,
}

impl PqIndex {
    pub fn build(
        keys: &Tensor,
        m: usize,
        iters: usize,
        eta: f32,
        bits: usize,
        seed: u64,
    ) -> PqIndex {
        let pq = Pq::train_with_bits(keys, m, iters, eta, bits, seed);
        let codes = pq.encode(keys);
        PqIndex {
            d: keys.row_width(),
            pq,
            codes: Section::owned(codes),
            keys: keys.clone(),
            rerank: 32,
            iters,
            eta,
        }
    }

    /// Deserialize from an artifact payload (see
    /// [`crate::index::artifact`]). At version ≥ 3 the code matrix and
    /// re-rank keys sit in aligned sections and come back as borrowed
    /// views of a mapped source; earlier versions decode by copy.
    pub(crate) fn read_payload(src: &mut Src, version: u32) -> Result<PqIndex> {
        let d = artifact::r_u64(&mut *src)? as usize;
        let pq = Pq::read_payload(&mut *src, version)?;
        let codes = if version >= 3 {
            artifact::r_section::<u8>(src)?
        } else {
            Section::owned(artifact::r_u8s(&mut *src)?)
        };
        let keys = if version >= 3 {
            artifact::r_tensor_v3(src)?
        } else {
            artifact::r_tensor(&mut *src)?
        };
        let rerank = artifact::r_u64(&mut *src)? as usize;
        let iters = artifact::r_u64(&mut *src)? as usize;
        let eta = artifact::r_f32(&mut *src)?;
        codes.advise_sequential();
        ensure!(
            d == pq.m * pq.dsub
                && keys.row_width() == d
                && codes.len() == keys.rows() * pq.code_width(),
            "inconsistent PQ payload: d={d}, m={}, dsub={}, bits={}, {} codes, {} keys",
            pq.m,
            pq.dsub,
            pq.bits,
            codes.len(),
            keys.rows()
        );
        Ok(PqIndex {
            d,
            pq,
            codes,
            keys,
            rerank,
            iters,
            eta,
        })
    }

    /// Stage 2 shared by the per-query and batched paths: exact re-rank
    /// of the ADC candidates plus the cost assembly.
    fn rerank_exact(&self, query: &[f32], cand: TopK, k: usize, n: usize) -> SearchResult {
        let (cand_ids, _) = cand.into_sorted();
        let mut top = TopK::new(k);
        for &id in &cand_ids {
            top.offer(dot(query, self.keys.row(id as usize)), id);
        }
        let (ids, scores) = top.into_sorted();
        let flops = self.pq.table_flops()
            + (n * self.pq.m) as u64              // lookups+adds
            + (cand_ids.len() * self.d * 2) as u64; // re-rank
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops,
                keys_scanned: n as u64,
                cells_probed: 0,
            },
        }
    }
}

impl VectorIndex for PqIndex {
    fn name(&self) -> &str {
        "pq"
    }

    fn len(&self) -> usize {
        let cw = self.pq.code_width();
        if cw == 0 {
            0
        } else {
            self.codes.len() / cw
        }
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        let n = self.len();
        let cw = self.pq.code_width();
        let rerank = rerank_depth(n, k, self.rerank, effort);
        // 1. ADC scan of every code
        let table = self.pq.adc_table(query);
        let mut cand = TopK::new(rerank);
        for i in 0..n {
            let score = self.pq.adc_score(&table, &self.codes[i * cw..(i + 1) * cw]);
            cand.offer(score, i as u32);
        }
        // 2. exact re-rank
        self.rerank_exact(query, cand, k, n)
    }

    /// Fused batched ADC: build all tables in one pass
    /// ([`Pq::adc_tables_batch`] — one codeword gemm per subspace), then
    /// scan the code matrix once, scoring every query against each code
    /// row while it is hot. Bit-identical to per-query
    /// [`PqIndex::search_effort`].
    fn search_batch_effort(&self, queries: &Tensor, k: usize, effort: Effort) -> Vec<SearchResult> {
        let b = queries.rows();
        if b == 0 {
            return Vec::new();
        }
        let n = self.len();
        let cw = self.pq.code_width();
        let rerank = rerank_depth(n, k, self.rerank, effort);
        // Exhaustive-depth rerank would hold `b` candidate heaps of
        // capacity n at once; the per-row scan is bit-identical and
        // peaks at one heap (the exact re-rank dominates there anyway).
        if rerank >= n.max(1) {
            return (0..b)
                .map(|q| self.search_effort(queries.row(q), k, effort))
                .collect();
        }
        let tables = self.pq.adc_tables_batch(queries);
        let tw = self.pq.table_width();
        let mut cands: Vec<TopK> = (0..b).map(|_| TopK::new(rerank)).collect();
        for i in 0..n {
            let code = &self.codes[i * cw..(i + 1) * cw];
            for (q, cand) in cands.iter_mut().enumerate() {
                cand.offer(self.pq.adc_score(&tables[q * tw..(q + 1) * tw], code), i as u32);
            }
        }
        cands
            .into_iter()
            .enumerate()
            .map(|(q, cand)| self.rerank_exact(queries.row(q), cand, k, n))
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Pq(PqSpec {
            m: Some(self.pq.m),
            iters: self.iters,
            eta: self.eta,
            bits: self.pq.bits,
        })
    }

    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        artifact::w_u64(w, self.d as u64)?;
        self.pq.write_payload(w)?;
        artifact::w_section_u8s(w, &self.codes)?;
        artifact::w_tensor_v3(w, &self.keys)?;
        artifact::w_u64(w, self.rerank as u64)?;
        artifact::w_u64(w, self.iters as u64)?;
        artifact::w_f32(w, self.eta)
    }

    fn zero_copy(&self) -> bool {
        self.codes.is_view() && self.keys.is_view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;

    fn unit_keys(n: usize, d: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn adc_approximates_inner_product() {
        let keys = unit_keys(500, 32, 1);
        let pq = Pq::train(&keys, 8, 8, 1.0, 2);
        let codes = pq.encode(&keys);
        let q = unit_keys(20, 32, 3);
        let mut err = 0.0f64;
        for i in 0..20 {
            let table = pq.adc_table(q.row(i));
            for kidx in 0..500 {
                let approx = pq.adc_score(&table, &codes[kidx * 8..(kidx + 1) * 8]);
                let exact = dot(q.row(i), keys.row(kidx));
                err += ((approx - exact) as f64).abs();
            }
        }
        let mae = err / (20.0 * 500.0);
        assert!(mae < 0.15, "ADC mean abs err {mae}");
    }

    #[test]
    fn four_bit_codes_pack_and_score() {
        let keys = unit_keys(400, 32, 30);
        let pq = Pq::train_with_bits(&keys, 8, 8, 1.0, 4, 31);
        assert_eq!((pq.bits(), pq.kk()), (4, 16));
        assert_eq!(pq.code_width(), 4); // 8 subspaces packed 2/byte
        assert_eq!(pq.table_width(), 8 * 16);
        let codes = pq.encode(&keys);
        assert_eq!(codes.len(), 400 * 4);
        let q = unit_keys(10, 32, 32);
        let cw = pq.code_width();
        let mut err = 0.0f64;
        for i in 0..10 {
            let table = pq.adc_table(q.row(i));
            assert_eq!(table.len(), pq.table_width());
            for kidx in 0..400 {
                let code = &codes[kidx * cw..(kidx + 1) * cw];
                let approx = pq.adc_score(&table, code);
                // adc_score must equal the manual table walk over
                // unpacked nibbles (scalar reference semantics)
                let mut manual = 0.0f32;
                for sub in 0..8 {
                    let byte = code[sub >> 1];
                    let nib = if sub & 1 == 0 { byte & 0x0F } else { byte >> 4 };
                    manual += table[sub * 16 + nib as usize];
                }
                assert!((approx - manual).abs() <= 1e-4, "key {kidx}");
                err += ((approx - dot(q.row(i), keys.row(kidx))) as f64).abs();
            }
        }
        // 16 codewords are coarse, but still informative
        let mae = err / (10.0 * 400.0);
        assert!(mae < 0.3, "4-bit ADC mean abs err {mae}");
        // decode round-trips through the packed representation
        let rec = pq.decode(&codes[..cw]);
        assert_eq!(rec.len(), 32);
    }

    #[test]
    fn odd_m_four_bit_uses_padded_final_byte() {
        let keys = unit_keys(200, 15, 33);
        let pq = Pq::train_with_bits(&keys, 5, 6, 1.0, 4, 34);
        assert_eq!(pq.code_width(), 3); // ⌈5/2⌉
        let codes = pq.encode(&keys);
        assert_eq!(codes.len(), 200 * 3);
        // the high nibble of the last byte is padding and stays zero
        for i in 0..200 {
            assert_eq!(codes[i * 3 + 2] >> 4, 0, "row {i}");
        }
        let q = unit_keys(1, 15, 35);
        let table = pq.adc_table(q.row(0));
        let s = pq.adc_score(&table, &codes[..3]);
        assert!(s.is_finite());
    }

    #[test]
    fn decode_roundtrip_close() {
        let keys = unit_keys(300, 16, 4);
        let pq = Pq::train(&keys, 4, 10, 1.0, 5);
        let codes = pq.encode(&keys);
        let mut mse = 0.0f64;
        for i in 0..300 {
            let rec = pq.decode(&codes[i * 4..(i + 1) * 4]);
            for (a, b) in rec.iter().zip(keys.row(i)) {
                mse += ((a - b) as f64).powi(2);
            }
        }
        mse /= 300.0 * 16.0;
        assert!(mse < 0.05, "reconstruction mse {mse}");
    }

    #[test]
    fn anisotropic_beats_plain_on_inner_product() {
        // eta>1 should reduce inner-product estimation error for queries
        // correlated with the keys (the MIPS regime).
        let keys = unit_keys(600, 32, 6);
        let plain = Pq::train(&keys, 4, 10, 1.0, 7);
        let aniso = Pq::train(&keys, 4, 10, 4.0, 7);
        // queries = noisy keys (correlated)
        let mut q = keys.gather_rows(&(0..50).collect::<Vec<_>>());
        Rng::new(8).fill_normal(&mut q.data_mut()[..0], 0.0); // no-op, keep q
        let eval = |pq: &Pq| -> f64 {
            let codes = pq.encode(&keys);
            let mut err = 0.0f64;
            for i in 0..50 {
                let t = pq.adc_table(q.row(i));
                for kidx in 0..600 {
                    let approx = pq.adc_score(&t, &codes[kidx * 4..(kidx + 1) * 4]);
                    let exact = dot(q.row(i), keys.row(kidx));
                    err += ((approx - exact) as f64).powi(2);
                }
            }
            err
        };
        let (ep, ea) = (eval(&plain), eval(&aniso));
        // anisotropic should not be significantly worse
        assert!(ea < ep * 1.25, "plain {ep} aniso {ea}");
    }

    #[test]
    fn table_flops_positive() {
        let keys = unit_keys(300, 16, 9);
        let pq = Pq::train(&keys, 4, 4, 1.0, 10);
        assert_eq!(pq.table_flops(), (4 * 256 * 4 * 2) as u64);
        let pq4 = Pq::train_with_bits(&keys, 4, 4, 1.0, 4, 10);
        assert_eq!(pq4.table_flops(), (4 * 16 * 4 * 2) as u64);
    }

    #[test]
    fn pq_index_exhaustive_is_exact() {
        let keys = unit_keys(400, 32, 11);
        for bits in [8usize, 4] {
            let idx = PqIndex::build(&keys, 8, 8, 1.0, bits, 12);
            let q = unit_keys(10, 32, 13);
            for i in 0..10 {
                let res = idx.search_effort(q.row(i), 1, Effort::Exhaustive);
                // oracle: exact argmax — Exhaustive re-ranks everything
                // against the exact f32 keys, so even 16-codeword ADC
                // cannot miss it
                let mut best = (0u32, f32::NEG_INFINITY);
                for kidx in 0..400 {
                    let s = dot(q.row(i), keys.row(kidx));
                    if s > best.1 {
                        best = (kidx as u32, s);
                    }
                }
                assert_eq!(res.ids[0], best.0, "bits={bits} query {i}");
                assert!((res.scores[0] - best.1).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn batch_adc_tables_match_per_query_tables() {
        let keys = unit_keys(300, 32, 20);
        for bits in [8usize, 4] {
            let pq = Pq::train_with_bits(&keys, 8, 6, 1.0, bits, 21);
            let q = unit_keys(9, 32, 22);
            let tables = pq.adc_tables_batch(&q);
            let tw = pq.table_width();
            for i in 0..9 {
                assert_eq!(
                    &tables[i * tw..(i + 1) * tw],
                    &pq.adc_table(q.row(i))[..],
                    "bits={bits} query {i}"
                );
            }
        }
    }

    #[test]
    fn batched_search_is_bit_identical_to_per_query() {
        let keys = unit_keys(250, 16, 23);
        for bits in [8usize, 4] {
            let idx = PqIndex::build(&keys, 4, 6, 1.0, bits, 24);
            let q = unit_keys(6, 16, 25);
            for effort in [Effort::Auto, Effort::Probes(3), Effort::Exhaustive] {
                let batched = idx.search_batch_effort(&q, 4, effort);
                for i in 0..6 {
                    let single = idx.search_effort(q.row(i), 4, effort);
                    assert_eq!(batched[i].ids, single.ids, "bits={bits} {effort:?} query {i}");
                    assert_eq!(
                        batched[i].scores, single.scores,
                        "bits={bits} {effort:?} query {i}"
                    );
                    assert_eq!(batched[i].cost, single.cost, "bits={bits} {effort:?} query {i}");
                }
            }
        }
    }

    #[test]
    fn pq_index_effort_scales_rerank_cost() {
        let keys = unit_keys(300, 16, 14);
        let idx = PqIndex::build(&keys, 4, 6, 1.0, 8, 15);
        let q = unit_keys(1, 16, 16);
        let cheap = idx.search_effort(q.row(0), 1, Effort::Auto).cost;
        let scaled = idx.search_effort(q.row(0), 1, Effort::Probes(4)).cost;
        let full = idx.search_effort(q.row(0), 1, Effort::Exhaustive).cost;
        // Probes(p) widens the exact re-rank, so the effort axis is real
        assert!(scaled.flops > cheap.flops);
        assert!(full.flops >= scaled.flops);
        assert_eq!(cheap.keys_scanned, 300);
        assert_eq!(full.keys_scanned, 300);
    }
}
