//! SQ8 backbone (FAISS `IndexScalarQuantizer` analog): every dimension
//! quantized to 8 bits with per-dimension affine ranges, scored by
//! dequantized inner product, followed by exact re-ranking of the best
//! candidates. 4x memory compression on the scan path with near-flat
//! recall — the simplest compressed baseline the mapped/routed paths can
//! drop onto.
//!
//! Effort translation mirrors [`crate::index::pq::PqIndex`]: no coarse
//! cells; `Effort::Probes(p)` multiplies the base re-rank depth by `p`,
//! `Effort::Frac(f)` re-ranks `⌈f·n⌉` candidates exactly and
//! `Effort::Exhaustive` re-ranks everything (exact).

use std::io::{Read, Write};

use anyhow::{ensure, Result};

use crate::api::Effort;
use crate::index::artifact;
use crate::index::spec::{IndexSpec, SqSpec};
use crate::index::traits::{rerank_depth, SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::{dot, Tensor};

pub struct SqIndex {
    d: usize,
    /// [n, d] u8 codes.
    codes: Vec<u8>,
    /// Per-dimension dequantization: value = lo[j] + scale[j] * code.
    lo: Vec<f32>,
    scale: Vec<f32>,
    /// Full-precision keys for exact re-ranking.
    keys: Tensor,
    /// Default re-rank depth under `Effort::Auto` / `Effort::Probes`.
    pub rerank: usize,
}

impl SqIndex {
    pub fn build(keys: &Tensor) -> SqIndex {
        let (n, d) = (keys.rows(), keys.row_width());
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for i in 0..n {
            for (j, &v) in keys.row(i).iter().enumerate() {
                lo[j] = lo[j].min(v);
                hi[j] = hi[j].max(v);
            }
        }
        if n == 0 {
            lo.fill(0.0);
            hi.fill(0.0);
        }
        let scale: Vec<f32> = lo
            .iter()
            .zip(&hi)
            .map(|(&l, &h)| ((h - l) / 255.0).max(f32::MIN_POSITIVE))
            .collect();
        let mut codes = vec![0u8; n * d];
        for i in 0..n {
            let row = keys.row(i);
            for j in 0..d {
                let q = ((row[j] - lo[j]) / scale[j]).round().clamp(0.0, 255.0);
                codes[i * d + j] = q as u8;
            }
        }
        SqIndex {
            d,
            codes,
            lo,
            scale,
            keys: keys.clone(),
            rerank: 32,
        }
    }

    /// Approximate inner product against a stored code.
    #[inline]
    fn approx_score(&self, query: &[f32], code: &[u8], q_dot_lo: f32) -> f32 {
        let mut s = 0.0f32;
        for j in 0..self.d {
            s += query[j] * self.scale[j] * code[j] as f32;
        }
        s + q_dot_lo
    }

    /// Deserialize from an artifact payload (see [`crate::index::artifact`]).
    pub(crate) fn read_payload(r: &mut dyn Read) -> Result<SqIndex> {
        let d = artifact::r_u64(r)? as usize;
        let codes = artifact::r_u8s(r)?;
        let lo = artifact::r_f32s(r)?;
        let scale = artifact::r_f32s(r)?;
        let keys = artifact::r_tensor(r)?;
        let rerank = artifact::r_u64(r)? as usize;
        ensure!(
            lo.len() == d
                && scale.len() == d
                && keys.row_width() == d
                && codes.len() == keys.rows() * d,
            "inconsistent SQ8 payload: d={d}, {} lo, {} scale, {} codes, {} keys",
            lo.len(),
            scale.len(),
            codes.len(),
            keys.rows()
        );
        Ok(SqIndex {
            d,
            codes,
            lo,
            scale,
            keys,
            rerank,
        })
    }
}

impl VectorIndex for SqIndex {
    fn name(&self) -> &str {
        "sq8"
    }

    fn len(&self) -> usize {
        if self.d == 0 {
            0
        } else {
            self.codes.len() / self.d
        }
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        let n = self.len();
        let d = self.d;
        let rerank = rerank_depth(n, k, self.rerank, effort);
        // constant part of every dequantized score: <q, lo>
        let q_dot_lo = dot(query, &self.lo);
        let mut cand = TopK::new(rerank);
        for i in 0..n {
            let s = self.approx_score(query, &self.codes[i * d..(i + 1) * d], q_dot_lo);
            cand.push(s, i as u32);
        }
        let (cand_ids, _) = cand.into_sorted();
        let mut top = TopK::new(k);
        for &id in &cand_ids {
            top.push(dot(query, self.keys.row(id as usize)), id);
        }
        let (ids, scores) = top.into_sorted();
        // quantized scan is 2 ops/dim (mul+add) like a dot, plus re-rank
        let flops = (n * d * 2) as u64 + (cand_ids.len() * d * 2) as u64;
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops,
                keys_scanned: n as u64,
                cells_probed: 0,
            },
        }
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Sq(SqSpec)
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        artifact::w_u64(w, self.d as u64)?;
        artifact::w_u8s(w, &self.codes)?;
        artifact::w_f32s(w, &self.lo)?;
        artifact::w_f32s(w, &self.scale)?;
        artifact::w_tensor(w, &self.keys)?;
        artifact::w_u64(w, self.rerank as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit_keys(n: usize, d: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn quantized_scores_approximate_exact() {
        let keys = unit_keys(300, 16, 1);
        let idx = SqIndex::build(&keys);
        let q = unit_keys(10, 16, 2);
        let mut err = 0.0f64;
        for i in 0..10 {
            let q_dot_lo = dot(q.row(i), &idx.lo);
            for kidx in 0..300 {
                let approx =
                    idx.approx_score(q.row(i), &idx.codes[kidx * 16..(kidx + 1) * 16], q_dot_lo);
                let exact = dot(q.row(i), keys.row(kidx));
                err += ((approx - exact) as f64).abs();
            }
        }
        let mae = err / (10.0 * 300.0);
        assert!(mae < 0.02, "SQ8 mean abs err {mae}");
    }

    #[test]
    fn exhaustive_effort_is_exact() {
        let keys = unit_keys(400, 16, 3);
        let idx = SqIndex::build(&keys);
        let q = unit_keys(10, 16, 4);
        for i in 0..10 {
            let res = idx.search_effort(q.row(i), 1, Effort::Exhaustive);
            let mut best = (0u32, f32::NEG_INFINITY);
            for kidx in 0..400 {
                let s = dot(q.row(i), keys.row(kidx));
                if s > best.1 {
                    best = (kidx as u32, s);
                }
            }
            assert_eq!(res.ids[0], best.0, "query {i}");
        }
    }

    #[test]
    fn default_rerank_recall_reasonable() {
        let keys = unit_keys(500, 24, 5);
        let idx = SqIndex::build(&keys);
        let q = unit_keys(40, 24, 6);
        let mut hits = 0;
        for i in 0..40 {
            let truth = {
                let mut best = (0u32, f32::NEG_INFINITY);
                for kidx in 0..500 {
                    let s = dot(q.row(i), keys.row(kidx));
                    if s > best.1 {
                        best = (kidx as u32, s);
                    }
                }
                best.0
            };
            if idx.search_effort(q.row(i), 10, Effort::Auto).ids.contains(&truth) {
                hits += 1;
            }
        }
        assert!(hits >= 36, "recall@10 = {hits}/40");
    }
}
