//! SQ8 backbone (FAISS `IndexScalarQuantizer` analog): every dimension
//! quantized to 8 bits with per-dimension affine ranges, scored by
//! dequantized inner product, followed by exact re-ranking of the best
//! candidates. 4x memory compression on the scan path with near-flat
//! recall — the simplest compressed baseline the mapped/routed paths can
//! drop onto.
//!
//! Effort translation mirrors [`crate::index::pq::PqIndex`]: no coarse
//! cells; `Effort::Probes(p)` multiplies the base re-rank depth by `p`,
//! `Effort::Frac(f)` re-ranks `⌈f·n⌉` candidates exactly and
//! `Effort::Exhaustive` re-ranks everything (exact).

use anyhow::{ensure, Result};

use crate::api::Effort;
use crate::index::artifact::{self, Src};
use crate::index::spec::{IndexSpec, SqSpec};
use crate::index::traits::{rerank_depth, SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::mapped::Section;
use crate::tensor::{dot, Tensor};

pub struct SqIndex {
    d: usize,
    /// [n, d] u8 codes — a borrowed container view on the zero-copy
    /// artifact read path, owned RAM otherwise.
    codes: Section<u8>,
    /// Per-dimension dequantization: value = lo[j] + scale[j] * code.
    lo: Vec<f32>,
    scale: Vec<f32>,
    /// Full-precision keys for exact re-ranking.
    keys: Tensor,
    /// Default re-rank depth under `Effort::Auto` / `Effort::Probes`.
    pub rerank: usize,
}

impl SqIndex {
    pub fn build(keys: &Tensor) -> SqIndex {
        let (n, d) = (keys.rows(), keys.row_width());
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for i in 0..n {
            for (j, &v) in keys.row(i).iter().enumerate() {
                lo[j] = lo[j].min(v);
                hi[j] = hi[j].max(v);
            }
        }
        if n == 0 {
            lo.fill(0.0);
            hi.fill(0.0);
        }
        let scale: Vec<f32> = lo
            .iter()
            .zip(&hi)
            .map(|(&l, &h)| ((h - l) / 255.0).max(f32::MIN_POSITIVE))
            .collect();
        let mut codes = vec![0u8; n * d];
        for i in 0..n {
            let row = keys.row(i);
            for j in 0..d {
                let q = ((row[j] - lo[j]) / scale[j]).round().clamp(0.0, 255.0);
                codes[i * d + j] = q as u8;
            }
        }
        SqIndex {
            d,
            codes: Section::owned(codes),
            lo,
            scale,
            keys: keys.clone(),
            rerank: 32,
        }
    }

    /// The per-query dequantization transform, computed once per query
    /// and reused across every code row: `qs[j] = query[j] * scale[j]`
    /// and the constant `<query, lo>`. [`SqIndex::scaled_score`] then
    /// needs one multiply-add per dimension. `(query[j] * scale[j]) *
    /// code[j]` associates exactly like the old fused expression, so
    /// scores are bit-identical to the pre-transform path.
    fn query_transform(&self, query: &[f32]) -> (Vec<f32>, f32) {
        let qs: Vec<f32> = query.iter().zip(&self.scale).map(|(&q, &s)| q * s).collect();
        (qs, dot(query, &self.lo))
    }

    /// Approximate inner product of a transformed query against a code,
    /// through the dispatched dequant-dot kernel
    /// ([`crate::tensor::kernels::sq8_dot`]).
    #[inline]
    fn scaled_score(qs: &[f32], code: &[u8], q_dot_lo: f32) -> f32 {
        crate::tensor::kernels::sq8_dot(qs, code) + q_dot_lo
    }

    /// Stage 2 shared by the per-query and batched paths: exact re-rank
    /// of the quantized-scan candidates plus the cost assembly.
    fn rerank_exact(&self, query: &[f32], cand: TopK, k: usize, n: usize) -> SearchResult {
        let (cand_ids, _) = cand.into_sorted();
        let mut top = TopK::new(k);
        for &id in &cand_ids {
            top.offer(dot(query, self.keys.row(id as usize)), id);
        }
        let (ids, scores) = top.into_sorted();
        // quantized scan is 2 ops/dim (mul+add) like a dot, plus re-rank
        let flops = (n * self.d * 2) as u64 + (cand_ids.len() * self.d * 2) as u64;
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops,
                keys_scanned: n as u64,
                cells_probed: 0,
            },
        }
    }

    /// Deserialize from an artifact payload (see
    /// [`crate::index::artifact`]). At version ≥ 3 the code matrix and
    /// re-rank keys sit in aligned sections and come back as borrowed
    /// views of a mapped source; earlier versions decode by copy.
    pub(crate) fn read_payload(src: &mut Src, version: u32) -> Result<SqIndex> {
        let d = artifact::r_u64(&mut *src)? as usize;
        let codes = if version >= 3 {
            artifact::r_section::<u8>(src)?
        } else {
            Section::owned(artifact::r_u8s(&mut *src)?)
        };
        let lo = artifact::r_f32s(&mut *src)?;
        let scale = artifact::r_f32s(&mut *src)?;
        let keys = if version >= 3 {
            artifact::r_tensor_v3(src)?
        } else {
            artifact::r_tensor(&mut *src)?
        };
        let rerank = artifact::r_u64(&mut *src)? as usize;
        codes.advise_sequential();
        ensure!(
            lo.len() == d
                && scale.len() == d
                && keys.row_width() == d
                && codes.len() == keys.rows() * d,
            "inconsistent SQ8 payload: d={d}, {} lo, {} scale, {} codes, {} keys",
            lo.len(),
            scale.len(),
            codes.len(),
            keys.rows()
        );
        Ok(SqIndex {
            d,
            codes,
            lo,
            scale,
            keys,
            rerank,
        })
    }
}

impl VectorIndex for SqIndex {
    fn name(&self) -> &str {
        "sq8"
    }

    fn len(&self) -> usize {
        if self.d == 0 {
            0
        } else {
            self.codes.len() / self.d
        }
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        let n = self.len();
        let d = self.d;
        let rerank = rerank_depth(n, k, self.rerank, effort);
        let (qs, q_dot_lo) = self.query_transform(query);
        let mut cand = TopK::new(rerank);
        for i in 0..n {
            let s = Self::scaled_score(&qs, &self.codes[i * d..(i + 1) * d], q_dot_lo);
            cand.offer(s, i as u32);
        }
        self.rerank_exact(query, cand, k, n)
    }

    /// Fused batched scan: run the dequantization transform for every
    /// query up front, then stream the code matrix once, scoring all
    /// queries against each code row while it is hot. Bit-identical to
    /// per-query [`SqIndex::search_effort`].
    fn search_batch_effort(&self, queries: &Tensor, k: usize, effort: Effort) -> Vec<SearchResult> {
        let b = queries.rows();
        if b == 0 {
            return Vec::new();
        }
        let n = self.len();
        let d = self.d;
        let rerank = rerank_depth(n, k, self.rerank, effort);
        // Exhaustive-depth rerank would hold `b` candidate heaps of
        // capacity n at once; the per-row scan is bit-identical and
        // peaks at one heap (the exact re-rank dominates there anyway).
        if rerank >= n.max(1) {
            return (0..b)
                .map(|q| self.search_effort(queries.row(q), k, effort))
                .collect();
        }
        let transforms: Vec<(Vec<f32>, f32)> =
            (0..b).map(|q| self.query_transform(queries.row(q))).collect();
        let mut cands: Vec<TopK> = (0..b).map(|_| TopK::new(rerank)).collect();
        for i in 0..n {
            let code = &self.codes[i * d..(i + 1) * d];
            for (cand, (qs, q_dot_lo)) in cands.iter_mut().zip(&transforms) {
                cand.offer(Self::scaled_score(qs, code, *q_dot_lo), i as u32);
            }
        }
        cands
            .into_iter()
            .enumerate()
            .map(|(q, cand)| self.rerank_exact(queries.row(q), cand, k, n))
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Sq(SqSpec)
    }

    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        artifact::w_u64(w, self.d as u64)?;
        artifact::w_section_u8s(w, &self.codes)?;
        artifact::w_f32s(w, &self.lo)?;
        artifact::w_f32s(w, &self.scale)?;
        artifact::w_tensor_v3(w, &self.keys)?;
        artifact::w_u64(w, self.rerank as u64)
    }

    fn zero_copy(&self) -> bool {
        self.codes.is_view() && self.keys.is_view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit_keys(n: usize, d: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn quantized_scores_approximate_exact() {
        let keys = unit_keys(300, 16, 1);
        let idx = SqIndex::build(&keys);
        let q = unit_keys(10, 16, 2);
        let mut err = 0.0f64;
        for i in 0..10 {
            let (qs, q_dot_lo) = idx.query_transform(q.row(i));
            for kidx in 0..300 {
                let approx =
                    SqIndex::scaled_score(&qs, &idx.codes[kidx * 16..(kidx + 1) * 16], q_dot_lo);
                let exact = dot(q.row(i), keys.row(kidx));
                err += ((approx - exact) as f64).abs();
            }
        }
        let mae = err / (10.0 * 300.0);
        assert!(mae < 0.02, "SQ8 mean abs err {mae}");
    }

    #[test]
    fn exhaustive_effort_is_exact() {
        let keys = unit_keys(400, 16, 3);
        let idx = SqIndex::build(&keys);
        let q = unit_keys(10, 16, 4);
        for i in 0..10 {
            let res = idx.search_effort(q.row(i), 1, Effort::Exhaustive);
            let mut best = (0u32, f32::NEG_INFINITY);
            for kidx in 0..400 {
                let s = dot(q.row(i), keys.row(kidx));
                if s > best.1 {
                    best = (kidx as u32, s);
                }
            }
            assert_eq!(res.ids[0], best.0, "query {i}");
        }
    }

    #[test]
    fn batched_search_is_bit_identical_to_per_query() {
        let keys = unit_keys(220, 12, 7);
        let idx = SqIndex::build(&keys);
        let q = unit_keys(5, 12, 8);
        for effort in [Effort::Auto, Effort::Frac(0.3), Effort::Exhaustive] {
            let batched = idx.search_batch_effort(&q, 3, effort);
            for i in 0..5 {
                let single = idx.search_effort(q.row(i), 3, effort);
                assert_eq!(batched[i].ids, single.ids, "{effort:?} query {i}");
                assert_eq!(batched[i].scores, single.scores, "{effort:?} query {i}");
                assert_eq!(batched[i].cost, single.cost, "{effort:?} query {i}");
            }
        }
    }

    #[test]
    fn default_rerank_recall_reasonable() {
        let keys = unit_keys(500, 24, 5);
        let idx = SqIndex::build(&keys);
        let q = unit_keys(40, 24, 6);
        let mut hits = 0;
        for i in 0..40 {
            let truth = {
                let mut best = (0u32, f32::NEG_INFINITY);
                for kidx in 0..500 {
                    let s = dot(q.row(i), keys.row(kidx));
                    if s > best.1 {
                        best = (kidx as u32, s);
                    }
                }
                best.0
            };
            if idx.search_effort(q.row(i), 10, Effort::Auto).ids.contains(&truth) {
                hits += 1;
            }
        }
        assert!(hits >= 36, "recall@10 = {hits}/40");
    }
}
