//! ScaNN-analog backbone (Guo et al. 2020): IVF coarse cells +
//! anisotropic product quantization for in-cell scoring, followed by
//! exact re-ranking of the best ADC candidates.
//!
//! This is the "strongest learned-quantization baseline" of App. A.8: it
//! is already distribution-aware at index build time, so the margin that
//! KeyNet adds on top of it is the paper's most conservative claim.
//!
//! Effort translation: the probe count follows `Effort::resolve(nlist)`;
//! `Effort::Exhaustive` additionally widens the exact re-rank to every
//! scanned candidate, making the answer exact.

use std::io::Read;

use anyhow::{ensure, Result};

use crate::api::Effort;
use crate::index::artifact;
use crate::index::ivf::{invert_to_probers, rank_cells_tensor};
use crate::index::kmeans::KMeans;
use crate::index::pq::Pq;
use crate::index::spec::{IndexSpec, ScannSpec};
use crate::index::traits::{SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::{dot, Tensor};

pub struct ScannIndex {
    nlist: usize,
    d: usize,
    centroids: Tensor,
    /// Raw keys packed by cell (for exact re-ranking).
    packed: Tensor,
    codes: Vec<u8>, // [n, m] packed by cell
    ids: Vec<u32>,
    offsets: Vec<usize>,
    pq: Pq,
    /// Exact re-rank depth (candidates kept from the ADC pass).
    pub rerank: usize,
    /// PQ codebook training iterations (spec echo).
    iters: usize,
    /// Anisotropic parallel-error weight (spec echo).
    eta: f32,
}

impl ScannIndex {
    /// Build: `nlist` coarse cells (IVF-default Lloyd schedule), `m` PQ
    /// subspaces trained for `iters` iterations at anisotropy `eta`,
    /// with `bits`-wide codes (8 default, 4 packs two per byte).
    pub fn build(
        keys: &Tensor,
        nlist: usize,
        m: usize,
        iters: usize,
        eta: f32,
        bits: usize,
        seed: u64,
    ) -> ScannIndex {
        let n = keys.rows();
        let d = keys.row_width();
        let km = KMeans::fit(keys, nlist, 15, seed);
        // PQ trained on residual-free vectors (unit-norm data): simpler
        // and adequate at this scale; anisotropy is the differentiator.
        let pq = Pq::train_with_bits(keys, m, iters, eta, bits, seed ^ 0x5CA);

        let mut counts = vec![0usize; nlist];
        for &a in &km.assign {
            counts[a as usize] += 1;
        }
        let mut offsets = vec![0usize; nlist + 1];
        for j in 0..nlist {
            offsets[j + 1] = offsets[j] + counts[j];
        }
        let mut cursor = offsets.clone();
        let mut packed = Tensor::zeros(&[n, d]);
        let mut ids = vec![0u32; n];
        for i in 0..n {
            let cell = km.assign[i] as usize;
            let pos = cursor[cell];
            cursor[cell] += 1;
            packed.row_mut(pos).copy_from_slice(keys.row(i));
            ids[pos] = i as u32;
        }
        let codes = pq.encode(&packed);
        ScannIndex {
            nlist,
            d,
            centroids: km.centroids,
            packed,
            codes,
            ids,
            offsets,
            pq,
            rerank: 32,
            iters,
            eta,
        }
    }

    /// Deserialize from an artifact payload (see [`crate::index::artifact`]).
    /// Version-1 payloads carry an 8-bit-only [`Pq`].
    pub(crate) fn read_payload(r: &mut dyn Read, version: u32) -> Result<ScannIndex> {
        let centroids = artifact::r_tensor(r)?;
        let packed = artifact::r_tensor(r)?;
        let codes = artifact::r_u8s(r)?;
        let ids = artifact::r_u32s(r)?;
        let offsets = artifact::r_usizes(r)?;
        let pq = Pq::read_payload(r, version)?;
        // rerank > len behaves identically to len (at most len candidates
        // exist), so clamping keeps search semantics while preventing a
        // crafted huge value from blowing up TopK's preallocation
        let rerank = (artifact::r_u64(r)? as usize).min(ids.len().max(1));
        let iters = artifact::r_u64(r)? as usize;
        let eta = artifact::r_f32(r)?;
        let nlist = centroids.rows();
        let d = packed.row_width();
        ensure!(
            nlist >= 1
                && centroids.row_width() == d
                && d == pq.m * pq.dsub
                && packed.rows() == ids.len()
                && codes.len() == ids.len() * pq.code_width()
                && offsets.len() == nlist + 1
                && offsets.last().copied() == Some(ids.len())
                && offsets.windows(2).all(|w| w[0] <= w[1]),
            "inconsistent ScaNN payload: {} cells, {} packed rows, {} ids, {} codes, {} offsets",
            nlist,
            packed.rows(),
            ids.len(),
            codes.len(),
            offsets.len()
        );
        Ok(ScannIndex {
            nlist,
            d,
            centroids,
            packed,
            codes,
            ids,
            offsets,
            pq,
            rerank,
            iters,
            eta,
        })
    }

    fn search_probes(&self, query: &[f32], k: usize, nprobe: usize, rerank: usize) -> SearchResult {
        let nprobe = nprobe.clamp(1, self.nlist);
        // 1. coarse: rank cells by centroid score
        let mut cell_top = TopK::new(nprobe);
        for j in 0..self.nlist {
            cell_top.offer(dot(query, self.centroids.row(j)), j as u32);
        }
        let (cells, _) = cell_top.into_sorted();

        // 2. ADC scan of probed cells
        let table = self.pq.adc_table(query);
        let cw = self.pq.code_width();
        let mut cand = TopK::new(rerank.max(k));
        let mut scanned = 0u64;
        for &cell in &cells {
            let (s, e) = (self.offsets[cell as usize], self.offsets[cell as usize + 1]);
            for pos in s..e {
                let score = self
                    .pq
                    .adc_score(&table, &self.codes[pos * cw..(pos + 1) * cw]);
                cand.offer(score, pos as u32);
            }
            scanned += (e - s) as u64;
        }

        // 3. exact re-rank of the candidates
        self.rerank_exact(query, cand, k, scanned, nprobe)
    }

    /// Stage 3 shared by the per-query and batched paths: exact re-rank
    /// of the ADC candidates (addressed by packed position) plus the
    /// cost assembly.
    fn rerank_exact(
        &self,
        query: &[f32],
        cand: TopK,
        k: usize,
        scanned: u64,
        nprobe: usize,
    ) -> SearchResult {
        let (cand_pos, _) = cand.into_sorted();
        let mut top = TopK::new(k);
        for &pos in &cand_pos {
            let exact = dot(query, self.packed.row(pos as usize));
            top.offer(exact, self.ids[pos as usize]);
        }
        let (ids, scores) = top.into_sorted();
        let flops = (self.nlist * self.d * 2) as u64        // coarse
            + self.pq.table_flops()                          // ADC table
            + scanned * self.pq.m as u64                     // lookups+adds
            + (cand_pos.len() * self.d * 2) as u64; // re-rank
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops,
                keys_scanned: scanned,
                cells_probed: nprobe as u64,
            },
        }
    }
}

impl VectorIndex for ScannIndex {
    fn name(&self) -> &str {
        "scann"
    }

    fn len(&self) -> usize {
        self.ids.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn n_cells(&self) -> usize {
        self.nlist
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        let rerank = if effort.is_exhaustive() {
            self.len()
        } else {
            self.rerank
        };
        self.search_probes(query, k, effort.resolve(self.nlist), rerank)
    }

    /// Fused batched probe: batch × centroids as one gemm tile, all ADC
    /// tables in one pass ([`Pq::adc_tables_batch`]), then a grouped
    /// cell scan streaming each probed cell's codes once for every
    /// query probing it, and per-query exact re-rank. Bit-identical to
    /// per-query [`ScannIndex::search_effort`].
    fn search_batch_effort(&self, queries: &Tensor, k: usize, effort: Effort) -> Vec<SearchResult> {
        let b = queries.rows();
        if b == 0 {
            return Vec::new();
        }
        let nprobe = effort.resolve(self.nlist).clamp(1, self.nlist);
        let rerank = if effort.is_exhaustive() {
            self.len()
        } else {
            self.rerank
        };
        // Exhaustive-depth rerank would hold `b` candidate heaps of
        // capacity n at once; the per-row scan is bit-identical and
        // peaks at one heap (the exact re-rank dominates there anyway).
        if rerank.max(k) >= self.len().max(1) {
            return (0..b)
                .map(|q| self.search_effort(queries.row(q), k, effort))
                .collect();
        }
        // 1. coarse: batch × centroids in one tile kernel
        let cells = rank_cells_tensor(queries, &self.centroids, nprobe);
        let probers = invert_to_probers(&cells, self.nlist);
        // 2. grouped ADC scan with per-batch tables
        let tables = self.pq.adc_tables_batch(queries);
        let cw = self.pq.code_width();
        let tw = self.pq.table_width();
        let mut cands: Vec<TopK> = (0..b).map(|_| TopK::new(rerank.max(k))).collect();
        let mut scanned = vec![0u64; b];
        for (cell, qs) in probers.iter().enumerate() {
            if qs.is_empty() {
                continue;
            }
            let (s, e) = (self.offsets[cell], self.offsets[cell + 1]);
            for pos in s..e {
                let code = &self.codes[pos * cw..(pos + 1) * cw];
                for &q in qs {
                    let q = q as usize;
                    cands[q].offer(
                        self.pq.adc_score(&tables[q * tw..(q + 1) * tw], code),
                        pos as u32,
                    );
                }
            }
            for &q in qs {
                scanned[q as usize] += (e - s) as u64;
            }
        }
        // 3. per-query exact re-rank
        cands
            .into_iter()
            .enumerate()
            .map(|(q, cand)| self.rerank_exact(queries.row(q), cand, k, scanned[q], nprobe))
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Scann(ScannSpec {
            nlist: self.nlist,
            m: Some(self.pq.m),
            iters: self.iters,
            eta: self.eta,
            bits: self.pq.bits(),
        })
    }

    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        artifact::w_tensor(w, &self.centroids)?;
        artifact::w_tensor(w, &self.packed)?;
        artifact::w_u8s(w, &self.codes)?;
        artifact::w_u32s(w, &self.ids)?;
        artifact::w_usizes(w, &self.offsets)?;
        self.pq.write_payload(w)?;
        artifact::w_u64(w, self.rerank as u64)?;
        artifact::w_u64(w, self.iters as u64)?;
        artifact::w_f32(w, self.eta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::flat::FlatIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit_keys(n: usize, d: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn high_probe_recall_reasonable() {
        let keys = unit_keys(600, 32, 1);
        let scann = ScannIndex::build(&keys, 12, 8, 10, 4.0, 8, 2);
        let flat = FlatIndex::new(keys.clone());
        let q = unit_keys(40, 32, 3);
        let mut hits = 0;
        for i in 0..40 {
            let truth = flat.search_effort(q.row(i), 1, Effort::Exhaustive).ids[0];
            let got = scann.search_effort(q.row(i), 10, Effort::Probes(12));
            if got.ids.contains(&truth) {
                hits += 1;
            }
        }
        assert!(hits >= 34, "recall@10 full-probe = {hits}/40");
    }

    #[test]
    fn exhaustive_effort_is_exact() {
        // holds for both code widths: Exhaustive re-ranks every scanned
        // candidate against the exact f32 keys, so even 16-codeword ADC
        // cannot drop the true top-k
        let keys = unit_keys(400, 32, 10);
        let flat = FlatIndex::new(keys.clone());
        let q = unit_keys(15, 32, 12);
        for bits in [8usize, 4] {
            let scann = ScannIndex::build(&keys, 8, 8, 10, 4.0, bits, 11);
            for i in 0..15 {
                let a = scann.search_effort(q.row(i), 3, Effort::Exhaustive);
                let b = flat.search_effort(q.row(i), 3, Effort::Exhaustive);
                assert_eq!(a.ids, b.ids, "bits={bits} query {i}");
            }
        }
    }

    #[test]
    fn cost_cheaper_than_flat_scan() {
        // ADC scoring must cost far fewer flops than exact scan at the
        // same number of keys visited.
        let keys = unit_keys(800, 32, 4);
        let scann = ScannIndex::build(&keys, 8, 8, 10, 4.0, 8, 5);
        let q = unit_keys(1, 32, 6);
        let res = scann.search_effort(q.row(0), 1, Effort::Probes(8)); // all cells
        let flat_flops = (800 * 32 * 2) as u64;
        assert!(
            res.cost.flops < flat_flops,
            "scann {} vs flat {}",
            res.cost.flops,
            flat_flops
        );
    }

    #[test]
    fn batched_search_is_bit_identical_to_per_query() {
        let keys = unit_keys(300, 16, 13);
        let q = unit_keys(7, 16, 15);
        for bits in [8usize, 4] {
            let scann = ScannIndex::build(&keys, 6, 4, 8, 4.0, bits, 14);
            for effort in [Effort::Probes(2), Effort::Auto, Effort::Exhaustive] {
                let batched = scann.search_batch_effort(&q, 4, effort);
                for i in 0..7 {
                    let single = scann.search_effort(q.row(i), 4, effort);
                    assert_eq!(batched[i].ids, single.ids, "bits={bits} {effort:?} query {i}");
                    assert_eq!(
                        batched[i].scores, single.scores,
                        "bits={bits} {effort:?} query {i}"
                    );
                    assert_eq!(
                        batched[i].cost, single.cost,
                        "bits={bits} {effort:?} query {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn results_sorted_and_unique() {
        let keys = unit_keys(300, 16, 7);
        let scann = ScannIndex::build(&keys, 6, 4, 10, 4.0, 8, 8);
        let q = unit_keys(1, 16, 9);
        let res = scann.search_effort(q.row(0), 8, Effort::Probes(3));
        for w in res.scores.windows(2) {
            assert!(w[0] >= w[1]);
        }
        let mut ids = res.ids.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), res.ids.len());
    }
}
