//! Common interface for all index backbones. The integration experiments
//! (Figs. 5, 16–28) swap backbones behind this trait and swap the *query*
//! between the original `x` and KeyNet's mapped `ŷ(x)` — the index itself
//! is never modified, which is the paper's drop-in claim.
//!
//! Backbones expose one typed entry point, [`VectorIndex::search_effort`]:
//! each backbone translates the [`Effort`] level into its native knob
//! (probe count, re-rank depth). The old positional
//! `search(query, k, nprobe)` is gone from the public surface; batching,
//! query mapping and routing live in [`crate::api`].

use std::io::Write;

use anyhow::Result;

use crate::api::Effort;
use crate::index::spec::IndexSpec;
use crate::tensor::Tensor;

/// Cost accounting for one backbone scan, used for the FLOPs axes of
/// every Pareto plot. Distances are multiply-add pairs (2 flops each).
/// Aggregated into [`crate::api::CostBreakdown`] by the API layer.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SearchCost {
    /// f32 multiply-adds spent scoring (coarse + fine).
    pub flops: u64,
    /// Number of database vectors fully scored.
    pub keys_scanned: u64,
    /// Number of coarse cells probed.
    pub cells_probed: u64,
}

impl SearchCost {
    pub fn add(&mut self, other: SearchCost) {
        self.flops += other.flops;
        self.keys_scanned += other.keys_scanned;
        self.cells_probed += other.cells_probed;
    }
}

/// Result list for one query: key ids sorted by descending score.
#[derive(Clone, Debug, Default)]
pub struct SearchResult {
    pub ids: Vec<u32>,
    pub scores: Vec<f32>,
    pub cost: SearchCost,
}

/// A maximum-inner-product index over a fixed key set.
///
/// Implementations get a batched [`crate::api::Searcher`] for free via
/// the blanket impl in `api::searcher` (parallel over the thread pool).
pub trait VectorIndex: Send + Sync {
    /// Human-readable backbone name ("ivf", "scann", …).
    fn name(&self) -> &str;

    /// Number of indexed keys.
    fn len(&self) -> usize;

    /// Key dimensionality.
    fn dim(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of coarse partitions an [`Effort`] can probe. Exhaustive
    /// backbones (flat, pq, sq8) report 1.
    fn n_cells(&self) -> usize {
        1
    }

    /// Top-`k` search at a typed effort level. [`Effort::Exhaustive`]
    /// must return the exact MIPS answer on every backbone.
    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult;

    /// Batched top-`k` search over `queries` [b, d]: one
    /// [`SearchResult`] per query row.
    ///
    /// Contract: every per-query result — ids, scores *and*
    /// [`SearchCost`] — is bit-identical to calling
    /// [`VectorIndex::search_effort`] on that row alone (enforced by
    /// the `searcher_conformance` sweep). The default maps per query;
    /// backbones override it with fused kernels that stream keys,
    /// centroids and ADC tables once per *tile* instead of once per
    /// query. Implementations are sequential — callers own parallelism
    /// (the blanket [`crate::api::Searcher`] impl splits batches into
    /// per-worker sub-batches before calling this).
    fn search_batch_effort(&self, queries: &Tensor, k: usize, effort: Effort) -> Vec<SearchResult> {
        (0..queries.rows())
            .map(|i| self.search_effort(queries.row(i), k, effort))
            .collect()
    }

    /// The typed [`IndexSpec`] this index was built from, reconstructed
    /// from its stored knobs (auto knobs appear resolved). Echoed into
    /// the artifact header and the catalog manifest.
    fn spec(&self) -> IndexSpec;

    /// Serialize the backbone-specific payload (trained state + packed
    /// storage, no framing). Each backbone pairs this with an inherent
    /// `read_payload` constructor; the framed artifact around it lives
    /// in [`crate::index::artifact`]. The sink is a `Vec<u8>` (not
    /// `dyn Write`) because the aligned v3 section codecs need the
    /// current payload offset to place their pads.
    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()>;

    /// Whether this index currently serves its bulk scan data (key
    /// matrices, code matrices) as borrowed views of a mapped artifact
    /// rather than owned RAM copies. `false` for backbones without a
    /// zero-copy read path and for anything built or decoded in RAM.
    fn zero_copy(&self) -> bool {
        false
    }

    /// Serialize the full versioned artifact: header (magic, version,
    /// backbone tag, dim, len, spec echo), payload, checksum. Reload
    /// with [`crate::index::load`] / [`crate::index::load_from`].
    fn save(&self, w: &mut dyn Write) -> Result<()> {
        let mut payload = Vec::new();
        self.write_payload(&mut payload)?;
        crate::index::artifact::write_framed(
            w,
            self.name(),
            self.dim(),
            self.len(),
            &self.spec().to_string(),
            &payload,
        )
    }
}

/// Translate an [`Effort`] into an exact re-rank depth for exhaustive
/// (cell-less) backbones like `PqIndex`/`SqIndex`: `Exhaustive` re-ranks
/// the whole database (exact answer), `Frac(f)` re-ranks `⌈f·n⌉`,
/// `Probes(p)` scales the backbone's base depth by `p` (so probe sweeps
/// trace a real effort axis), and `Auto` uses the base depth.
pub(crate) fn rerank_depth(n: usize, k: usize, base: usize, effort: Effort) -> usize {
    let depth = match effort {
        Effort::Exhaustive => n,
        Effort::Frac(f) => {
            let f = if f.is_finite() { f.clamp(0.0, 1.0) } else { 1.0 };
            (f as f64 * n as f64).ceil() as usize
        }
        Effort::Probes(p) => base.saturating_mul(p.max(1)),
        Effort::Auto => base,
    };
    depth.max(k).min(n.max(1))
}

/// Keep the `k` largest (score, id) pairs; tiny binary heap on arrays.
/// Deterministic: ties broken toward lower id. NaN scores are treated as
/// worst-ranked (they enter as `-inf` and can never displace a real
/// score), so [`TopK::into_sorted`] never panics.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    /// min-heap by score: heap[0] is the current floor.
    heap: Vec<(f32, u32)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK {
            k: k.max(1),
            heap: Vec::with_capacity(k.max(1)),
        }
    }

    #[inline]
    fn less(a: (f32, u32), b: (f32, u32)) -> bool {
        // "smaller" = worse: lower score, or equal score with higher id.
        a.0 < b.0 || (a.0 == b.0 && a.1 > b.1)
    }

    #[inline]
    pub fn floor(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::NEG_INFINITY
        } else {
            self.heap[0].0
        }
    }

    #[inline]
    pub fn push(&mut self, score: f32, id: u32) {
        // NaN would poison the heap invariant (all comparisons false):
        // rank it below every real score instead of panicking later.
        let score = if score.is_nan() {
            f32::NEG_INFINITY
        } else {
            score
        };
        if self.heap.len() < self.k {
            self.heap.push((score, id));
            let mut i = self.heap.len() - 1;
            while i > 0 {
                let p = (i - 1) / 2;
                if Self::less(self.heap[i], self.heap[p]) {
                    self.heap.swap(i, p);
                    i = p;
                } else {
                    break;
                }
            }
        } else if Self::less(self.heap[0], (score, id)) {
            self.heap[0] = (score, id);
            // sift down
            let mut i = 0;
            loop {
                let (l, r) = (2 * i + 1, 2 * i + 2);
                let mut m = i;
                if l < self.heap.len() && Self::less(self.heap[l], self.heap[m]) {
                    m = l;
                }
                if r < self.heap.len() && Self::less(self.heap[r], self.heap[m]) {
                    m = r;
                }
                if m == i {
                    break;
                }
                self.heap.swap(i, m);
                i = m;
            }
        }
    }

    /// [`TopK::push`] with an early-reject fast path for scan loops:
    /// a candidate strictly below the floor can never enter the heap
    /// (a full heap only admits scores that beat — or tie at lower id
    /// with — its minimum, and a non-full heap has floor `-inf`, which
    /// no score is strictly below). NaN fails the `<` comparison and
    /// falls through to `push`, which ranks it as `-inf` — so `offer`
    /// is result-identical to `push` on every input stream
    /// (property-tested in `tests/properties.rs`), while skipping the
    /// sift machinery for the common below-floor candidate.
    #[inline]
    pub fn offer(&mut self, score: f32, id: u32) {
        if score < self.floor() {
            return;
        }
        self.push(score, id);
    }

    /// Offer a contiguous block of scores for consecutive ids
    /// `first_id..first_id + scores.len()` — the batched-scan fast
    /// path. A SIMD compare ([`kernels::not_below_mask`]) drops whole
    /// lanes strictly below the floor before any heap work.
    ///
    /// Result-identical to calling [`TopK::offer`] per element: the
    /// mask is computed against the floor at the *start* of each chunk,
    /// which can only be ≤ the live floor — so every dropped candidate
    /// (strictly below the stale floor, hence below the live one, and
    /// never NaN since NaN fails `<`) is one `offer` would also have
    /// rejected, and every survivor goes through the same `offer`.
    /// TopK selection is push-order independent, so admitting a
    /// soon-to-be-evicted candidate never changes the final set.
    ///
    /// [`kernels::not_below_mask`]: crate::tensor::kernels::not_below_mask
    pub fn offer_block(&mut self, scores: &[f32], first_id: u32) {
        use crate::tensor::kernels;
        let w = kernels::prefilter_width();
        for (c, chunk) in scores.chunks(w).enumerate() {
            let base = first_id + (c * w) as u32;
            let mut mask = kernels::not_below_mask(chunk, self.floor());
            while mask != 0 {
                let i = mask.trailing_zeros() as usize;
                mask &= mask - 1;
                self.offer(chunk[i], base + i as u32);
            }
        }
    }

    /// Drain into descending-score order.
    pub fn into_sorted(mut self) -> (Vec<u32>, Vec<f32>) {
        // `push` maps NaN to -inf, so partial_cmp cannot fail here; the
        // fallback keeps this total anyway.
        self.heap.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        let ids = self.heap.iter().map(|e| e.1).collect();
        let scores = self.heap.iter().map(|e| e.0).collect();
        (ids, scores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_keeps_best() {
        let mut t = TopK::new(3);
        for (i, s) in [0.1f32, 0.9, 0.5, 0.7, 0.2, 0.8].iter().enumerate() {
            t.push(*s, i as u32);
        }
        let (ids, scores) = t.into_sorted();
        assert_eq!(ids, vec![1, 5, 3]);
        assert_eq!(scores, vec![0.9, 0.8, 0.7]);
    }

    #[test]
    fn topk_ties_prefer_lower_id() {
        let mut t = TopK::new(2);
        t.push(0.5, 7);
        t.push(0.5, 1);
        t.push(0.5, 3);
        let (ids, _) = t.into_sorted();
        assert_eq!(ids, vec![1, 3]);
    }

    #[test]
    fn topk_fewer_than_k() {
        let mut t = TopK::new(10);
        t.push(1.0, 0);
        t.push(2.0, 1);
        let (ids, scores) = t.into_sorted();
        assert_eq!(ids, vec![1, 0]);
        assert_eq!(scores, vec![2.0, 1.0]);
    }

    #[test]
    fn topk_floor_transitions() {
        let mut t = TopK::new(2);
        assert_eq!(t.floor(), f32::NEG_INFINITY);
        t.push(0.3, 0);
        assert_eq!(t.floor(), f32::NEG_INFINITY);
        t.push(0.9, 1);
        assert_eq!(t.floor(), 0.3);
        t.push(0.5, 2);
        assert_eq!(t.floor(), 0.5);
    }

    #[test]
    fn topk_offer_equals_push_on_edge_streams() {
        // ties at the floor, NaN into a non-full heap, and exact-floor
        // candidates must all behave identically through the fast path
        let streams: &[&[f32]] = &[
            &[0.5, 0.5, 0.5, 0.5],
            &[f32::NAN, 0.1, f32::NAN],
            &[1.0, 0.2, 0.2, 0.9, 0.2],
            &[f32::NEG_INFINITY, f32::INFINITY, 0.0],
        ];
        for scores in streams {
            let mut a = TopK::new(2);
            let mut b = TopK::new(2);
            for (i, &s) in scores.iter().enumerate() {
                a.push(s, i as u32);
                b.offer(s, i as u32);
            }
            assert_eq!(a.into_sorted(), b.into_sorted(), "{scores:?}");
        }
    }

    #[test]
    fn topk_offer_block_equals_offer_per_element() {
        use crate::util::Rng;
        let mut rng = Rng::new(42);
        for n in [0usize, 1, 7, 8, 9, 63, 200] {
            let mut scores = vec![0.0f32; n];
            rng.fill_normal(&mut scores, 1.0);
            if n > 4 {
                scores[1] = f32::NAN;
                scores[3] = f32::INFINITY;
                scores[4] = f32::NEG_INFINITY;
            }
            for k in [1usize, 3, 16] {
                let mut a = TopK::new(k);
                let mut b = TopK::new(k);
                for (i, &s) in scores.iter().enumerate() {
                    a.offer(s, 100 + i as u32);
                }
                b.offer_block(&scores, 100);
                assert_eq!(a.into_sorted(), b.into_sorted(), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn topk_nan_ranked_worst_and_never_panics() {
        // regression: a NaN score used to poison the heap comparisons and
        // panic in into_sorted's partial_cmp().unwrap()
        let mut t = TopK::new(3);
        t.push(f32::NAN, 0);
        t.push(0.5, 1);
        t.push(f32::NAN, 2);
        t.push(0.9, 3);
        let (ids, scores) = t.into_sorted();
        assert_eq!(ids[0], 3);
        assert_eq!(ids[1], 1);
        assert_eq!(scores[0], 0.9);
        // the NaN survivor ranks last, as -inf
        assert_eq!(scores[2], f32::NEG_INFINITY);

        // a full heap of real scores never admits NaN
        let mut t = TopK::new(2);
        t.push(0.1, 0);
        t.push(0.2, 1);
        t.push(f32::NAN, 2);
        let (ids, _) = t.into_sorted();
        assert_eq!(ids, vec![1, 0]);
    }
}
